//! Paper-anchor checks: every number the paper prints in its text is
//! asserted here against our reproduction (shape-level tolerance; our
//! substrate is not the authors' machine, but these are all
//! machine-independent LP optima, so most match tightly).

use dlt::cost::TradeoffTable;
use dlt::dlt::frontend::FeOptions;
use dlt::dlt::no_frontend::NfeOptions;
use dlt::dlt::Schedule;
use dlt::experiments::{params, run};

// The per-family solve forwards are gone: solve through the pipeline.
fn fe_solve(spec: &dlt::model::SystemSpec) -> dlt::error::Result<Schedule> {
    dlt::pipeline::solve(&FeOptions::default(), spec)
}

fn nfe_solve(spec: &dlt::model::SystemSpec) -> dlt::error::Result<Schedule> {
    dlt::pipeline::solve(&NfeOptions::default(), spec)
}

/// §6.2 / Fig. 16: Cost(6) = 3433.77, Cost(7) = 3451.67 dollars.
#[test]
fn fig16_cost_anchors() {
    let sweep = TradeoffTable::sweep(&params::table5()).unwrap();
    assert!((sweep.at(6).cost - 3433.77).abs() < 0.5, "cost(6) = {}", sweep.at(6).cost);
    assert!((sweep.at(7).cost - 3451.67).abs() < 0.5, "cost(7) = {}", sweep.at(7).cost);
}

/// §6.2 / Fig. 18: |gradient(5)| ≈ 8.4 %, |gradient(6)| ≈ 5.3 %.
#[test]
fn fig18_gradient_anchors() {
    let sweep = TradeoffTable::sweep(&params::table5()).unwrap();
    let g5 = sweep.gradients[3].abs() * 100.0;
    let g6 = sweep.gradients[4].abs() * 100.0;
    assert!((g5 - 8.4).abs() < 1.0, "gradient(5) = {g5}%");
    assert!((g6 - 5.3).abs() < 1.0, "gradient(6) = {g6}%");
}

/// §6.2: with a cost budget of $3450 the feasible counts are m <= 6,
/// and the 6% gradient rule recommends 5 processors.
#[test]
fn section_6_2_worked_example() {
    use dlt::cost::{advise, Advice, Budgets};
    let sweep = TradeoffTable::sweep(&params::table5()).unwrap();
    assert!(sweep.at(6).cost <= 3450.0);
    assert!(sweep.at(7).cost > 3450.0);
    match advise(
        &sweep,
        &Budgets { cost: Some(3450.0), time: None, gradient_threshold: 0.06 },
    ) {
        Advice::Use { m, .. } => assert_eq!(m, 5),
        other => panic!("{other:?}"),
    }
}

/// §5.2 / Fig. 15: speedups at 12 processors for 2/3/5/10 sources are
/// ≈ 1.59 / 1.90 / 2.21 / 2.49, and the quoted relative improvements
/// (3 vs 2 sources ≈ +19%, 10 vs 2 ≈ +57%) hold.
#[test]
fn fig15_speedup_anchors() {
    let t = run("fig15").unwrap();
    let r = 11; // m = 12
    let s2 = t.at(r, "speedup_2src");
    let s3 = t.at(r, "speedup_3src");
    let s5 = t.at(r, "speedup_5src");
    let s10 = t.at(r, "speedup_10src");
    for (got, paper) in [(s2, 1.59), (s3, 1.90), (s5, 2.21), (s10, 2.49)] {
        assert!((got - paper).abs() / paper < 0.15, "got {got}, paper {paper}");
    }
    let improvement_3v2 = (s3 / s2 - 1.0) * 100.0;
    let improvement_10v2 = (s10 / s2 - 1.0) * 100.0;
    assert!((improvement_3v2 - 19.0).abs() < 6.0, "3v2 = {improvement_3v2}%");
    assert!((improvement_10v2 - 57.0).abs() < 12.0, "10v2 = {improvement_10v2}%");
}

/// §4.3 / Fig. 13: at J = 500, going from 3 to 7 processors saves
/// about 50 % of the finish time.
#[test]
fn fig13_headline_saving() {
    let t = run("fig13").unwrap();
    let tf3 = t.at(2, "tf_J500");
    let tf7 = t.at(6, "tf_J500");
    let saving = (1.0 - tf7 / tf3) * 100.0;
    assert!((saving - 50.0).abs() < 10.0, "saving = {saving}% (paper ~50%)");
}

/// Fig. 12's qualitative claims: T_f decreases in both N and M with
/// diminishing returns in M.
#[test]
fn fig12_shape() {
    let t = run("fig12").unwrap();
    for col in ["tf_1src", "tf_2src", "tf_3src"] {
        let tf = t.column(col);
        assert!(tf.windows(2).all(|w| w[1] <= w[0] + 1e-6), "{col} not decreasing");
        // Diminishing returns: late deltas smaller than early ones.
        let d_early = tf[0] - tf[4];
        let d_late = tf[14] - tf[18];
        assert!(d_late < d_early, "{col}: no diminishing returns");
    }
    for r in 0..t.rows.len() {
        assert!(t.at(r, "tf_3src") <= t.at(r, "tf_2src") + 1e-6);
        assert!(t.at(r, "tf_2src") <= t.at(r, "tf_1src") + 1e-6);
    }
}

/// Fig. 19 / 20: the budget-overlap and no-overlap cases.
#[test]
fn fig19_20_solution_areas() {
    let f19 = run("fig19").unwrap();
    let both: Vec<f64> = f19.column("within_both");
    let count = both.iter().filter(|&&b| b > 0.5).count();
    assert_eq!(count, 7, "m = 6..=12 feasible");
    let f20 = run("fig20").unwrap();
    assert!(f20.column("within_both").iter().all(|&b| b < 0.5));
}

/// Table 1 front-end solve: release constraint binds exactly as the
/// paper's eq. 3 demands (β_{1,1} A_1 >= R_2 − R_1 = 40).
#[test]
fn table1_release_binding() {
    let spec = params::table1();
    let s = fe_solve(&spec).unwrap();
    assert!(s.beta(0, 0) * 2.0 >= 40.0 - 1e-6);
    // And the schedule validates.
    let rep = dlt::dlt::validate(&spec, &s);
    assert!(rep.is_valid(), "{:?}", rep.violations);
}

/// Table 2's published shape: without front-ends both sources feed
/// P1 more than the slower processors, and everything normalizes.
#[test]
fn table2_no_frontend_shape() {
    let spec = params::table2();
    let s = nfe_solve(&spec).unwrap();
    assert!((s.total_load() - 100.0).abs() < 1e-6);
    assert!(s.load_on_processor(0) > s.load_on_processor(1));
    assert!(s.load_on_processor(1) > s.load_on_processor(2));
}
