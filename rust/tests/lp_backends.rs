//! Back-end agreement properties: the sparse revised simplex (default)
//! vs the dense tableau (fallback/oracle) on randomized DLT LPs from
//! both frontends, warm-start equivalence, paper-anchor agreement, and
//! parallel-sweep determinism.

use dlt::dlt::schedule::TimingModel;
use dlt::dlt::{frontend, no_frontend};
use dlt::experiments::params;
use dlt::experiments::sweep::{job_grid, run_scenarios, SweepOptions};
use dlt::lp::{solve_warm, solve_with, LpProblem, SimplexOptions, SolverBackend};
use dlt::testkit::{arb_spec, props};

fn sweep_opts(threads: usize, warm_start: bool) -> SweepOptions {
    SweepOptions { threads, warm_start, steal: false, ..SweepOptions::default() }
}

fn dense() -> SimplexOptions {
    SimplexOptions { backend: SolverBackend::DenseTableau, ..SimplexOptions::default() }
}

fn revised() -> SimplexOptions {
    SimplexOptions::default()
}

/// Objectives agree within 1e-6 (relative) and the revised solution is
/// feasible for the original problem.
fn assert_backends_agree(lp: &LpProblem, ctx: &str) -> Result<(), String> {
    match (solve_with(lp, &revised()), solve_with(lp, &dense())) {
        (Ok(a), Ok(b)) => {
            let tol = 1e-6 * (1.0 + b.objective.abs());
            if (a.objective - b.objective).abs() > tol {
                return Err(format!(
                    "{ctx}: objectives differ: revised {} vs dense {}",
                    a.objective, b.objective
                ));
            }
            if let Some(v) = lp.check_feasible(&a.x, 1e-6) {
                return Err(format!("{ctx}: revised solution infeasible: {v}"));
            }
            Ok(())
        }
        (Err(_), Err(_)) => Ok(()), // both reject (e.g. infeasible spec)
        (a, b) => Err(format!("{ctx}: backends disagree on solvability: {a:?} vs {b:?}")),
    }
}

#[test]
fn prop_backends_agree_on_fe_lps() {
    props("revised == dense (fe)", 50, |g| {
        let spec = arb_spec(g, 4, 6);
        let lp = frontend::build_lp(&spec, &Default::default());
        assert_backends_agree(&lp, "fe")
    });
}

#[test]
fn prop_backends_agree_on_nfe_lps() {
    props("revised == dense (nfe)", 50, |g| {
        let spec = arb_spec(g, 3, 5);
        let lp = no_frontend::build_lp(&spec, &Default::default());
        assert_backends_agree(&lp, "nfe")
    });
}

/// Warm-starting from a perturbed instance's optimal basis reaches the
/// same optimum as a cold solve, without more iterations.
#[test]
fn prop_warm_start_matches_cold() {
    props("warm == cold", 40, |g| {
        let spec = arb_spec(g, 3, 5);
        let opts = revised();
        let base_lp = frontend::build_lp(&spec, &Default::default());
        let Ok(base) = solve_with(&base_lp, &opts) else { return Ok(()) };
        // Same structure, scaled job (rhs perturbation).
        let k = g.f64_in(0.5, 2.5);
        let lp2 = frontend::build_lp(&spec.with_job(spec.job * k), &Default::default());
        let Ok(cold) = solve_with(&lp2, &opts) else { return Ok(()) };
        let warm = solve_warm(&lp2, &opts, base.basis.as_ref()).map_err(|e| e.to_string())?;
        let tol = 1e-6 * (1.0 + cold.objective.abs());
        if (warm.objective - cold.objective).abs() > tol {
            return Err(format!("warm {} vs cold {}", warm.objective, cold.objective));
        }
        if let Some(v) = lp2.check_feasible(&warm.x, 1e-6) {
            return Err(format!("warm solution infeasible: {v}"));
        }
        if warm.iterations > cold.iterations {
            return Err(format!(
                "warm start took more iterations ({} > {})",
                warm.iterations, cold.iterations
            ));
        }
        Ok(())
    });
}

/// Acceptance: both backends agree on every paper-anchor instance.
#[test]
fn paper_anchor_instances_agree() {
    let cases: Vec<(&str, LpProblem)> = vec![
        ("table1 fe", frontend::build_lp(&params::table1(), &Default::default())),
        ("table2 nfe", no_frontend::build_lp(&params::table2(), &Default::default())),
        ("table3 fe", frontend::build_lp(&params::table3(), &Default::default())),
        ("table3 nfe", no_frontend::build_lp(&params::table3(), &Default::default())),
        ("table4 nfe", no_frontend::build_lp(&params::table4(), &Default::default())),
        ("table5 fe", frontend::build_lp(&params::table5(), &Default::default())),
    ];
    for (name, lp) in &cases {
        assert_backends_agree(lp, name).unwrap_or_else(|e| panic!("{e}"));
    }
    // Processor-count sub-instances of the Table 5 advisor sweep.
    let t5 = params::table5();
    for m in 1..=t5.m() {
        let lp = frontend::build_lp(&t5.with_m_processors(m), &Default::default());
        assert_backends_agree(&lp, &format!("table5 m={m}")).unwrap_or_else(|e| panic!("{e}"));
    }
}

/// The parallel sweep returns the same makespans as a serial sweep,
/// in the same order, regardless of thread count.
#[test]
fn parallel_sweep_is_deterministic() {
    let jobs: Vec<f64> = (0..24).map(|k| 60.0 + 20.0 * k as f64).collect();
    for model in [TimingModel::FrontEnd, TimingModel::NoFrontEnd] {
        // Table 2 for the NFE model: Table 1's releases (10, 50) make
        // the NFE LP infeasible below J = 200 (eq. 12 forces
        // beta[0][0] >= 200).
        let spec = match model {
            TimingModel::FrontEnd => params::table1(),
            TimingModel::NoFrontEnd => params::table2(),
        };
        let grid = job_grid(&spec, &jobs, model);
        let serial =
            run_scenarios(&grid, &sweep_opts(1, true)).unwrap();
        for threads in [2usize, 3, 8] {
            let par =
                run_scenarios(&grid, &sweep_opts(threads, true)).unwrap();
            assert_eq!(serial.len(), par.len());
            for (a, b) in serial.iter().zip(par.iter()) {
                assert_eq!(a.label, b.label);
                assert!(
                    (a.makespan - b.makespan).abs() < 1e-7 * (1.0 + a.makespan.abs()),
                    "{model:?} {}: serial {} vs {threads}-thread {}",
                    a.label,
                    a.makespan,
                    b.makespan
                );
            }
        }
    }
}

/// A warm sweep must not spend more total simplex iterations than the
/// same sweep solved cold — that is the whole point of basis reuse.
#[test]
fn warm_sweep_saves_iterations() {
    let spec = params::table1();
    let jobs: Vec<f64> = (0..32).map(|k| 80.0 + 10.0 * k as f64).collect();
    let grid = job_grid(&spec, &jobs, TimingModel::FrontEnd);
    let cold = run_scenarios(&grid, &sweep_opts(1, false)).unwrap();
    let warm = run_scenarios(&grid, &sweep_opts(1, true)).unwrap();
    let cold_iters: usize = cold.iter().map(|p| p.lp_iterations).sum();
    let warm_iters: usize = warm.iter().map(|p| p.lp_iterations).sum();
    assert!(
        warm_iters < cold_iters,
        "warm sweep should save iterations: warm {warm_iters} vs cold {cold_iters}"
    );
}
