//! Integration tests for the component-based cluster engine and its
//! divergence oracle ([`dlt::sim::replay`]):
//!
//! - **arena-order fuzz** — results are bit-identical under every
//!   component insertion order (the `(time, logical id, seq)`
//!   determinism contract), including with jitter and send gates;
//! - **legacy parity** — a greedy jitter-free (and jittered: the two
//!   engines share the shape-stable jitter hash) cluster run matches
//!   the legacy [`dlt::sim::engine`] to 1e-12 on the paper anchors;
//! - **LP reproduction** — the Schedule-gated replay reproduces the
//!   LP's promised `T_f` to 1e-9 on every paper table, both models;
//! - **injection monotonicity** — longer outages, more outages,
//!   redo-preemption vs resume-preemption, and link slowdowns can only
//!   delay the simulated makespan; and
//! - **seeded-random faults** — the same seed yields the identical
//!   `DivergenceReport`.

use dlt::dlt::frontend::FeOptions;
use dlt::dlt::no_frontend::NfeOptions;
use dlt::dlt::schedule::{Schedule, TimingModel};
use dlt::experiments::params;
use dlt::model::SystemSpec;
use dlt::sim::cluster::{ClusterSim, FaultSpec, InjectionPlan, LinkWindow, World};
use dlt::sim::replay::{replay, ReplayOptions};
use dlt::sim::{jitter, simulate, SimOptions};
use dlt::testkit::{arb_spec, props, Gen};

fn solve_for(spec: &SystemSpec, model: TimingModel) -> Schedule {
    match model {
        TimingModel::FrontEnd => dlt::pipeline::solve(&FeOptions::default(), spec).unwrap(),
        TimingModel::NoFrontEnd => dlt::pipeline::solve(&NfeOptions::default(), spec).unwrap(),
    }
}

fn assert_close(a: f64, b: f64, what: &str) {
    assert!((a - b).abs() <= 1e-12 * b.abs().max(1.0), "{what}: {a} vs {b}");
}

/// Build a world with randomized jitter factors and (sometimes) send
/// gates, deterministically from `g`'s draws.
fn fuzzed_world(
    spec: &SystemSpec,
    beta: &[f64],
    model: TimingModel,
    seed: u64,
    amp: f64,
    gates: &Option<Vec<f64>>,
) -> World {
    let (n, m) = (spec.n(), spec.m());
    let mut w = World::new(spec, beta, model);
    for i in 0..n {
        for j in 0..m {
            w.link_factor[i * m + j] = jitter::link_factor(seed, amp, i, j);
        }
    }
    for j in 0..m {
        w.comp_factor[j] = jitter::compute_factor(seed, amp, j);
    }
    w.gate_send = gates.clone();
    w
}

/// The determinism contract: every permutation of the component arena
/// produces bit-identical timing arrays and engine statistics.
#[test]
fn fuzz_arena_order_is_bit_identical() {
    props("arena order invariance", 60, |g: &mut Gen| {
        let spec = arb_spec(g, 4, 6);
        let (n, m) = (spec.n(), spec.m());
        let model = if g.bool() { TimingModel::FrontEnd } else { TimingModel::NoFrontEnd };
        let beta = g.f64_vec(n * m, 0.0, 40.0);
        let seed = g.usize_in(0, 1 << 20) as u64;
        let amp = if g.bool() { g.f64_in(0.0, 0.3) } else { 0.0 };
        let gates = if g.bool() { Some(g.f64_vec(n * m, 0.0, 5.0)) } else { None };

        let mut base = ClusterSim::new(fuzzed_world(&spec, &beta, model, seed, amp, &gates));
        base.run();

        // Fisher-Yates permutation of the arena insertion order.
        let mut order: Vec<usize> = (0..2 * n + m).collect();
        for k in (1..order.len()).rev() {
            order.swap(k, g.usize_in(0, k + 1));
        }
        let world = fuzzed_world(&spec, &beta, model, seed, amp, &gates);
        let mut other = ClusterSim::new_with_arena_order(world, &order);
        other.run();

        let (a, b) = (base.world(), other.world());
        if a.send_start != b.send_start || a.send_done != b.send_done {
            return Err(format!("send timing drifted under order {order:?}"));
        }
        if a.compute_done != b.compute_done {
            return Err(format!("compute timing drifted under order {order:?}"));
        }
        if base.stats() != other.stats() {
            return Err(format!("engine stats drifted under order {order:?}"));
        }
        Ok(())
    });
}

/// Greedy cluster runs match the legacy engine to 1e-12 on the paper
/// anchors — jitter-free and jittered (the engines share one
/// shape-stable jitter hash, so the factors are identical by
/// construction).
#[test]
fn asap_cluster_matches_legacy_engine_on_anchors() {
    let anchors = [
        ("table1/fe", params::table1(), TimingModel::FrontEnd),
        ("table2/nfe", params::table2(), TimingModel::NoFrontEnd),
        ("table3/nfe", params::table3(), TimingModel::NoFrontEnd),
        ("table5/fe", params::table5(), TimingModel::FrontEnd),
    ];
    for (name, spec, model) in anchors {
        let sched = solve_for(&spec, model);
        for (amp, seed) in [(0.0, 0u64), (0.1, 9)] {
            let legacy_opts = SimOptions {
                model,
                link_jitter: amp,
                compute_jitter: amp,
                seed,
                trace: false,
            };
            let legacy = simulate(&spec, &sched.beta, &legacy_opts);
            let world = fuzzed_world(&spec, &sched.beta, model, seed, amp, &None);
            let mut sim = ClusterSim::new(world);
            sim.run();
            let w = sim.world();
            let what = format!("{name} amp={amp}");
            assert_close(w.makespan(), legacy.makespan, &format!("{what}: makespan"));
            for k in 0..spec.n() * spec.m() {
                assert_close(w.send_start[k], legacy.send_start[k], &format!("{what}: ss[{k}]"));
                assert_close(w.send_done[k], legacy.send_done[k], &format!("{what}: sd[{k}]"));
            }
            for j in 0..spec.m() {
                let cd = format!("{what}: cd[{j}]");
                assert_close(w.compute_done[j], legacy.compute_done[j], &cd);
            }
        }
    }
}

/// The divergence-oracle acceptance bar: a jitter-free fault-free
/// Schedule-gated replay reproduces the LP's promised makespan to
/// 1e-9 relative gap, with no violated promises, on every paper
/// parameter table under both timing models.
#[test]
fn gated_replay_reproduces_lp_on_every_anchor() {
    let tables = [
        ("table1", params::table1()),
        ("table2", params::table2()),
        ("table3", params::table3()),
        ("table4", params::table4()),
        ("table5", params::table5()),
    ];
    for (name, spec) in tables {
        for model in [TimingModel::FrontEnd, TimingModel::NoFrontEnd] {
            let sched = solve_for(&spec, model);
            let rep = replay(&spec, &sched, &ReplayOptions::default()).unwrap();
            assert!(
                rep.rel_gap.abs() <= 1e-9,
                "{name}/{model:?}: rel gap {:+.3e} (sim {} vs LP {})",
                rep.rel_gap,
                rep.simulated_makespan,
                rep.predicted_makespan
            );
            assert!(
                rep.violated_constraints.is_empty(),
                "{name}/{model:?}: {:?}",
                rep.violated_constraints
            );
            assert!(rep.events > 0);
        }
    }
}

fn outage(processor: usize, at: f64, duration: f64) -> FaultSpec {
    FaultSpec { processor, at, duration: Some(duration), redo: true, blocks_recv: true }
}

fn makespan_under(spec: &SystemSpec, sched: &Schedule, plan: InjectionPlan) -> f64 {
    let opts = ReplayOptions { plan, ..ReplayOptions::default() };
    replay(spec, sched, &opts).unwrap().simulated_makespan
}

/// Injected adversity is monotone: a longer outage, or one more
/// outage, never finishes the job earlier.
#[test]
fn fault_injection_is_monotone() {
    let spec = params::table2();
    let sched = solve_for(&spec, TimingModel::NoFrontEnd);

    // Growing one outage's duration.
    let mut prev = makespan_under(&spec, &sched, InjectionPlan::default());
    for d in [0.5, 1.0, 2.0, 4.0] {
        let plan = InjectionPlan { faults: vec![outage(0, 1.0, d)], ..Default::default() };
        let cur = makespan_under(&spec, &sched, plan);
        assert!(cur >= prev, "duration {d}: {cur} < {prev}");
        prev = cur;
    }

    // Adding outages on more processors.
    let mut faults = Vec::new();
    let mut prev = makespan_under(&spec, &sched, InjectionPlan::default());
    for (p, at) in [(0usize, 1.0), (1, 2.0), (2, 3.0)] {
        faults.push(outage(p, at, 1.5));
        let plan = InjectionPlan { faults: faults.clone(), ..Default::default() };
        let cur = makespan_under(&spec, &sched, plan);
        assert!(cur >= prev, "{} outages: {cur} < {prev}", faults.len());
        prev = cur;
    }
}

/// Preemption ordering: clean ≤ pause-and-resume ≤ lose-and-redo for
/// the same window.
#[test]
fn preemption_resume_never_beats_clean_and_redo_never_beats_resume() {
    let spec = params::table2();
    let sched = solve_for(&spec, TimingModel::NoFrontEnd);
    let clean = makespan_under(&spec, &sched, InjectionPlan::default());
    let mid = sched.makespan * 0.6;
    let window = |redo: bool| InjectionPlan {
        faults: vec![FaultSpec {
            processor: 0,
            at: mid,
            duration: Some(2.0),
            redo,
            blocks_recv: false,
        }],
        ..Default::default()
    };
    let resume = makespan_under(&spec, &sched, window(false));
    let redo = makespan_under(&spec, &sched, window(true));
    assert!(resume >= clean, "resume {resume} < clean {clean}");
    assert!(redo >= resume, "redo {redo} < resume {resume}");
    assert!(redo > clean, "a mid-compute redo window must cost something");
}

/// Link capacity windows (the absorbed `sim::timevary` behavior): a
/// slowdown window only delays, and a factor-1.0 window is a bitwise
/// no-op.
#[test]
fn link_windows_slow_down_but_unit_factor_is_a_noop() {
    let spec = params::table2();
    let sched = solve_for(&spec, TimingModel::NoFrontEnd);
    let clean = makespan_under(&spec, &sched, InjectionPlan::default());

    let slow = InjectionPlan {
        link_windows: vec![LinkWindow { source: 0, from: 0.0, duration: 3.0, factor: 0.25 }],
        ..Default::default()
    };
    let slowed = makespan_under(&spec, &sched, slow);
    assert!(slowed > clean, "a 4x slowdown across the first sends must delay: {slowed}");

    let unit = InjectionPlan {
        link_windows: vec![LinkWindow { source: 0, from: 0.0, duration: 3.0, factor: 1.0 }],
        ..Default::default()
    };
    let same = makespan_under(&spec, &sched, unit);
    assert_eq!(same, clean, "factor-1.0 window changed the timeline");
}

/// Seeded-random faults are deterministic: the same seed produces the
/// identical report, a different seed is allowed to differ, and the
/// injected count is reported.
#[test]
fn random_faults_are_seed_deterministic() {
    let spec = params::table2();
    let sched = solve_for(&spec, TimingModel::NoFrontEnd);
    let opts = ReplayOptions {
        seed: 11,
        plan: InjectionPlan { random_faults: 2, ..Default::default() },
        ..Default::default()
    };
    let a = replay(&spec, &sched, &opts).unwrap();
    let b = replay(&spec, &sched, &opts).unwrap();
    assert_eq!(a, b, "same seed must reproduce the identical report");
    assert_eq!(a.faults_injected, 2);
    let clean = replay(&spec, &sched, &ReplayOptions::default()).unwrap();
    assert!(a.simulated_makespan >= clean.simulated_makespan);
}
