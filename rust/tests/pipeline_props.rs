//! Pipeline-level properties: presolve+solve vs raw solve agreement
//! (objective and restored duals) on randomized DLT LPs from every
//! scenario family, dual-simplex warm restarts without phase-1 work,
//! and cross-shape basis projection along processor-count sweeps.

use dlt::dlt::concurrent::{self, ConcurrentOptions};
use dlt::dlt::frontend::{self, FeOptions};
use dlt::dlt::multi_job::MultiJobStepModel;
use dlt::dlt::no_frontend::{self, NfeOptions};
use dlt::lp::presolve::presolve;
use dlt::lp::{solve_with, LpProblem, SimplexOptions};
use dlt::model::SystemSpec;
use dlt::pipeline::{self, PipelineOptions, ScenarioModel};
use dlt::testkit::{arb_spec, props, Gen};

/// Solve `lp` raw and through presolve+restore; check the objectives
/// agree within 1e-9 (relative) and that the restored duals are per
/// *original* row and satisfy strong duality there.
fn assert_presolve_agrees(lp: &LpProblem, ctx: &str) -> Result<(), String> {
    let opts = SimplexOptions::default();
    let raw = solve_with(lp, &opts);
    let pre = match presolve(lp) {
        Ok(pre) => pre,
        Err(_) => {
            // Presolve proved infeasibility: the raw solve must agree.
            return match raw {
                Err(_) => Ok(()),
                Ok(s) => {
                    Err(format!("{ctx}: presolve infeasible but raw solved to {}", s.objective))
                }
            };
        }
    };
    let red = solve_with(&pre.problem, &opts);
    match (raw, red) {
        (Ok(raw), Ok(red)) => {
            let full = pre.restore(lp, &red);
            // Randomized LPs can terminate at eps-distinct vertices, so
            // the property uses a looser tolerance than the 1e-9 the
            // deterministic `all_families_flow_through_pipeline` anchor
            // asserts.
            let tol = 1e-7 * (1.0 + raw.objective.abs());
            if (full.objective - raw.objective).abs() > tol {
                return Err(format!(
                    "{ctx}: objective drifted through presolve: raw {} vs restored {}",
                    raw.objective, full.objective
                ));
            }
            if let Some(v) = lp.check_feasible(&full.x, 1e-6) {
                return Err(format!("{ctx}: restored point infeasible: {v}"));
            }
            let y = full
                .duals
                .as_ref()
                .ok_or_else(|| format!("{ctx}: restored solution lost its duals"))?;
            if y.len() != lp.num_constraints() {
                return Err(format!(
                    "{ctx}: duals are per reduced row ({}) not per original row ({})",
                    y.len(),
                    lp.num_constraints()
                ));
            }
            // Strong duality on the ORIGINAL problem: b'y == c'x*.
            let by: f64 = lp
                .constraints()
                .iter()
                .zip(y.iter())
                .map(|(con, yi)| con.rhs * yi)
                .sum();
            let dtol = 1e-6 * (1.0 + raw.objective.abs());
            if (by - full.objective).abs() > dtol {
                return Err(format!(
                    "{ctx}: restored duals break strong duality: b'y {} vs obj {}",
                    by, full.objective
                ));
            }
            Ok(())
        }
        (Err(_), Err(_)) => Ok(()),
        (a, b) => Err(format!("{ctx}: raw and presolved disagree on solvability: {a:?} vs {b:?}")),
    }
}

fn fe_lp(g: &mut Gen) -> LpProblem {
    let spec = arb_spec(g, 4, 6);
    frontend::build_lp(&spec, &FeOptions::default())
}

#[test]
fn prop_presolve_agrees_on_fe_lps() {
    props("presolve == raw (fe)", 40, |g| {
        let lp = fe_lp(g);
        assert_presolve_agrees(&lp, "fe")
    });
}

#[test]
fn prop_presolve_agrees_on_nfe_lps() {
    props("presolve == raw (nfe)", 40, |g| {
        let spec = arb_spec(g, 3, 5);
        let lp = no_frontend::build_lp(&spec, &NfeOptions::default());
        assert_presolve_agrees(&lp, "nfe")
    });
}

#[test]
fn prop_presolve_agrees_on_concurrent_lps() {
    props("presolve == raw (concurrent)", 40, |g| {
        let spec = arb_spec(g, 3, 5);
        let mode = if g.bool() {
            dlt::dlt::concurrent::Mode::Staggered
        } else {
            dlt::dlt::concurrent::Mode::Proportional
        };
        let lp = concurrent::build_lp(&spec, mode);
        assert_presolve_agrees(&lp, "concurrent")
    });
}

#[test]
fn prop_presolve_agrees_on_multi_job_lps() {
    props("presolve == raw (multi_job)", 40, |g| {
        let spec = arb_spec(g, 3, 5);
        let ready: Vec<f64> = (0..spec.m()).map(|_| g.f64_in(0.0, 4.0)).collect();
        let step = MultiJobStepModel {
            fe: FeOptions { proc_ready: Some(ready), ..Default::default() },
        };
        let lp = step.build_lp(&spec);
        assert_presolve_agrees(&lp, "multi_job")
    });
}

/// Bound propagation (singleton `<=` caps tightened through coupling
/// rows, redundant rows dropped, infeasibility caught before phase 1)
/// must keep exact presolve==raw parity — objective, feasibility,
/// restored duals — on LPs built to exercise it.
#[test]
fn prop_presolve_bound_propagation_parity() {
    props("presolve bound propagation == raw", 60, |g| {
        let n = g.usize_in(2, 6);
        let mut p = LpProblem::new(n);
        let c: Vec<f64> = (0..n).map(|_| g.f64_in(-2.0, 2.0)).collect();
        p.set_objective(&c);
        // Singleton caps on a random subset (the ub seeds).
        for v in 0..n {
            if g.bool() {
                p.add_constraint(&[(v, 1.0)], dlt::lp::Cmp::Le, g.f64_in(0.5, 4.0));
            }
        }
        // Coupling rows with mixed signs: some become redundant under
        // the caps, some bind, some prove the instance infeasible —
        // all three paths must agree with the raw solve.
        let rows = g.usize_in(1, 5);
        for k in 0..rows {
            let coeffs: Vec<(usize, f64)> = (0..n)
                .filter_map(|v| {
                    if g.bool() {
                        Some((v, g.f64_in(-1.5, 1.5)))
                    } else {
                        None
                    }
                })
                .collect();
            if coeffs.is_empty() {
                continue;
            }
            let cmp = match k % 3 {
                0 => dlt::lp::Cmp::Le,
                1 => dlt::lp::Cmp::Ge,
                _ => dlt::lp::Cmp::Eq,
            };
            p.add_constraint(&coeffs, cmp, g.f64_in(-2.0, 6.0));
        }
        // Negatively-priced variables without a cap make the instance
        // unbounded — a legitimate outcome assert_presolve_agrees
        // handles (both paths must agree on the verdict).
        assert_presolve_agrees(&p, "bound-prop")
    });
}

/// All four scenario families solve through the single pipeline and
/// agree with their presolve-off baselines.
#[test]
fn all_families_flow_through_pipeline() {
    let spec = SystemSpec::builder()
        .source(0.2, 0.0)
        .source(0.3, 2.0)
        .processors(&[2.0, 3.0, 4.0])
        .job(100.0)
        .build()
        .unwrap();
    let on = PipelineOptions::default();
    let off = PipelineOptions { presolve: false, ..PipelineOptions::default() };

    fn check<S: ScenarioModel>(
        model: &S,
        spec: &SystemSpec,
        on: &PipelineOptions,
        off: &PipelineOptions,
    ) {
        let a = pipeline::solve_full(model, spec, on, None, None).unwrap();
        let b = pipeline::solve_full(model, spec, off, None, None).unwrap();
        assert!(
            (a.schedule.makespan - b.schedule.makespan).abs()
                < 1e-9 * (1.0 + b.schedule.makespan.abs()),
            "{}: presolve on {} vs off {}",
            model.name(),
            a.schedule.makespan,
            b.schedule.makespan
        );
    }
    check(&FeOptions::default(), &spec, &on, &off);
    check(&NfeOptions::default(), &spec, &on, &off);
    check(&ConcurrentOptions::default(), &spec, &on, &off);
    check(
        &MultiJobStepModel {
            fe: FeOptions { proc_ready: Some(vec![1.0, 2.0, 3.0]), ..Default::default() },
        },
        &spec,
        &on,
        &off,
    );
}

/// Acceptance: a warm re-solve whose cached basis went
/// primal-infeasible under an rhs perturbation completes via the dual
/// simplex — zero phase-1 iterations — instead of a cold restart.
#[test]
fn rhs_perturbed_warm_resolve_skips_phase1() {
    let base = SystemSpec::builder()
        .source(0.2, 10.0)
        .source(0.4, 50.0)
        .processors(&[2.0, 3.0, 4.0, 5.0, 6.0])
        .job(100.0)
        .build()
        .unwrap();
    let popts = PipelineOptions::default();
    let model = FeOptions::default();
    let solved = pipeline::solve_full(&model, &base, &popts, None, None).unwrap();
    let basis = solved.solution.basis.clone().expect("optimal basis");
    assert!(basis.is_complete());

    // A cold FE solve pays phase-1 pivots (the normalize equality and
    // release surplus rows need artificials).
    assert!(solved.solution.phase1_iterations > 0, "cold solve should run phase 1");

    // R2 beyond ~85 makes the §3.1 LP infeasible for this spec (the
    // release row's forced beta[0][0] collides with the continuity
    // chain), so perturb within the feasible band.
    let mut saw_dual_repair = false;
    for r2 in [55.0, 65.0, 75.0, 85.0] {
        let mut spec2 = base.clone();
        spec2.sources[1].release = r2;
        let cold = pipeline::solve_full(&model, &spec2, &popts, None, None).unwrap();
        let warm = pipeline::solve_full(
            &model,
            &spec2,
            &popts,
            None,
            Some((&solved.reduced, &basis)),
        )
        .unwrap();
        assert!(
            (warm.schedule.makespan - cold.schedule.makespan).abs()
                < 1e-7 * (1.0 + cold.schedule.makespan.abs()),
            "R2={r2}: warm {} vs cold {}",
            warm.schedule.makespan,
            cold.schedule.makespan
        );
        assert_eq!(
            warm.solution.phase1_iterations, 0,
            "R2={r2}: warm re-solve restarted phase 1"
        );
        if warm.solution.dual_iterations > 0 {
            saw_dual_repair = true;
        }
    }
    assert!(
        saw_dual_repair,
        "no perturbation exercised the dual-simplex repair path"
    );
}

/// Cross-shape projection: walking the processor axis m -> m+1, the
/// projected seed must give the cold optimum (it may need a dual
/// repair, never a wrong answer).
#[test]
fn processor_axis_projection_reaches_cold_optima() {
    let spec = SystemSpec::builder()
        .source(0.2, 1.0)
        .source(0.4, 3.0)
        .processors(&[2.0, 2.5, 3.0, 3.5, 4.0, 4.5])
        .job(120.0)
        .build()
        .unwrap();
    let popts = PipelineOptions::default();
    let model = FeOptions::default();
    let mut prev: Option<(LpProblem, dlt::lp::Basis)> = None;
    for m in 1..=spec.m() {
        let sub = spec.with_m_processors(m);
        let cold = pipeline::solve_full(&model, &sub, &popts, None, None).unwrap();
        let seeded = pipeline::solve_full(
            &model,
            &sub,
            &popts,
            None,
            prev.as_ref().map(|(lp, b)| (lp, b)),
        )
        .unwrap();
        assert!(
            (seeded.schedule.makespan - cold.schedule.makespan).abs()
                < 1e-7 * (1.0 + cold.schedule.makespan.abs()),
            "m={m}: seeded {} vs cold {}",
            seeded.schedule.makespan,
            cold.schedule.makespan
        );
        let basis = seeded.solution.basis.clone().expect("basis");
        if basis.is_complete() {
            prev = Some((seeded.reduced, basis));
        }
    }
}

/// The concurrent family's new cached entry point agrees with its
/// uncached solves across a job sweep.
#[test]
fn concurrent_solve_cached_matches_uncached() {
    let spec = SystemSpec::builder()
        .source(0.2, 0.0)
        .source(0.3, 1.0)
        .processors(&[2.0, 3.0, 4.0])
        .job(100.0)
        .build()
        .unwrap();
    let opts = ConcurrentOptions::default();
    let mut cache = dlt::lp::WarmCache::new();
    for k in 0..8 {
        let sub = spec.with_job(80.0 + 20.0 * k as f64);
        let cached = pipeline::solve_cached(&opts, &sub, &mut cache).unwrap();
        let plain = pipeline::solve(&ConcurrentOptions::default(), &sub).unwrap();
        assert!(
            (cached.makespan - plain.makespan).abs() < 1e-7 * (1.0 + plain.makespan.abs()),
            "J step {k}: cached {} vs plain {}",
            cached.makespan,
            plain.makespan
        );
    }
    assert!(cache.warm_attempts >= 7, "cache never warmed: {}", cache.warm_attempts);
}
