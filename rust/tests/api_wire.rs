//! Wire-format properties for the `dlt::api` facade: request JSON
//! round-trips losslessly across all four scenario families, response
//! JSON round-trips, and malformed input is rejected with
//! `Error::Config` — never a panic.

use dlt::api::{
    ApiError, Backend, Family, RequestOptions, SolveRequest, SolveResponse, Solver, FAMILIES,
};
use dlt::config::json::Json;
use dlt::dlt::concurrent::Mode;
use dlt::error::Error;
use dlt::lp::{Factorization, Pricing};
use dlt::testkit::{arb_spec, props, Gen};

fn arb_options(g: &mut Gen, family: Family, m: usize) -> RequestOptions {
    let mut o = RequestOptions::default();
    if g.bool() {
        o.backend = Some(match g.usize_in(0, 3) {
            0 => Backend::RevisedSimplex,
            1 => Backend::DenseTableau,
            _ => Backend::Pdhg,
        });
    }
    if g.bool() {
        o.presolve = Some(g.bool());
    }
    if g.bool() {
        o.eps = Some(g.f64_in(1e-12, 1e-6));
    }
    if g.bool() {
        o.max_iters = Some(g.usize_in(100, 100_000));
    }
    if g.bool() {
        o.pdhg_tol = Some(g.f64_in(1e-10, 1e-5));
    }
    if g.bool() {
        o.pdhg_max_blocks = Some(g.usize_in(1, 5000));
    }
    if g.bool() {
        o.timeout_ms = Some(g.usize_in(1, 600_000) as u64);
    }
    if g.bool() {
        o.factorization = Some(match g.usize_in(0, 4) {
            0 => Factorization::ProductFormEta,
            1 => Factorization::ForrestTomlin,
            2 => Factorization::Markowitz,
            _ => Factorization::BartelsGolub,
        });
    }
    if g.bool() {
        o.pricing = Some(match g.usize_in(0, 4) {
            0 => Pricing::Dantzig,
            1 => Pricing::Devex,
            2 => Pricing::SteepestEdge,
            _ => Pricing::Partial,
        });
    }
    match family {
        Family::Concurrent => {
            if g.bool() {
                o.mode = Some(if g.bool() { Mode::Staggered } else { Mode::Proportional });
            }
        }
        Family::Frontend => {
            if g.bool() {
                o.finish_sum_includes_j = Some(g.bool());
            }
        }
        Family::NoFrontend => {
            if g.bool() {
                o.drop_source_busy = Some(g.bool());
            }
        }
        Family::MultiJob => {
            if g.bool() {
                o.proc_ready = Some(g.f64_vec(m, 0.0, 10.0));
            }
        }
    }
    o
}

/// `request -> encode -> parse -> request` is the identity, for every
/// family, with and without option overrides, compact and pretty.
#[test]
fn prop_request_roundtrip_all_families() {
    props("request json roundtrip", 80, |g| {
        let family = FAMILIES[g.usize_in(0, FAMILIES.len())];
        let spec = arb_spec(g, 4, 6);
        let m = spec.m();
        let req = SolveRequest {
            id: if g.bool() { Some(format!("req-{}", g.usize_in(0, 10_000))) } else { None },
            family,
            spec,
            options: arb_options(g, family, m),
        };
        let compact = req.to_json().to_string_compact();
        let pretty = req.to_json().to_string_pretty();
        let back1 = SolveRequest::parse(&compact).map_err(|e| format!("compact: {e}"))?;
        let back2 = SolveRequest::parse(&pretty).map_err(|e| format!("pretty: {e}"))?;
        if back1 != req {
            return Err(format!("compact roundtrip drifted:\n{req:?}\nvs\n{back1:?}"));
        }
        if back2 != req {
            return Err(format!("pretty roundtrip drifted:\n{req:?}\nvs\n{back2:?}"));
        }
        Ok(())
    });
}

/// Responses round-trip too: solve a real request per family, encode,
/// decode, compare the payload fields.
#[test]
fn response_roundtrip_all_families() {
    let spec = dlt::model::SystemSpec::builder()
        .source(0.2, 0.0)
        .source(0.3, 2.0)
        .processors(&[2.0, 3.0, 4.0])
        .job(100.0)
        .build()
        .unwrap();
    let mut session = Solver::new().build();
    for family in FAMILIES {
        let mut req = SolveRequest::new(family, spec.clone());
        req.id = Some(format!("rt-{}", family.as_str()));
        let resp = session.solve(&req).unwrap();
        let text = resp.to_json().to_string_pretty();
        let back = SolveResponse::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.id, resp.id);
        assert_eq!(back.family, resp.family);
        assert_eq!(back.backend, resp.backend);
        assert_eq!(back.n, resp.n);
        assert_eq!(back.m, resp.m);
        assert_eq!(back.beta, resp.beta);
        assert_eq!(back.alpha, resp.alpha);
        assert_eq!(back.comm_start, resp.comm_start);
        assert_eq!(back.compute_end, resp.compute_end);
        assert_eq!(back.makespan, resp.makespan);
        assert_eq!(back.diagnostics.iterations, resp.diagnostics.iterations);
        assert_eq!(back.diagnostics.presolve, resp.diagnostics.presolve);
        assert_eq!(back.diagnostics.recovery_events, resp.diagnostics.recovery_events);
        assert_eq!(back.degraded, resp.degraded);
        // And the reconstructed schedule is self-consistent.
        let sched = back.schedule();
        assert_eq!(sched.model, family.timing_model());
        assert!((sched.total_load() - 100.0).abs() < 1e-6);
    }
}

/// A divergence report attached by `Session::solve_simulated` survives
/// the wire (the replay trace is deliberately not serialized).
#[test]
fn response_roundtrip_with_sim_diagnostics() {
    let spec = dlt::model::SystemSpec::builder()
        .source(0.2, 0.0)
        .source(0.3, 2.0)
        .processors(&[2.0, 3.0, 4.0])
        .job(100.0)
        .build()
        .unwrap();
    let mut session = Solver::new().build();
    let req = SolveRequest::new(Family::NoFrontend, spec);
    let resp =
        session.solve_simulated(&req, &dlt::sim::replay::ReplayOptions::default()).unwrap();
    let sim = resp.diagnostics.sim.clone().expect("sim diagnostics attached");
    assert!(sim.rel_gap.abs() <= 1e-9, "gap {}", sim.rel_gap);
    let text = resp.to_json().to_string_pretty();
    let back = SolveResponse::from_json(&Json::parse(&text).unwrap()).unwrap();
    let back_sim = back.diagnostics.sim.expect("sim diagnostics decoded");
    assert_eq!(back_sim, sim);
}

/// Robustness fields survive the wire: `recovery_events` and the
/// `degraded` flag round-trip when present, and responses encoded
/// before those fields existed still decode (absent => empty/false).
#[test]
fn response_roundtrip_robustness_fields() {
    let spec = dlt::model::SystemSpec::builder()
        .source(0.2, 0.0)
        .source(0.3, 2.0)
        .processors(&[2.0, 3.0, 4.0])
        .job(100.0)
        .build()
        .unwrap();
    let mut session = Solver::new().build();
    let req = SolveRequest::new(Family::NoFrontend, spec);
    let mut resp = session.solve(&req).unwrap();
    resp.degraded = true;
    resp.diagnostics.recovery_events =
        vec!["early_refactorize".to_string(), "markowitz_retry".to_string()];
    let text = resp.to_json().to_string_compact();
    assert!(text.contains("\"degraded\""), "{text}");
    assert!(text.contains("\"recovery_events\""), "{text}");
    let back = SolveResponse::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert!(back.degraded);
    assert_eq!(back.diagnostics.recovery_events, resp.diagnostics.recovery_events);
    // Legacy payloads predate both fields: strip them and re-decode.
    let doc = Json::parse(&text).unwrap();
    let Json::Object(pairs) = doc else { panic!("response is not an object") };
    let legacy: Vec<(String, Json)> = pairs
        .into_iter()
        .map(|(k, v)| {
            if k == "diagnostics" {
                let Json::Object(dp) = v else { panic!("diagnostics is not an object") };
                let kept = dp.into_iter().filter(|(dk, _)| dk != "recovery_events").collect();
                (k, Json::Object(kept))
            } else {
                (k, v)
            }
        })
        .filter(|(k, _)| k != "degraded")
        .collect();
    let old = SolveResponse::from_json(&Json::Object(legacy)).unwrap();
    assert!(!old.degraded);
    assert!(old.diagnostics.recovery_events.is_empty());
}

/// Malformed JSON documents are `Error::Config`, never a panic:
/// truncated objects, bad numbers, wrong types, trailing garbage.
#[test]
fn malformed_json_is_rejected_not_panicked() {
    let cases = [
        "",
        "{",
        "}",
        "[",
        "[1,",
        r#"{"a""#,
        r#"{"a":"#,
        r#"{"a":1"#,
        r#"{"a" 1}"#,
        r#"{"a":1,}"#,
        "[1,]",
        "nul",
        "tru",
        "falsey",
        "--1",
        "1e",
        "1..2",
        "0x10",
        "\"unterminated",
        "\"bad escape \\q\"",
        "\"lone surrogate \\ud800\"",
        "\"truncated \\u12",
        "1 2",
        "{} []",
        "\u{1}",
    ];
    for c in cases {
        match Json::parse(c) {
            Err(Error::Config(_)) => {}
            Err(e) => panic!("`{c}`: wrong error kind {e:?}"),
            Ok(v) => panic!("`{c}`: parsed to {v:?}"),
        }
    }
}

/// Structurally valid JSON that is not a valid request is also a
/// config error: missing fields, wrong types, out-of-domain values.
#[test]
fn invalid_requests_are_config_errors() {
    let spec_ok = r#"{"sources":[{"g":0.2}],"processors":[{"a":2}],"job":10}"#;
    let cases = [
        // Not an object.
        "[]".to_string(),
        "42".to_string(),
        // Missing family / spec.
        format!(r#"{{"spec": {spec_ok}}}"#),
        r#"{"family": "frontend"}"#.to_string(),
        // Wrong types.
        format!(r#"{{"family": 3, "spec": {spec_ok}}}"#),
        format!(r#"{{"family": "frontend", "spec": {spec_ok}, "options": {{"presolve": "yes"}}}}"#),
        format!(r#"{{"family": "frontend", "spec": {spec_ok}, "options": {{"eps": "small"}}}}"#),
        format!(r#"{{"family": "frontend", "spec": {spec_ok}, "options": {{"max_iters": 1.5}}}}"#),
        format!(r#"{{"family": "frontend", "spec": {spec_ok}, "options": {{"max_iters": -3}}}}"#),
        format!(
            r#"{{"family": "frontend", "spec": {spec_ok}, "options": {{"proc_ready": [1, "x"]}}}}"#
        ),
        format!(r#"{{"family": "frontend", "spec": {spec_ok}, "options": {{"mode": "warp"}}}}"#),
        format!(r#"{{"family": "frontend", "spec": {spec_ok}, "options": {{"backend": "cuda"}}}}"#),
        // Options must be an object, and misspelled keys must fail
        // loudly instead of silently solving with the defaults.
        format!(r#"{{"family": "frontend", "spec": {spec_ok}, "options": "pdhg"}}"#),
        format!(r#"{{"family": "frontend", "spec": {spec_ok}, "options": {{"backends": "pdhg"}}}}"#),
        // Bad spec payloads.
        r#"{"family": "frontend", "spec": {"sources":[],"processors":[{"a":2}],"job":10}}"#
            .to_string(),
        r#"{"family": "frontend", "spec": {"sources":[{"g":0.2}],"processors":[{"a":2}]}}"#
            .to_string(),
        r#"{"family": "frontend", "spec": {"sources":[{"g":"fast"}],"processors":[{"a":2}],"job":10}}"#
            .to_string(),
    ];
    for c in &cases {
        match SolveRequest::parse(c) {
            Err(Error::Config(_)) => {}
            Err(e) => panic!("`{c}`: wrong error kind {e:?}"),
            Ok(v) => panic!("`{c}`: parsed to {v:?}"),
        }
    }
}

/// Batch output slots line up with input slots even when some entries
/// are malformed: the error object carries the config message in-band.
#[test]
fn api_error_json_shape() {
    let err = ApiError::from(Error::Config("missing field `family`".into()));
    let j = err.to_json();
    let text = j.to_string_compact();
    assert!(text.contains("\"error\""), "{text}");
    assert!(text.contains("\"config\""), "{text}");
    let back = ApiError::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, err);
}
