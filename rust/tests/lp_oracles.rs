//! Cross-solver oracle tests for the LP substrate:
//! simplex vs (a) the §2 closed form, (b) brute-force vertex
//! enumeration on tiny problems, (c) duality relations.

use dlt::dlt::single_source;
use dlt::lp::{solve, Cmp, LpProblem};
use dlt::model::SystemSpec;
use dlt::testkit::props;

/// §2 closed form == LP-NFE with N = 1, R = 0, across random systems.
#[test]
fn closed_form_equals_lp() {
    props("closed form == lp", 40, |g| {
        let m = g.usize_in(1, 8);
        let a = g.sorted_f64_vec(m, 0.5, 5.0);
        let gg = g.f64_in(0.05, 1.0);
        let job = g.f64_in(10.0, 200.0);
        let cf = single_source::solve(gg, &a, job, 0.0).map_err(|e| format!("{e}"))?;
        let mut b = SystemSpec::builder().source(gg, 0.0);
        for &ai in &a {
            b = b.processor(ai);
        }
        let spec = b.job(job).build().map_err(|e| format!("{e}"))?;
        let lp = dlt::pipeline::solve(&dlt::dlt::no_frontend::NfeOptions::default(), &spec)
            .map_err(|e| format!("{e}"))?;
        let rel = (cf.makespan - lp.makespan).abs() / cf.makespan;
        if rel < 1e-6 {
            Ok(())
        } else {
            Err(format!("cf {} vs lp {}", cf.makespan, lp.makespan))
        }
    });
}

/// The closed-form recursion equals the direct linear-system solve.
#[test]
fn recursion_equals_linear_system() {
    props("recursion == linsys", 50, |g| {
        let m = g.usize_in(1, 10);
        let a = g.sorted_f64_vec(m, 0.3, 6.0);
        let gg = g.f64_in(0.05, 1.5);
        let job = g.f64_in(1.0, 500.0);
        let cf = single_source::solve(gg, &a, job, 0.0).map_err(|e| format!("{e}"))?;
        let (beta, tf) =
            single_source::solve_linear_system(gg, &a, job).map_err(|e| format!("{e}"))?;
        if (cf.makespan - tf).abs() > 1e-7 * tf {
            return Err(format!("tf {} vs {}", cf.makespan, tf));
        }
        for (b1, b2) in cf.beta.iter().zip(beta.iter()) {
            if (b1 - b2).abs() > 1e-7 * job {
                return Err(format!("beta {:?} vs {:?}", cf.beta, beta));
            }
        }
        Ok(())
    });
}

/// Brute force over a fine grid on 2-variable LPs never beats the
/// simplex optimum.
#[test]
fn brute_force_never_beats_simplex() {
    props("grid never beats simplex", 25, |g| {
        // min c'x st a1'x >= b1, a2'x <= b2 over x in [0, 10]^2
        let c = [g.f64_in(0.1, 3.0), g.f64_in(0.1, 3.0)];
        let a1 = [g.f64_in(0.1, 2.0), g.f64_in(0.1, 2.0)];
        let b1 = g.f64_in(0.5, 5.0);
        let a2 = [g.f64_in(0.1, 2.0), g.f64_in(0.1, 2.0)];
        let b2 = g.f64_in(6.0, 30.0);
        let mut p = LpProblem::new(2);
        p.set_objective(&c);
        p.add_constraint(&[(0, a1[0]), (1, a1[1])], Cmp::Ge, b1);
        p.add_constraint(&[(0, a2[0]), (1, a2[1])], Cmp::Le, b2);
        // Keep the box to make the grid exhaustive.
        p.add_constraint(&[(0, 1.0)], Cmp::Le, 10.0);
        p.add_constraint(&[(1, 1.0)], Cmp::Le, 10.0);
        let Ok(s) = solve(&p) else { return Ok(()) };
        let n = 220;
        for i in 0..=n {
            for j in 0..=n {
                let x = [10.0 * i as f64 / n as f64, 10.0 * j as f64 / n as f64];
                if p.check_feasible(&x, 1e-9).is_none() {
                    let obj = c[0] * x[0] + c[1] * x[1];
                    if obj < s.objective - 1e-6 {
                        return Err(format!("grid point {x:?} beats simplex: {obj} < {}", s.objective));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Weak duality on random feasible LPs: for any dual-feasible y,
/// b'y <= c'x*, with equality at the simplex optimum (strong duality).
#[test]
fn strong_duality_on_random_lps() {
    props("strong duality", 30, |g| {
        let n = g.usize_in(2, 6);
        let m = g.usize_in(1, 4);
        let mut p = LpProblem::new(n);
        let c = g.f64_vec(n, 0.1, 2.0);
        p.set_objective(&c);
        let mut rhs = Vec::new();
        for _ in 0..m {
            let coeffs: Vec<(usize, f64)> =
                (0..n).map(|v| (v, g.f64_in(0.1, 1.0))).collect();
            let b = g.f64_in(0.5, 3.0);
            p.add_constraint(&coeffs, Cmp::Ge, b);
            rhs.push(b);
        }
        let s = solve(&p).map_err(|e| format!("{e}"))?;
        let Some(y) = s.duals.as_ref() else { return Ok(()) };
        let by: f64 = y.iter().zip(rhs.iter()).map(|(yi, bi)| yi * bi).sum();
        if (by - s.objective).abs() < 1e-5 * s.objective.abs().max(1.0) {
            Ok(())
        } else {
            Err(format!("b'y {} != c'x {}", by, s.objective))
        }
    });
}

/// Presolve never changes the optimum.
#[test]
fn presolve_preserves_optimum() {
    props("presolve invariant", 30, |g| {
        let n = g.usize_in(2, 6);
        let mut p = LpProblem::new(n);
        p.set_objective(&g.f64_vec(n, 0.1, 2.0));
        let rows = g.usize_in(1, 5);
        for _ in 0..rows {
            let coeffs: Vec<(usize, f64)> =
                (0..n).map(|v| (v, g.f64_in(0.1, 1.0))).collect();
            p.add_constraint(&coeffs, Cmp::Ge, g.f64_in(0.5, 3.0));
        }
        // Inject noise rows that presolve should remove.
        p.add_constraint(&[], Cmp::Le, 1.0);
        p.add_constraint(&[(0, 0.0)], Cmp::Le, 5.0);
        let pre = dlt::lp::presolve::presolve(&p).map_err(|e| format!("{e}"))?;
        let s0 = solve(&p).map_err(|e| format!("{e}"))?;
        let s1 = solve(&pre.problem).map_err(|e| format!("{e}"))?;
        if (s0.objective - s1.objective).abs() < 1e-7 * s0.objective.abs().max(1.0) {
            Ok(())
        } else {
            Err(format!("{} vs {}", s0.objective, s1.objective))
        }
    });
}
