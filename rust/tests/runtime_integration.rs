//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! Gated: skipped (with a note) when `make artifacts` has not run.

use dlt::dlt::frontend;
use dlt::lp::{solve, Cmp, LpProblem};
use dlt::model::SystemSpec;
use dlt::pdhg::{solve_artifact, solve_rust, PdhgOptions};
use dlt::runtime::{Runtime, WorkloadExecutable};

fn artifacts_or_skip() -> Option<Runtime> {
    if !Runtime::artifacts_available() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::open_default().expect("open runtime"))
}

#[test]
fn manifest_lists_expected_variants() {
    let Some(rt) = artifacts_or_skip() else { return };
    assert!(!rt.manifest().pdhg.is_empty());
    assert!(!rt.manifest().workload.is_empty());
    assert!(rt.manifest().pdhg_variant_for(61, 61).is_some(), "paper sweeps must fit");
    assert!(rt.manifest().pdhg_variant_for(181, 183).is_some(), "NFE N=3 M=20 must fit");
}

#[test]
fn workload_artifact_executes_and_is_deterministic() {
    if !Runtime::artifacts_available() {
        eprintln!("SKIP: artifacts/ not built");
        return;
    }
    let mut w1 = WorkloadExecutable::open("artifacts", 7).expect("open workload");
    let a = w1.run_unit().expect("run");
    let b = w1.run_unit().expect("run");
    assert_eq!(a, b, "same chunk -> same checksum");
    assert!(a.is_finite() && a > 0.0, "relu-sum checksum must be positive, got {a}");
    // Different seed -> different chunk -> different checksum.
    let mut w2 = WorkloadExecutable::open("artifacts", 8).expect("open workload");
    assert_ne!(a, w2.run_unit().expect("run"));
}

#[test]
fn pdhg_artifact_matches_rust_backend() {
    let Some(mut rt) = artifacts_or_skip() else { return };
    // Small generic LP.
    let mut p = LpProblem::new(3);
    p.set_objective(&[3.0, 2.0, 4.0]);
    p.add_constraint(&[(0, 1.0), (1, 1.0), (2, 1.0)], Cmp::Eq, 10.0);
    p.add_constraint(&[(0, 1.0)], Cmp::Le, 4.0);
    p.add_constraint(&[(2, 1.0)], Cmp::Ge, 1.0);
    let opts = PdhgOptions::default();
    let art = solve_artifact(&mut rt, &p, &opts).expect("artifact solve");
    let rust = solve_rust(&p, &opts).expect("rust solve");
    assert!(art.converged, "artifact residuals {:?}", art.residuals);
    // The artifact path still iterates on zero-padded panels while the
    // in-process path runs the sparse kernels, so the trajectories are
    // no longer bit-identical — but both converge to the same optimum
    // within their residual tolerance.
    assert!(
        (art.objective - rust.objective).abs() < 1e-5 * rust.objective.abs().max(1.0),
        "artifact {} vs rust {}",
        art.objective,
        rust.objective
    );
}

#[test]
fn pdhg_artifact_solves_paper_frontend_lp() {
    let Some(mut rt) = artifacts_or_skip() else { return };
    // Table 1 system, solved via simplex (exact) and PDHG artifact.
    let spec = SystemSpec::builder()
        .source(0.2, 10.0)
        .source(0.4, 50.0)
        .processors(&[2.0, 3.0, 4.0, 5.0, 6.0])
        .job(100.0)
        .build()
        .unwrap();
    let lp = frontend::build_lp(&spec, &Default::default());
    let exact = solve(&lp).unwrap();
    let sol = solve_artifact(&mut rt, &lp, &PdhgOptions::default()).expect("artifact");
    let tf = sol.x[lp.num_vars() - 1];
    assert!(
        (tf - exact.objective).abs() < 5e-3 * exact.objective,
        "PDHG T_f {tf} vs simplex {}",
        exact.objective
    );
}
