//! 10k-processor scale smoke for the cluster engine, with a counting
//! global allocator: after construction, a steady-state
//! [`ClusterSim::run`] performs **zero** allocations (flat arena,
//! reserved heap, no per-event boxing), and the full divergence replay
//! is deterministic — the same seed yields a `PartialEq`-identical
//! [`DivergenceReport`], and the jitter-free gated replay reproduces
//! the stamped makespan bit-for-bit.
//!
//! Everything runs inside ONE `#[test]` so no parallel test thread
//! pollutes the allocation counters (same discipline as
//! `lp_scratch_alloc`).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct Counting;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for Counting {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if new_size > layout.size() {
            ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static COUNTER: Counting = Counting;

/// Allocations performed while running `f`.
fn allocs_during<F: FnOnce()>(f: F) -> u64 {
    let before = ALLOCATIONS.load(Ordering::Relaxed);
    f();
    ALLOCATIONS.load(Ordering::Relaxed) - before
}

#[test]
fn ten_thousand_processor_replay_is_allocation_free_and_deterministic() {
    use dlt::dlt::schedule::TimingModel;
    use dlt::model::SystemSpec;
    use dlt::sim::cluster::{ClusterSim, InjectionPlan, World};
    use dlt::sim::replay::{replay, synthetic_scale, ReplayOptions};

    let base = SystemSpec::builder()
        .source(0.2, 0.0)
        .source(0.3, 2.0)
        .processors(&[1.0])
        .job(100.0)
        .build()
        .unwrap();
    let (spec, sched) = synthetic_scale(&base, 10_000, TimingModel::NoFrontEnd).unwrap();
    assert_eq!(spec.m(), 10_000);

    // Steady-state engine run: all setup (arena, heap reservation,
    // timing arrays) happens in the constructor; run() itself must not
    // touch the allocator.
    let mut world = World::new(&spec, &sched.beta, sched.model);
    world.gate_send = Some(sched.comm_start.clone());
    let mut sim = ClusterSim::new(world);
    let allocs = allocs_during(|| sim.run());
    assert_eq!(allocs, 0, "steady-state run() allocated {allocs} times");
    let stats = sim.stats();
    assert!(stats.events > 0);
    // The gated replay of the stamped schedule is exact, bit-for-bit.
    assert_eq!(sim.world().makespan(), sched.makespan);

    // Full divergence replays: same inputs, identical reports —
    // including under jitter and seeded-random faults.
    let clean = ReplayOptions::default();
    let a = replay(&spec, &sched, &clean).unwrap();
    let b = replay(&spec, &sched, &clean).unwrap();
    assert_eq!(a, b, "jitter-free replay must be deterministic");
    assert_eq!(a.rel_gap, 0.0, "stamped makespan must reproduce exactly");
    assert!(a.violated_constraints.is_empty(), "{:?}", a.violated_constraints);
    assert_eq!(a.per_processor_slack.len(), 10_000);

    let adverse = ReplayOptions {
        link_jitter: 0.05,
        compute_jitter: 0.05,
        seed: 42,
        plan: InjectionPlan { random_faults: 3, ..Default::default() },
        ..Default::default()
    };
    let c = replay(&spec, &sched, &adverse).unwrap();
    let d = replay(&spec, &sched, &adverse).unwrap();
    assert_eq!(c, d, "seeded adverse replay must be deterministic");
    assert_eq!(c.faults_injected, 3);
    assert!(c.simulated_makespan >= a.simulated_makespan);
}
