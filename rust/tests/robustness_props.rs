//! Fail-operational properties: pathological LPs — degenerate,
//! infeasible, unbounded, near-singular — must land on *typed*
//! verdicts (never a panic) under every factorization × pricing arm,
//! the dense oracle, and the first-order backends; zero deadlines are
//! typed `DeadlineExceeded` through every pipeline backend; corrupted
//! warm bases fall back cold and say so in `recovery_events`.

use dlt::dlt::frontend::FeOptions;
use dlt::error::Error;
use dlt::lp::{
    solve_warm, solve_with, Basis, Cmp, Factorization, LpProblem, LpSolution, Pricing,
    SimplexOptions, SolverBackend,
};
use dlt::pdhg::{self, PdhgOptions};
use dlt::pipeline::{self, Backend, PipelineOptions};
use dlt::testkit::{arb_spec, props, Gen};

const ALL_FACTS: [Factorization; 4] = [
    Factorization::ProductFormEta,
    Factorization::ForrestTomlin,
    Factorization::Markowitz,
    Factorization::BartelsGolub,
];

const ALL_PRICINGS: [Pricing; 4] =
    [Pricing::Dantzig, Pricing::Devex, Pricing::SteepestEdge, Pricing::Partial];

/// Random raw LP biased toward solver-hostile structure: duplicate
/// rows (exact degeneracy), near-parallel rows scaled by `1 + 1e-12`
/// (ill-conditioned bases), random `Eq`/`Ge` mixes (often infeasible),
/// and an occasional forced infeasible pair or free improving ray.
fn arb_pathological(g: &mut Gen) -> LpProblem {
    let n = g.usize_in(2, 7);
    let mut p = LpProblem::new(n);
    let obj: Vec<f64> = (0..n).map(|_| g.f64_in(-3.0, 3.0)).collect();
    p.set_objective(&obj);
    for _ in 0..g.usize_in(1, 9) {
        let mut coeffs: Vec<(usize, f64)> = Vec::new();
        for j in 0..n {
            if g.bool() {
                coeffs.push((j, g.f64_in(-2.0, 2.0)));
            }
        }
        if coeffs.is_empty() {
            coeffs.push((g.usize_in(0, n), g.f64_in(-2.0, 2.0)));
        }
        let cmp = match g.usize_in(0, 3) {
            0 => Cmp::Le,
            1 => Cmp::Ge,
            _ => Cmp::Eq,
        };
        let rhs = g.f64_in(-5.0, 5.0);
        p.add_constraint(&coeffs, cmp, rhs);
        if g.bool() && g.bool() {
            p.add_constraint(&coeffs, cmp, rhs);
        }
        if g.bool() && g.bool() {
            let near: Vec<(usize, f64)> =
                coeffs.iter().map(|&(j, v)| (j, v * (1.0 + 1e-12))).collect();
            p.add_constraint(&near, cmp, rhs * (1.0 + 1e-12));
        }
    }
    match g.usize_in(0, 4) {
        0 => {
            // Deterministically infeasible pair.
            p.add_constraint(&[(0, 1.0)], Cmp::Le, 1.0);
            p.add_constraint(&[(0, 1.0)], Cmp::Ge, 2.0);
        }
        1 => {
            // Improving direction that is often unconstrained above.
            p.set_objective_coeff(n - 1, -1.0);
        }
        _ => {}
    }
    p
}

/// `Ok` must be a genuinely feasible finite point; `Err` must be one
/// of the typed solver verdicts. Anything else fails the property
/// (and a panic fails the test on its own).
fn typed_verdict(
    label: &str,
    p: &LpProblem,
    r: Result<LpSolution, Error>,
) -> Result<(), String> {
    match r {
        Ok(s) => {
            if !s.objective.is_finite() {
                return Err(format!("{label}: non-finite objective {}", s.objective));
            }
            if let Some(v) = p.check_feasible(&s.x, 1e-5) {
                return Err(format!("{label}: claimed optimal but infeasible: {v}"));
            }
            Ok(())
        }
        Err(
            Error::Infeasible(_)
            | Error::Unbounded(_)
            | Error::Numerical(_)
            | Error::IterationLimit { .. }
            | Error::DeadlineExceeded { .. },
        ) => Ok(()),
        Err(e) => Err(format!("{label}: untyped verdict {e:?}")),
    }
}

/// Every factorization × pricing arm, the dense tableau oracle, and
/// raw sparse PDHG on solver-hostile random LPs: typed verdicts only.
#[test]
fn prop_pathological_lps_yield_typed_verdicts_never_panics() {
    props("pathological lps -> typed verdicts", 30, |g| {
        let p = arb_pathological(g);
        for f in ALL_FACTS {
            for pr in ALL_PRICINGS {
                let opts = SimplexOptions {
                    factorization: f,
                    pricing: pr,
                    ..SimplexOptions::default()
                };
                let label = format!("{}/{}", f.as_str(), pr.as_str());
                typed_verdict(&label, &p, solve_with(&p, &opts))?;
            }
        }
        let dense = SimplexOptions {
            backend: SolverBackend::DenseTableau,
            ..SimplexOptions::default()
        };
        typed_verdict("dense_tableau", &p, solve_with(&p, &dense))?;
        // PDHG has no infeasibility certificate — it must still return
        // (bounded blocks, typed error or a point), never panic.
        let popts = PdhgOptions { max_blocks: 40, ..PdhgOptions::default() };
        match pdhg::solve_rust(&p, &popts) {
            Ok(ps) => {
                if ps.converged && !ps.objective.is_finite() {
                    return Err(format!("pdhg: converged to {}", ps.objective));
                }
            }
            Err(e) => {
                typed_verdict("pdhg", &p, Err(e))?;
            }
        }
        Ok(())
    });
}

/// A zero deadline is a typed `DeadlineExceeded` through *every*
/// pipeline backend — simplex arms, the dense oracle, sparse PDHG,
/// the block driver, and the hybrid — never a silent full solve.
#[test]
fn prop_zero_deadline_is_typed_across_all_backends() {
    const BACKENDS: [Backend; 5] = [
        Backend::RevisedSimplex,
        Backend::DenseTableau,
        Backend::Pdhg,
        Backend::PdhgBlock,
        Backend::Hybrid,
    ];
    props("zero deadline -> DeadlineExceeded on every backend", 20, |g| {
        let spec = arb_spec(g, 3, 5);
        let model = FeOptions::default();
        for backend in BACKENDS {
            let opts =
                PipelineOptions { backend, timeout_ms: Some(0), ..PipelineOptions::default() };
            match pipeline::solve_full(&model, &spec, &opts, None, None) {
                Err(Error::DeadlineExceeded { .. }) => {}
                other => {
                    return Err(format!(
                        "{}: expected DeadlineExceeded, got {:?}",
                        backend.as_str(),
                        other.map(|s| s.schedule.makespan)
                    ))
                }
            }
        }
        Ok(())
    });
}

/// Crafted singular / corrupted warm bases: the warm path must fall
/// back to a cold start, reach the same optimum, and record the
/// `warm_fallback_cold` recovery event (the same strings the session
/// clones onto the wire `Diagnostics.recovery_events`).
#[test]
fn prop_corrupted_warm_bases_recover_cold_and_record_events() {
    props("corrupt warm basis -> cold fallback + event", 25, |g| {
        let spec = arb_spec(g, 3, 5);
        let lp = dlt::dlt::frontend::build_lp(&spec, &FeOptions::default());
        let opts = SimplexOptions::default();
        let cold = solve_with(&lp, &opts).map_err(|e| format!("cold solve: {e}"))?;
        let garbage = [
            Basis { cols: vec![0] },                // wrong length
            Basis { cols: vec![usize::MAX; 4] },    // all-artificial rows
            Basis { cols: vec![0, 0, 0, 0] },       // duplicate (singular) columns
        ];
        for (k, basis) in garbage.iter().enumerate() {
            let s = solve_warm(&lp, &opts, Some(basis))
                .map_err(|e| format!("garbage basis #{k}: {e}"))?;
            if (s.objective - cold.objective).abs() > 1e-7 * (1.0 + cold.objective.abs()) {
                return Err(format!(
                    "garbage basis #{k}: {} vs cold {}",
                    s.objective, cold.objective
                ));
            }
            if !s.recovery_events.iter().any(|e| e == "warm_fallback_cold") {
                return Err(format!(
                    "garbage basis #{k}: missing warm_fallback_cold in {:?}",
                    s.recovery_events
                ));
            }
        }
        Ok(())
    });
}
