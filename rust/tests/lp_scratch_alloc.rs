//! Steady-state allocation regression for the scratch-pooled solve
//! path, measured with a counting global allocator.
//!
//! The guarantee under test: repeated *warm* revised-simplex solves
//! through one [`dlt::lp::SolverScratch`] settle to a steady state
//! whose per-solve allocation is (a) flat — solve 5 allocates exactly
//! as much as solve 50, i.e. nothing accumulates and every buffer is
//! recycled — and (b) far below the fresh-scratch path, which must
//! rebuild the factorization, pricing and work buffers every time.
//! The residual steady-state bytes come from the LP assembly around
//! the core (`StandardForm`, the solution vectors), not from the
//! simplex iteration loop, and are asserted to stay bounded relative
//! to the unpooled baseline.
//!
//! Everything runs inside ONE `#[test]` so no parallel test thread
//! pollutes the counters.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct Counting;

static ALLOCATED: AtomicU64 = AtomicU64::new(0);
static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for Counting {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATED.fetch_add(layout.size() as u64, Ordering::Relaxed);
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // Count only the growth; shrinks are free.
        if new_size > layout.size() {
            ALLOCATED.fetch_add((new_size - layout.size()) as u64, Ordering::Relaxed);
            ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static COUNTER: Counting = Counting;

/// Bytes allocated while running `f`.
fn bytes_during<F: FnOnce()>(f: F) -> u64 {
    let before = ALLOCATED.load(Ordering::Relaxed);
    f();
    ALLOCATED.load(Ordering::Relaxed) - before
}

#[test]
fn warm_scratch_solves_reach_a_flat_allocation_steady_state() {
    use dlt::dlt::no_frontend::{build_lp, NfeOptions};
    use dlt::lp::{Basis, SimplexOptions, SolverScratch};
    use dlt::model::SystemSpec;

    let spec = SystemSpec::builder()
        .source(0.2, 0.0)
        .source(0.3, 1.0)
        .processors(&[2.0, 3.0, 4.0, 5.0, 6.0, 7.0])
        .job(100.0)
        .build()
        .unwrap();
    let opts = SimplexOptions::default();

    // A family of rhs-perturbed LPs sharing one shape — the sweep
    // steady state.
    let lps: Vec<_> = (0..10)
        .map(|k| build_lp(&spec.with_job(100.0 + k as f64), &NfeOptions::default()))
        .collect();

    // Cold solve for a warm basis.
    let mut scratch = SolverScratch::new();
    let basis: Basis = dlt::lp::revised::solve_revised(&lps[0], &opts, None)
        .unwrap()
        .basis
        .unwrap();

    // Warm-up: let every pooled buffer reach its working size.
    for lp in &lps[..5] {
        dlt::lp::revised::solve_revised_scratch(lp, &opts, Some(&basis), &mut scratch).unwrap();
    }

    // Pooled steady state, measured on one fixed instance so the
    // solve path is bit-reproducible: per-solve bytes must be exactly
    // flat — solve 5 allocates what solve 50 allocates, i.e. the core
    // recycles every buffer and nothing accumulates. (The residual
    // constant comes from per-solve LP assembly around the core —
    // StandardForm, the sparse basis view, the solution vectors —
    // which is shape-determined and identical per solve.)
    let probe = &lps[5];
    let mut pooled = Vec::new();
    for _ in 0..10 {
        pooled.push(bytes_during(|| {
            dlt::lp::revised::solve_revised_scratch(probe, &opts, Some(&basis), &mut scratch)
                .unwrap();
        }));
    }
    assert!(
        pooled.windows(2).all(|w| w[0] == w[1]),
        "steady-state per-solve allocation must be flat (nothing accumulates): {pooled:?}"
    );

    // Fresh-scratch baseline on the same instances: rebuilding the
    // factorization/pricing objects and all work buffers every solve
    // must cost measurably more than the pooled path.
    let mut fresh = Vec::new();
    for _ in 0..10 {
        fresh.push(bytes_during(|| {
            let mut throwaway = SolverScratch::new();
            dlt::lp::revised::solve_revised_scratch(probe, &opts, Some(&basis), &mut throwaway)
                .unwrap();
        }));
    }
    let pooled_total: u64 = pooled.iter().sum();
    let fresh_total: u64 = fresh.iter().sum();
    assert!(
        pooled_total * 10 <= fresh_total * 9,
        "scratch pool should cut warm-solve allocation by well over 10%: pooled \
         {pooled_total}B vs fresh {fresh_total}B over 10 warm solves"
    );

    // The PDHG arm of the same scratch: repeated first-order solves
    // through one pool must also settle to an exactly flat per-solve
    // byte count (flat, not zero — the sparse standard form is rebuilt
    // per instance; the iteration vectors and padded panels are what
    // the pool recycles). Runs in this same #[test] so the global
    // counters stay single-threaded.
    let popts = dlt::pdhg::PdhgOptions { max_blocks: 5, ..Default::default() };
    for lp in &lps[..5] {
        dlt::pdhg::solve_rust_scratch(lp, &popts, None, &mut scratch).unwrap();
    }
    let mut pdhg_bytes = Vec::new();
    for _ in 0..10 {
        pdhg_bytes.push(bytes_during(|| {
            dlt::pdhg::solve_rust_scratch(probe, &popts, None, &mut scratch).unwrap();
        }));
    }
    assert!(
        pdhg_bytes.windows(2).all(|w| w[0] == w[1]),
        "steady-state per-PDHG-solve allocation must be flat: {pdhg_bytes:?}"
    );
}
