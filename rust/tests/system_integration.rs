//! Whole-stack integration: CLI flows, config round-trips through the
//! solvers, experiments CSV emission, cluster-vs-LP fidelity.

use dlt::cluster::{run_cluster, ClusterConfig, Compute};
use dlt::config::spec::{load_spec, save_spec};
use dlt::dlt::frontend::FeOptions;
use dlt::dlt::no_frontend::NfeOptions;
use dlt::dlt::Schedule;
use dlt::error::Result;
use dlt::experiments;
use dlt::model::SystemSpec;

// The per-family solve forwards are gone: everything goes through the
// unified pipeline (or the `dlt::api` facade).
fn fe_solve(spec: &SystemSpec) -> Result<Schedule> {
    dlt::pipeline::solve(&FeOptions::default(), spec)
}

fn nfe_solve(spec: &SystemSpec) -> Result<Schedule> {
    dlt::pipeline::solve(&NfeOptions::default(), spec)
}

fn tmpdir(name: &str) -> String {
    let d = format!("/tmp/dlt_it_{name}_{}", std::process::id());
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn spec_file_roundtrip_through_solver() {
    let dir = tmpdir("roundtrip");
    let spec = SystemSpec::builder()
        .source(0.3, 1.0)
        .source(0.4, 2.0)
        .priced_processors(&[(1.0, 20.0), (2.0, 10.0)])
        .job(50.0)
        .build()
        .unwrap();
    let path = format!("{dir}/spec.json");
    save_spec(&path, &spec).unwrap();
    let loaded = load_spec(&path).unwrap();
    assert_eq!(spec, loaded);
    let s1 = fe_solve(&spec).unwrap();
    let s2 = fe_solve(&loaded).unwrap();
    assert_eq!(s1.makespan, s2.makespan);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cli_experiments_writes_csv() {
    let dir = tmpdir("csv");
    let argv: Vec<String> = ["dlt", "experiments", "--exp", "fig10", "--csv-dir", &dir]
        .iter()
        .map(|s| s.to_string())
        .collect();
    dlt::cli::run(&argv).unwrap();
    let csv = std::fs::read_to_string(format!("{dir}/fig10.csv")).unwrap();
    assert!(csv.starts_with("processor,from_S1,from_S2,total"));
    assert_eq!(csv.lines().count(), 6, "header + 5 processors");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cli_full_pipeline_on_spec_file() {
    let dir = tmpdir("pipeline");
    let path = format!("{dir}/s.json");
    std::fs::write(
        &path,
        r#"{"sources":[{"g":0.2},{"g":0.3,"release":1}],
            "processors":[{"a":1.5,"cost":12},{"a":2.5,"cost":8}],"job":30}"#,
    )
    .unwrap();
    for cmd in [
        format!("solve --spec {path}"),
        format!("solve --spec {path} --model nfe"),
        format!("simulate --spec {path} --model fe --trace"),
        format!("tradeoff --spec {path} --budget-cost 2000 --budget-time 50"),
        format!("speedup --spec {path} --sources 1,2"),
        format!("cluster --spec {path} --time-scale 0.001"),
    ] {
        let argv: Vec<String> = std::iter::once("dlt".to_string())
            .chain(cmd.split_whitespace().map(String::from))
            .collect();
        dlt::cli::run(&argv).unwrap_or_else(|e| panic!("`{cmd}` failed: {e}"));
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cluster_fidelity_nfe_multi_source() {
    // A medium system: the realized makespan must track the LP within
    // scheduler noise.
    let spec = SystemSpec::builder()
        .source(0.2, 0.0)
        .source(0.25, 1.0)
        .source(0.3, 2.0)
        .processors(&[1.0, 1.4, 1.9, 2.5])
        .job(40.0)
        .build()
        .unwrap();
    let sched = nfe_solve(&spec).unwrap();
    let cfg = ClusterConfig { time_scale: 0.004, compute: Compute::Modeled, fe_splits: 8 };
    let rep = run_cluster(&spec, &sched, &cfg).unwrap();
    assert!(
        rep.relative_error.abs() < 0.25,
        "predicted {} realized {} ({:+.1}%)",
        rep.predicted_makespan,
        rep.realized_makespan,
        rep.relative_error * 100.0
    );
    // Load conservation.
    let total: f64 = rep.proc_load.iter().sum();
    assert!((total - 40.0).abs() < 1e-9);
}

#[test]
fn every_experiment_emits_consistent_csv() {
    let dir = tmpdir("all_csv");
    for name in experiments::ALL {
        let t = experiments::run(name).unwrap();
        let path = t.write_csv(&dir).unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        let mut lines = content.lines();
        let header = lines.next().unwrap();
        assert_eq!(header.split(',').count(), t.columns.len(), "{name}");
        assert_eq!(lines.count(), t.rows.len(), "{name}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fe_and_nfe_agree_on_trivial_system() {
    // One source, one processor: both models reduce to
    // T_f = R + J G + J A (receive everything, then compute — FE can
    // stream but the finish-time constraint is identical here).
    let spec = SystemSpec::builder().source(0.5, 2.0).processor(1.5).job(10.0).build().unwrap();
    let fe = fe_solve(&spec).unwrap();
    let nfe = nfe_solve(&spec).unwrap();
    let expect_nfe = 2.0 + 10.0 * 0.5 + 10.0 * 1.5;
    assert!((nfe.makespan - expect_nfe).abs() < 1e-6, "nfe {}", nfe.makespan);
    // FE streams: compute starts at R, bounded by compute time alone.
    let expect_fe = 2.0 + 10.0 * 1.5;
    assert!((fe.makespan - expect_fe).abs() < 1e-6, "fe {}", fe.makespan);
}
