//! Facade-level acceptance properties:
//!
//! - for every scenario family, `Backend::Pdhg` agrees with
//!   `Backend::RevisedSimplex` on the optimal makespan within `1e-4`
//!   relative tolerance, with PDHG demonstrably running *behind
//!   presolve* (presolve stats reported in its `SolveResponse`);
//! - mixed-family batches round-trip through `Session::solve_batch`
//!   and agree with sequential session solves;
//! - sessions keep their backends' results consistent (dense tableau
//!   vs revised simplex).

use dlt::api::{Backend, Family, RequestOptions, SolveRequest, Solver, FAMILIES};
use dlt::dlt::concurrent::Mode;
use dlt::model::SystemSpec;
use dlt::testkit::props;

/// Small, well-conditioned specs the first-order method converges on
/// comfortably (paper-shaped data, job 60..140, releases 0..4).
fn pdhg_spec(seed: usize) -> SystemSpec {
    let n = 2 + seed % 2; // 2..=3 sources
    let m = 2 + (seed / 2) % 2; // 2..=3 processors
    let mut b = SystemSpec::builder();
    for i in 0..n {
        let g = 0.2 + 0.1 * i as f64 + 0.01 * seed as f64;
        let r = (seed % 3) as f64 * (1.0 + i as f64);
        b = b.source(g, r);
    }
    let a: Vec<f64> = (0..m).map(|j| 2.0 + j as f64 + 0.1 * (seed % 5) as f64).collect();
    b.processors(&a).job(60.0 + 10.0 * (seed % 9) as f64).build().unwrap()
}

fn pdhg_request(family: Family, spec: SystemSpec) -> SolveRequest {
    SolveRequest {
        id: None,
        family,
        spec,
        options: RequestOptions {
            backend: Some(Backend::Pdhg),
            // Generous budget: the acceptance bar is agreement, not
            // speed. (tol is absolute on O(1..1e2) residuals.)
            pdhg_max_blocks: Some(20_000),
            ..RequestOptions::default()
        },
    }
}

/// Backend::Pdhg == Backend::RevisedSimplex within 1e-4 relative, for
/// every family, property-tested over a spread of specs.
#[test]
fn prop_pdhg_agrees_with_simplex_per_family() {
    props("pdhg == revised simplex (api)", 12, |g| {
        let seed = g.usize_in(0, 1000);
        let family = FAMILIES[g.usize_in(0, FAMILIES.len())];
        let spec = pdhg_spec(seed);

        let mut session = Solver::new().build();
        // A rare seed could make the NFE LP infeasible (eq. 12); that
        // is a legitimate outcome, not an agreement failure — skip it
        // (a first-order method cannot certify infeasibility).
        let exact = match session.solve(&SolveRequest::new(family, spec.clone())) {
            Ok(r) => r,
            Err(_) => return Ok(()),
        };
        let pdhg = session
            .solve(&pdhg_request(family, spec))
            .map_err(|e| format!("pdhg: {e}"))?;

        assert_eq!(pdhg.backend, Backend::Pdhg);
        let diag = pdhg
            .diagnostics
            .pdhg
            .as_ref()
            .ok_or("pdhg response lost its convergence diagnostics")?;
        let rel = (pdhg.makespan - exact.makespan).abs() / exact.makespan.abs().max(1.0);
        if rel >= 1e-4 {
            return Err(format!(
                "{}: pdhg {} vs simplex {} (rel {rel:.2e}, converged={}, blocks={})",
                family.as_str(),
                pdhg.makespan,
                exact.makespan,
                diag.converged,
                diag.blocks
            ));
        }
        Ok(())
    });
}

/// PDHG runs behind presolve: the NFE family always has a presolve
/// substitution (`TS[0][0] = R_1`), and the PDHG response must carry
/// those stats — proof the backend saw the reduced LP.
#[test]
fn pdhg_runs_behind_presolve_with_stats_reported() {
    let spec = SystemSpec::builder()
        .source(0.2, 0.0)
        .source(0.2, 5.0)
        .processors(&[2.0, 3.0])
        .job(100.0)
        .build()
        .unwrap();
    let mut session = Solver::new().build();
    let resp = session.solve(&pdhg_request(Family::NoFrontend, spec.clone())).unwrap();
    assert!(
        resp.diagnostics.presolve.fixed_vars >= 1,
        "presolve stats missing from the PDHG response: {:?}",
        resp.diagnostics.presolve
    );
    // With presolve disabled per request the stats are empty — the
    // report reflects what actually ran.
    let mut req = pdhg_request(Family::NoFrontend, spec);
    req.options.presolve = Some(false);
    let raw = session.solve(&req).unwrap();
    assert_eq!(raw.diagnostics.presolve.fixed_vars, 0);
    assert!(
        (raw.makespan - resp.makespan).abs() < 1e-3 * (1.0 + resp.makespan),
        "presolve changed the PDHG optimum: {} vs {}",
        raw.makespan,
        resp.makespan
    );
}

/// A mixed-family batch (the `dlt batch` workload) returns responses
/// in input order that match sequential session solves.
#[test]
fn mixed_family_batch_matches_sequential() {
    let spec = SystemSpec::builder()
        .source(0.2, 1.0)
        .source(0.4, 3.0)
        .processors(&[2.0, 3.0, 4.0, 5.0])
        .job(100.0)
        .build()
        .unwrap();
    let mut reqs: Vec<SolveRequest> = Vec::new();
    for k in 0..3 {
        let sub = spec.with_job(80.0 + 30.0 * k as f64);
        reqs.push(SolveRequest::new(Family::Frontend, sub.clone()));
        reqs.push(SolveRequest::new(Family::NoFrontend, sub.clone()));
        reqs.push(SolveRequest {
            id: Some(format!("con-{k}")),
            family: Family::Concurrent,
            spec: sub.clone(),
            options: RequestOptions {
                mode: Some(if k % 2 == 0 { Mode::Staggered } else { Mode::Proportional }),
                ..RequestOptions::default()
            },
        });
        reqs.push(SolveRequest {
            id: Some(format!("mj-{k}")),
            family: Family::MultiJob,
            spec: sub,
            options: RequestOptions {
                proc_ready: Some(vec![0.5, 1.0, 1.5, 2.0]),
                ..RequestOptions::default()
            },
        });
    }
    for threads in [1usize, 2, 4] {
        let batch = Solver::new().threads(threads).build().solve_batch(&reqs);
        assert_eq!(batch.len(), reqs.len());
        let mut sequential = Solver::new().build();
        for (req, out) in reqs.iter().zip(batch.iter()) {
            let b = out.as_ref().unwrap_or_else(|e| {
                panic!("{} failed in batch: {e}", req.family.as_str())
            });
            assert_eq!(b.id, req.id, "ids echo in order");
            let s = sequential.solve(req).unwrap();
            assert!(
                (b.makespan - s.makespan).abs() < 1e-7 * (1.0 + s.makespan),
                "{} (threads={threads}): batch {} vs sequential {}",
                req.family.as_str(),
                b.makespan,
                s.makespan
            );
        }
    }
}

/// `Backend::PdhgBlock` on a single request runs the panel kernels at
/// block width 1 and must agree with the sequential `Backend::Pdhg`
/// driver to fp noise: both start cold from zero, share the step
/// sizes, and check residuals on the same block boundaries.
#[test]
fn prop_pdhg_block_matches_sequential_pdhg() {
    props("pdhg_block == pdhg (api)", 8, |g| {
        let seed = g.usize_in(0, 1000);
        let family = [Family::Frontend, Family::NoFrontend][g.usize_in(0, 2)];
        let spec = pdhg_spec(seed);

        // Cold sessions on both sides: with no warm points to seed
        // from, the two drivers run the same trajectory.
        let mut req = pdhg_request(family, spec);
        let seq = match Solver::new().warm_start(false).build().solve(&req) {
            Ok(r) => r,
            Err(_) => return Ok(()),
        };
        req.options.backend = Some(Backend::PdhgBlock);
        let blk = Solver::new()
            .warm_start(false)
            .build()
            .solve(&req)
            .map_err(|e| format!("pdhg_block: {e}"))?;

        assert_eq!(blk.backend, Backend::PdhgBlock);
        let diag = blk
            .diagnostics
            .pdhg
            .as_ref()
            .ok_or("pdhg_block response lost its convergence diagnostics")?;
        if diag.block_width != 1 {
            return Err(format!("single request must run at width 1, got {}", diag.block_width));
        }
        let rel = (blk.makespan - seq.makespan).abs() / seq.makespan.abs().max(1.0);
        if rel >= 1e-8 {
            return Err(format!(
                "{}: pdhg_block {} vs pdhg {} (rel {rel:.2e})",
                family.as_str(),
                blk.makespan,
                seq.makespan
            ));
        }
        Ok(())
    });
}

/// `Backend::Hybrid` is *exact* on every family: whatever point the
/// loosened PDHG stage reaches, the crossover basis only seeds the
/// revised-simplex cleanup, which finishes at the true optimum.
#[test]
fn hybrid_crossover_reaches_the_simplex_optimum_on_every_family() {
    let spec = SystemSpec::builder()
        .source(0.2, 1.0)
        .source(0.4, 5.0)
        .processors(&[2.0, 3.0, 4.0, 5.0, 6.0])
        .job(100.0)
        .build()
        .unwrap();
    let mut session = Solver::new().build();
    for &family in FAMILIES.iter() {
        let exact = session.solve(&SolveRequest::new(family, spec.clone())).unwrap();
        let mut req = SolveRequest::new(family, spec.clone());
        req.options.backend = Some(Backend::Hybrid);
        let hy = session.solve(&req).unwrap();
        assert_eq!(hy.backend, Backend::Hybrid);
        let diag = hy
            .diagnostics
            .pdhg
            .as_ref()
            .expect("hybrid response carries first-order diagnostics");
        assert!(diag.converged, "{}: simplex cleanup makes hybrid exact", family.as_str());
        assert_eq!(diag.block_width, 1);
        assert!(
            (hy.makespan - exact.makespan).abs() <= 1e-9 * (1.0 + exact.makespan.abs()),
            "{}: hybrid {} vs revised simplex {}",
            family.as_str(),
            hy.makespan,
            exact.makespan
        );
    }
}

/// `sweep::refine` never misses the coarse-grid knee: an independent
/// facade-level evaluation of the same coarse grid locates the knee
/// interval, and the refined bracket must land inside it (and be
/// tighter than `tol` x its width).
#[test]
fn refinement_never_misses_the_coarse_grid_knee() {
    use dlt::cost::advisor::knee_interval;
    use dlt::dlt::schedule::TimingModel;
    use dlt::experiments::sweep::{refine, ContinuousAxis};

    let spec = SystemSpec::builder()
        .source(0.2, 1.0)
        .source(0.4, 5.0)
        .processors(&[2.0, 3.0, 4.0, 5.0, 6.0])
        .job(100.0)
        .build()
        .unwrap();
    let coarse: Vec<f64> = (1..=6).map(|k| k as f64).collect();
    let threshold = 0.05;

    // Independent coarse pass through the public facade, walking the
    // improvement direction (descending link scale) exactly like the
    // advisor walks m = 1..M.
    let mut session = Solver::new().build();
    let mut t = Vec::new();
    for &v in &coarse {
        let resp = session
            .solve(&SolveRequest::new(Family::Frontend, spec.with_scaled_links(v)))
            .unwrap();
        t.push(resp.makespan);
    }
    let n = coarse.len();
    let rate =
        |va: f64, ta: f64, vb: f64, tb: f64| (tb - ta) / (ta.abs().max(1e-12) * (va - vb));
    let rates: Vec<f64> = (0..n - 1)
        .map(|i| rate(coarse[n - 1 - i], t[n - 1 - i], coarse[n - 2 - i], t[n - 2 - i]))
        .collect();
    let k = knee_interval(&rates, threshold)
        .expect("the compute-bound floor guarantees a sub-threshold step on this grid");
    let (clo, chi) = (coarse[n - 2 - k], coarse[n - 1 - k]);

    let tol = 0.05;
    let r = refine(&spec, TimingModel::FrontEnd, ContinuousAxis::LinkScale, &coarse, threshold, tol)
        .unwrap();
    let (lo, hi) = r.knee.expect("refine locates the same knee");
    assert!(
        lo >= clo - 1e-9 && hi <= chi + 1e-9,
        "refined bracket [{lo}, {hi}] escaped the coarse knee interval [{clo}, {chi}]"
    );
    assert!(
        hi - lo <= tol * (chi - clo) + 1e-9,
        "bracket [{lo}, {hi}] wider than tol x the coarse interval [{clo}, {chi}]"
    );
    assert!(r.solves > coarse.len(), "refinement must spend bisection solves");
}

/// The dense tableau and the revised simplex agree through the facade
/// (backend selection is per request, warm state is skipped for the
/// non-default backend only when it cannot use it).
#[test]
fn dense_and_revised_backends_agree_via_api() {
    // Low releases: Table 1's (10, 50) releases make the NFE LP
    // infeasible at J = 100 (eq. 12 forces beta[0][0] >= 200).
    let spec = SystemSpec::builder()
        .source(0.2, 1.0)
        .source(0.4, 5.0)
        .processors(&[2.0, 3.0, 4.0, 5.0, 6.0])
        .job(100.0)
        .build()
        .unwrap();
    let mut session = Solver::new().build();
    for family in [Family::Frontend, Family::NoFrontend] {
        let mut dense_req = SolveRequest::new(family, spec.clone());
        dense_req.options.backend = Some(Backend::DenseTableau);
        let dense = session.solve(&dense_req).unwrap();
        let revised = session.solve(&SolveRequest::new(family, spec.clone())).unwrap();
        assert_eq!(dense.backend, Backend::DenseTableau);
        assert_eq!(revised.backend, Backend::RevisedSimplex);
        assert!(
            (dense.makespan - revised.makespan).abs() < 1e-7 * (1.0 + revised.makespan),
            "{}: dense {} vs revised {}",
            family.as_str(),
            dense.makespan,
            revised.makespan
        );
    }
}
