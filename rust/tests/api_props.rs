//! Facade-level acceptance properties:
//!
//! - for every scenario family, `Backend::Pdhg` agrees with
//!   `Backend::RevisedSimplex` on the optimal makespan within `1e-4`
//!   relative tolerance, with PDHG demonstrably running *behind
//!   presolve* (presolve stats reported in its `SolveResponse`);
//! - mixed-family batches round-trip through `Session::solve_batch`
//!   and agree with sequential session solves;
//! - sessions keep their backends' results consistent (dense tableau
//!   vs revised simplex).

use dlt::api::{Backend, Family, RequestOptions, SolveRequest, Solver, FAMILIES};
use dlt::dlt::concurrent::Mode;
use dlt::model::SystemSpec;
use dlt::testkit::props;

/// Small, well-conditioned specs the first-order method converges on
/// comfortably (paper-shaped data, job 60..140, releases 0..4).
fn pdhg_spec(seed: usize) -> SystemSpec {
    let n = 2 + seed % 2; // 2..=3 sources
    let m = 2 + (seed / 2) % 2; // 2..=3 processors
    let mut b = SystemSpec::builder();
    for i in 0..n {
        let g = 0.2 + 0.1 * i as f64 + 0.01 * seed as f64;
        let r = (seed % 3) as f64 * (1.0 + i as f64);
        b = b.source(g, r);
    }
    let a: Vec<f64> = (0..m).map(|j| 2.0 + j as f64 + 0.1 * (seed % 5) as f64).collect();
    b.processors(&a).job(60.0 + 10.0 * (seed % 9) as f64).build().unwrap()
}

fn pdhg_request(family: Family, spec: SystemSpec) -> SolveRequest {
    SolveRequest {
        id: None,
        family,
        spec,
        options: RequestOptions {
            backend: Some(Backend::Pdhg),
            // Generous budget: the acceptance bar is agreement, not
            // speed. (tol is absolute on O(1..1e2) residuals.)
            pdhg_max_blocks: Some(20_000),
            ..RequestOptions::default()
        },
    }
}

/// Backend::Pdhg == Backend::RevisedSimplex within 1e-4 relative, for
/// every family, property-tested over a spread of specs.
#[test]
fn prop_pdhg_agrees_with_simplex_per_family() {
    props("pdhg == revised simplex (api)", 12, |g| {
        let seed = g.usize_in(0, 1000);
        let family = FAMILIES[g.usize_in(0, FAMILIES.len())];
        let spec = pdhg_spec(seed);

        let mut session = Solver::new().build();
        // A rare seed could make the NFE LP infeasible (eq. 12); that
        // is a legitimate outcome, not an agreement failure — skip it
        // (a first-order method cannot certify infeasibility).
        let exact = match session.solve(&SolveRequest::new(family, spec.clone())) {
            Ok(r) => r,
            Err(_) => return Ok(()),
        };
        let pdhg = session
            .solve(&pdhg_request(family, spec))
            .map_err(|e| format!("pdhg: {e}"))?;

        assert_eq!(pdhg.backend, Backend::Pdhg);
        let diag = pdhg
            .diagnostics
            .pdhg
            .as_ref()
            .ok_or("pdhg response lost its convergence diagnostics")?;
        let rel = (pdhg.makespan - exact.makespan).abs() / exact.makespan.abs().max(1.0);
        if rel >= 1e-4 {
            return Err(format!(
                "{}: pdhg {} vs simplex {} (rel {rel:.2e}, converged={}, blocks={})",
                family.as_str(),
                pdhg.makespan,
                exact.makespan,
                diag.converged,
                diag.blocks
            ));
        }
        Ok(())
    });
}

/// PDHG runs behind presolve: the NFE family always has a presolve
/// substitution (`TS[0][0] = R_1`), and the PDHG response must carry
/// those stats — proof the backend saw the reduced LP.
#[test]
fn pdhg_runs_behind_presolve_with_stats_reported() {
    let spec = SystemSpec::builder()
        .source(0.2, 0.0)
        .source(0.2, 5.0)
        .processors(&[2.0, 3.0])
        .job(100.0)
        .build()
        .unwrap();
    let mut session = Solver::new().build();
    let resp = session.solve(&pdhg_request(Family::NoFrontend, spec.clone())).unwrap();
    assert!(
        resp.diagnostics.presolve.fixed_vars >= 1,
        "presolve stats missing from the PDHG response: {:?}",
        resp.diagnostics.presolve
    );
    // With presolve disabled per request the stats are empty — the
    // report reflects what actually ran.
    let mut req = pdhg_request(Family::NoFrontend, spec);
    req.options.presolve = Some(false);
    let raw = session.solve(&req).unwrap();
    assert_eq!(raw.diagnostics.presolve.fixed_vars, 0);
    assert!(
        (raw.makespan - resp.makespan).abs() < 1e-3 * (1.0 + resp.makespan),
        "presolve changed the PDHG optimum: {} vs {}",
        raw.makespan,
        resp.makespan
    );
}

/// A mixed-family batch (the `dlt batch` workload) returns responses
/// in input order that match sequential session solves.
#[test]
fn mixed_family_batch_matches_sequential() {
    let spec = SystemSpec::builder()
        .source(0.2, 1.0)
        .source(0.4, 3.0)
        .processors(&[2.0, 3.0, 4.0, 5.0])
        .job(100.0)
        .build()
        .unwrap();
    let mut reqs: Vec<SolveRequest> = Vec::new();
    for k in 0..3 {
        let sub = spec.with_job(80.0 + 30.0 * k as f64);
        reqs.push(SolveRequest::new(Family::Frontend, sub.clone()));
        reqs.push(SolveRequest::new(Family::NoFrontend, sub.clone()));
        reqs.push(SolveRequest {
            id: Some(format!("con-{k}")),
            family: Family::Concurrent,
            spec: sub.clone(),
            options: RequestOptions {
                mode: Some(if k % 2 == 0 { Mode::Staggered } else { Mode::Proportional }),
                ..RequestOptions::default()
            },
        });
        reqs.push(SolveRequest {
            id: Some(format!("mj-{k}")),
            family: Family::MultiJob,
            spec: sub,
            options: RequestOptions {
                proc_ready: Some(vec![0.5, 1.0, 1.5, 2.0]),
                ..RequestOptions::default()
            },
        });
    }
    for threads in [1usize, 2, 4] {
        let batch = Solver::new().threads(threads).build().solve_batch(&reqs);
        assert_eq!(batch.len(), reqs.len());
        let mut sequential = Solver::new().build();
        for (req, out) in reqs.iter().zip(batch.iter()) {
            let b = out.as_ref().unwrap_or_else(|e| {
                panic!("{} failed in batch: {e}", req.family.as_str())
            });
            assert_eq!(b.id, req.id, "ids echo in order");
            let s = sequential.solve(req).unwrap();
            assert!(
                (b.makespan - s.makespan).abs() < 1e-7 * (1.0 + s.makespan),
                "{} (threads={threads}): batch {} vs sequential {}",
                req.family.as_str(),
                b.makespan,
                s.makespan
            );
        }
    }
}

/// The dense tableau and the revised simplex agree through the facade
/// (backend selection is per request, warm state is skipped for the
/// non-default backend only when it cannot use it).
#[test]
fn dense_and_revised_backends_agree_via_api() {
    // Low releases: Table 1's (10, 50) releases make the NFE LP
    // infeasible at J = 100 (eq. 12 forces beta[0][0] >= 200).
    let spec = SystemSpec::builder()
        .source(0.2, 1.0)
        .source(0.4, 5.0)
        .processors(&[2.0, 3.0, 4.0, 5.0, 6.0])
        .job(100.0)
        .build()
        .unwrap();
    let mut session = Solver::new().build();
    for family in [Family::Frontend, Family::NoFrontend] {
        let mut dense_req = SolveRequest::new(family, spec.clone());
        dense_req.options.backend = Some(Backend::DenseTableau);
        let dense = session.solve(&dense_req).unwrap();
        let revised = session.solve(&SolveRequest::new(family, spec.clone())).unwrap();
        assert_eq!(dense.backend, Backend::DenseTableau);
        assert_eq!(revised.backend, Backend::RevisedSimplex);
        assert!(
            (dense.makespan - revised.makespan).abs() < 1e-7 * (1.0 + revised.makespan),
            "{}: dense {} vs revised {}",
            family.as_str(),
            dense.makespan,
            revised.makespan
        );
    }
}
