//! Property tests over the scheduling core (testkit harness):
//! solver invariants on randomly generated, valid systems.

use dlt::dlt::frontend::{self, FeOptions};
use dlt::dlt::no_frontend::NfeOptions;
use dlt::dlt::schedule::TimingModel;
use dlt::dlt::{validate, Schedule};
use dlt::error::Error;
use dlt::model::SystemSpec;
use dlt::sim::{simulate, SimOptions};
use dlt::testkit::{arb_spec, props};

// The per-family solve forwards are gone: solve through the pipeline.
fn fe_solve(spec: &SystemSpec) -> dlt::error::Result<Schedule> {
    dlt::pipeline::solve(&FeOptions::default(), spec)
}

fn nfe_solve(spec: &SystemSpec) -> dlt::error::Result<Schedule> {
    dlt::pipeline::solve(&NfeOptions::default(), spec)
}

/// Some random specs make the §3.2 LP infeasible (eq. 12 can demand
/// more first-fraction load than J provides) — that is a legitimate
/// outcome, not a failure. Everything *returned* must be valid.
#[test]
fn prop_nfe_schedules_validate() {
    props("nfe schedules validate", 60, |g| {
        let spec = arb_spec(g, 4, 6);
        match nfe_solve(&spec) {
            Ok(s) => {
                let rep = validate(&spec, &s);
                if !rep.is_valid() {
                    return Err(format!("{:?} on {spec:?}", rep.violations));
                }
                if (s.total_load() - spec.job).abs() > 1e-6 * spec.job {
                    return Err(format!("normalization broke: {}", s.total_load()));
                }
                Ok(())
            }
            Err(Error::Infeasible(_)) => Ok(()),
            Err(e) => Err(format!("unexpected error {e}")),
        }
    });
}

#[test]
fn prop_fe_schedules_validate() {
    props("fe schedules validate", 60, |g| {
        let spec = arb_spec(g, 4, 6);
        match fe_solve(&spec) {
            Ok(s) => {
                let rep = validate(&spec, &s);
                if !rep.is_valid() {
                    return Err(format!("{:?} on {spec:?}", rep.violations));
                }
                Ok(())
            }
            Err(Error::Infeasible(_)) => Ok(()),
            Err(e) => Err(format!("unexpected error {e}")),
        }
    });
}

/// Front-ends never hurt: FE optimum <= NFE optimum on the same spec.
#[test]
fn prop_fe_never_slower_than_nfe() {
    props("fe <= nfe", 40, |g| {
        let spec = arb_spec(g, 3, 5);
        let (Ok(fe), Ok(nfe)) = (fe_solve(&spec), nfe_solve(&spec)) else {
            return Ok(()); // either model infeasible -> nothing to compare
        };
        if fe.makespan <= nfe.makespan + 1e-6 {
            Ok(())
        } else {
            Err(format!("fe {} > nfe {}", fe.makespan, nfe.makespan))
        }
    });
}

/// The DES, executing the LP's β greedily (ASAP), never finishes later
/// than the LP's own T_f — the LP's timing is achievable.
#[test]
fn prop_des_achieves_lp_makespan() {
    props("des <= lp", 50, |g| {
        let spec = arb_spec(g, 3, 5);
        let Ok(s) = nfe_solve(&spec) else { return Ok(()) };
        let res = simulate(&spec, &s.beta, &SimOptions::default());
        if res.makespan <= s.makespan + 1e-6 {
            Ok(())
        } else {
            Err(format!("sim {} > lp {}", res.makespan, s.makespan))
        }
    });
}

/// NOTE: the §3.1 formulation leans on the paper's stated assumption
/// that "it always takes a much longer time to compute the data rather
/// than transfer it" (§3). When a link is *slower* than a processor
/// (G_i > A_j), a front-end processor can starve mid-stream and the
/// LP's T_f becomes optimistic (found by this very property — see
/// DESIGN.md §Paper wrinkles). The property therefore generates specs
/// in the paper's regime: every G strictly below every A.
#[test]
fn prop_des_achieves_fe_makespan() {
    props("des fe <= lp", 50, |g| {
        let mut spec = arb_spec(g, 3, 5);
        let min_a = spec.processors.iter().map(|p| p.a).fold(f64::INFINITY, f64::min);
        let max_g = spec.sources.iter().map(|s| s.g).fold(0.0f64, f64::max);
        if max_g > 0.8 * min_a {
            let scale = 0.8 * min_a / max_g;
            for s in spec.sources.iter_mut() {
                s.g *= scale;
            }
        }
        let Ok(s) = fe_solve(&spec) else { return Ok(()) };
        let res = simulate(
            &spec,
            &s.beta,
            &SimOptions { model: TimingModel::FrontEnd, ..Default::default() },
        );
        if res.makespan <= s.makespan + 1e-6 {
            Ok(())
        } else {
            Err(format!("sim {} > lp {}", res.makespan, s.makespan))
        }
    });
}

/// Adding a (fast) processor never makes the optimum worse.
#[test]
fn prop_monotone_in_processors() {
    props("monotone in m", 30, |g| {
        let spec = arb_spec(g, 3, 6);
        if spec.m() < 2 {
            return Ok(());
        }
        let (Ok(full), Ok(fewer)) = (
            fe_solve(&spec),
            fe_solve(&spec.with_m_processors(spec.m() - 1)),
        ) else {
            return Ok(());
        };
        if full.makespan <= fewer.makespan + 1e-6 {
            Ok(())
        } else {
            Err(format!("m={}: {} > m={}: {}", spec.m(), full.makespan, spec.m() - 1, fewer.makespan))
        }
    });
}

/// Scaling the job scales the FE schedule linearly when releases are
/// zero (the LP is homogeneous in (β, T_f) then).
#[test]
fn prop_job_scaling_linear_when_no_release() {
    props("job scaling", 30, |g| {
        let mut spec = arb_spec(g, 3, 4);
        for s in spec.sources.iter_mut() {
            s.release = 0.0;
        }
        let k = g.f64_in(1.5, 4.0);
        let (Ok(s1), Ok(sk)) = (fe_solve(&spec), fe_solve(&spec.with_job(spec.job * k)))
        else {
            return Ok(());
        };
        let rel = (sk.makespan - k * s1.makespan).abs() / (k * s1.makespan);
        if rel < 1e-6 {
            Ok(())
        } else {
            Err(format!("T_f({k}J) = {} != {k} * {}", sk.makespan, s1.makespan))
        }
    });
}

/// PDHG (rust backend) agrees with the simplex optimum on random FE
/// scheduling LPs.
#[test]
fn prop_pdhg_matches_simplex_on_fe_lps() {
    props("pdhg == simplex", 12, |g| {
        let spec = arb_spec(g, 2, 4);
        let lp = frontend::build_lp(&spec, &Default::default());
        let Ok(exact) = dlt::lp::solve(&lp) else { return Ok(()) };
        let sol = dlt::pdhg::solve_rust(&lp, &Default::default()).map_err(|e| format!("{e}"))?;
        let rel = (sol.objective - exact.objective).abs() / exact.objective.abs().max(1.0);
        if rel < 5e-3 {
            Ok(())
        } else {
            Err(format!(
                "pdhg {} vs simplex {} (rel {rel:.2e}, converged={})",
                sol.objective, exact.objective, sol.converged
            ))
        }
    });
}

/// Jittered simulations degrade gracefully: makespan under ±j jitter
/// stays within (1 ± 2j) of nominal.
#[test]
fn prop_jitter_bounded_degradation() {
    props("jitter bounded", 30, |g| {
        let spec = arb_spec(g, 3, 4);
        let Ok(s) = nfe_solve(&spec) else { return Ok(()) };
        let j = g.f64_in(0.01, 0.2);
        let res = simulate(
            &spec,
            &s.beta,
            &SimOptions {
                link_jitter: j,
                compute_jitter: j,
                seed: g.seed,
                ..Default::default()
            },
        );
        let hi = s.makespan * (1.0 + 2.0 * j) + 1e-9;
        // Lower bound is loose: jitter can shrink both comm and compute.
        let lo = s.makespan * (1.0 - 2.0 * j) - 1e-9;
        if res.makespan <= hi && res.makespan >= lo {
            Ok(())
        } else {
            Err(format!("jitter {j}: {} outside [{lo}, {hi}]", res.makespan))
        }
    });
}
