//! Wire-framing fuzz tests and live loopback-server tests for the
//! serving tier.
//!
//! The framing contract under fire here: arbitrary byte streams —
//! truncated, concatenated, interleaved with garbage, oversize,
//! non-UTF-8 — never panic the reader, a malformed frame yields
//! exactly one error response, and the connection stays usable
//! afterwards.

use dlt::api::{Family, SolveRequest};
use dlt::config::json::Json;
use dlt::model::SystemSpec;
use dlt::serve::{Frame, FrameReader, ServeOptions, Server};
use dlt::util::{Pcg32, Rng};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

fn spec() -> SystemSpec {
    SystemSpec::builder()
        .source(0.2, 10.0)
        .source(0.4, 50.0)
        .processors(&[2.0, 3.0, 4.0])
        .job(100.0)
        .build()
        .unwrap()
}

fn request_text(client: &str, id: &str) -> String {
    let mut req = SolveRequest::new(Family::Frontend, spec());
    req.id = Some(id.to_string());
    let mut doc = req.to_json();
    if let Json::Object(kv) = &mut doc {
        kv.insert(0, ("client".to_string(), Json::Str(client.to_string())));
    }
    doc.to_string_compact()
}

// ---------------------------------------------------------------------------
// FrameReader fuzz: random corpora through random chunkings.
// ---------------------------------------------------------------------------

/// Build a corpus of lines of every flavor the wire can carry, return
/// (bytes, expected frame events).
fn build_corpus(rng: &mut Pcg32, cap: usize) -> (Vec<u8>, Vec<Frame>) {
    let mut bytes = Vec::new();
    let mut want = Vec::new();
    for k in 0..40 {
        match rng.below(6) {
            // Valid request document.
            0 => {
                let line = request_text("fuzz", &format!("r{k}"));
                want.push(Frame::Line(line.clone()));
                bytes.extend_from_slice(line.as_bytes());
                bytes.push(b'\n');
            }
            // Malformed JSON (still a complete, valid UTF-8 line).
            1 => {
                let line = format!("{{\"family\": \"frontend\", {k}");
                want.push(Frame::Line(line.clone()));
                bytes.extend_from_slice(line.as_bytes());
                bytes.push(b'\n');
            }
            // Blank keep-alives, bare and CRLF — skipped silently.
            2 => {
                bytes.push(b'\n');
                bytes.extend_from_slice(b"\r\n");
            }
            // Oversize line: dropped, one Oversize event.
            3 => {
                let n = cap + 1 + rng.below(2 * cap);
                bytes.extend_from_slice(&vec![b'x'; n]);
                bytes.push(b'\n');
                want.push(Frame::Oversize { dropped: 0 });
            }
            // Non-UTF-8 line.
            4 => {
                bytes.extend_from_slice(&[0xff, 0xfe, 0x80, b'!']);
                bytes.push(b'\n');
                want.push(Frame::NotUtf8);
            }
            // CRLF-terminated valid line.
            _ => {
                let line = format!("{{\"k\": {k}}}");
                want.push(Frame::Line(line.clone()));
                bytes.extend_from_slice(line.as_bytes());
                bytes.extend_from_slice(b"\r\n");
            }
        }
    }
    (bytes, want)
}

/// Events must match regardless of how the bytes were chunked; the
/// `dropped` count of Oversize events is chunking-dependent (it counts
/// flushes of the discard buffer), so compare everything else exactly
/// and Oversize by kind.
fn assert_same_events(got: &[Frame], want: &[Frame], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: event count");
    for (g, w) in got.iter().zip(want) {
        match (g, w) {
            (Frame::Oversize { dropped }, Frame::Oversize { .. }) => {
                assert!(*dropped > 0, "{what}: oversize dropped nothing");
            }
            _ => assert_eq!(g, w, "{what}"),
        }
    }
}

#[test]
fn fuzz_random_chunkings_yield_identical_frames() {
    let cap = 256;
    for round in 0..20 {
        let mut rng = Pcg32::new(0xF0A3 + round);
        let (bytes, want) = build_corpus(&mut rng, cap);
        for trial in 0..10 {
            let mut r = FrameReader::new(cap);
            let mut got = Vec::new();
            let mut pos = 0;
            while pos < bytes.len() {
                let step = 1 + rng.below(97);
                let end = (pos + step).min(bytes.len());
                r.push(&bytes[pos..end]);
                pos = end;
                while let Some(f) = r.next_frame() {
                    got.push(f);
                }
            }
            assert_same_events(&got, &want, &format!("round {round} trial {trial}"));
        }
    }
}

#[test]
fn fuzz_pure_garbage_never_panics() {
    let mut rng = Pcg32::new(0xBAD5EED);
    for _ in 0..50 {
        let n = rng.below(4096);
        let bytes: Vec<u8> = (0..n).map(|_| rng.next_u32() as u8).collect();
        let mut r = FrameReader::new(128);
        for chunk in bytes.chunks(1 + rng.below(64)) {
            r.push(chunk);
            while r.next_frame().is_some() {}
        }
        // Bounded memory even if no newline ever arrived.
        assert!(r.buffered() <= 128 + 64, "buffer grew past the cap + one chunk");
    }
}

// ---------------------------------------------------------------------------
// Live loopback server.
// ---------------------------------------------------------------------------

fn boot(configure: impl FnOnce(&mut ServeOptions)) -> (Server, TcpStream, BufReader<TcpStream>) {
    let mut opts = ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        shards: 4,
        ..ServeOptions::default()
    };
    configure(&mut opts);
    let server = Server::start(opts).expect("start server");
    let stream = TcpStream::connect(server.local_addr()).expect("connect");
    stream.set_nodelay(true).unwrap();
    let reader = stream.try_clone().unwrap();
    reader.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    (server, stream, BufReader::new(reader))
}

fn read_docs(reader: &mut BufReader<TcpStream>, n: usize) -> Vec<Json> {
    let mut docs = Vec::with_capacity(n);
    let mut line = String::new();
    while docs.len() < n {
        line.clear();
        let read = reader.read_line(&mut line).expect("response before timeout");
        assert!(read > 0, "server closed the connection early");
        docs.push(Json::parse(line.trim_end()).expect("response line parses"));
    }
    docs
}

fn seq_of(doc: &Json) -> usize {
    doc.req("seq").unwrap().as_usize().unwrap()
}

fn error_kind(doc: &Json) -> Option<&str> {
    doc.get("error").map(|e| e.req("kind").unwrap().as_str().unwrap())
}

#[test]
fn mixed_malformed_split_and_batched_frames_all_get_answers() {
    let (server, mut stream, mut reader) = boot(|_| {});

    let good = request_text("alice", "good-1");
    // seq 0: valid single request.
    stream.write_all(format!("{good}\n").as_bytes()).unwrap();
    // seq 1: malformed JSON -> exactly one config error.
    stream.write_all(b"{\"family\": \"frontend\",\n").unwrap();
    // seq 2-3: a two-element batch array frame.
    let batch = format!("[{}, {}]\n", request_text("alice", "b-0"), request_text("bob", "b-1"));
    stream.write_all(batch.as_bytes()).unwrap();
    // Blank keep-alives: no seq, no response.
    stream.write_all(b"\r\n\n").unwrap();
    // seq 4: non-UTF-8 line -> one config error.
    stream.write_all(&[0xff, 0xfe, 0x01, b'\n']).unwrap();
    // seq 5: valid request split across two writes (torn frame).
    let torn = request_text("carol", "torn-1");
    let (head, tail) = torn.split_at(torn.len() / 2);
    stream.write_all(head.as_bytes()).unwrap();
    stream.flush().unwrap();
    std::thread::sleep(Duration::from_millis(20));
    stream.write_all(format!("{tail}\n").as_bytes()).unwrap();
    // seq 6-7: two frames concatenated into one write.
    let two = format!(
        "{}\n{}\n",
        request_text("alice", "cat-1"),
        request_text("dave", "cat-2")
    );
    stream.write_all(two.as_bytes()).unwrap();

    let docs = read_docs(&mut reader, 8);
    let mut seqs: Vec<usize> = docs.iter().map(seq_of).collect();
    seqs.sort_unstable();
    assert_eq!(seqs, (0..8).collect::<Vec<_>>(), "every frame got exactly one response");

    for doc in &docs {
        match seq_of(doc) {
            1 | 4 => {
                assert_eq!(error_kind(doc), Some("config"), "malformed frame -> config error");
            }
            _ => {
                assert!(error_kind(doc).is_none(), "valid request solved: {doc:?}");
                assert!(doc.req("makespan").unwrap().as_f64().unwrap() > 0.0);
            }
        }
    }

    // The connection survived all of it: one more request still works.
    stream.write_all(format!("{}\n", request_text("alice", "after")).as_bytes()).unwrap();
    let after = read_docs(&mut reader, 1);
    assert_eq!(seq_of(&after[0]), 8);
    assert!(error_kind(&after[0]).is_none());
    // alice solved earlier on this shard, so her session is warm.
    let serve = after[0].req("diagnostics").unwrap().req("serve").unwrap();
    assert!(serve.req("shard_hit").unwrap().as_bool().unwrap(), "alice should be warm");

    let stats = server.shutdown();
    assert_eq!(stats.malformed, 2);
    assert_eq!(stats.responses, 7, "seven solves; the two malformed frames never reach a shard");
}

#[test]
fn zero_queue_depth_sheds_with_retry_hint() {
    let (server, mut stream, mut reader) = boot(|o| {
        o.queue_depth = 0;
        o.retry_after_ms = 17;
    });
    for k in 0..5 {
        let line = request_text("shed-client", &format!("s{k}"));
        stream.write_all(format!("{line}\n").as_bytes()).unwrap();
    }
    let docs = read_docs(&mut reader, 5);
    for doc in &docs {
        assert_eq!(error_kind(doc), Some("overloaded"));
        assert_eq!(doc.req("retry_after_ms").unwrap().as_usize().unwrap(), 17);
    }
    let stats = server.shutdown();
    assert_eq!(stats.shed, 5);
    assert_eq!(stats.responses, 0);
}

#[test]
fn tiny_budget_evicts_and_revisits_come_back_cold() {
    // One worker, one shard: every client lands on the same shard and
    // the eviction order is deterministic LRU.
    let (server, mut stream, mut reader) = boot(|o| {
        o.workers = 1;
        o.shards = 1;
        o.warm_budget_bytes = 1; // evict down to a single session
    });

    // Eight distinct clients in a row: each new session pushes the
    // previous one over the budget.
    for k in 0..8 {
        let line = request_text(&format!("tenant-{k}"), &format!("t{k}"));
        stream.write_all(format!("{line}\n").as_bytes()).unwrap();
    }
    let docs = read_docs(&mut reader, 8);
    let last = &docs[7];
    let serve = last.req("diagnostics").unwrap().req("serve").unwrap();
    assert!(serve.req("evictions").unwrap().as_f64().unwrap() >= 6.0, "LRU evictions happened");
    assert!(serve.req("resident").unwrap().as_usize().unwrap() <= 2, "budget holds");

    // tenant-0 was evicted long ago: revisiting it is a shard miss.
    let line = request_text("tenant-0", "revisit");
    stream.write_all(format!("{line}\n").as_bytes()).unwrap();
    let doc = &read_docs(&mut reader, 1)[0];
    let serve = doc.req("diagnostics").unwrap().req("serve").unwrap();
    assert!(!serve.req("shard_hit").unwrap().as_bool().unwrap(), "evicted client is cold");

    let stats = server.shutdown();
    assert!(stats.evictions >= 6);
    assert_eq!(stats.shard_hits, 0);
    assert_eq!(stats.shard_misses, 9);
}

#[test]
fn degraded_mode_absorbs_overflow_with_flagged_answers() {
    // One worker, one shard, queue depth 1: a batch array frame admits
    // all its items back-to-back with no solving in between, so the
    // overflow pattern is deterministic — 1 admitted, 1 degraded
    // (queue_depth of overflow), 4 shed.
    let (server, mut stream, mut reader) = boot(|o| {
        o.workers = 1;
        o.shards = 1;
        o.queue_depth = 1;
        o.degraded = true;
        o.retry_after_ms = 10;
    });
    let items: Vec<String> = (0..6).map(|k| request_text("burst", &format!("b{k}"))).collect();
    stream.write_all(format!("[{}]\n", items.join(", ")).as_bytes()).unwrap();
    let docs = read_docs(&mut reader, 6);
    let (mut normal, mut degraded, mut shed) = (0, 0, 0);
    for doc in &docs {
        match error_kind(doc) {
            Some("overloaded") => {
                shed += 1;
                // The shard queue held 2 jobs at shed time, so the
                // adaptive hint sits above the base and under its cap.
                let hint = doc.req("retry_after_ms").unwrap().as_usize().unwrap();
                assert!(hint > 10 && hint <= 10 * 32, "adaptive hint out of range: {hint}");
            }
            Some(k) => panic!("unexpected error kind `{k}`"),
            None => {
                assert!(doc.req("makespan").unwrap().as_f64().unwrap() > 0.0);
                let flagged =
                    doc.get("degraded").map(|d| d.as_bool().unwrap()).unwrap_or(false);
                if flagged {
                    degraded += 1;
                } else {
                    normal += 1;
                }
            }
        }
    }
    assert_eq!((normal, degraded, shed), (1, 1, 4));
    let stats = server.shutdown();
    assert_eq!(stats.shed, 4);
    assert_eq!(stats.degraded, 1);
    assert_eq!(stats.responses, 2);
}

#[test]
fn reload_swaps_knobs_without_dropping_the_connection() {
    let (server, mut stream, mut reader) = boot(|o| {
        o.workers = 1;
        o.shards = 1;
        o.retry_after_ms = 17;
    });
    // seq 0: a normal solve before the reload.
    stream.write_all(format!("{}\n", request_text("alice", "pre")).as_bytes()).unwrap();
    assert!(error_kind(&read_docs(&mut reader, 1)[0]).is_none());

    // seq 1: the admin frame; the ack echoes the effective values.
    stream
        .write_all(b"{\"reload\": {\"queue_depth\": 0, \"retry_after_ms\": 23}}\n")
        .unwrap();
    let ack = &read_docs(&mut reader, 1)[0];
    assert_eq!(seq_of(ack), 1);
    let r = ack.req("reloaded").unwrap();
    assert_eq!(r.req("queue_depth").unwrap().as_usize().unwrap(), 0);
    assert_eq!(r.req("retry_after_ms").unwrap().as_usize().unwrap(), 23);

    // seq 2: the same connection now sheds, with the new base hint.
    stream.write_all(format!("{}\n", request_text("alice", "post")).as_bytes()).unwrap();
    let post = &read_docs(&mut reader, 1)[0];
    assert_eq!(error_kind(post), Some("overloaded"));
    assert_eq!(post.req("retry_after_ms").unwrap().as_usize().unwrap(), 23);

    // seq 3: an unknown reload key is a typed config error.
    stream.write_all(b"{\"reload\": {\"shard_count\": 9}}\n").unwrap();
    assert_eq!(error_kind(&read_docs(&mut reader, 1)[0]), Some("config"));

    // seq 4-5: reload the depth back up and solve again — the
    // connection was never dropped.
    stream.write_all(b"{\"reload\": {\"queue_depth\": 8}}\n").unwrap();
    let ack2 = &read_docs(&mut reader, 1)[0];
    assert_eq!(
        ack2.req("reloaded").unwrap().req("queue_depth").unwrap().as_usize().unwrap(),
        8
    );
    stream.write_all(format!("{}\n", request_text("alice", "after")).as_bytes()).unwrap();
    let after = &read_docs(&mut reader, 1)[0];
    assert_eq!(seq_of(after), 5);
    assert!(error_kind(after).is_none());

    let stats = server.shutdown();
    assert_eq!(stats.shed, 1);
}

#[test]
fn zero_deadline_requests_answer_deadline_exceeded() {
    let (server, mut stream, mut reader) = boot(|_| {});
    let mut req = SolveRequest::new(Family::Frontend, spec());
    req.id = Some("dl-0".into());
    req.options.backend = Some(dlt::pipeline::Backend::Pdhg);
    req.options.timeout_ms = Some(0);
    stream.write_all(format!("{}\n", req.to_json().to_string_compact()).as_bytes()).unwrap();
    // Whether the deadline fires in the queue or inside the solve, the
    // wire answer is the same typed kind.
    let doc = &read_docs(&mut reader, 1)[0];
    assert_eq!(error_kind(doc), Some("deadline_exceeded"), "{doc:?}");
    server.shutdown();
}

#[test]
fn graceful_shutdown_answers_every_admitted_request() {
    let (server, mut stream, mut reader) = boot(|_| {});
    for k in 0..6 {
        let line = request_text("drain-client", &format!("d{k}"));
        stream.write_all(format!("{line}\n").as_bytes()).unwrap();
    }
    // Read every response *before* shutdown so all six were admitted.
    let docs = read_docs(&mut reader, 6);
    assert!(docs.iter().all(|d| error_kind(d).is_none()));

    let stats = server.shutdown();
    assert_eq!(stats.requests, 6);
    assert_eq!(stats.responses, 6);
    assert_eq!(stats.shed, 0);

    // The drained server's socket is gone: the read side sees EOF.
    let mut line = String::new();
    let eof = reader.read_line(&mut line);
    assert!(matches!(eof, Ok(0)), "connection closed after drain, got {eof:?} {line:?}");
}
