//! Strategy-grid properties for the pluggable simplex layers: every
//! `(factorization, pricing)` combination — all four factorizations
//! (eta file, Forrest–Tomlin, Markowitz, Bartels–Golub) crossed with
//! all four pricing rules, including candidate-list partial pricing —
//! must agree with the dense tableau oracle on makespan across all
//! scenario families, Forrest–Tomlin must refactorize strictly less
//! often than the product-form eta file on a long pivot sequence, the
//! hypersparse FTRAN/BTRAN kernels must agree with the dense adapters
//! to 1e-10 on randomized bases, and the scratch-pooled batch path
//! must return bit-identical solutions.

use dlt::dlt::concurrent::{ConcurrentOptions, Mode};
use dlt::dlt::frontend::FeOptions;
use dlt::dlt::multi_job::MultiJobStepModel;
use dlt::dlt::no_frontend::{self, NfeOptions};
use dlt::lp::{solve_with, Factorization, Pricing, SimplexOptions, SolverBackend};
use dlt::model::SystemSpec;
use dlt::pipeline::{self, Backend, PipelineOptions, ScenarioModel};
use dlt::testkit::{arb_spec, props};

const ALL_FACTS: [Factorization; 4] = [
    Factorization::ProductFormEta,
    Factorization::ForrestTomlin,
    Factorization::Markowitz,
    Factorization::BartelsGolub,
];

fn combos() -> Vec<(Factorization, Pricing)> {
    let mut out = Vec::new();
    for f in ALL_FACTS {
        for p in [Pricing::Dantzig, Pricing::Devex, Pricing::SteepestEdge, Pricing::Partial] {
            out.push((f, p));
        }
    }
    out
}

fn combo_opts(f: Factorization, p: Pricing) -> PipelineOptions {
    PipelineOptions {
        simplex: SimplexOptions { factorization: f, pricing: p, ..SimplexOptions::default() },
        ..PipelineOptions::default()
    }
}

fn dense_opts() -> PipelineOptions {
    PipelineOptions { backend: Backend::DenseTableau, ..PipelineOptions::default() }
}

/// Deterministic anchor instances for all four families, solved by
/// every strategy combination and compared against the dense oracle at
/// 1e-8 relative — the satellite's makespan-parity bar.
#[test]
fn all_combos_match_dense_oracle_on_all_families() {
    let spec = SystemSpec::builder()
        .source(0.2, 0.0)
        .source(0.3, 2.0)
        .processors(&[2.0, 3.0, 4.0, 5.0])
        .job(100.0)
        .build()
        .unwrap();
    let models: Vec<(&str, Box<dyn ScenarioModel>)> = vec![
        ("frontend", Box::new(FeOptions::default())),
        ("no_frontend", Box::new(NfeOptions::default())),
        ("concurrent/staggered", Box::new(ConcurrentOptions { mode: Mode::Staggered })),
        ("concurrent/proportional", Box::new(ConcurrentOptions { mode: Mode::Proportional })),
        (
            "multi_job",
            Box::new(MultiJobStepModel {
                fe: FeOptions {
                    proc_ready: Some(vec![1.0, 2.0, 3.0, 4.0]),
                    ..Default::default()
                },
            }),
        ),
    ];
    for (name, model) in &models {
        let oracle = pipeline::solve_full(model.as_ref(), &spec, &dense_opts(), None, None)
            .unwrap()
            .schedule
            .makespan;
        for (f, p) in combos() {
            let got =
                pipeline::solve_full(model.as_ref(), &spec, &combo_opts(f, p), None, None)
                    .unwrap()
                    .schedule
                    .makespan;
            assert!(
                (got - oracle).abs() <= 1e-8 * (1.0 + oracle.abs()),
                "{name} under {}/{}: {got} vs oracle {oracle}",
                f.as_str(),
                p.as_str()
            );
        }
    }
}

/// Randomized parity per combination (looser tolerance — random
/// instances can terminate at eps-distinct vertices).
#[test]
fn prop_combos_match_dense_oracle_on_random_specs() {
    let dense = SimplexOptions {
        backend: SolverBackend::DenseTableau,
        ..SimplexOptions::default()
    };
    props("strategy combos == dense oracle", 30, |g| {
        let spec = arb_spec(g, 3, 5);
        let lp = if g.bool() {
            dlt::dlt::frontend::build_lp(&spec, &FeOptions::default())
        } else {
            no_frontend::build_lp(&spec, &NfeOptions::default())
        };
        let oracle = solve_with(&lp, &dense);
        for (f, p) in combos() {
            let opts = SimplexOptions {
                factorization: f,
                pricing: p,
                ..SimplexOptions::default()
            };
            match (&oracle, solve_with(&lp, &opts)) {
                (Ok(a), Ok(b)) => {
                    let tol = 1e-6 * (1.0 + a.objective.abs());
                    if (a.objective - b.objective).abs() > tol {
                        return Err(format!(
                            "{}/{}: {} vs oracle {}",
                            f.as_str(),
                            p.as_str(),
                            b.objective,
                            a.objective
                        ));
                    }
                    if let Some(v) = lp.check_feasible(&b.x, 1e-6) {
                        return Err(format!("{}/{}: infeasible point: {v}", f.as_str(), p.as_str()));
                    }
                }
                (Err(_), Err(_)) => {}
                (a, b) => {
                    return Err(format!(
                        "{}/{}: solvability disagrees: oracle {a:?} vs {b:?}",
                        f.as_str(),
                        p.as_str()
                    ))
                }
            }
        }
        Ok(())
    });
}

/// Regression for the tentpole's perf claim: on a long pivot sequence
/// (a cold NFE solve with ~165 rows, well past the 48-pivot eta
/// cadence) Forrest–Tomlin performs strictly fewer full
/// refactorizations than the product-form eta file, at the same
/// optimum.
#[test]
fn forrest_tomlin_refactorizes_less_on_long_pivot_sequences() {
    let mut b = SystemSpec::builder();
    for i in 0..3 {
        b = b.source(0.5 + 0.01 * i as f64, i as f64 * 0.5);
    }
    let a: Vec<f64> = (0..18).map(|k| 1.1 + 0.1 * k as f64).collect();
    let spec = b.processors(&a).job(100.0).build().unwrap();
    let lp = no_frontend::build_lp(&spec, &NfeOptions::default());

    let run = |f: Factorization| {
        let opts = SimplexOptions { factorization: f, ..SimplexOptions::default() };
        solve_with(&lp, &opts).unwrap()
    };
    let pfe = run(Factorization::ProductFormEta);
    let ft = run(Factorization::ForrestTomlin);

    assert!(
        (pfe.objective - ft.objective).abs() <= 1e-8 * (1.0 + pfe.objective.abs()),
        "optima diverged: pfe {} vs ft {}",
        pfe.objective,
        ft.objective
    );
    assert!(
        pfe.iterations > 48,
        "instance too small to exercise the refactorization cadence ({} pivots)",
        pfe.iterations
    );
    assert!(
        pfe.refactorizations >= 2,
        "eta file should refactorize repeatedly, saw {}",
        pfe.refactorizations
    );
    assert!(
        ft.refactorizations < pfe.refactorizations,
        "forrest-tomlin ({}) should refactorize less than the eta file ({})",
        ft.refactorizations,
        pfe.refactorizations
    );
    // The update files really were exercised.
    assert!(pfe.peak_update_len > 0 && ft.peak_update_len > 0);
    assert!(
        ft.peak_update_len >= pfe.peak_update_len,
        "forrest-tomlin should carry update files at least as long as the eta cadence \
         (ft {} vs pfe {})",
        ft.peak_update_len,
        pfe.peak_update_len
    );
}

/// Weighted and partial pricing, under every factorization strategy,
/// must survive warm restarts and dual repairs inside a session sweep:
/// the same makespans as the defaults across a job grid, with both
/// strategies reported in every response.
#[test]
fn weighted_pricing_matches_dantzig_across_warm_sweep() {
    use dlt::api::{Family, SolveRequest, Solver};
    let spec = SystemSpec::builder()
        .source(0.2, 0.0)
        .source(0.4, 2.0)
        .processors(&[2.0, 3.0, 4.0, 5.0, 6.0])
        .job(100.0)
        .build()
        .unwrap();
    for factorization in ALL_FACTS {
        for pricing in [Pricing::Devex, Pricing::SteepestEdge, Pricing::Partial] {
            let mut base = Solver::new().build();
            let mut session = Solver::new()
                .simplex(SimplexOptions {
                    factorization,
                    pricing,
                    ..SimplexOptions::default()
                })
                .build();
            let mut refreshes = 0usize;
            let mut ftran_nnz = 0.0f64;
            for k in 0..8 {
                let sub = spec.with_job(100.0 + 15.0 * k as f64);
                let want =
                    base.solve(&SolveRequest::new(Family::Frontend, sub.clone())).unwrap();
                let got = session.solve(&SolveRequest::new(Family::Frontend, sub)).unwrap();
                assert_eq!(got.diagnostics.pricing, pricing);
                assert_eq!(got.diagnostics.factorization, factorization);
                assert!(
                    (got.makespan - want.makespan).abs() < 1e-7 * (1.0 + want.makespan.abs()),
                    "{}/{} J-step {k}: {} vs {}",
                    factorization.as_str(),
                    pricing.as_str(),
                    got.makespan,
                    want.makespan
                );
                refreshes += got.diagnostics.candidate_refreshes;
                ftran_nnz += got.diagnostics.avg_ftran_nnz;
            }
            // A zero-pivot warm hit legitimately reports 0.0, but the
            // cold first solve pivots, so the sweep total must be
            // positive.
            assert!(ftran_nnz > 0.0, "hypersparsity diagnostic missing across the sweep");
            if pricing == Pricing::Partial {
                assert!(
                    refreshes > 0,
                    "partial pricing must report its full-pass refreshes on the wire"
                );
            } else {
                assert_eq!(
                    refreshes,
                    0,
                    "{}: refresh counter is partial-only",
                    pricing.as_str()
                );
            }
        }
    }
}

/// The hypersparse kernels through the whole solver: partial pricing
/// plus both factorizations must hit the same objective as the dense
/// oracle on randomized specs *through the api facade*, with
/// bit-identical repeated batches (the scratch-pooled path).
#[test]
fn scratch_pooled_batches_are_deterministic() {
    use dlt::api::{Family, SolveRequest, Solver};
    let spec = SystemSpec::builder()
        .source(0.2, 0.0)
        .source(0.3, 1.5)
        .processors(&[2.0, 3.0, 4.0, 5.0])
        .job(100.0)
        .build()
        .unwrap();
    let reqs: Vec<SolveRequest> = (0..12)
        .map(|k| {
            let mut r = SolveRequest::new(
                if k % 2 == 0 { Family::Frontend } else { Family::NoFrontend },
                spec.with_job(100.0 + 12.0 * k as f64),
            );
            r.options.pricing = Some(Pricing::Partial);
            r.options.factorization = Some(ALL_FACTS[k % ALL_FACTS.len()]);
            r
        })
        .collect();
    // One worker: with work-stealing, request→worker assignment (and
    // therefore each worker's warm-start sequence) is
    // timing-dependent, which is *allowed* to move makespans by
    // solver tolerance. Bit-identity is the single-worker contract.
    let session = Solver::new().threads(1).build();
    let first = session.solve_batch(&reqs);
    let second = session.solve_batch(&reqs);
    for (k, (a, b)) in first.iter().zip(second.iter()).enumerate() {
        let (a, b) = (a.as_ref().expect("first batch ok"), b.as_ref().expect("second batch ok"));
        assert!(
            a.makespan.to_bits() == b.makespan.to_bits(),
            "request {k}: repeated batch makespan diverged: {} vs {}",
            a.makespan,
            b.makespan
        );
        assert_eq!(a.beta, b.beta, "request {k}: repeated batch beta diverged");
    }
}

/// Hypersparse FTRAN/BTRAN vs the dense adapters on randomized bases
/// driven through real pivot sequences — the 1e-10 agreement bar from
/// the issue, at the integration level (the unit tests in
/// `lp/factorization.rs` cover the same against a fresh-LU oracle).
#[test]
fn prop_sparse_kernels_match_dense_adapters() {
    use dlt::lp::factorization::BasisFactorization;
    use dlt::linalg::{SparseMatrix, SparseVector};
    props("sparse ftran/btran == dense adapters", 25, |g| {
        let m = g.usize_in(2, 13);
        // Random sparse nonsingular basis (diagonally dominant).
        let mut trips: Vec<(usize, usize, f64)> = Vec::new();
        for i in 0..m {
            trips.push((i, i, g.f64_in(3.0, 4.0)));
            for j in 0..m {
                if i != j && g.bool() && g.bool() {
                    trips.push((i, j, g.f64_in(-0.5, 0.5)));
                }
            }
        }
        let b = SparseMatrix::from_triplets(m, m, &trips);
        let mut strategies: Vec<Box<dyn BasisFactorization>> =
            ALL_FACTS.iter().map(|f| f.build(m)).collect();
        if strategies.iter_mut().any(|s| s.refactorize(&b).is_err()) {
            return Ok(()); // numerically singular draw: skip
        }
        for strat in strategies.iter_mut() {
            for _ in 0..4 {
                let mut v = vec![0.0; m];
                v[g.usize_in(0, m)] = g.f64_in(-1.0, 1.0);
                v[g.usize_in(0, m)] = g.f64_in(-1.0, 1.0);
                let mut dense = vec![0.0; m];
                let mut sparse = vec![0.0; m];
                let mut sv = SparseVector::default();

                strat.ftran(&v, &mut dense);
                sv.set_from_dense(&v);
                strat.ftran_sparse(&mut sv);
                sv.copy_into_dense(&mut sparse);
                for i in 0..m {
                    if (dense[i] - sparse[i]).abs() > 1e-10 {
                        return Err(format!(
                            "{} ftran[{i}]: dense {} vs sparse {}",
                            strat.name(),
                            dense[i],
                            sparse[i]
                        ));
                    }
                }

                strat.btran(&v, &mut dense);
                sv.set_from_dense(&v);
                strat.btran_sparse(&mut sv);
                sv.copy_into_dense(&mut sparse);
                for i in 0..m {
                    if (dense[i] - sparse[i]).abs() > 1e-10 {
                        return Err(format!(
                            "{} btran[{i}]: dense {} vs sparse {}",
                            strat.name(),
                            dense[i],
                            sparse[i]
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}
