//! Configuration substrate: a from-scratch JSON parser/serializer and
//! the (de)serialization of [`crate::model::SystemSpec`] and experiment
//! configs. (The offline crate set has no `serde`/`serde_json`.)

pub mod json;
pub mod spec;

pub use json::Json;
pub use spec::{load_spec, save_spec, spec_from_json, spec_to_json};
