//! Minimal JSON value type, recursive-descent parser and writer.
//!
//! Supports the full JSON grammar (RFC 8259) except that numbers are
//! parsed into `f64` (sufficient for configs and manifests). Object key
//! order is preserved, which keeps emitted files diff-stable.

use crate::error::{Error, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (stored as f64).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Array(Vec<Json>),
    /// Object with preserved key order.
    Object(Vec<(String, Json)>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::Config(format!("trailing garbage at byte {}", p.pos)));
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Object(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Required object field.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| Error::Config(format!("missing field `{key}`")))
    }

    /// As f64.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => Err(Error::Config(format!("expected number, got {self:?}"))),
        }
    }

    /// As usize (must be a non-negative integer).
    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            return Err(Error::Config(format!("expected non-negative integer, got {x}")));
        }
        Ok(x as usize)
    }

    /// As str.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(Error::Config(format!("expected string, got {self:?}"))),
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(Error::Config(format!("expected bool, got {self:?}"))),
        }
    }

    /// As array slice.
    pub fn as_array(&self) -> Result<&[Json]> {
        match self {
            Json::Array(a) => Ok(a),
            _ => Err(Error::Config(format!("expected array, got {self:?}"))),
        }
    }

    /// As a vector of f64.
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_array()?.iter().map(|v| v.as_f64()).collect()
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Array(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Object(kv) => {
                if kv.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Config(format!("json parse error at byte {}: {msg}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() != Some(b) {
            return Err(self.err(&format!("expected `{}`", b as char)));
        }
        Ok(())
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected `{}`", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Array(items)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut kv = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(kv));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            kv.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Object(kv)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let full = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(full).ok_or_else(|| self.err("bad codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        s.push(c);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("bad number `{text}`")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap(), &Json::Str("x".into()));
        let a = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\ é 😀""#).unwrap();
        assert_eq!(v, Json::Str("a\n\t\"\\ é 😀".into()));
        // Raw multibyte UTF-8 passes through.
        let v = Json::parse("\"héllo 世界\"").unwrap();
        assert_eq!(v, Json::Str("héllo 世界".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"dlt","n":3,"gs":[0.5,0.6,0.7],"nested":{"ok":true,"x":null}}"#;
        let v = Json::parse(src).unwrap();
        let compact = v.to_string_compact();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
        assert!(pretty.contains("\n"));
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 3, "xs": [1.5, 2.5], "s": "x", "b": true}"#).unwrap();
        assert_eq!(v.req("n").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.req("xs").unwrap().as_f64_vec().unwrap(), vec![1.5, 2.5]);
        assert_eq!(v.req("s").unwrap().as_str().unwrap(), "x");
        assert!(v.req("b").unwrap().as_bool().unwrap());
        assert!(v.req("missing").is_err());
        assert!(v.req("s").unwrap().as_f64().is_err());
        assert!(Json::Num(1.5).as_usize().is_err());
        assert!(Json::Num(-1.0).as_usize().is_err());
    }

    #[test]
    fn integers_emit_without_decimal() {
        assert_eq!(Json::Num(5.0).to_string_compact(), "5");
        assert_eq!(Json::Num(5.5).to_string_compact(), "5.5");
    }
}
