//! JSON (de)serialization of [`SystemSpec`].
//!
//! The on-disk format mirrors the paper's parameter tables:
//!
//! ```json
//! {
//!   "sources":    [{"g": 0.2, "release": 10.0}, {"g": 0.4, "release": 50.0}],
//!   "processors": [{"a": 2.0, "cost": 29.0}, {"a": 3.0, "cost": 28.0}],
//!   "job": 100.0
//! }
//! ```

use crate::config::json::Json;
use crate::error::{Error, Result};
use crate::model::{Processor, Source, SystemSpec};

/// Serialize a spec to JSON.
pub fn spec_to_json(spec: &SystemSpec) -> Json {
    let sources = spec
        .sources
        .iter()
        .map(|s| {
            Json::Object(vec![
                ("g".into(), Json::Num(s.g)),
                ("release".into(), Json::Num(s.release)),
                ("name".into(), Json::Str(s.name.clone())),
            ])
        })
        .collect();
    let processors = spec
        .processors
        .iter()
        .map(|p| {
            Json::Object(vec![
                ("a".into(), Json::Num(p.a)),
                ("cost".into(), Json::Num(p.cost_rate)),
                ("name".into(), Json::Str(p.name.clone())),
            ])
        })
        .collect();
    Json::Object(vec![
        ("sources".into(), Json::Array(sources)),
        ("processors".into(), Json::Array(processors)),
        ("job".into(), Json::Num(spec.job)),
    ])
}

/// Deserialize a spec from JSON (validates before returning).
pub fn spec_from_json(v: &Json) -> Result<SystemSpec> {
    let sources = v
        .req("sources")?
        .as_array()?
        .iter()
        .enumerate()
        .map(|(i, s)| {
            Ok(Source {
                g: s.req("g")?.as_f64()?,
                release: s.get("release").map(|r| r.as_f64()).transpose()?.unwrap_or(0.0),
                name: s
                    .get("name")
                    .map(|n| n.as_str().map(str::to_string))
                    .transpose()?
                    .unwrap_or_else(|| format!("S{}", i + 1)),
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let processors = v
        .req("processors")?
        .as_array()?
        .iter()
        .enumerate()
        .map(|(j, p)| {
            Ok(Processor {
                a: p.req("a")?.as_f64()?,
                cost_rate: p.get("cost").map(|c| c.as_f64()).transpose()?.unwrap_or(0.0),
                name: p
                    .get("name")
                    .map(|n| n.as_str().map(str::to_string))
                    .transpose()?
                    .unwrap_or_else(|| format!("P{}", j + 1)),
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let spec = SystemSpec { sources, processors, job: v.req("job")?.as_f64()? };
    spec.validate().map_err(|e| Error::Config(format!("{e}")))?;
    Ok(spec)
}

/// Load a spec from a JSON file.
pub fn load_spec(path: &str) -> Result<SystemSpec> {
    let text = std::fs::read_to_string(path).map_err(|e| Error::io(path, e))?;
    spec_from_json(&Json::parse(&text)?)
}

/// Save a spec to a JSON file (pretty-printed).
pub fn save_spec(path: &str, spec: &SystemSpec) -> Result<()> {
    std::fs::write(path, spec_to_json(spec).to_string_pretty()).map_err(|e| Error::io(path, e))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table1() -> SystemSpec {
        SystemSpec::builder()
            .source(0.2, 10.0)
            .source(0.4, 50.0)
            .processors(&[2.0, 3.0, 4.0, 5.0, 6.0])
            .job(100.0)
            .build()
            .unwrap()
    }

    #[test]
    fn roundtrip() {
        let spec = table1();
        let j = spec_to_json(&spec);
        let back = spec_from_json(&j).unwrap();
        assert_eq!(spec, back);
    }

    #[test]
    fn defaults_for_optional_fields() {
        let j = Json::parse(
            r#"{"sources": [{"g": 0.5}], "processors": [{"a": 2.0}], "job": 10}"#,
        )
        .unwrap();
        let spec = spec_from_json(&j).unwrap();
        assert_eq!(spec.sources[0].release, 0.0);
        assert_eq!(spec.sources[0].name, "S1");
        assert_eq!(spec.processors[0].cost_rate, 0.0);
    }

    #[test]
    fn invalid_spec_rejected() {
        let j = Json::parse(r#"{"sources": [], "processors": [{"a": 1}], "job": 10}"#).unwrap();
        assert!(spec_from_json(&j).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let spec = table1();
        let path = "/tmp/dlt_spec_test.json";
        save_spec(path, &spec).unwrap();
        let back = load_spec(path).unwrap();
        assert_eq!(spec, back);
        std::fs::remove_file(path).ok();
    }
}
