//! The PDHG convergence loop, over either backend.

use crate::error::{Error, Result};
use crate::lp::{LpProblem, SolverScratch};
use crate::pdhg::rust_impl;
use crate::pdhg::standardize::{PaddedLp, SparseLp};
use crate::runtime::{PdhgExecutable, Runtime};

/// Iterations per fixed-step block: residuals are checked (and columns
/// can retire) only on block boundaries. Matches the AOT artifact's
/// compiled block length.
pub const BLOCK_STEPS: usize = 200;

/// Driver options.
#[derive(Debug, Clone)]
pub struct PdhgOptions {
    /// Primal/dual residual tolerance (absolute, problems are O(1..1e2)).
    pub tol: f64,
    /// Duality-gap tolerance (relative to |objective| + 1).
    pub gap_tol: f64,
    /// Maximum number of fixed-step blocks.
    pub max_blocks: usize,
    /// Step-size safety factor (`tau = sigma = factor / ||A||`).
    pub step_factor: f64,
    /// Wall-clock budget checked on block boundaries; unbounded by
    /// default. Expiry stops the iteration where it stands — the
    /// caller decides whether a non-converged point is an error
    /// ([`crate::pipeline`] returns `DeadlineExceeded`) or a usable
    /// degraded answer (the serving tier's degraded mode).
    pub budget: crate::lp::SolveBudget,
}

impl Default for PdhgOptions {
    fn default() -> Self {
        PdhgOptions {
            tol: 1e-7,
            gap_tol: 1e-6,
            max_blocks: 400,
            step_factor: 0.9,
            budget: crate::lp::SolveBudget::default(),
        }
    }
}

/// Padded `(nv, nc)` shape for the AOT artifact path: the next powers
/// of two (min 64) with row headroom for the slacks the
/// standardization keeps implicit. The in-process backend runs at the
/// problem's natural shape; this rounding exists so a problem can move
/// to a fixed-shape artifact unchanged.
pub fn pad_shape(nv: usize, nc: usize) -> (usize, usize) {
    let round = |x: usize| x.next_power_of_two().max(64);
    (round(nv), round(nc + nc / 2))
}

/// PDHG solve outcome.
#[derive(Debug, Clone)]
pub struct PdhgSolution {
    /// Primal solution (natural shape).
    pub x: Vec<f64>,
    /// Objective value `c'x`.
    pub objective: f64,
    /// Blocks executed (each [`BLOCK_STEPS`] iterations).
    pub blocks: usize,
    /// Final residuals (primal, dual, gap).
    pub residuals: (f64, f64, f64),
    /// Whether the tolerances were met.
    pub converged: bool,
}

/// Pooled state for repeated in-process PDHG solves: the standardized
/// problem, its triplet buffer, the iterate vectors, and the kernel
/// scratch. Lives inside [`crate::lp::SolverScratch`] so batch and
/// session loops re-solve without touching the heap.
#[derive(Debug, Default)]
pub struct PdhgPool {
    lp: SparseLp,
    trips: Vec<(usize, usize, f64)>,
    scratch: rust_impl::PdhgScratch,
    x: Vec<f64>,
    y: Vec<f64>,
}

/// Core sparse solve loop over a pooled [`SparseLp`].
fn solve_sparse(
    p: &LpProblem,
    opts: &PdhgOptions,
    warm_x: Option<&[f64]>,
    pool: &mut PdhgPool,
) -> PdhgSolution {
    pool.lp.rebuild(p, &mut pool.trips);
    let (nv, nc) = (pool.lp.num_vars(), pool.lp.num_rows());
    let tau = opts.step_factor / pool.lp.a_norm.max(1e-12);
    pool.x.clear();
    match warm_x {
        Some(w) if w.len() == nv => pool.x.extend_from_slice(w),
        _ => pool.x.resize(nv, 0.0),
    }
    pool.y.clear();
    pool.y.resize(nc, 0.0);

    let mut blocks = 0;
    let mut res = rust_impl::residuals_with(&pool.lp, &pool.x, &pool.y, &mut pool.scratch);
    let converged_at = |r: &rust_impl::Residuals| {
        r.primal < opts.tol
            && r.dual < opts.tol
            && r.gap < opts.gap_tol * (r.objective.abs() + 1.0)
    };
    while blocks < opts.max_blocks && !converged_at(&res) {
        if opts.budget.expired() {
            break;
        }
        res = rust_impl::run_block_with(
            &pool.lp,
            &mut pool.x,
            &mut pool.y,
            tau,
            tau,
            BLOCK_STEPS,
            &mut pool.scratch,
        );
        blocks += 1;
    }
    PdhgSolution {
        x: pool.x.clone(),
        objective: res.objective,
        blocks,
        residuals: (res.primal, res.dual, res.gap),
        converged: converged_at(&res),
    }
}

/// Solve with the pure-rust sparse backend (no artifacts needed).
pub fn solve_rust(p: &LpProblem, opts: &PdhgOptions) -> Result<PdhgSolution> {
    let mut pool = PdhgPool::default();
    Ok(solve_sparse(p, opts, None, &mut pool))
}

/// Pooled variant of [`solve_rust`]: buffers live in the caller's
/// [`SolverScratch`], and `warm_x` (a primal point at the problem's
/// natural shape, e.g. from a warm cache or a projected basis) seeds
/// the iterates instead of the cold zero start.
pub fn solve_rust_scratch(
    p: &LpProblem,
    opts: &PdhgOptions,
    warm_x: Option<&[f64]>,
    scratch: &mut SolverScratch,
) -> Result<PdhgSolution> {
    Ok(solve_sparse(p, opts, warm_x, &mut scratch.pdhg))
}

/// Solve through the AOT artifact (PJRT execution).
pub fn solve_artifact(rt: &mut Runtime, p: &LpProblem, opts: &PdhgOptions) -> Result<PdhgSolution> {
    // Row count of the standardized form equals constraint count.
    let nv0 = p.num_vars();
    let nc0 = p.num_constraints();
    let (nv, nc, steps) = {
        let var = rt.manifest().pdhg_variant_for(nv0, nc0).ok_or_else(|| {
            Error::Artifact(format!("no PDHG artifact fits {nv0} vars x {nc0} rows"))
        })?;
        (var.nv, var.nc, var.steps)
    };
    let pad = PaddedLp::build(p, nv, nc);
    let tau = opts.step_factor / pad.a_norm.max(1e-12);
    let mut exec = PdhgExecutable::for_shape(rt, nv0, nc0)?;
    debug_assert_eq!(exec.steps, steps);

    let mut x = vec![0.0; pad.nv];
    let mut y = vec![0.0; pad.nc];
    let mut blocks = 0;
    let mut res = (f64::INFINITY, f64::INFINITY, f64::INFINITY);
    while blocks < opts.max_blocks {
        if opts.budget.expired() {
            break;
        }
        let out = exec.run_block(
            &pad.a, &pad.at, &pad.b, &pad.c, &pad.eq_mask, &x, &y, tau, tau,
        )?;
        x = out.x;
        y = out.y;
        res = (out.primal_res, out.dual_res, out.gap);
        blocks += 1;
        let scale = crate::linalg::dot(&pad.c, &x).abs() + 1.0;
        if res.0 < opts.tol && res.1 < opts.tol && res.2 < opts.gap_tol * scale {
            break;
        }
    }
    let x = pad.unpad_x(&x);
    let objective = p.objective_at(&x);
    let converged = res.0 < opts.tol
        && res.1 < opts.tol
        && res.2 < opts.gap_tol * (objective.abs() + 1.0);
    Ok(PdhgSolution { x, objective, blocks, residuals: res, converged })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::{solve, Cmp, LpProblem};

    #[test]
    fn rust_backend_agrees_with_simplex() {
        let mut p = LpProblem::new(3);
        p.set_objective(&[3.0, 2.0, 4.0]);
        p.add_constraint(&[(0, 1.0), (1, 1.0), (2, 1.0)], Cmp::Eq, 10.0);
        p.add_constraint(&[(0, 1.0)], Cmp::Le, 4.0);
        p.add_constraint(&[(2, 1.0)], Cmp::Ge, 1.0);
        let exact = solve(&p).unwrap();
        let sol = solve_rust(&p, &PdhgOptions::default()).unwrap();
        assert!(sol.converged, "residuals {:?}", sol.residuals);
        assert!(
            (sol.objective - exact.objective).abs() < 1e-3 * exact.objective.max(1.0),
            "pdhg {} vs simplex {}",
            sol.objective,
            exact.objective
        );
        assert!(p.check_feasible(&sol.x, 1e-5).is_none());
    }

    #[test]
    fn warm_start_matches_cold_and_does_not_slow_down() {
        let mut p = LpProblem::new(2);
        p.set_objective(&[1.0, 2.0]);
        p.add_constraint(&[(0, 1.0), (1, 1.0)], Cmp::Eq, 3.0);
        p.add_constraint(&[(0, 1.0)], Cmp::Le, 2.0);
        let cold = solve_rust(&p, &PdhgOptions::default()).unwrap();
        assert!(cold.converged);
        let mut scratch = SolverScratch::default();
        let warm =
            solve_rust_scratch(&p, &PdhgOptions::default(), Some(&cold.x), &mut scratch).unwrap();
        assert!(warm.converged);
        assert!((warm.objective - cold.objective).abs() < 1e-6, "objectives agree");
        // Seeding x at the optimum cannot make the saddle-point
        // distance larger than the cold zero start.
        assert!(
            warm.blocks <= cold.blocks,
            "warm {} blocks vs cold {}",
            warm.blocks,
            cold.blocks
        );
    }

    #[test]
    fn unconverged_is_reported() {
        let mut p = LpProblem::new(2);
        p.set_objective(&[1.0, 1.0]);
        p.add_constraint(&[(0, 1.0), (1, 1.0)], Cmp::Eq, 5.0);
        let sol =
            solve_rust(&p, &PdhgOptions { max_blocks: 0, ..Default::default() }).unwrap();
        // No blocks run: the zero start is infeasible (x+y=5 violated).
        assert!(!sol.converged);
        assert_eq!(sol.blocks, 0);
    }
}
