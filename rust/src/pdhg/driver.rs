//! The PDHG convergence loop, over either backend.

use crate::error::{Error, Result};
use crate::lp::LpProblem;
use crate::pdhg::rust_impl;
use crate::pdhg::standardize::PaddedLp;
use crate::runtime::{PdhgExecutable, Runtime};

/// Driver options.
#[derive(Debug, Clone)]
pub struct PdhgOptions {
    /// Primal/dual residual tolerance (absolute, problems are O(1..1e2)).
    pub tol: f64,
    /// Duality-gap tolerance (relative to |objective| + 1).
    pub gap_tol: f64,
    /// Maximum number of fixed-step blocks.
    pub max_blocks: usize,
    /// Step-size safety factor (`tau = sigma = factor / ||A||`).
    pub step_factor: f64,
}

impl Default for PdhgOptions {
    fn default() -> Self {
        PdhgOptions { tol: 1e-7, gap_tol: 1e-6, max_blocks: 400, step_factor: 0.9 }
    }
}

/// Padded `(nv, nc)` shape for the pure-rust PDHG backend: the next
/// powers of two (min 64) with row headroom for the slacks the
/// standardization keeps implicit. The same rounding the AOT artifact
/// variants are built around, so a problem solved in-process today can
/// move to an artifact of the same shape unchanged.
pub fn pad_shape(nv: usize, nc: usize) -> (usize, usize) {
    let round = |x: usize| x.next_power_of_two().max(64);
    (round(nv), round(nc + nc / 2))
}

/// PDHG solve outcome.
#[derive(Debug, Clone)]
pub struct PdhgSolution {
    /// Primal solution (unpadded).
    pub x: Vec<f64>,
    /// Objective value `c'x`.
    pub objective: f64,
    /// Blocks executed.
    pub blocks: usize,
    /// Final residuals (primal, dual, gap).
    pub residuals: (f64, f64, f64),
    /// Whether the tolerances were met.
    pub converged: bool,
}

fn finish(p: &LpProblem, pad: &PaddedLp, x: Vec<f64>, blocks: usize, res: (f64, f64, f64), opts: &PdhgOptions) -> PdhgSolution {
    let x = pad.unpad_x(&x);
    let objective = p.objective_at(&x);
    let converged = res.0 < opts.tol
        && res.1 < opts.tol
        && res.2 < opts.gap_tol * (objective.abs() + 1.0);
    PdhgSolution { x, objective, blocks, residuals: res, converged }
}

/// Solve with the pure-rust backend (no artifacts needed).
pub fn solve_rust(p: &LpProblem, nv: usize, nc: usize, opts: &PdhgOptions) -> Result<PdhgSolution> {
    let pad = PaddedLp::build(p, nv, nc);
    let tau = opts.step_factor / pad.a_norm.max(1e-12);
    let mut x = vec![0.0; pad.nv];
    let mut y = vec![0.0; pad.nc];
    // One scratch allocation for the whole solve; every block reuses it.
    let mut scratch = rust_impl::PdhgScratch::for_shape(pad.nv, pad.nc);
    let mut blocks = 0;
    let mut res = rust_impl::residuals_with(&pad, &x, &y, &mut scratch);
    while blocks < opts.max_blocks {
        res = rust_impl::run_block_with(&pad, &mut x, &mut y, tau, tau, 200, &mut scratch);
        blocks += 1;
        let scale = crate::linalg::dot(&pad.c, &x).abs() + 1.0;
        if res.primal < opts.tol && res.dual < opts.tol && res.gap < opts.gap_tol * scale {
            break;
        }
    }
    Ok(finish(p, &pad, x, blocks, (res.primal, res.dual, res.gap), opts))
}

/// Solve through the AOT artifact (PJRT execution).
pub fn solve_artifact(rt: &mut Runtime, p: &LpProblem, opts: &PdhgOptions) -> Result<PdhgSolution> {
    // Row count of the standardized form equals constraint count.
    let nv0 = p.num_vars();
    let nc0 = p.num_constraints();
    let (nv, nc, steps) = {
        let var = rt.manifest().pdhg_variant_for(nv0, nc0).ok_or_else(|| {
            Error::Artifact(format!("no PDHG artifact fits {nv0} vars x {nc0} rows"))
        })?;
        (var.nv, var.nc, var.steps)
    };
    let pad = PaddedLp::build(p, nv, nc);
    let tau = opts.step_factor / pad.a_norm.max(1e-12);
    let mut exec = PdhgExecutable::for_shape(rt, nv0, nc0)?;
    debug_assert_eq!(exec.steps, steps);

    let mut x = vec![0.0; pad.nv];
    let mut y = vec![0.0; pad.nc];
    let mut blocks = 0;
    let mut res = (f64::INFINITY, f64::INFINITY, f64::INFINITY);
    while blocks < opts.max_blocks {
        let out = exec.run_block(
            &pad.a, &pad.at, &pad.b, &pad.c, &pad.eq_mask, &x, &y, tau, tau,
        )?;
        x = out.x;
        y = out.y;
        res = (out.primal_res, out.dual_res, out.gap);
        blocks += 1;
        let scale = crate::linalg::dot(&pad.c, &x).abs() + 1.0;
        if res.0 < opts.tol && res.1 < opts.tol && res.2 < opts.gap_tol * scale {
            break;
        }
    }
    Ok(finish(p, &pad, x, blocks, res, opts))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::{solve, Cmp, LpProblem};

    #[test]
    fn rust_backend_agrees_with_simplex() {
        let mut p = LpProblem::new(3);
        p.set_objective(&[3.0, 2.0, 4.0]);
        p.add_constraint(&[(0, 1.0), (1, 1.0), (2, 1.0)], Cmp::Eq, 10.0);
        p.add_constraint(&[(0, 1.0)], Cmp::Le, 4.0);
        p.add_constraint(&[(2, 1.0)], Cmp::Ge, 1.0);
        let exact = solve(&p).unwrap();
        let sol = solve_rust(&p, 8, 8, &PdhgOptions::default()).unwrap();
        assert!(sol.converged, "residuals {:?}", sol.residuals);
        assert!(
            (sol.objective - exact.objective).abs() < 1e-3 * exact.objective.max(1.0),
            "pdhg {} vs simplex {}",
            sol.objective,
            exact.objective
        );
        assert!(p.check_feasible(&sol.x, 1e-5).is_none());
    }

    #[test]
    fn unconverged_is_reported() {
        let mut p = LpProblem::new(2);
        p.set_objective(&[1.0, 1.0]);
        p.add_constraint(&[(0, 1.0), (1, 1.0)], Cmp::Eq, 5.0);
        let sol = solve_rust(
            &p,
            4,
            4,
            &PdhgOptions { max_blocks: 0, ..Default::default() },
        )
        .unwrap();
        // No blocks run: the zero start is infeasible (x+y=5 violated).
        assert!(!sol.converged);
        assert_eq!(sol.blocks, 0);
    }
}
