//! Pure-rust sparse PDHG iteration (Chambolle–Pock on the row-wise
//! form) — the same math as the JAX artifact
//! (`python/compile/model.py::pdhg_run`), executed over CSC at the
//! problem's natural shape instead of the artifact's dense padded one,
//! so each step costs O(nnz) rather than O(nv·nc). Summation order
//! therefore differs from the artifact in the last bits; the
//! integration suite compares the two converged solutions, not raw
//! trajectories.
//!
//! Exists for three reasons: (1) baseline for the artifact benches,
//! (2) fallback when `make artifacts` has not run, (3) an oracle that
//! the artifact executes the intended math.

use crate::pdhg::standardize::SparseLp;

/// Residuals after a block.
#[derive(Debug, Clone, Copy)]
pub struct Residuals {
    /// Infinity-norm primal feasibility violation.
    pub primal: f64,
    /// Dual stationarity violation.
    pub dual: f64,
    /// |c'x + b'y|.
    pub gap: f64,
    /// Objective `c'x` at the iterate — computed inside the residual
    /// pass (the gap needs `c'x` anyway) so drivers never re-walk the
    /// problem with `objective_at` after a block.
    pub objective: f64,
}

/// Reusable buffers for [`run_block_with`] / [`residuals_with`]: one
/// allocation per solve instead of several per block.
#[derive(Debug, Default)]
pub struct PdhgScratch {
    aty: Vec<f64>,
    az: Vec<f64>,
    z: Vec<f64>,
}

impl PdhgScratch {
    /// Buffers sized for an `(nv, nc)` problem.
    pub fn for_shape(nv: usize, nc: usize) -> PdhgScratch {
        PdhgScratch { aty: vec![0.0; nv], az: vec![0.0; nc], z: vec![0.0; nv] }
    }

    fn ensure(&mut self, nv: usize, nc: usize) {
        if self.aty.len() != nv {
            self.aty.resize(nv, 0.0);
            self.z.resize(nv, 0.0);
        }
        if self.az.len() != nc {
            self.az.resize(nc, 0.0);
        }
    }
}

/// Run `steps` PDHG iterations in place on `(x, y)` (allocating
/// convenience wrapper over [`run_block_with`]).
pub fn run_block(
    lp: &SparseLp,
    x: &mut [f64],
    y: &mut [f64],
    tau: f64,
    sigma: f64,
    steps: usize,
) -> Residuals {
    let mut scratch = PdhgScratch::for_shape(lp.num_vars(), lp.num_rows());
    run_block_with(lp, x, y, tau, sigma, steps, &mut scratch)
}

/// Run `steps` PDHG iterations in place on `(x, y)`, reusing
/// caller-owned scratch buffers across blocks.
pub fn run_block_with(
    lp: &SparseLp,
    x: &mut [f64],
    y: &mut [f64],
    tau: f64,
    sigma: f64,
    steps: usize,
    scratch: &mut PdhgScratch,
) -> Residuals {
    let (nv, nc) = (lp.num_vars(), lp.num_rows());
    debug_assert_eq!(x.len(), nv);
    debug_assert_eq!(y.len(), nc);
    scratch.ensure(nv, nc);
    let aty = &mut scratch.aty;
    let az = &mut scratch.az;
    let z = &mut scratch.z;

    for _ in 0..steps {
        // aty = A' y
        lp.a.matvec_t_into(y, aty);
        // x' = max(0, x - tau (c + A'y));  z = 2x' - x
        for j in 0..nv {
            let xn = (x[j] - tau * (lp.c[j] + aty[j])).max(0.0);
            z[j] = 2.0 * xn - x[j];
            x[j] = xn;
        }
        // y' = proj(y + sigma (A z - b))
        lp.a.matvec_into(z, az);
        for i in 0..nc {
            let yn = y[i] + sigma * (az[i] - lp.b[i]);
            y[i] = if lp.eq[i] { yn } else { yn.max(0.0) };
        }
    }
    residuals_with(lp, x, y, scratch)
}

/// KKT residuals at `(x, y)` (allocating convenience wrapper).
pub fn residuals(lp: &SparseLp, x: &[f64], y: &[f64]) -> Residuals {
    let mut scratch = PdhgScratch::for_shape(lp.num_vars(), lp.num_rows());
    residuals_with(lp, x, y, &mut scratch)
}

/// KKT residuals at `(x, y)`, reusing caller-owned scratch buffers.
pub fn residuals_with(
    lp: &SparseLp,
    x: &[f64],
    y: &[f64],
    scratch: &mut PdhgScratch,
) -> Residuals {
    let (nv, nc) = (lp.num_vars(), lp.num_rows());
    scratch.ensure(nv, nc);
    let ax = &mut scratch.az;
    lp.a.matvec_into(x, ax);
    let mut primal = 0.0f64;
    for i in 0..nc {
        let v = ax[i] - lp.b[i];
        let viol = if lp.eq[i] { v.abs() } else { v.max(0.0) };
        primal = primal.max(viol);
    }
    let aty = &mut scratch.aty;
    lp.a.matvec_t_into(y, aty);
    let mut dual = 0.0f64;
    for j in 0..nv {
        dual = dual.max((-(lp.c[j] + aty[j])).max(0.0));
    }
    let objective = crate::linalg::dot(&lp.c, x);
    let gap = (objective + crate::linalg::dot(&lp.b, y)).abs();
    Residuals { primal, dual, gap, objective }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::{solve, Cmp, LpProblem};
    use crate::pdhg::standardize::SparseLp;

    fn run_to_convergence(lp: &SparseLp, max_blocks: usize) -> (Vec<f64>, Residuals) {
        let tau = 0.9 / lp.a_norm.max(1e-12);
        let mut x = vec![0.0; lp.num_vars()];
        let mut y = vec![0.0; lp.num_rows()];
        let mut res = residuals(lp, &x, &y);
        for _ in 0..max_blocks {
            res = run_block(lp, &mut x, &mut y, tau, tau, 200);
            if res.primal < 1e-8 && res.dual < 1e-8 && res.gap < 1e-7 {
                break;
            }
        }
        (x, res)
    }

    #[test]
    fn converges_to_simplex_optimum() {
        // min x + 2y st x + y = 3, x <= 2 -> x=2, y=1, obj=4
        let mut p = LpProblem::new(2);
        p.set_objective(&[1.0, 2.0]);
        p.add_constraint(&[(0, 1.0), (1, 1.0)], Cmp::Eq, 3.0);
        p.add_constraint(&[(0, 1.0)], Cmp::Le, 2.0);
        let exact = solve(&p).unwrap();

        let lp = SparseLp::build(&p);
        let (x, res) = run_to_convergence(&lp, 50);
        let obj = crate::linalg::dot(&lp.c, &x);
        assert!(res.primal < 1e-6, "primal {res:?}");
        assert!((obj - exact.objective).abs() < 1e-4, "{obj} vs {}", exact.objective);
        assert!((res.objective - obj).abs() < 1e-12, "residual pass reports c'x");
    }

    #[test]
    fn matches_dlt_frontend_lp() {
        // Full §3.1 LP (Table 1 shape) vs simplex.
        let spec = crate::model::SystemSpec::builder()
            .source(0.2, 1.0)
            .source(0.4, 2.0)
            .processors(&[2.0, 3.0, 4.0])
            .job(10.0)
            .build()
            .unwrap();
        let lp = crate::dlt::frontend::build_lp(&spec, &Default::default());
        let exact = solve(&lp).unwrap();
        let slp = SparseLp::build(&lp);
        let (x, res) = run_to_convergence(&slp, 400);
        assert!(res.primal < 1e-6, "{res:?}");
        let tf_idx = lp.num_vars() - 1;
        assert!(
            (x[tf_idx] - exact.objective).abs() < 5e-3 * exact.objective.max(1.0),
            "pdhg {} vs simplex {}",
            x[tf_idx],
            exact.objective
        );
    }
}
