//! Pure-rust PDHG block — bit-for-bit the same iteration as the JAX
//! artifact (see `python/compile/model.py::pdhg_run`).
//!
//! Exists for three reasons: (1) baseline for the artifact benches,
//! (2) fallback when `make artifacts` has not run, (3) an oracle that
//! the artifact executes the intended math (integration test compares
//! the two trajectories).

use crate::pdhg::standardize::PaddedLp;

/// Residuals after a block.
#[derive(Debug, Clone, Copy)]
pub struct Residuals {
    /// Infinity-norm primal feasibility violation.
    pub primal: f64,
    /// Dual stationarity violation.
    pub dual: f64,
    /// |c'x + b'y|.
    pub gap: f64,
}

/// Reusable buffers for [`run_block_with`] / [`residuals_with`]: one
/// allocation per solve instead of several per block.
#[derive(Debug, Default)]
pub struct PdhgScratch {
    aty: Vec<f64>,
    az: Vec<f64>,
    z: Vec<f64>,
}

impl PdhgScratch {
    /// Buffers sized for a padded `(nv, nc)` problem.
    pub fn for_shape(nv: usize, nc: usize) -> PdhgScratch {
        PdhgScratch { aty: vec![0.0; nv], az: vec![0.0; nc], z: vec![0.0; nv] }
    }

    fn ensure(&mut self, nv: usize, nc: usize) {
        if self.aty.len() != nv {
            self.aty.resize(nv, 0.0);
            self.z.resize(nv, 0.0);
        }
        if self.az.len() != nc {
            self.az.resize(nc, 0.0);
        }
    }
}

/// Run `steps` PDHG iterations in place on `(x, y)` (allocating
/// convenience wrapper over [`run_block_with`]).
pub fn run_block(
    lp: &PaddedLp,
    x: &mut [f64],
    y: &mut [f64],
    tau: f64,
    sigma: f64,
    steps: usize,
) -> Residuals {
    let mut scratch = PdhgScratch::for_shape(lp.nv, lp.nc);
    run_block_with(lp, x, y, tau, sigma, steps, &mut scratch)
}

/// Run `steps` PDHG iterations in place on `(x, y)`, reusing
/// caller-owned scratch buffers across blocks.
pub fn run_block_with(
    lp: &PaddedLp,
    x: &mut [f64],
    y: &mut [f64],
    tau: f64,
    sigma: f64,
    steps: usize,
    scratch: &mut PdhgScratch,
) -> Residuals {
    let (nv, nc) = (lp.nv, lp.nc);
    debug_assert_eq!(x.len(), nv);
    debug_assert_eq!(y.len(), nc);
    scratch.ensure(nv, nc);
    let aty = &mut scratch.aty;
    let az = &mut scratch.az;
    let z = &mut scratch.z;

    for _ in 0..steps {
        // aty = A' y
        matvec_t(&lp.a, nc, nv, y, aty);
        // x' = max(0, x - tau (c + A'y));  z = 2x' - x
        for j in 0..nv {
            let xn = (x[j] - tau * (lp.c[j] + aty[j])).max(0.0);
            z[j] = 2.0 * xn - x[j];
            x[j] = xn;
        }
        // y' = proj(y + sigma (A z - b))
        matvec(&lp.a, nc, nv, z, az);
        for i in 0..nc {
            let yn = y[i] + sigma * (az[i] - lp.b[i]);
            y[i] = if lp.eq_mask[i] > 0.5 { yn } else { yn.max(0.0) };
        }
    }
    residuals_with(lp, x, y, scratch)
}

/// KKT residuals at `(x, y)` (allocating convenience wrapper).
pub fn residuals(lp: &PaddedLp, x: &[f64], y: &[f64]) -> Residuals {
    let mut scratch = PdhgScratch::for_shape(lp.nv, lp.nc);
    residuals_with(lp, x, y, &mut scratch)
}

/// KKT residuals at `(x, y)`, reusing caller-owned scratch buffers.
pub fn residuals_with(
    lp: &PaddedLp,
    x: &[f64],
    y: &[f64],
    scratch: &mut PdhgScratch,
) -> Residuals {
    let (nv, nc) = (lp.nv, lp.nc);
    scratch.ensure(nv, nc);
    let ax = &mut scratch.az;
    matvec(&lp.a, nc, nv, x, ax);
    let mut primal = 0.0f64;
    for i in 0..nc {
        let v = ax[i] - lp.b[i];
        let viol = if lp.eq_mask[i] > 0.5 { v.abs() } else { v.max(0.0) };
        primal = primal.max(viol);
    }
    let aty = &mut scratch.aty;
    matvec_t(&lp.a, nc, nv, y, aty);
    let mut dual = 0.0f64;
    for j in 0..nv {
        dual = dual.max((-(lp.c[j] + aty[j])).max(0.0));
    }
    let gap = (crate::linalg::dot(&lp.c, x) + crate::linalg::dot(&lp.b, y)).abs();
    Residuals { primal, dual, gap }
}

#[inline]
fn matvec(a: &[f64], nc: usize, nv: usize, x: &[f64], out: &mut [f64]) {
    for i in 0..nc {
        out[i] = crate::linalg::dot(&a[i * nv..(i + 1) * nv], x);
    }
}

#[inline]
fn matvec_t(a: &[f64], nc: usize, nv: usize, y: &[f64], out: &mut [f64]) {
    out.iter_mut().for_each(|v| *v = 0.0);
    for i in 0..nc {
        let yi = y[i];
        if yi == 0.0 {
            continue;
        }
        let row = &a[i * nv..(i + 1) * nv];
        for j in 0..nv {
            out[j] += row[j] * yi;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::{solve, Cmp, LpProblem};
    use crate::pdhg::standardize::PaddedLp;

    fn run_to_convergence(lp: &PaddedLp, max_blocks: usize) -> (Vec<f64>, Residuals) {
        let tau = 0.9 / lp.a_norm.max(1e-12);
        let mut x = vec![0.0; lp.nv];
        let mut y = vec![0.0; lp.nc];
        let mut res = residuals(lp, &x, &y);
        for _ in 0..max_blocks {
            res = run_block(lp, &mut x, &mut y, tau, tau, 200);
            if res.primal < 1e-8 && res.dual < 1e-8 && res.gap < 1e-7 {
                break;
            }
        }
        (x, res)
    }

    #[test]
    fn converges_to_simplex_optimum() {
        // min x + 2y st x + y = 3, x <= 2 -> x=2, y=1, obj=4
        let mut p = LpProblem::new(2);
        p.set_objective(&[1.0, 2.0]);
        p.add_constraint(&[(0, 1.0), (1, 1.0)], Cmp::Eq, 3.0);
        p.add_constraint(&[(0, 1.0)], Cmp::Le, 2.0);
        let exact = solve(&p).unwrap();

        let pad = PaddedLp::build(&p, 8, 6);
        let (x, res) = run_to_convergence(&pad, 50);
        let obj = crate::linalg::dot(&pad.c[..2], &x[..2]);
        assert!(res.primal < 1e-6, "primal {res:?}");
        assert!((obj - exact.objective).abs() < 1e-4, "{obj} vs {}", exact.objective);
    }

    #[test]
    fn padding_stays_at_zero() {
        let mut p = LpProblem::new(2);
        p.set_objective(&[1.0, 1.0]);
        p.add_constraint(&[(0, 1.0), (1, 1.0)], Cmp::Eq, 1.0);
        let pad = PaddedLp::build(&p, 16, 8);
        let (x, _) = run_to_convergence(&pad, 30);
        for &xi in &x[2..] {
            assert!(xi.abs() < 1e-9, "padding leaked: {xi}");
        }
    }

    #[test]
    fn matches_dlt_frontend_lp() {
        // Full §3.1 LP (Table 1 shape) vs simplex.
        let spec = crate::model::SystemSpec::builder()
            .source(0.2, 1.0)
            .source(0.4, 2.0)
            .processors(&[2.0, 3.0, 4.0])
            .job(10.0)
            .build()
            .unwrap();
        let lp = crate::dlt::frontend::build_lp(&spec, &Default::default());
        let exact = solve(&lp).unwrap();
        let pad = PaddedLp::build(&lp, 16, 16);
        let (x, res) = run_to_convergence(&pad, 400);
        assert!(res.primal < 1e-6, "{res:?}");
        let tf_idx = lp.num_vars() - 1;
        assert!(
            (x[tf_idx] - exact.objective).abs() < 5e-3 * exact.objective.max(1.0),
            "pdhg {} vs simplex {}",
            x[tf_idx],
            exact.objective
        );
    }
}
