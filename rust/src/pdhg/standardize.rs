//! LP standardization for the PDHG kernels.
//!
//! Two materializations of the same row-wise form
//! (`min c'x  s.t.  Ax <= b / Ax == b, x >= 0`, `>=` rows negated):
//!
//! - [`SparseLp`] — the in-process backend: CSC constraint matrix at
//!   the problem's natural shape, matvecs O(nnz). No padding: the
//!   scheduling matrices are ~95 % zeros and padding to powers of two
//!   squared the wasted work.
//! - [`PaddedLp`] — the AOT artifact path only: dense row-major
//!   `a`/`at` padded to the artifact's fixed shape, because the XLA
//!   executable consumes dense literals of exactly that layout.

use crate::linalg::SparseMatrix;
use crate::lp::standard::StandardForm;
use crate::lp::{Cmp, LpProblem};

/// Row-wise sparse LP for the in-process PDHG backend.
///
/// Built at the problem's natural `(rows, vars)` shape — no padding —
/// with the constraint matrix in CSC so both PDHG matvecs are O(nnz).
/// [`SparseLp::rebuild`] reuses all storage for pooled warm re-solves.
#[derive(Debug, Clone, Default)]
pub struct SparseLp {
    /// Constraint matrix, `rows × vars`, CSC.
    pub a: SparseMatrix,
    /// RHS, length `rows` (negated on `>=` rows).
    pub b: Vec<f64>,
    /// Objective, length `vars`.
    pub c: Vec<f64>,
    /// `true` where the row is an equality.
    pub eq: Vec<bool>,
    /// Power-iteration estimate of `||A||_2` (step-size scale).
    pub a_norm: f64,
}

impl SparseLp {
    /// Standardize `p` into the row-wise sparse form.
    pub fn build(p: &LpProblem) -> SparseLp {
        let mut lp = SparseLp::default();
        let mut trips = Vec::new();
        lp.rebuild(p, &mut trips);
        lp
    }

    /// Rebuild in place from `p`, reusing all storage (the triplet
    /// buffer is caller-owned so batch loops can pool it too). This is
    /// the allocation-free steady state of repeated PDHG solves.
    pub fn rebuild(&mut self, p: &LpProblem, trips: &mut Vec<(usize, usize, f64)>) {
        let nv = p.num_vars();
        let nc = p.num_constraints();
        trips.clear();
        self.b.clear();
        self.eq.clear();
        for (i, con) in p.constraints().iter().enumerate() {
            let sign = match con.cmp {
                Cmp::Ge => -1.0,
                _ => 1.0,
            };
            for &(v, coef) in &con.coeffs {
                trips.push((i, v, sign * coef));
            }
            self.b.push(sign * con.rhs);
            self.eq.push(con.cmp == Cmp::Eq);
        }
        // `refill_from_triplets` sums duplicate (row, var) pairs,
        // matching the dense `a[(i, v)] += ...` accumulation the
        // row-wise form is defined by.
        self.a.refill_from_triplets(nc, nv, trips);
        self.c.clear();
        self.c.extend_from_slice(p.objective());
        self.a_norm = spectral_norm(&self.a);
    }

    /// Number of structural variables.
    pub fn num_vars(&self) -> usize {
        self.a.cols()
    }

    /// Number of constraint rows.
    pub fn num_rows(&self) -> usize {
        self.a.rows()
    }
}

/// A padded row-wise LP ready for the AOT PDHG artifact.
///
/// Only the [`crate::runtime::PdhgExecutable`] path uses this: the XLA
/// executable consumes dense row-major literals of a fixed
/// power-of-two shape, so the dense `a`/`at` buffers are the artifact
/// ABI, not a kernel choice. The in-process backend uses [`SparseLp`].
///
/// Padding contract (validated by `python/tests/test_pdhg.py::
/// test_pdhg_padding_is_inert`): padded rows are all-zero with
/// `b = 1` (slack inequality, dual pinned at 0); padded columns have
/// cost `+1` and no constraint coefficients (primal pinned at 0).
#[derive(Debug, Clone)]
pub struct PaddedLp {
    /// Row-major `nc × nv` constraint matrix.
    pub a: Vec<f64>,
    /// Row-major `nv × nc` transpose.
    pub at: Vec<f64>,
    /// RHS, length `nc`.
    pub b: Vec<f64>,
    /// Objective, length `nv`.
    pub c: Vec<f64>,
    /// Equality-row mask (1.0 = equality), length `nc`.
    pub eq_mask: Vec<f64>,
    /// Padded variable count.
    pub nv: usize,
    /// Padded row count.
    pub nc: usize,
    /// Original (unpadded) variable count.
    pub nv0: usize,
    /// Original row count.
    pub nc0: usize,
    /// Spectral-norm estimate of the padded matrix.
    pub a_norm: f64,
}

impl PaddedLp {
    /// Standardize `p` and pad to `(nv, nc)`. Panics if the problem is
    /// larger than the target shape (callers pick the variant first).
    pub fn build(p: &LpProblem, nv: usize, nc: usize) -> PaddedLp {
        let rw = StandardForm::rowwise(p);
        let nv0 = p.num_vars();
        let nc0 = rw.b.len();
        assert!(nv0 <= nv, "problem has {nv0} vars, artifact takes {nv}");
        assert!(nc0 <= nc, "problem has {nc0} rows, artifact takes {nc}");

        let mut a = vec![0.0; nc * nv];
        for i in 0..nc0 {
            let row = rw.a.row(i);
            a[i * nv..i * nv + nv0].copy_from_slice(row);
        }
        let mut at = vec![0.0; nv * nc];
        for i in 0..nc0 {
            for j in 0..nv0 {
                at[j * nc + i] = a[i * nv + j];
            }
        }
        let mut b = vec![1.0; nc];
        b[..nc0].copy_from_slice(&rw.b);
        let mut c = vec![1.0; nv];
        c[..nv0].copy_from_slice(&rw.c);
        let mut eq_mask = vec![0.0; nc];
        for (i, &is_eq) in rw.eq_mask.iter().enumerate() {
            eq_mask[i] = if is_eq { 1.0 } else { 0.0 };
        }

        // The padding is inert (zero rows/columns), so the spectral
        // norm of the padded matrix equals that of the core block —
        // estimate it sparsely instead of walking nc × nv zeros.
        let a_norm = spectral_norm(&SparseMatrix::from_dense(&rw.a, 0.0));
        PaddedLp { a, at, b, c, eq_mask, nv, nc, nv0, nc0, a_norm }
    }

    /// Strip padding from a primal iterate.
    pub fn unpad_x(&self, x: &[f64]) -> Vec<f64> {
        x[..self.nv0].to_vec()
    }
}

/// Power-iteration estimate of the largest singular value of a CSC
/// matrix: 60 rounds of `v ← AᵀAv` from a seeded random start, O(nnz)
/// per round. Returns 0.0 for empty or all-zero matrices.
pub fn spectral_norm(a: &SparseMatrix) -> f64 {
    use crate::util::rng::{Pcg32, Rng};
    if a.rows() == 0 || a.cols() == 0 || a.nnz() == 0 {
        return 0.0;
    }
    let mut rng = Pcg32::new(0x5eed);
    let mut v: Vec<f64> = (0..a.cols()).map(|_| rng.f64() - 0.5).collect();
    let norm = crate::linalg::norm2(&v).max(1e-30);
    v.iter_mut().for_each(|x| *x /= norm);
    let mut sigma = 0.0;
    let mut av = vec![0.0; a.rows()];
    let mut atav = vec![0.0; a.cols()];
    for _ in 0..60 {
        a.matvec_into(&v, &mut av);
        a.matvec_t_into(&av, &mut atav);
        let n = crate::linalg::norm2(&atav);
        if n == 0.0 {
            return 0.0;
        }
        sigma = n.sqrt();
        for (vi, &ai) in v.iter_mut().zip(atav.iter()) {
            *vi = ai / n;
        }
    }
    sigma
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::{Cmp, LpProblem};

    fn tiny_lp() -> LpProblem {
        let mut p = LpProblem::new(2);
        p.set_objective(&[1.0, 2.0]);
        p.add_constraint(&[(0, 1.0), (1, 1.0)], Cmp::Eq, 3.0);
        p.add_constraint(&[(0, 1.0)], Cmp::Le, 2.0);
        p.add_constraint(&[(1, 1.0)], Cmp::Ge, 0.5);
        p
    }

    #[test]
    fn sparse_lp_layout() {
        let p = tiny_lp();
        let lp = SparseLp::build(&p);
        assert_eq!((lp.num_rows(), lp.num_vars()), (3, 2));
        assert_eq!(lp.a.nnz(), 4);
        // Ge row negated.
        assert_eq!(lp.a[(2, 1)], -1.0);
        assert_eq!(lp.b, vec![3.0, 2.0, -0.5]);
        assert_eq!(lp.eq, vec![true, false, false]);
        assert_eq!(lp.c, vec![1.0, 2.0]);
        assert!(lp.a_norm > 0.0);
    }

    #[test]
    fn sparse_lp_rebuild_matches_build() {
        let p = tiny_lp();
        let fresh = SparseLp::build(&p);
        let mut pooled = SparseLp::build(&LpProblem::new(1));
        let mut trips = Vec::new();
        pooled.rebuild(&p, &mut trips);
        assert_eq!(pooled.a, fresh.a);
        assert_eq!(pooled.b, fresh.b);
        assert_eq!(pooled.c, fresh.c);
        assert_eq!(pooled.eq, fresh.eq);
        assert_eq!(pooled.a_norm, fresh.a_norm);
    }

    #[test]
    fn sparse_lp_sums_duplicate_coefficients() {
        let mut p = LpProblem::new(1);
        p.add_constraint(&[(0, 1.0), (0, 2.0)], Cmp::Le, 4.0);
        let lp = SparseLp::build(&p);
        assert_eq!(lp.a[(0, 0)], 3.0);
    }

    #[test]
    fn padding_layout() {
        let p = tiny_lp();
        let pad = PaddedLp::build(&p, 8, 6);
        assert_eq!(pad.nv0, 2);
        assert_eq!(pad.nc0, 3);
        // Ge row negated by rowwise form.
        assert_eq!(pad.a[2 * 8 + 1], -1.0);
        assert_eq!(pad.b[2], -0.5);
        // Padded rows: zero with b=1.
        assert!(pad.a[3 * 8..4 * 8].iter().all(|&x| x == 0.0));
        assert_eq!(pad.b[3], 1.0);
        // Padded cols: cost 1.
        assert_eq!(pad.c[5], 1.0);
        // Eq mask only on row 0.
        assert_eq!(pad.eq_mask[0], 1.0);
        assert_eq!(pad.eq_mask[1], 0.0);
        // Transpose consistency.
        for i in 0..pad.nc {
            for j in 0..pad.nv {
                assert_eq!(pad.a[i * pad.nv + j], pad.at[j * pad.nc + i]);
            }
        }
        // Padded and natural-shape norms agree: padding is inert.
        let lp = SparseLp::build(&p);
        assert!((pad.a_norm - lp.a_norm).abs() < 1e-9);
    }

    #[test]
    fn spectral_norm_identityish() {
        // diag(3, 1): largest singular value is 3.
        let a = SparseMatrix::from_triplets(4, 4, &[(0, 0, 3.0), (1, 1, 1.0)]);
        let s = spectral_norm(&a);
        assert!((s - 3.0).abs() < 1e-6, "{s}");
        assert_eq!(spectral_norm(&SparseMatrix::zeros(4, 4)), 0.0);
    }

    #[test]
    #[should_panic(expected = "vars")]
    fn oversize_panics() {
        let p = LpProblem::new(10);
        PaddedLp::build(&p, 4, 4);
    }

    #[test]
    fn unpad() {
        let p = tiny_lp();
        let pad = PaddedLp::build(&p, 8, 6);
        let x = vec![1.0, 2.0, 9.0, 9.0, 9.0, 9.0, 9.0, 9.0];
        assert_eq!(pad.unpad_x(&x), vec![1.0, 2.0]);
    }
}
