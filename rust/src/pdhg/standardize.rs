//! LP standardization + inert padding for the fixed-shape artifact.

use crate::lp::standard::StandardForm;
use crate::lp::LpProblem;

/// A padded row-wise LP ready for the PDHG block.
///
/// Padding contract (validated by `python/tests/test_pdhg.py::
/// test_pdhg_padding_is_inert`): padded rows are all-zero with
/// `b = 1` (slack inequality, dual pinned at 0); padded columns have
/// cost `+1` and no constraint coefficients (primal pinned at 0).
#[derive(Debug, Clone)]
pub struct PaddedLp {
    /// Row-major `nc × nv` constraint matrix.
    pub a: Vec<f64>,
    /// Row-major `nv × nc` transpose.
    pub at: Vec<f64>,
    /// RHS, length `nc`.
    pub b: Vec<f64>,
    /// Objective, length `nv`.
    pub c: Vec<f64>,
    /// Equality-row mask (1.0 = equality), length `nc`.
    pub eq_mask: Vec<f64>,
    /// Padded variable count.
    pub nv: usize,
    /// Padded row count.
    pub nc: usize,
    /// Original (unpadded) variable count.
    pub nv0: usize,
    /// Original row count.
    pub nc0: usize,
    /// Spectral-norm estimate of the padded matrix.
    pub a_norm: f64,
}

impl PaddedLp {
    /// Standardize `p` and pad to `(nv, nc)`. Panics if the problem is
    /// larger than the target shape (callers pick the variant first).
    pub fn build(p: &LpProblem, nv: usize, nc: usize) -> PaddedLp {
        let rw = StandardForm::rowwise(p);
        let nv0 = p.num_vars();
        let nc0 = rw.b.len();
        assert!(nv0 <= nv, "problem has {nv0} vars, artifact takes {nv}");
        assert!(nc0 <= nc, "problem has {nc0} rows, artifact takes {nc}");

        let mut a = vec![0.0; nc * nv];
        for i in 0..nc0 {
            let row = rw.a.row(i);
            a[i * nv..i * nv + nv0].copy_from_slice(row);
        }
        let mut at = vec![0.0; nv * nc];
        for i in 0..nc0 {
            for j in 0..nv0 {
                at[j * nc + i] = a[i * nv + j];
            }
        }
        let mut b = vec![1.0; nc];
        b[..nc0].copy_from_slice(&rw.b);
        let mut c = vec![1.0; nv];
        c[..nv0].copy_from_slice(&rw.c);
        let mut eq_mask = vec![0.0; nc];
        for (i, &is_eq) in rw.eq_mask.iter().enumerate() {
            eq_mask[i] = if is_eq { 1.0 } else { 0.0 };
        }

        let a_norm = spectral_norm(&a, nc, nv);
        PaddedLp { a, at, b, c, eq_mask, nv, nc, nv0, nc0, a_norm }
    }

    /// Strip padding from a primal iterate.
    pub fn unpad_x(&self, x: &[f64]) -> Vec<f64> {
        x[..self.nv0].to_vec()
    }
}

/// Power-iteration estimate of the largest singular value of the
/// row-major `nc × nv` matrix `a`.
pub fn spectral_norm(a: &[f64], nc: usize, nv: usize) -> f64 {
    use crate::util::rng::{Pcg32, Rng};
    let mut rng = Pcg32::new(0x5eed);
    let mut v: Vec<f64> = (0..nv).map(|_| rng.f64() - 0.5).collect();
    let norm = crate::linalg::norm2(&v).max(1e-30);
    v.iter_mut().for_each(|x| *x /= norm);
    let mut sigma = 0.0;
    let mut av = vec![0.0; nc];
    let mut atav = vec![0.0; nv];
    for _ in 0..60 {
        for i in 0..nc {
            av[i] = crate::linalg::dot(&a[i * nv..(i + 1) * nv], &v);
        }
        atav.iter_mut().for_each(|x| *x = 0.0);
        for i in 0..nc {
            let yi = av[i];
            if yi != 0.0 {
                for j in 0..nv {
                    atav[j] += a[i * nv + j] * yi;
                }
            }
        }
        let n = crate::linalg::norm2(&atav);
        if n == 0.0 {
            return 0.0;
        }
        sigma = n.sqrt();
        for (vi, &ai) in v.iter_mut().zip(atav.iter()) {
            *vi = ai / n;
        }
    }
    sigma
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::{Cmp, LpProblem};

    fn tiny_lp() -> LpProblem {
        let mut p = LpProblem::new(2);
        p.set_objective(&[1.0, 2.0]);
        p.add_constraint(&[(0, 1.0), (1, 1.0)], Cmp::Eq, 3.0);
        p.add_constraint(&[(0, 1.0)], Cmp::Le, 2.0);
        p.add_constraint(&[(1, 1.0)], Cmp::Ge, 0.5);
        p
    }

    #[test]
    fn padding_layout() {
        let p = tiny_lp();
        let pad = PaddedLp::build(&p, 8, 6);
        assert_eq!(pad.nv0, 2);
        assert_eq!(pad.nc0, 3);
        // Ge row negated by rowwise form.
        assert_eq!(pad.a[2 * 8 + 1], -1.0);
        assert_eq!(pad.b[2], -0.5);
        // Padded rows: zero with b=1.
        assert!(pad.a[3 * 8..4 * 8].iter().all(|&x| x == 0.0));
        assert_eq!(pad.b[3], 1.0);
        // Padded cols: cost 1.
        assert_eq!(pad.c[5], 1.0);
        // Eq mask only on row 0.
        assert_eq!(pad.eq_mask[0], 1.0);
        assert_eq!(pad.eq_mask[1], 0.0);
        // Transpose consistency.
        for i in 0..pad.nc {
            for j in 0..pad.nv {
                assert_eq!(pad.a[i * pad.nv + j], pad.at[j * pad.nc + i]);
            }
        }
    }

    #[test]
    fn spectral_norm_identityish() {
        // 2x2 diag(3, 1) embedded in 4x4 padding.
        let mut a = vec![0.0; 16];
        a[0] = 3.0;
        a[5] = 1.0;
        let s = spectral_norm(&a, 4, 4);
        assert!((s - 3.0).abs() < 1e-6, "{s}");
    }

    #[test]
    #[should_panic(expected = "vars")]
    fn oversize_panics() {
        let p = LpProblem::new(10);
        PaddedLp::build(&p, 4, 4);
    }

    #[test]
    fn unpad() {
        let p = tiny_lp();
        let pad = PaddedLp::build(&p, 8, 6);
        let x = vec![1.0, 2.0, 9.0, 9.0, 9.0, 9.0, 9.0, 9.0];
        assert_eq!(pad.unpad_x(&x), vec![1.0, 2.0]);
    }
}
