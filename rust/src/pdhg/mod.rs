//! First-order LP solving path (PDHG / Chambolle–Pock).
//!
//! The simplex ([`crate::lp`]) is the exact reference solver; PDHG is
//! the accelerator for large `N × M` sweeps. The in-process backend
//! ([`rust_impl`], [`block`]) runs **sparse**: the row-wise form is
//! kept in CSC at the problem's natural shape ([`SparseLp`]) and both
//! matvecs cost O(nnz) per iteration. Whole sweep axes batch into one
//! block iteration stream ([`block::solve_block`]) with per-column
//! early retirement. The AOT artifact path (compiled from
//! JAX + Pallas, executed through PJRT via [`crate::runtime`]) still
//! consumes dense row-major literals padded to a fixed power-of-two
//! shape ([`PaddedLp`], [`pad_shape`]) — that padding is *inert*:
//! zero rows with `b = 1`, unit-cost columns.
//!
//! Step sizes come from a sparse power-iteration `||A||` estimate
//! ([`standardize::spectral_norm`]); the convergence loop checks KKT
//! residuals every [`BLOCK_STEPS`] iterations.

pub mod block;
pub mod driver;
pub mod rust_impl;
pub mod standardize;

pub use block::{solve_block, BlockSolution, DEFAULT_BLOCK_WIDTH};
pub use driver::{
    pad_shape, solve_artifact, solve_rust, solve_rust_scratch, PdhgOptions, PdhgPool,
    PdhgSolution, BLOCK_STEPS,
};
pub use rust_impl::PdhgScratch;
pub use standardize::{PaddedLp, SparseLp};
