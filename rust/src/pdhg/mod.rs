//! First-order LP solving path (PDHG / Chambolle–Pock).
//!
//! The simplex ([`crate::lp`]) is the exact reference solver; PDHG is
//! the accelerator for large `N × M` sweeps, compiled AOT from
//! JAX + Pallas and executed through PJRT ([`crate::runtime`]).
//!
//! This module owns everything around the compiled block:
//! standardization of an [`crate::lp::LpProblem`] to the row-wise
//! `Ax ≤ b / Ax = b, x ≥ 0` form, padding to the artifact's fixed
//! shape (with *inert* padding: zero rows with `b = 1`, unit-cost
//! columns), step-size selection via power iteration, and the
//! convergence loop. A pure-rust implementation of the identical
//! iteration ([`rust_impl`]) serves as a baseline and as the fallback
//! when artifacts have not been built.

pub mod driver;
pub mod rust_impl;
pub mod standardize;

pub use driver::{pad_shape, solve_artifact, solve_rust, PdhgOptions, PdhgSolution};
pub use rust_impl::PdhgScratch;
pub use standardize::PaddedLp;
