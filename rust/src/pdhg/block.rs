//! Batched multi-RHS block PDHG.
//!
//! A sweep axis (jobs, release scale, budget grid, …) produces K
//! near-identical LPs that differ only in rhs and/or cost: the
//! constraint matrix `A` is shared. Solving them one by one repeats
//! the matrix pass — and the `||A||` power iteration — K times.
//! This module stacks the K scenarios into column-major `x`/`y`
//! panels (`panel[j * K + k]` is column `k` of unknown `j`, so one
//! CSC entry updates K contiguous lanes) and runs **one** shared
//! matrix pass per PDHG step for the whole block, with per-column
//! residual tracking on block boundaries and early retirement of
//! converged columns.
//!
//! Columns whose constraint structure does not match the first
//! problem's fall out of the batch and are solved individually — the
//! result is always correct, batching is purely a fast path.

use crate::error::Result;
use crate::lp::{Cmp, LpProblem};
use crate::pdhg::driver::{solve_rust, PdhgOptions, PdhgSolution, BLOCK_STEPS};
use crate::pdhg::standardize::SparseLp;

/// Default number of scenario columns stacked per block: wide enough
/// to amortize the matrix pass, narrow enough that a panel row
/// (`K` lanes) stays within a couple of cache lines.
pub const DEFAULT_BLOCK_WIDTH: usize = 16;

/// Outcome of a batched block solve.
#[derive(Debug, Clone)]
pub struct BlockSolution {
    /// Per-input-problem solutions, in input order.
    pub columns: Vec<PdhgSolution>,
    /// Number of columns stacked (the input width).
    pub block_width: usize,
    /// Columns that converged and retired from the iteration while
    /// other columns were still running.
    pub columns_retired: usize,
}

/// Do two problems share a constraint matrix (same variables, same
/// rows, same coefficients and senses)? rhs and objective may differ —
/// that is exactly what the block batches over.
fn shares_structure(a: &LpProblem, b: &LpProblem) -> bool {
    a.num_vars() == b.num_vars()
        && a.num_constraints() == b.num_constraints()
        && a.constraints()
            .iter()
            .zip(b.constraints())
            .all(|(ca, cb)| ca.coeffs == cb.coeffs && ca.cmp == cb.cmp)
}

/// One shared pass of `out = Aᵀ · y` over the active panel lanes.
fn panel_matvec_t(
    lp: &SparseLp,
    y: &[f64],
    out: &mut [f64],
    kk: usize,
    active: &[usize],
) {
    for j in 0..lp.num_vars() {
        let base = j * kk;
        for &k in active {
            out[base + k] = 0.0;
        }
        for (i, v) in lp.a.col(j) {
            let yrow = i * kk;
            for &k in active {
                out[base + k] += v * y[yrow + k];
            }
        }
    }
}

/// One shared pass of `out = A · x` over the active panel lanes.
fn panel_matvec(lp: &SparseLp, x: &[f64], out: &mut [f64], kk: usize, active: &[usize]) {
    for i in 0..lp.num_rows() {
        let base = i * kk;
        for &k in active {
            out[base + k] = 0.0;
        }
    }
    for j in 0..lp.num_vars() {
        let base = j * kk;
        for (i, v) in lp.a.col(j) {
            let orow = i * kk;
            for &k in active {
                out[orow + k] += v * x[base + k];
            }
        }
    }
}

/// Per-column KKT residuals at the current panel iterate.
#[derive(Debug, Clone, Copy, Default)]
struct ColRes {
    primal: f64,
    dual: f64,
    gap: f64,
    objective: f64,
}

#[allow(clippy::too_many_arguments)]
fn panel_residuals(
    lp: &SparseLp,
    b: &[f64],
    c: &[f64],
    x: &[f64],
    y: &[f64],
    ax: &mut [f64],
    aty: &mut [f64],
    kk: usize,
    active: &[usize],
    out: &mut [ColRes],
) {
    panel_matvec(lp, x, ax, kk, active);
    panel_matvec_t(lp, y, aty, kk, active);
    for &k in active {
        out[k] = ColRes::default();
    }
    for (i, &is_eq) in lp.eq.iter().enumerate() {
        let base = i * kk;
        for &k in active {
            let v = ax[base + k] - b[base + k];
            let viol = if is_eq { v.abs() } else { v.max(0.0) };
            out[k].primal = out[k].primal.max(viol);
        }
    }
    for j in 0..lp.num_vars() {
        let base = j * kk;
        for &k in active {
            let d = (-(c[base + k] + aty[base + k])).max(0.0);
            out[k].dual = out[k].dual.max(d);
            out[k].objective += c[base + k] * x[base + k];
        }
    }
    for &k in active {
        let mut by = 0.0;
        for i in 0..lp.num_rows() {
            by += b[i * kk + k] * y[i * kk + k];
        }
        out[k].gap = (out[k].objective + by).abs();
    }
}

/// Solve the columns in `idx` (all sharing `problems[idx[0]]`'s
/// constraint structure) as one panel. Returns the per-column
/// solutions in `idx` order plus the early-retirement count.
fn solve_shared(
    problems: &[LpProblem],
    idx: &[usize],
    opts: &PdhgOptions,
) -> (Vec<PdhgSolution>, usize) {
    let kk = idx.len();
    let lp = SparseLp::build(&problems[idx[0]]);
    let (nv, nc) = (lp.num_vars(), lp.num_rows());
    // One power iteration for the whole block — the scalar path pays
    // this per problem.
    let tau = opts.step_factor / lp.a_norm.max(1e-12);

    // rhs/cost panels, one lane per column.
    let mut b = vec![0.0; nc * kk];
    let mut c = vec![0.0; nv * kk];
    for (lane, &k) in idx.iter().enumerate() {
        let p = &problems[k];
        for (i, con) in p.constraints().iter().enumerate() {
            let sign = if con.cmp == Cmp::Ge { -1.0 } else { 1.0 };
            b[i * kk + lane] = sign * con.rhs;
        }
        for (j, &cj) in p.objective().iter().enumerate() {
            c[j * kk + lane] = cj;
        }
    }

    let mut x = vec![0.0; nv * kk];
    let mut y = vec![0.0; nc * kk];
    let mut z = vec![0.0; nv * kk];
    let mut aty = vec![0.0; nv * kk];
    let mut az = vec![0.0; nc * kk];
    let mut res = vec![ColRes::default(); kk];
    let mut state: Vec<Option<(usize, ColRes, bool)>> = vec![None; kk];
    let mut active: Vec<usize> = (0..kk).collect();
    let mut retired = 0usize;

    let converged_at = |r: &ColRes| {
        r.primal < opts.tol
            && r.dual < opts.tol
            && r.gap < opts.gap_tol * (r.objective.abs() + 1.0)
    };

    let mut blocks = 0usize;
    panel_residuals(&lp, &b, &c, &x, &y, &mut az, &mut aty, kk, &active, &mut res);
    loop {
        let before = active.len();
        active.retain(|&k| {
            if converged_at(&res[k]) {
                state[k] = Some((blocks, res[k], true));
                false
            } else {
                true
            }
        });
        let removed = before - active.len();
        if !active.is_empty() {
            retired += removed;
        }
        if active.is_empty() || blocks >= opts.max_blocks || opts.budget.expired() {
            break;
        }

        for _ in 0..BLOCK_STEPS {
            panel_matvec_t(&lp, &y, &mut aty, kk, &active);
            for j in 0..nv {
                let base = j * kk;
                for &k in &active {
                    let xo = x[base + k];
                    let xn = (xo - tau * (c[base + k] + aty[base + k])).max(0.0);
                    z[base + k] = 2.0 * xn - xo;
                    x[base + k] = xn;
                }
            }
            panel_matvec(&lp, &z, &mut az, kk, &active);
            for (i, &is_eq) in lp.eq.iter().enumerate() {
                let base = i * kk;
                for &k in &active {
                    let yn = y[base + k] + tau * (az[base + k] - b[base + k]);
                    y[base + k] = if is_eq { yn } else { yn.max(0.0) };
                }
            }
        }
        blocks += 1;
        panel_residuals(&lp, &b, &c, &x, &y, &mut az, &mut aty, kk, &active, &mut res);
    }
    // Columns still active hit the block budget without converging.
    for &k in &active {
        state[k] = Some((blocks, res[k], false));
    }

    let columns = (0..kk)
        .map(|k| {
            let (blk, r, converged) = state[k].expect("every column recorded");
            let xk: Vec<f64> = (0..nv).map(|j| x[j * kk + k]).collect();
            PdhgSolution {
                x: xk,
                objective: r.objective,
                blocks: blk,
                residuals: (r.primal, r.dual, r.gap),
                converged,
            }
        })
        .collect();
    (columns, retired)
}

/// Solve a batch of LPs as one block iteration stream.
///
/// Columns sharing the first problem's constraint structure are
/// stacked into one panel (one matrix pass and one `||A||` estimate
/// per block, early retirement per column); the rest fall back to
/// individual [`solve_rust`] calls. Results come back in input order
/// and match the sequential path column for column.
pub fn solve_block(problems: &[LpProblem], opts: &PdhgOptions) -> Result<BlockSolution> {
    let width = problems.len();
    if width == 0 {
        return Ok(BlockSolution { columns: Vec::new(), block_width: 0, columns_retired: 0 });
    }
    let shared: Vec<usize> =
        (0..width).filter(|&k| shares_structure(&problems[0], &problems[k])).collect();
    let mut columns: Vec<Option<PdhgSolution>> = (0..width).map(|_| None).collect();
    let (batched, retired) = solve_shared(problems, &shared, opts);
    for (&slot, sol) in shared.iter().zip(batched) {
        columns[slot] = Some(sol);
    }
    for (k, col) in columns.iter_mut().enumerate() {
        if col.is_none() {
            *col = Some(solve_rust(&problems[k], opts)?);
        }
    }
    Ok(BlockSolution {
        columns: columns.into_iter().map(|c| c.expect("all columns solved")).collect(),
        block_width: width,
        columns_retired: retired,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::{solve, Cmp, LpProblem};
    use crate::pdhg::driver::solve_rust;

    fn family(rhs: f64, c1: f64) -> LpProblem {
        // min x + c1·y  st  x + y = rhs, x <= 2
        let mut p = LpProblem::new(2);
        p.set_objective(&[1.0, c1]);
        p.add_constraint(&[(0, 1.0), (1, 1.0)], Cmp::Eq, rhs);
        p.add_constraint(&[(0, 1.0)], Cmp::Le, 2.0);
        p
    }

    #[test]
    fn block_matches_sequential_per_column() {
        let probs: Vec<LpProblem> =
            [(3.0, 2.0), (4.0, 2.0), (3.5, 3.0), (5.0, 1.5)].map(|(r, c)| family(r, c)).into();
        let opts = PdhgOptions::default();
        let blk = solve_block(&probs, &opts).unwrap();
        assert_eq!(blk.block_width, 4);
        for (p, col) in probs.iter().zip(&blk.columns) {
            let seq = solve_rust(p, &opts).unwrap();
            assert_eq!(col.converged, seq.converged);
            assert_eq!(col.blocks, seq.blocks, "same per-column block count");
            assert!(
                (col.objective - seq.objective).abs() < 1e-8,
                "block {} vs sequential {}",
                col.objective,
                seq.objective
            );
            for (a, b) in col.x.iter().zip(&seq.x) {
                assert!((a - b).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn block_reaches_the_simplex_optimum() {
        let probs: Vec<LpProblem> = [(3.0, 2.0), (6.0, 2.0)].map(|(r, c)| family(r, c)).into();
        let blk = solve_block(&probs, &PdhgOptions::default()).unwrap();
        for (p, col) in probs.iter().zip(&blk.columns) {
            let exact = solve(p).unwrap();
            assert!(col.converged, "{:?}", col.residuals);
            assert!((col.objective - exact.objective).abs() < 1e-4);
        }
    }

    #[test]
    fn mismatched_structure_falls_back_per_column() {
        let mut odd = LpProblem::new(2);
        odd.set_objective(&[1.0, 1.0]);
        odd.add_constraint(&[(0, 2.0), (1, 1.0)], Cmp::Eq, 3.0); // different coeffs
        let probs = vec![family(3.0, 2.0), odd.clone(), family(4.0, 2.0)];
        let blk = solve_block(&probs, &PdhgOptions::default()).unwrap();
        let seq = solve_rust(&odd, &PdhgOptions::default()).unwrap();
        assert!((blk.columns[1].objective - seq.objective).abs() < 1e-10);
        assert_eq!(blk.block_width, 3);
    }

    #[test]
    fn empty_block_is_fine() {
        let blk = solve_block(&[], &PdhgOptions::default()).unwrap();
        assert!(blk.columns.is_empty());
        assert_eq!(blk.block_width, 0);
    }

    #[test]
    fn early_retirement_is_counted() {
        // One easy column (tiny rhs) and one that needs more blocks.
        let probs = vec![family(0.0, 2.0), family(50.0, 2.0)];
        let blk = solve_block(&probs, &PdhgOptions::default()).unwrap();
        let b0 = blk.columns[0].blocks;
        let b1 = blk.columns[1].blocks;
        if b0 != b1 {
            assert!(blk.columns_retired >= 1, "unequal block counts must retire a column");
        }
    }
}
