//! Pricing strategies for the revised simplex.
//!
//! Pricing answers "which column enters the basis?". The driver in
//! [`super::revised`] computes the reduced cost `d_j = c_j − y·A_j`
//! for every nonbasic column each iteration and hands the vector to a
//! [`PricingRule`]:
//!
//! - [`Dantzig`] — most negative reduced cost (extracted legacy
//!   behavior). Zero bookkeeping, but on large instances it walks many
//!   short edges: the reduced cost measures the objective rate per unit
//!   of the *entering variable*, not per unit of distance moved.
//! - [`Devex`] — Forrest–Goldfarb reference weights: approximate
//!   steepest-edge weights maintained from pivot-row information alone
//!   (one extra BTRAN per pivot). The workhorse choice for the large
//!   resource-sharing grids of arXiv:1902.01898.
//! - [`SteepestEdge`] — projected steepest edge with the Goldfarb–Reid
//!   style recurrence: weights track `‖B⁻¹A_j‖²` using both the pivot
//!   row and a reference FTRAN/BTRAN pair per pivot (costlier per
//!   iteration, fewest iterations on long thin problems).
//! - [`PartialDantzig`] — candidate-list partial pricing (the ROADMAP
//!   bullet): price a small rotating window of columns per iteration
//!   instead of the whole reduced-cost vector, refreshing the window
//!   with a rotating full scan whenever it yields no candidate. The
//!   driver computes reduced costs *only* for the window on a hit, so
//!   the per-iteration pricing pass drops from O(nnz(A)) to
//!   O(nnz(A_window)) on the widest sweep grids. Optimality is only
//!   ever declared from a full pass, so the rule stays exact.
//!
//! Weights are a *pivot-choice heuristic*, never a correctness
//! concern: every rule only selects among columns with `d_j < −eps`,
//! so any choice preserves simplex invariants, and the driver's Bland
//! fallback still guarantees termination under degeneracy. The
//! dual-simplex repair pass shares the same weights through
//! [`PricingRule::weight`] to break ratio-test ties toward
//! numerically long edges.

/// Which pricing rule the revised simplex runs (selected via
/// [`super::SimplexOptions::pricing`], threaded end-to-end from the
/// `dlt::api` wire options and the CLI flags).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Pricing {
    /// Most negative reduced cost (extracted legacy behavior).
    #[default]
    Dantzig,
    /// Forrest–Goldfarb devex reference weights.
    Devex,
    /// Projected steepest edge (exact-style recurrence).
    SteepestEdge,
    /// Candidate-list partial pricing (rotating window, Dantzig
    /// within the window, full-scan refresh on miss).
    Partial,
}

impl Pricing {
    /// Stable wire name (`dantzig` / `devex` / `steepest_edge` /
    /// `partial`).
    pub fn as_str(self) -> &'static str {
        match self {
            Pricing::Dantzig => "dantzig",
            Pricing::Devex => "devex",
            Pricing::SteepestEdge => "steepest_edge",
            Pricing::Partial => "partial",
        }
    }

    /// Parse a wire name; `None` for anything unknown.
    pub fn parse(s: &str) -> Option<Pricing> {
        match s {
            "dantzig" => Some(Pricing::Dantzig),
            "devex" => Some(Pricing::Devex),
            "steepest_edge" => Some(Pricing::SteepestEdge),
            "partial" => Some(Pricing::Partial),
            _ => None,
        }
    }

    /// Instantiate the rule.
    pub(crate) fn build(self) -> Box<dyn PricingRule> {
        match self {
            Pricing::Dantzig => Box::new(Dantzig),
            Pricing::Devex => Box::new(Devex::default()),
            Pricing::SteepestEdge => Box::new(SteepestEdge::default()),
            Pricing::Partial => Box::new(PartialDantzig::default()),
        }
    }
}

/// Everything a weight update may consume, captured *before* the pivot
/// mutated the factorization and *after* the basis index maps were
/// updated (so `in_basis` reflects the post-pivot state: `q` is basic,
/// `leaving` is nonbasic again).
pub struct PivotContext<'a> {
    /// Entering column.
    pub q: usize,
    /// Pivot row.
    pub r: usize,
    /// Column that left the basis in row `r` (`None` when an
    /// artificial left).
    pub leaving: Option<usize>,
    /// Pivot element `α_rq = w[r]` (pre-pivot FTRAN of the entering
    /// column).
    pub alpha_rq: f64,
    /// `‖w‖² = ‖B⁻¹A_q‖²` (pre-pivot).
    pub w_norm2: f64,
    /// Pivot row `α_r = eᵣᵀB⁻¹A` per column (pre-pivot; entries for
    /// basic columns are unspecified).
    pub alpha_r: &'a [f64],
    /// `A_j · v` per column with `v = B⁻ᵀw` (pre-pivot; only filled
    /// when [`PricingRule::needs_reference_ftran`] is true).
    pub a_dot_v: &'a [f64],
    /// Post-pivot basis membership.
    pub in_basis: &'a [bool],
}

/// One pricing strategy.
///
/// `Send` for the same reason as [`crate::lp::BasisFactorization`]:
/// boxed rules live inside session scratch state that the serving
/// tier moves between worker threads.
pub trait PricingRule: Send {
    /// Rule name (diagnostics).
    fn name(&self) -> &'static str;

    /// (Re-)initialize the reference framework for `ncols` columns.
    fn reset(&mut self, ncols: usize);

    /// Pick the entering column among nonbasic columns with reduced
    /// cost `d[j] < −eps`; `None` means optimal under this rule.
    fn select_entering(&mut self, d: &[f64], in_basis: &[bool], eps: f64) -> Option<usize>;

    /// Whether [`PricingRule::update`] consumes the pivot row `α_r`
    /// (costs the driver one extra BTRAN plus a column pass per pivot).
    fn needs_pivot_row(&self) -> bool;

    /// Whether [`PricingRule::update`] consumes `A_j·v` with
    /// `v = B⁻ᵀw` (one more BTRAN plus a column pass per pivot).
    fn needs_reference_ftran(&self) -> bool;

    /// Observe a pivot and update the weights.
    fn update(&mut self, ctx: &PivotContext<'_>);

    /// Reference weight of column `j` (1.0 for unweighted rules). The
    /// dual ratio test uses this to break ties.
    fn weight(&self, j: usize) -> f64;

    /// Whether [`PricingRule::weight`] carries information (lets the
    /// dual ratio test skip tie-breaking work for Dantzig).
    fn uses_weights(&self) -> bool;

    /// Times the reference framework was rebuilt after weight
    /// overflow.
    fn weight_resets(&self) -> usize;

    /// Fill `out` with the candidate window this rule wants priced
    /// *before* the full pass. Returning `false` (the default) means
    /// the rule prices every column and the driver skips the partial
    /// step entirely.
    fn gather_candidates(&self, _out: &mut Vec<usize>) -> bool {
        false
    }

    /// Select among the candidate window only, given reduced costs
    /// that are fresh *for the window columns* (others are stale).
    /// `None` is a miss: the driver then runs the full pricing pass
    /// and calls [`PricingRule::select_entering`], which doubles as
    /// the window refresh.
    fn select_from_candidates(
        &mut self,
        _d: &[f64],
        _in_basis: &[bool],
        _eps: f64,
    ) -> Option<usize> {
        None
    }

    /// Iterations that entered from the candidate window without a
    /// full pricing pass (partial rules only).
    fn candidate_hits(&self) -> usize {
        0
    }

    /// Full pricing passes that rebuilt the candidate window (partial
    /// rules only).
    fn candidate_refreshes(&self) -> usize {
        0
    }
}

/// Most negative reduced cost — the rule the driver hardwired before
/// this layer existed.
pub struct Dantzig;

impl PricingRule for Dantzig {
    fn name(&self) -> &'static str {
        "dantzig"
    }

    fn reset(&mut self, _ncols: usize) {}

    fn select_entering(&mut self, d: &[f64], in_basis: &[bool], eps: f64) -> Option<usize> {
        let mut best = -eps;
        let mut enter = None;
        for (j, &dj) in d.iter().enumerate() {
            if in_basis[j] {
                continue;
            }
            if dj < best {
                best = dj;
                enter = Some(j);
            }
        }
        enter
    }

    fn needs_pivot_row(&self) -> bool {
        false
    }

    fn needs_reference_ftran(&self) -> bool {
        false
    }

    fn update(&mut self, _ctx: &PivotContext<'_>) {}

    fn weight(&self, _j: usize) -> f64 {
        1.0
    }

    fn uses_weights(&self) -> bool {
        false
    }

    fn weight_resets(&self) -> usize {
        0
    }
}

/// Weights grow past this bound → rebuild the reference framework.
const WEIGHT_RESET_BOUND: f64 = 1e12;

/// Shared select for the weighted rules: maximize `d_j² / γ_j`.
fn select_weighted(gamma: &[f64], d: &[f64], in_basis: &[bool], eps: f64) -> Option<usize> {
    let mut best_score = 0.0;
    let mut enter = None;
    for (j, &dj) in d.iter().enumerate() {
        if in_basis[j] || dj >= -eps {
            continue;
        }
        let score = dj * dj / gamma[j];
        if score > best_score {
            best_score = score;
            enter = Some(j);
        }
    }
    enter
}

/// Forrest–Goldfarb devex: reference weights start at 1 and only ever
/// grow (`γ_j ← max(γ_j, τ_j²γ_q)` with `τ_j = α_rj/α_rq`), so they
/// approximate steepest-edge weights from pivot-row information alone.
#[derive(Default)]
pub struct Devex {
    gamma: Vec<f64>,
    resets: usize,
}

impl PricingRule for Devex {
    fn name(&self) -> &'static str {
        "devex"
    }

    fn reset(&mut self, ncols: usize) {
        self.gamma.clear();
        self.gamma.resize(ncols, 1.0);
    }

    fn select_entering(&mut self, d: &[f64], in_basis: &[bool], eps: f64) -> Option<usize> {
        select_weighted(&self.gamma, d, in_basis, eps)
    }

    fn needs_pivot_row(&self) -> bool {
        true
    }

    fn needs_reference_ftran(&self) -> bool {
        false
    }

    fn update(&mut self, ctx: &PivotContext<'_>) {
        let arq2 = ctx.alpha_rq * ctx.alpha_rq;
        if arq2 < 1e-24 {
            return;
        }
        let gq = self.gamma[ctx.q].max(1.0);
        for (j, &a) in ctx.alpha_r.iter().enumerate() {
            if ctx.in_basis[j] || Some(j) == ctx.leaving || a == 0.0 {
                continue;
            }
            let cand = (a * a / arq2) * gq;
            if cand > self.gamma[j] {
                self.gamma[j] = cand;
            }
        }
        if let Some(l) = ctx.leaving {
            self.gamma[l] = (gq / arq2).max(1.0);
        }
        if self.gamma.iter().any(|&g| g > WEIGHT_RESET_BOUND) {
            self.gamma.iter_mut().for_each(|g| *g = 1.0);
            self.resets += 1;
        }
    }

    fn weight(&self, j: usize) -> f64 {
        self.gamma[j]
    }

    fn uses_weights(&self) -> bool {
        true
    }

    fn weight_resets(&self) -> usize {
        self.resets
    }
}

/// Projected steepest edge: weights track `‖B⁻¹A_j‖²` through the
/// Goldfarb–Reid recurrence `γ_j ← γ_j − 2τ_j(A_j·v) + τ_j²γ_q` with
/// `v = B⁻ᵀη_q`, floored to stay positive (drift in the recurrence
/// only degrades the heuristic, never correctness).
#[derive(Default)]
pub struct SteepestEdge {
    gamma: Vec<f64>,
    resets: usize,
}

impl PricingRule for SteepestEdge {
    fn name(&self) -> &'static str {
        "steepest_edge"
    }

    fn reset(&mut self, ncols: usize) {
        self.gamma.clear();
        self.gamma.resize(ncols, 1.0);
    }

    fn select_entering(&mut self, d: &[f64], in_basis: &[bool], eps: f64) -> Option<usize> {
        select_weighted(&self.gamma, d, in_basis, eps)
    }

    fn needs_pivot_row(&self) -> bool {
        true
    }

    fn needs_reference_ftran(&self) -> bool {
        true
    }

    fn update(&mut self, ctx: &PivotContext<'_>) {
        let arq = ctx.alpha_rq;
        if arq.abs() < 1e-12 {
            return;
        }
        let gq = ctx.w_norm2.max(1e-12);
        for (j, &a) in ctx.alpha_r.iter().enumerate() {
            if ctx.in_basis[j] || Some(j) == ctx.leaving || a == 0.0 {
                continue;
            }
            let tau = a / arq;
            let cand = self.gamma[j] - 2.0 * tau * ctx.a_dot_v[j] + tau * tau * gq;
            self.gamma[j] = cand.max(tau * tau).max(1e-4);
        }
        if let Some(l) = ctx.leaving {
            self.gamma[l] = (gq / (arq * arq)).max(1e-4);
        }
        if self.gamma.iter().any(|&g| g > WEIGHT_RESET_BOUND) {
            self.gamma.iter_mut().for_each(|g| *g = 1.0);
            self.resets += 1;
        }
    }

    fn weight(&self, j: usize) -> f64 {
        self.gamma[j]
    }

    fn uses_weights(&self) -> bool {
        true
    }

    fn weight_resets(&self) -> usize {
        self.resets
    }
}

/// Window capacity for [`PartialDantzig`]: ≈ √ncols, clamped to a
/// useful range.
fn partial_window_cap(ncols: usize) -> usize {
    ((ncols as f64).sqrt().ceil() as usize).clamp(8, 128)
}

/// Candidate-list partial pricing: Dantzig within a small rotating
/// window of columns. On a *hit* the driver priced only the window —
/// the per-iteration win. On a *miss* (window empty or no violating
/// reduced cost in it) the driver runs the full pass and
/// [`PricingRule::select_entering`] rebuilds the window with a
/// rotating scan, so consecutive refreshes walk different parts of
/// the column range and no column is starved. Optimality is only ever
/// declared from a full pass, so the rule is exact; the driver's
/// Bland fallback still guarantees termination under degeneracy.
#[derive(Default)]
pub struct PartialDantzig {
    /// Current candidate window (column indices).
    window: Vec<usize>,
    /// Rotating scan start for the next refresh.
    cursor: usize,
    /// Window capacity (set at [`PricingRule::reset`]).
    cap: usize,
    hits: usize,
    refreshes: usize,
}

impl PricingRule for PartialDantzig {
    fn name(&self) -> &'static str {
        "partial"
    }

    fn reset(&mut self, ncols: usize) {
        self.window.clear();
        self.cursor = 0;
        self.cap = partial_window_cap(ncols);
    }

    /// Full pass — doubles as the window refresh. Scans the columns in
    /// rotating order from the cursor, collecting violating columns
    /// into the window; stops early once the window is full (progress
    /// is then guaranteed, so optimality need not be proven). `None`
    /// only after a complete scan found nothing, which is exact.
    fn select_entering(&mut self, d: &[f64], in_basis: &[bool], eps: f64) -> Option<usize> {
        self.refreshes += 1;
        self.window.clear();
        let n = d.len();
        if n == 0 {
            return None;
        }
        let start = self.cursor % n;
        let mut best: Option<usize> = None;
        let mut best_d = -eps;
        for step in 0..n {
            let j = (start + step) % n;
            if in_basis[j] {
                continue;
            }
            let dj = d[j];
            if dj < -eps {
                self.window.push(j);
                if dj < best_d {
                    best_d = dj;
                    best = Some(j);
                }
                if self.window.len() >= self.cap {
                    self.cursor = (j + 1) % n;
                    return best;
                }
            }
        }
        self.cursor = start;
        best
    }

    fn gather_candidates(&self, out: &mut Vec<usize>) -> bool {
        out.clear();
        out.extend_from_slice(&self.window);
        true
    }

    fn select_from_candidates(
        &mut self,
        d: &[f64],
        in_basis: &[bool],
        eps: f64,
    ) -> Option<usize> {
        let mut best: Option<usize> = None;
        let mut best_d = -eps;
        for &j in &self.window {
            if in_basis[j] {
                continue;
            }
            let dj = d[j];
            if dj < best_d {
                best_d = dj;
                best = Some(j);
            }
        }
        if best.is_some() {
            self.hits += 1;
        }
        best
    }

    fn needs_pivot_row(&self) -> bool {
        false
    }

    fn needs_reference_ftran(&self) -> bool {
        false
    }

    fn update(&mut self, _ctx: &PivotContext<'_>) {}

    fn weight(&self, _j: usize) -> f64 {
        1.0
    }

    fn uses_weights(&self) -> bool {
        false
    }

    fn weight_resets(&self) -> usize {
        0
    }

    fn candidate_hits(&self) -> usize {
        self.hits
    }

    fn candidate_refreshes(&self) -> usize {
        self.refreshes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx<'a>(
        q: usize,
        leaving: Option<usize>,
        alpha_rq: f64,
        alpha_r: &'a [f64],
        a_dot_v: &'a [f64],
        in_basis: &'a [bool],
    ) -> PivotContext<'a> {
        PivotContext { q, r: 0, leaving, alpha_rq, w_norm2: 2.0, alpha_r, a_dot_v, in_basis }
    }

    #[test]
    fn dantzig_picks_most_negative() {
        let mut p = Dantzig;
        let d = [0.0, -1.0, -3.0, -2.0];
        let basic = [false, false, false, false];
        assert_eq!(p.select_entering(&d, &basic, 1e-9), Some(2));
        // Basic columns are skipped even with the best reduced cost.
        let basic = [false, false, true, false];
        assert_eq!(p.select_entering(&d, &basic, 1e-9), Some(3));
        // Nothing below -eps → optimal.
        assert_eq!(p.select_entering(&[0.0, 1e-12], &[false, false], 1e-9), None);
    }

    #[test]
    fn devex_weights_bias_selection() {
        let mut p = Devex::default();
        p.reset(3);
        // Equal reduced costs: weights break the tie.
        let in_basis = [false, true, false];
        p.update(&ctx(1, None, 1.0, &[4.0, 0.0, 0.0], &[0.0; 3], &in_basis));
        // Column 0 now carries weight 16 (τ=4, γ_q=1): column 2 wins a
        // tie on equal reduced costs.
        assert!(p.weight(0) >= 16.0 - 1e-12);
        let d = [-1.0, 0.0, -1.0];
        assert_eq!(p.select_entering(&d, &[false, true, false], 1e-9), Some(2));
        assert!(p.uses_weights());
    }

    #[test]
    fn devex_resets_on_overflow() {
        let mut p = Devex::default();
        p.reset(2);
        let in_basis = [true, false];
        // A huge pivot-row entry with a tiny pivot element inflates the
        // weight past the reset bound.
        p.update(&ctx(0, None, 1e-7, &[0.0, 1e7], &[0.0; 2], &in_basis));
        assert_eq!(p.weight_resets(), 1);
        assert_eq!(p.weight(1), 1.0);
    }

    #[test]
    fn steepest_edge_recurrence_stays_positive() {
        let mut p = SteepestEdge::default();
        p.reset(3);
        let in_basis = [true, false, false];
        // An adversarial a_dot_v that would drive the naive recurrence
        // negative must be floored.
        p.update(&ctx(0, None, 1.0, &[0.0, 1.0, 0.5], &[0.0, 100.0, 50.0], &in_basis));
        assert!(p.weight(1) > 0.0);
        assert!(p.weight(2) > 0.0);
    }

    #[test]
    fn wire_names_roundtrip() {
        for p in
            [Pricing::Dantzig, Pricing::Devex, Pricing::SteepestEdge, Pricing::Partial]
        {
            assert_eq!(Pricing::parse(p.as_str()), Some(p));
        }
        assert_eq!(Pricing::parse("bland"), None);
    }

    #[test]
    fn partial_window_hit_miss_refresh() {
        let mut p = PartialDantzig::default();
        p.reset(6);
        // No window yet: the candidate step misses, the full pass
        // refreshes and returns the most negative column.
        let free = [false; 6];
        let mut buf = Vec::new();
        assert!(p.gather_candidates(&mut buf));
        assert!(buf.is_empty());
        let d = [0.0, -1.0, -3.0, 0.0, -2.0, 0.0];
        assert_eq!(p.select_from_candidates(&d, &free, 1e-9), None);
        assert_eq!(p.select_entering(&d, &free, 1e-9), Some(2));
        assert_eq!(p.candidate_refreshes(), 1);
        // The window now holds the violating columns: a hit prices
        // only those.
        assert!(p.gather_candidates(&mut buf));
        assert!(buf.contains(&1) && buf.contains(&2) && buf.contains(&4));
        assert_eq!(p.select_from_candidates(&d, &free, 1e-9), Some(2));
        assert_eq!(p.candidate_hits(), 1);
        // Columns that went basic are skipped inside the window.
        let basic2 = [false, false, true, false, false, false];
        assert_eq!(p.select_from_candidates(&d, &basic2, 1e-9), Some(4));
        // Optimality is only declared from a full scan.
        let opt = [0.0; 6];
        assert_eq!(p.select_from_candidates(&opt, &free, 1e-9), None);
        assert_eq!(p.select_entering(&opt, &free, 1e-9), None);
    }

    #[test]
    fn partial_refresh_rotates_and_caps() {
        let mut p = PartialDantzig::default();
        let n = 2000;
        p.reset(n);
        let d = vec![-1.0; n];
        let in_basis = vec![false; n];
        let first = p.select_entering(&d, &in_basis, 1e-9).unwrap();
        let mut w1 = Vec::new();
        p.gather_candidates(&mut w1);
        assert!(w1.len() <= 128, "window capped, got {}", w1.len());
        // A second refresh starts where the first stopped: disjoint
        // windows over a uniformly-violating vector.
        let second = p.select_entering(&d, &in_basis, 1e-9).unwrap();
        let mut w2 = Vec::new();
        p.gather_candidates(&mut w2);
        assert!(w2.iter().all(|j| !w1.contains(j)), "rotation must advance");
        assert_ne!(first, second);
        assert!(!p.uses_weights());
        assert_eq!(p.weight(0), 1.0);
    }
}
