//! LP problem description: `min c'x  s.t.  a_k' x {<=,>=,=} b_k, x >= 0`.

/// Constraint comparison operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cmp {
    /// `a'x <= b`
    Le,
    /// `a'x >= b`
    Ge,
    /// `a'x == b`
    Eq,
}

impl std::fmt::Display for Cmp {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Cmp::Le => write!(f, "<="),
            Cmp::Ge => write!(f, ">="),
            Cmp::Eq => write!(f, "=="),
        }
    }
}

/// One linear constraint in sparse form.
#[derive(Debug, Clone)]
pub struct Constraint {
    /// `(variable index, coefficient)` pairs. Duplicate indices are summed.
    pub coeffs: Vec<(usize, f64)>,
    /// Comparison operator.
    pub cmp: Cmp,
    /// Right-hand side.
    pub rhs: f64,
    /// Optional label for diagnostics (`release[2]`, `finish[7]`, ...).
    pub label: String,
}

/// A linear program over non-negative variables.
#[derive(Debug, Clone)]
pub struct LpProblem {
    num_vars: usize,
    objective: Vec<f64>,
    constraints: Vec<Constraint>,
    var_names: Vec<String>,
}

impl LpProblem {
    /// New problem with `num_vars` non-negative variables and zero
    /// objective.
    pub fn new(num_vars: usize) -> LpProblem {
        LpProblem {
            num_vars,
            objective: vec![0.0; num_vars],
            constraints: Vec::new(),
            var_names: (0..num_vars).map(|i| format!("x{i}")).collect(),
        }
    }

    /// Number of decision variables.
    pub fn num_vars(&self) -> usize {
        self.num_vars
    }

    /// Number of constraints.
    pub fn num_constraints(&self) -> usize {
        self.constraints.len()
    }

    /// Set the full objective vector (minimization).
    pub fn set_objective(&mut self, c: &[f64]) {
        assert_eq!(c.len(), self.num_vars, "objective length mismatch");
        self.objective.copy_from_slice(c);
    }

    /// Set a single objective coefficient.
    pub fn set_objective_coeff(&mut self, var: usize, c: f64) {
        assert!(var < self.num_vars);
        self.objective[var] = c;
    }

    /// Objective vector.
    pub fn objective(&self) -> &[f64] {
        &self.objective
    }

    /// Name a variable (diagnostics only).
    pub fn name_var(&mut self, var: usize, name: impl Into<String>) {
        self.var_names[var] = name.into();
    }

    /// Variable name.
    pub fn var_name(&self, var: usize) -> &str {
        &self.var_names[var]
    }

    /// Add a constraint from sparse coefficients.
    pub fn add_constraint(&mut self, coeffs: &[(usize, f64)], cmp: Cmp, rhs: f64) -> usize {
        self.add_labeled(coeffs, cmp, rhs, String::new())
    }

    /// Add a labeled constraint from sparse coefficients.
    pub fn add_labeled(
        &mut self,
        coeffs: &[(usize, f64)],
        cmp: Cmp,
        rhs: f64,
        label: impl Into<String>,
    ) -> usize {
        for &(v, _) in coeffs {
            assert!(v < self.num_vars, "constraint references unknown var {v}");
        }
        self.constraints.push(Constraint {
            coeffs: coeffs.to_vec(),
            cmp,
            rhs,
            label: label.into(),
        });
        self.constraints.len() - 1
    }

    /// Constraints slice.
    pub fn constraints(&self) -> &[Constraint] {
        &self.constraints
    }

    /// Evaluate the objective at a point.
    pub fn objective_at(&self, x: &[f64]) -> f64 {
        crate::linalg::dot(&self.objective, x)
    }

    /// Check feasibility of a point within tolerance `eps`; returns the
    /// first violated constraint description, or `None` if feasible.
    pub fn check_feasible(&self, x: &[f64], eps: f64) -> Option<String> {
        if x.len() != self.num_vars {
            return Some(format!("point has {} vars, problem has {}", x.len(), self.num_vars));
        }
        for (i, &xi) in x.iter().enumerate() {
            if xi < -eps {
                return Some(format!("{} = {} < 0", self.var_names[i], xi));
            }
        }
        for (k, c) in self.constraints.iter().enumerate() {
            let lhs: f64 = c.coeffs.iter().map(|&(v, a)| a * x[v]).sum();
            let ok = match c.cmp {
                Cmp::Le => lhs <= c.rhs + eps,
                Cmp::Ge => lhs >= c.rhs - eps,
                Cmp::Eq => (lhs - c.rhs).abs() <= eps,
            };
            if !ok {
                return Some(format!(
                    "constraint {k} `{}`: {} {} {} violated (lhs={})",
                    c.label, lhs, c.cmp, c.rhs, lhs
                ));
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_inspect() {
        let mut p = LpProblem::new(3);
        p.set_objective(&[1.0, 0.0, -1.0]);
        p.name_var(0, "beta_0");
        let idx = p.add_labeled(&[(0, 1.0), (2, 2.0)], Cmp::Le, 5.0, "cap");
        assert_eq!(idx, 0);
        assert_eq!(p.num_constraints(), 1);
        assert_eq!(p.var_name(0), "beta_0");
        assert_eq!(p.constraints()[0].label, "cap");
    }

    #[test]
    fn feasibility_check() {
        let mut p = LpProblem::new(2);
        p.add_constraint(&[(0, 1.0), (1, 1.0)], Cmp::Eq, 1.0);
        assert!(p.check_feasible(&[0.5, 0.5], 1e-9).is_none());
        assert!(p.check_feasible(&[0.9, 0.5], 1e-9).is_some());
        assert!(p.check_feasible(&[-0.1, 1.1], 1e-9).is_some());
    }

    #[test]
    fn objective_eval() {
        let mut p = LpProblem::new(2);
        p.set_objective(&[2.0, -3.0]);
        assert_eq!(p.objective_at(&[1.0, 1.0]), -1.0);
    }
}
