//! Revised simplex over sparse column storage — the driver.
//!
//! Instead of carrying the full dense tableau (O(m·width) per pivot),
//! the revised method keeps only a basis factorization and derives
//! everything per iteration from the *original* sparse columns:
//!
//! - **BTRAN** `y = B⁻ᵀ c_B`, then pricing as `d_j = c_j − y·A_j` — a
//!   sparse dot per column, O(nnz(A)) per pass (or O(nnz) of a small
//!   candidate window under partial pricing);
//! - **FTRAN** `w = B⁻¹ A_q` for the ratio test;
//! - one factorization **update** per pivot.
//!
//! The whole per-iteration path is **hypersparse**: FTRAN/BTRAN
//! right-hand sides travel as [`SparseVector`] work arrays through the
//! factorization's sparse kernels, the ratio test and the basic-value
//! update iterate only the FTRAN result's nonzeros, and the
//! factorization update consumes the sparse vector directly. On the
//! paper's timing-chain LPs an iteration touches tens of entries where
//! the dense path touched O(m²).
//!
//! The two per-pivot policies are strategy layers, selected through
//! [`SimplexOptions`]:
//!
//! - **how `B⁻¹` is maintained** — [`super::factorization`]: the
//!   product-form eta file (default, extracted legacy behavior), the
//!   same eta updating over a Markowitz/threshold refactorization, or
//!   Forrest–Tomlin / Bartels–Golub LU updating, which refactorize far
//!   less often on long pivot sequences;
//! - **which column enters** — [`super::pricing`]: Dantzig (default),
//!   devex, projected steepest edge, or candidate-list partial
//!   pricing (`partial`), whose window hits let the driver skip the
//!   full reduced-cost pass entirely. The same permanent Bland
//!   fallback and stall detection as the dense tableau guarantee
//!   termination regardless of rule.
//!
//! All work buffers live in a per-worker [`SolverScratch`] pool
//! ([`solve_revised_scratch`]): repeated warm solves through one
//! scratch — the `solve_batch` / sweep steady state — allocate
//! nothing in this module.
//!
//! Phase 1 starts from the slack/artificial identity basis;
//! [`solve_revised`] can instead **warm-start** from a previous optimal
//! [`Basis`] of a structurally identical problem, skipping phase 1
//! entirely when that basis is still primal feasible — the common case
//! across the paper's parameter sweeps, where consecutive scenarios
//! differ only in rhs or objective data.
//!
//! When an rhs perturbation leaves the cached basis primal-*infeasible*
//! but still dual-feasible (reduced costs are rhs-independent, so a
//! previously optimal basis always is), the solver re-optimizes with a
//! **dual simplex** pass instead of discarding the basis: pick the most
//! negative basic value as the leaving row, price the row `B⁻¹A` via a
//! BTRAN of `e_r`, and enter the column minimizing the dual ratio
//! `d_j / −α_j` (ties broken toward the larger devex/steepest-edge
//! weight when a weighted rule is active, so the repair pass shares the
//! primal loops' pricing state). Primal feasibility is restored in a
//! handful of pivots and phase 1 never runs —
//! [`LpSolution::phase1_iterations`] stays 0.

use super::factorization::{BasisFactorization, Factorization};
use super::pricing::{PivotContext, Pricing, PricingRule};
use super::problem::LpProblem;
use super::scratch::SolverScratch;
use super::simplex::SimplexOptions;
use super::solution::LpSolution;
use super::standard::{AuxKind, StandardForm};
use crate::error::{Error, Result};
use crate::linalg::{SparseMatrix, SparseVector};

/// A simplex basis: for each constraint row, the column (structural or
/// auxiliary, in [`StandardForm`] numbering) basic in that row.
/// `usize::MAX` marks a row still held by an artificial variable (only
/// possible for redundant rows); warm starts treat any such entry as
/// "no information" and fall back to a cold start.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Basis {
    /// Basic column per row.
    pub cols: Vec<usize>,
}

impl Basis {
    /// True when every row has a usable (non-artificial) basic column.
    pub fn is_complete(&self) -> bool {
        self.cols.iter().all(|&c| c != usize::MAX)
    }
}

/// Solve `p`, optionally warm-starting from `warm` (throwaway scratch
/// — see [`solve_revised_scratch`] for the pooled entry point).
pub fn solve_revised(
    p: &LpProblem,
    opts: &SimplexOptions,
    warm: Option<&Basis>,
) -> Result<LpSolution> {
    let mut scratch = SolverScratch::new();
    solve_revised_scratch(p, opts, warm, &mut scratch)
}

/// Solve `p` through a per-worker [`SolverScratch`] pool, optionally
/// warm-starting from `warm`. A warm basis that factorizes but is
/// primal-infeasible for the new rhs is repaired by the dual simplex
/// when it is still dual-feasible; only unusable bases (wrong shape,
/// singular, dual-infeasible, or a stalled dual repair) fall back to a
/// cold two-phase start. The scratch's buffers are borrowed for the
/// duration of the solve and returned afterwards — steady-state warm
/// re-solves allocate nothing here.
pub fn solve_revised_scratch(
    p: &LpProblem,
    opts: &SimplexOptions,
    warm: Option<&Basis>,
    scratch: &mut SolverScratch,
) -> Result<LpSolution> {
    let sf = StandardForm::equality(p);
    let mut s = Revised::new(&sf, opts, scratch);
    let result = s.drive(p, opts, warm);
    s.stash(scratch);
    result
}

/// Rebuild the pooled sparse basis matrix for a candidate set of
/// basic columns (artificial ids become unit columns) through the
/// reusable triplet buffer — the basis is never densified and the
/// warm-path assembly allocates nothing once the buffers are warm.
fn fill_basis_sparse(
    sf: &StandardForm,
    ncols: usize,
    m: usize,
    cols: &[usize],
    trips: &mut Vec<(usize, usize, f64)>,
    mat: &mut SparseMatrix,
) {
    trips.clear();
    for (k, &bv) in cols.iter().enumerate() {
        if bv < ncols {
            for (i, v) in sf.a.col(bv) {
                trips.push((i, k, v));
            }
        } else {
            trips.push((bv - ncols, k, 1.0));
        }
    }
    mat.refill_from_triplets(m, m, trips);
}

/// Outcome of adopting a warm basis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum WarmStart {
    /// Basis rejected (shape mismatch, artificial rows, singular).
    Unusable,
    /// Basis adopted and primal feasible: phase 2 can start directly.
    Feasible,
    /// Basis adopted but some basic values are negative: a dual-simplex
    /// repair is required before phase 2.
    PrimalInfeasible,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    One,
    Two,
}

struct Revised<'a> {
    sf: &'a StandardForm,
    m: usize,
    /// Structural + auxiliary column count; artificial for row `r` is
    /// represented as column id `ncols + r`.
    ncols: usize,
    basis: Vec<usize>,
    in_basis: Vec<bool>,
    /// Current basic-variable values `x_B` per row.
    xb: Vec<f64>,
    /// Basis-factorization strategy (`B⁻¹` maintenance).
    fact: Box<dyn BasisFactorization>,
    /// Pricing strategy (entering-column choice + weights).
    pricing: Box<dyn PricingRule>,
    /// Strategy tags, for returning the objects to the scratch pool.
    fact_kind: Factorization,
    pricing_kind: Pricing,
    eps: f64,
    feas_eps: f64,
    max_iters: usize,
    stall_limit: usize,
    /// Wall-clock budget, checked every 64 iterations in the primal
    /// and dual loops.
    budget: super::recovery::SolveBudget,
    /// In-solve fallbacks taken (`early_refactorize`, `bland_engaged`,
    /// `warm_fallback_cold`), drained into the solution on extract.
    /// Fresh (empty, unallocated) per solve — not pooled — so the
    /// scratch pool stays invisible to results.
    recovery_events: Vec<String>,
    iterations: usize,
    phase1_iters: usize,
    dual_iters: usize,
    /// Full refactorizations performed (periodic cadence, verdict
    /// re-checks, and numerical-breakdown recoveries; the initial
    /// factor of a warm basis is not counted).
    refactorizations: usize,
    /// Peak update-file length observed (etas / FT spikes).
    peak_update_len: usize,
    /// FTRAN nonzero tally (hypersparsity diagnostic).
    ftran_nnz_sum: usize,
    ftran_count: usize,
    /// BTRAN nonzero tally (hypersparsity diagnostic).
    btran_nnz_sum: usize,
    btran_count: usize,
    /// Factorization solve-mode counter baselines at solve start:
    /// pooled factorization objects persist across solves, so the
    /// solution must report per-solve deltas, not lifetime totals.
    dfs0: usize,
    scan0: usize,
    /// Pricing-rule counter baselines at solve start: pooled rule
    /// objects persist across solves, so the solution must report
    /// per-solve deltas, not lifetime totals.
    weight_resets0: usize,
    candidate_hits0: usize,
    candidate_refreshes0: usize,
    // Work vectors (sparse kernels), reused across iterations and —
    // via the scratch pool — across solves.
    /// FTRAN result `B⁻¹ A_q`.
    w: SparseVector,
    /// BTRAN result (pricing duals, or the dual loop's row vector).
    y: SparseVector,
    /// `B⁻ᵀ w` for the steepest-edge reference recurrence.
    vref: SparseVector,
    /// Dual-simplex pivot-row vector `B⁻ᵀ e_r` (kept separate from `y`
    /// because one dual iteration needs both the row and the duals).
    rho: Vec<f64>,
    /// Reduced costs per column (length ncols).
    d: Vec<f64>,
    /// Pivot row `α_r` per column (length ncols; weighted rules only).
    alpha_r: Vec<f64>,
    /// `A_j·vref` per column (length ncols; steepest edge only).
    adv: Vec<f64>,
    /// Candidate window borrowed from the pricing rule each iteration.
    cand_buf: Vec<usize>,
    /// Gathered FTRAN-column `(index, value)` pairs: the ratio test
    /// and the x_B update stream these two flat arrays instead of
    /// chasing `idx -> vals` per element.
    gidx: Vec<usize>,
    gval: Vec<f64>,
    /// Triplet buffer for sparse basis assembly.
    trip_buf: Vec<(usize, usize, f64)>,
    /// Pooled CSC basis view (rebuilt in place per refactorization).
    basis_mat: SparseMatrix,
}

impl<'a> Revised<'a> {
    fn new(
        sf: &'a StandardForm,
        opts: &SimplexOptions,
        scratch: &mut SolverScratch,
    ) -> Revised<'a> {
        let m = sf.b.len();
        let ncols = sf.a.cols();
        let max_iters =
            if opts.max_iters == 0 { 200 * (m + ncols + 1) } else { opts.max_iters };
        let fact = scratch.take_fact(opts.factorization, m);
        let dfs0 = fact.dfs_solves();
        let scan0 = fact.scan_solves();
        let mut pricing = scratch.take_pricing(opts.pricing);
        pricing.reset(ncols);
        let weight_resets0 = pricing.weight_resets();
        let candidate_hits0 = pricing.candidate_hits();
        let candidate_refreshes0 = pricing.candidate_refreshes();

        let mut basis = std::mem::take(&mut scratch.basis);
        basis.clear();
        basis.resize(m, usize::MAX);
        let mut in_basis = std::mem::take(&mut scratch.in_basis);
        in_basis.clear();
        in_basis.resize(ncols, false);
        let mut xb = std::mem::take(&mut scratch.xb);
        xb.clear();
        xb.resize(m, 0.0);
        let mut rho = std::mem::take(&mut scratch.rho);
        rho.clear();
        rho.resize(m, 0.0);
        let mut d = std::mem::take(&mut scratch.d);
        d.clear();
        d.resize(ncols, 0.0);
        let mut alpha_r = std::mem::take(&mut scratch.alpha_r);
        alpha_r.clear();
        alpha_r.resize(ncols, 0.0);
        let mut adv = std::mem::take(&mut scratch.adv);
        adv.clear();
        adv.resize(ncols, 0.0);
        let mut w = std::mem::take(&mut scratch.w);
        w.resize_clear(m);
        let mut y = std::mem::take(&mut scratch.y);
        y.resize_clear(m);
        let mut vref = std::mem::take(&mut scratch.vref);
        vref.resize_clear(m);
        let mut cand_buf = std::mem::take(&mut scratch.cand_buf);
        cand_buf.clear();
        let mut trip_buf = std::mem::take(&mut scratch.trip_buf);
        trip_buf.clear();
        let mut gidx = std::mem::take(&mut scratch.gidx);
        gidx.clear();
        let mut gval = std::mem::take(&mut scratch.gval);
        gval.clear();
        let basis_mat = std::mem::take(&mut scratch.basis_mat);

        Revised {
            sf,
            m,
            ncols,
            basis,
            in_basis,
            xb,
            fact,
            pricing,
            fact_kind: opts.factorization,
            pricing_kind: opts.pricing,
            eps: opts.eps,
            feas_eps: opts.feas_eps,
            max_iters,
            stall_limit: opts.stall_limit,
            budget: opts.budget,
            recovery_events: Vec::new(),
            iterations: 0,
            phase1_iters: 0,
            dual_iters: 0,
            refactorizations: 0,
            peak_update_len: 0,
            ftran_nnz_sum: 0,
            ftran_count: 0,
            btran_nnz_sum: 0,
            btran_count: 0,
            dfs0,
            scan0,
            weight_resets0,
            candidate_hits0,
            candidate_refreshes0,
            w,
            y,
            vref,
            rho,
            d,
            alpha_r,
            adv,
            cand_buf,
            gidx,
            gval,
            trip_buf,
            basis_mat,
        }
    }

    /// Return every pooled buffer (and the strategy objects) to the
    /// scratch, success or error.
    fn stash(self, scratch: &mut SolverScratch) {
        scratch.put_fact(self.fact_kind, self.m, self.fact);
        scratch.put_pricing(self.pricing_kind, self.pricing);
        scratch.basis = self.basis;
        scratch.in_basis = self.in_basis;
        scratch.xb = self.xb;
        scratch.rho = self.rho;
        scratch.d = self.d;
        scratch.alpha_r = self.alpha_r;
        scratch.adv = self.adv;
        scratch.w = self.w;
        scratch.y = self.y;
        scratch.vref = self.vref;
        scratch.cand_buf = self.cand_buf;
        scratch.trip_buf = self.trip_buf;
        scratch.gidx = self.gidx;
        scratch.gval = self.gval;
        scratch.basis_mat = self.basis_mat;
    }

    /// The full solve: warm adoption (with dual repair), cold phase 1
    /// fallback, phase 2, extraction.
    fn drive(
        &mut self,
        p: &LpProblem,
        opts: &SimplexOptions,
        warm: Option<&Basis>,
    ) -> Result<LpSolution> {
        let mut warmed = false;
        if let Some(w) = warm {
            match self.try_warm_start(w) {
                WarmStart::Feasible => warmed = true,
                WarmStart::PrimalInfeasible => {
                    let before = self.iterations;
                    match self.dual_simplex() {
                        Ok(true) => warmed = true,
                        // An expired deadline is not a numerical wobble
                        // — falling back to a cold start would only run
                        // longer past the budget.
                        Err(e @ Error::DeadlineExceeded { .. }) => return Err(e),
                        // Gave up (dual-infeasible basis, stall, or a
                        // numerical wobble): pretend the warm attempt
                        // never happened and fall back to a cold start.
                        Ok(false) | Err(_) => {
                            self.iterations = before;
                            self.dual_iters = 0;
                            self.recovery_events.push("warm_fallback_cold".into());
                        }
                    }
                }
                WarmStart::Unusable => {
                    self.recovery_events.push("warm_fallback_cold".into());
                }
            }
        }
        if !warmed {
            self.cold_start();
            self.phase1()?;
        }
        self.run(Phase::Two)?;
        self.extract(p, opts)
    }

    /// Identity start basis: slack where a row has one, artificial
    /// otherwise. Both columns are `e_r`, so `B = I` and `x_B = b`.
    fn cold_start(&mut self) {
        self.in_basis.iter_mut().for_each(|b| *b = false);
        let mut aux_col = self.sf.num_structural;
        for i in 0..self.m {
            match self.sf.aux[i] {
                AuxKind::Slack => {
                    self.basis[i] = aux_col;
                    self.in_basis[aux_col] = true;
                    aux_col += 1;
                }
                AuxKind::Surplus => {
                    aux_col += 1;
                    self.basis[i] = self.ncols + i;
                }
                AuxKind::None => {
                    self.basis[i] = self.ncols + i;
                }
            }
        }
        self.xb.copy_from_slice(&self.sf.b);
        self.fact.reset_identity();
    }

    /// Adopt a previous basis when it factorizes. Primal-infeasible
    /// basic values are kept (not clamped) so a follow-up
    /// [`Revised::dual_simplex`] pass can repair them; only tiny
    /// negatives within `feas_eps` are snapped to zero. Returns
    /// [`WarmStart::Unusable`] (leaving `self` ready for a cold start)
    /// when the basis has the wrong shape or does not factorize.
    fn try_warm_start(&mut self, warm: &Basis) -> WarmStart {
        if warm.cols.len() != self.m || !warm.is_complete() {
            return WarmStart::Unusable;
        }
        if warm.cols.iter().any(|&c| c >= self.ncols) {
            return WarmStart::Unusable;
        }
        fill_basis_sparse(
            self.sf,
            self.ncols,
            self.m,
            &warm.cols,
            &mut self.trip_buf,
            &mut self.basis_mat,
        );
        if self.fact.refactorize(&self.basis_mat).is_err() {
            self.fact.reset_identity();
            return WarmStart::Unusable;
        }
        self.fact.ftran(&self.sf.b, &mut self.xb);
        let feasible = self.xb.iter().all(|&v| v >= -self.feas_eps);
        for v in self.xb.iter_mut() {
            if *v < 0.0 && *v > -self.feas_eps {
                *v = 0.0;
            }
        }
        self.basis.copy_from_slice(&warm.cols);
        self.in_basis.iter_mut().for_each(|x| *x = false);
        for &c in &warm.cols {
            self.in_basis[c] = true;
        }
        if feasible {
            WarmStart::Feasible
        } else {
            WarmStart::PrimalInfeasible
        }
    }

    /// Dual-simplex repair of a primal-infeasible but dual-feasible
    /// basis: repeatedly drive the most negative basic value out of the
    /// basis while keeping all reduced costs non-negative. Returns
    /// `Ok(true)` once `x_B ≥ 0` (phase 2 may then start from a
    /// primal- and dual-feasible basis), `Ok(false)` to request a cold
    /// fallback (dual-infeasible start, stall, or an unrepairable row —
    /// the cold phase 1 then gives the authoritative verdict).
    fn dual_simplex(&mut self) -> Result<bool> {
        self.pricing.reset(self.ncols);
        // Dual feasibility of the phase-2 costs at the warm basis.
        self.btran_costs(Phase::Two);
        for j in 0..self.ncols {
            if self.in_basis[j] {
                continue;
            }
            let d = self.cost_col(Phase::Two, j) - self.sf.a.col_dot(j, self.y.values());
            if d < -self.eps * 10.0 {
                return Ok(false);
            }
        }

        let budget = 400 + 8 * self.m;
        loop {
            // Leaving row: most negative basic value.
            let mut leave: Option<usize> = None;
            let mut most_neg = -self.feas_eps;
            for (i, &v) in self.xb.iter().enumerate() {
                if v < most_neg {
                    most_neg = v;
                    leave = Some(i);
                }
            }
            let Some(r) = leave else {
                // Primal feasible: snap residual noise and hand over.
                for v in self.xb.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
                return Ok(true);
            };
            if self.dual_iters >= budget {
                return Ok(false);
            }
            self.iterations += 1;
            self.dual_iters += 1;
            if self.iterations & 63 == 0 {
                self.budget.check(self.iterations, "dual_simplex")?;
            }

            // Pivot row rho = B^{-T} e_r (a hypersparse BTRAN) ...
            self.btran_unit(r);
            self.rho.copy_from_slice(self.y.values());
            // ... and current duals y = B^{-T} c_B for the ratio test.
            self.btran_costs(Phase::Two);

            // Entering column: among alpha_j = rho·A_j < 0, minimize
            // d_j / -alpha_j. Ties go to the lowest index under
            // Dantzig (deterministic legacy behavior); a weighted rule
            // instead prefers the candidate with the larger
            // alpha²/gamma — the dual steepest-edge tie-break, sharing
            // the primal weights.
            let uses_weights = self.pricing.uses_weights();
            let mut enter: Option<usize> = None;
            let mut best_ratio = f64::INFINITY;
            let mut best_score = 0.0;
            for j in 0..self.ncols {
                if self.in_basis[j] {
                    continue;
                }
                let alpha = self.sf.a.col_dot(j, &self.rho);
                self.alpha_r[j] = alpha;
                if alpha < -self.eps {
                    let d = (self.cost_col(Phase::Two, j)
                        - self.sf.a.col_dot(j, self.y.values()))
                    .max(0.0);
                    let ratio = d / -alpha;
                    let score = alpha * alpha / self.pricing.weight(j);
                    let better = if ratio < best_ratio - 1e-12 {
                        true
                    } else {
                        uses_weights && ratio < best_ratio + 1e-12 && score > best_score
                    };
                    if better {
                        best_ratio = best_ratio.min(ratio);
                        best_score = score;
                        enter = Some(j);
                    }
                }
            }
            let Some(q) = enter else {
                if self.fact.update_len() > 0 {
                    // Rule out update-file drift before giving up on
                    // the row.
                    self.refactorize()?;
                    continue;
                }
                // Row r certifies primal infeasibility, but let the
                // cold phase 1 deliver the authoritative verdict.
                return Ok(false);
            };

            self.ftran_col(q);
            if self.w.get(r) > -self.eps {
                // FTRAN disagrees with the BTRAN row (numerical drift).
                if self.fact.update_len() > 0 {
                    self.refactorize()?;
                    continue;
                }
                return Ok(false);
            }
            self.prepare_reference_ftran();
            let leaving = self.basis[r];
            self.pivot_dual(q, r)?;
            self.apply_weight_update(q, r, leaving);

            if self.fact.should_refactorize() {
                self.refactorize()?;
            }
        }
    }

    /// Rebuild the factorization from the current basis, drop the
    /// update file, and recompute `x_B` at full accuracy.
    fn refactorize(&mut self) -> Result<()> {
        fill_basis_sparse(
            self.sf,
            self.ncols,
            self.m,
            &self.basis,
            &mut self.trip_buf,
            &mut self.basis_mat,
        );
        self.fact
            .refactorize(&self.basis_mat)
            .map_err(|e| Error::Numerical(format!("basis refactorization failed: {e}")))?;
        self.refactorizations += 1;
        self.fact.ftran(&self.sf.b, &mut self.xb);
        for v in self.xb.iter_mut() {
            if *v < 0.0 && *v > -self.feas_eps {
                *v = 0.0;
            }
        }
        Ok(())
    }

    /// Hypersparse FTRAN of column `q`: scatter the CSC column into
    /// the work vector and solve in place — `self.w = B⁻¹ A_q`.
    fn ftran_col(&mut self, q: usize) {
        debug_assert!(q < self.ncols);
        self.w.clear();
        for (i, v) in self.sf.a.col(q) {
            self.w.set(i, v);
        }
        self.fact.ftran_sparse(&mut self.w);
        self.ftran_nnz_sum += self.w.nnz();
        self.ftran_count += 1;
    }

    /// Hypersparse BTRAN of the phase cost vector:
    /// `self.y = B⁻ᵀ c_B`. The basic cost vector is mostly zeros (only
    /// the makespan column and the phase-1 artificials carry cost), so
    /// the right-hand side is genuinely sparse.
    fn btran_costs(&mut self, phase: Phase) {
        self.y.clear();
        for r in 0..self.m {
            let c = self.cost_basic(phase, r);
            if c != 0.0 {
                self.y.set(r, c);
            }
        }
        self.fact.btran_sparse(&mut self.y);
        self.btran_nnz_sum += self.y.nnz();
        self.btran_count += 1;
    }

    /// Hypersparse BTRAN of a unit vector: `self.y = B⁻ᵀ e_r`.
    fn btran_unit(&mut self, r: usize) {
        self.y.clear();
        self.y.set(r, 1.0);
        self.fact.btran_sparse(&mut self.y);
        self.btran_nnz_sum += self.y.nnz();
        self.btran_count += 1;
    }

    #[inline]
    fn cost_col(&self, phase: Phase, j: usize) -> f64 {
        match phase {
            Phase::One => 0.0,
            Phase::Two => self.sf.c[j],
        }
    }

    #[inline]
    fn cost_basic(&self, phase: Phase, r: usize) -> f64 {
        let bv = self.basis[r];
        if bv >= self.ncols {
            match phase {
                Phase::One => 1.0,
                Phase::Two => 0.0,
            }
        } else {
            self.cost_col(phase, bv)
        }
    }

    fn objective(&self, phase: Phase) -> f64 {
        (0..self.m).map(|r| self.cost_basic(phase, r) * self.xb[r]).sum()
    }

    /// Primal pivot: column `q` enters at row `r`, using the FTRAN
    /// result in `self.w`. The step length clamps tiny negative basic
    /// values to zero (ratio-test convention).
    fn pivot(&mut self, q: usize, r: usize) -> Result<()> {
        let theta = self.xb[r].max(0.0) / self.w.get(r);
        self.pivot_at(q, r, theta)
    }

    /// Dual pivot: the leaving row's basic value is *negative* and the
    /// pivot element `w[r]` is negative too, so the unclamped step
    /// `x_B[r] / w[r]` is positive and the entering variable comes in
    /// at a non-negative value.
    fn pivot_dual(&mut self, q: usize, r: usize) -> Result<()> {
        let theta = self.xb[r] / self.w.get(r);
        self.pivot_at(q, r, theta)
    }

    /// Shared pivot body: column `q` enters at row `r` with step
    /// `theta`, using the FTRAN result in `self.w`. Updates `x_B` only
    /// at `w`'s nonzeros and the basis maps, then records the pivot
    /// with the factorization strategy; an update breakdown triggers
    /// an immediate refactorization from the (new) basis.
    fn pivot_at(&mut self, q: usize, r: usize, theta: f64) -> Result<()> {
        debug_assert!(self.w.get(r).abs() > 1e-14);
        if theta != 0.0 {
            // Stream the gathered (index, value) pairs contiguously
            // instead of chasing idx -> vals per entry.
            self.w.gather_into(&mut self.gidx, &mut self.gval);
            for (&i, &wi) in self.gidx.iter().zip(self.gval.iter()) {
                if i == r || wi == 0.0 {
                    continue;
                }
                let v = self.xb[i] - theta * wi;
                self.xb[i] = if v < 0.0 && v > -self.feas_eps { 0.0 } else { v };
            }
        }
        self.xb[r] = theta.max(0.0);
        let old = self.basis[r];
        if old < self.ncols {
            self.in_basis[old] = false;
        }
        self.basis[r] = q;
        self.in_basis[q] = true;
        if self.fact.update(r, &self.w).is_err() {
            // Numerical breakdown inside the update: rebuild from the
            // already-updated basis at full accuracy.
            self.recovery_events.push("early_refactorize".into());
            self.refactorize()?;
        }
        self.peak_update_len = self.peak_update_len.max(self.fact.update_len());
        Ok(())
    }

    /// Pre-pivot quantities a weighted pricing rule needs: the pivot
    /// row `alpha_r = e_rᵀB⁻¹A` (one BTRAN of `e_r` plus a column
    /// pass) and, for steepest edge, `A_j·v` with `v = B⁻ᵀw`.
    fn prepare_weight_update(&mut self, r: usize) {
        if !self.pricing.needs_pivot_row() {
            return;
        }
        self.btran_unit(r);
        self.rho.copy_from_slice(self.y.values());
        for j in 0..self.ncols {
            self.alpha_r[j] =
                if self.in_basis[j] { 0.0 } else { self.sf.a.col_dot(j, &self.rho) };
        }
        self.prepare_reference_ftran();
    }

    /// The steepest-edge half of [`Revised::prepare_weight_update`]
    /// (also used by the dual loop, which has `alpha_r` already).
    fn prepare_reference_ftran(&mut self) {
        if !self.pricing.needs_reference_ftran() {
            return;
        }
        self.vref.copy_from(&self.w);
        self.fact.btran_sparse(&mut self.vref);
        self.btran_nnz_sum += self.vref.nnz();
        self.btran_count += 1;
        for j in 0..self.ncols {
            self.adv[j] =
                if self.in_basis[j] { 0.0 } else { self.sf.a.col_dot(j, self.vref.values()) };
        }
    }

    /// Hand the pivot to the pricing rule (post-pivot: the basis maps
    /// already reflect `q` basic / `leaving` nonbasic).
    fn apply_weight_update(&mut self, q: usize, r: usize, leaving: usize) {
        if !self.pricing.needs_pivot_row() {
            return;
        }
        let alpha_rq = self.w.get(r);
        if alpha_rq.abs() < 1e-12 {
            return;
        }
        let w_norm2 = self.w.norm2_sq();
        self.pricing.update(&PivotContext {
            q,
            r,
            leaving: if leaving < self.ncols { Some(leaving) } else { None },
            alpha_rq,
            w_norm2,
            alpha_r: &self.alpha_r,
            a_dot_v: &self.adv,
            in_basis: &self.in_basis,
        });
    }

    /// Simplex iterations for one phase's cost vector. Artificial
    /// columns never (re-)enter; on an optimality or unboundedness
    /// verdict reached through a non-empty update file, the basis is
    /// refactorized first and the verdict re-checked at full accuracy.
    fn run(&mut self, phase: Phase) -> Result<()> {
        let mut stall = 0usize;
        let mut bland = false;
        let mut last_obj = f64::INFINITY;
        self.pricing.reset(self.ncols);

        loop {
            self.iterations += 1;
            if self.iterations > self.max_iters {
                return Err(Error::IterationLimit { iterations: self.iterations });
            }
            if self.iterations & 63 == 0 {
                self.budget.check(self.iterations, "simplex")?;
            }

            // BTRAN for the pricing vector y = B^{-T} c_B.
            self.btran_costs(phase);

            // Pricing: d_j = c_j - y·A_j over nonbasic columns. A
            // partial rule prices its candidate window first; a miss
            // falls through to the full pass, which doubles as the
            // window refresh — optimality is only declared from a
            // full pass.
            let mut enter: Option<usize> = None;
            if bland {
                for j in 0..self.ncols {
                    if self.in_basis[j] {
                        continue;
                    }
                    let d = self.cost_col(phase, j) - self.sf.a.col_dot(j, self.y.values());
                    if d < -self.eps {
                        enter = Some(j);
                        break;
                    }
                }
            } else {
                if self.pricing.gather_candidates(&mut self.cand_buf)
                    && !self.cand_buf.is_empty()
                {
                    for &j in &self.cand_buf {
                        self.d[j] = if self.in_basis[j] {
                            0.0
                        } else {
                            self.cost_col(phase, j)
                                - self.sf.a.col_dot(j, self.y.values())
                        };
                    }
                    enter =
                        self.pricing.select_from_candidates(&self.d, &self.in_basis, self.eps);
                }
                if enter.is_none() {
                    for j in 0..self.ncols {
                        self.d[j] = if self.in_basis[j] {
                            0.0
                        } else {
                            self.cost_col(phase, j)
                                - self.sf.a.col_dot(j, self.y.values())
                        };
                    }
                    enter = self.pricing.select_entering(&self.d, &self.in_basis, self.eps);
                }
            }
            let Some(q) = enter else {
                if self.fact.update_len() > 0 {
                    // Rule out update-file drift before declaring
                    // optimality.
                    self.refactorize()?;
                    continue;
                }
                return Ok(());
            };

            // FTRAN: w = B^{-1} A_q (hypersparse).
            self.ftran_col(q);

            // Ratio test over w's nonzeros only, streamed through the
            // gathered flat arrays.
            self.w.gather_into(&mut self.gidx, &mut self.gval);
            let mut leave: Option<usize> = None;
            let mut best_ratio = f64::INFINITY;
            for (&i, &wi) in self.gidx.iter().zip(self.gval.iter()) {
                if wi > self.eps {
                    let ratio = self.xb[i].max(0.0) / wi;
                    let better = if bland {
                        ratio < best_ratio - self.eps
                            || (ratio < best_ratio + self.eps
                                && leave.map_or(true, |l| self.basis[i] < self.basis[l]))
                    } else {
                        ratio < best_ratio
                    };
                    if better {
                        best_ratio = ratio;
                        leave = Some(i);
                    }
                }
            }
            let Some(r) = leave else {
                if self.fact.update_len() > 0 {
                    self.refactorize()?;
                    continue;
                }
                return Err(Error::Unbounded(format!("column {q} has no positive entries")));
            };

            // Once Bland's rule is permanent the weights are never read
            // again — skip their (BTRAN + column-pass) maintenance.
            if !bland {
                self.prepare_weight_update(r);
            }
            let leaving = self.basis[r];
            self.pivot(q, r)?;
            if !bland {
                self.apply_weight_update(q, r, leaving);
            }

            // Degeneracy detection -> switch to Bland permanently.
            let obj = self.objective(phase);
            if obj < last_obj - 1e-12 {
                last_obj = obj;
                stall = 0;
            } else {
                stall += 1;
                if stall > self.stall_limit && !bland {
                    bland = true;
                    self.recovery_events.push("bland_engaged".into());
                }
            }

            if self.fact.should_refactorize() {
                self.refactorize()?;
            }
        }
    }

    fn phase1(&mut self) -> Result<()> {
        if !self.basis.iter().any(|&b| b >= self.ncols) {
            return Ok(());
        }
        let before = self.iterations;
        self.run(Phase::One)?;
        let obj = self.objective(Phase::One);
        if obj > self.feas_eps {
            return Err(Error::Infeasible(format!("phase-1 objective {obj:.3e} > 0")));
        }
        self.drive_out_artificials()?;
        self.phase1_iters += self.iterations - before;
        Ok(())
    }

    /// Pivot any artificial still basic (at value ~0) out on a
    /// non-artificial column. Rows where no such column exists are
    /// redundant: their artificial stays basic at zero and is inert —
    /// `e_rᵀ B⁻¹ A_j = 0` for every real column, so no later pivot can
    /// move it.
    fn drive_out_artificials(&mut self) -> Result<()> {
        if self.basis.iter().all(|&b| b < self.ncols) {
            return Ok(());
        }
        // Work at full accuracy: the update file is about to be probed
        // row-by-row.
        self.refactorize()?;
        for r in 0..self.m {
            if self.basis[r] < self.ncols {
                continue;
            }
            // rho = B^{-T} e_r, then alpha_j = rho·A_j per column.
            self.btran_unit(r);
            let mut found = None;
            for j in 0..self.ncols {
                if self.in_basis[j] {
                    continue;
                }
                if self.sf.a.col_dot(j, self.y.values()).abs() > self.eps {
                    found = Some(j);
                    break;
                }
            }
            if let Some(q) = found {
                self.ftran_col(q);
                if self.w.get(r).abs() > self.eps {
                    // Degenerate pivot (theta ~ 0): swaps the basis
                    // without moving the point.
                    self.pivot(q, r)?;
                    if self.fact.should_refactorize() {
                        self.refactorize()?;
                    }
                }
            }
        }
        Ok(())
    }

    fn extract(&mut self, p: &LpProblem, opts: &SimplexOptions) -> Result<LpSolution> {
        // Residual artificial mass means numerical trouble.
        let art_mass: f64 = (0..self.m)
            .filter(|&r| self.basis[r] >= self.ncols)
            .map(|r| self.xb[r].abs())
            .sum();
        if art_mass > self.feas_eps * 10.0 {
            return Err(Error::Numerical(format!("artificial mass {art_mass:.3e} after phase 2")));
        }

        let mut x_full = vec![0.0; self.ncols];
        for r in 0..self.m {
            if self.basis[r] < self.ncols {
                x_full[self.basis[r]] = self.xb[r];
            }
        }
        let x: Vec<f64> = x_full[..p.num_vars()]
            .iter()
            .map(|&v| crate::util::float::snap_nonneg(v, 1e-9))
            .collect();
        let objective = p.objective_at(&x);

        let duals = if opts.compute_duals { Some(self.compute_duals()) } else { None };

        let basis = Basis {
            cols: self
                .basis
                .iter()
                .map(|&b| if b < self.ncols { b } else { usize::MAX })
                .collect(),
        };

        Ok(LpSolution {
            x,
            objective,
            iterations: self.iterations,
            phase1_iterations: self.phase1_iters,
            dual_iterations: self.dual_iters,
            factorization: opts.factorization,
            pricing: opts.pricing,
            refactorizations: self.refactorizations,
            peak_update_len: self.peak_update_len,
            weight_resets: self.pricing.weight_resets() - self.weight_resets0,
            candidate_hits: self.pricing.candidate_hits() - self.candidate_hits0,
            candidate_refreshes: self.pricing.candidate_refreshes()
                - self.candidate_refreshes0,
            avg_ftran_nnz: if self.ftran_count > 0 {
                self.ftran_nnz_sum as f64 / self.ftran_count as f64
            } else {
                0.0
            },
            avg_btran_nnz: if self.btran_count > 0 {
                self.btran_nnz_sum as f64 / self.btran_count as f64
            } else {
                0.0
            },
            dfs_solves: self.fact.dfs_solves() - self.dfs0,
            scan_solves: self.fact.scan_solves() - self.scan0,
            recovery_events: std::mem::take(&mut self.recovery_events),
            duals,
            basis: Some(basis),
        })
    }

    /// Duals `y = B⁻ᵀ c_B` (phase-2 costs), with standardization row
    /// flips undone.
    fn compute_duals(&mut self) -> Vec<f64> {
        self.btran_costs(Phase::Two);
        self.y
            .values()
            .iter()
            .zip(self.sf.flipped.iter())
            .map(|(&yi, &f)| if f { -yi } else { yi })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::factorization::Factorization;
    use crate::lp::pricing::Pricing;
    use crate::lp::problem::{Cmp, LpProblem};
    use crate::lp::simplex::{solve_warm, SolverBackend};

    fn opts() -> SimplexOptions {
        SimplexOptions::default() // RevisedSparse is the default backend
    }

    fn assert_close(a: f64, b: f64) {
        assert!((a - b).abs() < 1e-7, "{a} vs {b}");
    }

    fn textbook() -> LpProblem {
        // max 3x + 5y st x<=4, 2y<=12, 3x+2y<=18 -> x=2, y=6, obj=36
        let mut p = LpProblem::new(2);
        p.set_objective(&[-3.0, -5.0]);
        p.add_constraint(&[(0, 1.0)], Cmp::Le, 4.0);
        p.add_constraint(&[(1, 2.0)], Cmp::Le, 12.0);
        p.add_constraint(&[(0, 3.0), (1, 2.0)], Cmp::Le, 18.0);
        p
    }

    /// Every factorization × pricing combination — including partial
    /// pricing — (used by several tests below to sweep the strategy
    /// grid).
    fn combos() -> Vec<SimplexOptions> {
        let mut out = Vec::new();
        for f in [
            Factorization::ProductFormEta,
            Factorization::ForrestTomlin,
            Factorization::Markowitz,
            Factorization::BartelsGolub,
        ] {
            for pr in
                [Pricing::Dantzig, Pricing::Devex, Pricing::SteepestEdge, Pricing::Partial]
            {
                out.push(SimplexOptions { factorization: f, pricing: pr, ..opts() });
            }
        }
        out
    }

    #[test]
    fn textbook_optimum_and_basis() {
        let p = textbook();
        let s = solve_revised(&p, &opts(), None).unwrap();
        assert_close(s.objective, -36.0);
        assert_close(s.x[0], 2.0);
        assert_close(s.x[1], 6.0);
        let b = s.basis.as_ref().unwrap();
        assert!(b.is_complete());
        assert_eq!(b.cols.len(), 3);
        assert!(s.avg_ftran_nnz > 0.0, "ftran nnz diagnostic should be populated");
        assert!(s.avg_btran_nnz > 0.0, "btran nnz diagnostic should be populated");
        assert!(s.dfs_solves + s.scan_solves > 0, "solve-mode counters should tick");
    }

    #[test]
    fn every_strategy_combo_solves_textbook() {
        let p = textbook();
        for o in combos() {
            let s = solve_revised(&p, &o, None).unwrap();
            assert_close(s.objective, -36.0);
            assert_eq!(s.factorization, o.factorization);
            assert_eq!(s.pricing, o.pricing);
        }
    }

    #[test]
    fn warm_start_reaches_same_optimum_faster() {
        let p = textbook();
        let cold = solve_revised(&p, &opts(), None).unwrap();
        // Same structure, perturbed rhs.
        let mut p2 = LpProblem::new(2);
        p2.set_objective(&[-3.0, -5.0]);
        p2.add_constraint(&[(0, 1.0)], Cmp::Le, 4.4);
        p2.add_constraint(&[(1, 2.0)], Cmp::Le, 13.0);
        p2.add_constraint(&[(0, 3.0), (1, 2.0)], Cmp::Le, 19.0);
        let cold2 = solve_revised(&p2, &opts(), None).unwrap();
        let warm2 = solve_revised(&p2, &opts(), cold.basis.as_ref()).unwrap();
        assert_close(warm2.objective, cold2.objective);
        assert!(
            warm2.iterations <= cold2.iterations,
            "warm {} > cold {}",
            warm2.iterations,
            cold2.iterations
        );
    }

    #[test]
    fn scratch_reuse_is_bit_identical() {
        // The scratch pool must be invisible to results: repeated
        // solves through one scratch reproduce the fresh-scratch
        // solution bit for bit.
        let p = textbook();
        let mut shared = SolverScratch::new();
        for o in combos() {
            let fresh = solve_revised(&p, &o, None).unwrap();
            for trial in 0..3 {
                let pooled = solve_revised_scratch(&p, &o, None, &mut shared).unwrap();
                assert_eq!(
                    pooled.x, fresh.x,
                    "{:?}/{:?} trial {trial}: pooled x diverged",
                    o.factorization, o.pricing
                );
                assert!(
                    pooled.objective == fresh.objective,
                    "{:?}/{:?} trial {trial}: pooled objective diverged",
                    o.factorization,
                    o.pricing
                );
                assert_eq!(pooled.iterations, fresh.iterations);
            }
        }
    }

    #[test]
    fn dual_simplex_repairs_primal_infeasible_warm_basis() {
        // Optimal basis of the textbook problem: x, y basic with rows 2
        // and 3 binding, slack of row 1 basic. Shrinking b3 from 18 to
        // 10 makes that basis primal-infeasible (solving B x_B = b
        // forces x < 0) while the reduced costs — which do not depend
        // on b — stay dual feasible, so the warm re-solve must complete
        // through the dual simplex without a phase-1 restart. Checked
        // across the full strategy grid: the repair pass shares both
        // layers.
        let p = textbook();
        let mut p2 = LpProblem::new(2);
        p2.set_objective(&[-3.0, -5.0]);
        p2.add_constraint(&[(0, 1.0)], Cmp::Le, 4.0);
        p2.add_constraint(&[(1, 2.0)], Cmp::Le, 12.0);
        p2.add_constraint(&[(0, 3.0), (1, 2.0)], Cmp::Le, 10.0);
        for o in combos() {
            let cold = solve_revised(&p, &o, None).unwrap();
            let cold2 = solve_revised(&p2, &o, None).unwrap();
            let warm2 = solve_revised(&p2, &o, cold.basis.as_ref()).unwrap();
            assert_close(warm2.objective, cold2.objective);
            assert_eq!(
                warm2.phase1_iterations, 0,
                "{:?}/{:?}: dual repair must not restart phase 1",
                o.factorization, o.pricing
            );
            assert!(
                warm2.dual_iterations > 0,
                "{:?}/{:?}: expected the dual-simplex path to run",
                o.factorization,
                o.pricing
            );
            assert!(p2.check_feasible(&warm2.x, 1e-7).is_none());
        }
    }

    #[test]
    fn dual_simplex_falls_back_cold_on_infeasible_perturbation() {
        // min x st x <= b: basis {x}? Construct a perturbation that
        // makes the problem itself infeasible; the warm path must agree
        // with the cold path's verdict.
        let mut p = LpProblem::new(1);
        p.set_objective(&[-1.0]);
        p.add_constraint(&[(0, 1.0)], Cmp::Le, 5.0);
        p.add_constraint(&[(0, 1.0)], Cmp::Ge, 1.0);
        let base = solve_revised(&p, &opts(), None).unwrap();
        let mut bad = LpProblem::new(1);
        bad.set_objective(&[-1.0]);
        bad.add_constraint(&[(0, 1.0)], Cmp::Le, 5.0);
        bad.add_constraint(&[(0, 1.0)], Cmp::Ge, 7.0);
        match solve_revised(&bad, &opts(), base.basis.as_ref()) {
            Err(Error::Infeasible(_)) => {}
            other => panic!("expected infeasible, got {other:?}"),
        }
    }

    #[test]
    fn cold_solves_report_phase1_iterations() {
        // An equality row forces an artificial, so the cold path pays
        // phase-1 pivots that a warm or dual-repaired start skips.
        let mut p = LpProblem::new(2);
        p.set_objective(&[1.0, 2.0]);
        p.add_constraint(&[(0, 1.0), (1, 1.0)], Cmp::Eq, 4.0);
        p.add_constraint(&[(0, 1.0)], Cmp::Ge, 1.0);
        let s = solve_revised(&p, &opts(), None).unwrap();
        assert!(s.phase1_iterations > 0, "equality rows require phase-1 work");
        assert_eq!(s.dual_iterations, 0);
        let warm = solve_revised(&p, &opts(), s.basis.as_ref()).unwrap();
        assert_eq!(warm.phase1_iterations, 0);
        assert_close(warm.objective, s.objective);
    }

    #[test]
    fn warm_start_with_garbage_basis_falls_back() {
        let p = textbook();
        let junk = Basis { cols: vec![0, 0, 0] }; // singular
        let s = solve_revised(&p, &opts(), Some(&junk)).unwrap();
        assert_close(s.objective, -36.0);
        assert!(
            s.recovery_events.iter().any(|e| e == "warm_fallback_cold"),
            "singular warm basis must record the cold fallback: {:?}",
            s.recovery_events
        );
        let wrong_len = Basis { cols: vec![0] };
        let s = solve_revised(&p, &opts(), Some(&wrong_len)).unwrap();
        assert_close(s.objective, -36.0);
        assert!(s.recovery_events.iter().any(|e| e == "warm_fallback_cold"));
    }

    #[test]
    fn deadline_budget_stops_the_primal_loop() {
        use crate::lp::recovery::SolveBudget;
        // An already-expired budget must surface as DeadlineExceeded
        // from the first amortized check, not run to optimality.
        let p = textbook();
        let o = SimplexOptions {
            budget: SolveBudget::from_timeout_ms(Some(0)),
            ..opts()
        };
        match solve_revised(&p, &o, None) {
            // Tiny solves can finish before iteration 64 (the first
            // amortized check): both outcomes are legal, but a bounded
            // budget must never panic.
            Ok(s) => assert_close(s.objective, -36.0),
            Err(Error::DeadlineExceeded { phase, .. }) => {
                assert!(phase == "simplex" || phase == "dual_simplex");
            }
            other => panic!("unexpected outcome {other:?}"),
        }
    }

    #[test]
    fn clean_cold_solves_record_no_events() {
        let p = textbook();
        let s = solve_revised(&p, &opts(), None).unwrap();
        assert!(s.recovery_events.is_empty(), "events: {:?}", s.recovery_events);
    }

    #[test]
    fn infeasible_detected() {
        let mut p = LpProblem::new(1);
        p.add_constraint(&[(0, 1.0)], Cmp::Le, 1.0);
        p.add_constraint(&[(0, 1.0)], Cmp::Ge, 2.0);
        for o in combos() {
            match solve_revised(&p, &o, None) {
                Err(Error::Infeasible(_)) => {}
                other => panic!(
                    "{:?}/{:?}: expected infeasible, got {other:?}",
                    o.factorization, o.pricing
                ),
            }
        }
    }

    #[test]
    fn unbounded_detected() {
        let mut p = LpProblem::new(1);
        p.set_objective(&[-1.0]);
        p.add_constraint(&[(0, 1.0)], Cmp::Ge, 0.0);
        for o in combos() {
            match solve_revised(&p, &o, None) {
                Err(Error::Unbounded(_)) => {}
                other => panic!(
                    "{:?}/{:?}: expected unbounded, got {other:?}",
                    o.factorization, o.pricing
                ),
            }
        }
    }

    #[test]
    fn degenerate_terminates() {
        let mut p = LpProblem::new(2);
        p.set_objective(&[-1.0, -1.0]);
        p.add_constraint(&[(0, 1.0), (1, 1.0)], Cmp::Le, 1.0);
        p.add_constraint(&[(0, 1.0)], Cmp::Le, 1.0);
        p.add_constraint(&[(1, 1.0)], Cmp::Le, 1.0);
        p.add_constraint(&[(0, 1.0), (1, -1.0)], Cmp::Le, 0.0);
        p.add_constraint(&[(0, -1.0), (1, 1.0)], Cmp::Le, 0.0);
        for o in combos() {
            let s = solve_revised(&p, &o, None).unwrap();
            assert_close(s.objective, -1.0);
        }
    }

    #[test]
    fn redundant_equality_rows() {
        let mut p = LpProblem::new(2);
        p.set_objective(&[-1.0, 0.0]);
        p.add_constraint(&[(0, 1.0), (1, 1.0)], Cmp::Eq, 1.0);
        p.add_constraint(&[(0, 1.0), (1, 1.0)], Cmp::Eq, 1.0);
        let s = solve_revised(&p, &opts(), None).unwrap();
        assert_close(s.x[0], 1.0);
    }

    #[test]
    fn duals_satisfy_strong_duality() {
        let p = textbook();
        let s = solve_revised(&p, &opts(), None).unwrap();
        let y = s.duals.as_ref().unwrap();
        let by = 4.0 * y[0] + 12.0 * y[1] + 18.0 * y[2];
        assert_close(by, s.objective);
    }

    #[test]
    fn agrees_with_dense_backend_on_random_lps() {
        use crate::util::rng::{Pcg32, Rng};
        let dense = SimplexOptions {
            backend: SolverBackend::DenseTableau,
            ..SimplexOptions::default()
        };
        let mut rng = Pcg32::new(4242);
        for trial in 0..40 {
            let n = rng.range_usize(2, 7);
            let m = rng.range_usize(1, 6);
            let mut p = LpProblem::new(n);
            let c: Vec<f64> = (0..n).map(|_| rng.range_f64(0.1, 2.0)).collect();
            p.set_objective(&c);
            for k in 0..m {
                let coeffs: Vec<(usize, f64)> =
                    (0..n).map(|v| (v, rng.range_f64(0.1, 1.0))).collect();
                let cmp = if k % 3 == 0 { Cmp::Eq } else { Cmp::Ge };
                p.add_constraint(&coeffs, cmp, rng.range_f64(0.5, 3.0));
            }
            let a = solve_revised(&p, &opts(), None);
            let b = solve_warm(&p, &dense, None);
            match (a, b) {
                (Ok(sa), Ok(sb)) => {
                    assert!(
                        (sa.objective - sb.objective).abs()
                            < 1e-6 * (1.0 + sb.objective.abs()),
                        "trial {trial}: revised {} vs dense {}",
                        sa.objective,
                        sb.objective
                    );
                    assert!(p.check_feasible(&sa.x, 1e-6).is_none(), "trial {trial}");
                }
                (Err(_), Err(_)) => {}
                (a, b) => panic!("trial {trial}: backends disagree: {a:?} vs {b:?}"),
            }
        }
    }
}
