//! Basis-factorization strategies for the revised simplex.
//!
//! The revised method never forms `B⁻¹`; it keeps a factorization of
//! the basis matrix `B` and answers two queries per iteration — FTRAN
//! (`B⁻¹v`) and BTRAN (`B⁻ᵀv`) — plus a rank-one *update* per pivot
//! (column `q` replaces the column basic in row `r`). How that update
//! is represented is a classic engineering trade-off, so it is a
//! strategy layer ([`BasisFactorization`]) with two implementations:
//!
//! - [`ProductFormEta`] — a sparse LU of the last refactorization plus
//!   a *product-form eta file* (one sparse column per pivot, stored in
//!   a shared arena so warm re-solves allocate nothing), with a full
//!   refactorization every 48 pivots to bound drift. Cheap per update
//!   (O(nnz(w))), but the eta file both grows and loses accuracy
//!   quickly, forcing the short refactorization cadence.
//! - [`ForrestTomlin`] — Forrest–Tomlin LU updating: the
//!   upper-triangular factor `U` is maintained *explicitly* in sparse
//!   row + column form. A pivot replaces one column of `U` with the
//!   spike `L⁻¹A_q`, cyclically permutes the spiked index to the
//!   border, and eliminates the lone off-triangular row with
//!   multipliers absorbed into the `L⁻¹` operator chain. The cyclic
//!   permutation is *never materialized*: entries stay in their
//!   physical slots and a logical↔physical position map drives the
//!   triangular sweeps, so an update costs O(nnz) bookkeeping instead
//!   of the old dense implementation's O(m²) row/column rotation, and
//!   the factor memory drops from two dense `m × m` buffers to
//!   O(nnz(L) + nnz(U)). `U` stays genuinely triangular and accurate
//!   for hundreds of pivots, making full refactorizations rare.
//!
//! Both strategies expose **hypersparse** kernels
//! ([`BasisFactorization::ftran_sparse`] /
//! [`BasisFactorization::btran_sparse`]) operating on
//! [`SparseVector`] work arrays: the triangular sweeps are
//! column-oriented and skip every column whose intermediate value is
//! zero, so an FTRAN of a 3-nonzero DLT column touches a handful of
//! entries instead of O(m²) — the standard revised-simplex speedup for
//! the paper's timing-chain LPs. The dense `ftran`/`btran` entry
//! points remain as adapters (and, for [`ProductFormEta`], as an
//! independent dense implementation the sparse kernels are
//! property-tested against).
//!
//! Both implementations are driven identically by the primal
//! phase-1/phase-2 loops, the dual-simplex repair pass and the
//! artificial-eviction sweep in [`super::revised`]; the driver decides
//! *when* to refactorize (periodically via [`should_refactorize`],
//! and whenever an optimality/unboundedness verdict must be re-checked
//! at full accuracy), the strategy decides *how*.
//!
//! [`should_refactorize`]: BasisFactorization::should_refactorize

use crate::error::{Error, Result};
use crate::linalg::{LuFactors, SparseMatrix, SparseVector};

/// Refactorize the product-form eta file after this many updates.
const PFE_REFACTOR_EVERY: usize = 48;
/// Refactorize the Forrest–Tomlin factors after this many updates (the
/// explicit `U` stays accurate far longer than an eta file).
const FT_REFACTOR_EVERY: usize = 192;
/// Safety valve: refactorize when the absorbed `L⁻¹` operator chain
/// grows past this many entries per basis row.
const FT_OPS_PER_ROW: usize = 16;

/// Which basis-factorization strategy maintains `B⁻¹` (selected via
/// [`super::SimplexOptions::factorization`], threaded end-to-end from
/// the `dlt::api` wire options and the CLI flags).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Factorization {
    /// Sparse LU + product-form eta file (extracted legacy behavior).
    #[default]
    ProductFormEta,
    /// Forrest–Tomlin LU updating (sparse `U`, rare refactorization).
    ForrestTomlin,
}

impl Factorization {
    /// Stable wire name (`product_form_eta` / `forrest_tomlin`).
    pub fn as_str(self) -> &'static str {
        match self {
            Factorization::ProductFormEta => "product_form_eta",
            Factorization::ForrestTomlin => "forrest_tomlin",
        }
    }

    /// Parse a wire name; `None` for anything unknown.
    pub fn parse(s: &str) -> Option<Factorization> {
        match s {
            "product_form_eta" => Some(Factorization::ProductFormEta),
            "forrest_tomlin" => Some(Factorization::ForrestTomlin),
            _ => None,
        }
    }

    /// Instantiate the strategy for an `m`-row basis.
    pub(crate) fn build(self, m: usize) -> Box<dyn BasisFactorization> {
        match self {
            Factorization::ProductFormEta => Box::new(ProductFormEta::new(m)),
            Factorization::ForrestTomlin => Box::new(ForrestTomlin::new(m)),
        }
    }
}

/// One basis-factorization strategy. All vectors are length `m` (the
/// basis dimension) and indexed by constraint row / basis position.
pub trait BasisFactorization {
    /// Strategy name (diagnostics).
    fn name(&self) -> &'static str;

    /// Reset to the identity basis (`B = I`, the slack/artificial cold
    /// start).
    fn reset_identity(&mut self);

    /// Replace the factorization with a fresh one of `b` (CSC — the
    /// basis columns are scattered straight from the constraint
    /// matrix, never densified). Errors when `b` is (numerically)
    /// singular; the strategy is left ready for
    /// [`BasisFactorization::reset_identity`].
    fn refactorize(&mut self, b: &SparseMatrix) -> Result<()>;

    /// FTRAN: `out = B⁻¹ v` (dense adapter over the sparse kernel).
    fn ftran(&mut self, v: &[f64], out: &mut [f64]);

    /// BTRAN: `out = B⁻ᵀ v` (dense adapter over the sparse kernel).
    fn btran(&mut self, v: &[f64], out: &mut [f64]);

    /// Hypersparse FTRAN, in place: `v ← B⁻¹ v`. Work is proportional
    /// to the nonzeros actually created, not the basis dimension.
    fn ftran_sparse(&mut self, v: &mut SparseVector);

    /// Hypersparse BTRAN, in place: `v ← B⁻ᵀ v`.
    fn btran_sparse(&mut self, v: &mut SparseVector);

    /// Record a pivot: the entering column replaces the column basic in
    /// row `r`, where `w = B⁻¹ A_q` is the (sparse) result of the FTRAN
    /// the driver just performed for that column. An error signals
    /// numerical breakdown — the caller must refactorize from the (new)
    /// basis before the factorization is used again.
    fn update(&mut self, r: usize, w: &SparseVector) -> Result<()>;

    /// Updates recorded since the last (re)factorization (eta count,
    /// or Forrest–Tomlin spike count).
    fn update_len(&self) -> usize;

    /// True when the update file is long enough that the driver should
    /// refactorize before the next pivot.
    fn should_refactorize(&self) -> bool;

    /// Entries currently stored across the factors and the update file
    /// — the sparse-memory diagnostic (a dense `L`/`U` pair would put
    /// this at `2m²` regardless of basis sparsity).
    fn storage_nnz(&self) -> usize;
}

/// One product-form eta head: the pivot column `w = B_prev⁻¹ A_q`
/// recorded at pivot row `r`; its off-`r` entries live in the shared
/// arena at `pool[start..end]` (no per-pivot allocation).
#[derive(Debug, Clone, Copy)]
struct EtaHead {
    r: usize,
    wr: f64,
    start: usize,
    end: usize,
}

/// Sparse LU of the last refactorization plus a product-form eta file —
/// the behavior `lp/revised.rs` hardwired before this layer existed.
pub struct ProductFormEta {
    m: usize,
    lu: LuFactors,
    etas: Vec<EtaHead>,
    /// Shared entry arena for all etas (reset with the file, so warm
    /// re-solves reuse its capacity).
    pool: Vec<(usize, f64)>,
    // Dense BTRAN scratch (eta application happens before the LU
    // transpose solve, which itself needs a scratch vector).
    u: Vec<f64>,
    t: Vec<f64>,
    /// Sparse-kernel scratch.
    sv: SparseVector,
}

impl ProductFormEta {
    /// Identity-basis start.
    pub fn new(m: usize) -> ProductFormEta {
        ProductFormEta {
            m,
            lu: LuFactors::identity(m),
            etas: Vec::new(),
            pool: Vec::new(),
            u: vec![0.0; m],
            t: vec![0.0; m],
            sv: SparseVector::with_dim(m),
        }
    }
}

impl BasisFactorization for ProductFormEta {
    fn name(&self) -> &'static str {
        "product_form_eta"
    }

    fn reset_identity(&mut self) {
        self.lu.reset_identity(self.m);
        self.etas.clear();
        self.pool.clear();
    }

    fn refactorize(&mut self, b: &SparseMatrix) -> Result<()> {
        self.lu.refactor_csc(b)?;
        self.etas.clear();
        self.pool.clear();
        Ok(())
    }

    // The dense entry points keep the original dense implementation —
    // an independent oracle the sparse kernels are tested against.
    fn ftran(&mut self, v: &[f64], out: &mut [f64]) {
        self.lu.solve_into(v, out);
        for &EtaHead { r, wr, start, end } in &self.etas {
            let ur = out[r] / wr;
            if ur != 0.0 {
                for &(i, wi) in &self.pool[start..end] {
                    out[i] -= wi * ur;
                }
            }
            out[r] = ur;
        }
    }

    fn btran(&mut self, v: &[f64], out: &mut [f64]) {
        self.u.copy_from_slice(v);
        for &EtaHead { r, wr, start, end } in self.etas.iter().rev() {
            let mut acc = self.u[r];
            for &(i, wi) in &self.pool[start..end] {
                acc -= wi * self.u[i];
            }
            self.u[r] = acc / wr;
        }
        self.lu.solve_transpose_into(&self.u, &mut self.t, out);
    }

    fn ftran_sparse(&mut self, v: &mut SparseVector) {
        self.lu.solve_sparse(v, &mut self.sv);
        // Eta passes exploit RHS sparsity: a pivot row the vector never
        // touches is skipped without reading its entries.
        for &EtaHead { r, wr, start, end } in &self.etas {
            let ur = v.get(r) / wr;
            if ur != 0.0 {
                for &(i, wi) in &self.pool[start..end] {
                    v.add(i, -wi * ur);
                }
                v.set(r, ur);
            }
        }
    }

    fn btran_sparse(&mut self, v: &mut SparseVector) {
        for &EtaHead { r, wr, start, end } in self.etas.iter().rev() {
            let mut acc = v.get(r);
            for &(i, wi) in &self.pool[start..end] {
                acc -= wi * v.get(i);
            }
            if acc != 0.0 || v.get(r) != 0.0 {
                v.set(r, acc / wr);
            }
        }
        self.lu.solve_transpose_sparse(v, &mut self.sv);
    }

    fn update(&mut self, r: usize, w: &SparseVector) -> Result<()> {
        let wr = w.get(r);
        if wr.abs() < 1e-13 {
            return Err(Error::Numerical(format!(
                "product-form eta: pivot element {wr:.3e} too small in row {r}"
            )));
        }
        let start = self.pool.len();
        for k in 0..w.nnz() {
            let i = w.index_at(k);
            if i == r {
                continue;
            }
            let wi = w.get(i);
            if wi.abs() > 1e-12 {
                self.pool.push((i, wi));
            }
        }
        self.etas.push(EtaHead { r, wr, start, end: self.pool.len() });
        Ok(())
    }

    fn update_len(&self) -> usize {
        self.etas.len()
    }

    fn should_refactorize(&self) -> bool {
        self.etas.len() >= PFE_REFACTOR_EVERY
    }

    fn storage_nnz(&self) -> usize {
        self.lu.nnz() + self.pool.len() + self.etas.len()
    }
}

/// One row elimination absorbed into the `L⁻¹` chain by a
/// Forrest–Tomlin update (physical slot indices):
/// `z[row] -= mult * z[col]`.
#[derive(Debug, Clone, Copy)]
struct Elim {
    row: usize,
    col: usize,
    mult: f64,
}

/// Forrest–Tomlin LU updating over a sparse, explicitly maintained
/// `U`.
///
/// Invariant: `B = L' · U` where `L'⁻¹` is the composition
/// `ops ∘ L₀⁻¹ ∘ P` (initial PLU row permutation and lower factor,
/// then the recorded eliminations in order, all in *physical slot*
/// space), and `U` is upper triangular in *logical* index space. The
/// bordered cyclic permutation of the textbook algorithm is carried by
/// the `pos`/`lpos` maps instead of moving data: physical slot `r`
/// (row *and* column of the replaced basis position) simply becomes
/// logical position `m−1`, which is what keeps updates O(nnz).
pub struct ForrestTomlin {
    m: usize,
    /// PLU of the last refactorization. Only the permutation and the
    /// lower factor are consulted after [`ForrestTomlin::refactorize`]
    /// copies `U` out into the updatable sparse form below.
    lu: LuFactors,
    /// Off-diagonal entries of the maintained `U` by physical row:
    /// `(physical col, value)`.
    u_rows: Vec<Vec<(usize, f64)>>,
    /// Off-diagonal entries by physical column: `(physical row, value)`.
    u_cols: Vec<Vec<(usize, f64)>>,
    /// Diagonal by physical slot.
    u_diag: Vec<f64>,
    /// Logical position → physical slot.
    pos: Vec<usize>,
    /// Physical slot → logical position.
    lpos: Vec<usize>,
    /// Row eliminations absorbed into `L'⁻¹` since the last
    /// refactorization, in application order.
    ops: Vec<Elim>,
    /// Updates recorded since the last refactorization.
    updates: usize,
    /// Scratch for the lower-factor halves of the sparse kernels.
    sv: SparseVector,
    /// Carrier for the dense adapter entry points.
    dsv: SparseVector,
    /// Spike workspace (`U · w`).
    spike: SparseVector,
    /// Relocated-row workspace during an update.
    rowbuf: SparseVector,
}

impl ForrestTomlin {
    /// Identity-basis start.
    pub fn new(m: usize) -> ForrestTomlin {
        ForrestTomlin {
            m,
            lu: LuFactors::identity(m),
            u_rows: vec![Vec::new(); m],
            u_cols: vec![Vec::new(); m],
            u_diag: vec![1.0; m],
            pos: (0..m).collect(),
            lpos: (0..m).collect(),
            ops: Vec::new(),
            updates: 0,
            sv: SparseVector::with_dim(m),
            dsv: SparseVector::with_dim(m),
            spike: SparseVector::with_dim(m),
            rowbuf: SparseVector::with_dim(m),
        }
    }

    /// Reset the position maps, update state, and move `U` out of the
    /// freshly computed PLU into the updatable sparse form (the PLU's
    /// own copy is dropped afterwards so the upper factor is never
    /// stored twice — only the permutation and `L₀` stay live).
    fn adopt_factor(&mut self) {
        let m = self.m;
        let (ur, uc, ud) = self.lu.upper_parts();
        for i in 0..m {
            self.u_rows[i].clear();
            self.u_rows[i].extend_from_slice(&ur[i]);
            self.u_cols[i].clear();
            self.u_cols[i].extend_from_slice(&uc[i]);
            self.u_diag[i] = ud[i];
            self.pos[i] = i;
            self.lpos[i] = i;
        }
        self.lu.clear_upper();
        self.ops.clear();
        self.updates = 0;
    }
}

impl BasisFactorization for ForrestTomlin {
    fn name(&self) -> &'static str {
        "forrest_tomlin"
    }

    fn reset_identity(&mut self) {
        let m = self.m;
        self.lu.reset_identity(m);
        for i in 0..m {
            self.u_rows[i].clear();
            self.u_cols[i].clear();
            self.u_diag[i] = 1.0;
            self.pos[i] = i;
            self.lpos[i] = i;
        }
        self.ops.clear();
        self.updates = 0;
    }

    fn refactorize(&mut self, b: &SparseMatrix) -> Result<()> {
        debug_assert_eq!(b.rows(), self.m);
        debug_assert_eq!(b.cols(), self.m);
        self.lu.refactor_csc(b).map_err(|e| {
            Error::Numerical(format!("forrest-tomlin: {e}"))
        })?;
        self.adopt_factor();
        Ok(())
    }

    fn ftran(&mut self, v: &[f64], out: &mut [f64]) {
        let mut carrier = std::mem::take(&mut self.dsv);
        carrier.set_from_dense(v);
        self.ftran_sparse(&mut carrier);
        carrier.copy_into_dense(out);
        carrier.clear();
        self.dsv = carrier;
    }

    fn btran(&mut self, v: &[f64], out: &mut [f64]) {
        let mut carrier = std::mem::take(&mut self.dsv);
        carrier.set_from_dense(v);
        self.btran_sparse(&mut carrier);
        carrier.copy_into_dense(out);
        carrier.clear();
        self.dsv = carrier;
    }

    fn ftran_sparse(&mut self, v: &mut SparseVector) {
        // z = L₀⁻¹ P v …
        self.lu.lower_solve_sparse(v, &mut self.sv);
        // … then the absorbed eliminations, in order.
        for &Elim { row, col, mult } in &self.ops {
            let zc = v.get(col);
            if zc != 0.0 {
                v.add(row, -mult * zc);
            }
        }
        // Back-substitute U x = z in logical order, column-oriented
        // with zero-skip (hypersparse).
        for &p in self.pos.iter().rev() {
            let zp = v.get(p);
            if zp == 0.0 {
                continue;
            }
            let xp = zp / self.u_diag[p];
            v.set(p, xp);
            for &(r, uv) in &self.u_cols[p] {
                v.add(r, -uv * xp);
            }
        }
    }

    fn btran_sparse(&mut self, v: &mut SparseVector) {
        // Forward-substitute Uᵀ s = v in logical order (Uᵀ is lower
        // triangular), column-oriented with zero-skip.
        for &p in &self.pos {
            let bp = v.get(p);
            if bp == 0.0 {
                continue;
            }
            let sp = bp / self.u_diag[p];
            v.set(p, sp);
            for &(c, uv) in &self.u_rows[p] {
                v.add(c, -uv * sp);
            }
        }
        // Transposed eliminations in reverse order …
        for &Elim { row, col, mult } in self.ops.iter().rev() {
            let zr = v.get(row);
            if zr != 0.0 {
                v.add(col, -mult * zr);
            }
        }
        // … then L₀⁻ᵀ and Pᵀ.
        self.lu.lower_transpose_solve_sparse(v, &mut self.sv);
    }

    fn update(&mut self, r: usize, w: &SparseVector) -> Result<()> {
        let m = self.m;
        // Spike s = U·w (physical row space): the partial FTRAN
        // L'⁻¹A_q recovered without re-touching the constraint matrix,
        // accumulated column-wise over w's nonzeros only.
        self.spike.resize_clear(m);
        for k in 0..w.nnz() {
            let j = w.index_at(k);
            let wj = w.get(j);
            if wj == 0.0 {
                continue;
            }
            self.spike.add(j, self.u_diag[j] * wj);
            for &(i, uv) in &self.u_cols[j] {
                self.spike.add(i, uv * wj);
            }
        }

        let t = self.lpos[r];
        // Drop the replaced column (physical slot r) from the row lists.
        for &(i, _) in &self.u_cols[r] {
            if let Some(ix) = self.u_rows[i].iter().position(|&(c, _)| c == r) {
                self.u_rows[i].swap_remove(ix);
            }
        }
        self.u_cols[r].clear();
        // Insert the spike as the new column at slot r (it becomes
        // logical column m−1, so every entry is legally upper
        // triangular). Its entry in row r is the new diagonal seed.
        for k in 0..self.spike.nnz() {
            let i = self.spike.index_at(k);
            if i == r {
                continue;
            }
            let v = self.spike.get(i);
            if v == 0.0 {
                continue;
            }
            self.u_rows[i].push((r, v));
            self.u_cols[r].push((i, v));
        }
        let diag_seed = self.spike.get(r);
        self.spike.clear();

        // Border the spiked index: rotate logical positions t..m-1
        // (maps only; no data moves).
        for k in t..m - 1 {
            let p = self.pos[k + 1];
            self.pos[k] = p;
            self.lpos[p] = k;
        }
        self.pos[m - 1] = r;
        self.lpos[r] = m - 1;

        // The relocated row (physical r, now logical m−1) is the only
        // off-triangular part: eliminate its entries at logical columns
        // t..m−2, absorbing the multipliers into the L'⁻¹ chain.
        self.rowbuf.resize_clear(m);
        for &(c, v) in &self.u_rows[r] {
            self.rowbuf.set(c, v);
            if let Some(ix) = self.u_cols[c].iter().position(|&(rr, _)| rr == r) {
                self.u_cols[c].swap_remove(ix);
            }
        }
        self.u_rows[r].clear();
        self.rowbuf.set(r, diag_seed);

        let last = m.saturating_sub(1);
        for &pj in &self.pos[t..last] {
            let e = self.rowbuf.get(pj);
            if e == 0.0 {
                continue;
            }
            let d = self.u_diag[pj];
            if d.abs() < 1e-12 {
                return Err(Error::Numerical(format!(
                    "forrest-tomlin: zero diagonal {d:.3e} during update at column {pj}"
                )));
            }
            let mult = e / d;
            if mult.abs() > 1e9 {
                return Err(Error::Numerical(format!(
                    "forrest-tomlin: unstable multiplier {mult:.3e} during update"
                )));
            }
            for &(c, v) in &self.u_rows[pj] {
                self.rowbuf.add(c, -mult * v);
            }
            self.rowbuf.set(pj, 0.0);
            self.ops.push(Elim { row: r, col: pj, mult });
        }
        let new_diag = self.rowbuf.get(r);
        if new_diag.abs() < 1e-12 {
            return Err(Error::Numerical(
                "forrest-tomlin: singular updated factor".into(),
            ));
        }
        self.u_diag[r] = new_diag;
        // Rebuild the (now triangular) relocated row from the
        // workspace.
        for k in 0..self.rowbuf.nnz() {
            let c = self.rowbuf.index_at(k);
            if c == r {
                continue;
            }
            let v = self.rowbuf.get(c);
            if v == 0.0 {
                continue;
            }
            self.u_rows[r].push((c, v));
            self.u_cols[c].push((r, v));
        }
        self.rowbuf.clear();
        self.updates += 1;
        Ok(())
    }

    fn update_len(&self) -> usize {
        self.updates
    }

    fn should_refactorize(&self) -> bool {
        self.updates >= FT_REFACTOR_EVERY || self.ops.len() >= FT_OPS_PER_ROW * self.m + 512
    }

    fn storage_nnz(&self) -> usize {
        let u: usize = self.u_cols.iter().map(|c| c.len()).sum();
        self.lu.nnz() + u + self.m + self.ops.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::util::rng::{Pcg32, Rng};

    fn random_nonsingular(rng: &mut Pcg32, m: usize) -> Matrix {
        let mut b = Matrix::zeros(m, m);
        for i in 0..m {
            for j in 0..m {
                // Sparse-ish, diagonally dominant → safely nonsingular
                // with the structure LP bases actually have.
                if i == j {
                    b[(i, j)] = 4.0 + rng.range_f64(0.0, 2.0);
                } else if rng.f64() < 0.4 {
                    b[(i, j)] = rng.range_f64(-1.0, 1.0);
                }
            }
        }
        b
    }

    fn sv(v: &[f64]) -> SparseVector {
        let mut s = SparseVector::default();
        s.set_from_dense(v);
        s
    }

    fn assert_vec_close(a: &[f64], b: &[f64], tol: f64, ctx: &str) {
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert!((x - y).abs() < tol, "{ctx}: index {i}: {x} vs {y}");
        }
    }

    /// Both strategies, driven through a random pivot sequence, must
    /// agree with a from-scratch LU of the current basis on FTRAN and
    /// BTRAN — through the dense adapters *and* the sparse kernels.
    #[test]
    fn strategies_agree_with_fresh_lu_under_updates() {
        let mut rng = Pcg32::new(99);
        for m in [1usize, 2, 4, 7, 12] {
            // A pool of candidate columns to pivot in.
            let pool: Vec<Vec<f64>> = (0..3 * m)
                .map(|_| (0..m).map(|_| rng.range_f64(-2.0, 2.0)).collect())
                .collect();
            let b0 = random_nonsingular(&mut rng, m);
            let mut cols: Vec<Vec<f64>> =
                (0..m).map(|k| (0..m).map(|i| b0[(i, k)]).collect()).collect();

            let mut pfe = ProductFormEta::new(m);
            let mut ft = ForrestTomlin::new(m);
            let b0s = SparseMatrix::from_dense(&b0, 0.0);
            pfe.refactorize(&b0s).unwrap();
            ft.refactorize(&b0s).unwrap();

            let mut w_pfe = vec![0.0; m];
            let mut w_ft = vec![0.0; m];
            let mut w_ref = vec![0.0; m];
            let mut w_sp = vec![0.0; m];
            for step in 0..20 {
                // Current-basis oracle.
                let mut bmat = Matrix::zeros(m, m);
                for (k, col) in cols.iter().enumerate() {
                    for i in 0..m {
                        bmat[(i, k)] = col[i];
                    }
                }
                let fresh = LuFactors::factor(&bmat).unwrap();

                let v: Vec<f64> = (0..m).map(|_| rng.range_f64(-1.0, 1.0)).collect();
                fresh.solve_into(&v, &mut w_ref);
                pfe.ftran(&v, &mut w_pfe);
                ft.ftran(&v, &mut w_ft);
                assert_vec_close(&w_pfe, &w_ref, 1e-7, &format!("m={m} step={step} pfe ftran"));
                assert_vec_close(&w_ft, &w_ref, 1e-7, &format!("m={m} step={step} ft ftran"));
                // Sparse kernels agree with the dense adapters.
                let mut vs = sv(&v);
                pfe.ftran_sparse(&mut vs);
                vs.copy_into_dense(&mut w_sp);
                let ctx = format!("m={m} step={step} pfe ftran_sparse");
                assert_vec_close(&w_sp, &w_pfe, 1e-10, &ctx);
                let mut vs = sv(&v);
                ft.ftran_sparse(&mut vs);
                vs.copy_into_dense(&mut w_sp);
                let ctx = format!("m={m} step={step} ft ftran_sparse");
                assert_vec_close(&w_sp, &w_ft, 1e-10, &ctx);

                let mut s = vec![0.0; m];
                fresh.solve_transpose_into(&v, &mut s, &mut w_ref);
                pfe.btran(&v, &mut w_pfe);
                ft.btran(&v, &mut w_ft);
                assert_vec_close(&w_pfe, &w_ref, 1e-7, &format!("m={m} step={step} pfe btran"));
                assert_vec_close(&w_ft, &w_ref, 1e-7, &format!("m={m} step={step} ft btran"));
                let mut vs = sv(&v);
                pfe.btran_sparse(&mut vs);
                vs.copy_into_dense(&mut w_sp);
                let ctx = format!("m={m} step={step} pfe btran_sparse");
                assert_vec_close(&w_sp, &w_pfe, 1e-10, &ctx);
                let mut vs = sv(&v);
                ft.btran_sparse(&mut vs);
                vs.copy_into_dense(&mut w_sp);
                let ctx = format!("m={m} step={step} ft btran_sparse");
                assert_vec_close(&w_sp, &w_ft, 1e-10, &ctx);

                // Pivot: a random pool column enters at a row where the
                // FTRAN result is comfortably nonzero.
                let aq = &pool[rng.range_usize(0, pool.len())];
                pfe.ftran(aq, &mut w_pfe);
                let Some(r) = (0..m).max_by(|&a, &b| {
                    w_pfe[a].abs().partial_cmp(&w_pfe[b].abs()).unwrap()
                }) else {
                    break;
                };
                if w_pfe[r].abs() < 1e-6 {
                    continue;
                }
                ft.ftran(aq, &mut w_ft);
                pfe.update(r, &sv(&w_pfe)).unwrap();
                ft.update(r, &sv(&w_ft)).unwrap();
                cols[r] = aq.clone();
            }
            assert_eq!(pfe.update_len(), ft.update_len());
        }
    }

    #[test]
    fn identity_reset_solves_trivially() {
        for strategy in [Factorization::ProductFormEta, Factorization::ForrestTomlin] {
            let mut f = strategy.build(4);
            let v = [1.0, -2.0, 3.0, 0.5];
            let mut out = [0.0; 4];
            f.ftran(&v, &mut out);
            assert_vec_close(&out, &v, 1e-12, strategy.as_str());
            f.btran(&v, &mut out);
            assert_vec_close(&out, &v, 1e-12, strategy.as_str());
            let mut s = sv(&v);
            f.ftran_sparse(&mut s);
            s.copy_into_dense(&mut out);
            assert_vec_close(&out, &v, 1e-12, strategy.as_str());
            assert_eq!(f.update_len(), 0);
            assert!(!f.should_refactorize());
        }
    }

    #[test]
    fn singular_refactorization_rejected() {
        let b = SparseMatrix::zeros(3, 3);
        for strategy in [Factorization::ProductFormEta, Factorization::ForrestTomlin] {
            let mut f = strategy.build(3);
            assert!(f.refactorize(&b).is_err(), "{}", strategy.as_str());
        }
    }

    /// The O(m²)-memory regression guard: on a sparse basis, both
    /// strategies must store O(nnz) — far below the two dense `m × m`
    /// buffers the old Forrest–Tomlin carried — even after a long
    /// update sequence.
    #[test]
    fn factor_storage_stays_sparse() {
        let m = 120;
        let mut rng = Pcg32::new(7);
        // Bidiagonal-ish basis: ~2 entries per column, like the DLT
        // timing chains.
        let mut trips: Vec<(usize, usize, f64)> = Vec::new();
        for j in 0..m {
            trips.push((j, j, 2.0 + rng.f64()));
            if j + 1 < m {
                trips.push((j + 1, j, rng.range_f64(-1.0, 1.0)));
            }
        }
        let b = SparseMatrix::from_triplets(m, m, &trips);
        for strategy in [Factorization::ProductFormEta, Factorization::ForrestTomlin] {
            let mut f = strategy.build(m);
            f.refactorize(&b).unwrap();
            // A few sparse updates so the update file is exercised too.
            let mut w = SparseVector::with_dim(m);
            for k in 0..10 {
                let q = (11 * k + 3) % m;
                w.clear();
                w.set(q, 1.5);
                if q + 1 < m {
                    w.set(q + 1, -0.5);
                }
                f.ftran_sparse(&mut w);
                let r = w
                    .indices()
                    .iter()
                    .copied()
                    .max_by(|&a, &b| w.get(a).abs().partial_cmp(&w.get(b).abs()).unwrap())
                    .unwrap();
                if w.get(r).abs() < 1e-6 {
                    continue;
                }
                f.update(r, &w).unwrap();
            }
            let nnz = f.storage_nnz();
            assert!(
                nnz < m * m / 8,
                "{}: {} stored entries on a {}-row basis (dense pair would be {})",
                f.name(),
                nnz,
                m,
                2 * m * m
            );
        }
    }

    #[test]
    fn wire_names_roundtrip() {
        for f in [Factorization::ProductFormEta, Factorization::ForrestTomlin] {
            assert_eq!(Factorization::parse(f.as_str()), Some(f));
        }
        assert_eq!(Factorization::parse("bartels_golub"), None);
    }
}
