//! Basis-factorization strategies for the revised simplex.
//!
//! The revised method never forms `B⁻¹`; it keeps a factorization of
//! the basis matrix `B` and answers two queries per iteration — FTRAN
//! (`B⁻¹v`) and BTRAN (`B⁻ᵀv`) — plus a rank-one *update* per pivot
//! (column `q` replaces the column basic in row `r`). How that update
//! is represented is a classic engineering trade-off, so it is a
//! strategy layer ([`BasisFactorization`]) with four implementations:
//!
//! - [`ProductFormEta`] — a sparse LU of the last refactorization plus
//!   a *product-form eta file* (one sparse column per pivot, stored in
//!   a shared arena so warm re-solves allocate nothing), with a full
//!   refactorization every 48 pivots to bound drift. Cheap per update
//!   (O(nnz(w))), but the eta file both grows and loses accuracy
//!   quickly, forcing the short refactorization cadence.
//! - [`Factorization::Markowitz`] — the same eta-file updating over a
//!   *Markowitz/threshold-pivot* refactorization
//!   ([`LuFactors::refactor_csc_markowitz`]): pivots are chosen
//!   fill-in-aware (sparsest eligible row within a 0.1 magnitude
//!   threshold of the column max), so the factors — and therefore
//!   every FTRAN/BTRAN between refactorizations — stay sparser on
//!   bases whose largest entries sit in dense rows.
//! - [`ForrestTomlin`] — Forrest–Tomlin LU updating: the
//!   upper-triangular factor `U` is maintained *explicitly* in sparse
//!   row + column form. A pivot replaces one column of `U` with the
//!   spike `L⁻¹A_q`, cyclically permutes the spiked index to the
//!   border, and eliminates the lone off-triangular row with
//!   multipliers absorbed into the `L⁻¹` operator chain. The cyclic
//!   permutation is *never materialized*: entries stay in their
//!   physical slots and a logical↔physical position map drives the
//!   triangular sweeps, so an update costs O(nnz) bookkeeping instead
//!   of the old dense implementation's O(m²) row/column rotation, and
//!   the factor memory drops from two dense `m × m` buffers to
//!   O(nnz(L) + nnz(U)). `U` stays genuinely triangular and accurate
//!   for hundreds of pivots, making full refactorizations rare.
//! - [`BartelsGolub`] — sparse Bartels–Golub updating, raced against
//!   Forrest–Tomlin on the same machinery: the same spike insertion
//!   and logical border rotation, but the off-triangular row is swept
//!   through the resulting Hessenberg profile with a *per-step
//!   stability interchange* — whichever of the stationary diagonal and
//!   the traveling entry is larger becomes the pivot, so every
//!   absorbed multiplier satisfies `|mult| ≤ 1` and the update never
//!   hits Forrest–Tomlin's unstable-multiplier bailout. The
//!   interchange is recorded as an explicit swap in the `L⁻¹` chain;
//!   the trade is slightly more bookkeeping per update for strictly
//!   bounded growth.
//!
//! All strategies expose **hypersparse** kernels
//! ([`BasisFactorization::ftran_sparse`] /
//! [`BasisFactorization::btran_sparse`]) operating on
//! [`SparseVector`] work arrays: the triangular sweeps are
//! column-oriented and skip every column whose intermediate value is
//! zero, so an FTRAN of a 3-nonzero DLT column touches a handful of
//! entries instead of O(m²) — the standard revised-simplex speedup for
//! the paper's timing-chain LPs. The dense `ftran`/`btran` entry
//! points remain as adapters (and, for [`ProductFormEta`], as an
//! independent dense implementation the sparse kernels are
//! property-tested against).
//!
//! Both implementations are driven identically by the primal
//! phase-1/phase-2 loops, the dual-simplex repair pass and the
//! artificial-eviction sweep in [`super::revised`]; the driver decides
//! *when* to refactorize (periodically via [`should_refactorize`],
//! and whenever an optimality/unboundedness verdict must be re-checked
//! at full accuracy), the strategy decides *how*.
//!
//! [`should_refactorize`]: BasisFactorization::should_refactorize

use crate::error::{Error, Result};
use crate::linalg::{LuFactors, SparseMatrix, SparseVector};

/// Refactorize the product-form eta file after this many updates.
const PFE_REFACTOR_EVERY: usize = 48;
/// Refactorize the Forrest–Tomlin factors after this many updates (the
/// explicit `U` stays accurate far longer than an eta file).
const FT_REFACTOR_EVERY: usize = 192;
/// Safety valve: refactorize when the absorbed `L⁻¹` operator chain
/// grows past this many entries per basis row.
const FT_OPS_PER_ROW: usize = 16;
/// Refactorize the Bartels–Golub factors after this many updates —
/// deliberately the same cadence as Forrest–Tomlin so the two updating
/// schemes race on equal footing in `bench_hypersparse`.
const BG_REFACTOR_EVERY: usize = 192;

/// Which basis-factorization strategy maintains `B⁻¹` (selected via
/// [`super::SimplexOptions::factorization`], threaded end-to-end from
/// the `dlt::api` wire options and the CLI flags).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Factorization {
    /// Sparse LU + product-form eta file (extracted legacy behavior).
    #[default]
    ProductFormEta,
    /// Forrest–Tomlin LU updating (sparse `U`, rare refactorization).
    ForrestTomlin,
    /// Eta-file updating over a Markowitz/threshold-pivot
    /// refactorization (fill-in-aware pivot order).
    Markowitz,
    /// Bartels–Golub LU updating (per-step stability interchange,
    /// `|mult| ≤ 1` guaranteed).
    BartelsGolub,
}

impl Factorization {
    /// Stable wire name (`product_form_eta` / `forrest_tomlin` /
    /// `markowitz` / `bartels_golub`).
    pub fn as_str(self) -> &'static str {
        match self {
            Factorization::ProductFormEta => "product_form_eta",
            Factorization::ForrestTomlin => "forrest_tomlin",
            Factorization::Markowitz => "markowitz",
            Factorization::BartelsGolub => "bartels_golub",
        }
    }

    /// Parse a wire name; `None` for anything unknown.
    pub fn parse(s: &str) -> Option<Factorization> {
        match s {
            "product_form_eta" => Some(Factorization::ProductFormEta),
            "forrest_tomlin" => Some(Factorization::ForrestTomlin),
            "markowitz" => Some(Factorization::Markowitz),
            "bartels_golub" => Some(Factorization::BartelsGolub),
            _ => None,
        }
    }

    /// Instantiate the strategy for an `m`-row basis. Public so the
    /// benches can race strategies directly against each other.
    pub fn build(self, m: usize) -> Box<dyn BasisFactorization> {
        match self {
            Factorization::ProductFormEta => Box::new(ProductFormEta::new(m)),
            Factorization::ForrestTomlin => Box::new(ForrestTomlin::new(m)),
            Factorization::Markowitz => Box::new(ProductFormEta::new_markowitz(m)),
            Factorization::BartelsGolub => Box::new(BartelsGolub::new(m)),
        }
    }
}

/// One basis-factorization strategy. All vectors are length `m` (the
/// basis dimension) and indexed by constraint row / basis position.
///
/// `Send` so boxed strategies (inside [`crate::lp::SolverScratch`],
/// and hence whole [`crate::api::Session`]s) can migrate across the
/// serving tier's worker threads.
pub trait BasisFactorization: Send {
    /// Strategy name (diagnostics).
    fn name(&self) -> &'static str;

    /// Reset to the identity basis (`B = I`, the slack/artificial cold
    /// start).
    fn reset_identity(&mut self);

    /// Replace the factorization with a fresh one of `b` (CSC — the
    /// basis columns are scattered straight from the constraint
    /// matrix, never densified). Errors when `b` is (numerically)
    /// singular; the strategy is left ready for
    /// [`BasisFactorization::reset_identity`].
    fn refactorize(&mut self, b: &SparseMatrix) -> Result<()>;

    /// FTRAN: `out = B⁻¹ v` (dense adapter over the sparse kernel).
    fn ftran(&mut self, v: &[f64], out: &mut [f64]);

    /// BTRAN: `out = B⁻ᵀ v` (dense adapter over the sparse kernel).
    fn btran(&mut self, v: &[f64], out: &mut [f64]);

    /// Hypersparse FTRAN, in place: `v ← B⁻¹ v`. Work is proportional
    /// to the nonzeros actually created, not the basis dimension.
    fn ftran_sparse(&mut self, v: &mut SparseVector);

    /// Hypersparse BTRAN, in place: `v ← B⁻ᵀ v`.
    fn btran_sparse(&mut self, v: &mut SparseVector);

    /// Record a pivot: the entering column replaces the column basic in
    /// row `r`, where `w = B⁻¹ A_q` is the (sparse) result of the FTRAN
    /// the driver just performed for that column. An error signals
    /// numerical breakdown — the caller must refactorize from the (new)
    /// basis before the factorization is used again.
    fn update(&mut self, r: usize, w: &SparseVector) -> Result<()>;

    /// Updates recorded since the last (re)factorization (eta count,
    /// or Forrest–Tomlin spike count).
    fn update_len(&self) -> usize;

    /// True when the update file is long enough that the driver should
    /// refactorize before the next pivot.
    fn should_refactorize(&self) -> bool;

    /// Entries currently stored across the factors and the update file
    /// — the sparse-memory diagnostic (a dense `L`/`U` pair would put
    /// this at `2m²` regardless of basis sparsity).
    fn storage_nnz(&self) -> usize;

    /// Triangular solves answered through the Gilbert–Peierls symbolic
    /// DFS path since construction (see
    /// [`crate::linalg::SolveMode`]). Strategies that do not route
    /// through [`LuFactors`] report 0.
    fn dfs_solves(&self) -> usize {
        0
    }

    /// Triangular solves answered through the full O(m) column scan
    /// since construction (the dense-RHS side of the DFS/scan
    /// crossover).
    fn scan_solves(&self) -> usize {
        0
    }
}

/// One product-form eta head: the pivot column `w = B_prev⁻¹ A_q`
/// recorded at pivot row `r`; its off-`r` entries live in the shared
/// arena at `pool[start..end]` (no per-pivot allocation).
#[derive(Debug, Clone, Copy)]
struct EtaHead {
    r: usize,
    wr: f64,
    start: usize,
    end: usize,
}

/// Sparse LU of the last refactorization plus a product-form eta file —
/// the behavior `lp/revised.rs` hardwired before this layer existed.
pub struct ProductFormEta {
    m: usize,
    lu: LuFactors,
    etas: Vec<EtaHead>,
    /// Shared entry arena for all etas (reset with the file, so warm
    /// re-solves reuse its capacity).
    pool: Vec<(usize, f64)>,
    // Dense BTRAN scratch (eta application happens before the LU
    // transpose solve, which itself needs a scratch vector).
    u: Vec<f64>,
    t: Vec<f64>,
    /// Sparse-kernel scratch.
    sv: SparseVector,
    /// Use Markowitz/threshold pivoting when refactorizing (the
    /// [`Factorization::Markowitz`] strategy shares this struct — only
    /// the refactorization pivot rule differs).
    markowitz: bool,
}

impl ProductFormEta {
    /// Identity-basis start.
    pub fn new(m: usize) -> ProductFormEta {
        ProductFormEta {
            m,
            lu: LuFactors::identity(m),
            etas: Vec::new(),
            pool: Vec::new(),
            u: vec![0.0; m],
            t: vec![0.0; m],
            sv: SparseVector::with_dim(m),
            markowitz: false,
        }
    }

    /// Identity-basis start with Markowitz/threshold refactorization.
    pub fn new_markowitz(m: usize) -> ProductFormEta {
        ProductFormEta { markowitz: true, ..ProductFormEta::new(m) }
    }
}

impl BasisFactorization for ProductFormEta {
    fn name(&self) -> &'static str {
        if self.markowitz {
            "markowitz"
        } else {
            "product_form_eta"
        }
    }

    fn reset_identity(&mut self) {
        self.lu.reset_identity(self.m);
        self.etas.clear();
        self.pool.clear();
    }

    fn refactorize(&mut self, b: &SparseMatrix) -> Result<()> {
        if self.markowitz {
            self.lu.refactor_csc_markowitz(b)?;
        } else {
            self.lu.refactor_csc(b)?;
        }
        self.etas.clear();
        self.pool.clear();
        Ok(())
    }

    // The dense entry points keep the original dense implementation —
    // an independent oracle the sparse kernels are tested against.
    fn ftran(&mut self, v: &[f64], out: &mut [f64]) {
        self.lu.solve_into(v, out);
        for &EtaHead { r, wr, start, end } in &self.etas {
            let ur = out[r] / wr;
            if ur != 0.0 {
                for &(i, wi) in &self.pool[start..end] {
                    out[i] -= wi * ur;
                }
            }
            out[r] = ur;
        }
    }

    fn btran(&mut self, v: &[f64], out: &mut [f64]) {
        self.u.copy_from_slice(v);
        for &EtaHead { r, wr, start, end } in self.etas.iter().rev() {
            let mut acc = self.u[r];
            for &(i, wi) in &self.pool[start..end] {
                acc -= wi * self.u[i];
            }
            self.u[r] = acc / wr;
        }
        self.lu.solve_transpose_into(&self.u, &mut self.t, out);
    }

    fn ftran_sparse(&mut self, v: &mut SparseVector) {
        self.lu.solve_sparse(v, &mut self.sv);
        // Eta passes exploit RHS sparsity: a pivot row the vector never
        // touches is skipped without reading its entries.
        for &EtaHead { r, wr, start, end } in &self.etas {
            let ur = v.get(r) / wr;
            if ur != 0.0 {
                for &(i, wi) in &self.pool[start..end] {
                    v.add(i, -wi * ur);
                }
                v.set(r, ur);
            }
        }
    }

    fn btran_sparse(&mut self, v: &mut SparseVector) {
        for &EtaHead { r, wr, start, end } in self.etas.iter().rev() {
            let mut acc = v.get(r);
            for &(i, wi) in &self.pool[start..end] {
                acc -= wi * v.get(i);
            }
            if acc != 0.0 || v.get(r) != 0.0 {
                v.set(r, acc / wr);
            }
        }
        self.lu.solve_transpose_sparse(v, &mut self.sv);
    }

    fn update(&mut self, r: usize, w: &SparseVector) -> Result<()> {
        let wr = w.get(r);
        if wr.abs() < 1e-13 {
            return Err(Error::Numerical(format!(
                "product-form eta: pivot element {wr:.3e} too small in row {r}"
            )));
        }
        let start = self.pool.len();
        for k in 0..w.nnz() {
            let i = w.index_at(k);
            if i == r {
                continue;
            }
            let wi = w.get(i);
            if wi.abs() > 1e-12 {
                self.pool.push((i, wi));
            }
        }
        self.etas.push(EtaHead { r, wr, start, end: self.pool.len() });
        Ok(())
    }

    fn update_len(&self) -> usize {
        self.etas.len()
    }

    fn should_refactorize(&self) -> bool {
        self.etas.len() >= PFE_REFACTOR_EVERY
    }

    fn storage_nnz(&self) -> usize {
        self.lu.nnz() + self.pool.len() + self.etas.len()
    }

    fn dfs_solves(&self) -> usize {
        self.lu.solve_mode_counts().0
    }

    fn scan_solves(&self) -> usize {
        self.lu.solve_mode_counts().1
    }
}

/// One row elimination absorbed into the `L⁻¹` chain by a
/// Forrest–Tomlin update (physical slot indices):
/// `z[row] -= mult * z[col]`.
#[derive(Debug, Clone, Copy)]
struct Elim {
    row: usize,
    col: usize,
    mult: f64,
}

/// Forrest–Tomlin LU updating over a sparse, explicitly maintained
/// `U`.
///
/// Invariant: `B = L' · U` where `L'⁻¹` is the composition
/// `ops ∘ L₀⁻¹ ∘ P` (initial PLU row permutation and lower factor,
/// then the recorded eliminations in order, all in *physical slot*
/// space), and `U` is upper triangular in *logical* index space. The
/// bordered cyclic permutation of the textbook algorithm is carried by
/// the `pos`/`lpos` maps instead of moving data: physical slot `r`
/// (row *and* column of the replaced basis position) simply becomes
/// logical position `m−1`, which is what keeps updates O(nnz).
pub struct ForrestTomlin {
    m: usize,
    /// PLU of the last refactorization. Only the permutation and the
    /// lower factor are consulted after [`ForrestTomlin::refactorize`]
    /// copies `U` out into the updatable sparse form below.
    lu: LuFactors,
    /// Off-diagonal entries of the maintained `U` by physical row:
    /// `(physical col, value)`.
    u_rows: Vec<Vec<(usize, f64)>>,
    /// Off-diagonal entries by physical column: `(physical row, value)`.
    u_cols: Vec<Vec<(usize, f64)>>,
    /// Diagonal by physical slot.
    u_diag: Vec<f64>,
    /// Logical position → physical slot.
    pos: Vec<usize>,
    /// Physical slot → logical position.
    lpos: Vec<usize>,
    /// Row eliminations absorbed into `L'⁻¹` since the last
    /// refactorization, in application order.
    ops: Vec<Elim>,
    /// Updates recorded since the last refactorization.
    updates: usize,
    /// Scratch for the lower-factor halves of the sparse kernels.
    sv: SparseVector,
    /// Carrier for the dense adapter entry points.
    dsv: SparseVector,
    /// Spike workspace (`U · w`).
    spike: SparseVector,
    /// Relocated-row workspace during an update.
    rowbuf: SparseVector,
}

impl ForrestTomlin {
    /// Identity-basis start.
    pub fn new(m: usize) -> ForrestTomlin {
        ForrestTomlin {
            m,
            lu: LuFactors::identity(m),
            u_rows: vec![Vec::new(); m],
            u_cols: vec![Vec::new(); m],
            u_diag: vec![1.0; m],
            pos: (0..m).collect(),
            lpos: (0..m).collect(),
            ops: Vec::new(),
            updates: 0,
            sv: SparseVector::with_dim(m),
            dsv: SparseVector::with_dim(m),
            spike: SparseVector::with_dim(m),
            rowbuf: SparseVector::with_dim(m),
        }
    }

    /// Reset the position maps, update state, and move `U` out of the
    /// freshly computed PLU into the updatable sparse form (the PLU's
    /// own copy is dropped afterwards so the upper factor is never
    /// stored twice — only the permutation and `L₀` stay live).
    fn adopt_factor(&mut self) {
        let m = self.m;
        let (ur, uc, ud) = self.lu.upper_parts();
        for i in 0..m {
            self.u_rows[i].clear();
            self.u_rows[i].extend_from_slice(&ur[i]);
            self.u_cols[i].clear();
            self.u_cols[i].extend_from_slice(&uc[i]);
            self.u_diag[i] = ud[i];
            self.pos[i] = i;
            self.lpos[i] = i;
        }
        self.lu.clear_upper();
        self.ops.clear();
        self.updates = 0;
    }
}

impl BasisFactorization for ForrestTomlin {
    fn name(&self) -> &'static str {
        "forrest_tomlin"
    }

    fn reset_identity(&mut self) {
        let m = self.m;
        self.lu.reset_identity(m);
        for i in 0..m {
            self.u_rows[i].clear();
            self.u_cols[i].clear();
            self.u_diag[i] = 1.0;
            self.pos[i] = i;
            self.lpos[i] = i;
        }
        self.ops.clear();
        self.updates = 0;
    }

    fn refactorize(&mut self, b: &SparseMatrix) -> Result<()> {
        debug_assert_eq!(b.rows(), self.m);
        debug_assert_eq!(b.cols(), self.m);
        self.lu.refactor_csc(b).map_err(|e| {
            Error::Numerical(format!("forrest-tomlin: {e}"))
        })?;
        self.adopt_factor();
        Ok(())
    }

    fn ftran(&mut self, v: &[f64], out: &mut [f64]) {
        let mut carrier = std::mem::take(&mut self.dsv);
        carrier.set_from_dense(v);
        self.ftran_sparse(&mut carrier);
        carrier.copy_into_dense(out);
        carrier.clear();
        self.dsv = carrier;
    }

    fn btran(&mut self, v: &[f64], out: &mut [f64]) {
        let mut carrier = std::mem::take(&mut self.dsv);
        carrier.set_from_dense(v);
        self.btran_sparse(&mut carrier);
        carrier.copy_into_dense(out);
        carrier.clear();
        self.dsv = carrier;
    }

    fn ftran_sparse(&mut self, v: &mut SparseVector) {
        // z = L₀⁻¹ P v …
        self.lu.lower_solve_sparse(v, &mut self.sv);
        // … then the absorbed eliminations, in order.
        for &Elim { row, col, mult } in &self.ops {
            let zc = v.get(col);
            if zc != 0.0 {
                v.add(row, -mult * zc);
            }
        }
        // Back-substitute U x = z in logical order, column-oriented
        // with zero-skip (hypersparse).
        for &p in self.pos.iter().rev() {
            let zp = v.get(p);
            if zp == 0.0 {
                continue;
            }
            let xp = zp / self.u_diag[p];
            v.set(p, xp);
            for &(r, uv) in &self.u_cols[p] {
                v.add(r, -uv * xp);
            }
        }
    }

    fn btran_sparse(&mut self, v: &mut SparseVector) {
        // Forward-substitute Uᵀ s = v in logical order (Uᵀ is lower
        // triangular), column-oriented with zero-skip.
        for &p in &self.pos {
            let bp = v.get(p);
            if bp == 0.0 {
                continue;
            }
            let sp = bp / self.u_diag[p];
            v.set(p, sp);
            for &(c, uv) in &self.u_rows[p] {
                v.add(c, -uv * sp);
            }
        }
        // Transposed eliminations in reverse order …
        for &Elim { row, col, mult } in self.ops.iter().rev() {
            let zr = v.get(row);
            if zr != 0.0 {
                v.add(col, -mult * zr);
            }
        }
        // … then L₀⁻ᵀ and Pᵀ.
        self.lu.lower_transpose_solve_sparse(v, &mut self.sv);
    }

    fn update(&mut self, r: usize, w: &SparseVector) -> Result<()> {
        let m = self.m;
        // Spike s = U·w (physical row space): the partial FTRAN
        // L'⁻¹A_q recovered without re-touching the constraint matrix,
        // accumulated column-wise over w's nonzeros only.
        self.spike.resize_clear(m);
        for k in 0..w.nnz() {
            let j = w.index_at(k);
            let wj = w.get(j);
            if wj == 0.0 {
                continue;
            }
            self.spike.add(j, self.u_diag[j] * wj);
            for &(i, uv) in &self.u_cols[j] {
                self.spike.add(i, uv * wj);
            }
        }

        let t = self.lpos[r];
        // Drop the replaced column (physical slot r) from the row lists.
        for &(i, _) in &self.u_cols[r] {
            if let Some(ix) = self.u_rows[i].iter().position(|&(c, _)| c == r) {
                self.u_rows[i].swap_remove(ix);
            }
        }
        self.u_cols[r].clear();
        // Insert the spike as the new column at slot r (it becomes
        // logical column m−1, so every entry is legally upper
        // triangular). Its entry in row r is the new diagonal seed.
        for k in 0..self.spike.nnz() {
            let i = self.spike.index_at(k);
            if i == r {
                continue;
            }
            let v = self.spike.get(i);
            if v == 0.0 {
                continue;
            }
            self.u_rows[i].push((r, v));
            self.u_cols[r].push((i, v));
        }
        let diag_seed = self.spike.get(r);
        self.spike.clear();

        // Border the spiked index: rotate logical positions t..m-1
        // (maps only; no data moves).
        for k in t..m - 1 {
            let p = self.pos[k + 1];
            self.pos[k] = p;
            self.lpos[p] = k;
        }
        self.pos[m - 1] = r;
        self.lpos[r] = m - 1;

        // The relocated row (physical r, now logical m−1) is the only
        // off-triangular part: eliminate its entries at logical columns
        // t..m−2, absorbing the multipliers into the L'⁻¹ chain.
        self.rowbuf.resize_clear(m);
        for &(c, v) in &self.u_rows[r] {
            self.rowbuf.set(c, v);
            if let Some(ix) = self.u_cols[c].iter().position(|&(rr, _)| rr == r) {
                self.u_cols[c].swap_remove(ix);
            }
        }
        self.u_rows[r].clear();
        self.rowbuf.set(r, diag_seed);

        let last = m.saturating_sub(1);
        for &pj in &self.pos[t..last] {
            let e = self.rowbuf.get(pj);
            if e == 0.0 {
                continue;
            }
            let d = self.u_diag[pj];
            if d.abs() < 1e-12 {
                return Err(Error::Numerical(format!(
                    "forrest-tomlin: zero diagonal {d:.3e} during update at column {pj}"
                )));
            }
            let mult = e / d;
            if mult.abs() > 1e9 {
                return Err(Error::Numerical(format!(
                    "forrest-tomlin: unstable multiplier {mult:.3e} during update"
                )));
            }
            for &(c, v) in &self.u_rows[pj] {
                self.rowbuf.add(c, -mult * v);
            }
            self.rowbuf.set(pj, 0.0);
            self.ops.push(Elim { row: r, col: pj, mult });
        }
        let new_diag = self.rowbuf.get(r);
        if new_diag.abs() < 1e-12 {
            return Err(Error::Numerical(
                "forrest-tomlin: singular updated factor".into(),
            ));
        }
        self.u_diag[r] = new_diag;
        // Rebuild the (now triangular) relocated row from the
        // workspace.
        for k in 0..self.rowbuf.nnz() {
            let c = self.rowbuf.index_at(k);
            if c == r {
                continue;
            }
            let v = self.rowbuf.get(c);
            if v == 0.0 {
                continue;
            }
            self.u_rows[r].push((c, v));
            self.u_cols[c].push((r, v));
        }
        self.rowbuf.clear();
        self.updates += 1;
        Ok(())
    }

    fn update_len(&self) -> usize {
        self.updates
    }

    fn should_refactorize(&self) -> bool {
        self.updates >= FT_REFACTOR_EVERY || self.ops.len() >= FT_OPS_PER_ROW * self.m + 512
    }

    fn storage_nnz(&self) -> usize {
        let u: usize = self.u_cols.iter().map(|c| c.len()).sum();
        self.lu.nnz() + u + self.m + self.ops.len()
    }

    fn dfs_solves(&self) -> usize {
        self.lu.solve_mode_counts().0
    }

    fn scan_solves(&self) -> usize {
        self.lu.solve_mode_counts().1
    }
}

/// One operation absorbed into the `L⁻¹` chain by a Bartels–Golub
/// update (physical slot indices): either a Forrest–Tomlin-style row
/// elimination or the row interchange of a stability pivot.
#[derive(Debug, Clone, Copy)]
enum BgOp {
    /// `z[row] -= mult * z[col]` (transpose: `z[col] -= mult * z[row]`).
    Elim { row: usize, col: usize, mult: f64 },
    /// `z[a] ↔ z[b]` (its own transpose).
    Swap { a: usize, b: usize },
}

/// Sparse Bartels–Golub LU updating.
///
/// Shares the Forrest–Tomlin skeleton — explicit sparse `U` in
/// row + column form, spike insertion at the replaced slot, the cyclic
/// border permutation carried by `pos`/`lpos` maps instead of data
/// movement — but the Hessenberg sweep that re-triangularizes the
/// relocated row makes a *stability interchange* at every step:
///
/// - if the traveling entry `e` is no larger than the stationary
///   diagonal `d`, eliminate it exactly like Forrest–Tomlin
///   (`mult = e/d`, `|mult| ≤ 1`);
/// - otherwise *swap roles*: the traveling row settles into the
///   stationary slot (its entry `e` becomes the diagonal) and the old
///   stationary row, minus `mult = d/e` times the traveling row,
///   travels on. The interchange is recorded as an explicit
///   [`BgOp::Swap`] in the `L⁻¹` chain.
///
/// Every absorbed multiplier therefore satisfies `|mult| ≤ 1` — the
/// update has no unstable-multiplier failure mode (the only breakdown
/// left is a genuinely singular updated basis), which is the classic
/// stability argument for Bartels–Golub over Forrest–Tomlin.
pub struct BartelsGolub {
    m: usize,
    /// PLU of the last refactorization (permutation + `L₀` stay live;
    /// `U` is moved out into the updatable form below).
    lu: LuFactors,
    /// Off-diagonal entries of the maintained `U` by physical row.
    u_rows: Vec<Vec<(usize, f64)>>,
    /// Off-diagonal entries by physical column.
    u_cols: Vec<Vec<(usize, f64)>>,
    /// Diagonal by physical slot.
    u_diag: Vec<f64>,
    /// Logical position → physical slot.
    pos: Vec<usize>,
    /// Physical slot → logical position.
    lpos: Vec<usize>,
    /// Operations absorbed into `L'⁻¹` since the last refactorization,
    /// in application order.
    ops: Vec<BgOp>,
    /// Updates recorded since the last refactorization.
    updates: usize,
    /// Scratch for the lower-factor halves of the sparse kernels.
    sv: SparseVector,
    /// Carrier for the dense adapter entry points.
    dsv: SparseVector,
    /// Spike workspace (`U · w`).
    spike: SparseVector,
    /// Traveling-row workspace during an update.
    rowbuf: SparseVector,
    /// Next-traveling-row workspace for the interchange branch.
    swapbuf: SparseVector,
}

impl BartelsGolub {
    /// Identity-basis start.
    pub fn new(m: usize) -> BartelsGolub {
        BartelsGolub {
            m,
            lu: LuFactors::identity(m),
            u_rows: vec![Vec::new(); m],
            u_cols: vec![Vec::new(); m],
            u_diag: vec![1.0; m],
            pos: (0..m).collect(),
            lpos: (0..m).collect(),
            ops: Vec::new(),
            updates: 0,
            sv: SparseVector::with_dim(m),
            dsv: SparseVector::with_dim(m),
            spike: SparseVector::with_dim(m),
            rowbuf: SparseVector::with_dim(m),
            swapbuf: SparseVector::with_dim(m),
        }
    }

    /// Move `U` out of the freshly computed PLU into the updatable
    /// sparse form and reset the maps and the op chain (identical to
    /// the Forrest–Tomlin adoption).
    fn adopt_factor(&mut self) {
        let m = self.m;
        let (ur, uc, ud) = self.lu.upper_parts();
        for i in 0..m {
            self.u_rows[i].clear();
            self.u_rows[i].extend_from_slice(&ur[i]);
            self.u_cols[i].clear();
            self.u_cols[i].extend_from_slice(&uc[i]);
            self.u_diag[i] = ud[i];
            self.pos[i] = i;
            self.lpos[i] = i;
        }
        self.lu.clear_upper();
        self.ops.clear();
        self.updates = 0;
    }

    /// Apply the absorbed op chain to `v` (FTRAN direction).
    fn apply_ops(&self, v: &mut SparseVector) {
        for op in &self.ops {
            match *op {
                BgOp::Elim { row, col, mult } => {
                    let zc = v.get(col);
                    if zc != 0.0 {
                        v.add(row, -mult * zc);
                    }
                }
                BgOp::Swap { a, b } => {
                    let za = v.get(a);
                    let zb = v.get(b);
                    if za != 0.0 || zb != 0.0 {
                        v.set(a, zb);
                        v.set(b, za);
                    }
                }
            }
        }
    }

    /// Apply the transposed op chain in reverse to `v` (BTRAN
    /// direction).
    fn apply_ops_transposed(&self, v: &mut SparseVector) {
        for op in self.ops.iter().rev() {
            match *op {
                BgOp::Elim { row, col, mult } => {
                    let zr = v.get(row);
                    if zr != 0.0 {
                        v.add(col, -mult * zr);
                    }
                }
                BgOp::Swap { a, b } => {
                    let za = v.get(a);
                    let zb = v.get(b);
                    if za != 0.0 || zb != 0.0 {
                        v.set(a, zb);
                        v.set(b, za);
                    }
                }
            }
        }
    }
}

impl BasisFactorization for BartelsGolub {
    fn name(&self) -> &'static str {
        "bartels_golub"
    }

    fn reset_identity(&mut self) {
        let m = self.m;
        self.lu.reset_identity(m);
        for i in 0..m {
            self.u_rows[i].clear();
            self.u_cols[i].clear();
            self.u_diag[i] = 1.0;
            self.pos[i] = i;
            self.lpos[i] = i;
        }
        self.ops.clear();
        self.updates = 0;
    }

    fn refactorize(&mut self, b: &SparseMatrix) -> Result<()> {
        debug_assert_eq!(b.rows(), self.m);
        debug_assert_eq!(b.cols(), self.m);
        self.lu.refactor_csc(b).map_err(|e| {
            Error::Numerical(format!("bartels-golub: {e}"))
        })?;
        self.adopt_factor();
        Ok(())
    }

    fn ftran(&mut self, v: &[f64], out: &mut [f64]) {
        let mut carrier = std::mem::take(&mut self.dsv);
        carrier.set_from_dense(v);
        self.ftran_sparse(&mut carrier);
        carrier.copy_into_dense(out);
        carrier.clear();
        self.dsv = carrier;
    }

    fn btran(&mut self, v: &[f64], out: &mut [f64]) {
        let mut carrier = std::mem::take(&mut self.dsv);
        carrier.set_from_dense(v);
        self.btran_sparse(&mut carrier);
        carrier.copy_into_dense(out);
        carrier.clear();
        self.dsv = carrier;
    }

    fn ftran_sparse(&mut self, v: &mut SparseVector) {
        // z = L₀⁻¹ P v, then the absorbed op chain in order.
        self.lu.lower_solve_sparse(v, &mut self.sv);
        self.apply_ops(v);
        // Back-substitute U x = z in logical order, column-oriented
        // with zero-skip (hypersparse).
        for &p in self.pos.iter().rev() {
            let zp = v.get(p);
            if zp == 0.0 {
                continue;
            }
            let xp = zp / self.u_diag[p];
            v.set(p, xp);
            for &(r, uv) in &self.u_cols[p] {
                v.add(r, -uv * xp);
            }
        }
    }

    fn btran_sparse(&mut self, v: &mut SparseVector) {
        // Forward-substitute Uᵀ s = v in logical order.
        for &p in &self.pos {
            let bp = v.get(p);
            if bp == 0.0 {
                continue;
            }
            let sp = bp / self.u_diag[p];
            v.set(p, sp);
            for &(c, uv) in &self.u_rows[p] {
                v.add(c, -uv * sp);
            }
        }
        // Transposed op chain in reverse, then L₀⁻ᵀ and Pᵀ.
        self.apply_ops_transposed(v);
        self.lu.lower_transpose_solve_sparse(v, &mut self.sv);
    }

    fn update(&mut self, r: usize, w: &SparseVector) -> Result<()> {
        let m = self.m;
        // Spike s = U·w, exactly as in Forrest–Tomlin.
        self.spike.resize_clear(m);
        for k in 0..w.nnz() {
            let j = w.index_at(k);
            let wj = w.get(j);
            if wj == 0.0 {
                continue;
            }
            self.spike.add(j, self.u_diag[j] * wj);
            for &(i, uv) in &self.u_cols[j] {
                self.spike.add(i, uv * wj);
            }
        }

        let t = self.lpos[r];
        // Drop the replaced column (physical slot r) from the row lists
        // and insert the spike in its place (logical column m−1).
        for &(i, _) in &self.u_cols[r] {
            if let Some(ix) = self.u_rows[i].iter().position(|&(c, _)| c == r) {
                self.u_rows[i].swap_remove(ix);
            }
        }
        self.u_cols[r].clear();
        for k in 0..self.spike.nnz() {
            let i = self.spike.index_at(k);
            if i == r {
                continue;
            }
            let v = self.spike.get(i);
            if v == 0.0 {
                continue;
            }
            self.u_rows[i].push((r, v));
            self.u_cols[r].push((i, v));
        }
        let diag_seed = self.spike.get(r);
        self.spike.clear();

        // Border the spiked index (maps only; no data moves).
        for k in t..m - 1 {
            let p = self.pos[k + 1];
            self.pos[k] = p;
            self.lpos[p] = k;
        }
        self.pos[m - 1] = r;
        self.lpos[r] = m - 1;

        // Gather the relocated row into the traveling-row workspace.
        self.rowbuf.resize_clear(m);
        for &(c, v) in &self.u_rows[r] {
            self.rowbuf.set(c, v);
            if let Some(ix) = self.u_cols[c].iter().position(|&(rr, _)| rr == r) {
                self.u_cols[c].swap_remove(ix);
            }
        }
        self.u_rows[r].clear();
        self.rowbuf.set(r, diag_seed);

        // Hessenberg sweep with a per-step stability interchange. At
        // step k the traveling row (logical position m−1, physical slot
        // r) has entries only at logical columns ≥ k; whichever of the
        // stationary diagonal `d` and the traveling entry `e` is larger
        // in magnitude becomes the pivot, so |mult| ≤ 1 always.
        let last = m.saturating_sub(1);
        for k in t..last {
            let c = self.pos[k];
            let e = self.rowbuf.get(c);
            if e == 0.0 {
                continue;
            }
            let d = self.u_diag[c];
            if e.abs() <= d.abs() {
                // Forrest–Tomlin-shaped step: eliminate the traveling
                // entry with the stationary row.
                let mult = e / d;
                for &(cc, v) in &self.u_rows[c] {
                    self.rowbuf.add(cc, -mult * v);
                }
                self.rowbuf.set(c, 0.0);
                self.ops.push(BgOp::Elim { row: r, col: c, mult });
            } else {
                // Interchange: the traveling row settles into slot c
                // (diagonal e) and the old row c − mult·(traveling row)
                // travels on. Its entry at column c is d − mult·e = 0
                // exactly and is never materialized.
                let mult = d / e;
                self.swapbuf.resize_clear(m);
                for &(cc, v) in &self.u_rows[c] {
                    self.swapbuf.set(cc, v);
                    if let Some(ix) = self.u_cols[cc].iter().position(|&(rr, _)| rr == c) {
                        self.u_cols[cc].swap_remove(ix);
                    }
                }
                self.u_rows[c].clear();
                for kk in 0..self.rowbuf.nnz() {
                    let cc = self.rowbuf.index_at(kk);
                    if cc == c {
                        continue;
                    }
                    let v = self.rowbuf.get(cc);
                    if v == 0.0 {
                        continue;
                    }
                    self.u_rows[c].push((cc, v));
                    self.u_cols[cc].push((c, v));
                    self.swapbuf.add(cc, -mult * v);
                }
                self.u_diag[c] = e;
                std::mem::swap(&mut self.rowbuf, &mut self.swapbuf);
                self.swapbuf.clear();
                if mult != 0.0 {
                    self.ops.push(BgOp::Elim { row: c, col: r, mult });
                }
                self.ops.push(BgOp::Swap { a: c, b: r });
            }
        }
        let new_diag = self.rowbuf.get(r);
        if new_diag.abs() < 1e-12 {
            return Err(Error::Numerical(
                "bartels-golub: singular updated factor".into(),
            ));
        }
        self.u_diag[r] = new_diag;
        // Rebuild the (now triangular) relocated row from the
        // workspace.
        for k in 0..self.rowbuf.nnz() {
            let c = self.rowbuf.index_at(k);
            if c == r {
                continue;
            }
            let v = self.rowbuf.get(c);
            if v == 0.0 {
                continue;
            }
            self.u_rows[r].push((c, v));
            self.u_cols[c].push((r, v));
        }
        self.rowbuf.clear();
        self.updates += 1;
        Ok(())
    }

    fn update_len(&self) -> usize {
        self.updates
    }

    fn should_refactorize(&self) -> bool {
        self.updates >= BG_REFACTOR_EVERY || self.ops.len() >= FT_OPS_PER_ROW * self.m + 512
    }

    fn storage_nnz(&self) -> usize {
        let u: usize = self.u_cols.iter().map(|c| c.len()).sum();
        self.lu.nnz() + u + self.m + self.ops.len()
    }

    fn dfs_solves(&self) -> usize {
        self.lu.solve_mode_counts().0
    }

    fn scan_solves(&self) -> usize {
        self.lu.solve_mode_counts().1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::util::rng::{Pcg32, Rng};

    fn random_nonsingular(rng: &mut Pcg32, m: usize) -> Matrix {
        let mut b = Matrix::zeros(m, m);
        for i in 0..m {
            for j in 0..m {
                // Sparse-ish, diagonally dominant → safely nonsingular
                // with the structure LP bases actually have.
                if i == j {
                    b[(i, j)] = 4.0 + rng.range_f64(0.0, 2.0);
                } else if rng.f64() < 0.4 {
                    b[(i, j)] = rng.range_f64(-1.0, 1.0);
                }
            }
        }
        b
    }

    fn sv(v: &[f64]) -> SparseVector {
        let mut s = SparseVector::default();
        s.set_from_dense(v);
        s
    }

    fn assert_vec_close(a: &[f64], b: &[f64], tol: f64, ctx: &str) {
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert!((x - y).abs() < tol, "{ctx}: index {i}: {x} vs {y}");
        }
    }

    const ALL: [Factorization; 4] = [
        Factorization::ProductFormEta,
        Factorization::ForrestTomlin,
        Factorization::Markowitz,
        Factorization::BartelsGolub,
    ];

    /// All four strategies, driven through a random pivot sequence in
    /// lockstep, must agree with a from-scratch LU of the current basis
    /// on FTRAN and BTRAN — through the dense adapters *and* the sparse
    /// kernels.
    #[test]
    fn strategies_agree_with_fresh_lu_under_updates() {
        let mut rng = Pcg32::new(99);
        for m in [1usize, 2, 4, 7, 12] {
            // A pool of candidate columns to pivot in.
            let pool: Vec<Vec<f64>> = (0..3 * m)
                .map(|_| (0..m).map(|_| rng.range_f64(-2.0, 2.0)).collect())
                .collect();
            let b0 = random_nonsingular(&mut rng, m);
            let mut cols: Vec<Vec<f64>> =
                (0..m).map(|k| (0..m).map(|i| b0[(i, k)]).collect()).collect();

            let mut strategies: Vec<Box<dyn BasisFactorization>> =
                ALL.iter().map(|k| k.build(m)).collect();
            let b0s = SparseMatrix::from_dense(&b0, 0.0);
            for f in strategies.iter_mut() {
                f.refactorize(&b0s).unwrap();
            }

            let mut w_f = vec![0.0; m];
            let mut w_ref = vec![0.0; m];
            let mut w_sp = vec![0.0; m];
            let mut w_piv = vec![0.0; m];
            for step in 0..20 {
                // Current-basis oracle.
                let mut bmat = Matrix::zeros(m, m);
                for (k, col) in cols.iter().enumerate() {
                    for i in 0..m {
                        bmat[(i, k)] = col[i];
                    }
                }
                let fresh = LuFactors::factor(&bmat).unwrap();

                let v: Vec<f64> = (0..m).map(|_| rng.range_f64(-1.0, 1.0)).collect();
                fresh.solve_into(&v, &mut w_ref);
                for f in strategies.iter_mut() {
                    let ctx = format!("m={m} step={step} {} ftran", f.name());
                    f.ftran(&v, &mut w_f);
                    assert_vec_close(&w_f, &w_ref, 1e-7, &ctx);
                    // Sparse kernel agrees with the dense adapter.
                    let mut vs = sv(&v);
                    f.ftran_sparse(&mut vs);
                    vs.copy_into_dense(&mut w_sp);
                    assert_vec_close(&w_sp, &w_f, 1e-10, &ctx);
                }

                let mut s = vec![0.0; m];
                fresh.solve_transpose_into(&v, &mut s, &mut w_ref);
                for f in strategies.iter_mut() {
                    let ctx = format!("m={m} step={step} {} btran", f.name());
                    f.btran(&v, &mut w_f);
                    assert_vec_close(&w_f, &w_ref, 1e-7, &ctx);
                    let mut vs = sv(&v);
                    f.btran_sparse(&mut vs);
                    vs.copy_into_dense(&mut w_sp);
                    assert_vec_close(&w_sp, &w_f, 1e-10, &ctx);
                }

                // Pivot: a random pool column enters at a row where the
                // FTRAN result is comfortably nonzero (chosen via the
                // oracle so every strategy takes the same pivot).
                let aq = &pool[rng.range_usize(0, pool.len())];
                fresh.solve_into(aq, &mut w_piv);
                let Some(r) = (0..m).max_by(|&a, &b| {
                    w_piv[a].abs().partial_cmp(&w_piv[b].abs()).unwrap()
                }) else {
                    break;
                };
                if w_piv[r].abs() < 1e-6 {
                    continue;
                }
                for f in strategies.iter_mut() {
                    f.ftran(aq, &mut w_f);
                    f.update(r, &sv(&w_f)).unwrap();
                }
                cols[r] = aq.clone();
            }
            let updates = strategies[0].update_len();
            for f in &strategies {
                assert_eq!(f.update_len(), updates, "{}", f.name());
            }
        }
    }

    #[test]
    fn identity_reset_solves_trivially() {
        for strategy in ALL {
            let mut f = strategy.build(4);
            let v = [1.0, -2.0, 3.0, 0.5];
            let mut out = [0.0; 4];
            f.ftran(&v, &mut out);
            assert_vec_close(&out, &v, 1e-12, strategy.as_str());
            f.btran(&v, &mut out);
            assert_vec_close(&out, &v, 1e-12, strategy.as_str());
            let mut s = sv(&v);
            f.ftran_sparse(&mut s);
            s.copy_into_dense(&mut out);
            assert_vec_close(&out, &v, 1e-12, strategy.as_str());
            assert_eq!(f.update_len(), 0);
            assert!(!f.should_refactorize());
        }
    }

    #[test]
    fn singular_refactorization_rejected() {
        let b = SparseMatrix::zeros(3, 3);
        for strategy in ALL {
            let mut f = strategy.build(3);
            assert!(f.refactorize(&b).is_err(), "{}", strategy.as_str());
        }
    }

    /// The O(m²)-memory regression guard: on a sparse basis, both
    /// strategies must store O(nnz) — far below the two dense `m × m`
    /// buffers the old Forrest–Tomlin carried — even after a long
    /// update sequence.
    #[test]
    fn factor_storage_stays_sparse() {
        let m = 120;
        let mut rng = Pcg32::new(7);
        // Bidiagonal-ish basis: ~2 entries per column, like the DLT
        // timing chains.
        let mut trips: Vec<(usize, usize, f64)> = Vec::new();
        for j in 0..m {
            trips.push((j, j, 2.0 + rng.f64()));
            if j + 1 < m {
                trips.push((j + 1, j, rng.range_f64(-1.0, 1.0)));
            }
        }
        let b = SparseMatrix::from_triplets(m, m, &trips);
        for strategy in ALL {
            let mut f = strategy.build(m);
            f.refactorize(&b).unwrap();
            // A few sparse updates so the update file is exercised too.
            let mut w = SparseVector::with_dim(m);
            for k in 0..10 {
                let q = (11 * k + 3) % m;
                w.clear();
                w.set(q, 1.5);
                if q + 1 < m {
                    w.set(q + 1, -0.5);
                }
                f.ftran_sparse(&mut w);
                let r = w
                    .indices()
                    .iter()
                    .copied()
                    .max_by(|&a, &b| w.get(a).abs().partial_cmp(&w.get(b).abs()).unwrap())
                    .unwrap();
                if w.get(r).abs() < 1e-6 {
                    continue;
                }
                f.update(r, &w).unwrap();
            }
            let nnz = f.storage_nnz();
            assert!(
                nnz < m * m / 8,
                "{}: {} stored entries on a {}-row basis (dense pair would be {})",
                f.name(),
                nnz,
                m,
                2 * m * m
            );
        }
    }

    #[test]
    fn wire_names_roundtrip() {
        for f in ALL {
            assert_eq!(Factorization::parse(f.as_str()), Some(f));
        }
        assert_eq!(Factorization::parse("cholesky"), None);
        assert_eq!(Factorization::parse("bartels-golub"), None, "wire names are snake_case");
    }

    /// The Bartels–Golub interchange branch must actually fire and the
    /// factors must stay exact through it: pivot a column whose FTRAN
    /// puts a large traveling entry over a small stationary diagonal.
    #[test]
    fn bartels_golub_interchange_branch_stays_exact() {
        let m = 5;
        // Upper-bidiagonal basis with a deliberately tiny diagonal in
        // the middle so the traveling row dominates it.
        let mut b0 = Matrix::zeros(m, m);
        for i in 0..m {
            b0[(i, i)] = if i == 2 { 1e-3 } else { 1.0 };
            if i + 1 < m {
                b0[(i, i + 1)] = 0.7;
            }
        }
        let mut bg = BartelsGolub::new(m);
        bg.refactorize(&SparseMatrix::from_dense(&b0, 0.0)).unwrap();

        // Enter a dense-ish column at row 0 so the relocated row sweeps
        // across the tiny diagonal.
        let aq: Vec<f64> = vec![1.0, 0.5, 2.0, -0.5, 0.25];
        let mut w = vec![0.0; m];
        bg.ftran(&aq, &mut w);
        bg.update(0, &sv(&w)).unwrap();
        assert!(
            bg.ops.iter().any(|op| matches!(op, BgOp::Swap { .. })),
            "expected at least one stability interchange"
        );

        // Against a fresh LU of the updated basis.
        let mut bmat = b0.clone();
        for i in 0..m {
            bmat[(i, 0)] = aq[i];
        }
        let fresh = LuFactors::factor(&bmat).unwrap();
        let v: Vec<f64> = vec![0.3, -1.0, 0.9, 0.1, -0.4];
        let mut w_ref = vec![0.0; m];
        fresh.solve_into(&v, &mut w_ref);
        bg.ftran(&v, &mut w);
        assert_vec_close(&w, &w_ref, 1e-9, "bg interchange ftran");
        let mut scratch = vec![0.0; m];
        fresh.solve_transpose_into(&v, &mut scratch, &mut w_ref);
        bg.btran(&v, &mut w);
        assert_vec_close(&w, &w_ref, 1e-9, "bg interchange btran");
    }
}
