//! Basis-factorization strategies for the revised simplex.
//!
//! The revised method never forms `B⁻¹`; it keeps a factorization of
//! the basis matrix `B` and answers two queries per iteration — FTRAN
//! (`B⁻¹v`) and BTRAN (`B⁻ᵀv`) — plus a rank-one *update* per pivot
//! (column `q` replaces the column basic in row `r`). How that update
//! is represented is a classic engineering trade-off, so it is a
//! strategy layer ([`BasisFactorization`]) with two implementations:
//!
//! - [`ProductFormEta`] — the original behavior, extracted from
//!   `lp/revised.rs`: a sparse LU of the last refactorization plus a
//!   *product-form eta file* (one sparse column per pivot), with a full
//!   refactorization every 48 pivots to bound drift. Cheap per update
//!   (O(nnz(w))), but the eta file both grows and loses accuracy
//!   quickly, forcing the short refactorization cadence.
//! - [`ForrestTomlin`] — Forrest–Tomlin LU updating: the
//!   upper-triangular factor `U` is maintained *explicitly*. A pivot
//!   replaces one column of `U` with the spike `L⁻¹A_q`, cyclically
//!   permutes the spiked index to the border, and eliminates the lone
//!   off-triangular row with multipliers that are absorbed into the
//!   `L⁻¹` operator chain. `U` is stored *densely*, so an update costs
//!   O(m²) worst case (spike product + bordering rotation) against the
//!   eta file's O(nnz(w)) — the trade is that `U` stays genuinely
//!   triangular and accurate for hundreds of pivots, making full
//!   O(m³) refactorizations rare: the win the ROADMAP's
//!   long-pivot-sequence bullet asks for. (A sparse-row `U` is the
//!   natural next impl behind the same trait if basis sizes outgrow
//!   the dense representation.)
//!
//! Both implementations are driven identically by the primal
//! phase-1/phase-2 loops, the dual-simplex repair pass and the
//! artificial-eviction sweep in [`super::revised`]; the driver decides
//! *when* to refactorize (periodically via [`should_refactorize`],
//! and whenever an optimality/unboundedness verdict must be re-checked
//! at full accuracy), the strategy decides *how*.
//!
//! [`should_refactorize`]: BasisFactorization::should_refactorize

use crate::error::{Error, Result};
use crate::linalg::{LuFactors, Matrix};

/// Refactorize the product-form eta file after this many updates.
const PFE_REFACTOR_EVERY: usize = 48;
/// Refactorize the Forrest–Tomlin factors after this many updates (the
/// explicit `U` stays accurate far longer than an eta file).
const FT_REFACTOR_EVERY: usize = 192;
/// Safety valve: refactorize when the absorbed `L⁻¹` operator chain
/// grows past this many entries per basis row.
const FT_OPS_PER_ROW: usize = 16;

/// Which basis-factorization strategy maintains `B⁻¹` (selected via
/// [`super::SimplexOptions::factorization`], threaded end-to-end from
/// the `dlt::api` wire options and the CLI flags).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Factorization {
    /// Sparse LU + product-form eta file (extracted legacy behavior).
    #[default]
    ProductFormEta,
    /// Forrest–Tomlin LU updating (explicit `U`, rare refactorization).
    ForrestTomlin,
}

impl Factorization {
    /// Stable wire name (`product_form_eta` / `forrest_tomlin`).
    pub fn as_str(self) -> &'static str {
        match self {
            Factorization::ProductFormEta => "product_form_eta",
            Factorization::ForrestTomlin => "forrest_tomlin",
        }
    }

    /// Parse a wire name; `None` for anything unknown.
    pub fn parse(s: &str) -> Option<Factorization> {
        match s {
            "product_form_eta" => Some(Factorization::ProductFormEta),
            "forrest_tomlin" => Some(Factorization::ForrestTomlin),
            _ => None,
        }
    }

    /// Instantiate the strategy for an `m`-row basis.
    pub(crate) fn build(self, m: usize) -> Box<dyn BasisFactorization> {
        match self {
            Factorization::ProductFormEta => Box::new(ProductFormEta::new(m)),
            Factorization::ForrestTomlin => Box::new(ForrestTomlin::new(m)),
        }
    }
}

/// One basis-factorization strategy. All vectors are length `m` (the
/// basis dimension) and indexed by constraint row / basis position.
pub trait BasisFactorization {
    /// Strategy name (diagnostics).
    fn name(&self) -> &'static str;

    /// Reset to the identity basis (`B = I`, the slack/artificial cold
    /// start).
    fn reset_identity(&mut self);

    /// Replace the factorization with a fresh one of `b`. Errors when
    /// `b` is (numerically) singular; the strategy is left ready for
    /// [`BasisFactorization::reset_identity`].
    fn refactorize(&mut self, b: &Matrix) -> Result<()>;

    /// FTRAN: `out = B⁻¹ v`.
    fn ftran(&mut self, v: &[f64], out: &mut [f64]);

    /// BTRAN: `out = B⁻ᵀ v`.
    fn btran(&mut self, v: &[f64], out: &mut [f64]);

    /// Record a pivot: the entering column replaces the column basic in
    /// row `r`, where `w = B⁻¹ A_q` is the result of the FTRAN the
    /// driver just performed for that column. An error signals
    /// numerical breakdown — the caller must refactorize from the (new)
    /// basis before the factorization is used again.
    fn update(&mut self, r: usize, w: &[f64]) -> Result<()>;

    /// Updates recorded since the last (re)factorization (eta count,
    /// or Forrest–Tomlin spike count).
    fn update_len(&self) -> usize;

    /// True when the update file is long enough that the driver should
    /// refactorize before the next pivot.
    fn should_refactorize(&self) -> bool;
}

/// One product-form eta: the pivot column `w = B_prev⁻¹ A_q` recorded
/// at pivot row `r` (entries exclude row `r`, whose value is `wr`).
struct Eta {
    r: usize,
    wr: f64,
    entries: Vec<(usize, f64)>,
}

/// Sparse LU of the last refactorization plus a product-form eta file —
/// the behavior `lp/revised.rs` hardwired before this layer existed.
pub struct ProductFormEta {
    m: usize,
    lu: LuFactors,
    etas: Vec<Eta>,
    // BTRAN scratch (eta application happens before the LU transpose
    // solve, which itself needs a scratch vector).
    u: Vec<f64>,
    t: Vec<f64>,
}

impl ProductFormEta {
    /// Identity-basis start.
    pub fn new(m: usize) -> ProductFormEta {
        ProductFormEta {
            m,
            lu: LuFactors::identity(m),
            etas: Vec::new(),
            u: vec![0.0; m],
            t: vec![0.0; m],
        }
    }
}

impl BasisFactorization for ProductFormEta {
    fn name(&self) -> &'static str {
        "product_form_eta"
    }

    fn reset_identity(&mut self) {
        self.lu = LuFactors::identity(self.m);
        self.etas.clear();
    }

    fn refactorize(&mut self, b: &Matrix) -> Result<()> {
        self.lu = LuFactors::factor(b)?;
        self.etas.clear();
        Ok(())
    }

    fn ftran(&mut self, v: &[f64], out: &mut [f64]) {
        self.lu.solve_into(v, out);
        for eta in &self.etas {
            let ur = out[eta.r] / eta.wr;
            if ur != 0.0 {
                for &(i, wi) in &eta.entries {
                    out[i] -= wi * ur;
                }
            }
            out[eta.r] = ur;
        }
    }

    fn btran(&mut self, v: &[f64], out: &mut [f64]) {
        self.u.copy_from_slice(v);
        for eta in self.etas.iter().rev() {
            let mut acc = self.u[eta.r];
            for &(i, wi) in &eta.entries {
                acc -= wi * self.u[i];
            }
            self.u[eta.r] = acc / eta.wr;
        }
        self.lu.solve_transpose_into(&self.u, &mut self.t, out);
    }

    fn update(&mut self, r: usize, w: &[f64]) -> Result<()> {
        let wr = w[r];
        if wr.abs() < 1e-13 {
            return Err(Error::Numerical(format!(
                "product-form eta: pivot element {wr:.3e} too small in row {r}"
            )));
        }
        let mut entries = Vec::new();
        for (i, &wi) in w.iter().enumerate() {
            if i != r && wi.abs() > 1e-12 {
                entries.push((i, wi));
            }
        }
        self.etas.push(Eta { r, wr, entries });
        Ok(())
    }

    fn update_len(&self) -> usize {
        self.etas.len()
    }

    fn should_refactorize(&self) -> bool {
        self.etas.len() >= PFE_REFACTOR_EVERY
    }
}

/// One operation absorbed into the `L⁻¹` chain by a Forrest–Tomlin
/// update, recorded in application order.
enum LOp {
    /// Left-rotate `z[from..m]` by one (row `from` moves to the end) —
    /// the symmetric cyclic permutation that borders the spiked index.
    Cycle { from: usize },
    /// `z[row] -= mult * z[col]` — elimination of one entry of the
    /// relocated row.
    Elim { row: usize, col: usize, mult: f64 },
}

/// Forrest–Tomlin LU updating over an explicitly maintained `U`.
///
/// Invariant: `B = L' · U_π` where `L'⁻¹` is the composition `ops ∘
/// L₀⁻¹ ∘ P` (initial PLU row permutation and lower factor, then the
/// recorded [`LOp`]s in order), `U` is upper triangular in its own
/// index space, and `pos_to_u` maps basis positions to `U` columns.
pub struct ForrestTomlin {
    m: usize,
    /// `perm[i]` = original row in pivot position `i` of the last PLU.
    perm: Vec<usize>,
    /// Strictly-lower unit-triangular multipliers of the last PLU
    /// (row-major `m × m`; the upper part stays zero).
    l: Vec<f64>,
    /// The maintained upper-triangular factor (row-major `m × m`).
    u: Vec<f64>,
    /// Basis position → `U` index.
    pos_to_u: Vec<usize>,
    /// Row transformations absorbed into `L'⁻¹` since the last
    /// refactorization, in application order.
    ops: Vec<LOp>,
    /// Updates recorded since the last refactorization.
    updates: usize,
    scratch: Vec<f64>,
    scratch2: Vec<f64>,
}

impl ForrestTomlin {
    /// Identity-basis start.
    pub fn new(m: usize) -> ForrestTomlin {
        let mut ft = ForrestTomlin {
            m,
            perm: (0..m).collect(),
            l: vec![0.0; m * m],
            u: vec![0.0; m * m],
            pos_to_u: (0..m).collect(),
            ops: Vec::new(),
            updates: 0,
            scratch: vec![0.0; m],
            scratch2: vec![0.0; m],
        };
        ft.reset_identity();
        ft
    }

    /// `scratch = L'⁻¹ v` (the partial transform that lands in `U`-row
    /// space).
    fn apply_linv(&mut self, v: &[f64]) {
        let m = self.m;
        for i in 0..m {
            self.scratch[i] = v[self.perm[i]];
        }
        for i in 0..m {
            let mut acc = self.scratch[i];
            let row = &self.l[i * m..i * m + i];
            for (j, &lv) in row.iter().enumerate() {
                if lv != 0.0 {
                    acc -= lv * self.scratch[j];
                }
            }
            self.scratch[i] = acc;
        }
        for op in &self.ops {
            match *op {
                LOp::Cycle { from } => {
                    let first = self.scratch[from];
                    for k in from..m - 1 {
                        self.scratch[k] = self.scratch[k + 1];
                    }
                    self.scratch[m - 1] = first;
                }
                LOp::Elim { row, col, mult } => {
                    let zc = self.scratch[col];
                    self.scratch[row] -= mult * zc;
                }
            }
        }
    }
}

impl BasisFactorization for ForrestTomlin {
    fn name(&self) -> &'static str {
        "forrest_tomlin"
    }

    fn reset_identity(&mut self) {
        let m = self.m;
        self.perm.clear();
        self.perm.extend(0..m);
        self.l.iter_mut().for_each(|v| *v = 0.0);
        self.u.iter_mut().for_each(|v| *v = 0.0);
        for i in 0..m {
            self.u[i * m + i] = 1.0;
            self.pos_to_u[i] = i;
        }
        self.ops.clear();
        self.updates = 0;
    }

    fn refactorize(&mut self, b: &Matrix) -> Result<()> {
        let m = self.m;
        debug_assert_eq!(b.rows(), m);
        debug_assert_eq!(b.cols(), m);
        let mut lu = b.data().to_vec();
        let mut perm: Vec<usize> = (0..m).collect();
        for k in 0..m {
            let mut p = k;
            let mut max = lu[k * m + k].abs();
            for i in (k + 1)..m {
                let v = lu[i * m + k].abs();
                if v > max {
                    max = v;
                    p = i;
                }
            }
            if max < 1e-13 {
                return Err(Error::Numerical(format!(
                    "forrest-tomlin: singular basis at pivot {k}"
                )));
            }
            if p != k {
                perm.swap(p, k);
                for j in 0..m {
                    lu.swap(k * m + j, p * m + j);
                }
            }
            let pivot = lu[k * m + k];
            for i in (k + 1)..m {
                let factor = lu[i * m + k] / pivot;
                lu[i * m + k] = factor;
                if factor != 0.0 {
                    for j in (k + 1)..m {
                        let v = lu[k * m + j];
                        if v != 0.0 {
                            lu[i * m + j] -= factor * v;
                        }
                    }
                }
            }
        }
        self.l.iter_mut().for_each(|v| *v = 0.0);
        self.u.iter_mut().for_each(|v| *v = 0.0);
        for i in 0..m {
            for j in 0..m {
                let v = lu[i * m + j];
                if j < i {
                    self.l[i * m + j] = v;
                } else {
                    self.u[i * m + j] = v;
                }
            }
        }
        self.perm = perm;
        for p in 0..m {
            self.pos_to_u[p] = p;
        }
        self.ops.clear();
        self.updates = 0;
        Ok(())
    }

    fn ftran(&mut self, v: &[f64], out: &mut [f64]) {
        let m = self.m;
        self.apply_linv(v);
        // Back-substitute U y = scratch (U-column space).
        for i in (0..m).rev() {
            let mut acc = self.scratch[i];
            let row = &self.u[i * m..(i + 1) * m];
            for (j, s2) in self.scratch2.iter().enumerate().take(m).skip(i + 1) {
                let uv = row[j];
                if uv != 0.0 {
                    acc -= uv * s2;
                }
            }
            self.scratch2[i] = acc / row[i];
        }
        for p in 0..m {
            out[p] = self.scratch2[self.pos_to_u[p]];
        }
    }

    fn btran(&mut self, v: &[f64], out: &mut [f64]) {
        let m = self.m;
        // Permute the input (basis-position space) into U-column space.
        for p in 0..m {
            self.scratch2[self.pos_to_u[p]] = v[p];
        }
        // Forward-substitute Uᵀ s = c (Uᵀ is lower triangular).
        for j in 0..m {
            let mut acc = self.scratch2[j];
            for i in 0..j {
                let uv = self.u[i * m + j];
                if uv != 0.0 {
                    acc -= uv * self.scratch[i];
                }
            }
            self.scratch[j] = acc / self.u[j * m + j];
        }
        // y = L'⁻ᵀ s: transposed ops in reverse order, then L₀⁻ᵀ and Pᵀ.
        for op in self.ops.iter().rev() {
            match *op {
                LOp::Cycle { from } => {
                    // Transpose of a left-rotation is the right-rotation.
                    let last = self.scratch[m - 1];
                    for k in (from..m - 1).rev() {
                        self.scratch[k + 1] = self.scratch[k];
                    }
                    self.scratch[from] = last;
                }
                LOp::Elim { row, col, mult } => {
                    let zr = self.scratch[row];
                    self.scratch[col] -= mult * zr;
                }
            }
        }
        for i in (0..m).rev() {
            let mut acc = self.scratch[i];
            for j in i + 1..m {
                let lv = self.l[j * m + i];
                if lv != 0.0 {
                    acc -= lv * self.scratch[j];
                }
            }
            self.scratch[i] = acc;
        }
        for i in 0..m {
            out[self.perm[i]] = self.scratch[i];
        }
    }

    fn update(&mut self, r: usize, w: &[f64]) -> Result<()> {
        let m = self.m;
        // w (basis-position space) → U-column space.
        for p in 0..m {
            self.scratch2[self.pos_to_u[p]] = w[p];
        }
        // Spike v = U · w (U-row space): the partial FTRAN L'⁻¹A_q
        // recovered without re-touching the constraint matrix.
        for i in 0..m {
            let row = &self.u[i * m..(i + 1) * m];
            let mut acc = 0.0;
            for (j, s2) in self.scratch2.iter().enumerate().take(m).skip(i) {
                let uv = row[j];
                if uv != 0.0 {
                    acc += uv * s2;
                }
            }
            self.scratch[i] = acc;
        }
        let t = self.pos_to_u[r];
        // Replace column t of U with the spike.
        for i in 0..m {
            self.u[i * m + t] = self.scratch[i];
        }
        // Border the spiked index: symmetric cyclic rotation t..m-1.
        if t + 1 < m {
            self.scratch.copy_from_slice(&self.u[t * m..(t + 1) * m]);
            for i in t..m - 1 {
                self.u.copy_within((i + 1) * m..(i + 2) * m, i * m);
            }
            self.u[(m - 1) * m..m * m].copy_from_slice(&self.scratch);
            for i in 0..m {
                let row = &mut self.u[i * m..(i + 1) * m];
                let save = row[t];
                for j in t..m - 1 {
                    row[j] = row[j + 1];
                }
                row[m - 1] = save;
            }
            self.ops.push(LOp::Cycle { from: t });
            for p in 0..m {
                let u = self.pos_to_u[p];
                if u == t {
                    self.pos_to_u[p] = m - 1;
                } else if u > t {
                    self.pos_to_u[p] = u - 1;
                }
            }
        }
        // The relocated row (old row t, now row m-1) is the only
        // off-triangular part: eliminate its entries in columns
        // t..m-2, absorbing the multipliers into the L'⁻¹ chain.
        for j in t..m.saturating_sub(1) {
            let e = self.u[(m - 1) * m + j];
            if e == 0.0 {
                continue;
            }
            let d = self.u[j * m + j];
            if d.abs() < 1e-12 {
                return Err(Error::Numerical(format!(
                    "forrest-tomlin: zero diagonal {d:.3e} during update at column {j}"
                )));
            }
            let mult = e / d;
            if mult.abs() > 1e9 {
                return Err(Error::Numerical(format!(
                    "forrest-tomlin: unstable multiplier {mult:.3e} during update"
                )));
            }
            for k in j..m {
                let v = self.u[j * m + k];
                if v != 0.0 {
                    self.u[(m - 1) * m + k] -= mult * v;
                }
            }
            self.u[(m - 1) * m + j] = 0.0;
            self.ops.push(LOp::Elim { row: m - 1, col: j, mult });
        }
        if self.u[(m - 1) * m + (m - 1)].abs() < 1e-12 {
            return Err(Error::Numerical(
                "forrest-tomlin: singular updated factor".into(),
            ));
        }
        self.updates += 1;
        Ok(())
    }

    fn update_len(&self) -> usize {
        self.updates
    }

    fn should_refactorize(&self) -> bool {
        self.updates >= FT_REFACTOR_EVERY || self.ops.len() >= FT_OPS_PER_ROW * self.m + 512
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Pcg32, Rng};

    fn random_nonsingular(rng: &mut Pcg32, m: usize) -> Matrix {
        let mut b = Matrix::zeros(m, m);
        for i in 0..m {
            for j in 0..m {
                // Diagonally dominant → safely nonsingular.
                b[(i, j)] = if i == j { 4.0 + rng.range_f64(0.0, 2.0) } else { rng.range_f64(-1.0, 1.0) };
            }
        }
        b
    }

    fn assert_vec_close(a: &[f64], b: &[f64], tol: f64, ctx: &str) {
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert!((x - y).abs() < tol, "{ctx}: index {i}: {x} vs {y}");
        }
    }

    /// Both strategies, driven through a random pivot sequence, must
    /// agree with a from-scratch LU of the current basis on FTRAN and
    /// BTRAN.
    #[test]
    fn strategies_agree_with_fresh_lu_under_updates() {
        let mut rng = Pcg32::new(99);
        for m in [1usize, 2, 4, 7, 12] {
            // A pool of candidate columns to pivot in.
            let pool: Vec<Vec<f64>> = (0..3 * m)
                .map(|_| (0..m).map(|_| rng.range_f64(-2.0, 2.0)).collect())
                .collect();
            let b0 = random_nonsingular(&mut rng, m);
            let mut cols: Vec<Vec<f64>> =
                (0..m).map(|k| (0..m).map(|i| b0[(i, k)]).collect()).collect();

            let mut pfe = ProductFormEta::new(m);
            let mut ft = ForrestTomlin::new(m);
            pfe.refactorize(&b0).unwrap();
            ft.refactorize(&b0).unwrap();

            let mut w_pfe = vec![0.0; m];
            let mut w_ft = vec![0.0; m];
            let mut w_ref = vec![0.0; m];
            for step in 0..20 {
                // Current-basis oracle.
                let mut bmat = Matrix::zeros(m, m);
                for (k, col) in cols.iter().enumerate() {
                    for i in 0..m {
                        bmat[(i, k)] = col[i];
                    }
                }
                let fresh = LuFactors::factor(&bmat).unwrap();

                let v: Vec<f64> = (0..m).map(|_| rng.range_f64(-1.0, 1.0)).collect();
                fresh.solve_into(&v, &mut w_ref);
                pfe.ftran(&v, &mut w_pfe);
                ft.ftran(&v, &mut w_ft);
                assert_vec_close(&w_pfe, &w_ref, 1e-7, &format!("m={m} step={step} pfe ftran"));
                assert_vec_close(&w_ft, &w_ref, 1e-7, &format!("m={m} step={step} ft ftran"));

                let mut s = vec![0.0; m];
                fresh.solve_transpose_into(&v, &mut s, &mut w_ref);
                pfe.btran(&v, &mut w_pfe);
                ft.btran(&v, &mut w_ft);
                assert_vec_close(&w_pfe, &w_ref, 1e-7, &format!("m={m} step={step} pfe btran"));
                assert_vec_close(&w_ft, &w_ref, 1e-7, &format!("m={m} step={step} ft btran"));

                // Pivot: a random pool column enters at a row where the
                // FTRAN result is comfortably nonzero.
                let aq = &pool[rng.range_usize(0, pool.len())];
                pfe.ftran(aq, &mut w_pfe);
                let Some(r) = (0..m).max_by(|&a, &b| {
                    w_pfe[a].abs().partial_cmp(&w_pfe[b].abs()).unwrap()
                }) else {
                    break;
                };
                if w_pfe[r].abs() < 1e-6 {
                    continue;
                }
                ft.ftran(aq, &mut w_ft);
                pfe.update(r, &w_pfe).unwrap();
                ft.update(r, &w_ft).unwrap();
                cols[r] = aq.clone();
            }
            assert_eq!(pfe.update_len(), ft.update_len());
        }
    }

    #[test]
    fn identity_reset_solves_trivially() {
        for strategy in [Factorization::ProductFormEta, Factorization::ForrestTomlin] {
            let mut f = strategy.build(4);
            let v = [1.0, -2.0, 3.0, 0.5];
            let mut out = [0.0; 4];
            f.ftran(&v, &mut out);
            assert_vec_close(&out, &v, 1e-12, strategy.as_str());
            f.btran(&v, &mut out);
            assert_vec_close(&out, &v, 1e-12, strategy.as_str());
            assert_eq!(f.update_len(), 0);
            assert!(!f.should_refactorize());
        }
    }

    #[test]
    fn singular_refactorization_rejected() {
        let b = Matrix::zeros(3, 3);
        for strategy in [Factorization::ProductFormEta, Factorization::ForrestTomlin] {
            let mut f = strategy.build(3);
            assert!(f.refactorize(&b).is_err(), "{}", strategy.as_str());
        }
    }

    #[test]
    fn wire_names_roundtrip() {
        for f in [Factorization::ProductFormEta, Factorization::ForrestTomlin] {
            assert_eq!(Factorization::parse(f.as_str()), Some(f));
        }
        assert_eq!(Factorization::parse("bartels_golub"), None);
    }
}
