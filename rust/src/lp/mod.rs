//! From-scratch linear-programming substrate.
//!
//! The paper solves every scheduling instance "by linear programming
//! techniques"; this module is that solver. It is a dense two-phase
//! primal simplex with Dantzig pricing, Bland anti-cycling fallback,
//! a light presolve, and dual extraction — no external LP dependency.
//!
//! All variables are non-negative (`x ≥ 0`), which matches every
//! formulation in the paper (load fractions, timestamps and the
//! makespan are all non-negative physical quantities).
//!
//! ```
//! use dlt::lp::{LpProblem, Cmp, solve};
//! // min -x0 - 2 x1  s.t.  x0 + x1 <= 4,  x1 <= 2
//! let mut p = LpProblem::new(2);
//! p.set_objective(&[-1.0, -2.0]);
//! p.add_constraint(&[(0, 1.0), (1, 1.0)], Cmp::Le, 4.0);
//! p.add_constraint(&[(1, 1.0)], Cmp::Le, 2.0);
//! let s = solve(&p).unwrap();
//! assert!((s.objective - (-6.0)).abs() < 1e-9);
//! ```

pub mod presolve;
pub mod problem;
pub mod simplex;
pub mod solution;
pub mod standard;

pub use problem::{Cmp, Constraint, LpProblem};
pub use simplex::{solve, solve_with, SimplexOptions};
pub use solution::LpSolution;
pub use standard::StandardForm;
