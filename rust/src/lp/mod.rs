//! From-scratch linear-programming substrate.
//!
//! The paper solves every scheduling instance "by linear programming
//! techniques"; this module is that solver. The default backend is a
//! revised simplex over sparse column storage with basis warm starts
//! ([`revised`]); its two per-pivot policies are strategy layers —
//! basis factorization ([`factorization`]: product-form eta file,
//! Markowitz-ordered refactorization, Forrest–Tomlin or Bartels–Golub
//! LU updates, all with hypersparse FTRAN/BTRAN
//! kernels) and pricing ([`pricing`]: Dantzig, devex, steepest edge,
//! candidate-list partial) — selected through [`SimplexOptions`] and
//! threaded end-to-end from the `dlt::api` wire options. Work buffers
//! live in a per-worker [`scratch::SolverScratch`] pool so warm
//! re-solves allocate nothing in steady state. The original
//! dense two-phase tableau remains available as a fallback/oracle
//! ([`simplex::SolverBackend::DenseTableau`]). Both backends keep a
//! Bland anti-cycling fallback and extract duals — no external LP
//! dependency. Warm restarts whose
//! cached basis went primal-infeasible are repaired by a dual-simplex
//! pass ([`revised`]), and [`presolve`] reduces problems (fixed
//! variables, vacuous/duplicate/empty rows) with exact solution and
//! dual restoration — the scenario pipeline ([`crate::pipeline`]) runs
//! it in front of both backends by default.
//!
//! All variables are non-negative (`x ≥ 0`), which matches every
//! formulation in the paper (load fractions, timestamps and the
//! makespan are all non-negative physical quantities).
//!
//! ```
//! use dlt::lp::{LpProblem, Cmp, solve};
//! // min -x0 - 2 x1  s.t.  x0 + x1 <= 4,  x1 <= 2
//! let mut p = LpProblem::new(2);
//! p.set_objective(&[-1.0, -2.0]);
//! p.add_constraint(&[(0, 1.0), (1, 1.0)], Cmp::Le, 4.0);
//! p.add_constraint(&[(1, 1.0)], Cmp::Le, 2.0);
//! let s = solve(&p).unwrap();
//! assert!((s.objective - (-6.0)).abs() < 1e-9);
//! ```

pub mod factorization;
pub mod presolve;
pub mod pricing;
pub mod problem;
pub mod recovery;
pub mod revised;
pub mod scratch;
pub mod simplex;
pub mod solution;
pub mod standard;
pub mod warm;

pub use factorization::{BasisFactorization, Factorization};
pub use presolve::{presolve, Presolved, PresolveStats};
pub use pricing::{Pricing, PricingRule};
pub use problem::{Cmp, Constraint, LpProblem};
pub use recovery::{solve_with_recovery, SolveBudget};
pub use revised::Basis;
pub use scratch::SolverScratch;
pub use simplex::{solve, solve_warm, solve_warm_scratch, solve_with, SimplexOptions, SolverBackend};
pub use solution::LpSolution;
pub use standard::StandardForm;
pub use warm::WarmCache;
