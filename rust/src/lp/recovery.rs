//! Fail-operational solving: wall-clock budgets and the structured
//! numerical recovery ladder.
//!
//! Two pieces live here:
//!
//! - [`SolveBudget`] — a wall-clock deadline carried inside
//!   [`SimplexOptions`] and [`crate::pdhg::PdhgOptions`] and checked
//!   (amortized, every 64 iterations) inside every solver inner loop.
//!   Expiry surfaces as a typed [`Error::DeadlineExceeded`] carrying
//!   the elapsed time, the iterations completed, and the phase that
//!   expired — never a silent open-loop run. The iteration cap lives
//!   next door in [`SimplexOptions::max_iters`]; together they bound a
//!   solve in both time and work.
//! - [`solve_with_recovery`] — the deterministic escalation ladder the
//!   revised backend runs behind. A solve that fails *numerically*
//!   (singular or ill-conditioned refactorization, residual artificial
//!   mass after phase 2) is retried rung by rung:
//!
//!   1. the configured solve itself (which already refactorizes early
//!      on update breakdown and engages Bland's rule on stalls — both
//!      recorded as in-solve events);
//!   2. `markowitz_retry` — a cold restart under Markowitz threshold
//!      pivoting, the most numerically careful factorization;
//!   3. `bland_perturbed` — instant Bland anti-cycling over a
//!      deterministically rhs-perturbed copy of the problem (the
//!      objective is re-evaluated on the *original* problem);
//!   4. `dense_oracle` — the dense two-phase tableau, the crate's
//!      cross-check oracle;
//!   5. a typed [`Error::Numerical`] listing every rung tried.
//!
//!   `Infeasible` / `Unbounded` verdicts and expired deadlines stop
//!   the ladder immediately — escalation is for numerical trouble
//!   only. Every rung taken is recorded in
//!   [`LpSolution::recovery_events`], which rides the wire as
//!   `Diagnostics.recovery_events`.

use std::time::{Duration, Instant};

use super::factorization::Factorization;
use super::problem::LpProblem;
use super::revised::{self, Basis};
use super::scratch::SolverScratch;
use super::simplex::{self, SimplexOptions, SolverBackend};
use super::solution::LpSolution;
use crate::error::{Error, Result};

/// Wall-clock budget for one solve. `Copy` and two words wide so it
/// travels inside option structs for free; the unbounded default makes
/// every existing call site a no-op (one branch per amortized check,
/// no clock read).
#[derive(Debug, Clone, Copy)]
pub struct SolveBudget {
    started: Instant,
    deadline: Option<Instant>,
}

impl Default for SolveBudget {
    fn default() -> Self {
        SolveBudget::unbounded()
    }
}

impl SolveBudget {
    /// A budget that never expires (the default).
    pub fn unbounded() -> SolveBudget {
        SolveBudget { started: Instant::now(), deadline: None }
    }

    /// Budget starting now with an optional `timeout_ms` deadline;
    /// `None` is unbounded.
    pub fn from_timeout_ms(timeout_ms: Option<u64>) -> SolveBudget {
        let started = Instant::now();
        SolveBudget { started, deadline: timeout_ms.map(|ms| started + Duration::from_millis(ms)) }
    }

    /// True when a deadline is set (bounded budget).
    pub fn is_bounded(&self) -> bool {
        self.deadline.is_some()
    }

    /// True once the deadline has passed. Unbounded budgets never
    /// expire and never read the clock.
    #[inline]
    pub fn expired(&self) -> bool {
        match self.deadline {
            Some(d) => Instant::now() >= d,
            None => false,
        }
    }

    /// Milliseconds since the budget was created.
    pub fn elapsed_ms(&self) -> u64 {
        self.started.elapsed().as_millis() as u64
    }

    /// Milliseconds left before expiry (`None` when unbounded, 0 once
    /// expired). The serving tier uses this to shrink a queued
    /// request's solve budget by its queue age.
    pub fn remaining_ms(&self) -> Option<u64> {
        self.deadline.map(|d| d.saturating_duration_since(Instant::now()).as_millis() as u64)
    }

    /// Return [`Error::DeadlineExceeded`] if the budget expired. Call
    /// sites amortize this (`iterations & 63 == 0`) so the hot path
    /// pays one integer branch per pivot, not a clock read.
    #[inline]
    pub fn check(&self, iterations: usize, phase: &str) -> Result<()> {
        if self.expired() {
            return Err(Error::DeadlineExceeded {
                elapsed_ms: self.elapsed_ms(),
                iterations,
                phase: phase.into(),
            });
        }
        Ok(())
    }
}

/// Ladder rung names as they appear in `recovery_events` (the wire
/// names — keep stable).
pub const MARKOWITZ_RETRY: &str = "markowitz_retry";
/// See [`MARKOWITZ_RETRY`].
pub const BLAND_PERTURBED: &str = "bland_perturbed";
/// See [`MARKOWITZ_RETRY`].
pub const DENSE_ORACLE: &str = "dense_oracle";

/// The revised backend's front door: the configured solve, then the
/// recovery ladder on numerical failure. This is what
/// [`simplex::solve_warm`] / [`simplex::solve_warm_scratch`] route the
/// [`SolverBackend::RevisedSparse`] arm through, so every caller —
/// warm caches, the pipeline, the API and serve tiers — inherits the
/// ladder without opting in.
pub fn solve_with_recovery(
    p: &LpProblem,
    opts: &SimplexOptions,
    warm: Option<&Basis>,
    scratch: &mut SolverScratch,
) -> Result<LpSolution> {
    match revised::solve_revised_scratch(p, opts, warm, scratch) {
        Ok(sol) => Ok(sol),
        Err(Error::Numerical(msg)) => escalate(p, opts, scratch, msg),
        Err(e) => Err(e),
    }
}

/// Rungs 2..4 of the ladder, in order, stopping at the first success
/// (or the first non-numerical verdict, which is authoritative).
fn escalate(
    p: &LpProblem,
    opts: &SimplexOptions,
    scratch: &mut SolverScratch,
    first: String,
) -> Result<LpSolution> {
    let mut events: Vec<String> = Vec::new();
    let mut last = first;

    events.push(MARKOWITZ_RETRY.into());
    opts.budget.check(0, "recovery")?;
    match rung_markowitz(p, opts, scratch) {
        Ok(sol) => return Ok(finish(sol, events)),
        Err(Error::Numerical(msg)) => last = msg,
        Err(e) => return Err(e),
    }

    events.push(BLAND_PERTURBED.into());
    opts.budget.check(0, "recovery")?;
    match rung_bland_perturbed(p, opts, scratch) {
        Ok(sol) => return Ok(finish(sol, events)),
        Err(Error::Numerical(msg)) => last = msg,
        Err(e) => return Err(e),
    }

    events.push(DENSE_ORACLE.into());
    opts.budget.check(0, "recovery")?;
    match rung_dense(p, opts) {
        Ok(sol) => return Ok(finish(sol, events)),
        Err(Error::Numerical(msg)) => last = msg,
        Err(e) => return Err(e),
    }

    Err(Error::Numerical(format!(
        "recovery ladder exhausted ({}): {last}",
        events.join(", ")
    )))
}

/// Prepend the ladder rungs taken to the solution's own in-solve
/// events (the rung engaged first, then whatever its solve recorded).
fn finish(mut sol: LpSolution, mut events: Vec<String>) -> LpSolution {
    events.append(&mut sol.recovery_events);
    sol.recovery_events = events;
    sol
}

/// Cold restart under Markowitz threshold pivoting — the most
/// numerically defensive factorization (fresh pivot order per factor,
/// explicit stability threshold).
fn rung_markowitz(
    p: &LpProblem,
    opts: &SimplexOptions,
    scratch: &mut SolverScratch,
) -> Result<LpSolution> {
    let o = SimplexOptions { factorization: Factorization::Markowitz, ..opts.clone() };
    revised::solve_revised_scratch(p, &o, None, scratch)
}

/// Instant Bland anti-cycling (`stall_limit: 0`) over a
/// deterministically rhs-perturbed copy of the problem: the tiny
/// relative perturbation breaks the exact degeneracy that drives
/// cycling and pivot-order pathologies, and the objective is
/// re-evaluated on the *original* problem so callers never see the
/// perturbed value.
fn rung_bland_perturbed(
    p: &LpProblem,
    opts: &SimplexOptions,
    scratch: &mut SolverScratch,
) -> Result<LpSolution> {
    let o = SimplexOptions {
        factorization: Factorization::Markowitz,
        stall_limit: 0,
        ..opts.clone()
    };
    let mut sol = revised::solve_revised_scratch(&perturbed(p), &o, None, scratch)?;
    sol.objective = p.objective_at(&sol.x);
    Ok(sol)
}

/// The dense two-phase tableau oracle (never recurses back into the
/// ladder: only the revised arm routes through recovery).
fn rung_dense(p: &LpProblem, opts: &SimplexOptions) -> Result<LpSolution> {
    let o = SimplexOptions { backend: SolverBackend::DenseTableau, ..opts.clone() };
    simplex::solve_warm(p, &o, None)
}

/// Copy of `p` with each rhs scaled by `1 + 1e-9·(k mod 97 + 1)` — a
/// deterministic, row-dependent perturbation far below the solver's
/// feasibility tolerance.
fn perturbed(p: &LpProblem) -> LpProblem {
    let mut q = LpProblem::new(p.num_vars());
    q.set_objective(p.objective());
    for (k, c) in p.constraints().iter().enumerate() {
        let scale = 1.0 + 1e-9 * (k % 97 + 1) as f64;
        q.add_labeled(&c.coeffs, c.cmp, c.rhs * scale, c.label.clone());
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::problem::Cmp;

    fn textbook() -> LpProblem {
        // max 3x + 5y st x<=4, 2y<=12, 3x+2y<=18 -> obj -36.
        let mut p = LpProblem::new(2);
        p.set_objective(&[-3.0, -5.0]);
        p.add_constraint(&[(0, 1.0)], Cmp::Le, 4.0);
        p.add_constraint(&[(1, 2.0)], Cmp::Le, 12.0);
        p.add_constraint(&[(0, 3.0), (1, 2.0)], Cmp::Le, 18.0);
        p
    }

    #[test]
    fn unbounded_budget_never_expires() {
        let b = SolveBudget::default();
        assert!(!b.is_bounded());
        assert!(!b.expired());
        assert_eq!(b.remaining_ms(), None);
        b.check(1_000_000, "simplex").unwrap();
    }

    #[test]
    fn zero_timeout_expires_immediately() {
        let b = SolveBudget::from_timeout_ms(Some(0));
        assert!(b.is_bounded());
        assert!(b.expired());
        assert_eq!(b.remaining_ms(), Some(0));
        match b.check(7, "simplex") {
            Err(Error::DeadlineExceeded { iterations: 7, phase, .. }) => {
                assert_eq!(phase, "simplex");
            }
            other => panic!("expected deadline exceeded, got {other:?}"),
        }
    }

    #[test]
    fn generous_timeout_does_not_expire() {
        let b = SolveBudget::from_timeout_ms(Some(60_000));
        assert!(!b.expired());
        assert!(b.remaining_ms().unwrap() <= 60_000);
        b.check(0, "simplex").unwrap();
    }

    #[test]
    fn clean_solves_report_no_events() {
        let p = textbook();
        let mut scratch = SolverScratch::new();
        let sol =
            solve_with_recovery(&p, &SimplexOptions::default(), None, &mut scratch).unwrap();
        assert!((sol.objective + 36.0).abs() < 1e-7);
        assert!(sol.recovery_events.is_empty(), "events: {:?}", sol.recovery_events);
    }

    #[test]
    fn ladder_recovers_from_numerical_failure() {
        // Fabricate a rung-1 numerical failure: the ladder must land on
        // the Markowitz retry and record exactly that rung.
        let p = textbook();
        let mut scratch = SolverScratch::new();
        let sol =
            escalate(&p, &SimplexOptions::default(), &mut scratch, "fabricated".into()).unwrap();
        assert!((sol.objective + 36.0).abs() < 1e-7);
        assert_eq!(sol.recovery_events, vec![MARKOWITZ_RETRY.to_string()]);
    }

    #[test]
    fn perturbed_rung_matches_unperturbed_optimum() {
        let p = textbook();
        let mut scratch = SolverScratch::new();
        let sol = rung_bland_perturbed(&p, &SimplexOptions::default(), &mut scratch).unwrap();
        assert!((sol.objective + 36.0).abs() < 1e-6, "objective {}", sol.objective);
        assert!(p.check_feasible(&sol.x, 1e-6).is_none());
    }

    #[test]
    fn dense_rung_is_exact() {
        let p = textbook();
        let sol = rung_dense(&p, &SimplexOptions::default()).unwrap();
        assert!((sol.objective + 36.0).abs() < 1e-7);
    }

    #[test]
    fn infeasible_verdict_stops_the_ladder() {
        let mut p = LpProblem::new(1);
        p.add_constraint(&[(0, 1.0)], Cmp::Le, 1.0);
        p.add_constraint(&[(0, 1.0)], Cmp::Ge, 2.0);
        let mut scratch = SolverScratch::new();
        match escalate(&p, &SimplexOptions::default(), &mut scratch, "fabricated".into()) {
            Err(Error::Infeasible(_)) => {}
            other => panic!("expected infeasible, got {other:?}"),
        }
    }

    #[test]
    fn expired_budget_stops_the_ladder() {
        let p = textbook();
        let opts = SimplexOptions {
            budget: SolveBudget::from_timeout_ms(Some(0)),
            ..SimplexOptions::default()
        };
        let mut scratch = SolverScratch::new();
        match escalate(&p, &opts, &mut scratch, "fabricated".into()) {
            Err(Error::DeadlineExceeded { phase, .. }) => assert_eq!(phase, "recovery"),
            other => panic!("expected deadline exceeded, got {other:?}"),
        }
    }
}
