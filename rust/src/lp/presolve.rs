//! LP presolve: row cleanup + fixed-variable substitution, with exact
//! solution restoration.
//!
//! The pipeline (`crate::pipeline`) runs this in front of both simplex
//! backends by default. Reductions, applied to a fixpoint:
//!
//! - **empty rows** — trivially satisfied rows are dropped, trivially
//!   violated ones report infeasibility immediately;
//! - **vacuous singleton bounds** — `a x ≥ b` with `a > 0, b ≤ 0` (and
//!   the mirrored `≤` form) is implied by `x ≥ 0` and dropped;
//! - **fixed variables** — a singleton equality `a x = b` fixes
//!   `x = b/a`; a singleton `a x ≤ 0` with `a > 0` fixes `x = 0`. The
//!   fixed value is substituted into every other row (rhs adjustment)
//!   and the defining row is removed, which can cascade into new empty
//!   or singleton rows;
//! - **duplicate rows** — exact duplicates (post-substitution bit
//!   patterns) are dropped.
//!
//! The variable *count* is never changed: a fixed variable's column is
//! simply emptied (no constraint or objective coefficients left), so a
//! [`crate::lp::Basis`] of the reduced problem stays meaningful across
//! a scenario family and [`Presolved::restore`] can map a reduced
//! solution back onto the original problem — fixed values re-inserted
//! into `x`, and duals mapped back through the row eliminations
//! (dropped rows get the unique multiplier that keeps the original
//! dual system tight, so strong duality holds on the *original*
//! problem).

use super::problem::{Cmp, LpProblem};
use super::solution::LpSolution;
use crate::error::{Error, Result};

/// Absolute tolerance for presolve decisions (rhs residuals, fixed
/// values). Paper-sized DLT data is O(100), so 1e-9 is conservative.
const TOL: f64 = 1e-9;

/// Presolve statistics.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PresolveStats {
    /// Rows with no coefficients that were trivially satisfied.
    pub empty_rows_dropped: usize,
    /// Exact duplicate rows removed.
    pub duplicate_rows_dropped: usize,
    /// Singleton inequality rows implied by `x >= 0`.
    pub vacuous_bounds_dropped: usize,
    /// Multi-variable rows implied by `x >= 0` plus the upper bounds
    /// of the surviving singleton rows (bound propagation).
    pub redundant_rows_dropped: usize,
    /// Variables fixed by singleton rows and substituted out.
    pub fixed_vars: usize,
}

impl PresolveStats {
    /// Total rows removed by any reduction.
    pub fn rows_dropped(&self) -> usize {
        self.empty_rows_dropped
            + self.duplicate_rows_dropped
            + self.vacuous_bounds_dropped
            + self.redundant_rows_dropped
            + self.fixed_vars
    }
}

/// One variable fixed by a singleton row (in elimination order).
#[derive(Debug, Clone)]
struct FixedVar {
    var: usize,
    value: f64,
    /// Original index of the row that forced the fix.
    row: usize,
}

/// A presolved problem plus everything needed to map a solution of the
/// reduced problem back onto the original one.
#[derive(Debug, Clone)]
pub struct Presolved {
    /// The reduced problem (same variable count, fewer rows).
    pub problem: LpProblem,
    /// What was removed.
    pub stats: PresolveStats,
    /// Reduced row index → original row index.
    row_map: Vec<usize>,
    /// Fixed variables in elimination order.
    fixed: Vec<FixedVar>,
    /// Original constraint count.
    orig_rows: usize,
}

/// Working copy of one constraint during reduction.
struct WorkRow {
    coeffs: Vec<(usize, f64)>,
    cmp: Cmp,
    rhs: f64,
    orig: usize,
    alive: bool,
}

/// Presolve `p` into a reduced problem plus restoration data. Errors
/// with [`Error::Infeasible`] when a reduction proves the problem has
/// no solution (empty row `0 >= 2`, singleton `x <= -1`, ...).
pub fn presolve(p: &LpProblem) -> Result<Presolved> {
    let nv = p.num_vars();
    let mut stats = PresolveStats::default();
    let mut fixed: Vec<FixedVar> = Vec::new();
    let mut fixed_mask = vec![false; nv];

    // Working rows with merged duplicate indices and explicit zeros
    // dropped (mirrors what StandardForm would do anyway).
    let mut rows: Vec<WorkRow> = p
        .constraints()
        .iter()
        .enumerate()
        .map(|(k, con)| {
            let mut sorted = con.coeffs.clone();
            sorted.sort_by_key(|&(v, _)| v);
            let mut merged: Vec<(usize, f64)> = Vec::with_capacity(sorted.len());
            for (v, a) in sorted {
                if let Some(last) = merged.last_mut() {
                    if last.0 == v {
                        last.1 += a;
                        continue;
                    }
                }
                merged.push((v, a));
            }
            merged.retain(|&(_, a)| a != 0.0);
            WorkRow { coeffs: merged, cmp: con.cmp, rhs: con.rhs, orig: k, alive: true }
        })
        .collect();

    // Reduce to a fixpoint: substitutions can empty rows or create new
    // singletons. Each pass either changes something or terminates, and
    // every change strictly shrinks total coefficient count, so this
    // loop is finite without an explicit cap.
    loop {
        let mut changed = false;
        // Decisions taken this pass, applied after the scan (borrow
        // discipline: the scan reads rows, substitution writes them).
        let mut new_fixes: Vec<FixedVar> = Vec::new();

        for row in rows.iter_mut() {
            if !row.alive {
                continue;
            }
            if row.coeffs.is_empty() {
                let ok = match row.cmp {
                    Cmp::Le => 0.0 <= row.rhs + TOL,
                    Cmp::Ge => 0.0 >= row.rhs - TOL,
                    Cmp::Eq => row.rhs.abs() <= TOL,
                };
                if !ok {
                    return Err(Error::Infeasible(format!(
                        "presolve: empty row `{}` requires 0 {} {}",
                        p.constraints()[row.orig].label,
                        row.cmp,
                        row.rhs
                    )));
                }
                row.alive = false;
                stats.empty_rows_dropped += 1;
                changed = true;
                continue;
            }
            if row.coeffs.len() != 1 {
                continue;
            }
            let (v, a) = row.coeffs[0];
            if fixed_mask[v] || new_fixes.iter().any(|f| f.var == v) {
                // The variable was fixed earlier in this pass (or is
                // stale): leave the row for the next pass, where the
                // substitution has been applied — an inconsistent
                // second fix then surfaces as an infeasible empty row.
                continue;
            }
            let (rhs, orig) = (row.rhs, row.orig);
            match row.cmp {
                Cmp::Eq => {
                    let value = rhs / a;
                    if value < -1e-7 {
                        return Err(Error::Infeasible(format!(
                            "presolve: row `{}` fixes {} = {value:.3e} < 0",
                            p.constraints()[orig].label,
                            p.var_name(v)
                        )));
                    }
                    row.alive = false;
                    new_fixes.push(FixedVar { var: v, value: value.max(0.0), row: orig });
                    changed = true;
                }
                Cmp::Le => {
                    // a x <= rhs with x >= 0.
                    if a > 0.0 && rhs < -TOL {
                        return Err(Error::Infeasible(format!(
                            "presolve: row `{}` requires {} <= {:.3e} < 0",
                            p.constraints()[orig].label,
                            p.var_name(v),
                            rhs / a
                        )));
                    } else if a > 0.0 && rhs <= TOL {
                        // x <= 0 with x >= 0: fixed at zero.
                        row.alive = false;
                        new_fixes.push(FixedVar { var: v, value: 0.0, row: orig });
                        changed = true;
                    } else if a < 0.0 && rhs >= -TOL {
                        // -|a| x <= rhs with rhs >= 0: implied by x >= 0.
                        row.alive = false;
                        stats.vacuous_bounds_dropped += 1;
                        changed = true;
                    }
                    // a > 0, rhs > 0: an upper bound — keep the row.
                    // a < 0, rhs < 0: a lower bound — keep the row.
                }
                Cmp::Ge => {
                    // Mirror of Le.
                    if a < 0.0 && rhs > TOL {
                        return Err(Error::Infeasible(format!(
                            "presolve: row `{}` requires {} <= {:.3e} < 0",
                            p.constraints()[orig].label,
                            p.var_name(v),
                            rhs / a
                        )));
                    } else if a < 0.0 && rhs >= -TOL {
                        // -|a| x >= rhs with rhs ~ 0: x <= 0, fixed.
                        row.alive = false;
                        new_fixes.push(FixedVar { var: v, value: 0.0, row: orig });
                        changed = true;
                    } else if a > 0.0 && rhs <= TOL {
                        // |a| x >= rhs with rhs <= 0: implied by x >= 0.
                        row.alive = false;
                        stats.vacuous_bounds_dropped += 1;
                        changed = true;
                    }
                    // a > 0, rhs > 0: a lower bound — keep the row.
                }
            }
        }

        // Substitute this pass's fixes into every remaining row.
        for f in &new_fixes {
            fixed_mask[f.var] = true;
            for row in rows.iter_mut().filter(|r| r.alive) {
                if let Some(pos) = row.coeffs.iter().position(|&(v, _)| v == f.var) {
                    let a = row.coeffs[pos].1;
                    row.rhs -= a * f.value;
                    row.coeffs.remove(pos);
                }
            }
        }
        stats.fixed_vars += new_fixes.len();
        fixed.extend(new_fixes);

        // Bound propagation (ROADMAP bullet): finite upper bounds from
        // the surviving singleton `<=` rows, tightened through the
        // remaining rows, catch infeasibility before phase 1 and let
        // rows implied by the bounds be dropped. Substitutions above
        // can create new singleton bounds, so this runs inside the
        // fixpoint loop.
        changed |= propagate_bounds(&mut rows, nv, &mut stats, p)?;

        if !changed {
            break;
        }
    }

    // Duplicate detection on bit patterns (post-substitution).
    let mut seen: Vec<(Vec<(usize, u64)>, Cmp, u64)> = Vec::new();
    let mut out = LpProblem::new(nv);
    let mut c = p.objective().to_vec();
    for f in &fixed {
        c[f.var] = 0.0;
    }
    out.set_objective(&c);
    for v in 0..nv {
        out.name_var(v, p.var_name(v));
    }
    let mut row_map = Vec::new();
    for row in rows.iter().filter(|r| r.alive) {
        let key: (Vec<(usize, u64)>, Cmp, u64) = (
            row.coeffs.iter().map(|&(v, a)| (v, a.to_bits())).collect(),
            row.cmp,
            row.rhs.to_bits(),
        );
        if seen.contains(&key) {
            stats.duplicate_rows_dropped += 1;
            continue;
        }
        seen.push(key);
        out.add_labeled(&row.coeffs, row.cmp, row.rhs, p.constraints()[row.orig].label.clone());
        row_map.push(row.orig);
    }

    Ok(Presolved { problem: out, stats, row_map, fixed, orig_rows: p.num_constraints() })
}

/// One bound-propagation pass over the working rows.
///
/// Upper bounds come in two tiers:
///
/// - **singleton-derived** (`ub_single`): implied by `x ≥ 0` and the
///   surviving singleton rows alone. Those rows are never dropped
///   here, so any row redundant with respect to this box stays implied
///   by the *remaining* problem — dropping it is exact, and its
///   restored dual is the 0 every slack-capable row gets.
/// - **propagated** (`ub`): tightened through multi-variable rows
///   (`a_v x_v + rest ≤ rhs` with `a_v > 0` bounds `x_v` by the least
///   the rest can contribute). Valid implications of the whole system,
///   used only for the *infeasibility* checks — declaring the system
///   infeasible from its own implications is sound regardless of which
///   row a bound came from, whereas a drop must never be justified by
///   a bound whose defining row could itself be dropped.
///
/// Returns whether any row was dropped; errors with
/// [`Error::Infeasible`] when the activity range of a row cannot meet
/// its rhs — the "catch infeasibility before phase 1" half of the
/// ROADMAP bullet.
fn propagate_bounds(
    rows: &mut [WorkRow],
    nv: usize,
    stats: &mut PresolveStats,
    p: &LpProblem,
) -> Result<bool> {
    let mut ub_single = vec![f64::INFINITY; nv];
    for row in rows.iter().filter(|r| r.alive && r.coeffs.len() == 1) {
        let (v, a) = row.coeffs[0];
        let bound = match row.cmp {
            // a x <= rhs with a > 0, and -|a| x >= rhs (both give a
            // finite cap once combined with x >= 0).
            Cmp::Le if a > 0.0 => row.rhs / a,
            Cmp::Ge if a < 0.0 => row.rhs / a,
            Cmp::Eq if a != 0.0 => row.rhs / a,
            _ => continue,
        };
        if bound < ub_single[v] {
            ub_single[v] = bound.max(0.0);
        }
    }

    // Tighten through multi-variable rows to a (capped) fixpoint.
    let mut ub = ub_single.clone();
    for _pass in 0..8 {
        let mut tightened = false;
        for row in rows.iter().filter(|r| r.alive && r.coeffs.len() >= 2) {
            // Normalize to `Σ (sense·a_u) x_u ≤ sense·rhs`.
            let (sense, rhs) = match row.cmp {
                Cmp::Le => (1.0, row.rhs),
                Cmp::Ge => (-1.0, -row.rhs),
                // Equality singletons fix variables in the main scan;
                // deriving bounds from wide equalities risks using a
                // row against itself, so they only get checked below.
                Cmp::Eq => continue,
            };
            // Least the negative-coefficient terms can contribute.
            let mut min_rest = 0.0;
            let mut rest_finite = true;
            for &(u, a0) in &row.coeffs {
                let a = a0 * sense;
                if a < 0.0 {
                    if ub[u].is_finite() {
                        min_rest += a * ub[u];
                    } else {
                        rest_finite = false;
                    }
                }
            }
            if !rest_finite {
                continue;
            }
            for &(v, a0) in &row.coeffs {
                let a = a0 * sense;
                if a <= 0.0 {
                    continue;
                }
                let bound = ((rhs - min_rest) / a).max(0.0);
                if bound < ub[v] - TOL {
                    ub[v] = bound;
                    tightened = true;
                }
            }
        }
        if !tightened {
            break;
        }
    }

    // Activity-range checks on the multi-variable rows.
    let mut changed = false;
    for row in rows.iter_mut().filter(|r| r.alive && r.coeffs.len() >= 2) {
        let mut min_act = 0.0;
        let mut min_finite = true;
        let mut max_act = 0.0;
        let mut max_finite = true;
        let mut min_single = 0.0;
        let mut min_single_finite = true;
        let mut max_single = 0.0;
        let mut max_single_finite = true;
        for &(u, a) in &row.coeffs {
            if a > 0.0 {
                if ub[u].is_finite() {
                    max_act += a * ub[u];
                } else {
                    max_finite = false;
                }
                if ub_single[u].is_finite() {
                    max_single += a * ub_single[u];
                } else {
                    max_single_finite = false;
                }
            } else {
                if ub[u].is_finite() {
                    min_act += a * ub[u];
                } else {
                    min_finite = false;
                }
                if ub_single[u].is_finite() {
                    min_single += a * ub_single[u];
                } else {
                    min_single_finite = false;
                }
            }
        }
        let scale = 1.0 + row.rhs.abs();
        let infeasible_reason = match row.cmp {
            Cmp::Le if min_finite && min_act > row.rhs + TOL * scale => {
                Some((min_act, ">="))
            }
            Cmp::Ge if max_finite && max_act < row.rhs - TOL * scale => {
                Some((max_act, "<="))
            }
            Cmp::Eq if min_finite && min_act > row.rhs + TOL * scale => {
                Some((min_act, ">="))
            }
            Cmp::Eq if max_finite && max_act < row.rhs - TOL * scale => {
                Some((max_act, "<="))
            }
            _ => None,
        };
        if let Some((act, dir)) = infeasible_reason {
            return Err(Error::Infeasible(format!(
                "presolve: bound propagation proves row `{}` infeasible \
                 (activity {dir} {act:.6} vs rhs {:.6})",
                p.constraints()[row.orig].label, row.rhs
            )));
        }
        let redundant = match row.cmp {
            Cmp::Le => max_single_finite && max_single <= row.rhs + TOL,
            Cmp::Ge => min_single_finite && min_single >= row.rhs - TOL,
            Cmp::Eq => false,
        };
        if redundant {
            row.alive = false;
            stats.redundant_rows_dropped += 1;
            changed = true;
        }
    }
    Ok(changed)
}

impl Presolved {
    /// Map a solution of the reduced problem back onto the original:
    /// fixed variables are re-inserted into `x`, the objective is
    /// re-evaluated on the original problem, and duals are mapped back
    /// through the row eliminations. Kept rows carry their reduced
    /// dual, rows dropped as empty/vacuous/duplicate get zero, and each
    /// fixing row gets the multiplier that makes its variable's dual
    /// constraint tight — computed in reverse elimination order, which
    /// respects the dependency structure of cascaded substitutions.
    ///
    /// For an *inequality* fixing row (a zero-fix like `x <= 0`) the
    /// tight multiplier can have the wrong sign (a positive shadow
    /// price on a `<=` row in a minimization); it is clamped to the
    /// dual-feasible side, which leaves the variable's reduced cost
    /// non-negative slack instead — complementary slackness holds
    /// either way because the fixing row is binding at `x = 0` and its
    /// rhs is ~0, so `b'y` is unaffected.
    pub fn restore(&self, orig: &LpProblem, sol: &LpSolution) -> LpSolution {
        let mut x = sol.x.clone();
        for f in &self.fixed {
            x[f.var] = f.value;
        }
        let objective = orig.objective_at(&x);

        let duals = sol.duals.as_ref().map(|yr| {
            let mut y = vec![0.0; self.orig_rows];
            for (ri, &oi) in self.row_map.iter().enumerate() {
                if ri < yr.len() {
                    y[oi] = yr[ri];
                }
            }
            // Merged coefficient of `var` in original row `k`.
            let coeff_of = |k: usize, var: usize| -> f64 {
                orig.constraints()[k]
                    .coeffs
                    .iter()
                    .filter(|&&(v, _)| v == var)
                    .map(|&(_, a)| a)
                    .sum()
            };
            for f in self.fixed.iter().rev() {
                let mut num = orig.objective()[f.var];
                for k in 0..self.orig_rows {
                    if k == f.row {
                        continue;
                    }
                    let a = coeff_of(k, f.var);
                    if a != 0.0 {
                        num -= y[k] * a;
                    }
                }
                let ar = coeff_of(f.row, f.var);
                let tight = if ar.abs() > 1e-300 { num / ar } else { 0.0 };
                // Sign conventions for `min c'x`: y <= 0 on `<=` rows,
                // y >= 0 on `>=` rows, free on equalities.
                y[f.row] = match orig.constraints()[f.row].cmp {
                    Cmp::Eq => tight,
                    Cmp::Le => tight.min(0.0),
                    Cmp::Ge => tight.max(0.0),
                };
            }
            y
        });

        LpSolution {
            x,
            objective,
            iterations: sol.iterations,
            phase1_iterations: sol.phase1_iterations,
            dual_iterations: sol.dual_iterations,
            factorization: sol.factorization,
            pricing: sol.pricing,
            refactorizations: sol.refactorizations,
            peak_update_len: sol.peak_update_len,
            weight_resets: sol.weight_resets,
            candidate_hits: sol.candidate_hits,
            candidate_refreshes: sol.candidate_refreshes,
            avg_ftran_nnz: sol.avg_ftran_nnz,
            avg_btran_nnz: sol.avg_btran_nnz,
            dfs_solves: sol.dfs_solves,
            scan_solves: sol.scan_solves,
            recovery_events: sol.recovery_events.clone(),
            duals,
            basis: sol.basis.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::{solve, Cmp, LpProblem};

    #[test]
    fn drops_empty_and_duplicate_rows() {
        let mut p = LpProblem::new(2);
        p.set_objective(&[1.0, 1.0]);
        p.add_constraint(&[], Cmp::Le, 5.0); // vacuous
        p.add_constraint(&[(0, 1.0)], Cmp::Ge, 1.0);
        p.add_constraint(&[(0, 1.0)], Cmp::Ge, 1.0); // duplicate
        p.add_constraint(&[(1, 0.0)], Cmp::Le, 3.0); // zero coeff -> empty
        let pre = presolve(&p).unwrap();
        assert_eq!(pre.stats.empty_rows_dropped, 2);
        assert_eq!(pre.stats.duplicate_rows_dropped, 1);
        assert_eq!(pre.problem.num_constraints(), 1);
        let s = solve(&pre.problem).unwrap();
        assert!((s.objective - 1.0).abs() < 1e-9);
    }

    #[test]
    fn detects_trivially_infeasible_empty_row() {
        let mut p = LpProblem::new(1);
        p.add_constraint(&[], Cmp::Ge, 2.0);
        assert!(presolve(&p).is_err());
    }

    #[test]
    fn merges_duplicate_indices() {
        let mut p = LpProblem::new(1);
        p.set_objective(&[1.0]);
        p.add_constraint(&[(0, 1.0), (0, 1.0)], Cmp::Ge, 4.0);
        let pre = presolve(&p).unwrap();
        let s = solve(&pre.problem).unwrap();
        assert!((s.x[0] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn presolve_preserves_optimum() {
        let mut p = LpProblem::new(2);
        p.set_objective(&[2.0, 3.0]);
        p.add_constraint(&[(0, 1.0), (1, 1.0)], Cmp::Ge, 4.0);
        p.add_constraint(&[(0, 1.0)], Cmp::Le, 3.0);
        let s0 = solve(&p).unwrap();
        let pre = presolve(&p).unwrap();
        let s1 = solve(&pre.problem).unwrap();
        assert!((s0.objective - s1.objective).abs() < 1e-9);
    }

    #[test]
    fn fixes_singleton_equality_and_restores() {
        // min 2x + y  s.t.  x = 3, x + y >= 5  ->  x=3, y=2, obj=8.
        let mut p = LpProblem::new(2);
        p.set_objective(&[2.0, 1.0]);
        p.add_labeled(&[(0, 1.0)], Cmp::Eq, 3.0, "fix_x");
        p.add_labeled(&[(0, 1.0), (1, 1.0)], Cmp::Ge, 5.0, "cover");
        let pre = presolve(&p).unwrap();
        assert_eq!(pre.stats.fixed_vars, 1);
        assert_eq!(pre.problem.num_constraints(), 1);
        // The reduced row is y >= 2.
        let sol = solve(&pre.problem).unwrap();
        let full = pre.restore(&p, &sol);
        assert!((full.x[0] - 3.0).abs() < 1e-9);
        assert!((full.x[1] - 2.0).abs() < 1e-9);
        assert!((full.objective - 8.0).abs() < 1e-9);
        // Restored duals satisfy strong duality on the ORIGINAL rows:
        // 3*y_fix + 5*y_cover == 8.
        let y = full.duals.as_ref().unwrap();
        assert_eq!(y.len(), 2);
        let by = 3.0 * y[0] + 5.0 * y[1];
        assert!((by - full.objective).abs() < 1e-7, "b'y {} vs obj {}", by, full.objective);
    }

    #[test]
    fn cascading_substitution_reaches_fixpoint() {
        // x = 2, then x + y = 5 becomes y = 3, then y + z >= 4 becomes
        // z >= 1.
        let mut p = LpProblem::new(3);
        p.set_objective(&[1.0, 1.0, 1.0]);
        p.add_labeled(&[(0, 1.0)], Cmp::Eq, 2.0, "a");
        p.add_labeled(&[(0, 1.0), (1, 1.0)], Cmp::Eq, 5.0, "b");
        p.add_labeled(&[(1, 1.0), (2, 1.0)], Cmp::Ge, 4.0, "c");
        let pre = presolve(&p).unwrap();
        assert_eq!(pre.stats.fixed_vars, 2);
        assert_eq!(pre.problem.num_constraints(), 1);
        let sol = solve(&pre.problem).unwrap();
        let full = pre.restore(&p, &sol);
        assert!((full.x[0] - 2.0).abs() < 1e-9);
        assert!((full.x[1] - 3.0).abs() < 1e-9);
        assert!((full.x[2] - 1.0).abs() < 1e-9);
        assert!((full.objective - 6.0).abs() < 1e-9);
        let y = full.duals.as_ref().unwrap();
        let by = 2.0 * y[0] + 5.0 * y[1] + 4.0 * y[2];
        assert!((by - full.objective).abs() < 1e-7);
    }

    #[test]
    fn vacuous_singleton_bounds_dropped() {
        // x >= -1 and -x <= 2 are implied by x >= 0.
        let mut p = LpProblem::new(1);
        p.set_objective(&[1.0]);
        p.add_constraint(&[(0, 1.0)], Cmp::Ge, -1.0);
        p.add_constraint(&[(0, -1.0)], Cmp::Le, 2.0);
        p.add_constraint(&[(0, 1.0)], Cmp::Ge, 1.0); // real bound, kept
        let pre = presolve(&p).unwrap();
        assert_eq!(pre.stats.vacuous_bounds_dropped, 2);
        assert_eq!(pre.problem.num_constraints(), 1);
    }

    #[test]
    fn singleton_infeasibilities_detected() {
        let mut p = LpProblem::new(1);
        p.add_constraint(&[(0, 1.0)], Cmp::Eq, -2.0);
        assert!(presolve(&p).is_err());
        let mut q = LpProblem::new(1);
        q.add_constraint(&[(0, 2.0)], Cmp::Le, -1.0);
        assert!(presolve(&q).is_err());
        let mut r = LpProblem::new(1);
        r.add_constraint(&[(0, -1.0)], Cmp::Ge, 1.0);
        assert!(presolve(&r).is_err());
    }

    #[test]
    fn inconsistent_fixes_detected_via_cascade() {
        // x = 2 and x = 3: substitution leaves an empty row 0 = 1.
        let mut p = LpProblem::new(1);
        p.add_constraint(&[(0, 1.0)], Cmp::Eq, 2.0);
        p.add_constraint(&[(0, 1.0)], Cmp::Eq, 3.0);
        assert!(presolve(&p).is_err());
    }

    #[test]
    fn le_zero_fixes_variable_at_zero() {
        let mut p = LpProblem::new(2);
        p.set_objective(&[-1.0, 1.0]);
        p.add_labeled(&[(0, 1.0)], Cmp::Le, 0.0, "cap");
        p.add_labeled(&[(0, 1.0), (1, 1.0)], Cmp::Ge, 2.0, "cover");
        let pre = presolve(&p).unwrap();
        assert_eq!(pre.stats.fixed_vars, 1);
        let sol = solve(&pre.problem).unwrap();
        let full = pre.restore(&p, &sol);
        assert_eq!(full.x[0], 0.0);
        assert!((full.x[1] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn bound_propagation_detects_infeasible_cover() {
        // x <= 2, y <= 3, x + y >= 6: the box caps the activity at 5,
        // so presolve must prove infeasibility before phase 1 runs.
        let mut p = LpProblem::new(2);
        p.set_objective(&[1.0, 1.0]);
        p.add_labeled(&[(0, 1.0)], Cmp::Le, 2.0, "cap_x");
        p.add_labeled(&[(1, 1.0)], Cmp::Le, 3.0, "cap_y");
        p.add_labeled(&[(0, 1.0), (1, 1.0)], Cmp::Ge, 6.0, "cover");
        match presolve(&p) {
            Err(crate::error::Error::Infeasible(msg)) => {
                assert!(msg.contains("cover"), "{msg}");
            }
            other => panic!("expected infeasible, got {other:?}"),
        }
        // The raw solver agrees (parity of verdicts).
        assert!(solve(&p).is_err());
    }

    #[test]
    fn bound_propagation_drops_redundant_rows() {
        // x <= 2 and y <= 3 make x + y <= 6 redundant; the defining
        // singleton rows stay, so the optimum and duals are unchanged.
        let mut p = LpProblem::new(2);
        p.set_objective(&[-1.0, -1.0]);
        p.add_labeled(&[(0, 1.0)], Cmp::Le, 2.0, "cap_x");
        p.add_labeled(&[(1, 1.0)], Cmp::Le, 3.0, "cap_y");
        p.add_labeled(&[(0, 1.0), (1, 1.0)], Cmp::Le, 6.0, "loose");
        p.add_labeled(&[(0, 1.0), (1, 1.0)], Cmp::Ge, -1.0, "vacuous_pair");
        let pre = presolve(&p).unwrap();
        assert_eq!(pre.stats.redundant_rows_dropped, 2, "{:?}", pre.stats);
        assert_eq!(pre.problem.num_constraints(), 2);
        let sol = solve(&pre.problem).unwrap();
        let full = pre.restore(&p, &sol);
        assert!((full.objective - (-5.0)).abs() < 1e-9);
        // Strong duality on the original rows (dropped rows take 0).
        let y = full.duals.as_ref().unwrap();
        let by = 2.0 * y[0] + 3.0 * y[1] + 6.0 * y[2] + (-1.0) * y[3];
        assert!((by - full.objective).abs() < 1e-7, "b'y {by} vs {}", full.objective);
        assert_eq!(y[2], 0.0);
        assert_eq!(y[3], 0.0);
    }

    #[test]
    fn propagated_bounds_reach_through_coupling_rows() {
        // u <= 1 and x - u <= 0 imply x <= 1; with x + y >= 3 and
        // y <= 1 the system is infeasible, but only *propagation*
        // (not the singleton seeds alone) can see it.
        let mut p = LpProblem::new(3); // u, x, y
        p.set_objective(&[1.0, 1.0, 1.0]);
        p.add_labeled(&[(0, 1.0)], Cmp::Le, 1.0, "cap_u");
        p.add_labeled(&[(1, 1.0), (0, -1.0)], Cmp::Le, 0.0, "x_below_u");
        p.add_labeled(&[(2, 1.0)], Cmp::Le, 1.0, "cap_y");
        p.add_labeled(&[(1, 1.0), (2, 1.0)], Cmp::Ge, 3.0, "cover");
        match presolve(&p) {
            Err(crate::error::Error::Infeasible(msg)) => {
                assert!(msg.contains("cover"), "{msg}");
            }
            other => panic!("expected infeasible, got {other:?}"),
        }
        assert!(solve(&p).is_err());
    }

    #[test]
    fn bound_propagation_keeps_binding_rows() {
        // x <= 4, y <= 4, x + y <= 6: the coupling row is NOT implied
        // by the box (max activity 8 > 6) and must survive.
        let mut p = LpProblem::new(2);
        p.set_objective(&[-1.0, -1.0]);
        p.add_constraint(&[(0, 1.0)], Cmp::Le, 4.0);
        p.add_constraint(&[(1, 1.0)], Cmp::Le, 4.0);
        p.add_constraint(&[(0, 1.0), (1, 1.0)], Cmp::Le, 6.0);
        let pre = presolve(&p).unwrap();
        assert_eq!(pre.stats.redundant_rows_dropped, 0);
        assert_eq!(pre.problem.num_constraints(), 3);
        let sol = solve(&pre.problem).unwrap();
        assert!((sol.objective - (-6.0)).abs() < 1e-9);
    }

    #[test]
    fn inequality_fix_duals_stay_sign_feasible() {
        // min 2x + y  s.t.  `cap`: x <= 0, `cover`: x + y >= 1.
        // Optimum x=0, y=1, obj 1; y_cover = 1. The *tight* multiplier
        // for `cap` would be (2-1)/1 = +1 — infeasible for a `<=` row
        // in a minimization. The true shadow price is 0 (relaxing the
        // cap leaves the optimum unchanged), so restore must clamp.
        let mut p = LpProblem::new(2);
        p.set_objective(&[2.0, 1.0]);
        p.add_labeled(&[(0, 1.0)], Cmp::Le, 0.0, "cap");
        p.add_labeled(&[(0, 1.0), (1, 1.0)], Cmp::Ge, 1.0, "cover");
        let pre = presolve(&p).unwrap();
        let sol = solve(&pre.problem).unwrap();
        let full = pre.restore(&p, &sol);
        assert!((full.objective - 1.0).abs() < 1e-9);
        let y = full.duals.as_ref().unwrap();
        assert!((y[1] - 1.0).abs() < 1e-7, "y_cover = {}", y[1]);
        assert!(
            y[0] <= 1e-12,
            "dual on a <= row must be non-positive, got {}",
            y[0]
        );
        // And it stays complementary: b'y still equals the objective.
        let by = 0.0 * y[0] + 1.0 * y[1];
        assert!((by - full.objective).abs() < 1e-7);
    }
}
