//! Light LP presolve: drop empty rows, detect trivial infeasibility,
//! and report simple statistics. The DLT builders generate clean
//! problems, so presolve is deliberately conservative — it never
//! changes the feasible set, it only removes rows that are vacuous.

use super::problem::{Cmp, LpProblem};
use crate::error::{Error, Result};

/// Presolve statistics.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PresolveStats {
    /// Rows with no coefficients that were trivially satisfied.
    pub empty_rows_dropped: usize,
    /// Exact duplicate rows removed.
    pub duplicate_rows_dropped: usize,
}

/// Presolve in place. Errors if an empty row is trivially infeasible
/// (e.g. `0 <= -1`).
pub fn presolve(p: &LpProblem) -> Result<(LpProblem, PresolveStats)> {
    let mut out = LpProblem::new(p.num_vars());
    out.set_objective(p.objective());
    for v in 0..p.num_vars() {
        out.name_var(v, p.var_name(v));
    }
    let mut stats = PresolveStats::default();
    let mut seen: Vec<(Vec<(usize, u64)>, Cmp, u64)> = Vec::new();

    for con in p.constraints() {
        // Merge duplicate indices, drop explicit zeros.
        let mut merged: Vec<(usize, f64)> = Vec::with_capacity(con.coeffs.len());
        let mut sorted = con.coeffs.clone();
        sorted.sort_by_key(|&(v, _)| v);
        for (v, a) in sorted {
            if let Some(last) = merged.last_mut() {
                if last.0 == v {
                    last.1 += a;
                    continue;
                }
            }
            merged.push((v, a));
        }
        merged.retain(|&(_, a)| a != 0.0);

        if merged.is_empty() {
            let ok = match con.cmp {
                Cmp::Le => 0.0 <= con.rhs + 1e-12,
                Cmp::Ge => 0.0 >= con.rhs - 1e-12,
                Cmp::Eq => con.rhs.abs() <= 1e-12,
            };
            if !ok {
                return Err(Error::Infeasible(format!(
                    "empty row `{}` requires 0 {} {}",
                    con.label, con.cmp, con.rhs
                )));
            }
            stats.empty_rows_dropped += 1;
            continue;
        }

        // Exact duplicate detection on bit patterns.
        let key: (Vec<(usize, u64)>, Cmp, u64) = (
            merged.iter().map(|&(v, a)| (v, a.to_bits())).collect(),
            con.cmp,
            con.rhs.to_bits(),
        );
        if seen.contains(&key) {
            stats.duplicate_rows_dropped += 1;
            continue;
        }
        seen.push(key);
        out.add_labeled(&merged, con.cmp, con.rhs, con.label.clone());
    }
    Ok((out, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::{solve, Cmp, LpProblem};

    #[test]
    fn drops_empty_and_duplicate_rows() {
        let mut p = LpProblem::new(2);
        p.set_objective(&[1.0, 1.0]);
        p.add_constraint(&[], Cmp::Le, 5.0); // vacuous
        p.add_constraint(&[(0, 1.0)], Cmp::Ge, 1.0);
        p.add_constraint(&[(0, 1.0)], Cmp::Ge, 1.0); // duplicate
        p.add_constraint(&[(1, 0.0)], Cmp::Le, 3.0); // zero coeff -> empty
        let (q, stats) = presolve(&p).unwrap();
        assert_eq!(stats.empty_rows_dropped, 2);
        assert_eq!(stats.duplicate_rows_dropped, 1);
        assert_eq!(q.num_constraints(), 1);
        let s = solve(&q).unwrap();
        assert!((s.objective - 1.0).abs() < 1e-9);
    }

    #[test]
    fn detects_trivially_infeasible_empty_row() {
        let mut p = LpProblem::new(1);
        p.add_constraint(&[], Cmp::Ge, 2.0);
        assert!(presolve(&p).is_err());
    }

    #[test]
    fn merges_duplicate_indices() {
        let mut p = LpProblem::new(1);
        p.set_objective(&[1.0]);
        p.add_constraint(&[(0, 1.0), (0, 1.0)], Cmp::Ge, 4.0);
        let (q, _) = presolve(&p).unwrap();
        let s = solve(&q).unwrap();
        assert!((s.x[0] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn presolve_preserves_optimum() {
        let mut p = LpProblem::new(2);
        p.set_objective(&[2.0, 3.0]);
        p.add_constraint(&[(0, 1.0), (1, 1.0)], Cmp::Ge, 4.0);
        p.add_constraint(&[(0, 1.0)], Cmp::Le, 3.0);
        let s0 = solve(&p).unwrap();
        let (q, _) = presolve(&p).unwrap();
        let s1 = solve(&q).unwrap();
        assert!((s0.objective - s1.objective).abs() < 1e-9);
    }
}
