//! Warm-start cache for families of structurally identical LPs.
//!
//! The paper's evaluation re-solves hundreds of near-identical
//! instances (job-size sweeps, processor-count sweeps, advisor
//! queries). Within such a family the LP *structure* — variable and
//! constraint counts — is fixed while rhs/objective data moves a
//! little, so the previous optimal basis is almost always primal
//! feasible for the next instance and phase 1 can be skipped.
//!
//! [`WarmCache`] keys the last optimal [`Basis`] by
//! `(num_vars, num_constraints)`; [`WarmCache::solve`] transparently
//! warm-starts when a basis for the shape is cached and falls back to
//! a cold solve otherwise (or when the basis turned out unusable —
//! see [`super::solve_warm`]). One cache per solver thread is the
//! intended usage; see `experiments::sweep` for the parallel layer.

use super::problem::LpProblem;
use super::revised::Basis;
use super::scratch::SolverScratch;
use super::simplex::{solve_warm_scratch, SimplexOptions};
use super::solution::LpSolution;
use crate::error::Result;
use std::collections::HashMap;

/// Per-thread warm-start state: last optimal basis per LP shape.
#[derive(Debug, Default)]
pub struct WarmCache {
    bases: HashMap<(usize, usize), Basis>,
    /// Solves that found a cached basis for their shape (the solver
    /// may still have fallen back if the basis was unusable).
    pub warm_attempts: usize,
    /// Solves with no cached basis for their shape.
    pub cold_solves: usize,
}

impl WarmCache {
    /// Empty cache.
    pub fn new() -> WarmCache {
        WarmCache::default()
    }

    /// Solve `p`, warm-starting from the cached basis for its shape
    /// when one exists, and caching the new optimal basis on success.
    pub fn solve(&mut self, p: &LpProblem, opts: &SimplexOptions) -> Result<LpSolution> {
        self.solve_seeded(p, opts, None)
    }

    /// Like [`WarmCache::solve`], but with an external fallback basis:
    /// when the cache has nothing for `p`'s shape, `seed` (typically a
    /// basis projected from a *different* shape — see
    /// `pipeline::project`) is tried instead of a cold start.
    pub fn solve_seeded(
        &mut self,
        p: &LpProblem,
        opts: &SimplexOptions,
        seed: Option<&Basis>,
    ) -> Result<LpSolution> {
        let mut scratch = SolverScratch::new();
        self.solve_seeded_scratch(p, opts, seed, &mut scratch)
    }

    /// Like [`WarmCache::solve_seeded`], routing the solver's work
    /// buffers through a per-worker [`SolverScratch`] pool (the
    /// allocation-free steady state for batch/sweep workers, which own
    /// one cache and one scratch each).
    pub fn solve_seeded_scratch(
        &mut self,
        p: &LpProblem,
        opts: &SimplexOptions,
        seed: Option<&Basis>,
        scratch: &mut SolverScratch,
    ) -> Result<LpSolution> {
        let key = (p.num_vars(), p.num_constraints());
        let warm = self.bases.get(&key).or(seed);
        if warm.is_some() {
            self.warm_attempts += 1;
        } else {
            self.cold_solves += 1;
        }
        let sol = solve_warm_scratch(p, opts, warm, scratch)?;
        if let Some(b) = &sol.basis {
            if b.is_complete() {
                self.bases.insert(key, b.clone());
            }
        }
        Ok(sol)
    }

    /// True when a basis is cached for the `(num_vars,
    /// num_constraints)` shape — callers can skip preparing a fallback
    /// seed (e.g. a cross-shape projection) when the cache will hit.
    pub fn has_shape(&self, num_vars: usize, num_constraints: usize) -> bool {
        self.bases.contains_key(&(num_vars, num_constraints))
    }

    /// Number of cached bases.
    pub fn len(&self) -> usize {
        self.bases.len()
    }

    /// True when nothing is cached yet.
    pub fn is_empty(&self) -> bool {
        self.bases.is_empty()
    }

    /// Drop all cached bases (counters are kept).
    pub fn clear(&mut self) {
        self.bases.clear();
    }

    /// Approximate resident bytes of the cached bases: the basis
    /// column indices plus a flat per-entry estimate for the key and
    /// hash-map slot. The serving tier's LRU eviction budgets warm
    /// sessions against this number, so it only needs to grow
    /// monotonically with cache content, not match the allocator.
    pub fn approx_bytes(&self) -> usize {
        const ENTRY_OVERHEAD: usize = 64;
        self.bases
            .values()
            .map(|b| b.cols.len() * std::mem::size_of::<usize>() + ENTRY_OVERHEAD)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::problem::{Cmp, LpProblem};

    fn lp(rhs: f64) -> LpProblem {
        let mut p = LpProblem::new(2);
        p.set_objective(&[1.0, 2.0]);
        p.add_constraint(&[(0, 1.0), (1, 1.0)], Cmp::Ge, rhs);
        p.add_constraint(&[(0, 1.0)], Cmp::Le, rhs * 2.0);
        p
    }

    #[test]
    fn caches_and_reuses_bases() {
        let mut cache = WarmCache::new();
        let opts = SimplexOptions::default();
        let s1 = cache.solve(&lp(3.0), &opts).unwrap();
        assert_eq!((cache.cold_solves, cache.warm_attempts), (1, 0));
        assert_eq!(cache.len(), 1);
        let s2 = cache.solve(&lp(4.5), &opts).unwrap();
        assert_eq!((cache.cold_solves, cache.warm_attempts), (1, 1));
        // min x + 2y st x + y >= r -> x = r.
        assert!((s1.objective - 3.0).abs() < 1e-7);
        assert!((s2.objective - 4.5).abs() < 1e-7);
        assert!(s2.iterations <= s1.iterations);
    }

    #[test]
    fn different_shapes_do_not_collide() {
        let mut cache = WarmCache::new();
        let opts = SimplexOptions::default();
        cache.solve(&lp(3.0), &opts).unwrap();
        let mut other = LpProblem::new(3);
        other.set_objective(&[1.0, 1.0, 1.0]);
        other.add_constraint(&[(0, 1.0), (2, 1.0)], Cmp::Ge, 1.0);
        cache.solve(&other, &opts).unwrap();
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.cold_solves, 2);
    }
}
