//! Warm-start cache for families of structurally identical LPs.
//!
//! The paper's evaluation re-solves hundreds of near-identical
//! instances (job-size sweeps, processor-count sweeps, advisor
//! queries). Within such a family the LP *structure* — variable and
//! constraint counts — is fixed while rhs/objective data moves a
//! little, so the previous optimal basis is almost always primal
//! feasible for the next instance and phase 1 can be skipped.
//!
//! [`WarmCache`] keys the last optimal [`Basis`] by
//! `(num_vars, num_constraints)`; [`WarmCache::solve`] transparently
//! warm-starts when a basis for the shape is cached and falls back to
//! a cold solve otherwise (or when the basis turned out unusable —
//! see [`super::solve_warm`]). One cache per solver thread is the
//! intended usage; see `experiments::sweep` for the parallel layer.

use super::problem::LpProblem;
use super::revised::Basis;
use super::scratch::SolverScratch;
use super::simplex::{solve_warm_scratch, SimplexOptions};
use super::solution::LpSolution;
use crate::error::Result;
use std::collections::HashMap;

/// Per-thread warm-start state: last optimal basis per LP shape, plus
/// the last optimal primal point per shape (the first-order analogue —
/// PDHG iterates seed from a nearby primal point the way the simplex
/// seeds from a basis).
#[derive(Debug, Default)]
pub struct WarmCache {
    bases: HashMap<(usize, usize), Basis>,
    points: HashMap<(usize, usize), (LpProblem, Vec<f64>)>,
    /// Solves that found a cached basis for their shape (the solver
    /// may still have fallen back if the basis was unusable).
    pub warm_attempts: usize,
    /// Solves with no cached basis for their shape.
    pub cold_solves: usize,
}

impl WarmCache {
    /// Empty cache.
    pub fn new() -> WarmCache {
        WarmCache::default()
    }

    /// Solve `p`, warm-starting from the cached basis for its shape
    /// when one exists, and caching the new optimal basis on success.
    pub fn solve(&mut self, p: &LpProblem, opts: &SimplexOptions) -> Result<LpSolution> {
        self.solve_seeded(p, opts, None)
    }

    /// Like [`WarmCache::solve`], but with an external fallback basis:
    /// when the cache has nothing for `p`'s shape, `seed` (typically a
    /// basis projected from a *different* shape — see
    /// `pipeline::project`) is tried instead of a cold start.
    pub fn solve_seeded(
        &mut self,
        p: &LpProblem,
        opts: &SimplexOptions,
        seed: Option<&Basis>,
    ) -> Result<LpSolution> {
        let mut scratch = SolverScratch::new();
        self.solve_seeded_scratch(p, opts, seed, &mut scratch)
    }

    /// Like [`WarmCache::solve_seeded`], routing the solver's work
    /// buffers through a per-worker [`SolverScratch`] pool (the
    /// allocation-free steady state for batch/sweep workers, which own
    /// one cache and one scratch each).
    pub fn solve_seeded_scratch(
        &mut self,
        p: &LpProblem,
        opts: &SimplexOptions,
        seed: Option<&Basis>,
        scratch: &mut SolverScratch,
    ) -> Result<LpSolution> {
        let key = (p.num_vars(), p.num_constraints());
        let warm = self.bases.get(&key).or(seed);
        if warm.is_some() {
            self.warm_attempts += 1;
        } else {
            self.cold_solves += 1;
        }
        let sol = solve_warm_scratch(p, opts, warm, scratch)?;
        if let Some(b) = &sol.basis {
            if b.is_complete() {
                self.bases.insert(key, b.clone());
            }
        }
        Ok(sol)
    }

    /// True when a basis is cached for the `(num_vars,
    /// num_constraints)` shape — callers can skip preparing a fallback
    /// seed (e.g. a cross-shape projection) when the cache will hit.
    pub fn has_shape(&self, num_vars: usize, num_constraints: usize) -> bool {
        self.bases.contains_key(&(num_vars, num_constraints))
    }

    /// Cache an optimal primal point for `p`'s shape (first-order warm
    /// start). The problem is stored alongside the point so callers
    /// can project it onto *other* shapes by variable name (see
    /// `pipeline::project::project_point`). `x.len()` must be
    /// `p.num_vars()`.
    pub fn store_point(&mut self, p: &LpProblem, x: &[f64]) {
        debug_assert_eq!(x.len(), p.num_vars());
        self.points.insert((p.num_vars(), p.num_constraints()), (p.clone(), x.to_vec()));
    }

    /// Cached primal point for a shape, if any, with the problem it
    /// was optimal for.
    pub fn point(&self, num_vars: usize, num_constraints: usize) -> Option<(&LpProblem, &[f64])> {
        self.points.get(&(num_vars, num_constraints)).map(|(p, v)| (p, v.as_slice()))
    }

    /// Iterate all cached `(problem, point)` pairs — the cross-shape
    /// fallback source for projected first-order warm starts.
    pub fn points(&self) -> impl Iterator<Item = (&LpProblem, &[f64])> {
        self.points.values().map(|(p, v)| (p, v.as_slice()))
    }

    /// Number of cached bases.
    pub fn len(&self) -> usize {
        self.bases.len()
    }

    /// True when nothing is cached yet.
    pub fn is_empty(&self) -> bool {
        self.bases.is_empty()
    }

    /// Drop all cached bases and points (counters are kept).
    pub fn clear(&mut self) {
        self.bases.clear();
        self.points.clear();
    }

    /// Approximate resident bytes of the cached bases and warm points:
    /// per-entry payload plus a flat estimate for the key and hash-map
    /// slot. The serving tier's LRU eviction budgets warm sessions
    /// against this number, so it only needs to grow monotonically
    /// with cache content, not match the allocator.
    pub fn approx_bytes(&self) -> usize {
        const ENTRY_OVERHEAD: usize = 64;
        self.bases
            .values()
            .map(|b| b.cols.len() * std::mem::size_of::<usize>() + ENTRY_OVERHEAD)
            .sum::<usize>()
            + self
                .points
                .values()
                .map(|(p, x)| {
                    std::mem::size_of_val(x.as_slice())
                        + p.num_vars() * std::mem::size_of::<f64>()
                        + ENTRY_OVERHEAD
                })
                .sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::problem::{Cmp, LpProblem};

    fn lp(rhs: f64) -> LpProblem {
        let mut p = LpProblem::new(2);
        p.set_objective(&[1.0, 2.0]);
        p.add_constraint(&[(0, 1.0), (1, 1.0)], Cmp::Ge, rhs);
        p.add_constraint(&[(0, 1.0)], Cmp::Le, rhs * 2.0);
        p
    }

    #[test]
    fn caches_and_reuses_bases() {
        let mut cache = WarmCache::new();
        let opts = SimplexOptions::default();
        let s1 = cache.solve(&lp(3.0), &opts).unwrap();
        assert_eq!((cache.cold_solves, cache.warm_attempts), (1, 0));
        assert_eq!(cache.len(), 1);
        let s2 = cache.solve(&lp(4.5), &opts).unwrap();
        assert_eq!((cache.cold_solves, cache.warm_attempts), (1, 1));
        // min x + 2y st x + y >= r -> x = r.
        assert!((s1.objective - 3.0).abs() < 1e-7);
        assert!((s2.objective - 4.5).abs() < 1e-7);
        assert!(s2.iterations <= s1.iterations);
    }

    #[test]
    fn warm_points_roundtrip_and_count_bytes() {
        let mut cache = WarmCache::new();
        let p = lp(3.0);
        assert!(cache.point(2, 2).is_none());
        cache.store_point(&p, &[1.0, 2.0]);
        let (stored, x) = cache.point(2, 2).unwrap();
        assert_eq!(x, &[1.0, 2.0]);
        assert_eq!(stored.num_vars(), 2);
        assert_eq!(cache.points().count(), 1);
        assert!(cache.approx_bytes() >= 2 * std::mem::size_of::<f64>());
        cache.clear();
        assert!(cache.point(2, 2).is_none());
    }

    #[test]
    fn different_shapes_do_not_collide() {
        let mut cache = WarmCache::new();
        let opts = SimplexOptions::default();
        cache.solve(&lp(3.0), &opts).unwrap();
        let mut other = LpProblem::new(3);
        other.set_objective(&[1.0, 1.0, 1.0]);
        other.add_constraint(&[(0, 1.0), (2, 1.0)], Cmp::Ge, 1.0);
        cache.solve(&other, &opts).unwrap();
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.cold_solves, 2);
    }
}
