//! Simplex front door: backend selection + the dense-tableau fallback.
//!
//! Two backends sit behind [`solve`]/[`solve_with`]:
//!
//! - [`SolverBackend::RevisedSparse`] (default) — revised simplex over
//!   CSC columns ([`super::revised`]), with pluggable
//!   basis-factorization ([`super::factorization`]: product-form eta
//!   or Forrest–Tomlin LU updates) and pricing
//!   ([`super::pricing`]: Dantzig, devex, steepest edge) strategy
//!   layers selected through [`SimplexOptions`]. Supports basis warm
//!   starts via [`solve_warm`].
//! - [`SolverBackend::DenseTableau`] — the original two-phase dense
//!   tableau, kept in this module as a fallback and as the oracle the
//!   revised backend is property-tested against. It always prices
//!   Dantzig and ignores the strategy options.
//!
//! Both backends keep a permanent switch to Bland's rule once
//! degeneracy stalls progress, which guarantees termination under any
//! pricing rule.

use super::factorization::Factorization;
use super::pricing::Pricing;
use super::problem::LpProblem;
use super::recovery::{self, SolveBudget};
use super::revised::Basis;
use super::scratch::SolverScratch;
use super::solution::LpSolution;
use super::standard::{AuxKind, StandardForm};
use crate::error::{Error, Result};
use crate::linalg::{lu_solve, Matrix};

/// Which simplex implementation runs a solve.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SolverBackend {
    /// Revised simplex over sparse column storage (default).
    #[default]
    RevisedSparse,
    /// Dense two-phase tableau (fallback / cross-check oracle).
    DenseTableau,
}

/// Solver tuning knobs.
#[derive(Debug, Clone)]
pub struct SimplexOptions {
    /// Numerical tolerance for reduced costs / pivots.
    pub eps: f64,
    /// Feasibility tolerance for the phase-1 objective.
    pub feas_eps: f64,
    /// Hard iteration cap (per phase). 0 means `50 * (m + n)`.
    pub max_iters: usize,
    /// Iterations without objective improvement before switching to
    /// Bland's rule.
    pub stall_limit: usize,
    /// Extract dual values on success.
    pub compute_duals: bool,
    /// Simplex implementation to run.
    pub backend: SolverBackend,
    /// Basis-factorization strategy for the revised backend
    /// ([`Factorization::ProductFormEta`] by default; the dense
    /// tableau carries no factorization and ignores this).
    pub factorization: Factorization,
    /// Pricing rule for the revised backend ([`Pricing::Dantzig`] by
    /// default; `Pricing::Partial` prices a rotating candidate window
    /// per iteration; the dense tableau always prices Dantzig and
    /// ignores this).
    pub pricing: Pricing,
    /// Wall-clock budget checked (amortized) inside both backends'
    /// inner loops; unbounded by default. Expiry returns
    /// [`Error::DeadlineExceeded`].
    pub budget: SolveBudget,
}

impl Default for SimplexOptions {
    fn default() -> Self {
        SimplexOptions {
            eps: 1e-9,
            feas_eps: 1e-7,
            max_iters: 0,
            stall_limit: 64,
            compute_duals: true,
            backend: SolverBackend::default(),
            factorization: Factorization::default(),
            pricing: Pricing::default(),
            budget: SolveBudget::default(),
        }
    }
}

/// Solve with default options.
pub fn solve(p: &LpProblem) -> Result<LpSolution> {
    solve_with(p, &SimplexOptions::default())
}

/// Solve with explicit options.
pub fn solve_with(p: &LpProblem, opts: &SimplexOptions) -> Result<LpSolution> {
    solve_warm(p, opts, None)
}

/// Solve, optionally starting from a previous optimal [`Basis`] of a
/// structurally identical problem (same variable/constraint counts).
///
/// Warm starts are honored by the revised backend: a basis that is
/// still primal feasible skips phase 1 outright, and one that went
/// primal-infeasible under an rhs perturbation (but is still
/// dual-feasible, as previously optimal bases always are) is repaired
/// by a dual-simplex pass instead of a phase-1 restart. Only an
/// unusable basis (wrong shape, singular, dual-infeasible) silently
/// falls back to a cold two-phase start, so this is always safe to
/// call. The dense backend ignores the hint.
pub fn solve_warm(p: &LpProblem, opts: &SimplexOptions, warm: Option<&Basis>) -> Result<LpSolution> {
    match opts.backend {
        SolverBackend::RevisedSparse => {
            let mut scratch = SolverScratch::new();
            recovery::solve_with_recovery(p, opts, warm, &mut scratch)
        }
        SolverBackend::DenseTableau => solve_dense(p, opts),
    }
}

/// Like [`solve_warm`], but routing the revised backend's work
/// buffers through a per-worker [`SolverScratch`] pool so repeated
/// warm solves allocate nothing in steady state. The dense tableau
/// has no reusable state and ignores the pool.
pub fn solve_warm_scratch(
    p: &LpProblem,
    opts: &SimplexOptions,
    warm: Option<&Basis>,
    scratch: &mut SolverScratch,
) -> Result<LpSolution> {
    match opts.backend {
        SolverBackend::RevisedSparse => recovery::solve_with_recovery(p, opts, warm, scratch),
        SolverBackend::DenseTableau => solve_dense(p, opts),
    }
}

/// The dense-tableau path shared by both front doors.
fn solve_dense(p: &LpProblem, opts: &SimplexOptions) -> Result<LpSolution> {
    let sf = StandardForm::equality(p);
    let mut t = Tableau::new(&sf, opts);
    t.phase1()?;
    t.phase2()?;
    t.extract(p, &sf, opts)
}

/// Dense simplex tableau: `m` constraint rows over `width` columns
/// (structural + aux + artificial), plus rhs column and a cost row.
struct Tableau {
    m: usize,
    /// Total columns excluding rhs.
    width: usize,
    /// First artificial column index.
    art_start: usize,
    /// Row-major (m x (width+1)); last column is rhs.
    rows: Vec<f64>,
    /// Basic variable per row.
    basis: Vec<usize>,
    /// Phase-2 cost vector (length width; artificials get 0 but are
    /// barred from re-entering).
    cost2: Vec<f64>,
    eps: f64,
    feas_eps: f64,
    max_iters: usize,
    stall_limit: usize,
    /// Wall-clock budget, checked every 64 iterations.
    budget: SolveBudget,
    iterations: usize,
    phase1_iters: usize,
    /// Pivot-row scratch buffer (reused across pivots).
    scratch: Vec<f64>,
}

impl Tableau {
    fn new(sf: &StandardForm, opts: &SimplexOptions) -> Tableau {
        let m = sf.b.len();
        let base = sf.a.cols();

        // Rows that already contain a +1 slack can use it as the initial
        // basic variable; all other rows need an artificial.
        let mut needs_artificial: Vec<bool> = Vec::with_capacity(m);
        for kind in &sf.aux {
            needs_artificial.push(!matches!(kind, AuxKind::Slack));
        }
        let num_art = needs_artificial.iter().filter(|&&x| x).count();
        let width = base + num_art;

        let stride = width + 1;
        let mut rows = vec![0.0; m * stride];
        // Scatter the CSC standard form into the dense tableau.
        for j in 0..base {
            for (i, v) in sf.a.col(j) {
                rows[i * stride + j] = v;
            }
        }
        let mut basis = vec![usize::MAX; m];
        let mut next_art = base;
        // Locate each row's slack column (if any) for the initial basis.
        // Slack/surplus columns are appended in row order in StandardForm.
        let mut aux_col = sf.num_structural;
        for i in 0..m {
            let r = &mut rows[i * stride..(i + 1) * stride];
            r[width] = sf.b[i];
            match sf.aux[i] {
                AuxKind::Slack => {
                    basis[i] = aux_col;
                    aux_col += 1;
                }
                AuxKind::Surplus => {
                    aux_col += 1;
                    r[next_art] = 1.0;
                    basis[i] = next_art;
                    next_art += 1;
                }
                AuxKind::None => {
                    r[next_art] = 1.0;
                    basis[i] = next_art;
                    next_art += 1;
                }
            }
        }

        let max_iters = if opts.max_iters == 0 { 200 * (m + width + 1) } else { opts.max_iters };

        Tableau {
            m,
            width,
            art_start: base,
            rows,
            basis,
            cost2: sf.c.iter().cloned().chain(std::iter::repeat(0.0).take(num_art)).collect(),
            eps: opts.eps,
            feas_eps: opts.feas_eps,
            max_iters,
            stall_limit: opts.stall_limit,
            budget: opts.budget,
            iterations: 0,
            phase1_iters: 0,
            scratch: Vec::with_capacity(width + 1),
        }
    }

    #[inline]
    fn at(&self, i: usize, j: usize) -> f64 {
        self.rows[i * (self.width + 1) + j]
    }

    #[inline]
    fn rhs(&self, i: usize) -> f64 {
        self.at(i, self.width)
    }

    /// Reduced-cost row for cost vector `c`: `z_j = c_j - c_B' B^{-1} A_j`
    /// maintained implicitly: compute from current tableau each pricing
    /// pass (dense dot over basic rows). For tableau simplex we instead
    /// carry the elimination explicitly: compute fresh each call —
    /// O(m·width), same order as a pivot.
    fn reduced_costs(&self, c: &[f64]) -> Vec<f64> {
        let mut red = c.to_vec();
        for i in 0..self.m {
            let cb = c[self.basis[i]];
            if cb == 0.0 {
                continue;
            }
            let stride = self.width + 1;
            let row = &self.rows[i * stride..i * stride + self.width];
            for j in 0..self.width {
                red[j] -= cb * row[j];
            }
        }
        red
    }

    fn objective_value(&self, c: &[f64]) -> f64 {
        (0..self.m).map(|i| c[self.basis[i]] * self.rhs(i)).sum()
    }

    /// Run simplex iterations for cost vector `c`. `barred` columns can
    /// never enter the basis (used to keep artificials out in phase 2).
    ///
    /// The reduced-cost row `z` is maintained *incrementally*: a pivot
    /// updates it with one axpy (`z -= z[q] · row_r`) instead of the
    /// O(m·width) from-scratch recompute — the single biggest win of
    /// the §Perf pass (see EXPERIMENTS.md). It is refreshed from
    /// scratch periodically to bound numerical drift.
    fn run(&mut self, c: &[f64], bar_artificials: bool) -> Result<()> {
        let mut stall = 0usize;
        let mut bland = false;
        let mut last_obj = f64::INFINITY;
        let mut z = self.reduced_costs(c);
        let mut since_refresh = 0usize;

        loop {
            self.iterations += 1;
            if self.iterations > self.max_iters {
                return Err(Error::IterationLimit { iterations: self.iterations });
            }
            if self.iterations & 63 == 0 {
                self.budget.check(self.iterations, "dense_tableau")?;
            }
            since_refresh += 1;
            if since_refresh == 256 {
                z = self.reduced_costs(c); // drift control
                since_refresh = 0;
            }

            // Pricing: pick entering column.
            let mut enter: Option<usize> = None;
            if bland {
                for (j, &zj) in z.iter().enumerate().take(self.width) {
                    if bar_artificials && j >= self.art_start {
                        continue;
                    }
                    if zj < -self.eps {
                        enter = Some(j);
                        break;
                    }
                }
            } else {
                let limit = if bar_artificials { self.art_start } else { self.width };
                let mut best = -self.eps;
                for (j, &zj) in z.iter().enumerate().take(limit) {
                    if zj < best {
                        best = zj;
                        enter = Some(j);
                    }
                }
            }
            let Some(q) = enter else {
                // Verify optimality against a fresh reduced-cost row to
                // rule out incremental drift having hidden a column.
                let fresh = self.reduced_costs(c);
                let limit = if bar_artificials { self.art_start } else { self.width };
                if fresh[..limit].iter().any(|&v| v < -self.eps * 10.0) {
                    z = fresh;
                    since_refresh = 0;
                    continue;
                }
                return Ok(()); // optimal
            };

            // Ratio test: pick leaving row.
            let stride = self.width + 1;
            let mut leave: Option<usize> = None;
            let mut best_ratio = f64::INFINITY;
            for i in 0..self.m {
                let aiq = self.rows[i * stride + q];
                if aiq > self.eps {
                    let ratio = self.rows[i * stride + self.width] / aiq;
                    let better = if bland {
                        // Bland: smallest ratio, ties by smallest basis index.
                        ratio < best_ratio - self.eps
                            || (ratio < best_ratio + self.eps
                                && leave.map_or(true, |l| self.basis[i] < self.basis[l]))
                    } else {
                        ratio < best_ratio
                    };
                    if better {
                        best_ratio = ratio;
                        leave = Some(i);
                    }
                }
            }
            let Some(r) = leave else {
                return Err(Error::Unbounded(format!("column {q} has no positive entries")));
            };

            self.pivot(r, q);

            // Incremental reduced-cost update: after the pivot, row r is
            // normalized; z' = z - z[q] * row_r, z'[q] = 0 exactly.
            let zq = z[q];
            if zq != 0.0 {
                let row = &self.rows[r * stride..r * stride + self.width];
                for (zj, &pj) in z.iter_mut().zip(row.iter()) {
                    *zj -= zq * pj;
                }
                z[q] = 0.0;
            }

            // Degeneracy detection -> switch to Bland permanently.
            let obj = self.objective_value(c);
            if obj < last_obj - 1e-12 {
                last_obj = obj;
                stall = 0;
            } else {
                stall += 1;
                if stall > self.stall_limit {
                    bland = true;
                }
            }
        }
    }

    /// Gauss-Jordan pivot on (r, q). The pivot row is copied into a
    /// scratch buffer once so every elimination is a branch-free
    /// slice-zip axpy the compiler auto-vectorizes.
    fn pivot(&mut self, r: usize, q: usize) {
        let stride = self.width + 1;
        let pivot = self.at(r, q);
        debug_assert!(pivot.abs() > 1e-14);
        let inv = 1.0 / pivot;
        {
            let row = &mut self.rows[r * stride..(r + 1) * stride];
            for x in row.iter_mut() {
                *x *= inv;
            }
            row[q] = 1.0; // exact
        }
        self.scratch.clear();
        self.scratch.extend_from_slice(&self.rows[r * stride..(r + 1) * stride]);
        for i in 0..self.m {
            if i == r {
                continue;
            }
            let row = &mut self.rows[i * stride..(i + 1) * stride];
            let factor = row[q];
            if factor == 0.0 {
                continue;
            }
            for (x, &p) in row.iter_mut().zip(self.scratch.iter()) {
                *x -= factor * p;
            }
            row[q] = 0.0; // exact
        }
        self.basis[r] = q;
    }

    fn phase1(&mut self) -> Result<()> {
        // Any artificials at all?
        if self.art_start == self.width {
            return Ok(());
        }
        let before = self.iterations;
        let mut c1 = vec![0.0; self.width];
        for j in self.art_start..self.width {
            c1[j] = 1.0;
        }
        self.run(&c1, false)?;
        self.phase1_iters += self.iterations - before;
        let obj = self.objective_value(&c1);
        if obj > self.feas_eps {
            return Err(Error::Infeasible(format!("phase-1 objective {obj:.3e} > 0")));
        }
        // Drive any remaining artificial basics out (they are at value
        // ~0). Pivot on any eligible non-artificial column; if the whole
        // row is zero the constraint is redundant and the artificial can
        // stay basic at zero (it will never become positive because its
        // row is all zeros among non-basic columns).
        for i in 0..self.m {
            if self.basis[i] >= self.art_start {
                let mut found = None;
                for j in 0..self.art_start {
                    if self.at(i, j).abs() > self.eps {
                        found = Some(j);
                        break;
                    }
                }
                if let Some(j) = found {
                    self.pivot(i, j);
                }
            }
        }
        Ok(())
    }

    fn phase2(&mut self) -> Result<()> {
        let c = self.cost2.clone();
        self.run(&c, true)
    }

    fn extract(&self, p: &LpProblem, sf: &StandardForm, opts: &SimplexOptions) -> Result<LpSolution> {
        let mut x_full = vec![0.0; self.width];
        for i in 0..self.m {
            x_full[self.basis[i]] = self.rhs(i);
        }
        // Residual artificial mass means numerical trouble.
        let art_mass: f64 = x_full[self.art_start..].iter().map(|v| v.abs()).sum();
        if art_mass > self.feas_eps * 10.0 {
            return Err(Error::Numerical(format!("artificial mass {art_mass:.3e} after phase 2")));
        }
        let x: Vec<f64> = x_full[..p.num_vars()]
            .iter()
            .map(|&v| crate::util::float::snap_nonneg(v, 1e-9))
            .collect();
        let objective = p.objective_at(&x);

        let duals = if opts.compute_duals {
            self.compute_duals(sf).ok()
        } else {
            None
        };

        // Basis in structural+aux numbering; rows still held by an
        // artificial (redundant constraints) are marked unusable.
        let basis_cols: Vec<usize> = self
            .basis
            .iter()
            .map(|&b| if b < self.art_start { b } else { usize::MAX })
            .collect();

        Ok(LpSolution {
            x,
            objective,
            iterations: self.iterations,
            phase1_iterations: self.phase1_iters,
            dual_iterations: 0,
            // The dense tableau carries no basis factorization and
            // always prices Dantzig; the configured strategies are
            // echoed for a uniform diagnostics surface.
            factorization: opts.factorization,
            pricing: Pricing::Dantzig,
            refactorizations: 0,
            peak_update_len: 0,
            weight_resets: 0,
            candidate_hits: 0,
            candidate_refreshes: 0,
            avg_ftran_nnz: 0.0,
            avg_btran_nnz: 0.0,
            dfs_solves: 0,
            scan_solves: 0,
            recovery_events: Vec::new(),
            duals,
            basis: Some(Basis { cols: basis_cols }),
        })
    }

    /// Duals via `Bᵀ y = c_B` on the *original* columns of the basis.
    fn compute_duals(&self, sf: &StandardForm) -> Result<Vec<f64>> {
        let m = self.m;
        let mut bt = Matrix::zeros(m, m);
        let mut cb = vec![0.0; m];
        for (k, &bv) in self.basis.iter().enumerate() {
            // Column of the original standard-form matrix for basic var bv;
            // artificial columns are unit vectors on their row.
            if bv < sf.a.cols() {
                for (i, v) in sf.a.col(bv) {
                    bt[(k, i)] = v;
                }
            }
            if bv >= sf.a.cols() {
                // Artificial for some row r: unit column e_r. Find r by
                // artificial ordering: artificials were appended per-row
                // in construction order. Recover from tableau instead:
                // the artificial is basic in row k and its original
                // column is e_{row it was created for}. We stored it
                // implicitly; treat as e_k scaled — only happens for
                // redundant rows where the dual is arbitrary; use e_k.
                bt[(k, k)] = 1.0;
            }
            cb[k] = if bv < self.cost2.len() { self.cost2[bv] } else { 0.0 };
        }
        let y = lu_solve(&bt, &cb)?;
        // Undo row flips from standardization.
        let y = y
            .iter()
            .zip(sf.flipped.iter())
            .map(|(&yi, &f)| if f { -yi } else { yi })
            .collect();
        Ok(y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::problem::{Cmp, LpProblem};

    fn assert_close(a: f64, b: f64) {
        assert!((a - b).abs() < 1e-7, "{a} vs {b}");
    }

    fn dense_opts() -> SimplexOptions {
        SimplexOptions { backend: SolverBackend::DenseTableau, ..SimplexOptions::default() }
    }

    #[test]
    fn dense_backend_still_solves_textbook() {
        let mut p = LpProblem::new(2);
        p.set_objective(&[-3.0, -5.0]);
        p.add_constraint(&[(0, 1.0)], Cmp::Le, 4.0);
        p.add_constraint(&[(1, 2.0)], Cmp::Le, 12.0);
        p.add_constraint(&[(0, 3.0), (1, 2.0)], Cmp::Le, 18.0);
        let s = solve_with(&p, &dense_opts()).unwrap();
        assert_close(s.objective, -36.0);
        assert!(s.basis.is_some());
    }

    #[test]
    fn backends_agree_on_equalities_and_degeneracy() {
        let mut p = LpProblem::new(3);
        p.set_objective(&[1.0, 2.0, 0.5]);
        p.add_constraint(&[(0, 1.0), (1, 1.0), (2, 1.0)], Cmp::Eq, 6.0);
        p.add_constraint(&[(0, 1.0)], Cmp::Ge, 1.0);
        p.add_constraint(&[(1, 1.0), (2, 1.0)], Cmp::Le, 5.0);
        let a = solve(&p).unwrap();
        let b = solve_with(&p, &dense_opts()).unwrap();
        assert_close(a.objective, b.objective);
    }

    #[test]
    fn textbook_max_problem() {
        // max 3x + 5y st x<=4, 2y<=12, 3x+2y<=18  -> x=2,y=6, obj=36
        let mut p = LpProblem::new(2);
        p.set_objective(&[-3.0, -5.0]);
        p.add_constraint(&[(0, 1.0)], Cmp::Le, 4.0);
        p.add_constraint(&[(1, 2.0)], Cmp::Le, 12.0);
        p.add_constraint(&[(0, 3.0), (1, 2.0)], Cmp::Le, 18.0);
        let s = solve(&p).unwrap();
        assert_close(s.objective, -36.0);
        assert_close(s.x[0], 2.0);
        assert_close(s.x[1], 6.0);
    }

    #[test]
    fn equality_and_ge_constraints() {
        // min x + y st x + y = 10, x >= 3  -> obj 10 (any split), x>=3
        let mut p = LpProblem::new(2);
        p.set_objective(&[1.0, 1.0]);
        p.add_constraint(&[(0, 1.0), (1, 1.0)], Cmp::Eq, 10.0);
        p.add_constraint(&[(0, 1.0)], Cmp::Ge, 3.0);
        let s = solve(&p).unwrap();
        assert_close(s.objective, 10.0);
        assert!(s.x[0] >= 3.0 - 1e-9);
        assert!(p.check_feasible(&s.x, 1e-7).is_none());
    }

    #[test]
    fn detects_infeasible() {
        let mut p = LpProblem::new(1);
        p.add_constraint(&[(0, 1.0)], Cmp::Le, 1.0);
        p.add_constraint(&[(0, 1.0)], Cmp::Ge, 2.0);
        match solve(&p) {
            Err(Error::Infeasible(_)) => {}
            other => panic!("expected infeasible, got {other:?}"),
        }
    }

    #[test]
    fn detects_unbounded() {
        let mut p = LpProblem::new(1);
        p.set_objective(&[-1.0]);
        p.add_constraint(&[(0, 1.0)], Cmp::Ge, 0.0);
        match solve(&p) {
            Err(Error::Unbounded(_)) => {}
            other => panic!("expected unbounded, got {other:?}"),
        }
    }

    #[test]
    fn negative_rhs_handled() {
        // x - y <= -2  with min x  => x=0, y>=2 feasible
        let mut p = LpProblem::new(2);
        p.set_objective(&[1.0, 0.0]);
        p.add_constraint(&[(0, 1.0), (1, -1.0)], Cmp::Le, -2.0);
        let s = solve(&p).unwrap();
        assert_close(s.objective, 0.0);
        assert!(p.check_feasible(&s.x, 1e-7).is_none());
    }

    #[test]
    fn degenerate_problem_terminates() {
        // Classic degeneracy: multiple constraints through the origin.
        let mut p = LpProblem::new(2);
        p.set_objective(&[-1.0, -1.0]);
        p.add_constraint(&[(0, 1.0), (1, 1.0)], Cmp::Le, 1.0);
        p.add_constraint(&[(0, 1.0)], Cmp::Le, 1.0);
        p.add_constraint(&[(1, 1.0)], Cmp::Le, 1.0);
        p.add_constraint(&[(0, 1.0), (1, -1.0)], Cmp::Le, 0.0);
        p.add_constraint(&[(0, -1.0), (1, 1.0)], Cmp::Le, 0.0);
        let s = solve(&p).unwrap();
        assert_close(s.objective, -1.0);
    }

    #[test]
    fn duals_satisfy_strong_duality() {
        let mut p = LpProblem::new(2);
        p.set_objective(&[-3.0, -5.0]);
        p.add_constraint(&[(0, 1.0)], Cmp::Le, 4.0);
        p.add_constraint(&[(1, 2.0)], Cmp::Le, 12.0);
        p.add_constraint(&[(0, 3.0), (1, 2.0)], Cmp::Le, 18.0);
        let s = solve(&p).unwrap();
        let y = s.duals.as_ref().unwrap();
        // b'y == optimal objective (strong duality).
        let by = 4.0 * y[0] + 12.0 * y[1] + 18.0 * y[2];
        assert_close(by, s.objective);
    }

    #[test]
    fn redundant_equality_rows() {
        // x + y = 1 twice; min -x => x=1.
        let mut p = LpProblem::new(2);
        p.set_objective(&[-1.0, 0.0]);
        p.add_constraint(&[(0, 1.0), (1, 1.0)], Cmp::Eq, 1.0);
        p.add_constraint(&[(0, 1.0), (1, 1.0)], Cmp::Eq, 1.0);
        let s = solve(&p).unwrap();
        assert_close(s.x[0], 1.0);
    }

    #[test]
    fn zero_objective_returns_feasible_point() {
        let mut p = LpProblem::new(3);
        p.add_constraint(&[(0, 1.0), (1, 1.0), (2, 1.0)], Cmp::Eq, 6.0);
        p.add_constraint(&[(0, 1.0)], Cmp::Ge, 1.0);
        let s = solve(&p).unwrap();
        assert!(p.check_feasible(&s.x, 1e-7).is_none());
    }

    #[test]
    fn random_lps_feasible_and_not_worse_than_random_points() {
        use crate::util::rng::{Pcg32, Rng};
        let mut rng = Pcg32::new(2024);
        for trial in 0..30 {
            let n = rng.range_usize(2, 6);
            let m = rng.range_usize(1, 5);
            let mut p = LpProblem::new(n);
            let c: Vec<f64> = (0..n).map(|_| rng.range_f64(0.1, 2.0)).collect();
            p.set_objective(&c);
            // Constraints sum a_i x_i >= b with positive coeffs keep it
            // feasible and bounded below.
            for _ in 0..m {
                let coeffs: Vec<(usize, f64)> =
                    (0..n).map(|v| (v, rng.range_f64(0.1, 1.0))).collect();
                p.add_constraint(&coeffs, Cmp::Ge, rng.range_f64(0.5, 3.0));
            }
            let s = solve(&p).unwrap_or_else(|e| panic!("trial {trial}: {e}"));
            assert!(p.check_feasible(&s.x, 1e-6).is_none(), "trial {trial}");
            // Compare against random feasible points obtained by scaling
            // a positive point up until feasible.
            for _ in 0..20 {
                let mut pt: Vec<f64> = (0..n).map(|_| rng.range_f64(0.0, 5.0)).collect();
                // scale up to satisfy all >= constraints
                for con in p.constraints() {
                    let lhs: f64 = con.coeffs.iter().map(|&(v, a)| a * pt[v]).sum();
                    if lhs < con.rhs {
                        let scale = if lhs > 1e-12 { con.rhs / lhs } else { 0.0 };
                        if scale == 0.0 {
                            for x in pt.iter_mut() {
                                *x += 1.0;
                            }
                        } else {
                            for x in pt.iter_mut() {
                                *x *= scale;
                            }
                        }
                    }
                }
                if p.check_feasible(&pt, 1e-9).is_none() {
                    assert!(
                        s.objective <= p.objective_at(&pt) + 1e-6,
                        "trial {trial}: simplex {} > random {}",
                        s.objective,
                        p.objective_at(&pt)
                    );
                }
            }
        }
    }
}
