//! LP solution container.

use super::revised::Basis;

/// Result of a successful LP solve.
#[derive(Debug, Clone)]
pub struct LpSolution {
    /// Optimal values for the structural variables.
    pub x: Vec<f64>,
    /// Optimal objective value (minimization).
    pub objective: f64,
    /// Total simplex iterations across all phases.
    pub iterations: usize,
    /// Iterations spent in phase 1 (feasibility search). Zero when the
    /// solve started from a usable warm basis — including bases that
    /// were primal-infeasible and repaired by the dual simplex.
    pub phase1_iterations: usize,
    /// Dual-simplex pivots spent repairing a primal-infeasible warm
    /// basis (revised backend only; zero on cold or primal-warm solves).
    pub dual_iterations: usize,
    /// Dual values per constraint (if requested and extractable).
    pub duals: Option<Vec<f64>>,
    /// Optimal basis, usable to warm-start the next solve of a
    /// structurally identical problem (see [`super::solve_warm`]).
    pub basis: Option<Basis>,
}

impl LpSolution {
    /// Value of variable `i`.
    pub fn value(&self, i: usize) -> f64 {
        self.x[i]
    }
}
