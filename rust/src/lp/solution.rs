//! LP solution container.

use super::factorization::Factorization;
use super::pricing::Pricing;
use super::revised::Basis;

/// Result of a successful LP solve.
#[derive(Debug, Clone)]
pub struct LpSolution {
    /// Optimal values for the structural variables.
    pub x: Vec<f64>,
    /// Optimal objective value (minimization).
    pub objective: f64,
    /// Total simplex iterations across all phases.
    pub iterations: usize,
    /// Iterations spent in phase 1 (feasibility search). Zero when the
    /// solve started from a usable warm basis — including bases that
    /// were primal-infeasible and repaired by the dual simplex.
    pub phase1_iterations: usize,
    /// Dual-simplex pivots spent repairing a primal-infeasible warm
    /// basis (revised backend only; zero on cold or primal-warm solves).
    pub dual_iterations: usize,
    /// Basis-factorization strategy the solve was configured with.
    pub factorization: Factorization,
    /// Pricing rule the solve actually ran (the dense tableau reports
    /// [`Pricing::Dantzig`] regardless of configuration).
    pub pricing: Pricing,
    /// Full basis refactorizations the revised backend performed
    /// (periodic cadence + verdict re-checks; zero on the dense
    /// tableau).
    pub refactorizations: usize,
    /// Peak update-file length (product-form etas, or Forrest–Tomlin
    /// spikes) between refactorizations.
    pub peak_update_len: usize,
    /// Times a weighted pricing rule rebuilt its reference framework
    /// after weight overflow (devex / steepest edge only).
    pub weight_resets: usize,
    /// Iterations that entered from the partial-pricing candidate
    /// window without a full pricing pass (`partial` pricing only).
    pub candidate_hits: usize,
    /// Full pricing passes that rebuilt the candidate window
    /// (`partial` pricing only).
    pub candidate_refreshes: usize,
    /// Mean nonzeros in the FTRAN results of this solve — the
    /// hypersparsity diagnostic (0.0 on the dense tableau and PDHG,
    /// which have no FTRAN).
    pub avg_ftran_nnz: f64,
    /// Mean nonzeros in the BTRAN results of this solve (pricing rows
    /// and dual updates; 0.0 where there is no BTRAN).
    pub avg_btran_nnz: f64,
    /// Triangular solves answered through the Gilbert–Peierls symbolic
    /// DFS path during this solve (see [`crate::linalg::SolveMode`];
    /// zero on backends that never route through `LuFactors`).
    pub dfs_solves: usize,
    /// Triangular solves answered through the full column scan during
    /// this solve (the dense-RHS side of the DFS/scan crossover).
    pub scan_solves: usize,
    /// Recovery-ladder rungs and in-solve fallbacks taken to produce
    /// this solution, in the order they fired (`early_refactorize`,
    /// `bland_engaged`, `warm_fallback_cold`, `markowitz_retry`,
    /// `bland_perturbed`, `dense_oracle`). Empty on a clean solve.
    pub recovery_events: Vec<String>,
    /// Dual values per constraint (if requested and extractable).
    pub duals: Option<Vec<f64>>,
    /// Optimal basis, usable to warm-start the next solve of a
    /// structurally identical problem (see [`super::solve_warm`]).
    pub basis: Option<Basis>,
}

impl LpSolution {
    /// Value of variable `i`.
    pub fn value(&self, i: usize) -> f64 {
        self.x[i]
    }
}
