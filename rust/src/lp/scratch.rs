//! Per-worker solver scratch pool: allocation-free warm re-solves.
//!
//! A revised-simplex solve needs a dozen work buffers (basic values,
//! reduced costs, FTRAN/BTRAN work vectors, the basis-factorization
//! and pricing objects themselves). Allocating them per solve is
//! invisible on one LP and dominant on the paper's sweeps, where
//! [`crate::api::Session::solve_batch`] and
//! `experiments::sweep::parallel_map_steal` workers re-solve thousands
//! of structurally identical instances.
//!
//! [`SolverScratch`] owns those buffers *between* solves. The driver
//! takes them at the start of a solve (`std::mem::take` — no copies),
//! resizes in place (a no-op once warm), and stashes them back at the
//! end, success or error. The factorization and pricing objects are
//! reused when the strategy and basis dimension match the previous
//! solve — the steady-state case in every sweep — so repeated warm
//! solves through one scratch perform no per-solve heap allocation in
//! the simplex core (asserted by the counting-allocator test in
//! `tests/lp_scratch_alloc.rs`). One scratch per solver thread, like
//! [`crate::lp::WarmCache`]; [`crate::api::Session`] owns exactly one
//! of each.

use super::factorization::{BasisFactorization, Factorization};
use super::pricing::{Pricing, PricingRule};
use crate::linalg::{SparseMatrix, SparseVector};

/// Reusable solver state (see module docs). All fields are
/// `pub(crate)`: the revised-simplex driver moves them in and out
/// wholesale.
#[derive(Default)]
pub struct SolverScratch {
    /// Last factorization object, keyed by strategy and basis rows.
    pub(crate) fact: Option<(Factorization, usize, Box<dyn BasisFactorization>)>,
    /// Last pricing object, keyed by rule.
    pub(crate) pricing: Option<(Pricing, Box<dyn PricingRule>)>,
    pub(crate) basis: Vec<usize>,
    pub(crate) in_basis: Vec<bool>,
    pub(crate) xb: Vec<f64>,
    pub(crate) rho: Vec<f64>,
    pub(crate) d: Vec<f64>,
    pub(crate) alpha_r: Vec<f64>,
    pub(crate) adv: Vec<f64>,
    pub(crate) w: SparseVector,
    pub(crate) y: SparseVector,
    pub(crate) vref: SparseVector,
    pub(crate) cand_buf: Vec<usize>,
    pub(crate) trip_buf: Vec<(usize, usize, f64)>,
    /// Gathered FTRAN-column indices for the ratio test / x_B update
    /// (parallel to `gval`; see [`SparseVector::gather_into`]).
    pub(crate) gidx: Vec<usize>,
    /// Gathered FTRAN-column values, streamed contiguously by the hot
    /// loops instead of chasing `idx -> vals` per element.
    pub(crate) gval: Vec<f64>,
    /// Pooled CSC basis view, rebuilt in place per (re)factorization.
    pub(crate) basis_mat: SparseMatrix,
    /// Pooled PDHG state (standardized problem, iterates, kernel
    /// buffers) for [`crate::pdhg::solve_rust_scratch`]: the
    /// first-order backend shares the same per-worker pool as the
    /// simplex side.
    pub(crate) pdhg: crate::pdhg::PdhgPool,
}

impl SolverScratch {
    /// Empty pool; buffers grow on first use and are reused after.
    pub fn new() -> SolverScratch {
        SolverScratch::default()
    }

    /// Hand out a factorization object for `(kind, m)`, reusing the
    /// pooled one when it matches.
    pub(crate) fn take_fact(
        &mut self,
        kind: Factorization,
        m: usize,
    ) -> Box<dyn BasisFactorization> {
        match self.fact.take() {
            Some((k, km, f)) if k == kind && km == m => f,
            _ => kind.build(m),
        }
    }

    /// Return a factorization object to the pool.
    pub(crate) fn put_fact(
        &mut self,
        kind: Factorization,
        m: usize,
        f: Box<dyn BasisFactorization>,
    ) {
        self.fact = Some((kind, m, f));
    }

    /// Hand out a pricing object for `kind`, reusing the pooled one
    /// when it matches.
    pub(crate) fn take_pricing(&mut self, kind: Pricing) -> Box<dyn PricingRule> {
        match self.pricing.take() {
            Some((k, p)) if k == kind => p,
            _ => kind.build(),
        }
    }

    /// Return a pricing object to the pool.
    pub(crate) fn put_pricing(&mut self, kind: Pricing, p: Box<dyn PricingRule>) {
        self.pricing = Some((kind, p));
    }
}

impl std::fmt::Debug for SolverScratch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SolverScratch")
            .field("fact", &self.fact.as_ref().map(|(k, m, _)| (*k, *m)))
            .field("pricing", &self.pricing.as_ref().map(|(k, _)| *k))
            .field("xb_capacity", &self.xb.capacity())
            .field("d_capacity", &self.d.capacity())
            .finish_non_exhaustive()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pooled_objects_reused_on_match_only() {
        let mut s = SolverScratch::new();
        let f = s.take_fact(Factorization::ForrestTomlin, 5);
        assert_eq!(f.name(), "forrest_tomlin");
        s.put_fact(Factorization::ForrestTomlin, 5, f);
        // Matching strategy and size: the same object comes back.
        let f = s.take_fact(Factorization::ForrestTomlin, 5);
        assert_eq!(f.name(), "forrest_tomlin");
        s.put_fact(Factorization::ForrestTomlin, 5, f);
        // Size mismatch: a fresh object is built.
        let f = s.take_fact(Factorization::ForrestTomlin, 7);
        assert_eq!(f.name(), "forrest_tomlin");
        s.put_fact(Factorization::ForrestTomlin, 7, f);
        // Strategy mismatch likewise.
        let f = s.take_fact(Factorization::ProductFormEta, 7);
        assert_eq!(f.name(), "product_form_eta");
        s.put_fact(Factorization::ProductFormEta, 7, f);
        let f = s.take_fact(Factorization::Markowitz, 7);
        assert_eq!(f.name(), "markowitz");
        s.put_fact(Factorization::Markowitz, 7, f);
        let f = s.take_fact(Factorization::BartelsGolub, 7);
        assert_eq!(f.name(), "bartels_golub");

        let p = s.take_pricing(Pricing::Partial);
        assert_eq!(p.name(), "partial");
        s.put_pricing(Pricing::Partial, p);
        let p = s.take_pricing(Pricing::Dantzig);
        assert_eq!(p.name(), "dantzig");
    }

    #[test]
    fn debug_format_is_stable() {
        let s = SolverScratch::new();
        let text = format!("{s:?}");
        assert!(text.contains("SolverScratch"));
    }
}
