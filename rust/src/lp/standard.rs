//! Conversion of an [`LpProblem`] to computational standard forms.
//!
//! Two consumers:
//! - the simplex solver wants `min c'x  s.t.  Ax = b, x >= 0, b >= 0`
//!   with explicit slack/surplus columns ([`StandardForm::equality`]);
//! - the PDHG path wants the row-wise form `Ax <= b` / `Ax == b`
//!   with an equality mask ([`StandardForm::rowwise`]).

use super::problem::{Cmp, LpProblem};
use crate::linalg::{Matrix, SparseMatrix};

/// Kind of auxiliary column appended for a constraint row.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AuxKind {
    /// Slack (`+1` coefficient, from a `<=` row).
    Slack,
    /// Surplus (`-1` coefficient, from a `>=` row).
    Surplus,
    /// No auxiliary column (equality row).
    None,
}

/// Equality standard form for the simplex: `min c'x, Ax = b, x >= 0`,
/// with `b >= 0` (rows are sign-flipped as needed).
///
/// The constraint matrix is carried **sparsely end-to-end**: the DLT
/// builders emit sparse rows, and both simplex backends consume CSC
/// columns, so nothing densifies in between. (The dense-tableau
/// fallback scatters columns into its own row-major buffer.)
#[derive(Debug, Clone)]
pub struct StandardForm {
    /// Constraint matrix including slack/surplus columns (CSC).
    pub a: SparseMatrix,
    /// Right-hand side, all entries `>= 0`.
    pub b: Vec<f64>,
    /// Objective over all columns (zeros for aux columns).
    pub c: Vec<f64>,
    /// Number of original (structural) variables.
    pub num_structural: usize,
    /// Per-row auxiliary column kind (after sign normalization).
    pub aux: Vec<AuxKind>,
    /// Per-row: was the row sign-flipped to make `b >= 0`?
    pub flipped: Vec<bool>,
}

impl StandardForm {
    /// Build the equality standard form used by the simplex.
    pub fn equality(p: &LpProblem) -> StandardForm {
        let n = p.num_vars();
        let m = p.num_constraints();

        // First pass: determine aux column per row (post flip).
        // Flipping a row negates coefficients and rhs and swaps Le/Ge.
        let mut aux = Vec::with_capacity(m);
        let mut flipped = Vec::with_capacity(m);
        for c in p.constraints() {
            let flip = c.rhs < 0.0;
            let cmp = match (c.cmp, flip) {
                (Cmp::Le, false) | (Cmp::Ge, true) => Cmp::Le,
                (Cmp::Ge, false) | (Cmp::Le, true) => Cmp::Ge,
                (Cmp::Eq, _) => Cmp::Eq,
            };
            aux.push(match cmp {
                Cmp::Le => AuxKind::Slack,
                Cmp::Ge => AuxKind::Surplus,
                Cmp::Eq => AuxKind::None,
            });
            flipped.push(flip);
        }
        let num_aux = aux.iter().filter(|k| **k != AuxKind::None).count();
        let total = n + num_aux;

        let nnz_est: usize = p.constraints().iter().map(|c| c.coeffs.len()).sum();
        let mut trips: Vec<(usize, usize, f64)> = Vec::with_capacity(nnz_est + num_aux);
        let mut b = vec![0.0; m];
        let mut c_vec = vec![0.0; total];
        c_vec[..n].copy_from_slice(p.objective());

        let mut next_aux = n;
        for (i, con) in p.constraints().iter().enumerate() {
            let sign = if flipped[i] { -1.0 } else { 1.0 };
            for &(v, coef) in &con.coeffs {
                trips.push((i, v, sign * coef));
            }
            b[i] = sign * con.rhs;
            match aux[i] {
                AuxKind::Slack => {
                    trips.push((i, next_aux, 1.0));
                    next_aux += 1;
                }
                AuxKind::Surplus => {
                    trips.push((i, next_aux, -1.0));
                    next_aux += 1;
                }
                AuxKind::None => {}
            }
        }
        debug_assert_eq!(next_aux, total);
        // `from_triplets` sums duplicate (row, var) pairs, matching the
        // previous dense `a[(i, v)] += ...` accumulation.
        let a = SparseMatrix::from_triplets(m, total, &trips);

        StandardForm { a, b, c: c_vec, num_structural: n, aux, flipped }
    }
}

/// Row-wise inequality form for first-order methods:
/// `min c'x  s.t.  (Ax)_k <= b_k` for inequality rows, `(Ax)_k == b_k`
/// for equality rows (`eq_mask[k] == true`), `x >= 0`.
/// `>=` rows are negated into `<=` rows.
#[derive(Debug, Clone)]
pub struct RowwiseForm {
    /// Dense constraint matrix (rows × structural vars).
    pub a: Matrix,
    /// Right-hand side.
    pub b: Vec<f64>,
    /// Objective over structural vars.
    pub c: Vec<f64>,
    /// `true` where the row is an equality.
    pub eq_mask: Vec<bool>,
}

impl StandardForm {
    /// Build the row-wise form used by the PDHG path.
    pub fn rowwise(p: &LpProblem) -> RowwiseForm {
        let n = p.num_vars();
        let m = p.num_constraints();
        let mut a = Matrix::zeros(m, n);
        let mut b = vec![0.0; m];
        let mut eq_mask = vec![false; m];
        for (i, con) in p.constraints().iter().enumerate() {
            let sign = match con.cmp {
                Cmp::Ge => -1.0,
                _ => 1.0,
            };
            for &(v, coef) in &con.coeffs {
                a[(i, v)] += sign * coef;
            }
            b[i] = sign * con.rhs;
            eq_mask[i] = con.cmp == Cmp::Eq;
        }
        RowwiseForm { a, b, c: p.objective().to_vec(), eq_mask }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::problem::{Cmp, LpProblem};

    #[test]
    fn equality_adds_slack_and_surplus() {
        let mut p = LpProblem::new(2);
        p.set_objective(&[1.0, 1.0]);
        p.add_constraint(&[(0, 1.0)], Cmp::Le, 4.0);
        p.add_constraint(&[(1, 1.0)], Cmp::Ge, 2.0);
        p.add_constraint(&[(0, 1.0), (1, 1.0)], Cmp::Eq, 5.0);
        let sf = StandardForm::equality(&p);
        assert_eq!(sf.a.cols(), 4); // 2 structural + slack + surplus
        assert_eq!(sf.aux, vec![AuxKind::Slack, AuxKind::Surplus, AuxKind::None]);
        assert_eq!(sf.a[(0, 2)], 1.0);
        assert_eq!(sf.a[(1, 3)], -1.0);
        assert_eq!(sf.b, vec![4.0, 2.0, 5.0]);
    }

    #[test]
    fn negative_rhs_flips_row() {
        let mut p = LpProblem::new(1);
        // x0 <= -3  (infeasible with x >= 0, but the form is mechanical)
        p.add_constraint(&[(0, 1.0)], Cmp::Le, -3.0);
        let sf = StandardForm::equality(&p);
        assert!(sf.flipped[0]);
        assert_eq!(sf.aux[0], AuxKind::Surplus); // Le flipped to Ge
        assert_eq!(sf.b[0], 3.0);
        assert_eq!(sf.a[(0, 0)], -1.0);
    }

    #[test]
    fn rowwise_negates_ge() {
        let mut p = LpProblem::new(2);
        p.add_constraint(&[(0, 2.0)], Cmp::Ge, 1.0);
        p.add_constraint(&[(1, 1.0)], Cmp::Eq, 3.0);
        let rw = StandardForm::rowwise(&p);
        assert_eq!(rw.a[(0, 0)], -2.0);
        assert_eq!(rw.b[0], -1.0);
        assert_eq!(rw.eq_mask, vec![false, true]);
    }

    #[test]
    fn duplicate_indices_sum() {
        let mut p = LpProblem::new(1);
        p.add_constraint(&[(0, 1.0), (0, 2.0)], Cmp::Le, 4.0);
        let sf = StandardForm::equality(&p);
        assert_eq!(sf.a[(0, 0)], 3.0);
    }

    #[test]
    fn equality_form_stays_sparse() {
        // 10 vars, each row touching 2: nnz must be per-row work, not
        // rows × cols.
        let mut p = LpProblem::new(10);
        for i in 0..9 {
            p.add_constraint(&[(i, 1.0), (i + 1, -1.0)], Cmp::Le, 1.0);
        }
        let sf = StandardForm::equality(&p);
        // 2 structural + 1 slack per row.
        assert_eq!(sf.a.nnz(), 9 * 3);
        assert!(sf.a.density() < 0.2);
    }
}
