//! Incremental newline-delimited framing over arbitrary byte chunks.
//!
//! The serving tier's wire is one JSON document per line — the same
//! format `dlt batch` reads from files — but a TCP read can deliver
//! half a frame, three frames, or a frame boundary split anywhere.
//! [`FrameReader`] absorbs raw chunks and yields complete frames,
//! with two guarantees the fuzz tests pin down:
//!
//! - **bounded memory**: a line longer than the configured cap is
//!   dropped as it streams in (the reader never buffers it), and the
//!   connection recovers at the next newline;
//! - **no panics**: any byte sequence — truncated, concatenated,
//!   interleaved, non-UTF-8 — produces a well-defined event stream.
//!
//! Blank lines (including `\r\n` keep-alives) are skipped silently so
//! interactive `nc` sessions behave.

/// One event recovered from the byte stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Frame {
    /// A complete line, trailing `\n` (and optional `\r`) stripped.
    Line(String),
    /// A line that exceeded the frame cap; its bytes were discarded as
    /// they arrived and the stream resynchronized at the newline.
    Oversize {
        /// Approximate number of bytes the abandoned line carried.
        dropped: usize,
    },
    /// A complete line that was not valid UTF-8.
    NotUtf8,
}

/// Streaming newline-delimited framer with a hard per-frame byte cap.
#[derive(Debug)]
pub struct FrameReader {
    buf: Vec<u8>,
    max_frame: usize,
    discarding: bool,
    dropped: usize,
}

impl FrameReader {
    /// New reader; `max_frame` is the largest line (exclusive of the
    /// newline) that will be buffered rather than discarded.
    pub fn new(max_frame: usize) -> FrameReader {
        FrameReader { buf: Vec::new(), max_frame, discarding: false, dropped: 0 }
    }

    /// Absorb one chunk of bytes from the socket.
    pub fn push(&mut self, chunk: &[u8]) {
        self.buf.extend_from_slice(chunk);
    }

    /// Bytes currently buffered (diagnostics / backpressure probes).
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Pull the next complete frame, if one is available. Returns
    /// `None` when more bytes are needed.
    pub fn next_frame(&mut self) -> Option<Frame> {
        loop {
            if let Some(pos) = self.buf.iter().position(|&b| b == b'\n') {
                let mut line: Vec<u8> = self.buf.drain(..=pos).collect();
                line.pop(); // the newline itself
                if line.last() == Some(&b'\r') {
                    line.pop();
                }
                if self.discarding {
                    self.discarding = false;
                    let dropped = self.dropped + line.len();
                    self.dropped = 0;
                    return Some(Frame::Oversize { dropped });
                }
                if line.is_empty() {
                    continue; // blank keep-alive
                }
                return match String::from_utf8(line) {
                    Ok(s) => Some(Frame::Line(s)),
                    Err(_) => Some(Frame::NotUtf8),
                };
            }
            // No newline buffered. Enforce the cap so a frame that
            // never terminates cannot grow the buffer without bound.
            if self.buf.len() > self.max_frame {
                self.dropped += self.buf.len();
                self.buf.clear();
                self.discarding = true;
            }
            return None;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Feed `bytes` in chunks of `step` and collect every frame.
    fn frames_chunked(bytes: &[u8], step: usize, cap: usize) -> Vec<Frame> {
        let mut r = FrameReader::new(cap);
        let mut out = Vec::new();
        for chunk in bytes.chunks(step.max(1)) {
            r.push(chunk);
            while let Some(f) = r.next_frame() {
                out.push(f);
            }
        }
        out
    }

    #[test]
    fn chunking_never_changes_the_frame_stream() {
        let bytes = b"{\"a\":1}\n\r\n{\"b\":2}\nplain text\n";
        let want = vec![
            Frame::Line("{\"a\":1}".into()),
            Frame::Line("{\"b\":2}".into()),
            Frame::Line("plain text".into()),
        ];
        for step in 1..=bytes.len() {
            assert_eq!(frames_chunked(bytes, step, 1024), want, "step {step}");
        }
    }

    #[test]
    fn truncated_frame_stays_pending() {
        let mut r = FrameReader::new(1024);
        r.push(b"{\"a\":");
        assert_eq!(r.next_frame(), None);
        r.push(b"1}\n");
        assert_eq!(r.next_frame(), Some(Frame::Line("{\"a\":1}".into())));
        assert_eq!(r.next_frame(), None);
    }

    #[test]
    fn oversize_line_is_dropped_and_stream_recovers() {
        let cap = 16;
        let long = vec![b'x'; 100];
        let mut bytes = long.clone();
        bytes.push(b'\n');
        bytes.extend_from_slice(b"{\"ok\":true}\n");
        for step in [1usize, 3, 7, 200] {
            let out = frames_chunked(&bytes, step, cap);
            assert_eq!(out.len(), 2, "step {step}: {out:?}");
            match &out[0] {
                Frame::Oversize { dropped } => assert!(*dropped >= cap, "dropped {dropped}"),
                other => panic!("step {step}: expected oversize, got {other:?}"),
            }
            assert_eq!(out[1], Frame::Line("{\"ok\":true}".into()));
        }
    }

    #[test]
    fn invalid_utf8_line_is_one_event() {
        let bytes = [0xffu8, 0xfe, 0x01, b'\n', b'o', b'k', b'\n'];
        let out = frames_chunked(&bytes, 2, 64);
        assert_eq!(out, vec![Frame::NotUtf8, Frame::Line("ok".into())]);
    }
}
