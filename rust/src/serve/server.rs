//! The `dlt serve` TCP server: thread-per-core accept loops, a
//! client-keyed shard router, bounded admission queues, and streamed
//! per-item responses.
//!
//! ## Architecture
//!
//! Every worker thread runs the same loop over a nonblocking clone of
//! the listener: accept new connections, read and frame bytes from
//! the connections it owns, parse frames into [`SolveRequest`]s, and
//! route each request to the session shard its client id hashes to.
//! Shards are striped across workers (`shard % workers`); a worker
//! solves from its own shards first (warm locality) and steals from
//! the *back* of other shards' queues when idle — the same deque
//! discipline as [`crate::experiments::sweep::parallel_map_steal`].
//! Warm state lives in the shard, not the worker, so a stolen solve
//! still hits the tenant's warm cache.
//!
//! Responses stream back in completion order, each line stamped with
//! the per-connection `seq` assigned at parse time, so a client can
//! pipeline a large batch and match responses without waiting for the
//! batch to finish.
//!
//! ## Admission control
//!
//! Each shard's queue is bounded ([`ServeOptions::queue_depth`]); a
//! request arriving at a full queue is rejected immediately with an
//! `overloaded` error carrying `retry_after_ms` — clients shed in
//! microseconds instead of queueing without bound. On shutdown the
//! workers stop accepting and parsing, finish every admitted job,
//! flush every outbuf, and exit (graceful drain).

use crate::api::wire::ServeDiagnostics;
use crate::api::{ApiError, SolveRequest, Solver};
use crate::config::json::Json;
use crate::error::{Error, Result};
use crate::serve::frame::{Frame, FrameReader};
use crate::serve::shard::SessionShard;
use std::collections::{HashMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Serving-tier configuration.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Bind address, e.g. `127.0.0.1:4517` (port `0` picks a free
    /// port; read it back from [`Server::local_addr`]).
    pub addr: String,
    /// Worker threads (`0` = one per core).
    pub workers: usize,
    /// Session shards (`0` = two per worker). More shards than
    /// workers keeps steal granularity fine and hash collisions rare.
    pub shards: usize,
    /// Bound on each shard's admission queue; a request arriving at a
    /// full queue is shed with `overloaded`. `0` sheds everything
    /// (useful to exercise the reject path).
    pub queue_depth: usize,
    /// Warm-state byte budget across all shards; each shard LRU-evicts
    /// whole client sessions beyond its `budget / shards` slice.
    pub warm_budget_bytes: usize,
    /// Back-off hint attached to shed responses.
    pub retry_after_ms: u64,
    /// Largest request line buffered per connection; longer lines are
    /// discarded and answered with a `config` error.
    pub max_frame_bytes: usize,
    /// Degraded mode: instead of shedding at a full queue, admit up to
    /// one extra queue-depth of overflow and answer those requests
    /// with a loosened first-order solve flagged `degraded: true`
    /// ([`crate::api::Session::solve_degraded`]). `queue_depth == 0`
    /// still sheds everything.
    pub degraded: bool,
    /// Server-wide deadline stamped on requests that carry no
    /// `timeout_ms` of their own; `None` leaves them unbounded.
    pub default_timeout_ms: Option<u64>,
    /// Solver configuration stamped onto every per-client session.
    pub solver: Solver,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            addr: "127.0.0.1:4517".to_string(),
            workers: 0,
            shards: 0,
            queue_depth: 64,
            warm_budget_bytes: 64 * 1024 * 1024,
            retry_after_ms: 50,
            max_frame_bytes: 1024 * 1024,
            degraded: false,
            default_timeout_ms: None,
            solver: Solver::new(),
        }
    }
}

/// Monotone server counters, snapshotted via [`Server::stats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    /// Connections accepted.
    pub connections: u64,
    /// Requests parsed off the wire (admitted or shed).
    pub requests: u64,
    /// Solve responses streamed back (success or solver error).
    pub responses: u64,
    /// Requests shed at admission (`overloaded`).
    pub shed: u64,
    /// Frames rejected before solving (bad JSON, oversize, non-UTF-8,
    /// malformed request).
    pub malformed: u64,
    /// Warm sessions LRU-evicted across all shards.
    pub evictions: u64,
    /// Requests that found their client session resident.
    pub shard_hits: u64,
    /// Requests that built a fresh client session.
    pub shard_misses: u64,
    /// Client sessions currently resident across all shards.
    pub resident_sessions: u64,
    /// Admitted jobs shed at dequeue because their deadline passed
    /// while they waited in the queue (`deadline_exceeded`).
    pub expired: u64,
    /// Overflow requests answered by the degraded path instead of
    /// being shed.
    pub degraded: u64,
}

struct Job {
    /// Worker that owns the originating connection.
    worker: usize,
    conn: u64,
    seq: u64,
    client: String,
    req: SolveRequest,
    /// When the job entered the queue (for expiry diagnostics).
    admitted: Instant,
    /// Absolute solve deadline (request `timeout_ms`, or the server
    /// default); checked again at dequeue so queue time counts.
    deadline: Option<Instant>,
    /// Admitted through the degraded overflow path: answer with a
    /// loosened first-order solve instead of the full pipeline.
    degraded: bool,
}

struct Completion {
    conn: u64,
    line: String,
}

struct Shard {
    queue: Mutex<VecDeque<Job>>,
    sessions: Mutex<SessionShard>,
}

struct Shared {
    opts: ServeOptions,
    nworkers: usize,
    shutdown: AtomicBool,
    /// Jobs admitted but not yet delivered to an outbuf (or dropped
    /// with their connection) — the graceful-drain barrier.
    pending: AtomicUsize,
    shards: Vec<Shard>,
    /// Per-worker inboxes for responses whose connection lives on
    /// another worker.
    completions: Vec<Mutex<VecDeque<Completion>>>,
    conn_ids: AtomicU64,
    connections: AtomicU64,
    requests: AtomicU64,
    responses: AtomicU64,
    shed: AtomicU64,
    malformed: AtomicU64,
    expired: AtomicU64,
    degraded_served: AtomicU64,
    /// Reloadable knobs, seeded from [`ServeOptions`] and swappable at
    /// runtime through the `{"reload": ...}` admin frame without
    /// dropping connections.
    queue_depth: AtomicUsize,
    retry_after_ms: AtomicU64,
    /// Per-shard warm byte budget (total budget / shard count).
    per_shard_budget: AtomicUsize,
    degraded: AtomicBool,
    /// Server-wide default deadline in ms; `0` = none.
    default_timeout_ms: AtomicU64,
}

/// A running server. Dropping the handle does **not** stop the worker
/// threads; call [`Server::shutdown`] (drain and join) or
/// [`Server::join`] (serve until the process dies).
pub struct Server {
    shared: Arc<Shared>,
    addr: SocketAddr,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `opts.addr` and start the worker threads.
    pub fn start(opts: ServeOptions) -> Result<Server> {
        let listener =
            TcpListener::bind(&opts.addr).map_err(|e| Error::io(opts.addr.clone(), e))?;
        listener.set_nonblocking(true).map_err(|e| Error::io(opts.addr.clone(), e))?;
        let addr = listener.local_addr().map_err(|e| Error::io(opts.addr.clone(), e))?;

        let nworkers = if opts.workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            opts.workers
        };
        let nshards = if opts.shards == 0 { nworkers * 2 } else { opts.shards };
        let per_shard_budget = (opts.warm_budget_bytes / nshards).max(1);
        let shards = (0..nshards)
            .map(|_| Shard {
                queue: Mutex::new(VecDeque::new()),
                sessions: Mutex::new(SessionShard::new(opts.solver.clone(), per_shard_budget)),
            })
            .collect();
        let completions = (0..nworkers).map(|_| Mutex::new(VecDeque::new())).collect();

        let (queue_depth, retry_after_ms) = (opts.queue_depth, opts.retry_after_ms);
        let (degraded, default_timeout) = (opts.degraded, opts.default_timeout_ms.unwrap_or(0));
        let shared = Arc::new(Shared {
            opts,
            nworkers,
            shutdown: AtomicBool::new(false),
            pending: AtomicUsize::new(0),
            shards,
            completions,
            conn_ids: AtomicU64::new(0),
            connections: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            responses: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            malformed: AtomicU64::new(0),
            expired: AtomicU64::new(0),
            degraded_served: AtomicU64::new(0),
            queue_depth: AtomicUsize::new(queue_depth),
            retry_after_ms: AtomicU64::new(retry_after_ms),
            per_shard_budget: AtomicUsize::new(per_shard_budget),
            degraded: AtomicBool::new(degraded),
            default_timeout_ms: AtomicU64::new(default_timeout),
        });

        let mut handles = Vec::with_capacity(nworkers);
        for w in 0..nworkers {
            let sh = Arc::clone(&shared);
            let lst = listener.try_clone().map_err(|e| Error::io("listener", e))?;
            let h = std::thread::Builder::new()
                .name(format!("dlt-serve-{w}"))
                .spawn(move || worker_loop(w, lst, sh))
                .map_err(|e| Error::io("spawn worker", e))?;
            handles.push(h);
        }
        Ok(Server { shared, addr, handles })
    }

    /// The bound address (resolves port `0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Worker threads running.
    pub fn workers(&self) -> usize {
        self.shared.nworkers
    }

    /// Session shards configured.
    pub fn shards(&self) -> usize {
        self.shared.shards.len()
    }

    /// Snapshot the monotone counters (cheap; takes each shard's
    /// session lock briefly).
    pub fn stats(&self) -> StatsSnapshot {
        snapshot(&self.shared)
    }

    /// Graceful drain: stop accepting and parsing, finish every
    /// admitted job, flush, join the workers.
    pub fn shutdown(self) -> StatsSnapshot {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        for h in self.handles {
            h.join().ok();
        }
        snapshot(&self.shared)
    }

    /// Serve until the process exits (the workers never return without
    /// a shutdown signal).
    pub fn join(self) {
        for h in self.handles {
            h.join().ok();
        }
    }
}

fn snapshot(shared: &Shared) -> StatsSnapshot {
    let mut snap = StatsSnapshot {
        connections: shared.connections.load(Ordering::Relaxed),
        requests: shared.requests.load(Ordering::Relaxed),
        responses: shared.responses.load(Ordering::Relaxed),
        shed: shared.shed.load(Ordering::Relaxed),
        malformed: shared.malformed.load(Ordering::Relaxed),
        expired: shared.expired.load(Ordering::Relaxed),
        degraded: shared.degraded_served.load(Ordering::Relaxed),
        ..StatsSnapshot::default()
    };
    for shard in &shared.shards {
        let sessions = lock_unpoisoned(&shard.sessions);
        snap.evictions += sessions.evictions;
        snap.shard_hits += sessions.hits;
        snap.shard_misses += sessions.misses;
        snap.resident_sessions += sessions.resident() as u64;
    }
    snap
}

/// Locks, ignoring poisoning: a worker that panicked mid-solve must
/// not wedge every other worker that shares the shard.
fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// FNV-1a client hash → shard index. Stable across runs so a tenant
/// re-lands on its warm shard after reconnecting.
fn shard_of(client: &str, nshards: usize) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in client.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h % nshards as u64) as usize
}

/// One live connection owned by a worker.
struct Conn {
    stream: TcpStream,
    reader: FrameReader,
    out: VecDeque<u8>,
    next_seq: u64,
    /// Read side open (false after EOF or a read/write error).
    open: bool,
    /// Admitted jobs whose response has not reached `out` yet; keeps
    /// a half-closed connection alive until its answers are flushed.
    inflight: usize,
}

impl Conn {
    fn take_seq(&mut self) -> u64 {
        let s = self.next_seq;
        self.next_seq += 1;
        s
    }

    fn queue_line(&mut self, line: &str) {
        self.out.extend(line.as_bytes());
        self.out.push_back(b'\n');
    }

    /// Write as much of the outbuf as the socket accepts right now.
    fn try_flush(&mut self) -> std::io::Result<()> {
        while !self.out.is_empty() {
            let (head, _) = self.out.as_slices();
            match self.stream.write(head) {
                Ok(0) => return Err(ErrorKind::WriteZero.into()),
                Ok(n) => {
                    self.out.drain(..n);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }
}

/// Insert `"seq": k` at the front of a response object.
fn with_seq(doc: &mut Json, seq: u64) {
    if let Json::Object(kv) = doc {
        kv.insert(0, ("seq".to_string(), Json::Num(seq as f64)));
    }
}

/// One error response line; `retry_after_ms` rides top-level so shed
/// clients can back off without parsing the message.
fn error_line(seq: u64, err: &ApiError, retry_after_ms: Option<u64>) -> String {
    let mut doc = err.to_json();
    if let Json::Object(kv) = &mut doc {
        if let Some(ms) = retry_after_ms {
            kv.insert(0, ("retry_after_ms".to_string(), Json::Num(ms as f64)));
        }
    }
    with_seq(&mut doc, seq);
    doc.to_string_compact()
}

/// Back-off hint for shed responses: the configured base scaled by the
/// shard queue length at shed time, so clients back off harder the
/// deeper the backlog — bounded above (32× the base, and one minute)
/// so a momentary spike cannot park clients forever. An empty queue
/// returns exactly the base.
fn adaptive_retry_ms(base: u64, queue_len: usize) -> u64 {
    let base = base.max(1);
    base.saturating_mul(1 + queue_len as u64).min(base.saturating_mul(32)).min(60_000)
}

const MAX_SOLVES_PER_PASS: usize = 4;
const READ_CHUNK: usize = 16 * 1024;
const IDLE_SLEEP: Duration = Duration::from_micros(200);

fn worker_loop(w: usize, listener: TcpListener, sh: Arc<Shared>) {
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut read_buf = vec![0u8; READ_CHUNK];
    loop {
        let draining = sh.shutdown.load(Ordering::SeqCst);
        let mut progressed = false;

        if !draining {
            progressed |= accept_new(&listener, &mut conns, &sh);
        }
        progressed |= pump_reads(w, &mut conns, &mut read_buf, draining, &sh);
        progressed |= drain_completions(w, &mut conns, &sh);
        progressed |= solve_some(w, &mut conns, &sh);

        for conn in conns.values_mut() {
            if conn.try_flush().is_err() {
                conn.open = false;
                conn.out.clear();
                conn.inflight = 0;
            }
        }
        conns.retain(|_, c| c.open || !c.out.is_empty() || c.inflight > 0);

        if draining {
            let idle = sh.pending.load(Ordering::SeqCst) == 0
                && lock_unpoisoned(&sh.completions[w]).is_empty()
                && conns.values().all(|c| c.out.is_empty());
            if idle {
                break;
            }
        }
        if !progressed {
            std::thread::sleep(IDLE_SLEEP);
        }
    }
}

fn accept_new(listener: &TcpListener, conns: &mut HashMap<u64, Conn>, sh: &Shared) -> bool {
    let mut any = false;
    loop {
        match listener.accept() {
            Ok((stream, _peer)) => {
                stream.set_nodelay(true).ok();
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                let id = sh.conn_ids.fetch_add(1, Ordering::Relaxed);
                sh.connections.fetch_add(1, Ordering::Relaxed);
                conns.insert(
                    id,
                    Conn {
                        stream,
                        reader: FrameReader::new(sh.opts.max_frame_bytes),
                        out: VecDeque::new(),
                        next_seq: 0,
                        open: true,
                        inflight: 0,
                    },
                );
                any = true;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(_) => break,
        }
    }
    any
}

fn pump_reads(
    w: usize,
    conns: &mut HashMap<u64, Conn>,
    read_buf: &mut [u8],
    draining: bool,
    sh: &Shared,
) -> bool {
    let mut any = false;
    for (&id, conn) in conns.iter_mut() {
        if conn.open {
            loop {
                match conn.stream.read(read_buf) {
                    Ok(0) => {
                        conn.open = false;
                        break;
                    }
                    Ok(n) => {
                        any = true;
                        conn.reader.push(&read_buf[..n]);
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        conn.open = false;
                        break;
                    }
                }
            }
        }
        // During drain the sockets still drain (so close is seen) but
        // buffered frames are not admitted.
        if draining {
            continue;
        }
        while let Some(frame) = conn.reader.next_frame() {
            any = true;
            handle_frame(w, id, conn, frame, sh);
        }
    }
    any
}

fn handle_frame(w: usize, conn_id: u64, conn: &mut Conn, frame: Frame, sh: &Shared) {
    match frame {
        Frame::Line(text) => match Json::parse(&text) {
            // An array frame is a batch: every element gets its own
            // seq and its own streamed response line.
            Ok(Json::Array(items)) => {
                for item in &items {
                    admit_request(w, conn_id, conn, item, sh);
                }
            }
            // An admin frame ({"reload": {...}}) swaps the reloadable
            // serving knobs in place; everything else is a request.
            Ok(doc) if doc.get("reload").is_some() => handle_reload(conn, &doc, sh),
            Ok(doc) => admit_request(w, conn_id, conn, &doc, sh),
            Err(e) => {
                let seq = conn.take_seq();
                sh.malformed.fetch_add(1, Ordering::Relaxed);
                conn.queue_line(&error_line(seq, &ApiError::from(e), None));
            }
        },
        Frame::Oversize { dropped } => {
            let seq = conn.take_seq();
            sh.malformed.fetch_add(1, Ordering::Relaxed);
            let err = ApiError::from(Error::Config(format!(
                "frame exceeded {} bytes ({dropped} dropped)",
                sh.opts.max_frame_bytes
            )));
            conn.queue_line(&error_line(seq, &err, None));
        }
        Frame::NotUtf8 => {
            let seq = conn.take_seq();
            sh.malformed.fetch_add(1, Ordering::Relaxed);
            let err = ApiError::from(Error::Config("frame is not valid UTF-8".to_string()));
            conn.queue_line(&error_line(seq, &err, None));
        }
    }
}

/// Parse one request document, route it to its shard, and admit or
/// shed it. Every outcome produces exactly one response line carrying
/// this request's seq.
fn admit_request(w: usize, conn_id: u64, conn: &mut Conn, doc: &Json, sh: &Shared) {
    let seq = conn.take_seq();
    sh.requests.fetch_add(1, Ordering::Relaxed);
    let req = match SolveRequest::from_json(doc) {
        Ok(r) => r,
        Err(e) => {
            sh.malformed.fetch_add(1, Ordering::Relaxed);
            conn.queue_line(&error_line(seq, &ApiError::from(e), None));
            return;
        }
    };
    // Tenant key: the optional top-level `client` field; anonymous
    // connections fall back to a per-connection key so they still
    // warm-start against themselves.
    let client = match doc.get("client") {
        Some(c) => match c.as_str() {
            Ok(s) => s.to_string(),
            Err(e) => {
                sh.malformed.fetch_add(1, Ordering::Relaxed);
                conn.queue_line(&error_line(seq, &ApiError::from(e), None));
                return;
            }
        },
        None => format!("conn-{conn_id}"),
    };
    let shard = shard_of(&client, sh.shards.len());
    // Deadline: the request's own timeout, falling back to the server
    // default. Stamped as an absolute instant so time spent queued
    // counts against it.
    let timeout_ms = req.options.timeout_ms.or({
        let d = sh.default_timeout_ms.load(Ordering::Relaxed);
        (d > 0).then_some(d)
    });
    let admitted = Instant::now();
    let deadline = timeout_ms.map(|ms| admitted + Duration::from_millis(ms));
    let depth = sh.queue_depth.load(Ordering::Relaxed);
    let mut queue = lock_unpoisoned(&sh.shards[shard].queue);
    let qlen = queue.len();
    let overflow = qlen >= depth;
    // Degraded mode absorbs up to one extra queue-depth of overflow
    // with loosened solves; `depth == 0` still sheds everything.
    let degraded =
        overflow && sh.degraded.load(Ordering::Relaxed) && qlen < depth.saturating_mul(2);
    if overflow && !degraded {
        drop(queue);
        sh.shed.fetch_add(1, Ordering::Relaxed);
        let ms = adaptive_retry_ms(sh.retry_after_ms.load(Ordering::Relaxed), qlen);
        let err = ApiError::from(Error::Overloaded { retry_after_ms: ms });
        conn.queue_line(&error_line(seq, &err, Some(ms)));
        return;
    }
    queue.push_back(Job {
        worker: w,
        conn: conn_id,
        seq,
        client,
        req,
        admitted,
        deadline,
        degraded,
    });
    drop(queue);
    sh.pending.fetch_add(1, Ordering::SeqCst);
    conn.inflight += 1;
}

/// Apply a `{"reload": {...}}` admin frame: swap the reloadable
/// serving knobs (`queue_depth`, `retry_after_ms`, `warm_budget_kb`,
/// `degraded`, `default_timeout_ms`; the latter `0` clears the
/// default) without dropping a single connection. A shrunken warm
/// budget takes effect on each shard's next post-solve eviction pass.
/// Unknown keys are a typed `config` error; the ack echoes every
/// effective value.
fn handle_reload(conn: &mut Conn, doc: &Json, sh: &Shared) {
    let seq = conn.take_seq();
    let applied = (|| -> Result<()> {
        let r = doc.req("reload")?;
        const KNOWN: [&str; 5] =
            ["queue_depth", "retry_after_ms", "warm_budget_kb", "degraded", "default_timeout_ms"];
        let Json::Object(kv) = r else {
            return Err(Error::Config(format!("reload must be an object, got {r:?}")));
        };
        if let Some((k, _)) = kv.iter().find(|(k, _)| !KNOWN.contains(&k.as_str())) {
            return Err(Error::Config(format!("unknown reload key `{k}`")));
        }
        if let Some(v) = r.get("queue_depth") {
            sh.queue_depth.store(v.as_usize()?, Ordering::Relaxed);
        }
        if let Some(v) = r.get("retry_after_ms") {
            sh.retry_after_ms.store(v.as_usize()? as u64, Ordering::Relaxed);
        }
        if let Some(v) = r.get("warm_budget_kb") {
            let per_shard = (v.as_usize()? * 1024 / sh.shards.len()).max(1);
            sh.per_shard_budget.store(per_shard, Ordering::Relaxed);
            for shard in &sh.shards {
                lock_unpoisoned(&shard.sessions).set_budget(per_shard);
            }
        }
        if let Some(v) = r.get("degraded") {
            sh.degraded.store(v.as_bool()?, Ordering::Relaxed);
        }
        if let Some(v) = r.get("default_timeout_ms") {
            sh.default_timeout_ms.store(v.as_usize()? as u64, Ordering::Relaxed);
        }
        Ok(())
    })();
    match applied {
        Ok(()) => {
            let per_shard = sh.per_shard_budget.load(Ordering::Relaxed);
            let mut doc = Json::Object(vec![(
                "reloaded".into(),
                Json::Object(vec![
                    (
                        "queue_depth".into(),
                        Json::Num(sh.queue_depth.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "retry_after_ms".into(),
                        Json::Num(sh.retry_after_ms.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "warm_budget_bytes".into(),
                        Json::Num((per_shard * sh.shards.len()) as f64),
                    ),
                    ("degraded".into(), Json::Bool(sh.degraded.load(Ordering::Relaxed))),
                    (
                        "default_timeout_ms".into(),
                        Json::Num(sh.default_timeout_ms.load(Ordering::Relaxed) as f64),
                    ),
                ]),
            )]);
            with_seq(&mut doc, seq);
            conn.queue_line(&doc.to_string_compact());
        }
        Err(e) => {
            sh.malformed.fetch_add(1, Ordering::Relaxed);
            conn.queue_line(&error_line(seq, &ApiError::from(e), None));
        }
    }
}

fn drain_completions(w: usize, conns: &mut HashMap<u64, Conn>, sh: &Shared) -> bool {
    let mut any = false;
    loop {
        let completion = lock_unpoisoned(&sh.completions[w]).pop_front();
        let Some(c) = completion else { break };
        any = true;
        if let Some(conn) = conns.get_mut(&c.conn) {
            conn.queue_line(&c.line);
            conn.inflight = conn.inflight.saturating_sub(1);
        }
        sh.pending.fetch_sub(1, Ordering::SeqCst);
    }
    any
}

/// Solve up to [`MAX_SOLVES_PER_PASS`] queued jobs: own shards from
/// the queue front, then other workers' shards from the back (steal).
/// The cap keeps the loop returning to reads and flushes, so under
/// overload the bounded queues — not the kernel socket buffers — are
/// what fills, and admission control actually triggers.
fn solve_some(w: usize, conns: &mut HashMap<u64, Conn>, sh: &Shared) -> bool {
    let mut solved = 0usize;
    for pass in 0..2usize {
        for (s, shard) in sh.shards.iter().enumerate() {
            let own = s % sh.nworkers == w;
            if (pass == 0) != own {
                continue;
            }
            while solved < MAX_SOLVES_PER_PASS {
                let (job, qlen) = {
                    let mut queue = lock_unpoisoned(&shard.queue);
                    let j = if own { queue.pop_front() } else { queue.pop_back() };
                    let remaining = queue.len();
                    (j, remaining)
                };
                let Some(job) = job else { break };
                // A job whose deadline passed while it queued is shed
                // here, with a back-off hint, without consuming one of
                // this pass's solve slots — expiry must not starve the
                // live jobs behind it.
                if job.deadline.is_some_and(|dl| Instant::now() >= dl) {
                    sh.expired.fetch_add(1, Ordering::Relaxed);
                    sh.responses.fetch_add(1, Ordering::Relaxed);
                    let ms =
                        adaptive_retry_ms(sh.retry_after_ms.load(Ordering::Relaxed), qlen);
                    let err = ApiError::from(Error::DeadlineExceeded {
                        elapsed_ms: job.admitted.elapsed().as_millis() as u64,
                        iterations: 0,
                        phase: "queue".into(),
                    });
                    deliver(w, conns, sh, &job, error_line(job.seq, &err, Some(ms)));
                    continue;
                }
                solved += 1;
                let line = solve_job(s, &job, sh);
                deliver(w, conns, sh, &job, line);
            }
            if solved >= MAX_SOLVES_PER_PASS {
                break;
            }
        }
        if solved >= MAX_SOLVES_PER_PASS {
            break;
        }
    }
    solved > 0
}

/// Route a finished line back to the job's connection: directly when
/// this worker owns it, through the owner's completion inbox
/// otherwise.
fn deliver(w: usize, conns: &mut HashMap<u64, Conn>, sh: &Shared, job: &Job, line: String) {
    if job.worker == w {
        if let Some(conn) = conns.get_mut(&job.conn) {
            conn.queue_line(&line);
            conn.inflight = conn.inflight.saturating_sub(1);
        }
        sh.pending.fetch_sub(1, Ordering::SeqCst);
    } else {
        lock_unpoisoned(&sh.completions[job.worker])
            .push_back(Completion { conn: job.conn, line });
    }
}

/// Solve one admitted job on its shard's warm session and render the
/// response line. A panicking solve costs the client its warm session
/// and yields a `worker_panicked` error — never a dead worker.
fn solve_job(shard_idx: usize, job: &Job, sh: &Shared) -> String {
    let shard = &sh.shards[shard_idx];
    // Re-stamp the deadline as the time still remaining, so the solve
    // budget accounts for time already spent in the queue.
    let mut req = job.req.clone();
    if let Some(dl) = job.deadline {
        let left = dl.saturating_duration_since(Instant::now());
        req.options.timeout_ms = Some(left.as_millis() as u64);
    }
    let (outcome, shard_hit, evictions, resident) = {
        let mut sessions = lock_unpoisoned(&shard.sessions);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let (session, hit) = sessions.session_for(&job.client);
            let out = if job.degraded {
                sh.degraded_served.fetch_add(1, Ordering::Relaxed);
                session.solve_degraded(&req)
            } else {
                session.solve(&req)
            };
            (out, hit)
        }));
        match caught {
            Ok((result, hit)) => {
                sessions.evict_to_budget(&job.client);
                (result, hit, sessions.evictions, sessions.resident())
            }
            Err(_) => {
                sessions.discard(&job.client);
                let err = ApiError::from(Error::WorkerPanicked(format!(
                    "solve panicked for client `{}`",
                    job.client
                )));
                (Err(err), false, sessions.evictions, sessions.resident())
            }
        }
    };
    sh.responses.fetch_add(1, Ordering::Relaxed);
    match outcome {
        Ok(mut resp) => {
            resp.diagnostics.serve =
                Some(ServeDiagnostics { shard: shard_idx, shard_hit, evictions, resident });
            let mut doc = resp.to_json();
            with_seq(&mut doc, job.seq);
            doc.to_string_compact()
        }
        Err(e) => error_line(job.seq, &e, None),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_router_is_stable_and_in_range() {
        for nshards in [1usize, 2, 7, 16] {
            for client in ["a", "tenant-42", "", "conn-123456"] {
                let s = shard_of(client, nshards);
                assert!(s < nshards);
                assert_eq!(s, shard_of(client, nshards), "stable");
            }
        }
    }

    #[test]
    fn adaptive_retry_hint_scales_with_queue_and_is_bounded() {
        // Empty queue: exactly the configured base (pinned by the
        // framing tests' zero-depth shed case).
        assert_eq!(adaptive_retry_ms(17, 0), 17);
        // Deeper queue => larger hint.
        assert!(adaptive_retry_ms(17, 4) > adaptive_retry_ms(17, 1));
        assert!(adaptive_retry_ms(17, 1) > adaptive_retry_ms(17, 0));
        // Bounded above: 32x the base, and one minute overall.
        assert_eq!(adaptive_retry_ms(17, 1_000_000), 17 * 32);
        assert_eq!(adaptive_retry_ms(50_000, 1_000_000), 60_000);
        // A zero base still yields a finite, nonzero hint.
        assert!(adaptive_retry_ms(0, 5) >= 1);
    }

    #[test]
    fn error_line_carries_seq_and_retry_hint() {
        let err = ApiError::from(Error::Overloaded { retry_after_ms: 25 });
        let line = error_line(7, &err, Some(25));
        let doc = Json::parse(&line).unwrap();
        assert_eq!(doc.req("seq").unwrap().as_usize().unwrap(), 7);
        assert_eq!(doc.req("retry_after_ms").unwrap().as_usize().unwrap(), 25);
        assert_eq!(
            doc.req("error").unwrap().req("kind").unwrap().as_str().unwrap(),
            "overloaded"
        );
    }
}
