//! The `dlt serve` TCP server: thread-per-core accept loops, a
//! client-keyed shard router, bounded admission queues, and streamed
//! per-item responses.
//!
//! ## Architecture
//!
//! Every worker thread runs the same loop over a nonblocking clone of
//! the listener: accept new connections, read and frame bytes from
//! the connections it owns, parse frames into [`SolveRequest`]s, and
//! route each request to the session shard its client id hashes to.
//! Shards are striped across workers (`shard % workers`); a worker
//! solves from its own shards first (warm locality) and steals from
//! the *back* of other shards' queues when idle — the same deque
//! discipline as [`crate::experiments::sweep::parallel_map_steal`].
//! Warm state lives in the shard, not the worker, so a stolen solve
//! still hits the tenant's warm cache.
//!
//! Responses stream back in completion order, each line stamped with
//! the per-connection `seq` assigned at parse time, so a client can
//! pipeline a large batch and match responses without waiting for the
//! batch to finish.
//!
//! ## Admission control
//!
//! Each shard's queue is bounded ([`ServeOptions::queue_depth`]); a
//! request arriving at a full queue is rejected immediately with an
//! `overloaded` error carrying `retry_after_ms` — clients shed in
//! microseconds instead of queueing without bound. On shutdown the
//! workers stop accepting and parsing, finish every admitted job,
//! flush every outbuf, and exit (graceful drain).

use crate::api::wire::ServeDiagnostics;
use crate::api::{ApiError, SolveRequest, Solver};
use crate::config::json::Json;
use crate::error::{Error, Result};
use crate::serve::frame::{Frame, FrameReader};
use crate::serve::shard::SessionShard;
use std::collections::{HashMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

/// Serving-tier configuration.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Bind address, e.g. `127.0.0.1:4517` (port `0` picks a free
    /// port; read it back from [`Server::local_addr`]).
    pub addr: String,
    /// Worker threads (`0` = one per core).
    pub workers: usize,
    /// Session shards (`0` = two per worker). More shards than
    /// workers keeps steal granularity fine and hash collisions rare.
    pub shards: usize,
    /// Bound on each shard's admission queue; a request arriving at a
    /// full queue is shed with `overloaded`. `0` sheds everything
    /// (useful to exercise the reject path).
    pub queue_depth: usize,
    /// Warm-state byte budget across all shards; each shard LRU-evicts
    /// whole client sessions beyond its `budget / shards` slice.
    pub warm_budget_bytes: usize,
    /// Back-off hint attached to shed responses.
    pub retry_after_ms: u64,
    /// Largest request line buffered per connection; longer lines are
    /// discarded and answered with a `config` error.
    pub max_frame_bytes: usize,
    /// Solver configuration stamped onto every per-client session.
    pub solver: Solver,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            addr: "127.0.0.1:4517".to_string(),
            workers: 0,
            shards: 0,
            queue_depth: 64,
            warm_budget_bytes: 64 * 1024 * 1024,
            retry_after_ms: 50,
            max_frame_bytes: 1024 * 1024,
            solver: Solver::new(),
        }
    }
}

/// Monotone server counters, snapshotted via [`Server::stats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    /// Connections accepted.
    pub connections: u64,
    /// Requests parsed off the wire (admitted or shed).
    pub requests: u64,
    /// Solve responses streamed back (success or solver error).
    pub responses: u64,
    /// Requests shed at admission (`overloaded`).
    pub shed: u64,
    /// Frames rejected before solving (bad JSON, oversize, non-UTF-8,
    /// malformed request).
    pub malformed: u64,
    /// Warm sessions LRU-evicted across all shards.
    pub evictions: u64,
    /// Requests that found their client session resident.
    pub shard_hits: u64,
    /// Requests that built a fresh client session.
    pub shard_misses: u64,
    /// Client sessions currently resident across all shards.
    pub resident_sessions: u64,
}

struct Job {
    /// Worker that owns the originating connection.
    worker: usize,
    conn: u64,
    seq: u64,
    client: String,
    req: SolveRequest,
}

struct Completion {
    conn: u64,
    line: String,
}

struct Shard {
    queue: Mutex<VecDeque<Job>>,
    sessions: Mutex<SessionShard>,
}

struct Shared {
    opts: ServeOptions,
    nworkers: usize,
    shutdown: AtomicBool,
    /// Jobs admitted but not yet delivered to an outbuf (or dropped
    /// with their connection) — the graceful-drain barrier.
    pending: AtomicUsize,
    shards: Vec<Shard>,
    /// Per-worker inboxes for responses whose connection lives on
    /// another worker.
    completions: Vec<Mutex<VecDeque<Completion>>>,
    conn_ids: AtomicU64,
    connections: AtomicU64,
    requests: AtomicU64,
    responses: AtomicU64,
    shed: AtomicU64,
    malformed: AtomicU64,
}

/// A running server. Dropping the handle does **not** stop the worker
/// threads; call [`Server::shutdown`] (drain and join) or
/// [`Server::join`] (serve until the process dies).
pub struct Server {
    shared: Arc<Shared>,
    addr: SocketAddr,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `opts.addr` and start the worker threads.
    pub fn start(opts: ServeOptions) -> Result<Server> {
        let listener =
            TcpListener::bind(&opts.addr).map_err(|e| Error::io(opts.addr.clone(), e))?;
        listener.set_nonblocking(true).map_err(|e| Error::io(opts.addr.clone(), e))?;
        let addr = listener.local_addr().map_err(|e| Error::io(opts.addr.clone(), e))?;

        let nworkers = if opts.workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            opts.workers
        };
        let nshards = if opts.shards == 0 { nworkers * 2 } else { opts.shards };
        let per_shard_budget = (opts.warm_budget_bytes / nshards).max(1);
        let shards = (0..nshards)
            .map(|_| Shard {
                queue: Mutex::new(VecDeque::new()),
                sessions: Mutex::new(SessionShard::new(opts.solver.clone(), per_shard_budget)),
            })
            .collect();
        let completions = (0..nworkers).map(|_| Mutex::new(VecDeque::new())).collect();

        let shared = Arc::new(Shared {
            opts,
            nworkers,
            shutdown: AtomicBool::new(false),
            pending: AtomicUsize::new(0),
            shards,
            completions,
            conn_ids: AtomicU64::new(0),
            connections: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            responses: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            malformed: AtomicU64::new(0),
        });

        let mut handles = Vec::with_capacity(nworkers);
        for w in 0..nworkers {
            let sh = Arc::clone(&shared);
            let lst = listener.try_clone().map_err(|e| Error::io("listener", e))?;
            let h = std::thread::Builder::new()
                .name(format!("dlt-serve-{w}"))
                .spawn(move || worker_loop(w, lst, sh))
                .map_err(|e| Error::io("spawn worker", e))?;
            handles.push(h);
        }
        Ok(Server { shared, addr, handles })
    }

    /// The bound address (resolves port `0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Worker threads running.
    pub fn workers(&self) -> usize {
        self.shared.nworkers
    }

    /// Session shards configured.
    pub fn shards(&self) -> usize {
        self.shared.shards.len()
    }

    /// Snapshot the monotone counters (cheap; takes each shard's
    /// session lock briefly).
    pub fn stats(&self) -> StatsSnapshot {
        snapshot(&self.shared)
    }

    /// Graceful drain: stop accepting and parsing, finish every
    /// admitted job, flush, join the workers.
    pub fn shutdown(self) -> StatsSnapshot {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        for h in self.handles {
            h.join().ok();
        }
        snapshot(&self.shared)
    }

    /// Serve until the process exits (the workers never return without
    /// a shutdown signal).
    pub fn join(self) {
        for h in self.handles {
            h.join().ok();
        }
    }
}

fn snapshot(shared: &Shared) -> StatsSnapshot {
    let mut snap = StatsSnapshot {
        connections: shared.connections.load(Ordering::Relaxed),
        requests: shared.requests.load(Ordering::Relaxed),
        responses: shared.responses.load(Ordering::Relaxed),
        shed: shared.shed.load(Ordering::Relaxed),
        malformed: shared.malformed.load(Ordering::Relaxed),
        ..StatsSnapshot::default()
    };
    for shard in &shared.shards {
        let sessions = lock_unpoisoned(&shard.sessions);
        snap.evictions += sessions.evictions;
        snap.shard_hits += sessions.hits;
        snap.shard_misses += sessions.misses;
        snap.resident_sessions += sessions.resident() as u64;
    }
    snap
}

/// Locks, ignoring poisoning: a worker that panicked mid-solve must
/// not wedge every other worker that shares the shard.
fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// FNV-1a client hash → shard index. Stable across runs so a tenant
/// re-lands on its warm shard after reconnecting.
fn shard_of(client: &str, nshards: usize) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in client.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h % nshards as u64) as usize
}

/// One live connection owned by a worker.
struct Conn {
    stream: TcpStream,
    reader: FrameReader,
    out: VecDeque<u8>,
    next_seq: u64,
    /// Read side open (false after EOF or a read/write error).
    open: bool,
    /// Admitted jobs whose response has not reached `out` yet; keeps
    /// a half-closed connection alive until its answers are flushed.
    inflight: usize,
}

impl Conn {
    fn take_seq(&mut self) -> u64 {
        let s = self.next_seq;
        self.next_seq += 1;
        s
    }

    fn queue_line(&mut self, line: &str) {
        self.out.extend(line.as_bytes());
        self.out.push_back(b'\n');
    }

    /// Write as much of the outbuf as the socket accepts right now.
    fn try_flush(&mut self) -> std::io::Result<()> {
        while !self.out.is_empty() {
            let (head, _) = self.out.as_slices();
            match self.stream.write(head) {
                Ok(0) => return Err(ErrorKind::WriteZero.into()),
                Ok(n) => {
                    self.out.drain(..n);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }
}

/// Insert `"seq": k` at the front of a response object.
fn with_seq(doc: &mut Json, seq: u64) {
    if let Json::Object(kv) = doc {
        kv.insert(0, ("seq".to_string(), Json::Num(seq as f64)));
    }
}

/// One error response line; `retry_after_ms` rides top-level so shed
/// clients can back off without parsing the message.
fn error_line(seq: u64, err: &ApiError, retry_after_ms: Option<u64>) -> String {
    let mut doc = err.to_json();
    if let Json::Object(kv) = &mut doc {
        if let Some(ms) = retry_after_ms {
            kv.insert(0, ("retry_after_ms".to_string(), Json::Num(ms as f64)));
        }
    }
    with_seq(&mut doc, seq);
    doc.to_string_compact()
}

const MAX_SOLVES_PER_PASS: usize = 4;
const READ_CHUNK: usize = 16 * 1024;
const IDLE_SLEEP: Duration = Duration::from_micros(200);

fn worker_loop(w: usize, listener: TcpListener, sh: Arc<Shared>) {
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut read_buf = vec![0u8; READ_CHUNK];
    loop {
        let draining = sh.shutdown.load(Ordering::SeqCst);
        let mut progressed = false;

        if !draining {
            progressed |= accept_new(&listener, &mut conns, &sh);
        }
        progressed |= pump_reads(w, &mut conns, &mut read_buf, draining, &sh);
        progressed |= drain_completions(w, &mut conns, &sh);
        progressed |= solve_some(w, &mut conns, &sh);

        for conn in conns.values_mut() {
            if conn.try_flush().is_err() {
                conn.open = false;
                conn.out.clear();
                conn.inflight = 0;
            }
        }
        conns.retain(|_, c| c.open || !c.out.is_empty() || c.inflight > 0);

        if draining {
            let idle = sh.pending.load(Ordering::SeqCst) == 0
                && lock_unpoisoned(&sh.completions[w]).is_empty()
                && conns.values().all(|c| c.out.is_empty());
            if idle {
                break;
            }
        }
        if !progressed {
            std::thread::sleep(IDLE_SLEEP);
        }
    }
}

fn accept_new(listener: &TcpListener, conns: &mut HashMap<u64, Conn>, sh: &Shared) -> bool {
    let mut any = false;
    loop {
        match listener.accept() {
            Ok((stream, _peer)) => {
                stream.set_nodelay(true).ok();
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                let id = sh.conn_ids.fetch_add(1, Ordering::Relaxed);
                sh.connections.fetch_add(1, Ordering::Relaxed);
                conns.insert(
                    id,
                    Conn {
                        stream,
                        reader: FrameReader::new(sh.opts.max_frame_bytes),
                        out: VecDeque::new(),
                        next_seq: 0,
                        open: true,
                        inflight: 0,
                    },
                );
                any = true;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(_) => break,
        }
    }
    any
}

fn pump_reads(
    w: usize,
    conns: &mut HashMap<u64, Conn>,
    read_buf: &mut [u8],
    draining: bool,
    sh: &Shared,
) -> bool {
    let mut any = false;
    for (&id, conn) in conns.iter_mut() {
        if conn.open {
            loop {
                match conn.stream.read(read_buf) {
                    Ok(0) => {
                        conn.open = false;
                        break;
                    }
                    Ok(n) => {
                        any = true;
                        conn.reader.push(&read_buf[..n]);
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        conn.open = false;
                        break;
                    }
                }
            }
        }
        // During drain the sockets still drain (so close is seen) but
        // buffered frames are not admitted.
        if draining {
            continue;
        }
        while let Some(frame) = conn.reader.next_frame() {
            any = true;
            handle_frame(w, id, conn, frame, sh);
        }
    }
    any
}

fn handle_frame(w: usize, conn_id: u64, conn: &mut Conn, frame: Frame, sh: &Shared) {
    match frame {
        Frame::Line(text) => match Json::parse(&text) {
            // An array frame is a batch: every element gets its own
            // seq and its own streamed response line.
            Ok(Json::Array(items)) => {
                for item in &items {
                    admit_request(w, conn_id, conn, item, sh);
                }
            }
            Ok(doc) => admit_request(w, conn_id, conn, &doc, sh),
            Err(e) => {
                let seq = conn.take_seq();
                sh.malformed.fetch_add(1, Ordering::Relaxed);
                conn.queue_line(&error_line(seq, &ApiError::from(e), None));
            }
        },
        Frame::Oversize { dropped } => {
            let seq = conn.take_seq();
            sh.malformed.fetch_add(1, Ordering::Relaxed);
            let err = ApiError::from(Error::Config(format!(
                "frame exceeded {} bytes ({dropped} dropped)",
                sh.opts.max_frame_bytes
            )));
            conn.queue_line(&error_line(seq, &err, None));
        }
        Frame::NotUtf8 => {
            let seq = conn.take_seq();
            sh.malformed.fetch_add(1, Ordering::Relaxed);
            let err = ApiError::from(Error::Config("frame is not valid UTF-8".to_string()));
            conn.queue_line(&error_line(seq, &err, None));
        }
    }
}

/// Parse one request document, route it to its shard, and admit or
/// shed it. Every outcome produces exactly one response line carrying
/// this request's seq.
fn admit_request(w: usize, conn_id: u64, conn: &mut Conn, doc: &Json, sh: &Shared) {
    let seq = conn.take_seq();
    sh.requests.fetch_add(1, Ordering::Relaxed);
    let req = match SolveRequest::from_json(doc) {
        Ok(r) => r,
        Err(e) => {
            sh.malformed.fetch_add(1, Ordering::Relaxed);
            conn.queue_line(&error_line(seq, &ApiError::from(e), None));
            return;
        }
    };
    // Tenant key: the optional top-level `client` field; anonymous
    // connections fall back to a per-connection key so they still
    // warm-start against themselves.
    let client = match doc.get("client") {
        Some(c) => match c.as_str() {
            Ok(s) => s.to_string(),
            Err(e) => {
                sh.malformed.fetch_add(1, Ordering::Relaxed);
                conn.queue_line(&error_line(seq, &ApiError::from(e), None));
                return;
            }
        },
        None => format!("conn-{conn_id}"),
    };
    let shard = shard_of(&client, sh.shards.len());
    let mut queue = lock_unpoisoned(&sh.shards[shard].queue);
    if queue.len() >= sh.opts.queue_depth {
        drop(queue);
        sh.shed.fetch_add(1, Ordering::Relaxed);
        let ms = sh.opts.retry_after_ms;
        let err = ApiError::from(Error::Overloaded { retry_after_ms: ms });
        conn.queue_line(&error_line(seq, &err, Some(ms)));
        return;
    }
    queue.push_back(Job { worker: w, conn: conn_id, seq, client, req });
    drop(queue);
    sh.pending.fetch_add(1, Ordering::SeqCst);
    conn.inflight += 1;
}

fn drain_completions(w: usize, conns: &mut HashMap<u64, Conn>, sh: &Shared) -> bool {
    let mut any = false;
    loop {
        let completion = lock_unpoisoned(&sh.completions[w]).pop_front();
        let Some(c) = completion else { break };
        any = true;
        if let Some(conn) = conns.get_mut(&c.conn) {
            conn.queue_line(&c.line);
            conn.inflight = conn.inflight.saturating_sub(1);
        }
        sh.pending.fetch_sub(1, Ordering::SeqCst);
    }
    any
}

/// Solve up to [`MAX_SOLVES_PER_PASS`] queued jobs: own shards from
/// the queue front, then other workers' shards from the back (steal).
/// The cap keeps the loop returning to reads and flushes, so under
/// overload the bounded queues — not the kernel socket buffers — are
/// what fills, and admission control actually triggers.
fn solve_some(w: usize, conns: &mut HashMap<u64, Conn>, sh: &Shared) -> bool {
    let mut solved = 0usize;
    for pass in 0..2usize {
        for (s, shard) in sh.shards.iter().enumerate() {
            let own = s % sh.nworkers == w;
            if (pass == 0) != own {
                continue;
            }
            while solved < MAX_SOLVES_PER_PASS {
                let job = {
                    let mut queue = lock_unpoisoned(&shard.queue);
                    if own {
                        queue.pop_front()
                    } else {
                        queue.pop_back()
                    }
                };
                let Some(job) = job else { break };
                solved += 1;
                let line = solve_job(s, &job, sh);
                if job.worker == w {
                    if let Some(conn) = conns.get_mut(&job.conn) {
                        conn.queue_line(&line);
                        conn.inflight = conn.inflight.saturating_sub(1);
                    }
                    sh.pending.fetch_sub(1, Ordering::SeqCst);
                } else {
                    lock_unpoisoned(&sh.completions[job.worker])
                        .push_back(Completion { conn: job.conn, line });
                }
            }
            if solved >= MAX_SOLVES_PER_PASS {
                break;
            }
        }
        if solved >= MAX_SOLVES_PER_PASS {
            break;
        }
    }
    solved > 0
}

/// Solve one admitted job on its shard's warm session and render the
/// response line. A panicking solve costs the client its warm session
/// and yields a `worker_panicked` error — never a dead worker.
fn solve_job(shard_idx: usize, job: &Job, sh: &Shared) -> String {
    let shard = &sh.shards[shard_idx];
    let (outcome, shard_hit, evictions, resident) = {
        let mut sessions = lock_unpoisoned(&shard.sessions);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let (session, hit) = sessions.session_for(&job.client);
            (session.solve(&job.req), hit)
        }));
        match caught {
            Ok((result, hit)) => {
                sessions.evict_to_budget(&job.client);
                (result, hit, sessions.evictions, sessions.resident())
            }
            Err(_) => {
                sessions.discard(&job.client);
                let err = ApiError::from(Error::WorkerPanicked(format!(
                    "solve panicked for client `{}`",
                    job.client
                )));
                (Err(err), false, sessions.evictions, sessions.resident())
            }
        }
    };
    sh.responses.fetch_add(1, Ordering::Relaxed);
    match outcome {
        Ok(mut resp) => {
            resp.diagnostics.serve =
                Some(ServeDiagnostics { shard: shard_idx, shard_hit, evictions, resident });
            let mut doc = resp.to_json();
            with_seq(&mut doc, job.seq);
            doc.to_string_compact()
        }
        Err(e) => error_line(job.seq, &e, None),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_router_is_stable_and_in_range() {
        for nshards in [1usize, 2, 7, 16] {
            for client in ["a", "tenant-42", "", "conn-123456"] {
                let s = shard_of(client, nshards);
                assert!(s < nshards);
                assert_eq!(s, shard_of(client, nshards), "stable");
            }
        }
    }

    #[test]
    fn error_line_carries_seq_and_retry_hint() {
        let err = ApiError::from(Error::Overloaded { retry_after_ms: 25 });
        let line = error_line(7, &err, Some(25));
        let doc = Json::parse(&line).unwrap();
        assert_eq!(doc.req("seq").unwrap().as_usize().unwrap(), 7);
        assert_eq!(doc.req("retry_after_ms").unwrap().as_usize().unwrap(), 25);
        assert_eq!(
            doc.req("error").unwrap().req("kind").unwrap().as_str().unwrap(),
            "overloaded"
        );
    }
}
