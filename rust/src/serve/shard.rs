//! Session shards: per-tenant warm solver state with LRU eviction.
//!
//! Each shard owns a map from client id to a warm
//! [`Session`](crate::api::Session). Requests for the same client
//! always hash to the same shard (see the router in
//! [`server`](crate::serve::server)), so a tenant's warm-start caches
//! and projection seeds stay hot across its whole connection — and
//! across *reconnections* — without any cross-thread cache sharing.
//! Whenever the shard's approximate resident bytes
//! ([`Session::warm_bytes`](crate::api::Session::warm_bytes)) exceed
//! its budget, the least-recently-used sessions are evicted whole (a
//! cold client re-pays one phase-1 solve, nothing else).

use crate::api::{Session, Solver};
use std::collections::HashMap;

/// One shard's client sessions plus its LRU/eviction accounting.
#[derive(Debug)]
pub struct SessionShard {
    solver: Solver,
    budget_bytes: usize,
    tick: u64,
    sessions: HashMap<String, Entry>,
    /// Warm sessions evicted so far to stay under the byte budget.
    pub evictions: u64,
    /// Requests that found their client's session resident.
    pub hits: u64,
    /// Requests that had to build a fresh session.
    pub misses: u64,
}

#[derive(Debug)]
struct Entry {
    session: Session,
    last_used: u64,
}

impl SessionShard {
    /// New shard stamping sessions from `solver`, evicting when the
    /// summed [`Session::warm_bytes`] exceed `budget_bytes`.
    pub fn new(solver: Solver, budget_bytes: usize) -> SessionShard {
        SessionShard {
            solver,
            budget_bytes,
            tick: 0,
            sessions: HashMap::new(),
            evictions: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// Borrow the client's warm session, building one on first
    /// contact (or after an eviction). The bool is the shard-hit flag
    /// reported on the wire: whether the session was already resident.
    pub fn session_for(&mut self, client: &str) -> (&mut Session, bool) {
        self.tick += 1;
        let hit = self.sessions.contains_key(client);
        if hit {
            self.hits += 1;
        } else {
            self.misses += 1;
            // Serve workers solve one request at a time; a nested
            // batch fan-out inside the shard would oversubscribe the
            // core the worker is pinned to.
            let solver = self.solver.clone().threads(1);
            self.sessions.insert(
                client.to_string(),
                Entry { session: solver.build(), last_used: 0 },
            );
        }
        let entry = self.sessions.get_mut(client).expect("session just ensured");
        entry.last_used = self.tick;
        (&mut entry.session, hit)
    }

    /// Evict least-recently-used sessions until the shard fits its
    /// byte budget again, never evicting `keep` (the client that just
    /// solved — evicting it would thrash on every request once over
    /// budget). Returns how many sessions were evicted.
    pub fn evict_to_budget(&mut self, keep: &str) -> usize {
        let mut evicted = 0;
        while self.warm_bytes() > self.budget_bytes && self.sessions.len() > 1 {
            let victim = self
                .sessions
                .iter()
                .filter(|(client, _)| client.as_str() != keep)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(client, _)| client.clone());
            match victim {
                Some(client) => {
                    self.sessions.remove(&client);
                    self.evictions += 1;
                    evicted += 1;
                }
                None => break,
            }
        }
        evicted
    }

    /// Drop a client's session outright (used after a panicked solve
    /// left its warm state suspect). Not counted as an eviction.
    pub fn discard(&mut self, client: &str) -> bool {
        self.sessions.remove(client).is_some()
    }

    /// Approximate resident bytes across every session on the shard.
    pub fn warm_bytes(&self) -> usize {
        self.sessions.values().map(|e| e.session.warm_bytes()).sum()
    }

    /// Sessions currently resident.
    pub fn resident(&self) -> usize {
        self.sessions.len()
    }

    /// Configured byte budget.
    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// Swap the byte budget (hot reload). Nothing is evicted eagerly;
    /// a shrunken budget takes effect on the next
    /// [`SessionShard::evict_to_budget`] call after a solve.
    pub fn set_budget(&mut self, bytes: usize) {
        self.budget_bytes = bytes.max(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Family, SolveRequest};
    use crate::model::SystemSpec;

    fn spec() -> SystemSpec {
        SystemSpec::builder()
            .source(0.2, 10.0)
            .source(0.4, 50.0)
            .processors(&[2.0, 3.0, 4.0])
            .job(100.0)
            .build()
            .unwrap()
    }

    fn solve_as(shard: &mut SessionShard, client: &str) -> bool {
        let req = SolveRequest::new(Family::Frontend, spec());
        let (session, hit) = shard.session_for(client);
        session.solve(&req).unwrap();
        shard.evict_to_budget(client);
        hit
    }

    #[test]
    fn generous_budget_keeps_every_tenant_warm() {
        let mut shard = SessionShard::new(Solver::new(), 64 * 1024 * 1024);
        assert!(!solve_as(&mut shard, "a"), "first contact is a miss");
        assert!(!solve_as(&mut shard, "b"));
        assert!(solve_as(&mut shard, "a"), "return visit must hit");
        assert!(solve_as(&mut shard, "b"));
        assert_eq!(shard.evictions, 0);
        assert_eq!(shard.resident(), 2);
        assert_eq!((shard.hits, shard.misses), (2, 2));
    }

    #[test]
    fn tiny_budget_evicts_lru_but_never_the_active_client() {
        let mut shard = SessionShard::new(Solver::new(), 1);
        solve_as(&mut shard, "a");
        assert_eq!(shard.resident(), 1, "active client survives even over budget");
        solve_as(&mut shard, "b");
        // b just solved, so a (the LRU entry) was evicted.
        assert_eq!(shard.resident(), 1);
        assert_eq!(shard.evictions, 1);
        assert!(!solve_as(&mut shard, "a"), "evicted client is cold again");
        assert!(shard.evictions >= 2);
    }
}
