//! Zero-dependency TCP serving tier over [`crate::api`].
//!
//! `dlt serve` turns the request/response facade into a long-running
//! multi-tenant service speaking the existing newline-delimited JSON
//! wire over persistent connections:
//!
//! - **Thread-per-core workers** ([`server`]): every worker accepts
//!   from a shared nonblocking listener, frames and parses its own
//!   connections, and solves from per-shard admission queues — its own
//!   shards from the front, everyone else's from the back (work
//!   stealing), so ragged tenants cannot idle a core.
//! - **Client-keyed warm shards** ([`shard`]): requests carry an
//!   optional top-level `"client"` id; all of a tenant's requests hash
//!   to one shard whose [`crate::api::Session`] keeps their warm-start
//!   caches hot, with LRU whole-session eviction under a byte budget.
//! - **Admission control**: bounded per-shard queues shed excess load
//!   instantly with an `overloaded` error and `retry_after_ms` hint;
//!   graceful drain on shutdown finishes every admitted job.
//! - **Streaming**: responses are flushed per item in completion
//!   order, each stamped with its per-connection `seq`, so pipelined
//!   batches stream back as they finish.
//!
//! The framing layer ([`frame`]) is fuzzed against truncated,
//! concatenated, interleaved, oversize, and non-UTF-8 input in
//! `tests/serve_framing.rs`; `benches/bench_serve.rs` closes the loop
//! with an open-loop load harness emitting `BENCH_serve.json`.

pub mod frame;
pub mod server;
pub mod shard;

pub use frame::{Frame, FrameReader};
pub use server::{ServeOptions, Server, StatsSnapshot};
pub use shard::SessionShard;
