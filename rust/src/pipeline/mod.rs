//! The unified solve pipeline every scenario family flows through:
//!
//! ```text
//! ScenarioModel::build_lp ─▶ presolve ─▶ backend ─▶ restore ─▶ Schedule
//!        (per family)      (default on)  (simplex with warm   (x, duals,
//!                                         cache / dual restart  objective)
//!                                         / seed — or PDHG)
//! ```
//!
//! Before this module existed, each scenario family in [`crate::dlt`]
//! hand-rolled its own `build_lp` / `solve` / `solve_opts` /
//! `solve_cached` quartet and none of them ran presolve. Now a family
//! is just a [`ScenarioModel`] implementation — build the LP, name the
//! variables, reconstruct the schedule — and [`solve`], [`solve_cached`]
//! and [`solve_full`] provide the shared machinery:
//!
//! - **presolve by default** ([`crate::lp::presolve`]): fixed-variable
//!   substitution plus row cleanup in front of *every* backend —
//!   including PDHG — with `x`, objective and duals mapped back
//!   through the eliminations before schedule reconstruction;
//! - **backend selection** ([`Backend`]): the sparse revised simplex
//!   (default), the dense tableau oracle, the first-order PDHG
//!   iteration ([`crate::pdhg`]), its batched block variant, or the
//!   PDHG→simplex hybrid — all selectable per solve through
//!   [`PipelineOptions::backend`], which is the single source of truth
//!   for backend and solver tuning (scenario families no longer carry
//!   their own `SimplexOptions` copies). The revised backend's
//!   basis-factorization and pricing strategies ride along in
//!   [`PipelineOptions::simplex`]
//!   ([`crate::lp::Factorization`] / [`crate::lp::Pricing`]);
//! - **warm restarts** ([`crate::lp::WarmCache`]): the cache keys the
//!   last optimal basis by reduced-LP shape; an rhs-perturbed basis
//!   that went primal-infeasible is repaired by the revised backend's
//!   dual simplex instead of a cold phase-1 restart;
//! - **cross-shape seeding** ([`project::project_basis`]): when the
//!   cache has nothing for a shape, a basis from a *neighbouring* shape
//!   (e.g. the `m`-processor instance of a processor-count sweep) is
//!   projected onto the new LP by variable name and row label and used
//!   as the fallback seed. First-order backends have the primal
//!   analogue: cached optimal points seed the PDHG iterates, projected
//!   across shapes by variable name ([`project::project_point`]), and
//!   [`Backend::Hybrid`] crosses a converged-enough PDHG point over to
//!   a basis guess ([`project::crossover_basis`]) for an exact warm
//!   simplex finish.
//!
//! The service facade over this pipeline — typed requests/responses,
//! sessions, batch solving — is [`crate::api`].

pub mod project;

use crate::dlt::Schedule;
use crate::error::Result;
use crate::lp::presolve::{presolve, PresolveStats};
use crate::lp::{
    Basis, LpProblem, LpSolution, SimplexOptions, SolverBackend, SolverScratch, WarmCache,
};
use crate::model::SystemSpec;
use crate::pdhg::PdhgOptions;

/// One scenario family: how to turn a [`SystemSpec`] into an LP and an
/// LP solution back into a timed [`Schedule`].
///
/// Implemented by [`crate::dlt::frontend::FeOptions`] (§3.1),
/// [`crate::dlt::no_frontend::NfeOptions`] (§3.2),
/// [`crate::dlt::concurrent::ConcurrentOptions`] (§8 fluid models) and
/// [`crate::dlt::multi_job::MultiJobStepModel`] (§8 FIFO pipeline
/// steps) — the model value *is* the family's option set. Solver
/// tuning lives in [`PipelineOptions`], not in the family.
pub trait ScenarioModel {
    /// Short family name (diagnostics, sweep labels, seed keys).
    fn name(&self) -> &'static str;

    /// Build the family's LP for a validated, sorted spec. Variables
    /// must be named and constraints labeled: the pipeline's
    /// cross-shape projection matches bases between LPs by those
    /// strings.
    fn build_lp(&self, spec: &SystemSpec) -> LpProblem;

    /// Reconstruct the timed schedule from an LP solution (full-length
    /// `x`, fixed variables already restored by the pipeline).
    fn schedule(&self, spec: &SystemSpec, sol: &LpSolution) -> Result<Schedule>;
}

/// Which solver runs the (presolved) LP. The single backend switch for
/// the whole stack — [`crate::api`] exposes it on the wire, the CLI
/// maps `--solver` onto it, and [`PipelineOptions`] carries it into
/// every solve.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Backend {
    /// Dense two-phase tableau ([`crate::lp::simplex`]) — the fallback
    /// / cross-check oracle.
    DenseTableau,
    /// Sparse revised simplex with LU basis, warm starts and
    /// dual-simplex restarts ([`crate::lp::revised`]). The default.
    #[default]
    RevisedSimplex,
    /// First-order primal-dual hybrid gradient iteration
    /// ([`crate::pdhg`], sparse in-process kernels). Runs behind
    /// presolve like the simplex backends; warm-starts from a cached
    /// (or cross-shape projected) primal point when a
    /// [`WarmCache`] is supplied.
    Pdhg,
    /// Batched block PDHG ([`crate::pdhg::block`]): a single request
    /// runs as a width-1 block; sweep engines stack whole axes into
    /// one shared iteration stream with per-column early retirement.
    PdhgBlock,
    /// PDHG → simplex hybrid: a loose, capped first-order stage
    /// localizes the active set, [`project::crossover_basis`] turns it
    /// into a basis guess, and a short warm revised-simplex cleanup
    /// certifies the exact optimum. Exact like [`Backend::RevisedSimplex`],
    /// with first-order warm paths on sweeps.
    Hybrid,
}

impl Backend {
    /// Stable wire name (`dense_tableau` / `revised_simplex` / `pdhg`
    /// / `pdhg_block` / `hybrid`).
    pub fn as_str(self) -> &'static str {
        match self {
            Backend::DenseTableau => "dense_tableau",
            Backend::RevisedSimplex => "revised_simplex",
            Backend::Pdhg => "pdhg",
            Backend::PdhgBlock => "pdhg_block",
            Backend::Hybrid => "hybrid",
        }
    }

    /// Parse a wire name; `None` for anything unknown.
    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "dense_tableau" => Some(Backend::DenseTableau),
            "revised_simplex" => Some(Backend::RevisedSimplex),
            "pdhg" => Some(Backend::Pdhg),
            "pdhg_block" => Some(Backend::PdhgBlock),
            "hybrid" => Some(Backend::Hybrid),
            _ => None,
        }
    }

    /// True for the backends that run the first-order PDHG iteration
    /// (alone, batched, or as the hybrid's first stage).
    pub fn is_first_order(self) -> bool {
        matches!(self, Backend::Pdhg | Backend::PdhgBlock | Backend::Hybrid)
    }
}

/// Pipeline tuning knobs: the single home for backend choice and
/// solver options (the per-family `simplex` fields this struct
/// replaced are gone).
#[derive(Debug, Clone)]
pub struct PipelineOptions {
    /// Run [`crate::lp::presolve`] in front of the backend (default
    /// true). Disable to measure raw-solve baselines or to debug a
    /// presolve reduction.
    pub presolve: bool,
    /// Which backend solves the (reduced) LP.
    pub backend: Backend,
    /// Simplex tuning for the two simplex backends. Its own `backend`
    /// field is overridden by [`PipelineOptions::backend`].
    pub simplex: SimplexOptions,
    /// PDHG tuning for [`Backend::Pdhg`].
    pub pdhg: PdhgOptions,
    /// Wall-clock deadline for the whole solve, in milliseconds
    /// (`None` = unbounded). A [`crate::lp::SolveBudget`] is started
    /// when the solve enters the pipeline and stamped into the simplex
    /// and PDHG option budgets, so a hybrid solve's stages share one
    /// deadline. Expiry surfaces as
    /// [`crate::error::Error::DeadlineExceeded`].
    pub timeout_ms: Option<u64>,
}

impl Default for PipelineOptions {
    fn default() -> Self {
        PipelineOptions {
            presolve: true,
            backend: Backend::default(),
            simplex: SimplexOptions::default(),
            pdhg: PdhgOptions::default(),
            timeout_ms: None,
        }
    }
}

/// What a first-order backend did during one pipeline solve (absent
/// on pure simplex solves).
#[derive(Debug, Clone)]
pub struct PdhgDiagnostics {
    /// Fixed-step blocks executed (each [`crate::pdhg::BLOCK_STEPS`]
    /// iterations).
    pub blocks: usize,
    /// Whether the residual/gap tolerances were met — always true for
    /// [`Backend::Hybrid`], whose simplex finish certifies optimality
    /// regardless of how far the first-order stage got.
    pub converged: bool,
    /// Final `(primal, dual, gap)` residuals of the first-order stage.
    pub residuals: (f64, f64, f64),
    /// Simplex pivots spent finishing the solve after crossover
    /// (phase 1 + primal + dual); 0 outside [`Backend::Hybrid`].
    pub crossover_pivots: usize,
    /// Columns that converged and retired early from a block solve;
    /// 0 outside [`Backend::PdhgBlock`].
    pub columns_retired: usize,
    /// Number of scenario columns stacked in the block (1 for the
    /// unbatched backends).
    pub block_width: usize,
}

/// Everything a pipeline solve produced, for callers that need more
/// than the schedule (sweep engines seed the next shape from
/// `solution.basis` + `reduced`; tests inspect iteration counts and
/// restored duals; [`crate::api`] turns this into a `SolveResponse`).
#[derive(Debug, Clone)]
pub struct Solved {
    /// The reconstructed schedule.
    pub schedule: Schedule,
    /// The LP solution mapped back onto the *original* LP (full `x`,
    /// duals per original constraint). `solution.basis` refers to
    /// `reduced` — pair them when seeding another solve.
    pub solution: LpSolution,
    /// What presolve removed (default/empty when presolve was off).
    pub stats: PresolveStats,
    /// The LP the backend actually solved (post-presolve).
    pub reduced: LpProblem,
    /// Which backend produced `solution`.
    pub backend: Backend,
    /// First-order convergence details when
    /// [`Backend::is_first_order`] holds for `backend`.
    pub pdhg: Option<PdhgDiagnostics>,
}

/// Solve one scenario with default pipeline options (presolve on,
/// revised simplex, no warm state).
pub fn solve<S: ScenarioModel + ?Sized>(model: &S, spec: &SystemSpec) -> Result<Schedule> {
    Ok(solve_full(model, spec, &PipelineOptions::default(), None, None)?.schedule)
}

/// Solve through a [`WarmCache`]: repeated solves of structurally
/// identical instances (job-size sweeps, perturbed specs, advisor
/// queries) start from the previous optimal basis instead of from
/// scratch. One cache per solver thread is the intended usage; see
/// [`crate::api::Session`] for the facade that owns one.
pub fn solve_cached<S: ScenarioModel + ?Sized>(
    model: &S,
    spec: &SystemSpec,
    cache: &mut WarmCache,
) -> Result<Schedule> {
    Ok(solve_full(model, spec, &PipelineOptions::default(), Some(cache), None)?.schedule)
}

/// Full-control pipeline entry: explicit options, optional warm cache,
/// and an optional cross-shape seed `(reduced LP of the solved
/// neighbour, its optimal basis)` used when the cache misses. The
/// simplex backends warm-start from cached bases (and the projected
/// seed); the first-order backends warm-start from cached primal
/// points — same or projected shape — and store their solution point
/// back. All backends run behind presolve.
pub fn solve_full<S: ScenarioModel + ?Sized>(
    model: &S,
    spec: &SystemSpec,
    opts: &PipelineOptions,
    cache: Option<&mut WarmCache>,
    seed: Option<(&LpProblem, &Basis)>,
) -> Result<Solved> {
    let mut scratch = SolverScratch::new();
    solve_full_scratch(model, spec, opts, cache, seed, &mut scratch)
}

/// [`solve_full`] with an explicit per-worker [`SolverScratch`] pool:
/// the simplex backends' work buffers, factorization and pricing
/// objects are borrowed from (and returned to) `scratch`, so repeated
/// warm solves — the batch/sweep steady state — perform no solver-core
/// heap allocation. [`crate::api::Session`] owns one scratch next to
/// its [`WarmCache`] and routes every request through here.
pub fn solve_full_scratch<S: ScenarioModel + ?Sized>(
    model: &S,
    spec: &SystemSpec,
    opts: &PipelineOptions,
    cache: Option<&mut WarmCache>,
    seed: Option<(&LpProblem, &Basis)>,
    scratch: &mut SolverScratch,
) -> Result<Solved> {
    spec.validate()?;
    // One budget for the whole solve: presolve, every backend stage
    // (both halves of a hybrid), and the recovery ladder share it.
    let budget = crate::lp::SolveBudget::from_timeout_ms(opts.timeout_ms);
    let lp = model.build_lp(spec);

    let pre = if opts.presolve { Some(presolve(&lp)?) } else { None };
    let target: &LpProblem = pre.as_ref().map(|pr| &pr.problem).unwrap_or(&lp);

    let (sol, pdhg) = match opts.backend {
        Backend::Pdhg | Backend::PdhgBlock | Backend::Hybrid => {
            solve_first_order(target, opts, budget, cache, seed, scratch)?
        }
        simplex_backend => {
            let mut sopts = opts.simplex.clone();
            sopts.budget = budget;
            sopts.backend = match simplex_backend {
                Backend::DenseTableau => SolverBackend::DenseTableau,
                _ => SolverBackend::RevisedSparse,
            };
            // The projection seed is only a *fallback* for cache
            // misses; don't pay for it when the cache will hit anyway.
            let cache_hits = match &cache {
                Some(c) => c.has_shape(target.num_vars(), target.num_constraints()),
                None => false,
            };
            let seed_basis: Option<Basis> = if cache_hits {
                None
            } else {
                seed.and_then(|(from_lp, basis)| project::project_basis(from_lp, target, basis))
            };
            let sol = match cache {
                Some(c) => {
                    c.solve_seeded_scratch(target, &sopts, seed_basis.as_ref(), scratch)?
                }
                None => {
                    crate::lp::solve_warm_scratch(target, &sopts, seed_basis.as_ref(), scratch)?
                }
            };
            (sol, None)
        }
    };

    let (solution, stats) = match &pre {
        Some(pr) => (pr.restore(&lp, &sol), pr.stats.clone()),
        None => (sol, PresolveStats::default()),
    };
    let schedule = model.schedule(spec, &solution)?;
    let reduced = match pre {
        Some(pr) => pr.problem,
        None => lp,
    };
    Ok(Solved { schedule, solution, stats, reduced, backend: opts.backend, pdhg })
}

/// Wrap a PDHG solution in the common [`LpSolution`] shape. Simplex
/// counters are zero by construction; `iterations` reports the total
/// first-order iteration count (`blocks × BLOCK_STEPS`), the unit the
/// wire diagnostics use consistently for PDHG cells.
fn pdhg_lp_solution(ps: crate::pdhg::PdhgSolution, opts: &PipelineOptions) -> LpSolution {
    LpSolution {
        x: ps.x,
        objective: ps.objective,
        iterations: ps.blocks * crate::pdhg::BLOCK_STEPS,
        phase1_iterations: 0,
        dual_iterations: 0,
        factorization: opts.simplex.factorization,
        pricing: opts.simplex.pricing,
        refactorizations: 0,
        peak_update_len: 0,
        weight_resets: 0,
        candidate_hits: 0,
        candidate_refreshes: 0,
        avg_ftran_nnz: 0.0,
        avg_btran_nnz: 0.0,
        dfs_solves: 0,
        scan_solves: 0,
        recovery_events: Vec::new(),
        duals: None,
        basis: None,
    }
}

/// Non-converged first-order result with the deadline gone: a typed
/// [`crate::error::Error::DeadlineExceeded`] — a normal block-cap
/// non-convergence (no deadline, or deadline not yet hit) still flows
/// through as a diagnosed solution like before.
fn first_order_deadline_guard(
    ps: &crate::pdhg::PdhgSolution,
    budget: crate::lp::SolveBudget,
) -> Result<()> {
    if !ps.converged && budget.expired() {
        return Err(crate::error::Error::DeadlineExceeded {
            elapsed_ms: budget.elapsed_ms(),
            iterations: ps.blocks * crate::pdhg::BLOCK_STEPS,
            phase: "pdhg".into(),
        });
    }
    Ok(())
}

/// Dispatch for the three first-order backends: warm-point lookup
/// (same shape, else any cached point projected by variable name),
/// the solve itself, point write-back, and diagnostics.
fn solve_first_order(
    target: &LpProblem,
    opts: &PipelineOptions,
    budget: crate::lp::SolveBudget,
    cache: Option<&mut WarmCache>,
    seed: Option<(&LpProblem, &Basis)>,
    scratch: &mut SolverScratch,
) -> Result<(LpSolution, Option<PdhgDiagnostics>)> {
    let key = (target.num_vars(), target.num_constraints());
    let warm_x: Option<Vec<f64>> = cache.as_ref().and_then(|c| match c.point(key.0, key.1) {
        Some((_, x)) => Some(x.to_vec()),
        None => c.points().find_map(|(p, x)| project::project_point(p, target, x)),
    });
    let mut popts = opts.pdhg.clone();
    popts.budget = budget;

    match opts.backend {
        Backend::PdhgBlock => {
            let blk = crate::pdhg::solve_block(std::slice::from_ref(target), &popts)?;
            let ps = blk.columns.into_iter().next().expect("width-1 block has one column");
            first_order_deadline_guard(&ps, budget)?;
            if let Some(c) = cache {
                c.store_point(target, &ps.x);
            }
            let diag = PdhgDiagnostics {
                blocks: ps.blocks,
                converged: ps.converged,
                residuals: ps.residuals,
                crossover_pivots: 0,
                columns_retired: blk.columns_retired,
                block_width: blk.block_width,
            };
            Ok((pdhg_lp_solution(ps, opts), Some(diag)))
        }
        Backend::Hybrid => {
            // Stage 1: loose, capped PDHG to localize the active set.
            // Accuracy is the simplex finish's job. An expired deadline
            // is left to the simplex stage's own budget check — the
            // stages share `budget`.
            let stage = crate::pdhg::PdhgOptions {
                tol: popts.tol.max(1e-4),
                gap_tol: popts.gap_tol.max(1e-5),
                max_blocks: popts.max_blocks.min(100),
                ..popts.clone()
            };
            let ps = crate::pdhg::solve_rust_scratch(target, &stage, warm_x.as_deref(), scratch)?;
            // Stage 2: crossover to a basis guess, exact warm-simplex
            // finish (an unusable guess falls back inside solve_warm).
            let guess = project::crossover_basis(target, &ps.x, 1e-6);
            let mut sopts = opts.simplex.clone();
            sopts.budget = budget;
            sopts.backend = SolverBackend::RevisedSparse;
            let sol = match cache {
                Some(c) => {
                    let seed_basis: Option<Basis> = guess.or_else(|| {
                        seed.and_then(|(f, b)| project::project_basis(f, target, b))
                    });
                    let out =
                        c.solve_seeded_scratch(target, &sopts, seed_basis.as_ref(), scratch)?;
                    c.store_point(target, &out.x);
                    out
                }
                None => crate::lp::solve_warm_scratch(target, &sopts, guess.as_ref(), scratch)?,
            };
            let crossover_pivots = sol.iterations + sol.phase1_iterations + sol.dual_iterations;
            let diag = PdhgDiagnostics {
                blocks: ps.blocks,
                converged: true,
                residuals: ps.residuals,
                crossover_pivots,
                columns_retired: 0,
                block_width: 1,
            };
            Ok((sol, Some(diag)))
        }
        _ => {
            let ps =
                crate::pdhg::solve_rust_scratch(target, &popts, warm_x.as_deref(), scratch)?;
            first_order_deadline_guard(&ps, budget)?;
            if let Some(c) = cache {
                c.store_point(target, &ps.x);
            }
            let diag = PdhgDiagnostics {
                blocks: ps.blocks,
                converged: ps.converged,
                residuals: ps.residuals,
                crossover_pivots: 0,
                columns_retired: 0,
                block_width: 1,
            };
            Ok((pdhg_lp_solution(ps, opts), Some(diag)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dlt::frontend::FeOptions;
    use crate::dlt::no_frontend::NfeOptions;
    use crate::model::SystemSpec;

    fn table1() -> SystemSpec {
        SystemSpec::builder()
            .source(0.2, 10.0)
            .source(0.4, 50.0)
            .processors(&[2.0, 3.0, 4.0, 5.0, 6.0])
            .job(100.0)
            .build()
            .unwrap()
    }

    #[test]
    fn pipeline_matches_raw_solve_fe() {
        let spec = table1();
        let with = solve_full(&FeOptions::default(), &spec, &PipelineOptions::default(), None, None)
            .unwrap();
        let without = solve_full(
            &FeOptions::default(),
            &spec,
            &PipelineOptions { presolve: false, ..PipelineOptions::default() },
            None,
            None,
        )
        .unwrap();
        let a = with.schedule.makespan;
        let b = without.schedule.makespan;
        assert!((a - b).abs() < 1e-9 * (1.0 + b.abs()), "{a} vs {b}");
    }

    #[test]
    fn presolve_fires_on_nfe_lps() {
        // Eq. 10 (`TS[0][0] = R_1`) is a singleton equality, so the NFE
        // family always gives presolve a variable to substitute.
        let spec = SystemSpec::builder()
            .source(0.2, 0.0)
            .source(0.2, 5.0)
            .processors(&[2.0, 3.0, 4.0])
            .job(100.0)
            .build()
            .unwrap();
        let solved =
            solve_full(&NfeOptions::default(), &spec, &PipelineOptions::default(), None, None)
                .unwrap();
        assert!(solved.stats.fixed_vars >= 1, "stats: {:?}", solved.stats);
        // The fixed TS[0][0] = R_1 = 0 must be restored into x.
        assert!((solved.schedule.comm_start[0] - 0.0).abs() < 1e-12);
    }

    #[test]
    fn cached_pipeline_solves_agree_with_uncached() {
        let spec = table1();
        let mut cache = WarmCache::new();
        for k in 0..6 {
            let sub = spec.with_job(100.0 + 25.0 * k as f64);
            let cached = solve_cached(&FeOptions::default(), &sub, &mut cache).unwrap();
            let plain = solve(&FeOptions::default(), &sub).unwrap();
            assert!(
                (cached.makespan - plain.makespan).abs() < 1e-7 * (1.0 + plain.makespan),
                "J step {k}: {} vs {}",
                cached.makespan,
                plain.makespan
            );
        }
        assert!(cache.warm_attempts >= 1);
    }

    #[test]
    fn pdhg_backend_runs_behind_presolve() {
        // NFE always has a presolve fix (TS[0][0] = R_1); the PDHG
        // backend must see the reduced problem and report the stats.
        let spec = SystemSpec::builder()
            .source(0.2, 0.0)
            .source(0.2, 5.0)
            .processors(&[2.0, 3.0])
            .job(100.0)
            .build()
            .unwrap();
        let opts = PipelineOptions {
            backend: Backend::Pdhg,
            pdhg: PdhgOptions { max_blocks: 20_000, ..PdhgOptions::default() },
            ..PipelineOptions::default()
        };
        let solved =
            solve_full(&NfeOptions::default(), &spec, &opts, None, None).unwrap();
        assert!(solved.stats.fixed_vars >= 1, "presolve did not fire: {:?}", solved.stats);
        let diag = solved.pdhg.as_ref().expect("pdhg diagnostics present");
        assert!(diag.blocks > 0);
        let exact = solve(&NfeOptions::default(), &spec).unwrap();
        let rel = (solved.schedule.makespan - exact.makespan).abs()
            / exact.makespan.abs().max(1.0);
        assert!(
            rel < 1e-3,
            "pdhg {} vs simplex {} (rel {rel:.2e}, converged={})",
            solved.schedule.makespan,
            exact.makespan,
            diag.converged
        );
    }

    #[test]
    fn timeout_on_first_order_backend_returns_deadline_exceeded() {
        // Zero budget: the PDHG loop cannot run a single block, the
        // zero start is infeasible, and the pipeline must surface the
        // typed deadline error rather than an unconverged answer.
        let spec = table1();
        let opts = PipelineOptions {
            backend: Backend::Pdhg,
            timeout_ms: Some(0),
            ..PipelineOptions::default()
        };
        match solve_full(&FeOptions::default(), &spec, &opts, None, None) {
            Err(crate::error::Error::DeadlineExceeded { phase, .. }) => {
                assert_eq!(phase, "pdhg");
            }
            other => panic!("expected deadline exceeded, got {other:?}"),
        }
    }

    #[test]
    fn generous_timeout_changes_nothing() {
        let spec = table1();
        let plain =
            solve_full(&FeOptions::default(), &spec, &PipelineOptions::default(), None, None)
                .unwrap();
        let budgeted = solve_full(
            &FeOptions::default(),
            &spec,
            &PipelineOptions { timeout_ms: Some(60_000), ..PipelineOptions::default() },
            None,
            None,
        )
        .unwrap();
        assert!((plain.schedule.makespan - budgeted.schedule.makespan).abs() < 1e-12);
        assert!(budgeted.solution.recovery_events.is_empty());
    }

    #[test]
    fn backend_wire_names_roundtrip() {
        for b in [
            Backend::DenseTableau,
            Backend::RevisedSimplex,
            Backend::Pdhg,
            Backend::PdhgBlock,
            Backend::Hybrid,
        ] {
            assert_eq!(Backend::parse(b.as_str()), Some(b));
        }
        assert_eq!(Backend::parse("simplex"), None);
        assert!(Backend::Hybrid.is_first_order());
        assert!(!Backend::RevisedSimplex.is_first_order());
    }

    #[test]
    fn hybrid_backend_is_exact_and_caches_points() {
        let spec = table1();
        let exact = solve(&FeOptions::default(), &spec).unwrap();
        let opts = PipelineOptions { backend: Backend::Hybrid, ..PipelineOptions::default() };
        let mut cache = WarmCache::new();
        let solved =
            solve_full(&FeOptions::default(), &spec, &opts, Some(&mut cache), None).unwrap();
        // The simplex finish certifies the exact optimum — not just a
        // first-order tolerance.
        let rel = (solved.schedule.makespan - exact.makespan).abs() / exact.makespan.abs();
        assert!(rel < 1e-9, "hybrid {} vs exact {}", solved.schedule.makespan, exact.makespan);
        let diag = solved.pdhg.as_ref().expect("hybrid reports first-order diagnostics");
        assert!(diag.converged, "hybrid diagnostics always converge");
        assert_eq!(diag.block_width, 1);
        assert!(cache.points().count() >= 1, "hybrid stores its warm point");
        // A second solve through the same cache warm-starts from the
        // stored point and basis and stays exact.
        let again =
            solve_full(&FeOptions::default(), &spec, &opts, Some(&mut cache), None).unwrap();
        let rel = (again.schedule.makespan - exact.makespan).abs() / exact.makespan.abs();
        assert!(rel < 1e-9, "warm hybrid {} vs exact {}", again.schedule.makespan, exact.makespan);
    }

    #[test]
    fn pdhg_block_backend_matches_pdhg() {
        let spec = SystemSpec::builder()
            .source(0.2, 0.0)
            .source(0.2, 5.0)
            .processors(&[2.0, 3.0])
            .job(100.0)
            .build()
            .unwrap();
        let popts = PdhgOptions { max_blocks: 20_000, ..PdhgOptions::default() };
        let plain = PipelineOptions {
            backend: Backend::Pdhg,
            pdhg: popts.clone(),
            ..PipelineOptions::default()
        };
        let block = PipelineOptions {
            backend: Backend::PdhgBlock,
            pdhg: popts,
            ..PipelineOptions::default()
        };
        let a = solve_full(&NfeOptions::default(), &spec, &plain, None, None).unwrap();
        let b = solve_full(&NfeOptions::default(), &spec, &block, None, None).unwrap();
        assert!((a.schedule.makespan - b.schedule.makespan).abs() < 1e-8);
        let diag = b.pdhg.as_ref().expect("block diagnostics present");
        assert_eq!(diag.block_width, 1, "a single request runs as a width-1 block");
    }
}
