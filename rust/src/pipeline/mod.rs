//! The unified solve pipeline every scenario family flows through:
//!
//! ```text
//! ScenarioModel::build_lp ─▶ presolve ─▶ simplex backend ─▶ restore ─▶ Schedule
//!        (per family)      (default on)  (warm cache / dual   (x, duals,
//!                                         restart / seed)      objective)
//! ```
//!
//! Before this module existed, each scenario family in [`crate::dlt`]
//! hand-rolled its own `build_lp` / `solve` / `solve_opts` /
//! `solve_cached` quartet and none of them ran presolve. Now a family
//! is just a [`ScenarioModel`] implementation — build the LP, name the
//! variables, reconstruct the schedule — and [`solve`], [`solve_cached`]
//! and [`solve_full`] provide the shared machinery:
//!
//! - **presolve by default** ([`crate::lp::presolve`]): fixed-variable
//!   substitution plus row cleanup in front of *both* simplex backends,
//!   with `x`, objective and duals mapped back through the eliminations
//!   before schedule reconstruction;
//! - **warm restarts** ([`crate::lp::WarmCache`]): the cache keys the
//!   last optimal basis by reduced-LP shape; an rhs-perturbed basis
//!   that went primal-infeasible is repaired by the revised backend's
//!   dual simplex instead of a cold phase-1 restart;
//! - **cross-shape seeding** ([`project::project_basis`]): when the
//!   cache has nothing for a shape, a basis from a *neighbouring* shape
//!   (e.g. the `m`-processor instance of a processor-count sweep) is
//!   projected onto the new LP by variable name and row label and used
//!   as the fallback seed.

pub mod project;

use crate::dlt::Schedule;
use crate::error::Result;
use crate::lp::presolve::{presolve, PresolveStats};
use crate::lp::{Basis, LpProblem, LpSolution, SimplexOptions, WarmCache};
use crate::model::SystemSpec;

/// One scenario family: how to turn a [`SystemSpec`] into an LP and an
/// LP solution back into a timed [`Schedule`].
///
/// Implemented by [`crate::dlt::frontend::FeOptions`] (§3.1),
/// [`crate::dlt::no_frontend::NfeOptions`] (§3.2),
/// [`crate::dlt::concurrent::ConcurrentOptions`] (§8 fluid models) and
/// [`crate::dlt::multi_job::MultiJobStepModel`] (§8 FIFO pipeline
/// steps) — the model value *is* the family's option set.
pub trait ScenarioModel {
    /// Short family name (diagnostics, sweep labels).
    fn name(&self) -> &'static str;

    /// Build the family's LP for a validated, sorted spec. Variables
    /// must be named and constraints labeled: the pipeline's
    /// cross-shape projection matches bases between LPs by those
    /// strings.
    fn build_lp(&self, spec: &SystemSpec) -> LpProblem;

    /// Simplex options for this model.
    fn simplex(&self) -> SimplexOptions {
        SimplexOptions::default()
    }

    /// Reconstruct the timed schedule from an LP solution (full-length
    /// `x`, fixed variables already restored by the pipeline).
    fn schedule(&self, spec: &SystemSpec, sol: &LpSolution) -> Result<Schedule>;
}

/// Pipeline tuning knobs.
#[derive(Debug, Clone)]
pub struct PipelineOptions {
    /// Run [`crate::lp::presolve`] in front of the backend (default
    /// true). Disable to measure raw-solve baselines or to debug a
    /// presolve reduction.
    pub presolve: bool,
}

impl Default for PipelineOptions {
    fn default() -> Self {
        PipelineOptions { presolve: true }
    }
}

/// Everything a pipeline solve produced, for callers that need more
/// than the schedule (sweep engines seed the next shape from
/// `solution.basis` + `reduced`; tests inspect iteration counts and
/// restored duals).
#[derive(Debug, Clone)]
pub struct Solved {
    /// The reconstructed schedule.
    pub schedule: Schedule,
    /// The LP solution mapped back onto the *original* LP (full `x`,
    /// duals per original constraint). `solution.basis` refers to
    /// `reduced` — pair them when seeding another solve.
    pub solution: LpSolution,
    /// What presolve removed (default/empty when presolve was off).
    pub stats: PresolveStats,
    /// The LP the backend actually solved (post-presolve).
    pub reduced: LpProblem,
}

/// Solve one scenario with default pipeline options (presolve on, no
/// warm state).
pub fn solve<S: ScenarioModel + ?Sized>(model: &S, spec: &SystemSpec) -> Result<Schedule> {
    Ok(solve_full(model, spec, &PipelineOptions::default(), None, None)?.schedule)
}

/// Solve through a [`WarmCache`]: repeated solves of structurally
/// identical instances (job-size sweeps, perturbed specs, advisor
/// queries) start from the previous optimal basis instead of from
/// scratch. One cache per solver thread is the intended usage; see
/// [`crate::experiments::sweep`] for the parallel layer.
pub fn solve_cached<S: ScenarioModel + ?Sized>(
    model: &S,
    spec: &SystemSpec,
    cache: &mut WarmCache,
) -> Result<Schedule> {
    Ok(solve_full(model, spec, &PipelineOptions::default(), Some(cache), None)?.schedule)
}

/// Full-control pipeline entry: explicit options, optional warm cache,
/// and an optional cross-shape seed `(reduced LP of the solved
/// neighbour, its optimal basis)` used when the cache misses.
pub fn solve_full<S: ScenarioModel + ?Sized>(
    model: &S,
    spec: &SystemSpec,
    opts: &PipelineOptions,
    cache: Option<&mut WarmCache>,
    seed: Option<(&LpProblem, &Basis)>,
) -> Result<Solved> {
    spec.validate()?;
    let lp = model.build_lp(spec);
    let simplex = model.simplex();

    let pre = if opts.presolve { Some(presolve(&lp)?) } else { None };
    let target: &LpProblem = pre.as_ref().map(|pr| &pr.problem).unwrap_or(&lp);

    let seed_basis: Option<Basis> =
        seed.and_then(|(from_lp, basis)| project::project_basis(from_lp, target, basis));

    let sol = match cache {
        Some(c) => c.solve_seeded(target, &simplex, seed_basis.as_ref())?,
        None => crate::lp::solve_warm(target, &simplex, seed_basis.as_ref())?,
    };

    let (solution, stats) = match &pre {
        Some(pr) => (pr.restore(&lp, &sol), pr.stats.clone()),
        None => (sol, PresolveStats::default()),
    };
    let schedule = model.schedule(spec, &solution)?;
    let reduced = match pre {
        Some(pr) => pr.problem,
        None => lp,
    };
    Ok(Solved { schedule, solution, stats, reduced })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dlt::frontend::FeOptions;
    use crate::dlt::no_frontend::NfeOptions;
    use crate::model::SystemSpec;

    fn table1() -> SystemSpec {
        SystemSpec::builder()
            .source(0.2, 10.0)
            .source(0.4, 50.0)
            .processors(&[2.0, 3.0, 4.0, 5.0, 6.0])
            .job(100.0)
            .build()
            .unwrap()
    }

    #[test]
    fn pipeline_matches_raw_solve_fe() {
        let spec = table1();
        let with = solve_full(&FeOptions::default(), &spec, &PipelineOptions::default(), None, None)
            .unwrap();
        let without = solve_full(
            &FeOptions::default(),
            &spec,
            &PipelineOptions { presolve: false },
            None,
            None,
        )
        .unwrap();
        let a = with.schedule.makespan;
        let b = without.schedule.makespan;
        assert!((a - b).abs() < 1e-9 * (1.0 + b.abs()), "{a} vs {b}");
    }

    #[test]
    fn presolve_fires_on_nfe_lps() {
        // Eq. 10 (`TS[0][0] = R_1`) is a singleton equality, so the NFE
        // family always gives presolve a variable to substitute.
        let spec = SystemSpec::builder()
            .source(0.2, 0.0)
            .source(0.2, 5.0)
            .processors(&[2.0, 3.0, 4.0])
            .job(100.0)
            .build()
            .unwrap();
        let solved =
            solve_full(&NfeOptions::default(), &spec, &PipelineOptions::default(), None, None)
                .unwrap();
        assert!(solved.stats.fixed_vars >= 1, "stats: {:?}", solved.stats);
        // The fixed TS[0][0] = R_1 = 0 must be restored into x.
        assert!((solved.schedule.comm_start[0] - 0.0).abs() < 1e-12);
    }

    #[test]
    fn cached_pipeline_solves_agree_with_uncached() {
        let spec = table1();
        let mut cache = WarmCache::new();
        for k in 0..6 {
            let sub = spec.with_job(100.0 + 25.0 * k as f64);
            let cached = solve_cached(&FeOptions::default(), &sub, &mut cache).unwrap();
            let plain = solve(&FeOptions::default(), &sub).unwrap();
            assert!(
                (cached.makespan - plain.makespan).abs() < 1e-7 * (1.0 + plain.makespan),
                "J step {k}: {} vs {}",
                cached.makespan,
                plain.makespan
            );
        }
        assert!(cache.warm_attempts >= 1);
    }
}
