//! The unified solve pipeline every scenario family flows through:
//!
//! ```text
//! ScenarioModel::build_lp ─▶ presolve ─▶ backend ─▶ restore ─▶ Schedule
//!        (per family)      (default on)  (simplex with warm   (x, duals,
//!                                         cache / dual restart  objective)
//!                                         / seed — or PDHG)
//! ```
//!
//! Before this module existed, each scenario family in [`crate::dlt`]
//! hand-rolled its own `build_lp` / `solve` / `solve_opts` /
//! `solve_cached` quartet and none of them ran presolve. Now a family
//! is just a [`ScenarioModel`] implementation — build the LP, name the
//! variables, reconstruct the schedule — and [`solve`], [`solve_cached`]
//! and [`solve_full`] provide the shared machinery:
//!
//! - **presolve by default** ([`crate::lp::presolve`]): fixed-variable
//!   substitution plus row cleanup in front of *every* backend —
//!   including PDHG — with `x`, objective and duals mapped back
//!   through the eliminations before schedule reconstruction;
//! - **backend selection** ([`Backend`]): the sparse revised simplex
//!   (default), the dense tableau oracle, or the first-order PDHG
//!   iteration ([`crate::pdhg`]) — all selectable per solve through
//!   [`PipelineOptions::backend`], which is the single source of truth
//!   for backend and solver tuning (scenario families no longer carry
//!   their own `SimplexOptions` copies). The revised backend's
//!   basis-factorization and pricing strategies ride along in
//!   [`PipelineOptions::simplex`]
//!   ([`crate::lp::Factorization`] / [`crate::lp::Pricing`]);
//! - **warm restarts** ([`crate::lp::WarmCache`]): the cache keys the
//!   last optimal basis by reduced-LP shape; an rhs-perturbed basis
//!   that went primal-infeasible is repaired by the revised backend's
//!   dual simplex instead of a cold phase-1 restart;
//! - **cross-shape seeding** ([`project::project_basis`]): when the
//!   cache has nothing for a shape, a basis from a *neighbouring* shape
//!   (e.g. the `m`-processor instance of a processor-count sweep) is
//!   projected onto the new LP by variable name and row label and used
//!   as the fallback seed.
//!
//! The service facade over this pipeline — typed requests/responses,
//! sessions, batch solving — is [`crate::api`].

pub mod project;

use crate::dlt::Schedule;
use crate::error::Result;
use crate::lp::presolve::{presolve, PresolveStats};
use crate::lp::{
    Basis, LpProblem, LpSolution, SimplexOptions, SolverBackend, SolverScratch, WarmCache,
};
use crate::model::SystemSpec;
use crate::pdhg::PdhgOptions;

/// One scenario family: how to turn a [`SystemSpec`] into an LP and an
/// LP solution back into a timed [`Schedule`].
///
/// Implemented by [`crate::dlt::frontend::FeOptions`] (§3.1),
/// [`crate::dlt::no_frontend::NfeOptions`] (§3.2),
/// [`crate::dlt::concurrent::ConcurrentOptions`] (§8 fluid models) and
/// [`crate::dlt::multi_job::MultiJobStepModel`] (§8 FIFO pipeline
/// steps) — the model value *is* the family's option set. Solver
/// tuning lives in [`PipelineOptions`], not in the family.
pub trait ScenarioModel {
    /// Short family name (diagnostics, sweep labels, seed keys).
    fn name(&self) -> &'static str;

    /// Build the family's LP for a validated, sorted spec. Variables
    /// must be named and constraints labeled: the pipeline's
    /// cross-shape projection matches bases between LPs by those
    /// strings.
    fn build_lp(&self, spec: &SystemSpec) -> LpProblem;

    /// Reconstruct the timed schedule from an LP solution (full-length
    /// `x`, fixed variables already restored by the pipeline).
    fn schedule(&self, spec: &SystemSpec, sol: &LpSolution) -> Result<Schedule>;
}

/// Which solver runs the (presolved) LP. The single backend switch for
/// the whole stack — [`crate::api`] exposes it on the wire, the CLI
/// maps `--solver` onto it, and [`PipelineOptions`] carries it into
/// every solve.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Backend {
    /// Dense two-phase tableau ([`crate::lp::simplex`]) — the fallback
    /// / cross-check oracle.
    DenseTableau,
    /// Sparse revised simplex with LU basis, warm starts and
    /// dual-simplex restarts ([`crate::lp::revised`]). The default.
    #[default]
    RevisedSimplex,
    /// First-order primal-dual hybrid gradient iteration
    /// ([`crate::pdhg`], pure-rust block loop). Runs behind presolve
    /// like the simplex backends; ignores warm bases (it has none).
    Pdhg,
}

impl Backend {
    /// Stable wire name (`dense_tableau` / `revised_simplex` / `pdhg`).
    pub fn as_str(self) -> &'static str {
        match self {
            Backend::DenseTableau => "dense_tableau",
            Backend::RevisedSimplex => "revised_simplex",
            Backend::Pdhg => "pdhg",
        }
    }

    /// Parse a wire name; `None` for anything unknown.
    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "dense_tableau" => Some(Backend::DenseTableau),
            "revised_simplex" => Some(Backend::RevisedSimplex),
            "pdhg" => Some(Backend::Pdhg),
            _ => None,
        }
    }
}

/// Pipeline tuning knobs: the single home for backend choice and
/// solver options (the per-family `simplex` fields this struct
/// replaced are gone).
#[derive(Debug, Clone)]
pub struct PipelineOptions {
    /// Run [`crate::lp::presolve`] in front of the backend (default
    /// true). Disable to measure raw-solve baselines or to debug a
    /// presolve reduction.
    pub presolve: bool,
    /// Which backend solves the (reduced) LP.
    pub backend: Backend,
    /// Simplex tuning for the two simplex backends. Its own `backend`
    /// field is overridden by [`PipelineOptions::backend`].
    pub simplex: SimplexOptions,
    /// PDHG tuning for [`Backend::Pdhg`].
    pub pdhg: PdhgOptions,
}

impl Default for PipelineOptions {
    fn default() -> Self {
        PipelineOptions {
            presolve: true,
            backend: Backend::default(),
            simplex: SimplexOptions::default(),
            pdhg: PdhgOptions::default(),
        }
    }
}

/// What the PDHG backend did during one pipeline solve (absent on
/// simplex solves).
#[derive(Debug, Clone)]
pub struct PdhgDiagnostics {
    /// Fixed-step blocks executed.
    pub blocks: usize,
    /// Whether the residual/gap tolerances were met.
    pub converged: bool,
    /// Final `(primal, dual, gap)` residuals.
    pub residuals: (f64, f64, f64),
}

/// Everything a pipeline solve produced, for callers that need more
/// than the schedule (sweep engines seed the next shape from
/// `solution.basis` + `reduced`; tests inspect iteration counts and
/// restored duals; [`crate::api`] turns this into a `SolveResponse`).
#[derive(Debug, Clone)]
pub struct Solved {
    /// The reconstructed schedule.
    pub schedule: Schedule,
    /// The LP solution mapped back onto the *original* LP (full `x`,
    /// duals per original constraint). `solution.basis` refers to
    /// `reduced` — pair them when seeding another solve.
    pub solution: LpSolution,
    /// What presolve removed (default/empty when presolve was off).
    pub stats: PresolveStats,
    /// The LP the backend actually solved (post-presolve).
    pub reduced: LpProblem,
    /// Which backend produced `solution`.
    pub backend: Backend,
    /// PDHG convergence details when `backend == Backend::Pdhg`.
    pub pdhg: Option<PdhgDiagnostics>,
}

/// Solve one scenario with default pipeline options (presolve on,
/// revised simplex, no warm state).
pub fn solve<S: ScenarioModel + ?Sized>(model: &S, spec: &SystemSpec) -> Result<Schedule> {
    Ok(solve_full(model, spec, &PipelineOptions::default(), None, None)?.schedule)
}

/// Solve through a [`WarmCache`]: repeated solves of structurally
/// identical instances (job-size sweeps, perturbed specs, advisor
/// queries) start from the previous optimal basis instead of from
/// scratch. One cache per solver thread is the intended usage; see
/// [`crate::api::Session`] for the facade that owns one.
pub fn solve_cached<S: ScenarioModel + ?Sized>(
    model: &S,
    spec: &SystemSpec,
    cache: &mut WarmCache,
) -> Result<Schedule> {
    Ok(solve_full(model, spec, &PipelineOptions::default(), Some(cache), None)?.schedule)
}

/// Full-control pipeline entry: explicit options, optional warm cache,
/// and an optional cross-shape seed `(reduced LP of the solved
/// neighbour, its optimal basis)` used when the cache misses. The
/// cache and seed apply to the simplex backends; [`Backend::Pdhg`]
/// solves cold (but still behind presolve).
pub fn solve_full<S: ScenarioModel + ?Sized>(
    model: &S,
    spec: &SystemSpec,
    opts: &PipelineOptions,
    cache: Option<&mut WarmCache>,
    seed: Option<(&LpProblem, &Basis)>,
) -> Result<Solved> {
    let mut scratch = SolverScratch::new();
    solve_full_scratch(model, spec, opts, cache, seed, &mut scratch)
}

/// [`solve_full`] with an explicit per-worker [`SolverScratch`] pool:
/// the simplex backends' work buffers, factorization and pricing
/// objects are borrowed from (and returned to) `scratch`, so repeated
/// warm solves — the batch/sweep steady state — perform no solver-core
/// heap allocation. [`crate::api::Session`] owns one scratch next to
/// its [`WarmCache`] and routes every request through here.
pub fn solve_full_scratch<S: ScenarioModel + ?Sized>(
    model: &S,
    spec: &SystemSpec,
    opts: &PipelineOptions,
    cache: Option<&mut WarmCache>,
    seed: Option<(&LpProblem, &Basis)>,
    scratch: &mut SolverScratch,
) -> Result<Solved> {
    spec.validate()?;
    let lp = model.build_lp(spec);

    let pre = if opts.presolve { Some(presolve(&lp)?) } else { None };
    let target: &LpProblem = pre.as_ref().map(|pr| &pr.problem).unwrap_or(&lp);

    let (sol, pdhg) = match opts.backend {
        Backend::Pdhg => {
            let (nv, nc) =
                crate::pdhg::pad_shape(target.num_vars(), target.num_constraints());
            let ps = crate::pdhg::solve_rust(target, nv, nc, &opts.pdhg)?;
            let diag = PdhgDiagnostics {
                blocks: ps.blocks,
                converged: ps.converged,
                residuals: ps.residuals,
            };
            let sol = LpSolution {
                x: ps.x,
                objective: ps.objective,
                iterations: ps.blocks,
                phase1_iterations: 0,
                dual_iterations: 0,
                factorization: opts.simplex.factorization,
                pricing: opts.simplex.pricing,
                refactorizations: 0,
                peak_update_len: 0,
                weight_resets: 0,
                candidate_hits: 0,
                candidate_refreshes: 0,
                avg_ftran_nnz: 0.0,
                avg_btran_nnz: 0.0,
                dfs_solves: 0,
                scan_solves: 0,
                duals: None,
                basis: None,
            };
            (sol, Some(diag))
        }
        simplex_backend => {
            let mut sopts = opts.simplex.clone();
            sopts.backend = match simplex_backend {
                Backend::DenseTableau => SolverBackend::DenseTableau,
                _ => SolverBackend::RevisedSparse,
            };
            // The projection seed is only a *fallback* for cache
            // misses; don't pay for it when the cache will hit anyway.
            let cache_hits = match &cache {
                Some(c) => c.has_shape(target.num_vars(), target.num_constraints()),
                None => false,
            };
            let seed_basis: Option<Basis> = if cache_hits {
                None
            } else {
                seed.and_then(|(from_lp, basis)| project::project_basis(from_lp, target, basis))
            };
            let sol = match cache {
                Some(c) => {
                    c.solve_seeded_scratch(target, &sopts, seed_basis.as_ref(), scratch)?
                }
                None => {
                    crate::lp::solve_warm_scratch(target, &sopts, seed_basis.as_ref(), scratch)?
                }
            };
            (sol, None)
        }
    };

    let (solution, stats) = match &pre {
        Some(pr) => (pr.restore(&lp, &sol), pr.stats.clone()),
        None => (sol, PresolveStats::default()),
    };
    let schedule = model.schedule(spec, &solution)?;
    let reduced = match pre {
        Some(pr) => pr.problem,
        None => lp,
    };
    Ok(Solved { schedule, solution, stats, reduced, backend: opts.backend, pdhg })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dlt::frontend::FeOptions;
    use crate::dlt::no_frontend::NfeOptions;
    use crate::model::SystemSpec;

    fn table1() -> SystemSpec {
        SystemSpec::builder()
            .source(0.2, 10.0)
            .source(0.4, 50.0)
            .processors(&[2.0, 3.0, 4.0, 5.0, 6.0])
            .job(100.0)
            .build()
            .unwrap()
    }

    #[test]
    fn pipeline_matches_raw_solve_fe() {
        let spec = table1();
        let with = solve_full(&FeOptions::default(), &spec, &PipelineOptions::default(), None, None)
            .unwrap();
        let without = solve_full(
            &FeOptions::default(),
            &spec,
            &PipelineOptions { presolve: false, ..PipelineOptions::default() },
            None,
            None,
        )
        .unwrap();
        let a = with.schedule.makespan;
        let b = without.schedule.makespan;
        assert!((a - b).abs() < 1e-9 * (1.0 + b.abs()), "{a} vs {b}");
    }

    #[test]
    fn presolve_fires_on_nfe_lps() {
        // Eq. 10 (`TS[0][0] = R_1`) is a singleton equality, so the NFE
        // family always gives presolve a variable to substitute.
        let spec = SystemSpec::builder()
            .source(0.2, 0.0)
            .source(0.2, 5.0)
            .processors(&[2.0, 3.0, 4.0])
            .job(100.0)
            .build()
            .unwrap();
        let solved =
            solve_full(&NfeOptions::default(), &spec, &PipelineOptions::default(), None, None)
                .unwrap();
        assert!(solved.stats.fixed_vars >= 1, "stats: {:?}", solved.stats);
        // The fixed TS[0][0] = R_1 = 0 must be restored into x.
        assert!((solved.schedule.comm_start[0] - 0.0).abs() < 1e-12);
    }

    #[test]
    fn cached_pipeline_solves_agree_with_uncached() {
        let spec = table1();
        let mut cache = WarmCache::new();
        for k in 0..6 {
            let sub = spec.with_job(100.0 + 25.0 * k as f64);
            let cached = solve_cached(&FeOptions::default(), &sub, &mut cache).unwrap();
            let plain = solve(&FeOptions::default(), &sub).unwrap();
            assert!(
                (cached.makespan - plain.makespan).abs() < 1e-7 * (1.0 + plain.makespan),
                "J step {k}: {} vs {}",
                cached.makespan,
                plain.makespan
            );
        }
        assert!(cache.warm_attempts >= 1);
    }

    #[test]
    fn pdhg_backend_runs_behind_presolve() {
        // NFE always has a presolve fix (TS[0][0] = R_1); the PDHG
        // backend must see the reduced problem and report the stats.
        let spec = SystemSpec::builder()
            .source(0.2, 0.0)
            .source(0.2, 5.0)
            .processors(&[2.0, 3.0])
            .job(100.0)
            .build()
            .unwrap();
        let opts = PipelineOptions {
            backend: Backend::Pdhg,
            pdhg: PdhgOptions { max_blocks: 20_000, ..PdhgOptions::default() },
            ..PipelineOptions::default()
        };
        let solved =
            solve_full(&NfeOptions::default(), &spec, &opts, None, None).unwrap();
        assert!(solved.stats.fixed_vars >= 1, "presolve did not fire: {:?}", solved.stats);
        let diag = solved.pdhg.as_ref().expect("pdhg diagnostics present");
        assert!(diag.blocks > 0);
        let exact = solve(&NfeOptions::default(), &spec).unwrap();
        let rel = (solved.schedule.makespan - exact.makespan).abs()
            / exact.makespan.abs().max(1.0);
        assert!(
            rel < 1e-3,
            "pdhg {} vs simplex {} (rel {rel:.2e}, converged={})",
            solved.schedule.makespan,
            exact.makespan,
            diag.converged
        );
    }

    #[test]
    fn backend_wire_names_roundtrip() {
        for b in [Backend::DenseTableau, Backend::RevisedSimplex, Backend::Pdhg] {
            assert_eq!(Backend::parse(b.as_str()), Some(b));
        }
        assert_eq!(Backend::parse("simplex"), None);
    }
}
