//! Cross-shape basis projection: seed one LP's simplex basis from a
//! structurally *related* (not identical) LP that was already solved.
//!
//! The motivating case is the processor-count axis of a sweep: the
//! `m+1`-processor instance shares almost all of its variables
//! (`beta[i][j]`, `T_f`) and constraints (`release[i]`,
//! `continuity[i][j]`, `finish[j]`, `normalize`) with the
//! `m`-processor instance that was just solved, but the raw column
//! indices all shift. Matching by **variable name** and **row label**
//! instead of by index gives a model-agnostic translation:
//!
//! - a basic structural column maps through its variable name;
//! - a basic slack/surplus column maps through its row's label (aux
//!   columns are appended per non-equality row in row order in
//!   [`crate::lp::StandardForm`], in both LPs);
//! - rows that exist only in the target LP get their own aux column,
//!   so the projected basis is complete and factorizable.
//!
//! The projected basis is a *seed*, not a guarantee: it is usually
//! primal-infeasible for the new data (the new finish rows bind), which
//! is exactly what the revised backend's dual-simplex repair is for,
//! and an unusable projection just falls back to a cold start inside
//! `solve_warm`.

use crate::lp::{Basis, Cmp, LpProblem};
use std::collections::HashMap;

/// Per-row auxiliary-column rank in [`crate::lp::StandardForm`]
/// numbering: `Some(rank)` when the row gets a slack/surplus column
/// (any non-equality row — rhs sign flips swap slack and surplus but
/// never add or remove the column), `None` for equality rows.
fn aux_ranks(p: &LpProblem) -> Vec<Option<usize>> {
    let mut rank = 0usize;
    p.constraints()
        .iter()
        .map(|c| {
            if c.cmp == Cmp::Eq {
                None
            } else {
                let r = rank;
                rank += 1;
                Some(r)
            }
        })
        .collect()
}

/// Project `basis` (optimal for `from`) onto `to`'s shape. Returns
/// `None` when the two LPs cannot be matched reliably: duplicate or
/// empty row labels, duplicate variable names, a basic variable or row
/// with no counterpart, or a target row left without any usable column.
pub fn project_basis(from: &LpProblem, to: &LpProblem, basis: &Basis) -> Option<Basis> {
    if basis.cols.len() != from.num_constraints() || !basis.is_complete() {
        return None;
    }

    // Unique-name maps for the target.
    let mut var_of: HashMap<&str, usize> = HashMap::with_capacity(to.num_vars());
    for v in 0..to.num_vars() {
        let name = to.var_name(v);
        if name.is_empty() || var_of.insert(name, v).is_some() {
            return None;
        }
    }
    let mut row_of: HashMap<&str, usize> = HashMap::with_capacity(to.num_constraints());
    for (k, con) in to.constraints().iter().enumerate() {
        if con.label.is_empty() || row_of.insert(con.label.as_str(), k).is_some() {
            return None;
        }
    }
    // Source-side labels must be unique too, or the row translation is
    // ambiguous.
    {
        let mut seen: HashMap<&str, ()> = HashMap::with_capacity(from.num_constraints());
        for con in from.constraints() {
            if con.label.is_empty() || seen.insert(con.label.as_str(), ()).is_some() {
                return None;
            }
        }
    }

    let from_aux = aux_ranks(from);
    let to_aux = aux_ranks(to);
    // Aux rank -> row index, source side.
    let mut from_aux_row: Vec<usize> = Vec::new();
    for (k, r) in from_aux.iter().enumerate() {
        if r.is_some() {
            from_aux_row.push(k);
        }
    }
    let from_nv = from.num_vars();
    let to_nv = to.num_vars();

    let mut cols = vec![usize::MAX; to.num_constraints()];
    for (r_old, &col) in basis.cols.iter().enumerate() {
        // Which target row does this source row correspond to? Rows
        // that vanished (e.g. a release row presolved away in the new
        // instance) simply drop their basic column.
        let Some(&r_new) = row_of.get(from.constraints()[r_old].label.as_str()) else {
            continue;
        };
        let new_col = if col < from_nv {
            match var_of.get(from.var_name(col)) {
                Some(&v) => v,
                None => continue, // variable gone; row falls back to its aux below
            }
        } else {
            let rank = col - from_nv;
            if rank >= from_aux_row.len() {
                return None; // not a structural or aux column: corrupt basis
            }
            let src_row = from_aux_row[rank];
            let Some(&aux_row_new) = row_of.get(from.constraints()[src_row].label.as_str())
            else {
                continue;
            };
            match to_aux[aux_row_new] {
                Some(rk) => to_nv + rk,
                None => continue, // the target row became an equality
            }
        };
        cols[r_new] = new_col;
    }

    // Rows with no inherited column (new rows, or rows whose basic
    // column had no counterpart) take their own aux column.
    for (k, slot) in cols.iter_mut().enumerate() {
        if *slot == usize::MAX {
            match to_aux[k] {
                Some(rk) => *slot = to_nv + rk,
                None => return None, // a new equality row cannot self-seed
            }
        }
    }

    // A column may only be basic in one row.
    let mut used: Vec<usize> = cols.clone();
    used.sort_unstable();
    if used.windows(2).any(|w| w[0] == w[1]) {
        return None;
    }

    Some(Basis { cols })
}

/// Project an optimal primal point from `from` onto `to`'s variables
/// by **variable name**; variables that exist only in `to` start at
/// zero. This is the first-order analogue of [`project_basis`]: a PDHG
/// solve of the `m+1`-processor instance can start from the
/// `m`-processor optimum instead of the origin. Returns `None` when
/// names are empty or duplicated on either side (ambiguous match) or
/// when `x` does not match `from`'s shape.
pub fn project_point(from: &LpProblem, to: &LpProblem, x: &[f64]) -> Option<Vec<f64>> {
    if x.len() != from.num_vars() {
        return None;
    }
    let mut val: HashMap<&str, f64> = HashMap::with_capacity(from.num_vars());
    for (v, &xv) in x.iter().enumerate() {
        let name = from.var_name(v);
        if name.is_empty() || val.insert(name, xv).is_some() {
            return None;
        }
    }
    let mut out = vec![0.0; to.num_vars()];
    let mut seen: HashMap<&str, ()> = HashMap::with_capacity(to.num_vars());
    for (v, slot) in out.iter_mut().enumerate() {
        let name = to.var_name(v);
        if name.is_empty() || seen.insert(name, ()).is_some() {
            return None;
        }
        if let Some(&xv) = val.get(name) {
            *slot = xv;
        }
    }
    Some(out)
}

/// Build a simplex basis guess from an approximate primal point (the
/// PDHG → simplex **crossover**): rows with visible slack at `x` take
/// their own slack/surplus column; tight and equality rows greedily
/// pick the strongest unused structural column from their support
/// (largest `|a_rj · x_j|` with `x_j` clearly positive), falling back
/// to the row's aux column. Returns `None` only when an equality row
/// cannot be covered — the guess never needs to be feasible, because
/// the warm simplex repairs or cold-restarts it, but a good guess
/// turns the cleanup into a handful of pivots.
pub fn crossover_basis(p: &LpProblem, x: &[f64], eps: f64) -> Option<Basis> {
    if x.len() != p.num_vars() {
        return None;
    }
    let n = p.num_vars();
    let aux = aux_ranks(p);
    let mut cols = vec![usize::MAX; p.num_constraints()];
    let mut used = vec![false; n];
    // Pass 1: rows with slack keep their aux column basic.
    let mut tight: Vec<usize> = Vec::new();
    for (k, con) in p.constraints().iter().enumerate() {
        let act: f64 = con.coeffs.iter().map(|&(v, c)| c * x[v]).sum();
        let loose = (con.rhs - act).abs() > eps * (1.0 + con.rhs.abs());
        match aux[k] {
            Some(rk) if loose => cols[k] = n + rk,
            _ => tight.push(k),
        }
    }
    // Pass 2: tight/equality rows pick a structural column from their
    // support. Aux columns are per-row, so only structural picks can
    // collide; `used` keeps the basis a permutation.
    for &k in &tight {
        let con = &p.constraints()[k];
        let mut best: Option<(f64, usize)> = None;
        for &(v, c) in &con.coeffs {
            if used[v] || x[v] <= eps {
                continue;
            }
            let w = (c * x[v]).abs();
            if best.is_none_or(|(bw, _)| w > bw) {
                best = Some((w, v));
            }
        }
        match (best, aux[k]) {
            (Some((_, v)), _) => {
                used[v] = true;
                cols[k] = v;
            }
            (None, Some(rk)) => cols[k] = n + rk,
            (None, None) => return None,
        }
    }
    Some(Basis { cols })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dlt::frontend::{self, FeOptions};
    use crate::lp::{solve_warm, solve_with, SimplexOptions};
    use crate::model::SystemSpec;

    fn spec(m: usize) -> SystemSpec {
        let a: Vec<f64> = (0..m).map(|k| 2.0 + 0.5 * k as f64).collect();
        SystemSpec::builder()
            .source(0.2, 1.0)
            .source(0.4, 3.0)
            .processors(&a)
            .job(100.0)
            .build()
            .unwrap()
    }

    #[test]
    fn identity_projection_roundtrips() {
        let lp = frontend::build_lp(&spec(4), &FeOptions::default());
        let opts = SimplexOptions::default();
        let sol = solve_with(&lp, &opts).unwrap();
        let basis = sol.basis.as_ref().unwrap();
        let proj = project_basis(&lp, &lp, basis).expect("identity projection");
        assert_eq!(proj.cols, basis.cols);
        // And it warm-starts to the same optimum in few iterations.
        let warm = solve_warm(&lp, &opts, Some(&proj)).unwrap();
        assert!((warm.objective - sol.objective).abs() < 1e-7);
        assert_eq!(warm.phase1_iterations, 0);
    }

    #[test]
    fn projects_m_to_m_plus_one_and_solves() {
        let opts = SimplexOptions::default();
        let lp_m = frontend::build_lp(&spec(4), &FeOptions::default());
        let sol_m = solve_with(&lp_m, &opts).unwrap();
        let lp_m1 = frontend::build_lp(&spec(5), &FeOptions::default());
        let proj = project_basis(&lp_m, &lp_m1, sol_m.basis.as_ref().unwrap())
            .expect("m -> m+1 projection");
        assert!(proj.is_complete());
        assert_eq!(proj.cols.len(), lp_m1.num_constraints());
        // Whatever the seed's feasibility, the warm solve must land on
        // the cold optimum.
        let cold = solve_with(&lp_m1, &opts).unwrap();
        let warm = solve_warm(&lp_m1, &opts, Some(&proj)).unwrap();
        assert!(
            (warm.objective - cold.objective).abs() < 1e-7 * (1.0 + cold.objective.abs()),
            "warm {} vs cold {}",
            warm.objective,
            cold.objective
        );
    }

    #[test]
    fn project_point_maps_by_name_and_zeroes_new_vars() {
        let lp_m = frontend::build_lp(&spec(4), &FeOptions::default());
        let lp_m1 = frontend::build_lp(&spec(5), &FeOptions::default());
        let x: Vec<f64> = (0..lp_m.num_vars()).map(|v| 1.0 + v as f64).collect();
        // Identity projection is exact.
        assert_eq!(project_point(&lp_m, &lp_m, &x).unwrap(), x);
        // m -> m+1: shared names carry their value, new vars start at 0.
        let px = project_point(&lp_m, &lp_m1, &x).unwrap();
        assert_eq!(px.len(), lp_m1.num_vars());
        for v in 0..lp_m.num_vars() {
            let name = lp_m.var_name(v);
            let v1 = (0..lp_m1.num_vars()).find(|&w| lp_m1.var_name(w) == name).unwrap();
            assert_eq!(px[v1], x[v]);
        }
        // Shape mismatch refuses.
        assert!(project_point(&lp_m, &lp_m1, &x[1..]).is_none());
    }

    #[test]
    fn crossover_from_converged_pdhg_point_solves_exactly() {
        let lp = frontend::build_lp(&spec(4), &FeOptions::default());
        let opts = SimplexOptions::default();
        let cold = solve_with(&lp, &opts).unwrap();
        let pdhg = crate::pdhg::solve_rust(&lp, &Default::default()).unwrap();
        let basis = crossover_basis(&lp, &pdhg.x, 1e-6).expect("crossover basis");
        assert!(basis.is_complete());
        assert_eq!(basis.cols.len(), lp.num_constraints());
        let warm = solve_warm(&lp, &opts, Some(&basis)).unwrap();
        assert!(
            (warm.objective - cold.objective).abs() < 1e-7 * (1.0 + cold.objective.abs()),
            "crossover warm {} vs cold {}",
            warm.objective,
            cold.objective
        );
    }

    #[test]
    fn unlabeled_rows_refuse_projection() {
        let mut p = LpProblem::new(2);
        p.set_objective(&[1.0, 1.0]);
        p.add_constraint(&[(0, 1.0), (1, 1.0)], Cmp::Ge, 1.0); // no label
        let sol = solve_with(&p, &SimplexOptions::default()).unwrap();
        assert!(project_basis(&p, &p, sol.basis.as_ref().unwrap()).is_none());
    }
}
