//! Generic experiment-result table (render to text or CSV).

/// Column-labeled numeric table with provenance notes.
#[derive(Debug, Clone)]
pub struct ExpTable {
    /// Experiment id (`fig12`, ...).
    pub name: String,
    /// What the paper shows there.
    pub title: String,
    /// Column headers.
    pub columns: Vec<String>,
    /// Data rows.
    pub rows: Vec<Vec<f64>>,
    /// Free-form notes (paper anchor comparisons, advice text, ...).
    pub notes: Vec<String>,
}

impl ExpTable {
    /// New empty table.
    pub fn new(name: &str, title: &str, columns: &[&str]) -> ExpTable {
        ExpTable {
            name: name.to_string(),
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    /// Append a row (must match the column count).
    pub fn push_row(&mut self, row: Vec<f64>) {
        assert_eq!(row.len(), self.columns.len(), "row width mismatch");
        self.rows.push(row);
    }

    /// Append a note.
    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    /// Column index by header name.
    pub fn col(&self, header: &str) -> usize {
        self.columns
            .iter()
            .position(|c| c == header)
            .unwrap_or_else(|| panic!("no column `{header}` in {}", self.name))
    }

    /// Value at (row, column-name).
    pub fn at(&self, row: usize, header: &str) -> f64 {
        self.rows[row][self.col(header)]
    }

    /// Extract a whole column.
    pub fn column(&self, header: &str) -> Vec<f64> {
        let c = self.col(header);
        self.rows.iter().map(|r| r[c]).collect()
    }

    /// Render as an aligned text table.
    pub fn render_text(&self) -> String {
        let mut out = format!("## {} — {}\n", self.name, self.title);
        out.push_str(&self.columns.iter().map(|c| format!("{c:>14}")).collect::<String>());
        out.push('\n');
        for row in &self.rows {
            for v in row {
                if v.fract() == 0.0 && v.abs() < 1e9 {
                    out.push_str(&format!("{:>14}", *v as i64));
                } else {
                    out.push_str(&format!("{v:>14.4}"));
                }
            }
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("  note: {n}\n"));
        }
        out
    }

    /// Render as CSV.
    pub fn render_csv(&self) -> String {
        let mut out = self.columns.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(
                &row.iter().map(|v| format!("{v}")).collect::<Vec<_>>().join(","),
            );
            out.push('\n');
        }
        out
    }

    /// Write the CSV to `dir/<name>.csv`; returns the path.
    pub fn write_csv(&self, dir: &str) -> crate::error::Result<String> {
        std::fs::create_dir_all(dir).map_err(|e| crate::error::Error::io(dir, e))?;
        let path = format!("{dir}/{}.csv", self.name);
        std::fs::write(&path, self.render_csv())
            .map_err(|e| crate::error::Error::io(&path, e))?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> ExpTable {
        let mut t = ExpTable::new("figX", "demo", &["m", "tf"]);
        t.push_row(vec![1.0, 10.5]);
        t.push_row(vec![2.0, 8.25]);
        t.note("hello");
        t
    }

    #[test]
    fn accessors() {
        let t = t();
        assert_eq!(t.col("tf"), 1);
        assert_eq!(t.at(1, "tf"), 8.25);
        assert_eq!(t.column("m"), vec![1.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        let mut t = t();
        t.push_row(vec![1.0]);
    }

    #[test]
    fn renders() {
        let t = t();
        let txt = t.render_text();
        assert!(txt.contains("figX"));
        assert!(txt.contains("note: hello"));
        let csv = t.render_csv();
        assert!(csv.starts_with("m,tf\n"));
        assert!(csv.contains("2,8.25"));
    }

    #[test]
    fn csv_file_roundtrip() {
        let t = t();
        let path = t.write_csv("/tmp/dlt_exp_test").unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        assert!(content.contains("8.25"));
        std::fs::remove_dir_all("/tmp/dlt_exp_test").ok();
    }
}
