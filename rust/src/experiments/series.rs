//! Generators for every figure in the paper's evaluation.

use crate::cost::{advise, Advice, Budgets, TradeoffTable};
use crate::dlt::frontend::FeOptions;
use crate::dlt::no_frontend::NfeOptions;
use crate::error::Result;
use crate::experiments::params;
use crate::experiments::table::ExpTable;
use crate::lp::WarmCache;
use crate::pipeline;
use crate::speedup;

/// Fig. 10 — per-processor load split by source (Table 1, front-ends).
pub fn fig10() -> Result<ExpTable> {
    let spec = params::table1();
    let s = pipeline::solve(&FeOptions::default(), &spec)?;
    let mut t = ExpTable::new(
        "fig10",
        "load per processor from each source (Table 1, with front-ends)",
        &["processor", "from_S1", "from_S2", "total"],
    );
    for j in 0..s.m {
        t.push_row(vec![(j + 1) as f64, s.beta(0, j), s.beta(1, j), s.load_on_processor(j)]);
    }
    t.note(format!("T_f = {:.4}", s.makespan));
    t.note("paper: faster processors do more processing work");
    Ok(t)
}

/// Fig. 11 — per-processor load split by source (Table 2, no front-ends).
pub fn fig11() -> Result<ExpTable> {
    let spec = params::table2();
    let s = pipeline::solve(&NfeOptions::default(), &spec)?;
    let mut t = ExpTable::new(
        "fig11",
        "load per processor from each source (Table 2, without front-ends)",
        &["processor", "from_S1", "from_S2", "total"],
    );
    for j in 0..s.m {
        t.push_row(vec![(j + 1) as f64, s.beta(0, j), s.beta(1, j), s.load_on_processor(j)]);
    }
    t.note(format!("T_f = {:.4}", s.makespan));
    Ok(t)
}

/// Fig. 12 — minimal finish time vs processors for 1/2/3 sources
/// (Table 3, no front-ends).
pub fn fig12() -> Result<ExpTable> {
    let spec = params::table3();
    let mut t = ExpTable::new(
        "fig12",
        "T_f vs processors for 1/2/3 sources (Table 3, without front-ends)",
        &["m", "tf_1src", "tf_2src", "tf_3src"],
    );
    let mut cache = WarmCache::new();
    for m in 1..=spec.m() {
        let mut row = vec![m as f64];
        for n in 1..=3usize {
            let sub = spec.with_n_sources(n).with_m_processors(m);
            row.push(
                pipeline::solve_cached(&NfeOptions::default(), &sub, &mut cache)?.makespan,
            );
        }
        t.push_row(row);
    }
    t.note("paper: more sources and more processors both reduce T_f, with diminishing returns");
    Ok(t)
}

/// Fig. 13 — finish time vs processors for different job sizes
/// (Table 3, 3 sources, front-ends).
pub fn fig13() -> Result<ExpTable> {
    let spec = params::table3();
    let mut t = ExpTable::new(
        "fig13",
        "T_f vs processors for J = 100/300/500 (Table 3, with front-ends)",
        &["m", "tf_J100", "tf_J300", "tf_J500"],
    );
    // For each m the three job sizes share one LP shape, so the second
    // and third solves warm-start from the first one's basis.
    let mut cache = WarmCache::new();
    for m in 1..=spec.m() {
        let mut row = vec![m as f64];
        for &job in params::FIG13_JOB_SIZES {
            let sub = spec.with_job(job).with_m_processors(m);
            row.push(pipeline::solve_cached(&FeOptions::default(), &sub, &mut cache)?.makespan);
        }
        t.push_row(row);
    }
    // Paper's headline: for J=500 going from 3 to 7 processors saves
    // about 50% of the finish time.
    let tf3 = t.at(2, "tf_J500");
    let tf7 = t.at(6, "tf_J500");
    t.note(format!(
        "J=500: T_f(3 procs) = {tf3:.2}, T_f(7 procs) = {tf7:.2} -> saves {:.0}% (paper: ~50%)",
        (1.0 - tf7 / tf3) * 100.0
    ));
    Ok(t)
}

/// Fig. 14 — finish time, homogeneous nodes, 1/2/3/5/10 sources
/// (Table 4, no front-ends).
pub fn fig14() -> Result<ExpTable> {
    let spec = params::table4();
    let cols: Vec<String> = std::iter::once("m".to_string())
        .chain(params::FIG14_SOURCE_COUNTS.iter().map(|p| format!("tf_{p}src")))
        .collect();
    let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut t = ExpTable::new(
        "fig14",
        "T_f, homogeneous nodes (Table 4, without front-ends)",
        &col_refs,
    );
    let pts = speedup::sweep(&spec, params::FIG14_SOURCE_COUNTS, spec.m())?;
    for m in 1..=spec.m() {
        let mut row = vec![m as f64];
        for &p in params::FIG14_SOURCE_COUNTS {
            let pt = pts.iter().find(|x| x.sources == p && x.processors == m).unwrap();
            row.push(pt.tf);
        }
        t.push_row(row);
    }
    Ok(t)
}

/// Fig. 15 — speedup over the single-source system (from Fig. 14).
pub fn fig15() -> Result<ExpTable> {
    let spec = params::table4();
    let cols: Vec<String> = std::iter::once("m".to_string())
        .chain(params::FIG14_SOURCE_COUNTS.iter().map(|p| format!("speedup_{p}src")))
        .collect();
    let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut t = ExpTable::new("fig15", "speedup vs single source (Table 4)", &col_refs);
    let pts = speedup::sweep(&spec, params::FIG14_SOURCE_COUNTS, spec.m())?;
    for m in 1..=spec.m() {
        let mut row = vec![m as f64];
        for &p in params::FIG14_SOURCE_COUNTS {
            let pt = pts.iter().find(|x| x.sources == p && x.processors == m).unwrap();
            row.push(pt.speedup);
        }
        t.push_row(row);
    }
    // Paper anchors at 12 processors.
    let r = 11; // m = 12
    t.note(format!(
        "m=12 speedups: 2src {:.2} (paper 1.59), 3src {:.2} (1.90), 5src {:.2} (2.21), 10src {:.2} (2.49)",
        t.at(r, "speedup_2src"),
        t.at(r, "speedup_3src"),
        t.at(r, "speedup_5src"),
        t.at(r, "speedup_10src"),
    ));
    Ok(t)
}

/// Figs. 16, 17, 18 — cost, finish time and gradient vs processors
/// (Table 5, front-ends). One sweep feeds all three figures.
pub fn fig16_17_18() -> Result<(ExpTable, ExpTable, ExpTable)> {
    let spec = params::table5();
    let sweep = TradeoffTable::sweep(&spec)?;

    let mut f16 = ExpTable::new(
        "fig16",
        "total monetary cost vs processors (Table 5, with front-ends)",
        &["m", "cost"],
    );
    let mut f17 = ExpTable::new("fig17", "minimal finish time vs processors (Table 5)", &["m", "tf"]);
    let mut f18 =
        ExpTable::new("fig18", "gradient of finish time vs processors (Table 5)", &["m", "gradient_pct"]);
    for p in &sweep.points {
        f16.push_row(vec![p.m as f64, p.cost]);
        f17.push_row(vec![p.m as f64, p.tf]);
    }
    for (k, g) in sweep.gradients.iter().enumerate() {
        // gradient entering m = k+2
        f18.push_row(vec![(k + 2) as f64, g * 100.0]);
    }
    f16.note(format!(
        "cost(6) = {:.2} (paper 3433.77), cost(7) = {:.2} (paper 3451.67)",
        sweep.at(6).cost,
        sweep.at(7).cost
    ));
    f18.note(format!(
        "|gradient(5)| = {:.1}% (paper ~8.4%), |gradient(6)| = {:.1}% (paper ~5.3%)",
        sweep.gradients[3].abs() * 100.0,
        sweep.gradients[4].abs() * 100.0
    ));
    Ok((f16, f17, f18))
}

/// Budget-area table shared by Figs. 19/20 (the caller supplies the
/// sweep so each figure runs exactly one).
fn budget_table(
    name: &str,
    title: &str,
    sweep: &TradeoffTable,
    budget_cost: f64,
    budget_time: f64,
) -> Result<ExpTable> {
    let mut t = ExpTable::new(
        name,
        title,
        &["m", "cost", "tf", "within_cost", "within_time", "within_both"],
    );
    for p in &sweep.points {
        let wc = (p.cost <= budget_cost) as i64 as f64;
        let wt = (p.tf <= budget_time) as i64 as f64;
        t.push_row(vec![p.m as f64, p.cost, p.tf, wc, wt, wc * wt]);
    }
    let advice = advise(
        sweep,
        &Budgets {
            cost: Some(budget_cost),
            time: Some(budget_time),
            gradient_threshold: params::FIG19_GRADIENT_THRESHOLD,
        },
    );
    t.note(format!("Budget_cost = {budget_cost:.2}, Budget_time = {budget_time:.2}"));
    t.note(match advice {
        Advice::Use { m, tf, cost } => {
            format!("advice: use m = {m} (T_f {tf:.2}, cost {cost:.2})")
        }
        Advice::Range { lo, hi, recommended } => format!(
            "advice: any m in [{lo}, {hi}] satisfies both budgets; cheapest is m = {recommended}"
        ),
        Advice::Infeasible { min_cost_meeting_time, min_time_within_cost } => format!(
            "advice: INFEASIBLE — meeting the deadline costs >= {:.2}; staying in budget takes >= {:.2} time",
            min_cost_meeting_time.unwrap_or(f64::NAN),
            min_time_within_cost.unwrap_or(f64::NAN)
        ),
    });
    Ok(t)
}

/// Fig. 19 — both budgets, overlapping solution areas (m ∈ [6, 12]).
pub fn fig19() -> Result<ExpTable> {
    let spec = params::table5();
    let sweep = TradeoffTable::sweep(&spec)?;
    // Pin the budgets to the sweep so the overlap is exactly [6, 12],
    // matching the paper's plot.
    let (cost, tf) = (sweep.at(12).cost, sweep.at(6).tf);
    budget_table("fig19", "two solution areas, overlapped (Table 5)", &sweep, cost, tf)
}

/// Fig. 20 — both budgets, disjoint solution areas (no feasible m).
pub fn fig20() -> Result<ExpTable> {
    let spec = params::table5();
    let sweep = TradeoffTable::sweep(&spec)?;
    // Cost budget only affords m <= 4; deadline needs m >= 10.
    let (cost, tf) = (sweep.at(4).cost, sweep.at(10).tf);
    budget_table("fig20", "two solution areas, no overlap (Table 5)", &sweep, cost, tf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig10_normalizes_and_orders() {
        let t = fig10().unwrap();
        let total: f64 = t.column("total").iter().sum();
        assert!((total - 100.0).abs() < 1e-6);
        let loads = t.column("total");
        assert!(loads.windows(2).all(|w| w[0] >= w[1] - 1e-6), "faster procs do more");
    }

    #[test]
    fn fig12_monotone_in_sources_and_processors() {
        let t = fig12().unwrap();
        for r in 0..t.rows.len() {
            assert!(t.at(r, "tf_2src") <= t.at(r, "tf_1src") + 1e-6);
            assert!(t.at(r, "tf_3src") <= t.at(r, "tf_2src") + 1e-6);
        }
        let c1 = t.column("tf_1src");
        assert!(c1.windows(2).all(|w| w[1] <= w[0] + 1e-6));
    }

    #[test]
    fn fig13_larger_jobs_take_longer() {
        let t = fig13().unwrap();
        for r in 0..t.rows.len() {
            assert!(t.at(r, "tf_J100") < t.at(r, "tf_J300"));
            assert!(t.at(r, "tf_J300") < t.at(r, "tf_J500"));
        }
    }

    #[test]
    fn fig15_speedup_anchors_close_to_paper() {
        let t = fig15().unwrap();
        let r = 11; // m = 12
        // Shape-level reproduction: within 15% of the paper's values.
        for (col, paper) in [
            ("speedup_2src", 1.59),
            ("speedup_3src", 1.90),
            ("speedup_5src", 2.21),
            ("speedup_10src", 2.49),
        ] {
            let got = t.at(r, col);
            assert!(
                (got - paper).abs() / paper < 0.15,
                "{col}: got {got}, paper {paper}"
            );
        }
    }

    #[test]
    fn fig19_overlap_is_6_to_12() {
        let t = fig19().unwrap();
        let both = t.column("within_both");
        let ms: Vec<usize> = t
            .column("m")
            .iter()
            .zip(both.iter())
            .filter(|(_, &b)| b > 0.5)
            .map(|(&m, _)| m as usize)
            .collect();
        assert_eq!(ms.first(), Some(&6));
        assert_eq!(ms.last(), Some(&12));
    }

    #[test]
    fn fig20_has_no_overlap() {
        let t = fig20().unwrap();
        assert!(t.column("within_both").iter().all(|&b| b < 0.5));
        assert!(t.notes.iter().any(|n| n.contains("INFEASIBLE")));
    }
}
