//! Parallel scenario sweeps with per-thread, warm-started solver state.
//!
//! The paper's trade-off figures and the follow-up resource-sharing /
//! Amdahl analyses (arXiv:1902.01898, 1902.01952) all boil down to the
//! same shape of computation: *solve hundreds of near-identical DLT
//! LPs over a parameter grid*. This module fans such a grid across
//! `std::thread` scoped workers. Each worker owns a private
//! [`WarmCache`], and the grid is split into **contiguous chunks** so
//! neighbouring scenarios (which differ by one small parameter step)
//! warm-start from each other's optimal bases.
//!
//! Used by the `dlt sweep` CLI subcommand and the solver benches;
//! [`parallel_map`] is the reusable primitive for anything else that
//! wants "per-thread solver state over a work list".

use crate::dlt::schedule::TimingModel;
use crate::dlt::{frontend, no_frontend};
use crate::error::Result;
use crate::lp::WarmCache;
use crate::model::SystemSpec;

/// One point of a scenario grid.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Display label (e.g. `J=250`).
    pub label: String,
    /// Full system description for this point.
    pub spec: SystemSpec,
    /// Timing model to solve under.
    pub model: TimingModel,
}

/// Result for one scenario.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// Scenario label.
    pub label: String,
    /// Optimal finish time.
    pub makespan: f64,
    /// Simplex iterations the solve took (lower on warm starts).
    pub lp_iterations: usize,
}

/// Sweep execution options.
#[derive(Debug, Clone)]
pub struct SweepOptions {
    /// Worker threads. `0` = one per available core.
    pub threads: usize,
    /// Warm-start consecutive solves within each worker (disable to
    /// measure cold-solve baselines).
    pub warm_start: bool,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions { threads: 0, warm_start: true }
    }
}

/// Scenario grid over job sizes (fixed system, one LP shape — the
/// ideal warm-start family).
pub fn job_grid(spec: &SystemSpec, jobs: &[f64], model: TimingModel) -> Vec<Scenario> {
    jobs.iter()
        .map(|&j| Scenario {
            label: format!("J={j:.4}"),
            spec: spec.with_job(j),
            model,
        })
        .collect()
}

/// Scenario grid over processor counts `m = 1..=spec.m()`.
pub fn processor_grid(spec: &SystemSpec, model: TimingModel) -> Vec<Scenario> {
    (1..=spec.m())
        .map(|m| Scenario {
            label: format!("m={m}"),
            spec: spec.with_m_processors(m),
            model,
        })
        .collect()
}

/// Solve every scenario, in input order, fanning across worker threads.
pub fn run_scenarios(scenarios: &[Scenario], opts: &SweepOptions) -> Result<Vec<SweepPoint>> {
    let warm = opts.warm_start;
    let results = parallel_map(scenarios, opts.threads, move |cache, sc| {
        let sched = match (sc.model, warm) {
            (TimingModel::FrontEnd, true) => {
                frontend::solve_cached(&sc.spec, &Default::default(), cache)
            }
            (TimingModel::FrontEnd, false) => frontend::solve(&sc.spec),
            (TimingModel::NoFrontEnd, true) => {
                no_frontend::solve_cached(&sc.spec, &Default::default(), cache)
            }
            (TimingModel::NoFrontEnd, false) => no_frontend::solve(&sc.spec),
        }?;
        Ok(SweepPoint {
            label: sc.label.clone(),
            makespan: sched.makespan,
            lp_iterations: sched.lp_iterations,
        })
    });
    results.into_iter().collect()
}

/// Run `f` over `items` on scoped worker threads, each worker owning a
/// private [`WarmCache`]. Items are split into contiguous chunks (one
/// per worker) and results come back in input order. `threads == 0`
/// uses one worker per available core; the count is always capped by
/// the item count.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&mut WarmCache, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = effective_threads(threads, n);
    if threads <= 1 {
        let mut cache = WarmCache::new();
        return items.iter().map(|it| f(&mut cache, it)).collect();
    }

    let chunk = (n + threads - 1) / threads;
    let mut out: Vec<R> = Vec::with_capacity(n);
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(threads);
        for part in items.chunks(chunk) {
            let fref = &f;
            handles.push(s.spawn(move || {
                let mut cache = WarmCache::new();
                part.iter().map(|it| fref(&mut cache, it)).collect::<Vec<R>>()
            }));
        }
        for h in handles {
            out.extend(h.join().expect("sweep worker panicked"));
        }
    });
    out
}

fn effective_threads(requested: usize, items: usize) -> usize {
    let t = if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    };
    t.clamp(1, items)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table1_spec() -> SystemSpec {
        SystemSpec::builder()
            .source(0.2, 10.0)
            .source(0.4, 50.0)
            .processors(&[2.0, 3.0, 4.0, 5.0, 6.0])
            .job(100.0)
            .build()
            .unwrap()
    }

    #[test]
    fn parallel_matches_serial_on_job_grid() {
        let spec = table1_spec();
        let jobs: Vec<f64> = (0..16).map(|k| 100.0 + 10.0 * k as f64).collect();
        let grid = job_grid(&spec, &jobs, TimingModel::FrontEnd);
        let serial =
            run_scenarios(&grid, &SweepOptions { threads: 1, warm_start: true }).unwrap();
        let par = run_scenarios(&grid, &SweepOptions { threads: 4, warm_start: true }).unwrap();
        assert_eq!(serial.len(), par.len());
        for (a, b) in serial.iter().zip(par.iter()) {
            assert_eq!(a.label, b.label, "order preserved");
            assert!(
                (a.makespan - b.makespan).abs() < 1e-7 * (1.0 + a.makespan.abs()),
                "{}: {} vs {}",
                a.label,
                a.makespan,
                b.makespan
            );
        }
    }

    #[test]
    fn warm_start_agrees_with_cold() {
        let spec = table1_spec();
        let jobs: Vec<f64> = (0..12).map(|k| 80.0 + 15.0 * k as f64).collect();
        let grid = job_grid(&spec, &jobs, TimingModel::NoFrontEnd);
        let cold = run_scenarios(&grid, &SweepOptions { threads: 1, warm_start: false }).unwrap();
        let warm = run_scenarios(&grid, &SweepOptions { threads: 1, warm_start: true }).unwrap();
        let mut warm_total = 0usize;
        let mut cold_total = 0usize;
        for (a, b) in cold.iter().zip(warm.iter()) {
            assert!((a.makespan - b.makespan).abs() < 1e-7 * (1.0 + a.makespan.abs()));
            cold_total += a.lp_iterations;
            warm_total += b.lp_iterations;
        }
        assert!(
            warm_total <= cold_total,
            "warm sweeps should not iterate more: {warm_total} vs {cold_total}"
        );
    }

    #[test]
    fn processor_grid_covers_all_m() {
        let grid = processor_grid(&table1_spec(), TimingModel::FrontEnd);
        assert_eq!(grid.len(), 5);
        let pts = run_scenarios(&grid, &SweepOptions::default()).unwrap();
        // More processors never hurt.
        for w in pts.windows(2) {
            assert!(w[1].makespan <= w[0].makespan + 1e-6);
        }
    }

    #[test]
    fn parallel_map_empty_and_oversubscribed() {
        let none: Vec<u32> = Vec::new();
        let out = parallel_map(&none, 8, |_, x| *x);
        assert!(out.is_empty());
        let items = [1u32, 2, 3];
        let out = parallel_map(&items, 64, |_, x| x * 2);
        assert_eq!(out, vec![2, 4, 6]);
    }
}
