//! Multi-dimensional, parallel scenario sweeps with per-thread,
//! warm-started solver state.
//!
//! The paper's trade-off figures and the follow-up resource-sharing /
//! Amdahl analyses (arXiv:1902.01898, 1902.01952) all boil down to the
//! same shape of computation: *solve hundreds of near-identical DLT
//! LPs over a parameter grid*. This module builds such grids over four
//! axes — job size, processor count, release-time scale, link-speed
//! scale (compose them with [`cross_grid`]) — and fans them across
//! `std::thread` scoped workers, every solve flowing through the
//! unified [`crate::pipeline`].
//!
//! Two schedulers:
//!
//! - **contiguous chunks** ([`parallel_map`] / [`parallel_map_with`]):
//!   one slice per worker, ideal when all points cost about the same —
//!   neighbouring scenarios warm-start from each other;
//! - **work-stealing deques** ([`parallel_map_steal`], enabled with
//!   [`SweepOptions::steal`]): each worker drains its own deque from
//!   the front and steals from the *back* of a neighbour's when idle —
//!   the right scheduler for **ragged** grids (a processor-count axis
//!   makes LP sizes, and therefore point costs, wildly uneven). Output
//!   order stays the input order either way.
//!
//! Every worker owns a [`crate::api::Session`], so each solve
//! warm-starts from the worker's [`WarmCache`], and on a cache miss
//! (the previous point had a *different* LP shape, e.g. along the
//! processor axis) the last optimal basis is projected onto the new
//! shape by variable name and row label
//! ([`crate::pipeline::project`]) and used as the seed — a
//! primal-infeasible seed is repaired by the dual simplex instead of
//! falling back to a cold phase-1 start. The session also owns a
//! [`crate::lp::SolverScratch`] pool, so a worker's repeated warm
//! solves reuse every solver work buffer instead of reallocating per
//! grid point — steady-state sweep iterations are allocation-free in
//! the simplex core.
//!
//! Panics are contained per item: a worker that panics on one
//! scenario surfaces [`WorkerPanic`] in that item's slot (rebuilding
//! its warm state so later items don't inherit the damage) instead of
//! poisoning the whole sweep.
//!
//! Used by the `dlt sweep` CLI subcommand, [`crate::api::Session::solve_batch`],
//! and the solver benches.

use crate::api::{Family, Session, Solver, SolveRequest};
use crate::cost::advisor::knee_interval;
use crate::dlt::frontend::FeOptions;
use crate::dlt::no_frontend::NfeOptions;
use crate::dlt::schedule::TimingModel;
use crate::error::{Error, Result};
use crate::lp::{SimplexOptions, WarmCache};
use crate::model::SystemSpec;
use crate::pdhg::{solve_block, PdhgOptions, BLOCK_STEPS, DEFAULT_BLOCK_WIDTH};
use crate::pipeline::{Backend, ScenarioModel};
use std::collections::VecDeque;
use std::sync::Mutex;

/// One point of a scenario grid.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Display label (e.g. `J=250 m=4`).
    pub label: String,
    /// Full system description for this point.
    pub spec: SystemSpec,
    /// Timing model to solve under.
    pub model: TimingModel,
}

/// Result for one scenario.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// Scenario label.
    pub label: String,
    /// Optimal finish time.
    pub makespan: f64,
    /// Simplex iterations the solve took (lower on warm starts).
    pub lp_iterations: usize,
}

/// Marker for an item whose worker panicked mid-solve. The parallel
/// maps return it in the item's slot so one poisoned scenario never
/// takes down the other results.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkerPanic {
    /// Panic payload rendered to text (`&str`/`String` payloads pass
    /// through verbatim).
    pub message: String,
}

/// Per-item result of the parallel maps: the computed value, or the
/// panic that consumed this item.
pub type MapResult<R> = std::result::Result<R, WorkerPanic>;

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "sweep worker panicked".to_string()
    }
}

/// Run one item under `catch_unwind`; on a panic the worker state is
/// rebuilt via `init` so the remaining items of this worker don't
/// inherit a half-updated cache or scratch pool.
fn run_caught<T, R, S>(
    state: &mut S,
    init: &(impl Fn() -> S + Sync),
    f: &(impl Fn(&mut S, &T) -> R + Sync),
    item: &T,
) -> MapResult<R> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(state, item))) {
        Ok(r) => Ok(r),
        Err(payload) => {
            *state = init();
            Err(WorkerPanic { message: panic_message(payload.as_ref()) })
        }
    }
}

/// Sweep execution options.
#[derive(Debug, Clone)]
pub struct SweepOptions {
    /// Worker threads. `0` = one per available core.
    pub threads: usize,
    /// Warm-start consecutive solves within each worker (disable to
    /// measure cold-solve baselines).
    pub warm_start: bool,
    /// Schedule with work-stealing deques instead of contiguous chunks
    /// (better wall-clock on ragged grids; results are identical).
    pub steal: bool,
    /// Backend every per-worker session solves with.
    /// [`Backend::PdhgBlock`] short-circuits [`run_scenarios`] into
    /// [`run_block_grid`]: the whole grid batches into shared
    /// iteration streams instead of fanning across sessions.
    pub backend: Backend,
    /// Simplex tuning (factorization / pricing strategies and
    /// tolerances) for every per-worker session.
    pub simplex: SimplexOptions,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions {
            threads: 0,
            warm_start: true,
            steal: false,
            backend: Backend::default(),
            simplex: SimplexOptions::default(),
        }
    }
}

/// One grid dimension for [`cross_grid`].
#[derive(Debug, Clone)]
pub enum Axis {
    /// Job sizes `J` ([`SystemSpec::with_job`]).
    Jobs(Vec<f64>),
    /// Processor counts `m` ([`SystemSpec::with_m_processors`]); values
    /// outside `1..=M` are skipped.
    Procs(Vec<usize>),
    /// Release-time scales ([`SystemSpec::with_scaled_releases`]).
    ReleaseScale(Vec<f64>),
    /// Link-speed scales ([`SystemSpec::with_scaled_links`]).
    LinkScale(Vec<f64>),
}

/// Scenario grid over job sizes (fixed system, one LP shape — the
/// ideal warm-start family).
pub fn job_grid(spec: &SystemSpec, jobs: &[f64], model: TimingModel) -> Vec<Scenario> {
    cross_grid(spec, model, &[Axis::Jobs(jobs.to_vec())])
}

/// Scenario grid over processor counts `m = 1..=spec.m()`.
pub fn processor_grid(spec: &SystemSpec, model: TimingModel) -> Vec<Scenario> {
    cross_grid(spec, model, &[Axis::Procs((1..=spec.m()).collect())])
}

/// Scenario grid over release-time scales.
pub fn release_grid(spec: &SystemSpec, scales: &[f64], model: TimingModel) -> Vec<Scenario> {
    cross_grid(spec, model, &[Axis::ReleaseScale(scales.to_vec())])
}

/// Scenario grid over link-speed scales.
pub fn link_grid(spec: &SystemSpec, scales: &[f64], model: TimingModel) -> Vec<Scenario> {
    cross_grid(spec, model, &[Axis::LinkScale(scales.to_vec())])
}

/// Cartesian product of axes, applied left to right; labels are the
/// space-joined per-axis labels (`J=250 m=4 R×0.5`).
pub fn cross_grid(spec: &SystemSpec, model: TimingModel, axes: &[Axis]) -> Vec<Scenario> {
    let mut grid =
        vec![Scenario { label: String::new(), spec: spec.clone(), model }];
    for axis in axes {
        let mut next = Vec::new();
        for sc in &grid {
            let join = |tag: String| {
                if sc.label.is_empty() {
                    tag
                } else {
                    format!("{} {}", sc.label, tag)
                }
            };
            match axis {
                Axis::Jobs(v) => {
                    for &j in v {
                        next.push(Scenario {
                            label: join(format!("J={j:.4}")),
                            spec: sc.spec.with_job(j),
                            model,
                        });
                    }
                }
                Axis::Procs(v) => {
                    for &m in v {
                        if m >= 1 && m <= sc.spec.m() {
                            next.push(Scenario {
                                label: join(format!("m={m}")),
                                spec: sc.spec.with_m_processors(m),
                                model,
                            });
                        }
                    }
                }
                Axis::ReleaseScale(v) => {
                    for &s in v {
                        next.push(Scenario {
                            label: join(format!("R\u{d7}{s:.3}")),
                            spec: sc.spec.with_scaled_releases(s),
                            model,
                        });
                    }
                }
                Axis::LinkScale(v) => {
                    for &s in v {
                        next.push(Scenario {
                            label: join(format!("G\u{d7}{s:.3}")),
                            spec: sc.spec.with_scaled_links(s),
                            model,
                        });
                    }
                }
            }
        }
        grid = next;
    }
    grid
}

/// Solve one scenario through a per-worker [`Session`]. The session
/// owns the warm cache *and* the per-family cross-shape projection
/// seed that used to live in a hand-rolled worker-state struct here —
/// the facade is now the one place that logic exists.
fn solve_scenario(session: &mut Session, sc: &Scenario) -> Result<SweepPoint> {
    let req = SolveRequest::new(Family::from(sc.model), sc.spec.clone());
    let resp = session.solve(&req).map_err(|e| e.into_error())?;
    Ok(SweepPoint {
        label: sc.label.clone(),
        makespan: resp.makespan,
        lp_iterations: resp.diagnostics.iterations,
    })
}

/// Solve every scenario, in input order, fanning across worker threads
/// with one [`Session`] per worker. [`Backend::PdhgBlock`] grids
/// instead batch through [`run_block_grid`] (one shared iteration
/// stream per chunk of [`DEFAULT_BLOCK_WIDTH`] columns).
pub fn run_scenarios(scenarios: &[Scenario], opts: &SweepOptions) -> Result<Vec<SweepPoint>> {
    if opts.backend == Backend::PdhgBlock {
        return run_block_grid(scenarios, &PdhgOptions::default());
    }
    let warm = opts.warm_start;
    let simplex = opts.simplex.clone();
    let backend = opts.backend;
    let init = move || {
        Solver::new().backend(backend).warm_start(warm).simplex(simplex.clone()).build()
    };
    let results = if opts.steal {
        parallel_map_steal(scenarios, opts.threads, init, solve_scenario)
    } else {
        parallel_map_with(scenarios, opts.threads, init, solve_scenario)
    };
    results
        .into_iter()
        .map(|slot| slot.unwrap_or_else(|p| Err(Error::WorkerPanicked(p.message))))
        .collect()
}

/// Solve a scenario grid through the batched block-PDHG backend
/// ([`solve_block`]): the grid is chunked into
/// [`DEFAULT_BLOCK_WIDTH`]-column panels, each chunk sharing one
/// matrix pass and one `‖A‖` power iteration per PDHG step, with
/// per-column early retirement. The LPs are solved raw (no presolve);
/// `makespan` is the LP objective (the families minimize `T_f`) and
/// `lp_iterations` counts first-order iterations
/// (`blocks × BLOCK_STEPS`). A grid whose points share constraint
/// structure — a job-size or release axis — batches fully; mixed
/// shapes fall back per column inside [`solve_block`].
pub fn run_block_grid(scenarios: &[Scenario], opts: &PdhgOptions) -> Result<Vec<SweepPoint>> {
    let mut out = Vec::with_capacity(scenarios.len());
    for chunk in scenarios.chunks(DEFAULT_BLOCK_WIDTH.max(1)) {
        let mut lps = Vec::with_capacity(chunk.len());
        for sc in chunk {
            sc.spec.validate()?;
            let lp = match sc.model {
                TimingModel::FrontEnd => FeOptions::default().build_lp(&sc.spec),
                TimingModel::NoFrontEnd => NfeOptions::default().build_lp(&sc.spec),
            };
            lps.push(lp);
        }
        let blk = solve_block(&lps, opts)?;
        for (sc, col) in chunk.iter().zip(blk.columns) {
            out.push(SweepPoint {
                label: sc.label.clone(),
                makespan: col.objective,
                lp_iterations: col.blocks * BLOCK_STEPS,
            });
        }
    }
    Ok(out)
}

/// A continuous sweep axis for [`refine`]. The processor axis is
/// discrete and needs no refinement — the advisor walks it directly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ContinuousAxis {
    /// Job size `J` ([`SystemSpec::with_job`]).
    Jobs,
    /// Release-time scale ([`SystemSpec::with_scaled_releases`]).
    ReleaseScale,
    /// Link-speed scale ([`SystemSpec::with_scaled_links`]).
    LinkScale,
}

impl ContinuousAxis {
    /// Spec at axis value `v`.
    fn apply(self, spec: &SystemSpec, v: f64) -> SystemSpec {
        match self {
            ContinuousAxis::Jobs => spec.with_job(v),
            ContinuousAxis::ReleaseScale => spec.with_scaled_releases(v),
            ContinuousAxis::LinkScale => spec.with_scaled_links(v),
        }
    }

    /// Point label in the same style as [`cross_grid`].
    fn label(self, v: f64) -> String {
        match self {
            ContinuousAxis::Jobs => format!("J={v:.4}"),
            ContinuousAxis::ReleaseScale => format!("R\u{d7}{v:.4}"),
            ContinuousAxis::LinkScale => format!("G\u{d7}{v:.4}"),
        }
    }
}

/// Outcome of [`refine`]: the evaluated points plus the located knee
/// bracket.
#[derive(Debug, Clone)]
pub struct Refinement {
    /// Every evaluated point in ascending axis order — the coarse grid
    /// plus the bisection midpoints.
    pub points: Vec<SweepPoint>,
    /// Axis interval bracketing the knee. `None` when no coarse-grid
    /// step's improvement dropped below the threshold (no knee on the
    /// grid).
    pub knee: Option<(f64, f64)>,
    /// LP solves spent (coarse grid + refinement midpoints).
    pub solves: usize,
}

/// §6.2-style knee localization on a continuous axis.
///
/// Solves the coarse `values` grid, walks it in the improvement
/// direction (descending values), finds the first interval whose
/// relative improvement *rate* (relative `T_f` change per axis unit)
/// drops below `threshold` ([`knee_interval`]), then bisects that
/// interval — evaluating only midpoints, all through one warm
/// [`Session`] — until its width shrinks below `tol` × the initial
/// bracket width. The refined bracket always stays inside the coarse
/// interval, so the coarse-grid knee is never missed; a uniform coarse
/// grid makes the per-unit rates proportional to the advisor's
/// per-step gradients.
pub fn refine(
    spec: &SystemSpec,
    model: TimingModel,
    axis: ContinuousAxis,
    values: &[f64],
    threshold: f64,
    tol: f64,
) -> Result<Refinement> {
    if values.len() < 2 {
        return Err(Error::Usage("refine needs at least two axis values".into()));
    }
    if !tol.is_finite() || tol <= 0.0 {
        return Err(Error::Usage(format!("refine tolerance must be positive, got {tol}")));
    }
    let mut vals = values.to_vec();
    vals.sort_by(|a, b| a.partial_cmp(b).expect("finite axis values"));

    let mut session = Solver::new().build();
    let mut solves = 0usize;
    let mut eval = |v: f64, session: &mut Session| -> Result<(f64, SweepPoint)> {
        let sc = Scenario { label: axis.label(v), spec: axis.apply(spec, v), model };
        solves += 1;
        Ok((v, solve_scenario(session, &sc)?))
    };

    let mut pts: Vec<(f64, SweepPoint)> = Vec::with_capacity(vals.len());
    for &v in &vals {
        pts.push(eval(v, &mut session)?);
    }
    // The improvement direction is *descending* axis values — a
    // smaller job, release scale, or link scale can only shrink the
    // makespan — so the walk mirrors the advisor's m = 1..M series,
    // where every step adds resources and improvements taper off.
    // `rate(a -> b)` is the relative T_f improvement per axis unit,
    // based at the walk's current point `a` (negative when improving,
    // like the advisor's gradients).
    let rate = |va: f64, ta: f64, vb: f64, tb: f64| {
        (tb - ta) / (ta.abs().max(1e-12) * (va - vb).max(f64::MIN_POSITIVE))
    };
    let n = pts.len();
    let rates: Vec<f64> = (0..n - 1)
        .map(|i| {
            let a = &pts[n - 1 - i];
            let b = &pts[n - 2 - i];
            rate(a.0, a.1.makespan, b.0, b.1.makespan)
        })
        .collect();
    let Some(k) = knee_interval(&rates, threshold) else {
        return Ok(Refinement {
            points: pts.into_iter().map(|(_, p)| p).collect(),
            knee: None,
            solves,
        });
    };

    let (mut lo, mut hi) = (pts[n - 2 - k].0, pts[n - 1 - k].0);
    let mut thi = pts[n - 1 - k].1.makespan;
    let span = hi - lo;
    // 64 midpoints would shrink the bracket by 2^64 — a backstop, not
    // a budget anyone reaches with a sane tolerance.
    while hi - lo > tol * span && solves < vals.len() + 64 {
        let mid = 0.5 * (lo + hi);
        let (_, p) = eval(mid, &mut session)?;
        let tmid = p.makespan;
        pts.push((mid, p));
        if -rate(hi, thi, mid, tmid) < threshold {
            // The improvement from `hi` down to `mid` is already below
            // the threshold, so the crossing happened above `mid`.
            lo = mid;
        } else {
            hi = mid;
            thi = tmid;
        }
    }
    pts.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite axis values"));
    Ok(Refinement {
        points: pts.into_iter().map(|(_, p)| p).collect(),
        knee: Some((lo, hi)),
        solves,
    })
}

/// Run `f` over `items` on scoped worker threads, each worker owning a
/// private [`WarmCache`]. See [`parallel_map_with`] for the
/// generic-state version and [`parallel_map_steal`] for the
/// work-stealing scheduler.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<MapResult<R>>
where
    T: Sync,
    R: Send,
    F: Fn(&mut WarmCache, &T) -> R + Sync,
{
    parallel_map_with(items, threads, WarmCache::new, f)
}

/// Run `f` over `items` on scoped worker threads, each worker owning
/// private state built by `init`. Items are split into contiguous
/// chunks (one per worker) and results come back in input order.
/// `threads == 0` uses one worker per available core; the count is
/// always capped by the item count. A panic inside `f` lands in that
/// item's slot as [`WorkerPanic`]; the worker rebuilds its state and
/// finishes its chunk.
pub fn parallel_map_with<T, R, S, F, I>(
    items: &[T],
    threads: usize,
    init: I,
    f: F,
) -> Vec<MapResult<R>>
where
    T: Sync,
    R: Send,
    F: Fn(&mut S, &T) -> R + Sync,
    I: Fn() -> S + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = effective_threads(threads, n);
    if threads <= 1 {
        let mut state = init();
        return items.iter().map(|it| run_caught(&mut state, &init, &f, it)).collect();
    }

    let chunk = n.div_ceil(threads);
    let mut out: Vec<MapResult<R>> = Vec::with_capacity(n);
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(threads);
        for part in items.chunks(chunk) {
            let fref = &f;
            let iref = &init;
            handles.push((
                part.len(),
                s.spawn(move || {
                    let mut state = iref();
                    part.iter()
                        .map(|it| run_caught(&mut state, iref, fref, it))
                        .collect::<Vec<MapResult<R>>>()
                }),
            ));
        }
        for (len, h) in handles {
            match h.join() {
                Ok(part_out) => out.extend(part_out),
                // `init` itself panicked (per-item panics are caught
                // above): this chunk is lost, the others survive.
                Err(payload) => {
                    let message = panic_message(payload.as_ref());
                    out.extend((0..len).map(|_| Err(WorkerPanic { message: message.clone() })));
                }
            }
        }
    });
    out
}

/// Work-stealing variant of [`parallel_map_with`] for ragged work
/// lists: each worker is seeded with a contiguous block (so
/// neighbouring scenarios still share warm state), drains it from the
/// front, and when empty steals single items from the *back* of the
/// next non-empty neighbour — the classic deque discipline, so a thief
/// takes the work farthest from where the owner is currently warm.
/// Results come back in input order regardless of who solved what.
/// Panics are contained per item (see [`parallel_map_with`]); a worker
/// lost to an `init` panic leaves its deque behind, and the surviving
/// workers drain it.
pub fn parallel_map_steal<T, R, S, F, I>(
    items: &[T],
    threads: usize,
    init: I,
    f: F,
) -> Vec<MapResult<R>>
where
    T: Sync,
    R: Send,
    F: Fn(&mut S, &T) -> R + Sync,
    I: Fn() -> S + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = effective_threads(threads, n);
    if threads <= 1 {
        let mut state = init();
        return items.iter().map(|it| run_caught(&mut state, &init, &f, it)).collect();
    }

    // Contiguous blocks, one deque per worker.
    let chunk = n.div_ceil(threads);
    let deques: Vec<Mutex<VecDeque<usize>>> = (0..threads)
        .map(|w| {
            let lo = w * chunk;
            let hi = ((w + 1) * chunk).min(n);
            Mutex::new((lo..hi.max(lo)).collect())
        })
        .collect();

    let mut slots: Vec<Option<MapResult<R>>> = (0..n).map(|_| None).collect();
    let mut lost_worker: Option<String> = None;
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(threads);
        for w in 0..threads {
            let fref = &f;
            let iref = &init;
            let dref = &deques;
            handles.push(s.spawn(move || {
                let mut state = iref();
                let mut done: Vec<(usize, MapResult<R>)> = Vec::new();
                loop {
                    // Own work first (front: preserves warm locality).
                    let mut idx = dref[w].lock().expect("deque lock").pop_front();
                    if idx.is_none() {
                        // Steal from the back of the first non-empty
                        // neighbour, scanning round-robin from w+1.
                        for off in 1..threads {
                            let v = (w + off) % threads;
                            if let Some(i) = dref[v].lock().expect("deque lock").pop_back() {
                                idx = Some(i);
                                break;
                            }
                        }
                    }
                    let Some(i) = idx else { break };
                    done.push((i, run_caught(&mut state, iref, fref, &items[i])));
                }
                done
            }));
        }
        for h in handles {
            match h.join() {
                Ok(done) => {
                    for (i, r) in done {
                        slots[i] = Some(r);
                    }
                }
                // `init` panicked before the worker touched any item;
                // its seeded deque was (or will be) drained by the
                // surviving workers, so only record the message for
                // the all-workers-dead fallback below.
                Err(payload) => lost_worker = Some(panic_message(payload.as_ref())),
            }
        }
    });
    slots
        .into_iter()
        .map(|slot| {
            slot.unwrap_or_else(|| {
                Err(WorkerPanic {
                    message: lost_worker
                        .clone()
                        .unwrap_or_else(|| "sweep worker panicked".to_string()),
                })
            })
        })
        .collect()
}

fn effective_threads(requested: usize, items: usize) -> usize {
    let t = if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    };
    t.clamp(1, items)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table1_spec() -> SystemSpec {
        SystemSpec::builder()
            .source(0.2, 10.0)
            .source(0.4, 50.0)
            .processors(&[2.0, 3.0, 4.0, 5.0, 6.0])
            .job(100.0)
            .build()
            .unwrap()
    }

    #[test]
    fn parallel_matches_serial_on_job_grid() {
        let spec = table1_spec();
        let jobs: Vec<f64> = (0..16).map(|k| 100.0 + 10.0 * k as f64).collect();
        let grid = job_grid(&spec, &jobs, TimingModel::FrontEnd);
        let serial = run_scenarios(
            &grid,
            &SweepOptions { threads: 1, warm_start: true, steal: false, ..SweepOptions::default() },
        )
        .unwrap();
        let par = run_scenarios(
            &grid,
            &SweepOptions { threads: 4, warm_start: true, steal: false, ..SweepOptions::default() },
        )
        .unwrap();
        assert_eq!(serial.len(), par.len());
        for (a, b) in serial.iter().zip(par.iter()) {
            assert_eq!(a.label, b.label, "order preserved");
            assert!(
                (a.makespan - b.makespan).abs() < 1e-7 * (1.0 + a.makespan.abs()),
                "{}: {} vs {}",
                a.label,
                a.makespan,
                b.makespan
            );
        }
    }

    #[test]
    fn warm_start_agrees_with_cold() {
        // mild_spec, not table1: Table 1's releases (10, 50) make the
        // NFE LP infeasible below J = 200 (eq. 12 forces
        // beta[0][0] >= 200).
        let spec = mild_spec();
        let jobs: Vec<f64> = (0..12).map(|k| 80.0 + 15.0 * k as f64).collect();
        let grid = job_grid(&spec, &jobs, TimingModel::NoFrontEnd);
        let cold = run_scenarios(
            &grid,
            &SweepOptions { threads: 1, warm_start: false, steal: false, ..SweepOptions::default() },
        )
        .unwrap();
        let warm = run_scenarios(
            &grid,
            &SweepOptions { threads: 1, warm_start: true, steal: false, ..SweepOptions::default() },
        )
        .unwrap();
        let mut warm_total = 0usize;
        let mut cold_total = 0usize;
        for (a, b) in cold.iter().zip(warm.iter()) {
            assert!((a.makespan - b.makespan).abs() < 1e-7 * (1.0 + a.makespan.abs()));
            cold_total += a.lp_iterations;
            warm_total += b.lp_iterations;
        }
        assert!(
            warm_total <= cold_total,
            "warm sweeps should not iterate more: {warm_total} vs {cold_total}"
        );
    }

    #[test]
    fn processor_grid_covers_all_m() {
        let grid = processor_grid(&table1_spec(), TimingModel::FrontEnd);
        assert_eq!(grid.len(), 5);
        let pts = run_scenarios(&grid, &SweepOptions::default()).unwrap();
        // More processors never hurt.
        for w in pts.windows(2) {
            assert!(w[1].makespan <= w[0].makespan + 1e-6);
        }
    }

    /// A spec whose first release is 0, so release scaling only raises
    /// the *inter*-release gaps — the formally monotone direction (all
    /// affected constraints are `>=` rows whose rhs grows).
    fn mild_spec() -> SystemSpec {
        SystemSpec::builder()
            .source(0.2, 0.0)
            .source(0.2, 5.0)
            .processors(&[2.0, 3.0, 4.0])
            .job(100.0)
            .build()
            .unwrap()
    }

    #[test]
    fn release_axis_is_monotone() {
        let spec = mild_spec();
        let scales = [0.0, 0.5, 1.0, 1.5, 2.0];
        for model in [TimingModel::FrontEnd, TimingModel::NoFrontEnd] {
            // Later releases can only delay the finish.
            let pts = run_scenarios(
                &release_grid(&spec, &scales, model),
                &SweepOptions { threads: 1, warm_start: true, steal: false, ..SweepOptions::default() },
            )
            .unwrap();
            assert_eq!(pts.len(), scales.len());
            for w in pts.windows(2) {
                assert!(
                    w[1].makespan >= w[0].makespan - 1e-6,
                    "{model:?} release axis: {} then {}",
                    w[0].makespan,
                    w[1].makespan
                );
            }
        }
    }

    #[test]
    fn link_axis_matches_direct_solves() {
        let spec = mild_spec();
        let scales = [0.5, 1.0, 2.0];
        for model in [TimingModel::FrontEnd, TimingModel::NoFrontEnd] {
            let pts = run_scenarios(
                &link_grid(&spec, &scales, model),
                &SweepOptions { threads: 1, warm_start: true, steal: false, ..SweepOptions::default() },
            )
            .unwrap();
            for (pt, &s) in pts.iter().zip(scales.iter()) {
                let sub = spec.with_scaled_links(s);
                let direct = match model {
                    TimingModel::FrontEnd => crate::pipeline::solve(
                        &crate::dlt::frontend::FeOptions::default(),
                        &sub,
                    )
                    .unwrap(),
                    TimingModel::NoFrontEnd => crate::pipeline::solve(
                        &crate::dlt::no_frontend::NfeOptions::default(),
                        &sub,
                    )
                    .unwrap(),
                };
                assert!(
                    (pt.makespan - direct.makespan).abs()
                        < 1e-7 * (1.0 + direct.makespan.abs()),
                    "{model:?} G scale {s}: sweep {} vs direct {}",
                    pt.makespan,
                    direct.makespan
                );
            }
        }
    }

    #[test]
    fn cross_grid_builds_cartesian_product() {
        let spec = table1_spec();
        let grid = cross_grid(
            &spec,
            TimingModel::FrontEnd,
            &[
                Axis::Jobs(vec![100.0, 200.0]),
                Axis::Procs(vec![2, 4, 99]), // 99 > M is skipped
                Axis::ReleaseScale(vec![0.5, 1.0]),
            ],
        );
        assert_eq!(grid.len(), 2 * 2 * 2);
        assert!(grid[0].label.contains("J=") && grid[0].label.contains("m="));
    }

    #[test]
    fn work_stealing_matches_chunked_on_ragged_grid() {
        // procs × job: LP sizes vary by 5x across the grid — the
        // ragged case work stealing exists for.
        let spec = table1_spec();
        let grid = cross_grid(
            &spec,
            TimingModel::FrontEnd,
            &[
                Axis::Procs((1..=5).collect()),
                Axis::Jobs((0..5).map(|k| 100.0 + 40.0 * k as f64).collect()),
            ],
        );
        let serial = run_scenarios(
            &grid,
            &SweepOptions { threads: 1, warm_start: true, steal: false, ..SweepOptions::default() },
        )
        .unwrap();
        for threads in [2usize, 3, 8] {
            let stolen = run_scenarios(
                &grid,
                &SweepOptions { threads, warm_start: true, steal: true, ..SweepOptions::default() },
            )
            .unwrap();
            assert_eq!(serial.len(), stolen.len());
            for (a, b) in serial.iter().zip(stolen.iter()) {
                assert_eq!(a.label, b.label, "input order preserved under stealing");
                assert!(
                    (a.makespan - b.makespan).abs() < 1e-7 * (1.0 + a.makespan.abs()),
                    "{}: serial {} vs stolen {}",
                    a.label,
                    a.makespan,
                    b.makespan
                );
            }
        }
    }

    #[test]
    fn block_grid_matches_simplex_sweep() {
        let spec = mild_spec();
        let jobs: Vec<f64> = (0..20).map(|k| 80.0 + 15.0 * k as f64).collect();
        let grid = job_grid(&spec, &jobs, TimingModel::NoFrontEnd);
        let exact = run_scenarios(&grid, &SweepOptions::default()).unwrap();
        // Through the SweepOptions routing (not a direct call), so the
        // CLI's `--backend pdhg-block` path is what's exercised.
        let block = run_scenarios(
            &grid,
            &SweepOptions { backend: Backend::PdhgBlock, ..SweepOptions::default() },
        )
        .unwrap();
        assert_eq!(exact.len(), block.len());
        for (a, b) in exact.iter().zip(block.iter()) {
            assert_eq!(a.label, b.label, "order preserved");
            assert!(
                (a.makespan - b.makespan).abs() < 1e-3 * (1.0 + a.makespan.abs()),
                "{}: simplex {} vs block {}",
                a.label,
                a.makespan,
                b.makespan
            );
        }
    }

    #[test]
    fn refine_tightens_the_knee_bracket() {
        // Faster links shrink the makespan with diminishing returns —
        // the continuous analogue of the §6.2 processor knee.
        let spec = mild_spec();
        let coarse: Vec<f64> = (1..=6).map(|k| k as f64).collect();
        let threshold = 0.05;
        let r = refine(
            &spec,
            TimingModel::FrontEnd,
            ContinuousAxis::LinkScale,
            &coarse,
            threshold,
            0.05,
        )
        .unwrap();
        let (lo, hi) = r.knee.expect("diminishing returns must produce a knee");
        // The refined bracket lies inside one coarse interval ...
        let k = coarse.windows(2).position(|w| w[0] <= lo && hi <= w[1]);
        assert!(k.is_some(), "refined bracket [{lo}, {hi}] escaped the coarse grid");
        // ... and is tightened to the requested fraction of it.
        assert!(hi - lo <= 0.05 * 1.0 + 1e-12, "bracket [{lo}, {hi}] not tightened");
        assert!(r.solves > coarse.len(), "refinement must add midpoint solves");
        assert_eq!(r.points.len(), r.solves, "every solve is reported as a point");
        for w in r.points.windows(2) {
            assert!(w[0].label != w[1].label, "labels distinct after sorting");
        }
        // Degenerate inputs error cleanly.
        assert!(matches!(
            refine(&spec, TimingModel::FrontEnd, ContinuousAxis::Jobs, &[1.0], 0.05, 0.1),
            Err(Error::Usage(_))
        ));
        assert!(matches!(
            refine(
                &spec,
                TimingModel::FrontEnd,
                ContinuousAxis::Jobs,
                &[1.0, 2.0],
                0.05,
                0.0
            ),
            Err(Error::Usage(_))
        ));
    }

    #[test]
    fn parallel_map_empty_and_oversubscribed() {
        let none: Vec<u32> = Vec::new();
        let out = parallel_map(&none, 8, |_, x| *x);
        assert!(out.is_empty());
        let items = [1u32, 2, 3];
        let out = parallel_map(&items, 64, |_, x| x * 2);
        assert_eq!(out, vec![Ok(2), Ok(4), Ok(6)]);
        let out = parallel_map_steal(&items, 64, || (), |_, x| x * 3);
        assert_eq!(out, vec![Ok(3), Ok(6), Ok(9)]);
    }

    #[test]
    fn item_panic_costs_only_its_slot() {
        let items: Vec<u32> = (0..20).collect();
        let work = |calls: &mut u32, &x: &u32| {
            *calls += 1;
            assert!(x != 7, "boom on 7");
            x * 2
        };
        for threads in [1usize, 3] {
            let chunked = parallel_map_with(&items, threads, || 0u32, work);
            let stolen = parallel_map_steal(&items, threads, || 0u32, work);
            for out in [chunked, stolen] {
                assert_eq!(out.len(), items.len());
                for (i, slot) in out.iter().enumerate() {
                    if i == 7 {
                        let p = slot.as_ref().expect_err("item 7 must surface its panic");
                        assert!(p.message.contains("boom on 7"), "{}", p.message);
                    } else {
                        assert_eq!(slot.as_ref().unwrap(), &(i as u32 * 2), "slot {i}");
                    }
                }
            }
        }
    }

    #[test]
    fn run_scenarios_survives_a_poisoned_point() {
        // A panic inside one scenario's solve must not abort the sweep
        // machinery; exercised through the generic map the sweeps use.
        let items = [1u32, 2, 3];
        let out = parallel_map_with(
            &items,
            2,
            || (),
            |_, &x| {
                assert!(x != 2, "poisoned point");
                x
            },
        );
        assert!(out[0].is_ok() && out[2].is_ok());
        assert!(out[1].is_err());
        // And the error surfaces as Error::WorkerPanicked through the
        // ApiError kind mapping the batch path uses.
        let err = crate::api::ApiError::from(Error::WorkerPanicked("poisoned point".into()));
        assert_eq!(err.kind, "worker_panicked");
        assert!(matches!(err.into_error(), Error::WorkerPanicked(_)));
    }
}
