//! The paper's parameter tables, verbatim.

use crate::model::SystemSpec;

/// Table 1 — numerical test, **with** front-ends:
/// `G = (0.2, 0.4)`, `R = (10, 50)`, `A = (2..6)`, `J = 100`.
pub fn table1() -> SystemSpec {
    SystemSpec::builder()
        .source(0.2, 10.0)
        .source(0.4, 50.0)
        .processors(&[2.0, 3.0, 4.0, 5.0, 6.0])
        .job(100.0)
        .build()
        .expect("table 1 params are valid")
}

/// Table 2 — numerical test, **without** front-ends:
/// `G = (0.2, 0.2)`, `R = (0, 5)`, `A = (2, 3, 4)`, `J = 100`.
pub fn table2() -> SystemSpec {
    SystemSpec::builder()
        .source(0.2, 0.0)
        .source(0.2, 5.0)
        .processors(&[2.0, 3.0, 4.0])
        .job(100.0)
        .build()
        .expect("table 2 params are valid")
}

/// Table 3 — finish-time sweeps (Figs. 12, 13):
/// `G = (0.5, 0.6, 0.7)`, `R = (2, 3, 4)`, `A = 1.1, 1.2, …, 3.0`
/// (20 processors), `J = 100`.
pub fn table3() -> SystemSpec {
    let a: Vec<f64> = (0..20).map(|k| 1.1 + 0.1 * k as f64).collect();
    SystemSpec::builder()
        .source(0.5, 2.0)
        .source(0.6, 3.0)
        .source(0.7, 4.0)
        .processors(&a)
        .job(100.0)
        .build()
        .expect("table 3 params are valid")
}

/// Table 4 — speedup analysis (Figs. 14, 15), homogeneous nodes:
/// `G = 0.5 ×10`, `R = 0 ×10`, `A = 2 ×18`, `J = 100`.
pub fn table4() -> SystemSpec {
    let mut b = SystemSpec::builder();
    for _ in 0..10 {
        b = b.source(0.5, 0.0);
    }
    b.processors(&[2.0; 18]).job(100.0).build().expect("table 4 params are valid")
}

/// Table 5 — trade-off analysis (Figs. 16–20):
/// `G = (0.5, 0.6)`, `R = (2, 3)`, `A = 1.1…3.0`, `C = 29, 28, …, 10`,
/// `J = 100`.
pub fn table5() -> SystemSpec {
    let ac: Vec<(f64, f64)> = (0..20).map(|k| (1.1 + 0.1 * k as f64, 29.0 - k as f64)).collect();
    SystemSpec::builder()
        .source(0.5, 2.0)
        .source(0.6, 3.0)
        .priced_processors(&ac)
        .job(100.0)
        .build()
        .expect("table 5 params are valid")
}

/// Source counts plotted in Figs. 14/15.
pub const FIG14_SOURCE_COUNTS: &[usize] = &[1, 2, 3, 5, 10];

/// Job sizes plotted in Fig. 13.
pub const FIG13_JOB_SIZES: &[f64] = &[100.0, 300.0, 500.0];

/// Fig. 19 budgets (chosen to reproduce the paper's overlapping
/// solution areas m ∈ [6, 12]; the paper plots budgets without printing
/// their values, so ours are pinned to the sweep's own m=12 cost and
/// m=6 finish time).
pub const FIG19_GRADIENT_THRESHOLD: f64 = 0.06;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tables_valid() {
        for (name, spec) in [
            ("t1", table1()),
            ("t2", table2()),
            ("t3", table3()),
            ("t4", table4()),
            ("t5", table5()),
        ] {
            spec.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn table3_matches_paper_text() {
        let s = table3();
        assert_eq!(s.n(), 3);
        assert_eq!(s.m(), 20);
        assert!((s.a()[0] - 1.1).abs() < 1e-12);
        assert!((s.a()[19] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn table5_costs_descend() {
        let s = table5();
        let c = s.cost_rates();
        assert_eq!(c[0], 29.0);
        assert_eq!(c[19], 10.0);
        assert!(c.windows(2).all(|w| w[0] > w[1]), "paper: C_1 > C_2 > ...");
    }
}
