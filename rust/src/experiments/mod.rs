//! The paper's experiment registry: every table and figure in the
//! evaluation, as code.
//!
//! [`params`] holds the parameter tables (Tables 1–5) exactly as
//! printed in the paper; [`series`] regenerates each figure's data
//! series; [`table::ExpTable`] is the common row/column container the
//! CLI, benches and examples all render from.
//!
//! | Paper artifact | Generator |
//! |---|---|
//! | Table 1 + Fig 10 | [`series::fig10`] |
//! | Table 2 + Fig 11 | [`series::fig11`] |
//! | Table 3 + Fig 12 | [`series::fig12`] |
//! | Fig 13           | [`series::fig13`] |
//! | Table 4 + Fig 14 | [`series::fig14`] |
//! | Fig 15           | [`series::fig15`] |
//! | Table 5 + Fig 16–18 | [`series::fig16_17_18`] |
//! | Fig 19           | [`series::fig19`] |
//! | Fig 20           | [`series::fig20`] |

pub mod params;
pub mod series;
pub mod sweep;
pub mod table;

pub use table::ExpTable;

use crate::error::{Error, Result};

/// All experiment names, in paper order.
pub const ALL: &[&str] =
    &["fig10", "fig11", "fig12", "fig13", "fig14", "fig15", "fig16", "fig17", "fig18", "fig19", "fig20"];

/// Run one experiment by name.
pub fn run(name: &str) -> Result<ExpTable> {
    match name {
        "fig10" => series::fig10(),
        "fig11" => series::fig11(),
        "fig12" => series::fig12(),
        "fig13" => series::fig13(),
        "fig14" => series::fig14(),
        "fig15" => series::fig15(),
        "fig16" | "fig17" | "fig18" => {
            let (f16, f17, f18) = series::fig16_17_18()?;
            Ok(match name {
                "fig16" => f16,
                "fig17" => f17,
                _ => f18,
            })
        }
        "fig19" => series::fig19(),
        "fig20" => series::fig20(),
        _ => Err(Error::Usage(format!(
            "unknown experiment `{name}` (expected one of {})",
            ALL.join(", ")
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_all_figures() {
        for name in ALL {
            let t = run(name).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(!t.rows.is_empty(), "{name} produced no rows");
            assert_eq!(
                t.rows[0].len(),
                t.columns.len(),
                "{name}: row width != column count"
            );
        }
    }

    #[test]
    fn unknown_name_errors() {
        assert!(run("fig99").is_err());
    }
}
