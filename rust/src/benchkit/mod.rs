//! Micro-benchmark harness (the offline crate set has no `criterion`).
//!
//! Usage in a `harness = false` bench target:
//!
//! ```no_run
//! use dlt::benchkit::{Bencher, Reporter};
//! let mut rep = Reporter::new("my_bench_group");
//! let b = Bencher::default();
//! rep.report("solve_small", b.bench(|| {
//!     // work under test
//!     std::hint::black_box(2 + 2);
//! }));
//! rep.finish();
//! ```
//!
//! The harness warms up, then runs timed batches until both a minimum
//! wall-clock budget and a minimum sample count are met, and reports
//! robust statistics (median/p95 rather than best-of).

use crate::util::stats::Summary;
use std::time::{Duration, Instant};

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct Bencher {
    /// Warm-up time before measurement.
    pub warmup: Duration,
    /// Minimum total measurement time.
    pub min_time: Duration,
    /// Minimum number of samples.
    pub min_samples: usize,
    /// Maximum number of samples (cap for very fast functions).
    pub max_samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            min_time: Duration::from_millis(800),
            min_samples: 20,
            max_samples: 100_000,
        }
    }
}

impl Bencher {
    /// Fast settings for CI-ish runs (set `DLT_BENCH_FAST=1`).
    pub fn from_env() -> Bencher {
        if std::env::var("DLT_BENCH_FAST").is_ok() {
            Bencher {
                warmup: Duration::from_millis(20),
                min_time: Duration::from_millis(100),
                min_samples: 5,
                max_samples: 10_000,
            }
        } else {
            Bencher::default()
        }
    }
}

/// Result of one benchmark: per-iteration timings in nanoseconds.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Summary over per-iteration nanoseconds.
    pub ns: Summary,
    /// Iterations per timed batch that was used.
    pub batch: usize,
}

impl Bencher {
    /// Benchmark a closure.
    pub fn bench<F: FnMut()>(&self, mut f: F) -> BenchResult {
        // Warm-up and batch sizing: aim for batches of >= ~100 µs so
        // timer overhead stays below ~0.1 %.
        let warm_start = Instant::now();
        let mut iters_during_warmup = 0u64;
        while warm_start.elapsed() < self.warmup || iters_during_warmup == 0 {
            f();
            iters_during_warmup += 1;
            if iters_during_warmup > 10_000_000 {
                break;
            }
        }
        let per_iter = warm_start.elapsed().as_nanos() as f64 / iters_during_warmup as f64;
        let batch = ((100_000.0 / per_iter.max(1.0)).ceil() as usize).clamp(1, 1_000_000);

        let mut samples = Vec::new();
        let start = Instant::now();
        while (start.elapsed() < self.min_time || samples.len() < self.min_samples)
            && samples.len() < self.max_samples
        {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            let dt = t0.elapsed().as_nanos() as f64 / batch as f64;
            samples.push(dt);
        }
        BenchResult { ns: Summary::of(&samples), batch }
    }

    /// Benchmark a closure that returns a value (kept alive via
    /// `black_box` to prevent the optimizer from deleting the work).
    pub fn bench_val<T, F: FnMut() -> T>(&self, mut f: F) -> BenchResult {
        self.bench(|| {
            std::hint::black_box(f());
        })
    }
}

/// Pretty-printer for bench results. Machine-readable output:
///
/// - `DLT_BENCH_JSON` set — one JSON line per entry on stdout;
/// - `DLT_BENCH_JSON_DIR=dir` set — [`Reporter::finish`] additionally
///   writes `dir/BENCH_<slug>.json` with every entry and note, so CI
///   can archive the perf trajectory across commits.
pub struct Reporter {
    group: String,
    slug: Option<String>,
    rows: Vec<(String, BenchResult)>,
    notes: Vec<String>,
}

impl Reporter {
    /// Start a report group.
    pub fn new(group: impl Into<String>) -> Reporter {
        let group = group.into();
        println!("\n== bench group: {group} ==");
        println!(
            "{:<44} {:>12} {:>12} {:>12} {:>12} {:>8}",
            "benchmark", "median", "mean", "p95", "max", "samples"
        );
        Reporter { group, slug: None, rows: Vec::new(), notes: Vec::new() }
    }

    /// Short machine name for the JSON artifact (`BENCH_<slug>.json`).
    /// Without one, a sanitized group name is used.
    pub fn slug(mut self, s: impl Into<String>) -> Reporter {
        self.slug = Some(s.into());
        self
    }

    /// Report one benchmark.
    pub fn report(&mut self, name: &str, r: BenchResult) {
        println!(
            "{:<44} {:>12} {:>12} {:>12} {:>12} {:>8}",
            name,
            fmt_ns(r.ns.median),
            fmt_ns(r.ns.mean),
            fmt_ns(r.ns.p95),
            fmt_ns(r.ns.max),
            r.ns.n
        );
        if std::env::var("DLT_BENCH_JSON").is_ok() {
            println!(
                "{{\"group\":\"{}\",\"name\":\"{}\",\"median_ns\":{},\"mean_ns\":{},\"p95_ns\":{}}}",
                self.group, name, r.ns.median, r.ns.mean, r.ns.p95
            );
        }
        self.rows.push((name.to_string(), r));
    }

    /// Print a free-form note under the table.
    pub fn note(&mut self, text: &str) {
        println!("   note: {text}");
        self.notes.push(text.to_string());
    }

    /// Finish the group and return the collected rows. When
    /// `DLT_BENCH_JSON_DIR` is set, also writes `BENCH_<slug>.json`
    /// into that directory.
    pub fn finish(self) -> Vec<(String, BenchResult)> {
        if let Ok(dir) = std::env::var("DLT_BENCH_JSON_DIR") {
            if let Err(e) = self.write_json(&dir) {
                eprintln!("benchkit: failed to write JSON report: {e}");
            }
        }
        self.rows
    }

    fn write_json(&self, dir: &str) -> std::io::Result<()> {
        use crate::config::json::Json;
        let slug = self.slug.clone().unwrap_or_else(|| sanitize_slug(&self.group));
        let entries: Vec<Json> = self
            .rows
            .iter()
            .map(|(name, r)| {
                Json::Object(vec![
                    ("name".to_string(), Json::Str(name.clone())),
                    ("median_ns".to_string(), Json::Num(r.ns.median)),
                    ("mean_ns".to_string(), Json::Num(r.ns.mean)),
                    ("p95_ns".to_string(), Json::Num(r.ns.p95)),
                    ("max_ns".to_string(), Json::Num(r.ns.max)),
                    ("samples".to_string(), Json::Num(r.ns.n as f64)),
                ])
            })
            .collect();
        let doc = Json::Object(vec![
            ("group".to_string(), Json::Str(self.group.clone())),
            ("entries".to_string(), Json::Array(entries)),
            (
                "notes".to_string(),
                Json::Array(self.notes.iter().map(|n| Json::Str(n.clone())).collect()),
            ),
        ]);
        std::fs::create_dir_all(dir)?;
        std::fs::write(
            std::path::Path::new(dir).join(format!("BENCH_{slug}.json")),
            doc.to_string_pretty(),
        )
    }
}

/// Group name -> filesystem-safe slug.
fn sanitize_slug(group: &str) -> String {
    let mut out: String = group
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
        .collect();
    out.truncate(48);
    out
}

/// Human-friendly nanosecond formatting.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let b = Bencher {
            warmup: Duration::from_millis(5),
            min_time: Duration::from_millis(20),
            min_samples: 5,
            max_samples: 1000,
        };
        let r = b.bench_val(|| (0..100).sum::<u64>());
        assert!(r.ns.n >= 5);
        assert!(r.ns.median >= 0.0);
    }

    #[test]
    fn json_report_format() {
        let mut rep = Reporter::new("group \"quoted\"").slug("testgrp");
        let b = Bencher {
            warmup: Duration::from_millis(1),
            min_time: Duration::from_millis(5),
            min_samples: 3,
            max_samples: 100,
        };
        rep.report("entry_one", b.bench_val(|| (0..10).sum::<u64>()));
        rep.note("a note with \"quotes\"");
        let dir = std::env::temp_dir().join(format!("dlt_benchkit_{}", std::process::id()));
        rep.write_json(dir.to_str().unwrap()).unwrap();
        let content = std::fs::read_to_string(dir.join("BENCH_testgrp.json")).unwrap();
        assert!(content.contains("\"group\": \"group \\\"quoted\\\"\""), "{content}");
        assert!(content.contains("\"name\": \"entry_one\""));
        assert!(content.contains("median_ns"));
        assert!(content.contains("a note with \\\"quotes\\\""));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn slug_sanitization() {
        assert_eq!(sanitize_slug("Solver Backends (v2)"), "solver_backends__v2_");
    }

    #[test]
    fn fmt_ns_ranges() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.2e9), "3.20 s");
    }
}
