//! Monetary cost (paper eq. 17) and the finish-time gradient (eq. 18).

use crate::dlt::Schedule;
use crate::model::SystemSpec;

/// Total monetary cost of a schedule:
/// `Cost_total = Σ_i Σ_j β_{i,j} · A_j · C_j` (eq. 17).
pub fn schedule_cost(spec: &SystemSpec, sched: &Schedule) -> f64 {
    let a = spec.a();
    let c = spec.cost_rates();
    let mut total = 0.0;
    for j in 0..sched.m {
        total += sched.load_on_processor(j) * a[j] * c[j];
    }
    total
}

/// Gradient of the finish time when going from `m−1` to `m` processors
/// (eq. 18): `(T_f(m) − T_f(m−1)) / T_f(m−1)`. Negative values mean
/// the extra processor helped.
pub fn tf_gradient(tf_m: f64, tf_m_minus_1: f64) -> f64 {
    (tf_m - tf_m_minus_1) / tf_m_minus_1
}

/// Gradient series over a finish-time sweep indexed by processor count
/// (entry `k` is the gradient of going from `k` to `k+1` processors,
/// 0-based over the input slice).
pub fn gradient_series(tf: &[f64]) -> Vec<f64> {
    tf.windows(2).map(|w| tf_gradient(w[1], w[0])).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dlt::frontend::FeOptions;
    use crate::dlt::Schedule;
    use crate::model::SystemSpec;

    fn fe_solve(spec: &SystemSpec) -> Schedule {
        crate::pipeline::solve(&FeOptions::default(), spec).unwrap()
    }

    fn priced_spec(m: usize) -> SystemSpec {
        let ac: Vec<(f64, f64)> =
            (0..m).map(|k| (1.1 + 0.1 * k as f64, 29.0 - k as f64)).collect();
        SystemSpec::builder()
            .source(0.5, 2.0)
            .source(0.6, 3.0)
            .priced_processors(&ac)
            .job(100.0)
            .build()
            .unwrap()
    }

    #[test]
    fn cost_is_positive_and_bounded() {
        let spec = priced_spec(5);
        let s = fe_solve(&spec);
        let cost = schedule_cost(&spec, &s);
        assert!(cost > 0.0);
        // Upper bound: all load on the most expensive processor-time.
        let max_rate = spec
            .processors
            .iter()
            .map(|p| p.a * p.cost_rate)
            .fold(0.0f64, f64::max);
        assert!(cost <= 100.0 * max_rate + 1e-9);
    }

    #[test]
    fn cost_zero_when_free() {
        let spec = SystemSpec::builder()
            .source(0.5, 0.0)
            .processors(&[1.0, 2.0])
            .job(10.0)
            .build()
            .unwrap();
        let s = fe_solve(&spec);
        assert_eq!(schedule_cost(&spec, &s), 0.0);
    }

    #[test]
    fn gradient_math() {
        assert!((tf_gradient(90.0, 100.0) + 0.10).abs() < 1e-12);
        let g = gradient_series(&[100.0, 80.0, 70.0]);
        assert_eq!(g.len(), 2);
        assert!((g[0] + 0.2).abs() < 1e-12);
        assert!((g[1] + 0.125).abs() < 1e-12);
    }

    #[test]
    fn cost_grows_with_more_processors() {
        // Paper Fig. 16: total cost grows with processor count (with
        // decreasing rate). Check monotonicity on the paper's params.
        let mut prev = 0.0;
        for m in 1..=8 {
            let spec = priced_spec(m);
            let s = fe_solve(&spec);
            let cost = schedule_cost(&spec, &s);
            assert!(cost >= prev - 1e-6, "m={m}: {cost} < {prev}");
            prev = cost;
        }
    }
}
