//! §6.2–6.4 — the trade-off advisor.
//!
//! Sweeps the number of processors `m = 1..M`, solving the schedule and
//! computing `(T_f(m), Cost(m))`, then answers the paper's three user
//! questions:
//!
//! - **cost budget** (§6.2): largest feasible `m` under the budget,
//!   then walk back while the finish-time gradient is below the
//!   user's "not worth it" threshold (paper example: 6 %).
//! - **time budget** (§6.3): smallest `m` with `T_f(m) ≤ budget`
//!   (cheapest solution that meets the deadline).
//! - **both** (§6.4): the overlap of the two solution areas, or a
//!   report that no solution exists (paper Fig. 19 / Fig. 20).

use crate::api::{Family, Session, Solver, SolveRequest};
use crate::cost::model::{gradient_series, schedule_cost};
use crate::error::Result;
use crate::model::SystemSpec;

/// One row of the trade-off sweep.
#[derive(Debug, Clone)]
pub struct TradeoffPoint {
    /// Number of processors used (prefix of the sorted list).
    pub m: usize,
    /// Optimal finish time with `m` processors.
    pub tf: f64,
    /// Total monetary cost (eq. 17).
    pub cost: f64,
}

/// The full sweep plus gradients.
#[derive(Debug, Clone)]
pub struct TradeoffTable {
    /// Points for `m = 1..=M`.
    pub points: Vec<TradeoffPoint>,
    /// `gradient[k]` = relative change of `T_f` from `m=k+1` to `m=k+2`.
    pub gradients: Vec<f64>,
}

impl TradeoffTable {
    /// Sweep `m = 1..=spec.m()` with the front-end solver (the paper's
    /// §6 simulations all use the front-end network).
    pub fn sweep(spec: &SystemSpec) -> Result<TradeoffTable> {
        Self::sweep_session(spec, &mut Solver::new().build())
    }

    /// Sweep through an api [`Session`]: repeated sweeps (the advisor
    /// is queried many times per session, and Figs. 19/20 each
    /// re-sweep Table 5) warm-start every `m`'s LP from the previous
    /// sweep's optimal basis for that shape, and the session's
    /// cross-shape projection seeds the `m+1`-processor LP from the
    /// `m`-processor basis within one sweep.
    pub fn sweep_session(spec: &SystemSpec, session: &mut Session) -> Result<TradeoffTable> {
        let mut points = Vec::with_capacity(spec.m());
        for m in 1..=spec.m() {
            let sub = spec.with_m_processors(m);
            let resp = session
                .solve(&SolveRequest::new(Family::Frontend, sub.clone()))
                .map_err(|e| e.into_error())?;
            let sched = resp.schedule();
            points.push(TradeoffPoint {
                m,
                tf: resp.makespan,
                cost: schedule_cost(&sub, &sched),
            });
        }
        let tf: Vec<f64> = points.iter().map(|p| p.tf).collect();
        Ok(TradeoffTable { points, gradients: gradient_series(&tf) })
    }

    /// Point for a given `m` (1-based).
    pub fn at(&self, m: usize) -> &TradeoffPoint {
        &self.points[m - 1]
    }
}

/// User budgets. `None` means unconstrained.
#[derive(Debug, Clone, Copy, Default)]
pub struct Budgets {
    /// Maximum money the user will spend.
    pub cost: Option<f64>,
    /// Maximum acceptable finish time.
    pub time: Option<f64>,
    /// "Not worth another processor" gradient threshold (e.g. 0.06 for
    /// the paper's 6 %). Only used with a cost budget.
    pub gradient_threshold: f64,
}

/// Advisor outcome.
#[derive(Debug, Clone, PartialEq)]
pub enum Advice {
    /// Use exactly this many processors.
    Use { m: usize, tf: f64, cost: f64 },
    /// A whole range satisfies both budgets (Fig. 19): any `m` in
    /// `lo..=hi` works; `recommended` minimizes cost (i.e. `lo`).
    Range { lo: usize, hi: usize, recommended: usize },
    /// No feasible processor count (Fig. 20): report the closest
    /// misses so the user can relax a budget.
    Infeasible {
        /// Cheapest cost achievable within the time budget, if any m
        /// meets the deadline at all.
        min_cost_meeting_time: Option<f64>,
        /// Fastest finish achievable within the cost budget, if any m
        /// is affordable at all.
        min_time_within_cost: Option<f64>,
    },
}

/// First index `k` such that the improvement `-gradients[k]` falls
/// below `threshold` — the §6.2 "knee": the step from point `k` to
/// point `k+1` is the first not worth taking, so the knee of the
/// trade-off curve lies inside that interval. `None` when every step
/// still clears the threshold. [`crate::experiments::sweep::refine`]
/// uses this to pick the bracket it subdivides.
pub fn knee_interval(gradients: &[f64], threshold: f64) -> Option<usize> {
    gradients.iter().position(|&g| -g < threshold)
}

/// Run the advisor against a sweep.
pub fn advise(table: &TradeoffTable, budgets: &Budgets) -> Advice {
    let pts = &table.points;
    match (budgets.cost, budgets.time) {
        (Some(cb), None) => {
            // §6.2: all m with cost <= budget are candidates; prefer the
            // largest, then walk back while the *next* processor's
            // improvement was below the threshold.
            let mut best: Option<usize> = None;
            for p in pts {
                if p.cost <= cb {
                    best = Some(p.m);
                }
            }
            let Some(mut m) = best else {
                return Advice::Infeasible {
                    min_cost_meeting_time: None,
                    min_time_within_cost: None,
                };
            };
            // gradients[k] is the improvement from m=k+1 to m=k+2; going
            // from m-1 to m is gradients[m-2].
            while m >= 2 {
                let grad = table.gradients[m - 2];
                if -grad < budgets.gradient_threshold {
                    m -= 1;
                } else {
                    break;
                }
            }
            let p = table.at(m);
            Advice::Use { m, tf: p.tf, cost: p.cost }
        }
        (None, Some(tb)) => {
            // §6.3: smallest m meeting the deadline (cost grows with m).
            for p in pts {
                if p.tf <= tb {
                    return Advice::Use { m: p.m, tf: p.tf, cost: p.cost };
                }
            }
            Advice::Infeasible { min_cost_meeting_time: None, min_time_within_cost: None }
        }
        (Some(cb), Some(tb)) => {
            // §6.4: intersection of the two solution areas.
            let feas: Vec<&TradeoffPoint> =
                pts.iter().filter(|p| p.cost <= cb && p.tf <= tb).collect();
            if feas.is_empty() {
                let min_cost_meeting_time = pts
                    .iter()
                    .filter(|p| p.tf <= tb)
                    .map(|p| p.cost)
                    .fold(None, |acc: Option<f64>, c| Some(acc.map_or(c, |a| a.min(c))));
                let min_time_within_cost = pts
                    .iter()
                    .filter(|p| p.cost <= cb)
                    .map(|p| p.tf)
                    .fold(None, |acc: Option<f64>, t| Some(acc.map_or(t, |a| a.min(t))));
                return Advice::Infeasible { min_cost_meeting_time, min_time_within_cost };
            }
            let lo = feas.iter().map(|p| p.m).min().unwrap();
            let hi = feas.iter().map(|p| p.m).max().unwrap();
            if lo == hi {
                let p = table.at(lo);
                Advice::Use { m: lo, tf: p.tf, cost: p.cost }
            } else {
                Advice::Range { lo, hi, recommended: lo }
            }
        }
        (None, None) => {
            // Unconstrained: fastest system.
            let p = pts.iter().min_by(|a, b| a.tf.partial_cmp(&b.tf).unwrap()).unwrap();
            Advice::Use { m: p.m, tf: p.tf, cost: p.cost }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Table 5 parameters.
    fn table5_spec() -> SystemSpec {
        let ac: Vec<(f64, f64)> =
            (0..20).map(|k| (1.1 + 0.1 * k as f64, 29.0 - k as f64)).collect();
        SystemSpec::builder()
            .source(0.5, 2.0)
            .source(0.6, 3.0)
            .priced_processors(&ac)
            .job(100.0)
            .build()
            .unwrap()
    }

    #[test]
    fn sweep_shapes() {
        let t = TradeoffTable::sweep(&table5_spec()).unwrap();
        assert_eq!(t.points.len(), 20);
        assert_eq!(t.gradients.len(), 19);
        // T_f non-increasing; cost non-decreasing while processors still
        // matter (paper Figs. 16–17). At the far tail the LP may shift a
        // sliver of load to a cheaper processor, so allow a tiny dip.
        for w in t.points.windows(2) {
            assert!(w[1].tf <= w[0].tf + 1e-6);
            assert!(w[1].cost >= w[0].cost - 1.0, "{} -> {}", w[0].cost, w[1].cost);
        }
        // Paper anchor values (Fig. 16): Cost(6)=3433.77, Cost(7)=3451.67.
        assert!((t.at(6).cost - 3433.77).abs() < 0.5, "cost(6)={}", t.at(6).cost);
        assert!((t.at(7).cost - 3451.67).abs() < 0.5, "cost(7)={}", t.at(7).cost);
    }

    #[test]
    fn cost_budget_advice() {
        let t = TradeoffTable::sweep(&table5_spec()).unwrap();
        let advice = advise(
            &t,
            &Budgets { cost: Some(3450.0), time: None, gradient_threshold: 0.06 },
        );
        // Paper §6.2: budget 3450 admits m<=6; the 6% gradient rule
        // walks back to m=5.
        match advice {
            Advice::Use { m, .. } => assert_eq!(m, 5, "paper recommends 5 processors"),
            other => panic!("unexpected advice {other:?}"),
        }
    }

    #[test]
    fn time_budget_advice_picks_cheapest() {
        let t = TradeoffTable::sweep(&table5_spec()).unwrap();
        let tb = t.at(10).tf + 1e-9; // deadline exactly at m=10's T_f
        let advice = advise(&t, &Budgets { cost: None, time: Some(tb), gradient_threshold: 0.0 });
        match advice {
            Advice::Use { m, .. } => assert_eq!(m, 10, "paper §6.3 picks the smallest m"),
            other => panic!("unexpected advice {other:?}"),
        }
    }

    #[test]
    fn both_budgets_overlap_gives_range() {
        let t = TradeoffTable::sweep(&table5_spec()).unwrap();
        // Budgets spanning m in [6, 12] (Fig. 19).
        let cb = t.at(12).cost + 1e-9;
        let tb = t.at(6).tf + 1e-9;
        let advice =
            advise(&t, &Budgets { cost: Some(cb), time: Some(tb), gradient_threshold: 0.0 });
        match advice {
            Advice::Range { lo, hi, recommended } => {
                assert_eq!((lo, hi), (6, 12));
                assert_eq!(recommended, 6);
            }
            other => panic!("unexpected advice {other:?}"),
        }
    }

    #[test]
    fn both_budgets_disjoint_is_infeasible() {
        let t = TradeoffTable::sweep(&table5_spec()).unwrap();
        // Cost budget only allows m<=4 but deadline needs m>=10.
        let cb = t.at(4).cost + 1e-9;
        let tb = t.at(10).tf + 1e-9;
        let advice =
            advise(&t, &Budgets { cost: Some(cb), time: Some(tb), gradient_threshold: 0.0 });
        match advice {
            Advice::Infeasible { min_cost_meeting_time, min_time_within_cost } => {
                assert!(min_cost_meeting_time.unwrap() > cb);
                assert!(min_time_within_cost.unwrap() > tb);
            }
            other => panic!("unexpected advice {other:?}"),
        }
    }

    #[test]
    fn unconstrained_picks_fastest() {
        let t = TradeoffTable::sweep(&table5_spec()).unwrap();
        match advise(&t, &Budgets::default()) {
            Advice::Use { m, .. } => assert_eq!(m, 20),
            other => panic!("unexpected advice {other:?}"),
        }
    }

    #[test]
    fn knee_interval_finds_first_below_threshold_step() {
        // Improvements of 10%, 8%, 3%, 1%: with a 6% threshold the
        // knee is inside the third interval (index 2).
        let grads = [-0.10, -0.08, -0.03, -0.01];
        assert_eq!(knee_interval(&grads, 0.06), Some(2));
        assert_eq!(knee_interval(&grads, 0.005), None);
        assert_eq!(knee_interval(&grads, 0.5), Some(0));
        assert_eq!(knee_interval(&[], 0.06), None);
        // Consistency with the advisor's §6.2 walk-back on Table 5: the
        // paper's m=5→6 step is below 6% (that is why advise() stops at
        // m=5), so the first below-threshold interval is no later.
        let t = TradeoffTable::sweep(&table5_spec()).unwrap();
        let k = knee_interval(&t.gradients, 0.06).expect("Table 5 has a 6% knee");
        assert!(k <= 4, "knee interval {k} must not be after the m=5->6 step");
        assert!(-t.gradients[k] < 0.06);
    }

    #[test]
    fn impossible_cost_budget() {
        let t = TradeoffTable::sweep(&table5_spec()).unwrap();
        let advice =
            advise(&t, &Budgets { cost: Some(0.01), time: None, gradient_threshold: 0.06 });
        assert!(matches!(advice, Advice::Infeasible { .. }));
    }
}
