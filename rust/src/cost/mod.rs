//! §6 — monetary cost model and the cost/time trade-off advisor.

pub mod advisor;
pub mod model;

pub use advisor::{advise, knee_interval, Advice, Budgets, TradeoffPoint, TradeoffTable};
pub use model::{gradient_series, schedule_cost, tf_gradient};
