//! Crate-wide error type.
//!
//! `Display`/`Error` are implemented by hand: the offline crate set has
//! no `thiserror`, and keeping the crate dependency-free means
//! `cargo build` needs nothing but the toolchain.

use std::fmt;

/// Unified error type for the `dlt` crate.
#[derive(Debug)]
pub enum Error {
    /// A system specification failed validation.
    InvalidSpec(String),

    /// The LP was infeasible (e.g. release times violate eq. 3).
    Infeasible(String),

    /// The LP was unbounded — indicates a malformed formulation.
    Unbounded(String),

    /// The solver hit its iteration limit before converging.
    IterationLimit {
        /// Iterations performed before giving up.
        iterations: usize,
    },

    /// Numerical trouble (singular matrix, NaN in the tableau, ...).
    Numerical(String),

    /// The solve's wall-clock budget expired before convergence; the
    /// partial progress made is carried for diagnostics.
    DeadlineExceeded {
        /// Milliseconds elapsed when the budget check fired.
        elapsed_ms: u64,
        /// Iterations (pivots / first-order steps) completed.
        iterations: usize,
        /// Which stage of the solve expired (`simplex`, `dual_simplex`,
        /// `dense_tableau`, `pdhg`, `recovery`, `serve_queue`, ...).
        phase: String,
    },

    /// A schedule failed post-hoc validation against the timing model.
    InvalidSchedule(String),

    /// Configuration / JSON parse problems.
    Config(String),

    /// CLI usage problems.
    Usage(String),

    /// Artifact missing / malformed / shape mismatch.
    Artifact(String),

    /// Errors bubbling up from the XLA/PJRT runtime.
    Runtime(String),

    /// Cluster runtime failure (actor panicked, channel closed, ...).
    Cluster(String),

    /// The serving tier shed the request at admission (queue full).
    Overloaded {
        /// Suggested client back-off before retrying, in milliseconds.
        retry_after_ms: u64,
    },

    /// A batch/sweep worker panicked while solving this item; the
    /// other items in the batch are unaffected.
    WorkerPanicked(String),

    /// I/O errors with path context.
    Io {
        /// Path the operation failed on.
        path: String,
        /// Underlying I/O error.
        source: std::io::Error,
    },
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::InvalidSpec(s) => write!(f, "invalid system spec: {s}"),
            Error::Infeasible(s) => write!(f, "linear program infeasible: {s}"),
            Error::Unbounded(s) => write!(f, "linear program unbounded: {s}"),
            Error::IterationLimit { iterations } => {
                write!(f, "solver iteration limit reached after {iterations} iterations")
            }
            Error::Numerical(s) => write!(f, "numerical error: {s}"),
            Error::DeadlineExceeded { elapsed_ms, iterations, phase } => {
                write!(
                    f,
                    "deadline exceeded after {elapsed_ms} ms in {phase} \
                     ({iterations} iterations)"
                )
            }
            Error::InvalidSchedule(s) => write!(f, "schedule validation failed: {s}"),
            Error::Config(s) => write!(f, "config error: {s}"),
            Error::Usage(s) => write!(f, "usage error: {s}"),
            Error::Artifact(s) => write!(f, "artifact error: {s}"),
            Error::Runtime(s) => write!(f, "runtime error: {s}"),
            Error::Cluster(s) => write!(f, "cluster error: {s}"),
            Error::Overloaded { retry_after_ms } => {
                write!(f, "server overloaded: retry after {retry_after_ms}ms")
            }
            Error::WorkerPanicked(s) => write!(f, "worker panicked: {s}"),
            Error::Io { path, source } => write!(f, "io error on {path}: {source}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl Error {
    /// Helper to wrap an I/O error with its path.
    pub fn io(path: impl Into<String>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_previous_formats() {
        assert_eq!(
            Error::Infeasible("x".into()).to_string(),
            "linear program infeasible: x"
        );
        assert_eq!(
            Error::IterationLimit { iterations: 7 }.to_string(),
            "solver iteration limit reached after 7 iterations"
        );
        let io = Error::io("f.json", std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        assert!(io.to_string().starts_with("io error on f.json:"));
        assert_eq!(
            Error::Overloaded { retry_after_ms: 50 }.to_string(),
            "server overloaded: retry after 50ms"
        );
        assert_eq!(
            Error::WorkerPanicked("boom".into()).to_string(),
            "worker panicked: boom"
        );
        assert_eq!(
            Error::DeadlineExceeded { elapsed_ms: 12, iterations: 34, phase: "simplex".into() }
                .to_string(),
            "deadline exceeded after 12 ms in simplex (34 iterations)"
        );
    }

    #[test]
    fn io_source_is_chained() {
        use std::error::Error as _;
        let e = super::Error::io("p", std::io::Error::new(std::io::ErrorKind::Other, "inner"));
        assert!(e.source().is_some());
        assert!(super::Error::Usage("u".into()).source().is_none());
    }
}
