//! Crate-wide error type.

use thiserror::Error;

/// Unified error type for the `dlt` crate.
#[derive(Error, Debug)]
pub enum Error {
    /// A system specification failed validation.
    #[error("invalid system spec: {0}")]
    InvalidSpec(String),

    /// The LP was infeasible (e.g. release times violate eq. 3).
    #[error("linear program infeasible: {0}")]
    Infeasible(String),

    /// The LP was unbounded — indicates a malformed formulation.
    #[error("linear program unbounded: {0}")]
    Unbounded(String),

    /// The solver hit its iteration limit before converging.
    #[error("solver iteration limit reached after {iterations} iterations")]
    IterationLimit { iterations: usize },

    /// Numerical trouble (singular matrix, NaN in the tableau, ...).
    #[error("numerical error: {0}")]
    Numerical(String),

    /// A schedule failed post-hoc validation against the timing model.
    #[error("schedule validation failed: {0}")]
    InvalidSchedule(String),

    /// Configuration / JSON parse problems.
    #[error("config error: {0}")]
    Config(String),

    /// CLI usage problems.
    #[error("usage error: {0}")]
    Usage(String),

    /// Artifact missing / malformed / shape mismatch.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// Errors bubbling up from the XLA/PJRT runtime.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Cluster runtime failure (actor panicked, channel closed, ...).
    #[error("cluster error: {0}")]
    Cluster(String),

    /// I/O errors with path context.
    #[error("io error on {path}: {source}")]
    Io {
        path: String,
        #[source]
        source: std::io::Error,
    },
}

impl Error {
    /// Helper to wrap an I/O error with its path.
    pub fn io(path: impl Into<String>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
