//! # `dlt` — Multi-Source Multi-Processor Divisible-Load Scheduling
//!
//! A production-shaped reproduction of *"Scheduling and Trade-off Analysis
//! for Multi-Source Multi-Processor Systems with Divisible Loads"*
//! (Cao, Wu, Robertazzi, 2019).
//!
//! The crate is organized bottom-up:
//!
//! - [`util`], [`linalg`] — numeric substrates (PRNG, stats, dense +
//!   sparse-CSC linear algebra, reusable LU factors).
//! - [`lp`] — a from-scratch simplex solver: sparse revised simplex
//!   with basis warm starts by default, the dense two-phase tableau as
//!   fallback; every scheduling problem in the paper is solved
//!   through it.
//! - [`model`] — the system specification (sources `G_i`/`R_i`,
//!   processors `A_j`/`C_j`, job `J`).
//! - [`dlt`] — the paper's scheduling formulations: §2 single-source
//!   closed form, §3.1 multi-source with front-ends, §3.2 without
//!   front-ends; schedule extraction and validation.
//! - [`pipeline`] — the unified solve pipeline: every scheduling
//!   family implements [`pipeline::ScenarioModel`] and flows through
//!   `build LP → presolve → backend → warm cache → schedule`.
//! - [`cost`], [`speedup`] — §6 monetary-cost/trade-off analysis and
//!   §5 Amdahl-style speedup analysis.
//! - [`sim`] — a deterministic discrete-event simulator that *executes*
//!   schedules and independently measures the realized makespan.
//! - [`cluster`] — a threaded in-process cluster runtime whose
//!   processors perform real compute via AOT-compiled XLA artifacts.
//! - [`runtime`], [`pdhg`] — the PJRT artifact runtime and the
//!   first-order (PDHG) LP solving path compiled from JAX + Pallas.
//! - [`config`], [`cli`], [`benchkit`], [`testkit`], [`experiments`] —
//!   framework glue: JSON config, CLI, bench harness, property-test
//!   harness, and the paper's experiment registry.
//!
//! ## Quickstart
//!
//! ```
//! use dlt::model::SystemSpec;
//! use dlt::dlt::frontend;
//!
//! // Table 1 of the paper: 2 sources, 5 processors, J = 100.
//! let spec = SystemSpec::builder()
//!     .source(0.2, 10.0)
//!     .source(0.4, 50.0)
//!     .processors(&[2.0, 3.0, 4.0, 5.0, 6.0])
//!     .job(100.0)
//!     .build()
//!     .unwrap();
//! let sched = frontend::solve(&spec).unwrap();
//! assert!(sched.makespan > 0.0);
//! let total: f64 = sched.beta.iter().sum();
//! assert!((total - 100.0).abs() < 1e-6);
//! ```

pub mod benchkit;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod cost;
pub mod dlt;
pub mod error;
pub mod experiments;
pub mod linalg;
pub mod lp;
pub mod model;
pub mod pdhg;
pub mod pipeline;
pub mod runtime;
pub mod sim;
pub mod speedup;
pub mod testkit;
pub mod util;

pub use error::{Error, Result};
