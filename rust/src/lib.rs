//! # `dlt` — Multi-Source Multi-Processor Divisible-Load Scheduling
//!
//! A production-shaped reproduction of *"Scheduling and Trade-off Analysis
//! for Multi-Source Multi-Processor Systems with Divisible Loads"*
//! (Cao, Wu, Robertazzi, 2019).
//!
//! The crate is organized bottom-up:
//!
//! - [`util`], [`linalg`] — numeric substrates (PRNG, stats, dense +
//!   sparse-CSC linear algebra, reusable LU factors, and the
//!   [`linalg::SparseVector`] work arrays behind the hypersparse
//!   simplex kernels).
//! - [`lp`] — a from-scratch simplex solver: sparse revised simplex
//!   with basis warm starts and hypersparse FTRAN/BTRAN by default,
//!   the dense two-phase tableau as fallback; its basis-factorization
//!   ([`lp::Factorization`]: product-form eta or sparse Forrest–Tomlin
//!   LU updates) and pricing ([`lp::Pricing`]: Dantzig, devex,
//!   steepest edge, candidate-list partial) policies are pluggable
//!   strategy layers selected per solve, and per-worker
//!   [`lp::SolverScratch`] pools make repeated warm solves
//!   allocation-free; every scheduling problem in the paper is solved
//!   through it.
//! - [`model`] — the system specification (sources `G_i`/`R_i`,
//!   processors `A_j`/`C_j`, job `J`).
//! - [`dlt`] — the paper's scheduling formulations: §2 single-source
//!   closed form, §3.1 multi-source with front-ends, §3.2 without
//!   front-ends; schedule extraction and validation.
//! - [`pipeline`] — the unified solve pipeline: every scheduling
//!   family implements [`pipeline::ScenarioModel`] and flows through
//!   `build LP → presolve → backend → warm cache → schedule`, with
//!   the backend ([`pipeline::Backend`]) selectable per solve:
//!   revised simplex, dense tableau, sparse PDHG, batched block PDHG,
//!   or the hybrid PDHG-then-crossover-then-simplex path that is
//!   exact at vertex precision.
//! - [`api`] — **the public facade**: typed JSON-serializable
//!   [`api::SolveRequest`]/[`api::SolveResponse`] wire structs, a
//!   [`api::Solver`] builder producing warm [`api::Session`]s, and
//!   work-stealing [`api::Session::solve_batch`] — what the CLI,
//!   sweeps, advisor, speedup analysis and any future network server
//!   all call.
//! - [`cost`], [`speedup`] — §6 monetary-cost/trade-off analysis
//!   (including the [`cost::knee_interval`] diminishing-returns rule
//!   shared with the sweep refiner) and §5 Amdahl-style speedup
//!   analysis (both routed through [`api`]).
//! - [`serve`] — the zero-dependency TCP serving tier over [`api`]:
//!   thread-per-core workers, client-keyed session shards with LRU
//!   warm-cache eviction, bounded admission queues with overload
//!   fast-reject, and streamed per-item responses (`dlt serve`).
//! - [`sim`] — deterministic discrete-event simulation: the
//!   component-based cluster engine (faults, preemption, time-varying
//!   links, zero-alloc at 10k-processor scale) plus the
//!   predicted-vs-simulated divergence oracle ([`sim::replay`]), with
//!   the legacy engine kept as a parity oracle.
//! - [`cluster`] — a threaded in-process cluster runtime whose
//!   processors perform real compute via AOT-compiled XLA artifacts.
//! - [`runtime`], [`pdhg`] — the PJRT artifact runtime and the
//!   first-order (PDHG) LP solving path: sparse-CSC O(nnz)/iteration
//!   in-process kernels, column-major block panels that solve many
//!   same-shaped scenarios per matrix pass ([`pdhg::solve_block`]),
//!   and the fixed-shape AOT artifact variant compiled from JAX +
//!   Pallas.
//! - [`config`], [`cli`], [`benchkit`], [`testkit`], [`experiments`] —
//!   framework glue: JSON config, CLI, bench harness, property-test
//!   harness, and the paper's experiment registry.
//!
//! ## Quickstart: builder → session → batch
//!
//! ```
//! use dlt::api::{Family, SolveRequest, Solver};
//! use dlt::model::SystemSpec;
//!
//! // Table 1 of the paper: 2 sources, 5 processors, J = 100.
//! let spec = SystemSpec::builder()
//!     .source(0.2, 10.0)
//!     .source(0.4, 50.0)
//!     .processors(&[2.0, 3.0, 4.0, 5.0, 6.0])
//!     .job(100.0)
//!     .build()
//!     .unwrap();
//!
//! // One session owns the warm solver state; repeated or perturbed
//! // requests skip simplex phase 1 automatically.
//! let mut session = Solver::new().build();
//! let resp = session.solve(&SolveRequest::new(Family::Frontend, spec.clone())).unwrap();
//! assert!(resp.makespan > 0.0);
//! let total: f64 = resp.beta.iter().sum();
//! assert!((total - 100.0).abs() < 1e-6);
//!
//! // Heterogeneous batches fan across work-stealing workers and come
//! // back in input order — this is what `dlt batch` serves.
//! let reqs: Vec<SolveRequest> = (1..=4)
//!     .map(|k| SolveRequest::new(Family::Frontend, spec.with_job(50.0 * k as f64)))
//!     .collect();
//! let out = Solver::new().threads(2).build().solve_batch(&reqs);
//! assert_eq!(out.len(), 4);
//! assert!(out.iter().all(|r| r.is_ok()));
//! ```

pub mod api;
pub mod benchkit;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod cost;
pub mod dlt;
pub mod error;
pub mod experiments;
pub mod linalg;
pub mod lp;
pub mod model;
pub mod pdhg;
pub mod pipeline;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod speedup;
pub mod testkit;
pub mod util;

pub use error::{Error, Result};
