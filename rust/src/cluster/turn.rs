//! Turn gate: enforces the paper's "a processor receives from one
//! source at a time, in source order" rule across source threads.

use std::sync::{Condvar, Mutex};

/// A monotone turn counter with blocking waits.
#[derive(Debug, Default)]
pub struct TurnGate {
    state: Mutex<usize>,
    cv: Condvar,
}

impl TurnGate {
    /// New gate at turn 0.
    pub fn new() -> TurnGate {
        TurnGate::default()
    }

    /// Block until it is `who`'s turn.
    pub fn wait_for(&self, who: usize) {
        let mut turn = self.state.lock().expect("turn gate poisoned");
        while *turn != who {
            turn = self.cv.wait(turn).expect("turn gate poisoned");
        }
    }

    /// Finish the current turn, waking waiters.
    pub fn advance(&self) {
        let mut turn = self.state.lock().expect("turn gate poisoned");
        *turn += 1;
        self.cv.notify_all();
    }

    /// Current turn (for diagnostics).
    pub fn current(&self) -> usize {
        *self.state.lock().expect("turn gate poisoned")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn turns_serialize_threads() {
        let gate = Arc::new(TurnGate::new());
        let order = Arc::new(Mutex::new(Vec::new()));
        let mut handles = Vec::new();
        // Spawn in reverse order to make a scheduling accident unlikely.
        for who in (0..4).rev() {
            let gate = gate.clone();
            let order = order.clone();
            handles.push(std::thread::spawn(move || {
                gate.wait_for(who);
                order.lock().unwrap().push(who);
                gate.advance();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(gate.current(), 4);
    }
}
