//! In-process cluster runtime: the schedule, executed for real.
//!
//! Threads play the roles of the paper's nodes: one thread per source
//! and one per processor, connected by channels. Transfers occupy real
//! (scaled) wall-clock time according to `β·G_i`; the paper's
//! sequential-communication rules are enforced with per-processor turn
//! locks; processors either *model* their compute (scaled sleep) or do
//! *real* compute through a work function — the e2e example plugs in
//! the AOT-compiled XLA workload artifact there.
//!
//! (The offline crate set has no `tokio`; this is a from-scratch
//! thread+channel actor runtime with an interface shaped like one.)

pub mod harness;
pub mod turn;

pub use harness::{run_cluster, ClusterConfig, ClusterReport, Compute};
pub use turn::TurnGate;
