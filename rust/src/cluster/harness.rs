//! The cluster harness: spawn sources and processors, execute a
//! schedule in scaled wall-clock time, measure the realized makespan.

use crate::dlt::schedule::{Schedule, TimingModel};
use crate::error::{Error, Result};
use crate::model::SystemSpec;
use crate::cluster::turn::TurnGate;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How processors burn compute time.
#[derive(Clone)]
pub enum Compute {
    /// Sleep `β · A_j · time_scale` (pure timing model).
    Modeled,
    /// Real work: `factory(j)` runs **inside** processor `j`'s thread
    /// (so it may create thread-local, non-`Send` state like a PJRT
    /// client) and returns the work function called once per received
    /// chunk with the chunk's load amount.
    Custom(Arc<dyn Fn(usize) -> Box<dyn FnMut(f64)> + Send + Sync>),
}

impl std::fmt::Debug for Compute {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Compute::Modeled => write!(f, "Compute::Modeled"),
            Compute::Custom(_) => write!(f, "Compute::Custom(..)"),
        }
    }
}

/// Cluster configuration.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Wall-clock seconds per model time unit.
    pub time_scale: f64,
    /// Compute implementation.
    pub compute: Compute,
    /// Front-end streaming granularity: each fraction is transmitted
    /// as this many sub-chunks so a front-end processor can start
    /// computing while the rest of the fraction is still in flight
    /// (approximates the paper's byte-level streaming). Ignored for
    /// the no-front-end model.
    pub fe_splits: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig { time_scale: 0.002, compute: Compute::Modeled, fe_splits: 16 }
    }
}

/// One chunk of load in flight.
#[derive(Debug, Clone, Copy)]
struct Chunk {
    #[allow(dead_code)] // diagnostic provenance
    source: usize,
    amount: f64,
}

/// Result of a cluster run.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    /// Schedule's predicted `T_f` (model units).
    pub predicted_makespan: f64,
    /// Measured makespan converted back to model units.
    pub realized_makespan: f64,
    /// Per-processor completion times (model units).
    pub proc_done: Vec<f64>,
    /// Per-processor total load processed.
    pub proc_load: Vec<f64>,
    /// Relative error of realized vs predicted.
    pub relative_error: f64,
    /// Wall-clock duration of the run.
    pub wall: Duration,
}

/// Execute `sched` on a real thread-per-node cluster.
///
/// Sources transmit their fractions sequentially (`P_1 → P_M`), each
/// transfer occupying `β·G_i·time_scale` seconds of wall time, gated so
/// a processor receives from one source at a time in source order.
/// Processors apply the schedule's timing model: with front-ends they
/// process each chunk as it arrives; without, they buffer everything
/// and compute at the end.
pub fn run_cluster(
    spec: &SystemSpec,
    sched: &Schedule,
    cfg: &ClusterConfig,
) -> Result<ClusterReport> {
    let n = spec.n();
    let m = spec.m();
    if sched.n != n || sched.m != m {
        return Err(Error::Cluster("schedule/spec shape mismatch".into()));
    }
    let scale = cfg.time_scale;
    let g = spec.g();
    let r = spec.releases();
    let a = spec.a();
    let model = sched.model;

    // Per-processor chunk channels and turn gates.
    let mut senders = Vec::with_capacity(m);
    let mut receivers = Vec::with_capacity(m);
    for _ in 0..m {
        let (tx, rx) = mpsc::channel::<Chunk>();
        senders.push(tx);
        receivers.push(rx);
    }
    let gates: Vec<Arc<TurnGate>> = (0..m).map(|_| Arc::new(TurnGate::new())).collect();
    let (report_tx, report_rx) = mpsc::channel::<(usize, f64, f64)>();

    // Sub-chunk streaming only matters for front-ends.
    let splits = match model {
        TimingModel::FrontEnd => cfg.fe_splits.max(1),
        TimingModel::NoFrontEnd => 1,
    };

    // Two-phase start: every node thread finishes its (possibly
    // expensive) setup — e.g. creating a PJRT client — and parks at
    // `ready`; main then stamps the epoch and releases `go`. Setup
    // cost never pollutes the measured makespan.
    let ready = Arc::new(std::sync::Barrier::new(n + m + 1));
    let go = Arc::new(std::sync::Barrier::new(n + m + 1));
    let epoch_cell: Arc<std::sync::OnceLock<Instant>> = Arc::new(std::sync::OnceLock::new());

    let mut handles = Vec::new();

    // Source threads.
    for i in 0..n {
        let senders = senders.clone();
        let gates = gates.clone();
        let beta_row: Vec<f64> = (0..m).map(|j| sched.beta(i, j)).collect();
        let (gi, ri) = (g[i], r[i]);
        let (ready, go, epoch_cell) = (ready.clone(), go.clone(), epoch_cell.clone());
        handles.push(
            std::thread::Builder::new()
                .name(format!("source-{i}"))
                .spawn(move || {
                    ready.wait();
                    go.wait();
                    let epoch = *epoch_cell.get().expect("epoch set before go");
                    // Honor the release time.
                    sleep_until(epoch, ri * scale);
                    for (j, &amount) in beta_row.iter().enumerate() {
                        // Paper rule: wait until P_j is ready to receive
                        // from this source (previous sources done). The
                        // gate is held for the whole fraction.
                        gates[j].wait_for(i);
                        let sub = amount / splits as f64;
                        for _ in 0..splits {
                            // Transfer occupies the link for sub*G_i.
                            precise_sleep(Duration::from_secs_f64(sub * gi * scale));
                            senders[j]
                                .send(Chunk { source: i, amount: sub })
                                .expect("proc hung up");
                        }
                        gates[j].advance();
                    }
                })
                .expect("spawn source"),
        );
    }
    drop(senders);

    // Processor threads.
    for (j, rx) in receivers.into_iter().enumerate() {
        let aj = a[j];
        let report_tx = report_tx.clone();
        let compute = cfg.compute.clone();
        let (ready, go, epoch_cell) = (ready.clone(), go.clone(), epoch_cell.clone());
        handles.push(
            std::thread::Builder::new()
                .name(format!("proc-{j}"))
                .spawn(move || {
                    let mut work: Box<dyn FnMut(f64)> = match &compute {
                        Compute::Modeled => Box::new(move |load: f64| {
                            precise_sleep(Duration::from_secs_f64(load * aj * scale));
                        }),
                        Compute::Custom(factory) => factory(j),
                    };
                    ready.wait();
                    go.wait();
                    let epoch = *epoch_cell.get().expect("epoch set before go");
                    let mut total = 0.0;
                    let mut received = 0;
                    let expected = n * splits;
                    while received < expected {
                        let chunk = rx.recv().expect("source hung up");
                        received += 1;
                        total += chunk.amount;
                        match model {
                            TimingModel::FrontEnd => {
                                if chunk.amount > 0.0 {
                                    work(chunk.amount);
                                }
                            }
                            TimingModel::NoFrontEnd => {} // buffer: compute at end
                        }
                    }
                    if model == TimingModel::NoFrontEnd && total > 0.0 {
                        work(total);
                    }
                    let done = epoch.elapsed().as_secs_f64() / scale;
                    report_tx.send((j, done, total)).expect("harness hung up");
                })
                .expect("spawn processor"),
        );
    }
    drop(report_tx);

    // Release the cluster and stamp the epoch.
    ready.wait();
    let epoch = Instant::now();
    epoch_cell.set(epoch).expect("epoch set once");
    go.wait();

    let mut proc_done = vec![0.0; m];
    let mut proc_load = vec![0.0; m];
    for _ in 0..m {
        let (j, done, load) = report_rx
            .recv()
            .map_err(|_| Error::Cluster("processor thread died".into()))?;
        proc_done[j] = done;
        proc_load[j] = load;
    }
    for h in handles {
        h.join().map_err(|_| Error::Cluster("node thread panicked".into()))?;
    }
    let wall = epoch.elapsed();

    let realized = proc_done.iter().fold(0.0f64, |acc, &x| acc.max(x));
    let predicted = sched.makespan;
    Ok(ClusterReport {
        predicted_makespan: predicted,
        realized_makespan: realized,
        relative_error: (realized - predicted) / predicted,
        proc_done,
        proc_load,
        wall,
    })
}

/// Sleep until `offset` seconds after `epoch`.
fn sleep_until(epoch: Instant, offset: f64) {
    let target = epoch + Duration::from_secs_f64(offset);
    let now = Instant::now();
    if target > now {
        precise_sleep(target - now);
    }
}

/// Sleep `d`. Plain `thread::sleep`: Linux nanosleep is accurate to
/// well under the time scales used here, and — unlike a spin tail —
/// it never steals the core from the other node threads (this harness
/// routinely runs M + N threads on few physical cores).
fn precise_sleep(d: Duration) {
    if d > Duration::ZERO {
        std::thread::sleep(d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dlt::frontend::FeOptions;
    use crate::dlt::no_frontend::NfeOptions;
    use crate::dlt::Schedule;
    use crate::model::SystemSpec;

    fn fe_solve(spec: &SystemSpec) -> Schedule {
        crate::pipeline::solve(&FeOptions::default(), spec).unwrap()
    }

    fn nfe_solve(spec: &SystemSpec) -> Schedule {
        crate::pipeline::solve(&NfeOptions::default(), spec).unwrap()
    }

    fn small_spec() -> SystemSpec {
        SystemSpec::builder()
            .source(0.2, 0.0)
            .source(0.2, 2.0)
            .processors(&[2.0, 3.0])
            .job(20.0)
            .build()
            .unwrap()
    }

    #[test]
    fn cluster_matches_nfe_prediction() {
        let spec = small_spec();
        let sched = nfe_solve(&spec);
        let cfg = ClusterConfig { time_scale: 0.002, compute: Compute::Modeled, ..Default::default() };
        let rep = run_cluster(&spec, &sched, &cfg).unwrap();
        assert!(
            rep.relative_error.abs() < 0.25,
            "realized {} vs predicted {} (err {:.1}%)",
            rep.realized_makespan,
            rep.predicted_makespan,
            rep.relative_error * 100.0
        );
        let total: f64 = rep.proc_load.iter().sum();
        assert!((total - 20.0).abs() < 1e-9);
    }

    #[test]
    fn cluster_matches_fe_prediction() {
        let spec = small_spec();
        let sched = fe_solve(&spec);
        // Front-end streaming sends 16 sub-chunks per fraction; keep
        // each sleep comfortably above scheduler granularity.
        let cfg = ClusterConfig { time_scale: 0.01, compute: Compute::Modeled, ..Default::default() };
        let rep = run_cluster(&spec, &sched, &cfg).unwrap();
        // FE realized can beat predicted (ASAP closes LP slack); bound
        // the error both ways generously — CI machines are noisy.
        assert!(
            rep.realized_makespan <= rep.predicted_makespan * 1.25,
            "realized {} vs predicted {}",
            rep.realized_makespan,
            rep.predicted_makespan
        );
    }

    #[test]
    fn custom_compute_runs_in_processor_thread() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let spec = small_spec();
        let sched = nfe_solve(&spec);
        let calls = Arc::new(AtomicUsize::new(0));
        let calls2 = calls.clone();
        let cfg = ClusterConfig {
            time_scale: 0.001,
            fe_splits: 16,
            compute: Compute::Custom(Arc::new(move |_j| {
                let calls = calls2.clone();
                Box::new(move |load: f64| {
                    assert!(load > 0.0);
                    calls.fetch_add(1, Ordering::Relaxed);
                })
            })),
        };
        let rep = run_cluster(&spec, &sched, &cfg).unwrap();
        // NFE: one work call per processor with load.
        assert_eq!(calls.load(Ordering::Relaxed), 2);
        assert!(rep.realized_makespan > 0.0);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let spec = small_spec();
        let sched = nfe_solve(&spec);
        let other = spec.with_m_processors(1);
        assert!(run_cluster(&other, &sched, &ClusterConfig::default()).is_err());
    }
}
