//! Wire types: typed, JSON-serializable requests, responses and
//! errors.
//!
//! Everything here round-trips through the zero-dependency
//! [`crate::config::json`] value type — `struct -> Json -> text ->
//! Json -> struct` is lossless (property-tested in
//! `tests/api_wire.rs`), and malformed input surfaces as
//! [`ApiError`]/[`crate::error::Error::Config`], never a panic. The
//! format is the serving contract: the `dlt batch` subcommand consumes
//! a JSON array of requests and emits a JSON array of
//! response-or-error objects in the same order.

use crate::config::json::Json;
use crate::config::spec::{spec_from_json, spec_to_json};
use crate::dlt::concurrent::Mode;
use crate::dlt::schedule::{Schedule, TimingModel};
use crate::error::{Error, Result};
use crate::lp::presolve::PresolveStats;
use crate::lp::{Factorization, Pricing};
use crate::model::SystemSpec;
use crate::pipeline::{Backend, PdhgDiagnostics};
use crate::sim::replay::DivergenceReport;

/// Which scheduling formulation a request asks for.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Family {
    /// §3.1 — processors with front-ends.
    Frontend,
    /// §3.2 — processors without front-ends.
    NoFrontend,
    /// §8 — concurrent (fluid) distribution under a bandwidth cap.
    Concurrent,
    /// §8 — one FIFO multi-job pipeline step (front-end LP with
    /// carried-over per-processor ready times).
    MultiJob,
}

/// All families, in wire order (handy for tests and sweeps).
pub const FAMILIES: [Family; 4] =
    [Family::Frontend, Family::NoFrontend, Family::Concurrent, Family::MultiJob];

impl Family {
    /// Stable wire name. Matches the family's
    /// [`crate::pipeline::ScenarioModel::name`].
    pub fn as_str(self) -> &'static str {
        match self {
            Family::Frontend => "frontend",
            Family::NoFrontend => "no_frontend",
            Family::Concurrent => "concurrent",
            Family::MultiJob => "multi_job",
        }
    }

    /// Parse a wire name.
    pub fn parse(s: &str) -> Result<Family> {
        match s {
            "frontend" => Ok(Family::Frontend),
            "no_frontend" => Ok(Family::NoFrontend),
            "concurrent" => Ok(Family::Concurrent),
            "multi_job" => Ok(Family::MultiJob),
            other => Err(Error::Config(format!(
                "unknown family `{other}` (expected frontend|no_frontend|concurrent|multi_job)"
            ))),
        }
    }

    /// Timing semantics of the family's schedules.
    pub fn timing_model(self) -> TimingModel {
        match self {
            Family::Frontend | Family::MultiJob => TimingModel::FrontEnd,
            Family::NoFrontend | Family::Concurrent => TimingModel::NoFrontEnd,
        }
    }
}

/// The paper-core family for a timing model (`fe` → frontend, `nfe` →
/// no-frontend) — the mapping the CLI's `--model` flag and the sweep
/// engine's [`TimingModel`]-tagged scenarios share. The §8 extension
/// families have no `TimingModel` of their own and are addressed by
/// name.
impl From<TimingModel> for Family {
    fn from(model: TimingModel) -> Family {
        match model {
            TimingModel::FrontEnd => Family::Frontend,
            TimingModel::NoFrontEnd => Family::NoFrontend,
        }
    }
}

fn mode_to_str(mode: Mode) -> &'static str {
    match mode {
        Mode::Proportional => "proportional",
        Mode::Staggered => "staggered",
    }
}

fn mode_from_str(s: &str) -> Result<Mode> {
    match s {
        "proportional" => Ok(Mode::Proportional),
        "staggered" => Ok(Mode::Staggered),
        other => Err(Error::Config(format!(
            "unknown concurrent mode `{other}` (expected proportional|staggered)"
        ))),
    }
}

/// Per-request option overrides. Every field is optional; `None`
/// inherits the session default (set through
/// [`crate::api::Solver`]'s builder methods).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RequestOptions {
    /// Backend override (`revised_simplex` | `dense_tableau` | `pdhg`
    /// | `pdhg_block` | `hybrid`).
    pub backend: Option<Backend>,
    /// Presolve override.
    pub presolve: Option<bool>,
    /// Basis-factorization override for the revised backend
    /// (`product_form_eta` | `forrest_tomlin` | `markowitz` |
    /// `bartels_golub`).
    pub factorization: Option<Factorization>,
    /// Pricing-rule override for the revised backend
    /// (`dantzig` | `devex` | `steepest_edge` | `partial`).
    pub pricing: Option<Pricing>,
    /// Simplex reduced-cost/pivot tolerance override.
    pub eps: Option<f64>,
    /// Simplex per-phase iteration cap override (`0` = auto).
    pub max_iters: Option<usize>,
    /// Concurrent-family fluid model (`proportional` | `staggered`).
    pub mode: Option<Mode>,
    /// Frontend-family eq. 5 summation variant.
    pub finish_sum_includes_j: Option<bool>,
    /// No-frontend-family eq. 12 relaxation.
    pub drop_source_busy: Option<bool>,
    /// Frontend / multi-job per-processor compute-ready times.
    pub proc_ready: Option<Vec<f64>>,
    /// PDHG residual tolerance override.
    pub pdhg_tol: Option<f64>,
    /// PDHG block-count cap override.
    pub pdhg_max_blocks: Option<usize>,
    /// Wall-clock deadline for the whole solve, in milliseconds. On
    /// expiry the pipeline returns a typed `deadline_exceeded` error
    /// (or, when the serving tier runs in degraded mode, a loosened
    /// answer flagged `degraded: true`). `None` = unbounded.
    pub timeout_ms: Option<u64>,
}

impl RequestOptions {
    /// Encode as a JSON object (only the overridden fields appear).
    pub fn to_json(&self) -> Json {
        let mut kv: Vec<(String, Json)> = Vec::new();
        if let Some(b) = self.backend {
            kv.push(("backend".into(), Json::Str(b.as_str().into())));
        }
        if let Some(p) = self.presolve {
            kv.push(("presolve".into(), Json::Bool(p)));
        }
        if let Some(f) = self.factorization {
            kv.push(("factorization".into(), Json::Str(f.as_str().into())));
        }
        if let Some(p) = self.pricing {
            kv.push(("pricing".into(), Json::Str(p.as_str().into())));
        }
        if let Some(e) = self.eps {
            kv.push(("eps".into(), Json::Num(e)));
        }
        if let Some(i) = self.max_iters {
            kv.push(("max_iters".into(), Json::Num(i as f64)));
        }
        if let Some(m) = self.mode {
            kv.push(("mode".into(), Json::Str(mode_to_str(m).into())));
        }
        if let Some(f) = self.finish_sum_includes_j {
            kv.push(("finish_sum_includes_j".into(), Json::Bool(f)));
        }
        if let Some(d) = self.drop_source_busy {
            kv.push(("drop_source_busy".into(), Json::Bool(d)));
        }
        if let Some(r) = &self.proc_ready {
            kv.push(("proc_ready".into(), Json::Array(r.iter().map(|&x| Json::Num(x)).collect())));
        }
        if let Some(t) = self.pdhg_tol {
            kv.push(("pdhg_tol".into(), Json::Num(t)));
        }
        if let Some(b) = self.pdhg_max_blocks {
            kv.push(("pdhg_max_blocks".into(), Json::Num(b as f64)));
        }
        if let Some(t) = self.timeout_ms {
            kv.push(("timeout_ms".into(), Json::Num(t as f64)));
        }
        Json::Object(kv)
    }

    /// Decode from a JSON object. Strict: a non-object value or an
    /// unknown key is `Error::Config` — a misspelled override must
    /// fail loudly, not silently solve with the defaults.
    pub fn from_json(v: &Json) -> Result<RequestOptions> {
        const KNOWN: [&str; 13] = [
            "backend",
            "presolve",
            "factorization",
            "pricing",
            "eps",
            "max_iters",
            "mode",
            "finish_sum_includes_j",
            "drop_source_busy",
            "proc_ready",
            "pdhg_tol",
            "pdhg_max_blocks",
            "timeout_ms",
        ];
        let Json::Object(kv) = v else {
            return Err(Error::Config(format!("options must be an object, got {v:?}")));
        };
        if let Some((k, _)) = kv.iter().find(|(k, _)| !KNOWN.contains(&k.as_str())) {
            return Err(Error::Config(format!("unknown option key `{k}`")));
        }
        let mut o = RequestOptions::default();
        if let Some(b) = v.get("backend") {
            let s = b.as_str()?;
            o.backend = Some(Backend::parse(s).ok_or_else(|| {
                Error::Config(format!(
                    "unknown backend `{s}` (expected \
                     revised_simplex|dense_tableau|pdhg|pdhg_block|hybrid)"
                ))
            })?);
        }
        if let Some(p) = v.get("presolve") {
            o.presolve = Some(p.as_bool()?);
        }
        if let Some(f) = v.get("factorization") {
            let s = f.as_str()?;
            o.factorization = Some(Factorization::parse(s).ok_or_else(|| {
                Error::Config(format!(
                    "unknown factorization `{s}` (expected \
                     product_form_eta|forrest_tomlin|markowitz|bartels_golub)"
                ))
            })?);
        }
        if let Some(p) = v.get("pricing") {
            let s = p.as_str()?;
            o.pricing = Some(Pricing::parse(s).ok_or_else(|| {
                Error::Config(format!(
                    "unknown pricing `{s}` (expected dantzig|devex|steepest_edge|partial)"
                ))
            })?);
        }
        if let Some(e) = v.get("eps") {
            o.eps = Some(e.as_f64()?);
        }
        if let Some(i) = v.get("max_iters") {
            o.max_iters = Some(i.as_usize()?);
        }
        if let Some(m) = v.get("mode") {
            o.mode = Some(mode_from_str(m.as_str()?)?);
        }
        if let Some(f) = v.get("finish_sum_includes_j") {
            o.finish_sum_includes_j = Some(f.as_bool()?);
        }
        if let Some(d) = v.get("drop_source_busy") {
            o.drop_source_busy = Some(d.as_bool()?);
        }
        if let Some(r) = v.get("proc_ready") {
            o.proc_ready = Some(r.as_f64_vec()?);
        }
        if let Some(t) = v.get("pdhg_tol") {
            o.pdhg_tol = Some(t.as_f64()?);
        }
        if let Some(b) = v.get("pdhg_max_blocks") {
            o.pdhg_max_blocks = Some(b.as_usize()?);
        }
        if let Some(t) = v.get("timeout_ms") {
            o.timeout_ms = Some(t.as_usize()? as u64);
        }
        Ok(o)
    }
}

/// One solve request: a family, a system spec, and optional overrides.
#[derive(Debug, Clone, PartialEq)]
pub struct SolveRequest {
    /// Caller-chosen correlation id, echoed in the response.
    pub id: Option<String>,
    /// Scheduling formulation.
    pub family: Family,
    /// Full system description.
    pub spec: SystemSpec,
    /// Per-request option overrides.
    pub options: RequestOptions,
}

impl SolveRequest {
    /// Minimal request: family + spec, session defaults for the rest.
    pub fn new(family: Family, spec: SystemSpec) -> SolveRequest {
        SolveRequest { id: None, family, spec, options: RequestOptions::default() }
    }

    /// Encode as a JSON object.
    pub fn to_json(&self) -> Json {
        let mut kv: Vec<(String, Json)> = Vec::new();
        if let Some(id) = &self.id {
            kv.push(("id".into(), Json::Str(id.clone())));
        }
        kv.push(("family".into(), Json::Str(self.family.as_str().into())));
        kv.push(("spec".into(), spec_to_json(&self.spec)));
        kv.push(("options".into(), self.options.to_json()));
        Json::Object(kv)
    }

    /// Decode from a JSON object (the spec is validated).
    pub fn from_json(v: &Json) -> Result<SolveRequest> {
        if !matches!(v, Json::Object(_)) {
            return Err(Error::Config(format!("request must be an object, got {v:?}")));
        }
        let id = match v.get("id") {
            Some(j) => Some(j.as_str()?.to_string()),
            None => None,
        };
        let family = Family::parse(v.req("family")?.as_str()?)?;
        let spec = spec_from_json(v.req("spec")?)?;
        let options = match v.get("options") {
            Some(o) => RequestOptions::from_json(o)?,
            None => RequestOptions::default(),
        };
        Ok(SolveRequest { id, family, spec, options })
    }

    /// Parse a request from JSON text.
    pub fn parse(text: &str) -> Result<SolveRequest> {
        SolveRequest::from_json(&Json::parse(text)?)
    }
}

/// Solver diagnostics attached to every response.
#[derive(Debug, Clone, Default)]
pub struct Diagnostics {
    /// Total backend iterations: simplex pivots, or — for every
    /// first-order backend — PDHG iterations counted as
    /// `blocks × BLOCK_STEPS` (the hybrid reports its simplex finish
    /// here and the first-order stage under `pdhg`).
    pub iterations: usize,
    /// Simplex phase-1 iterations (0 on warm or PDHG solves).
    pub phase1_iterations: usize,
    /// Dual-simplex repair pivots (warm restarts only).
    pub dual_iterations: usize,
    /// Whether this solve started from a cached/projected warm basis.
    pub warm_start: bool,
    /// Basis-factorization strategy the solve ran
    /// (`product_form_eta` | `forrest_tomlin` | `markowitz` |
    /// `bartels_golub`).
    pub factorization: Factorization,
    /// Pricing rule the solve ran (`dantzig` | `devex` |
    /// `steepest_edge`; the dense tableau always reports `dantzig`).
    pub pricing: Pricing,
    /// Full basis refactorizations the revised backend performed.
    pub refactorizations: usize,
    /// Peak update-file length (product-form etas / Forrest–Tomlin
    /// spikes) between refactorizations.
    pub update_len: usize,
    /// Devex / steepest-edge reference-framework rebuilds.
    pub weight_resets: usize,
    /// Iterations that entered from the partial-pricing candidate
    /// window without a full pricing pass (`pricing == partial` only).
    pub candidate_hits: usize,
    /// Full pricing passes that rebuilt the candidate window
    /// (`pricing == partial` only).
    pub candidate_refreshes: usize,
    /// Mean FTRAN-result nonzeros per pivot — the hypersparsity
    /// diagnostic (0.0 on the dense tableau and PDHG).
    pub avg_ftran_nnz: f64,
    /// Mean BTRAN-result nonzeros per solve (pricing rows and dual
    /// updates; 0.0 where there is no BTRAN).
    pub avg_btran_nnz: f64,
    /// Triangular solves answered through the Gilbert–Peierls symbolic
    /// DFS path (0 on the dense tableau and PDHG).
    pub dfs_solves: usize,
    /// Triangular solves answered through the full column scan (the
    /// dense-RHS side of the DFS/scan crossover).
    pub scan_solves: usize,
    /// Numerical-resilience events the solve recorded, in order:
    /// recovery-ladder rungs (`markowitz_retry`, `bland_perturbed`,
    /// `dense_oracle`) and in-solve events (`early_refactorize`,
    /// `bland_engaged`, `warm_fallback_cold`). Empty on clean solves.
    pub recovery_events: Vec<String>,
    /// What presolve removed in front of the backend.
    pub presolve: PresolveStats,
    /// First-order convergence details (`pdhg` / `pdhg_block` /
    /// `hybrid` backends only).
    pub pdhg: Option<PdhgDiagnostics>,
    /// Serving-tier routing details (`dlt serve` responses only).
    pub serve: Option<ServeDiagnostics>,
    /// Predicted-vs-simulated divergence from a cluster-engine replay
    /// of this schedule (`dlt simulate` / `Session::solve_simulated`
    /// only; the replay's trace is not serialized).
    pub sim: Option<DivergenceReport>,
    /// Wall-clock nanoseconds the solve took inside the session.
    pub solve_ns: u64,
}

/// Shard-router diagnostics the serving tier attaches to responses it
/// produced (absent on direct `Session` solves).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ServeDiagnostics {
    /// Session shard the client id hashed to.
    pub shard: usize,
    /// Whether the client's warm session was already resident on the
    /// shard (false on first contact and after an LRU eviction).
    pub shard_hit: bool,
    /// Warm sessions this shard has LRU-evicted so far to stay under
    /// its byte budget (monotone per-shard counter).
    pub evictions: u64,
    /// Warm sessions resident on the shard after this solve.
    pub resident: usize,
}

/// Encode a [`DivergenceReport`] as the `diagnostics.sim` wire object
/// (also used standalone by `dlt simulate --json`; the replay's trace
/// is deliberately not serialized).
pub fn sim_to_json(s: &DivergenceReport) -> Json {
    let nums = |xs: &[f64]| Json::Array(xs.iter().map(|&x| Json::Num(x)).collect());
    Json::Object(vec![
        ("predicted_makespan".into(), Json::Num(s.predicted_makespan)),
        ("simulated_makespan".into(), Json::Num(s.simulated_makespan)),
        ("rel_gap".into(), Json::Num(s.rel_gap)),
        ("per_processor_slack".into(), nums(&s.per_processor_slack)),
        (
            "violated_constraints".into(),
            Json::Array(s.violated_constraints.iter().map(|c| Json::Str(c.clone())).collect()),
        ),
        ("events".into(), Json::Num(s.events as f64)),
        ("max_queue_depth".into(), Json::Num(s.max_queue_depth as f64)),
        ("faults_injected".into(), Json::Num(s.faults_injected as f64)),
        ("preemptions".into(), Json::Num(s.preemptions as f64)),
    ])
}

/// One solve response: the optimum, the full timed schedule, and
/// solver diagnostics.
#[derive(Debug, Clone)]
pub struct SolveResponse {
    /// Echo of the request id.
    pub id: Option<String>,
    /// Echo of the request family.
    pub family: Family,
    /// Backend that produced the solution.
    pub backend: Backend,
    /// Optimal finish time `T_f`.
    pub makespan: f64,
    /// Number of sources.
    pub n: usize,
    /// Number of processors.
    pub m: usize,
    /// Load fractions `β_{i,j}`, row-major `n × m`.
    pub beta: Vec<f64>,
    /// Per-source totals `α_i = Σ_j β_{i,j}`.
    pub alpha: Vec<f64>,
    /// Communication window starts `TS_{i,j}`, row-major `n × m`.
    pub comm_start: Vec<f64>,
    /// Communication window ends `TF_{i,j}`, row-major `n × m`.
    pub comm_end: Vec<f64>,
    /// Per-processor compute start times.
    pub compute_start: Vec<f64>,
    /// Per-processor compute end times.
    pub compute_end: Vec<f64>,
    /// Whether this answer came from the serving tier's degraded mode:
    /// a loosened first-order solve produced under overload instead of
    /// a shed. Always `false` on direct `Session` solves.
    pub degraded: bool,
    /// Solver diagnostics.
    pub diagnostics: Diagnostics,
}

impl SolveResponse {
    /// Rebuild the in-memory [`Schedule`] this response serializes —
    /// wire clients get back exactly what a crate-level caller would.
    pub fn schedule(&self) -> Schedule {
        Schedule {
            n: self.n,
            m: self.m,
            model: self.family.timing_model(),
            beta: self.beta.clone(),
            comm_start: self.comm_start.clone(),
            comm_end: self.comm_end.clone(),
            compute_start: self.compute_start.clone(),
            compute_end: self.compute_end.clone(),
            makespan: self.makespan,
            lp_iterations: self.diagnostics.iterations,
        }
    }

    /// Encode as a JSON object.
    pub fn to_json(&self) -> Json {
        let nums = |xs: &[f64]| Json::Array(xs.iter().map(|&x| Json::Num(x)).collect());
        let d = &self.diagnostics;
        let mut diag: Vec<(String, Json)> = vec![
            ("iterations".into(), Json::Num(d.iterations as f64)),
            ("phase1_iterations".into(), Json::Num(d.phase1_iterations as f64)),
            ("dual_iterations".into(), Json::Num(d.dual_iterations as f64)),
            ("warm_start".into(), Json::Bool(d.warm_start)),
            ("factorization".into(), Json::Str(d.factorization.as_str().into())),
            ("pricing".into(), Json::Str(d.pricing.as_str().into())),
            ("refactorizations".into(), Json::Num(d.refactorizations as f64)),
            ("update_len".into(), Json::Num(d.update_len as f64)),
            ("weight_resets".into(), Json::Num(d.weight_resets as f64)),
            ("candidate_hits".into(), Json::Num(d.candidate_hits as f64)),
            (
                "candidate_refreshes".into(),
                Json::Num(d.candidate_refreshes as f64),
            ),
            ("avg_ftran_nnz".into(), Json::Num(d.avg_ftran_nnz)),
            ("avg_btran_nnz".into(), Json::Num(d.avg_btran_nnz)),
            ("dfs_solves".into(), Json::Num(d.dfs_solves as f64)),
            ("scan_solves".into(), Json::Num(d.scan_solves as f64)),
            (
                "recovery_events".into(),
                Json::Array(d.recovery_events.iter().map(|e| Json::Str(e.clone())).collect()),
            ),
            (
                "presolve".into(),
                Json::Object(vec![
                    ("fixed_vars".into(), Json::Num(d.presolve.fixed_vars as f64)),
                    (
                        "empty_rows_dropped".into(),
                        Json::Num(d.presolve.empty_rows_dropped as f64),
                    ),
                    (
                        "duplicate_rows_dropped".into(),
                        Json::Num(d.presolve.duplicate_rows_dropped as f64),
                    ),
                    (
                        "vacuous_bounds_dropped".into(),
                        Json::Num(d.presolve.vacuous_bounds_dropped as f64),
                    ),
                    (
                        "redundant_rows_dropped".into(),
                        Json::Num(d.presolve.redundant_rows_dropped as f64),
                    ),
                ]),
            ),
        ];
        if let Some(p) = &d.pdhg {
            diag.push((
                "pdhg".into(),
                Json::Object(vec![
                    ("blocks".into(), Json::Num(p.blocks as f64)),
                    ("converged".into(), Json::Bool(p.converged)),
                    ("primal_residual".into(), Json::Num(p.residuals.0)),
                    ("dual_residual".into(), Json::Num(p.residuals.1)),
                    ("gap".into(), Json::Num(p.residuals.2)),
                    ("crossover_pivots".into(), Json::Num(p.crossover_pivots as f64)),
                    ("columns_retired".into(), Json::Num(p.columns_retired as f64)),
                    ("block_width".into(), Json::Num(p.block_width as f64)),
                ]),
            ));
        }
        if let Some(s) = &d.serve {
            diag.push((
                "serve".into(),
                Json::Object(vec![
                    ("shard".into(), Json::Num(s.shard as f64)),
                    ("shard_hit".into(), Json::Bool(s.shard_hit)),
                    ("evictions".into(), Json::Num(s.evictions as f64)),
                    ("resident".into(), Json::Num(s.resident as f64)),
                ]),
            ));
        }
        if let Some(s) = &d.sim {
            diag.push(("sim".into(), sim_to_json(s)));
        }
        diag.push(("solve_ns".into(), Json::Num(d.solve_ns as f64)));

        let mut kv: Vec<(String, Json)> = Vec::new();
        if let Some(id) = &self.id {
            kv.push(("id".into(), Json::Str(id.clone())));
        }
        kv.push(("family".into(), Json::Str(self.family.as_str().into())));
        kv.push(("backend".into(), Json::Str(self.backend.as_str().into())));
        kv.push(("makespan".into(), Json::Num(self.makespan)));
        kv.push(("n".into(), Json::Num(self.n as f64)));
        kv.push(("m".into(), Json::Num(self.m as f64)));
        kv.push(("beta".into(), nums(&self.beta)));
        kv.push(("alpha".into(), nums(&self.alpha)));
        kv.push(("comm_start".into(), nums(&self.comm_start)));
        kv.push(("comm_end".into(), nums(&self.comm_end)));
        kv.push(("compute_start".into(), nums(&self.compute_start)));
        kv.push(("compute_end".into(), nums(&self.compute_end)));
        if self.degraded {
            kv.push(("degraded".into(), Json::Bool(true)));
        }
        kv.push(("diagnostics".into(), Json::Object(diag)));
        Json::Object(kv)
    }

    /// Decode from a JSON object (for wire clients and tests).
    pub fn from_json(v: &Json) -> Result<SolveResponse> {
        let id = match v.get("id") {
            Some(j) => Some(j.as_str()?.to_string()),
            None => None,
        };
        let d = v.req("diagnostics")?;
        let pres = d.req("presolve")?;
        let pdhg = match d.get("pdhg") {
            Some(p) => Some(PdhgDiagnostics {
                blocks: p.req("blocks")?.as_usize()?,
                converged: p.req("converged")?.as_bool()?,
                residuals: (
                    p.req("primal_residual")?.as_f64()?,
                    p.req("dual_residual")?.as_f64()?,
                    p.req("gap")?.as_f64()?,
                ),
                crossover_pivots: p.req("crossover_pivots")?.as_usize()?,
                columns_retired: p.req("columns_retired")?.as_usize()?,
                block_width: p.req("block_width")?.as_usize()?,
            }),
            None => None,
        };
        let serve = match d.get("serve") {
            Some(s) => Some(ServeDiagnostics {
                shard: s.req("shard")?.as_usize()?,
                shard_hit: s.req("shard_hit")?.as_bool()?,
                evictions: s.req("evictions")?.as_f64()? as u64,
                resident: s.req("resident")?.as_usize()?,
            }),
            None => None,
        };
        let sim = match d.get("sim") {
            Some(s) => Some(DivergenceReport {
                predicted_makespan: s.req("predicted_makespan")?.as_f64()?,
                simulated_makespan: s.req("simulated_makespan")?.as_f64()?,
                rel_gap: s.req("rel_gap")?.as_f64()?,
                per_processor_slack: s.req("per_processor_slack")?.as_f64_vec()?,
                violated_constraints: s
                    .req("violated_constraints")?
                    .as_array()?
                    .iter()
                    .map(|c| Ok(c.as_str()?.to_string()))
                    .collect::<Result<Vec<String>>>()?,
                events: s.req("events")?.as_f64()? as u64,
                max_queue_depth: s.req("max_queue_depth")?.as_usize()?,
                faults_injected: s.req("faults_injected")?.as_usize()?,
                preemptions: s.req("preemptions")?.as_usize()?,
                trace: None,
            }),
            None => None,
        };
        let fact_s = d.req("factorization")?.as_str()?;
        let pricing_s = d.req("pricing")?.as_str()?;
        let diagnostics = Diagnostics {
            iterations: d.req("iterations")?.as_usize()?,
            phase1_iterations: d.req("phase1_iterations")?.as_usize()?,
            dual_iterations: d.req("dual_iterations")?.as_usize()?,
            warm_start: d.req("warm_start")?.as_bool()?,
            factorization: Factorization::parse(fact_s)
                .ok_or_else(|| Error::Config(format!("unknown factorization `{fact_s}`")))?,
            pricing: Pricing::parse(pricing_s)
                .ok_or_else(|| Error::Config(format!("unknown pricing `{pricing_s}`")))?,
            refactorizations: d.req("refactorizations")?.as_usize()?,
            update_len: d.req("update_len")?.as_usize()?,
            weight_resets: d.req("weight_resets")?.as_usize()?,
            candidate_hits: d.req("candidate_hits")?.as_usize()?,
            candidate_refreshes: d.req("candidate_refreshes")?.as_usize()?,
            avg_ftran_nnz: d.req("avg_ftran_nnz")?.as_f64()?,
            avg_btran_nnz: d.req("avg_btran_nnz")?.as_f64()?,
            dfs_solves: d.req("dfs_solves")?.as_usize()?,
            scan_solves: d.req("scan_solves")?.as_usize()?,
            // Tolerant: absent on responses from pre-ladder servers.
            recovery_events: match d.get("recovery_events") {
                Some(r) => r
                    .as_array()?
                    .iter()
                    .map(|e| Ok(e.as_str()?.to_string()))
                    .collect::<Result<Vec<String>>>()?,
                None => Vec::new(),
            },
            presolve: PresolveStats {
                fixed_vars: pres.req("fixed_vars")?.as_usize()?,
                empty_rows_dropped: pres.req("empty_rows_dropped")?.as_usize()?,
                duplicate_rows_dropped: pres.req("duplicate_rows_dropped")?.as_usize()?,
                vacuous_bounds_dropped: pres.req("vacuous_bounds_dropped")?.as_usize()?,
                redundant_rows_dropped: pres.req("redundant_rows_dropped")?.as_usize()?,
            },
            pdhg,
            serve,
            sim,
            solve_ns: d.req("solve_ns")?.as_f64()? as u64,
        };
        let backend_s = v.req("backend")?.as_str()?;
        Ok(SolveResponse {
            id,
            family: Family::parse(v.req("family")?.as_str()?)?,
            backend: Backend::parse(backend_s)
                .ok_or_else(|| Error::Config(format!("unknown backend `{backend_s}`")))?,
            makespan: v.req("makespan")?.as_f64()?,
            n: v.req("n")?.as_usize()?,
            m: v.req("m")?.as_usize()?,
            beta: v.req("beta")?.as_f64_vec()?,
            alpha: v.req("alpha")?.as_f64_vec()?,
            comm_start: v.req("comm_start")?.as_f64_vec()?,
            comm_end: v.req("comm_end")?.as_f64_vec()?,
            compute_start: v.req("compute_start")?.as_f64_vec()?,
            compute_end: v.req("compute_end")?.as_f64_vec()?,
            degraded: match v.get("degraded") {
                Some(b) => b.as_bool()?,
                None => false,
            },
            diagnostics,
        })
    }
}

/// A serializable error: the crate's [`Error`] flattened into a stable
/// `(kind, message)` pair so batch output can carry per-request
/// failures in-band.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ApiError {
    /// Stable kind slug (`infeasible`, `config`, `usage`, ...).
    pub kind: String,
    /// Human-readable detail.
    pub message: String,
}

impl std::fmt::Display for ApiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.kind, self.message)
    }
}

impl std::error::Error for ApiError {}

impl From<Error> for ApiError {
    fn from(e: Error) -> ApiError {
        let kind = match &e {
            Error::InvalidSpec(_) => "invalid_spec",
            Error::Infeasible(_) => "infeasible",
            Error::Unbounded(_) => "unbounded",
            Error::IterationLimit { .. } => "iteration_limit",
            Error::DeadlineExceeded { .. } => "deadline_exceeded",
            Error::Numerical(_) => "numerical",
            Error::InvalidSchedule(_) => "invalid_schedule",
            Error::Config(_) => "config",
            Error::Usage(_) => "usage",
            Error::Artifact(_) => "artifact",
            Error::Runtime(_) => "runtime",
            Error::Cluster(_) => "cluster",
            Error::Overloaded { .. } => "overloaded",
            Error::WorkerPanicked(_) => "worker_panicked",
            Error::Io { .. } => "io",
        };
        ApiError { kind: kind.to_string(), message: e.to_string() }
    }
}

impl ApiError {
    /// Map back onto the closest crate-level [`Error`] variant (for
    /// callers whose signatures predate the facade).
    pub fn into_error(self) -> Error {
        match self.kind.as_str() {
            "invalid_spec" => Error::InvalidSpec(self.message),
            "infeasible" => Error::Infeasible(self.message),
            "unbounded" => Error::Unbounded(self.message),
            "invalid_schedule" => Error::InvalidSchedule(self.message),
            "config" => Error::Config(self.message),
            "usage" => Error::Usage(self.message),
            "artifact" => Error::Artifact(self.message),
            "runtime" => Error::Runtime(self.message),
            "cluster" => Error::Cluster(self.message),
            "worker_panicked" => Error::WorkerPanicked(self.message),
            "overloaded" => {
                // Recover the retry hint from the canonical Display
                // text ("server overloaded: retry after {ms}ms").
                let digits: String =
                    self.message.chars().filter(|c| c.is_ascii_digit()).collect();
                Error::Overloaded { retry_after_ms: digits.parse().unwrap_or(0) }
            }
            "deadline_exceeded" => {
                // Recover the elapsed time from the canonical Display
                // text ("deadline exceeded after {ms} ms in {phase}
                // ({n} iterations)") — the first number is elapsed_ms.
                let ms = self
                    .message
                    .split_whitespace()
                    .find_map(|w| w.parse::<u64>().ok())
                    .unwrap_or(0);
                Error::DeadlineExceeded { elapsed_ms: ms, iterations: 0, phase: "wire".into() }
            }
            _ => Error::Numerical(self.message),
        }
    }

    /// Encode as `{"error": {"kind": ..., "message": ...}}`.
    pub fn to_json(&self) -> Json {
        Json::Object(vec![(
            "error".into(),
            Json::Object(vec![
                ("kind".into(), Json::Str(self.kind.clone())),
                ("message".into(), Json::Str(self.message.clone())),
            ]),
        )])
    }

    /// Decode from the `{"error": ...}` shape.
    pub fn from_json(v: &Json) -> Result<ApiError> {
        let e = v.req("error")?;
        Ok(ApiError {
            kind: e.req("kind")?.as_str()?.to_string(),
            message: e.req("message")?.as_str()?.to_string(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> SystemSpec {
        SystemSpec::builder()
            .source(0.2, 10.0)
            .source(0.4, 50.0)
            .processors(&[2.0, 3.0, 4.0])
            .job(100.0)
            .build()
            .unwrap()
    }

    #[test]
    fn request_roundtrip_with_options() {
        let req = SolveRequest {
            id: Some("r-1".into()),
            family: Family::Concurrent,
            spec: spec(),
            options: RequestOptions {
                backend: Some(Backend::Pdhg),
                presolve: Some(false),
                factorization: Some(Factorization::BartelsGolub),
                pricing: Some(Pricing::Devex),
                eps: Some(1e-8),
                mode: Some(Mode::Proportional),
                pdhg_max_blocks: Some(1234),
                timeout_ms: Some(250),
                ..RequestOptions::default()
            },
        };
        let text = req.to_json().to_string_pretty();
        let back = SolveRequest::parse(&text).unwrap();
        assert_eq!(req, back);
    }

    #[test]
    fn minimal_request_defaults() {
        let text = r#"{"family": "frontend",
                       "spec": {"sources":[{"g":0.2}],"processors":[{"a":2}],"job":10}}"#;
        let req = SolveRequest::parse(text).unwrap();
        assert_eq!(req.family, Family::Frontend);
        assert_eq!(req.options, RequestOptions::default());
        assert!(req.id.is_none());
    }

    #[test]
    fn bad_family_and_backend_are_config_errors() {
        let bad_family = r#"{"family": "quantum",
            "spec": {"sources":[{"g":0.2}],"processors":[{"a":2}],"job":10}}"#;
        assert!(matches!(SolveRequest::parse(bad_family), Err(Error::Config(_))));
        let bad_backend = r#"{"family": "frontend",
            "spec": {"sources":[{"g":0.2}],"processors":[{"a":2}],"job":10},
            "options": {"backend": "gurobi"}}"#;
        assert!(matches!(SolveRequest::parse(bad_backend), Err(Error::Config(_))));
        let bad_fact = r#"{"family": "frontend",
            "spec": {"sources":[{"g":0.2}],"processors":[{"a":2}],"job":10},
            "options": {"factorization": "cholesky"}}"#;
        assert!(matches!(SolveRequest::parse(bad_fact), Err(Error::Config(_))));
        let bad_pricing = r#"{"family": "frontend",
            "spec": {"sources":[{"g":0.2}],"processors":[{"a":2}],"job":10},
            "options": {"pricing": "random"}}"#;
        assert!(matches!(SolveRequest::parse(bad_pricing), Err(Error::Config(_))));
    }

    #[test]
    fn api_error_roundtrip() {
        let e = ApiError::from(Error::Infeasible("release times collide".into()));
        let back = ApiError::from_json(&e.to_json()).unwrap();
        assert_eq!(e, back);
        assert!(matches!(back.into_error(), Error::Infeasible(_)));
    }

    #[test]
    fn deadline_error_maps_to_stable_kind_and_back() {
        let e = ApiError::from(Error::DeadlineExceeded {
            elapsed_ms: 12,
            iterations: 34,
            phase: "simplex".into(),
        });
        assert_eq!(e.kind, "deadline_exceeded");
        let back = ApiError::from_json(&e.to_json()).unwrap();
        assert_eq!(e, back);
        match back.into_error() {
            Error::DeadlineExceeded { elapsed_ms, .. } => assert_eq!(elapsed_ms, 12),
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
    }

    #[test]
    fn family_names_roundtrip() {
        for f in FAMILIES {
            assert_eq!(Family::parse(f.as_str()).unwrap(), f);
        }
        assert!(Family::parse("fe").is_err());
    }
}
