//! `dlt::api` — the stable service facade.
//!
//! Every consumer — the CLI, the sweep engine, the §6 trade-off
//! advisor, the §5 speedup analysis, benches, and any future network
//! server — goes through this one boundary instead of the per-family
//! entry points scattered across [`crate::dlt`]:
//!
//! ```text
//! SolveRequest ──▶ Solver (builder) ──▶ Session ──▶ SolveResponse
//!   family            backend             owns        makespan, β/α,
//!   spec              presolve            WarmCache +  timing windows,
//!   options           threads             projection   diagnostics
//!  (JSON in)          warm_start          seeds       (JSON out)
//! ```
//!
//! - **Typed wire structs** ([`SolveRequest`] / [`SolveResponse`] /
//!   [`ApiError`]) with lossless JSON encode/decode through the
//!   zero-dependency [`crate::config::json`] — the serving contract
//!   without a serde or network dependency.
//! - **Sessions** ([`Solver`] → [`Session`]): repeated and perturbed
//!   queries warm-start from the previous optimal basis (per reduced-LP
//!   shape) and cross-shape projection seeds (per family), with the
//!   dual simplex repairing rhs-perturbed bases — callers never touch
//!   [`crate::lp`] types.
//! - **Batch solving** ([`Session::solve_batch`]): heterogeneous
//!   request vectors fan across work-stealing worker deques with one
//!   fresh session per worker; responses come back in input order with
//!   per-request errors in-band.
//! - **Backend selection** ([`Backend`], re-exported from
//!   [`crate::pipeline`]): revised simplex (default), dense tableau,
//!   or PDHG — all behind presolve, selectable per request.
//!
//! The CLI front door is `dlt batch`: a JSON array of requests on a
//! file or stdin, a JSON array of responses on stdout.
//!
//! ## Example
//!
//! ```
//! use dlt::api::{Family, SolveRequest, Solver};
//! use dlt::model::SystemSpec;
//!
//! let spec = SystemSpec::builder()
//!     .source(0.2, 10.0)
//!     .source(0.4, 50.0)
//!     .processors(&[2.0, 3.0, 4.0, 5.0, 6.0])
//!     .job(100.0)
//!     .build()
//!     .unwrap();
//! let mut session = Solver::new().build();
//! let resp = session.solve(&SolveRequest::new(Family::Frontend, spec)).unwrap();
//! assert!(resp.makespan > 0.0);
//! // The same request/response pair round-trips as JSON:
//! let wire = resp.to_json().to_string_compact();
//! assert!(wire.contains("\"makespan\""));
//! ```

pub mod session;
pub mod wire;

pub use crate::lp::{Factorization, Pricing};
pub use crate::pipeline::Backend;
pub use session::{solve_one, Session, Solver};
pub use wire::{
    sim_to_json, ApiError, Diagnostics, Family, RequestOptions, ServeDiagnostics, SolveRequest,
    SolveResponse, FAMILIES,
};
