//! Sessions: warm solver state behind the request/response facade.
//!
//! A [`Solver`] is a reusable configuration builder; [`Solver::build`]
//! produces a [`Session`] that owns the per-thread solver state — a
//! [`WarmCache`] keyed by reduced-LP shape plus one cross-shape
//! projection seed per family — so repeated or perturbed requests
//! warm-start automatically without the caller ever touching
//! [`crate::lp`] types. [`Session::solve_batch`] fans a heterogeneous
//! request vector across worker threads (work-stealing deques, one
//! fresh `Session` per worker) and returns responses in input order.

use crate::api::wire::{ApiError, Diagnostics, Family, SolveRequest, SolveResponse};
use crate::dlt::concurrent::ConcurrentOptions;
use crate::dlt::frontend::FeOptions;
use crate::dlt::multi_job::MultiJobStepModel;
use crate::dlt::no_frontend::NfeOptions;
use crate::error::Result;
use crate::experiments::sweep::parallel_map_steal;
use crate::lp::{Basis, LpProblem, SimplexOptions, SolverScratch, WarmCache};
use crate::pdhg::PdhgOptions;
use crate::pipeline::{self, Backend, PipelineOptions, ScenarioModel};
use std::collections::HashMap;

/// Facade configuration + builder. `Clone`-able so one configuration
/// can stamp out many per-thread [`Session`]s.
#[derive(Debug, Clone)]
pub struct Solver {
    /// Default backend for requests that do not override it.
    pub backend: Backend,
    /// Default presolve switch.
    pub presolve: bool,
    /// Default simplex tuning.
    pub simplex: SimplexOptions,
    /// Default PDHG tuning.
    pub pdhg: PdhgOptions,
    /// Worker threads for [`Session::solve_batch`] (`0` = one per
    /// core).
    pub threads: usize,
    /// Keep warm state between solves (disable for cold baselines).
    pub warm_start: bool,
}

impl Default for Solver {
    fn default() -> Self {
        Solver {
            backend: Backend::default(),
            presolve: true,
            simplex: SimplexOptions::default(),
            pdhg: PdhgOptions::default(),
            threads: 0,
            warm_start: true,
        }
    }
}

impl Solver {
    /// Default configuration (revised simplex, presolve on, warm
    /// starts on, auto threads).
    pub fn new() -> Solver {
        Solver::default()
    }

    /// Set the default backend.
    pub fn backend(mut self, b: Backend) -> Solver {
        self.backend = b;
        self
    }

    /// Enable/disable presolve by default.
    pub fn presolve(mut self, on: bool) -> Solver {
        self.presolve = on;
        self
    }

    /// Set batch worker threads.
    ///
    /// `0` means "one per core": [`Solver::build`] resolves it to
    /// [`std::thread::available_parallelism`] **once**, and the built
    /// [`Session`] keeps that count for every
    /// [`Session::solve_batch`] call (it is not re-read per batch).
    pub fn threads(mut self, t: usize) -> Solver {
        self.threads = t;
        self
    }

    /// Enable/disable warm state between solves.
    pub fn warm_start(mut self, on: bool) -> Solver {
        self.warm_start = on;
        self
    }

    /// Set the default simplex tuning.
    pub fn simplex(mut self, s: SimplexOptions) -> Solver {
        self.simplex = s;
        self
    }

    /// Set the default PDHG tuning.
    pub fn pdhg(mut self, p: PdhgOptions) -> Solver {
        self.pdhg = p;
        self
    }

    /// Build a session owning fresh warm state. The `threads == 0`
    /// ("one per core") default is resolved here, once, instead of on
    /// every `solve_batch` call.
    pub fn build(self) -> Session {
        let batch_threads = if self.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.threads
        };
        Session {
            config: self,
            batch_threads,
            cache: WarmCache::new(),
            seeds: HashMap::new(),
            scratch: SolverScratch::new(),
            solves: 0,
        }
    }
}

/// A solving session: configuration plus private warm state. One
/// session per thread is the intended usage — [`Session::solve_batch`]
/// arranges exactly that.
#[derive(Debug)]
pub struct Session {
    config: Solver,
    /// Worker count for `solve_batch`, resolved from
    /// `Solver::threads` at build time (`0` → core count, read once).
    batch_threads: usize,
    cache: WarmCache,
    /// Last reduced LP + optimal basis per family, for cross-shape
    /// projection when the cache misses a new LP shape.
    seeds: HashMap<&'static str, (LpProblem, Basis)>,
    /// Per-session solver scratch pool: work buffers, factorization
    /// and pricing objects reused across solves, so repeated warm
    /// requests allocate nothing in the simplex core.
    scratch: SolverScratch,
    /// Requests solved so far (successful or not).
    pub solves: usize,
}

impl Session {
    /// The configuration this session was built from.
    pub fn config(&self) -> &Solver {
        &self.config
    }

    /// `(warm_attempts, cold_solves)` from the underlying cache.
    pub fn cache_stats(&self) -> (usize, usize) {
        (self.cache.warm_attempts, self.cache.cold_solves)
    }

    /// Worker threads [`Session::solve_batch`] will use — the
    /// build-time resolution of [`Solver::threads`].
    pub fn batch_threads(&self) -> usize {
        self.batch_threads
    }

    /// Approximate resident bytes of this session's warm state (cached
    /// bases plus cross-shape projection seeds). This is the currency
    /// the serving tier's LRU eviction budgets against; absolute
    /// accuracy matters less than monotonicity in cache growth.
    pub fn warm_bytes(&self) -> usize {
        let seed_bytes: usize = self
            .seeds
            .values()
            .map(|(lp, b)| (lp.num_vars() + lp.num_constraints() + b.cols.len()) * 16 + 128)
            .sum();
        self.cache.approx_bytes() + seed_bytes
    }

    /// Solve one request. Warm state is consulted and updated for
    /// every backend that can use it: cached bases for the revised
    /// simplex and the hybrid's finish, cached primal points for the
    /// first-order backends. Only the dense tableau always runs cold.
    pub fn solve(&mut self, req: &SolveRequest) -> std::result::Result<SolveResponse, ApiError> {
        self.solves += 1;
        self.solve_inner(req).map_err(ApiError::from)
    }

    fn solve_inner(&mut self, req: &SolveRequest) -> Result<SolveResponse> {
        let cfg = &self.config;
        let o = &req.options;
        // The LP builder asserts on this; a wire request must surface
        // it as an error, never a panic.
        if let Some(ready) = &o.proc_ready {
            if ready.len() != req.spec.m() {
                return Err(crate::error::Error::Config(format!(
                    "proc_ready has {} entries but the spec has {} processors",
                    ready.len(),
                    req.spec.m()
                )));
            }
        }

        let mut simplex = cfg.simplex.clone();
        if let Some(eps) = o.eps {
            simplex.eps = eps;
        }
        if let Some(mi) = o.max_iters {
            simplex.max_iters = mi;
        }
        if let Some(f) = o.factorization {
            simplex.factorization = f;
        }
        if let Some(p) = o.pricing {
            simplex.pricing = p;
        }
        let mut pdhg = cfg.pdhg.clone();
        if let Some(t) = o.pdhg_tol {
            pdhg.tol = t;
        }
        if let Some(b) = o.pdhg_max_blocks {
            pdhg.max_blocks = b;
        }
        let popts = PipelineOptions {
            presolve: o.presolve.unwrap_or(cfg.presolve),
            backend: o.backend.unwrap_or(cfg.backend),
            simplex,
            pdhg,
            timeout_ms: o.timeout_ms,
        };

        let model: Box<dyn ScenarioModel> = match req.family {
            Family::Frontend => Box::new(FeOptions {
                finish_sum_includes_j: o.finish_sum_includes_j.unwrap_or(false),
                proc_ready: o.proc_ready.clone(),
            }),
            Family::NoFrontend => Box::new(NfeOptions {
                drop_source_busy_constraint: o.drop_source_busy.unwrap_or(false),
            }),
            Family::Concurrent => Box::new(ConcurrentOptions { mode: o.mode.unwrap_or_default() }),
            Family::MultiJob => Box::new(MultiJobStepModel {
                fe: FeOptions {
                    finish_sum_includes_j: o.finish_sum_includes_j.unwrap_or(false),
                    proc_ready: o.proc_ready.clone(),
                },
            }),
        };

        // Warm state flows to the backends that can consume it: the
        // revised simplex (cached bases), the first-order backends
        // (cached primal points), and the hybrid (both). The dense
        // tableau always runs cold, so for it the cache is skipped and
        // `warm_start` stays honest.
        let warm = self.config.warm_start && popts.backend != Backend::DenseTableau;
        let key = req.family.as_str();
        let attempts_before = self.cache.warm_attempts;
        let t0 = std::time::Instant::now();
        let solved = {
            let seed = if warm {
                self.seeds.get(key).map(|(lp, b)| (lp, b))
            } else {
                None
            };
            let cache = if warm { Some(&mut self.cache) } else { None };
            pipeline::solve_full_scratch(
                model.as_ref(),
                &req.spec,
                &popts,
                cache,
                seed,
                &mut self.scratch,
            )?
        };
        let solve_ns = t0.elapsed().as_nanos() as u64;
        let warm_start = self.cache.warm_attempts > attempts_before;

        if warm {
            if let Some(basis) = solved.solution.basis.as_ref() {
                // The seed only matters on cache misses (new LP
                // shapes), so refresh it — and pay the LpProblem
                // clone — only when this solve changed the shape.
                let shape = (solved.reduced.num_vars(), solved.reduced.num_constraints());
                let stale = match self.seeds.get(key) {
                    Some((lp, _)) => (lp.num_vars(), lp.num_constraints()) != shape,
                    None => true,
                };
                if basis.is_complete() && stale {
                    self.seeds.insert(key, (solved.reduced.clone(), basis.clone()));
                }
            }
        }

        let sched = &solved.schedule;
        let alpha: Vec<f64> = (0..sched.n).map(|i| sched.load_from_source(i)).collect();
        Ok(SolveResponse {
            id: req.id.clone(),
            family: req.family,
            backend: solved.backend,
            makespan: sched.makespan,
            n: sched.n,
            m: sched.m,
            beta: sched.beta.clone(),
            alpha,
            comm_start: sched.comm_start.clone(),
            comm_end: sched.comm_end.clone(),
            compute_start: sched.compute_start.clone(),
            compute_end: sched.compute_end.clone(),
            degraded: false,
            diagnostics: Diagnostics {
                iterations: solved.solution.iterations,
                phase1_iterations: solved.solution.phase1_iterations,
                dual_iterations: solved.solution.dual_iterations,
                warm_start,
                factorization: solved.solution.factorization,
                pricing: solved.solution.pricing,
                refactorizations: solved.solution.refactorizations,
                update_len: solved.solution.peak_update_len,
                weight_resets: solved.solution.weight_resets,
                candidate_hits: solved.solution.candidate_hits,
                candidate_refreshes: solved.solution.candidate_refreshes,
                avg_ftran_nnz: solved.solution.avg_ftran_nnz,
                avg_btran_nnz: solved.solution.avg_btran_nnz,
                dfs_solves: solved.solution.dfs_solves,
                scan_solves: solved.solution.scan_solves,
                recovery_events: solved.solution.recovery_events.clone(),
                presolve: solved.stats,
                pdhg: solved.pdhg,
                serve: None,
                sim: None,
                solve_ns,
            },
        })
    }

    /// Degraded solve for the serving tier's overload path: force a
    /// loosened first-order backend (coarse tolerances, small block
    /// cap, no deadline) so an overloaded shard can still answer with
    /// a usable approximate schedule instead of shedding the request.
    /// The response is flagged `degraded: true`; its makespan may sit
    /// above the true optimum by the loosened tolerance.
    pub fn solve_degraded(
        &mut self,
        req: &SolveRequest,
    ) -> std::result::Result<SolveResponse, ApiError> {
        self.solves += 1;
        let mut loose = req.clone();
        loose.options.backend = Some(Backend::Pdhg);
        loose.options.timeout_ms = None;
        loose.options.pdhg_tol = Some(req.options.pdhg_tol.map_or(1e-3, |t| t.max(1e-3)));
        loose.options.pdhg_max_blocks =
            Some(req.options.pdhg_max_blocks.map_or(40, |b| b.min(40)));
        let mut resp = self.solve_inner(&loose).map_err(ApiError::from)?;
        resp.degraded = true;
        Ok(resp)
    }

    /// Solve one request, then replay the resulting schedule through
    /// the cluster engine ([`crate::sim::replay`]) and attach the
    /// divergence report as `diagnostics.sim`. Frontend and
    /// no-frontend families only — the concurrent and multi-job
    /// extensions have no sequential replay semantics.
    pub fn solve_simulated(
        &mut self,
        req: &SolveRequest,
        ropts: &crate::sim::replay::ReplayOptions,
    ) -> std::result::Result<SolveResponse, ApiError> {
        if !matches!(req.family, Family::Frontend | Family::NoFrontend) {
            self.solves += 1;
            return Err(ApiError::from(crate::error::Error::Usage(format!(
                "simulate supports frontend|no_frontend, not {}",
                req.family.as_str()
            ))));
        }
        let mut resp = self.solve(req)?;
        let report = crate::sim::replay::replay(&req.spec, &resp.schedule(), ropts)
            .map_err(ApiError::from)?;
        resp.diagnostics.sim = Some(report);
        Ok(resp)
    }

    /// Solve a heterogeneous request vector in parallel: the requests
    /// are fanned across work-stealing worker deques
    /// ([`parallel_map_steal`]), each worker owning a fresh `Session`
    /// built from this session's configuration, so neighbouring
    /// requests warm-start from each other. Responses (or per-request
    /// errors) come back in input order; a panicking worker costs only
    /// its current item (`worker_panicked`), never the whole batch.
    pub fn solve_batch(
        &self,
        reqs: &[SolveRequest],
    ) -> Vec<std::result::Result<SolveResponse, ApiError>> {
        let mut cfg = self.config.clone();
        // Workers never re-batch, so pin them to one thread instead of
        // letting each rebuilt worker session re-resolve the core
        // count.
        cfg.threads = 1;
        parallel_map_steal(
            reqs,
            self.batch_threads,
            || cfg.clone().build(),
            |session: &mut Session, req: &SolveRequest| session.solve(req),
        )
        .into_iter()
        .map(|slot| {
            slot.unwrap_or_else(|panic| {
                Err(ApiError::from(crate::error::Error::WorkerPanicked(panic.message)))
            })
        })
        .collect()
    }
}

/// One-shot convenience: solve a single request with a throwaway
/// default session.
pub fn solve_one(req: &SolveRequest) -> std::result::Result<SolveResponse, ApiError> {
    Solver::new().build().solve(req)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SystemSpec;

    fn spec() -> SystemSpec {
        SystemSpec::builder()
            .source(0.2, 10.0)
            .source(0.4, 50.0)
            .processors(&[2.0, 3.0, 4.0, 5.0, 6.0])
            .job(100.0)
            .build()
            .unwrap()
    }

    #[test]
    fn session_matches_direct_pipeline_solve() {
        let mut session = Solver::new().build();
        let resp = session.solve(&SolveRequest::new(Family::Frontend, spec())).unwrap();
        let direct =
            pipeline::solve(&FeOptions::default(), &spec()).unwrap();
        assert!((resp.makespan - direct.makespan).abs() < 1e-9 * (1.0 + direct.makespan));
        let total: f64 = resp.beta.iter().sum();
        assert!((total - 100.0).abs() < 1e-6);
        assert_eq!(resp.alpha.len(), 2);
        assert!(resp.diagnostics.iterations > 0);
    }

    #[test]
    fn repeated_requests_warm_start() {
        let mut session = Solver::new().build();
        let first = session.solve(&SolveRequest::new(Family::Frontend, spec())).unwrap();
        assert!(!first.diagnostics.warm_start);
        let second = session
            .solve(&SolveRequest::new(Family::Frontend, spec().with_job(140.0)))
            .unwrap();
        assert!(second.diagnostics.warm_start, "second solve of the shape should warm-start");
        assert_eq!(second.diagnostics.phase1_iterations, 0);
        let (warm, cold) = session.cache_stats();
        assert_eq!((warm, cold), (1, 1));
    }

    #[test]
    fn cross_shape_seeding_covers_processor_sweeps() {
        // m -> m+1 changes the LP shape; the session's per-family seed
        // must still warm the solve via projection.
        let mut session = Solver::new().build();
        let base = spec();
        for m in 1..=base.m() {
            let sub = base.with_m_processors(m);
            let resp = session.solve(&SolveRequest::new(Family::Frontend, sub.clone())).unwrap();
            let direct = pipeline::solve(&FeOptions::default(), &sub).unwrap();
            assert!(
                (resp.makespan - direct.makespan).abs() < 1e-7 * (1.0 + direct.makespan),
                "m={m}: {} vs {}",
                resp.makespan,
                direct.makespan
            );
        }
    }

    #[test]
    fn batch_matches_individual_solves() {
        // Low releases: Table 1's (10, 50) releases make the NFE LP
        // infeasible below J = 200 (eq. 12 forces beta[0][0] >= 200).
        let nfe_spec = SystemSpec::builder()
            .source(0.2, 0.0)
            .source(0.4, 2.0)
            .processors(&[2.0, 3.0, 4.0, 5.0, 6.0])
            .job(100.0)
            .build()
            .unwrap();
        let reqs: Vec<SolveRequest> = (0..10)
            .map(|k| {
                SolveRequest::new(Family::NoFrontend, nfe_spec.with_job(100.0 + 10.0 * k as f64))
            })
            .collect();
        let session = Solver::new().threads(3).build();
        let batch = session.solve_batch(&reqs);
        assert_eq!(batch.len(), reqs.len());
        let mut single = Solver::new().build();
        for (req, out) in reqs.iter().zip(batch.iter()) {
            let b = out.as_ref().expect("batch solve succeeded");
            let s = single.solve(req).unwrap();
            assert!(
                (b.makespan - s.makespan).abs() < 1e-7 * (1.0 + s.makespan),
                "{:?}: batch {} vs single {}",
                req.spec.job,
                b.makespan,
                s.makespan
            );
        }
    }

    #[test]
    fn factorization_and_pricing_overrides_reach_diagnostics() {
        // Acceptance: ForrestTomlin + Devex selectable per request and
        // reflected in the response diagnostics, with the same optimum
        // as the defaults.
        use crate::lp::{Factorization, Pricing};
        let mut session = Solver::new().build();
        let default = session.solve(&SolveRequest::new(Family::Frontend, spec())).unwrap();
        assert_eq!(default.diagnostics.factorization, Factorization::ProductFormEta);
        assert_eq!(default.diagnostics.pricing, Pricing::Dantzig);
        let mut req = SolveRequest::new(Family::Frontend, spec());
        req.options.factorization = Some(Factorization::ForrestTomlin);
        req.options.pricing = Some(Pricing::Devex);
        let resp = Solver::new().build().solve(&req).unwrap();
        assert_eq!(resp.diagnostics.factorization, Factorization::ForrestTomlin);
        assert_eq!(resp.diagnostics.pricing, Pricing::Devex);
        assert!(
            (resp.makespan - default.makespan).abs() < 1e-7 * (1.0 + default.makespan),
            "strategies changed the optimum: {} vs {}",
            resp.makespan,
            default.makespan
        );
        // The hypersparse arms (Markowitz refactorization, Bartels-Golub
        // updates) are selectable through the same path.
        for f in [Factorization::Markowitz, Factorization::BartelsGolub] {
            let mut req = SolveRequest::new(Family::Frontend, spec());
            req.options.factorization = Some(f);
            let resp = Solver::new().build().solve(&req).unwrap();
            assert_eq!(resp.diagnostics.factorization, f);
            assert!(
                (resp.makespan - default.makespan).abs() < 1e-7 * (1.0 + default.makespan),
                "{f:?} changed the optimum: {} vs {}",
                resp.makespan,
                default.makespan
            );
        }
    }

    #[test]
    fn threads_zero_resolves_once_at_build() {
        let auto = Solver::new().threads(0).build();
        assert!(auto.batch_threads() >= 1, "0 must resolve to a real core count");
        let fixed = Solver::new().threads(3).build();
        assert_eq!(fixed.batch_threads(), 3);
    }

    #[test]
    fn warm_bytes_grows_with_cache() {
        let mut session = Solver::new().build();
        let before = session.warm_bytes();
        session.solve(&SolveRequest::new(Family::Frontend, spec())).unwrap();
        assert!(
            session.warm_bytes() > before,
            "a warm-cached solve must be visible to the eviction accounting"
        );
    }

    #[test]
    fn wrong_length_proc_ready_is_an_error_not_a_panic() {
        let mut req = SolveRequest::new(Family::MultiJob, spec());
        req.options.proc_ready = Some(vec![1.0, 2.0]); // spec has 5 processors
        let err = Solver::new().build().solve(&req).unwrap_err();
        assert_eq!(err.kind, "config", "{err}");
    }

    #[test]
    fn solve_simulated_attaches_divergence() {
        let mut session = Solver::new().build();
        let nfe_spec = SystemSpec::builder()
            .source(0.2, 0.0)
            .source(0.4, 2.0)
            .processors(&[2.0, 3.0, 4.0])
            .job(100.0)
            .build()
            .unwrap();
        let resp = session
            .solve_simulated(
                &SolveRequest::new(Family::NoFrontend, nfe_spec),
                &crate::sim::replay::ReplayOptions::default(),
            )
            .unwrap();
        let sim = resp.diagnostics.sim.expect("divergence report attached");
        assert!(sim.rel_gap.abs() <= 1e-9, "gap {}", sim.rel_gap);
        assert!(sim.violated_constraints.is_empty(), "{:?}", sim.violated_constraints);
        // Families without sequential replay semantics error cleanly.
        let err = session
            .solve_simulated(
                &SolveRequest::new(Family::Concurrent, spec()),
                &crate::sim::replay::ReplayOptions::default(),
            )
            .unwrap_err();
        assert_eq!(err.kind, "usage", "{err}");
    }

    #[test]
    fn degraded_solve_is_flagged_and_answers() {
        let mut session = Solver::new().build();
        let exact = session.solve(&SolveRequest::new(Family::Frontend, spec())).unwrap();
        assert!(!exact.degraded, "direct solves are never degraded");
        let deg = session.solve_degraded(&SolveRequest::new(Family::Frontend, spec())).unwrap();
        assert!(deg.degraded, "degraded responses must be flagged");
        assert_eq!(deg.backend, Backend::Pdhg);
        assert!(deg.makespan.is_finite() && deg.makespan > 0.0, "makespan {}", deg.makespan);
        // The flag survives the wire roundtrip.
        let back = SolveResponse::from_json(&deg.to_json()).unwrap();
        assert!(back.degraded);
    }

    #[test]
    fn request_timeout_surfaces_as_deadline_exceeded() {
        // A zero deadline on a first-order backend cannot finish a
        // single block; the session must surface the typed kind.
        let mut req = SolveRequest::new(Family::Frontend, spec());
        req.options.backend = Some(Backend::Pdhg);
        req.options.timeout_ms = Some(0);
        let err = Solver::new().build().solve(&req).unwrap_err();
        assert_eq!(err.kind, "deadline_exceeded", "{err}");
    }

    #[test]
    fn batch_reports_errors_in_band() {
        // An infeasible NFE instance (release gap larger than the job
        // can stretch) must come back as Err at its slot, not poison
        // the batch.
        let bad = SystemSpec::builder()
            .source(0.01, 0.0)
            .source(0.01, 1000.0)
            .processors(&[2.0])
            .job(1.0)
            .build()
            .unwrap();
        let reqs = vec![
            SolveRequest::new(Family::Frontend, spec()),
            SolveRequest::new(Family::NoFrontend, bad),
            SolveRequest::new(Family::Concurrent, spec()),
        ];
        let out = Solver::new().threads(2).build().solve_batch(&reqs);
        assert!(out[0].is_ok());
        assert!(out[1].is_err(), "infeasible instance should error in-band");
        assert!(out[2].is_ok());
    }
}
