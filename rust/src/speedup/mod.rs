//! §5 — Amdahl-style speedup analysis.
//!
//! `S(p, n) = T_f(1 source, n processors) / T_f(p sources, n processors)`
//! (paper eq. 16). The paper's Figure 14/15 sweep uses homogeneous
//! nodes with the no-front-end solver.

use crate::api::{Family, Solver, SolveRequest};
use crate::error::Result;
use crate::model::SystemSpec;

/// Speedup of `p` sources over one source at fixed `n` processors
/// (eq. 16): ratio of single-source to multi-source finish time.
pub fn speedup(tf_single: f64, tf_multi: f64) -> f64 {
    tf_single / tf_multi
}

/// One cell of the Fig. 14/15 sweep.
#[derive(Debug, Clone)]
pub struct SpeedupPoint {
    /// Number of sources used.
    pub sources: usize,
    /// Number of processors used.
    pub processors: usize,
    /// Optimal finish time.
    pub tf: f64,
    /// Speedup vs the single-source system with the same processors.
    pub speedup: f64,
}

/// Sweep finish time and speedup over `sources × processors` grids
/// using the no-front-end solver (paper §5.2).
pub fn sweep(
    spec: &SystemSpec,
    source_counts: &[usize],
    max_processors: usize,
) -> Result<Vec<SpeedupPoint>> {
    // One api session across the whole grid: each (n, m) shape keeps
    // its last optimal basis in the session's warm cache, so re-sweeps
    // and repeated shapes skip phase 1, and every solve flows through
    // the pipeline (presolve + dual-simplex warm restarts).
    let mut session = Solver::new().build();
    let mut tf_of = |n: usize, m: usize| -> Result<f64> {
        let sub = spec.with_n_sources(n).with_m_processors(m);
        let resp = session
            .solve(&SolveRequest::new(Family::NoFrontend, sub))
            .map_err(|e| e.into_error())?;
        Ok(resp.makespan)
    };
    let mut out = Vec::new();
    for m in 1..=max_processors {
        // Single-source baseline for this m.
        let base = tf_of(1, m)?;
        for &p in source_counts {
            let tf = if p == 1 { base } else { tf_of(p, m)? };
            out.push(SpeedupPoint {
                sources: p,
                processors: m,
                tf,
                speedup: speedup(base, tf),
            });
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Table 4: homogeneous G=0.5, R=0, A=2.
    fn table4_spec(n_sources: usize, m_procs: usize) -> SystemSpec {
        let mut b = SystemSpec::builder();
        for _ in 0..n_sources {
            b = b.source(0.5, 0.0);
        }
        b.processors(&vec![2.0; m_procs]).job(100.0).build().unwrap()
    }

    #[test]
    fn speedup_of_one_source_is_one() {
        let pts = sweep(&table4_spec(3, 4), &[1], 4).unwrap();
        for p in pts {
            assert!((p.speedup - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn more_sources_never_slower() {
        let pts = sweep(&table4_spec(3, 6), &[1, 2, 3], 6).unwrap();
        for m in 1..=6 {
            let at = |src: usize| {
                pts.iter()
                    .find(|p| p.sources == src && p.processors == m)
                    .unwrap()
                    .speedup
            };
            assert!(at(2) >= at(1) - 1e-7, "m={m}");
            assert!(at(3) >= at(2) - 1e-7, "m={m}");
        }
    }

    #[test]
    fn speedup_grows_with_processors() {
        // Paper Fig. 15: fitted speedup grows with processor count.
        let pts = sweep(&table4_spec(2, 8), &[2], 8).unwrap();
        let s1 = pts.iter().find(|p| p.processors == 1).unwrap().speedup;
        let s8 = pts.iter().find(|p| p.processors == 8).unwrap().speedup;
        assert!(s8 > s1, "{s8} !> {s1}");
    }

    #[test]
    fn speedup_ratio_definition() {
        assert!((speedup(10.0, 5.0) - 2.0).abs() < 1e-12);
    }
}
