//! PJRT artifact runtime.
//!
//! Loads the HLO-text artifacts produced by `python/compile/aot.py`,
//! compiles them once on the PJRT CPU client, caches the executables,
//! and exposes typed entry points for the PDHG solver block and the
//! workload kernel. Python never runs at request time — the artifacts
//! are self-contained.
//!
//! NOTE: `xla::PjRtClient` is `Rc`-based and **not `Send`**; a
//! [`Runtime`] lives and dies on one thread. Threads that need compute
//! (cluster processors) construct their own `Runtime` locally.

pub mod manifest;
pub mod pdhg_exec;
pub mod workload;

pub use manifest::{Manifest, PdhgVariant, WorkloadVariant};
pub use pdhg_exec::PdhgExecutable;
pub use workload::WorkloadExecutable;

use crate::error::{Error, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A loaded PJRT CPU runtime with an executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Open the artifact directory (reads `manifest.json`, creates the
    /// PJRT CPU client).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PjRtClient::cpu: {e}")))?;
        Ok(Runtime { client, dir, manifest, cache: HashMap::new() })
    }

    /// Default artifact directory: `$DLT_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<Runtime> {
        let dir = std::env::var("DLT_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Runtime::open(dir)
    }

    /// The manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the named artifact.
    pub fn load(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let file = self
                .manifest
                .file_for(name)
                .ok_or_else(|| Error::Artifact(format!("unknown artifact `{name}`")))?;
            let path = self.dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| Error::Artifact(format!("parse {}: {e}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| Error::Runtime(format!("compile `{name}`: {e}")))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Execute a cached artifact on literal inputs; returns the
    /// flattened tuple of output literals.
    pub fn execute(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.load(name)?;
        let out = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| Error::Runtime(format!("execute `{name}`: {e}")))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("fetch output of `{name}`: {e}")))?;
        lit.to_tuple().map_err(|e| Error::Runtime(format!("untuple `{name}`: {e}")))
    }

    /// True when the artifact directory exists and has a manifest —
    /// used by tests/benches to skip gracefully before `make artifacts`.
    pub fn artifacts_available() -> bool {
        let dir = std::env::var("DLT_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Path::new(&dir).join("manifest.json").exists()
    }
}

/// Build an f64 vector literal with shape `dims`.
pub fn lit_f64(data: &[f64], dims: &[i64]) -> Result<xla::Literal> {
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| Error::Runtime(format!("literal reshape: {e}")))
}

/// Build an f32 vector literal with shape `dims`.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| Error::Runtime(format!("literal reshape: {e}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lit_roundtrip() {
        let l = lit_f64(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f64>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn missing_dir_errors() {
        assert!(Runtime::open("/nonexistent/artifacts").is_err());
    }

    // Runtime execution tests live in rust/tests/runtime_integration.rs
    // and are gated on `make artifacts` having run.
}
