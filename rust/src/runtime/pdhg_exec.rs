//! Typed wrapper around the PDHG-block artifact.

use crate::error::{Error, Result};
use crate::runtime::{lit_f64, Runtime};

/// One PDHG block execution's outputs.
#[derive(Debug, Clone)]
pub struct PdhgBlockOut {
    /// Primal iterate (padded length `nv`).
    pub x: Vec<f64>,
    /// Dual iterate (padded length `nc`).
    pub y: Vec<f64>,
    /// Infinity-norm primal feasibility residual.
    pub primal_res: f64,
    /// Dual stationarity residual.
    pub dual_res: f64,
    /// |c'x + b'y| duality-gap surrogate.
    pub gap: f64,
}

/// A bound PDHG artifact: fixed padded shape, reusable across calls.
pub struct PdhgExecutable<'rt> {
    rt: &'rt mut Runtime,
    name: String,
    /// Padded variable count.
    pub nv: usize,
    /// Padded constraint count.
    pub nc: usize,
    /// Iterations per execution.
    pub steps: usize,
}

impl<'rt> PdhgExecutable<'rt> {
    /// Bind the smallest variant that fits `nv × nc`, compiling it.
    pub fn for_shape(rt: &'rt mut Runtime, nv: usize, nc: usize) -> Result<PdhgExecutable<'rt>> {
        let var = rt
            .manifest()
            .pdhg_variant_for(nv, nc)
            .ok_or_else(|| {
                Error::Artifact(format!("no PDHG variant fits nv={nv}, nc={nc}"))
            })?
            .clone();
        rt.load(&var.name)?;
        Ok(PdhgExecutable { rt, name: var.name, nv: var.nv, nc: var.nc, steps: var.steps })
    }

    /// Run one block of `steps` iterations.
    ///
    /// All slices must already be padded to (`nv`, `nc`).
    #[allow(clippy::too_many_arguments)]
    pub fn run_block(
        &mut self,
        a: &[f64],       // nc*nv row-major
        at: &[f64],      // nv*nc row-major
        b: &[f64],       // nc
        c: &[f64],       // nv
        eq_mask: &[f64], // nc
        x: &[f64],       // nv
        y: &[f64],       // nc
        tau: f64,
        sigma: f64,
    ) -> Result<PdhgBlockOut> {
        let (nv, nc) = (self.nv, self.nc);
        debug_assert_eq!(a.len(), nc * nv);
        debug_assert_eq!(at.len(), nv * nc);
        let inputs = [
            lit_f64(a, &[nc as i64, nv as i64])?,
            lit_f64(at, &[nv as i64, nc as i64])?,
            lit_f64(b, &[nc as i64])?,
            lit_f64(c, &[nv as i64])?,
            lit_f64(eq_mask, &[nc as i64])?,
            lit_f64(x, &[nv as i64])?,
            lit_f64(y, &[nc as i64])?,
            xla::Literal::scalar(tau),
            xla::Literal::scalar(sigma),
        ];
        let outs = self.rt.execute(&self.name, &inputs)?;
        if outs.len() != 5 {
            return Err(Error::Runtime(format!("pdhg block returned {} outputs", outs.len())));
        }
        let x = outs[0].to_vec::<f64>().map_err(wrap)?;
        let y = outs[1].to_vec::<f64>().map_err(wrap)?;
        let primal_res = outs[2].to_vec::<f64>().map_err(wrap)?[0];
        let dual_res = outs[3].to_vec::<f64>().map_err(wrap)?[0];
        let gap = outs[4].to_vec::<f64>().map_err(wrap)?[0];
        Ok(PdhgBlockOut { x, y, primal_res, dual_res, gap })
    }
}

fn wrap(e: xla::Error) -> Error {
    Error::Runtime(format!("pdhg output fetch: {e}"))
}
