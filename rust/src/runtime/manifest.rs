//! `artifacts/manifest.json` — metadata emitted by `compile/aot.py`.

use crate::config::json::Json;
use crate::error::{Error, Result};
use std::path::Path;

/// A compiled PDHG block variant (padded LP shape).
#[derive(Debug, Clone)]
pub struct PdhgVariant {
    /// Artifact name (cache key).
    pub name: String,
    /// File name inside the artifact dir.
    pub file: String,
    /// Padded variable count.
    pub nv: usize,
    /// Padded constraint-row count.
    pub nc: usize,
    /// PDHG iterations per execution.
    pub steps: usize,
}

/// A compiled workload-kernel variant.
#[derive(Debug, Clone)]
pub struct WorkloadVariant {
    /// Artifact name (cache key).
    pub name: String,
    /// File name inside the artifact dir.
    pub file: String,
    /// Chunk rows.
    pub rows: usize,
    /// Chunk cols.
    pub cols: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    /// PDHG variants, ascending by size.
    pub pdhg: Vec<PdhgVariant>,
    /// Workload variants.
    pub workload: Vec<WorkloadVariant>,
}

impl Manifest {
    /// Load and parse `manifest.json`.
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        Manifest::parse(&text)
    }

    /// Parse manifest JSON text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text)?;
        let mut pdhg = Vec::new();
        for e in v.req("pdhg")?.as_array()? {
            pdhg.push(PdhgVariant {
                name: e.req("name")?.as_str()?.to_string(),
                file: e.req("file")?.as_str()?.to_string(),
                nv: e.req("nv")?.as_usize()?,
                nc: e.req("nc")?.as_usize()?,
                steps: e.req("steps")?.as_usize()?,
            });
        }
        pdhg.sort_by_key(|p| p.nv);
        let mut workload = Vec::new();
        for e in v.req("workload")?.as_array()? {
            workload.push(WorkloadVariant {
                name: e.req("name")?.as_str()?.to_string(),
                file: e.req("file")?.as_str()?.to_string(),
                rows: e.req("rows")?.as_usize()?,
                cols: e.req("cols")?.as_usize()?,
            });
        }
        Ok(Manifest { pdhg, workload })
    }

    /// File name for an artifact, if known.
    pub fn file_for(&self, name: &str) -> Option<&str> {
        self.pdhg
            .iter()
            .find(|p| p.name == name)
            .map(|p| p.file.as_str())
            .or_else(|| self.workload.iter().find(|w| w.name == name).map(|w| w.file.as_str()))
    }

    /// Smallest PDHG variant that fits an `nv × nc` LP.
    pub fn pdhg_variant_for(&self, nv: usize, nc: usize) -> Option<&PdhgVariant> {
        self.pdhg.iter().find(|p| p.nv >= nv && p.nc >= nc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "pdhg": [
        {"name": "pdhg_big", "file": "big.hlo.txt", "nv": 256, "nc": 384, "steps": 200, "dtype": "f64"},
        {"name": "pdhg_small", "file": "small.hlo.txt", "nv": 128, "nc": 192, "steps": 200, "dtype": "f64"}
      ],
      "workload": [
        {"name": "workload_r128_c128", "file": "w.hlo.txt", "rows": 128, "cols": 128, "dtype": "f32"}
      ]
    }"#;

    #[test]
    fn parse_and_sort() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.pdhg.len(), 2);
        assert_eq!(m.pdhg[0].name, "pdhg_small", "sorted ascending by nv");
        assert_eq!(m.workload[0].rows, 128);
    }

    #[test]
    fn variant_selection() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.pdhg_variant_for(61, 61).unwrap().name, "pdhg_small");
        assert_eq!(m.pdhg_variant_for(181, 183).unwrap().name, "pdhg_big");
        assert!(m.pdhg_variant_for(1000, 10).is_none());
    }

    #[test]
    fn file_lookup() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.file_for("pdhg_big").unwrap(), "big.hlo.txt");
        assert_eq!(m.file_for("workload_r128_c128").unwrap(), "w.hlo.txt");
        assert!(m.file_for("nope").is_none());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        if let Ok(text) = std::fs::read_to_string("artifacts/manifest.json") {
            let m = Manifest::parse(&text).unwrap();
            assert!(!m.pdhg.is_empty());
            assert!(!m.workload.is_empty());
        }
    }
}
