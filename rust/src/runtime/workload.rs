//! Typed wrapper around the workload-kernel artifact — the "real
//! compute" cluster processors execute per unit of divisible load.

use crate::error::{Error, Result};
use crate::runtime::{lit_f32, Runtime};
use crate::util::rng::{Pcg32, Rng};

/// A bound workload artifact plus a reusable input chunk.
pub struct WorkloadExecutable {
    rt: Runtime,
    name: String,
    /// Chunk rows.
    pub rows: usize,
    /// Chunk cols.
    pub cols: usize,
    data: Vec<f32>,
    weights: Vec<f32>,
}

impl WorkloadExecutable {
    /// Open the default runtime and bind the first workload variant.
    /// `seed` generates the synthetic chunk contents deterministically.
    pub fn open(dir: &str, seed: u64) -> Result<WorkloadExecutable> {
        let mut rt = Runtime::open(dir)?;
        let var = rt
            .manifest()
            .workload
            .first()
            .ok_or_else(|| Error::Artifact("manifest has no workload variants".into()))?
            .clone();
        rt.load(&var.name)?;
        let mut rng = Pcg32::new(seed);
        let data: Vec<f32> =
            (0..var.rows * var.cols).map(|_| rng.f64() as f32 - 0.5).collect();
        let weights: Vec<f32> =
            (0..var.cols * var.cols).map(|_| rng.f64() as f32 - 0.5).collect();
        Ok(WorkloadExecutable { rt, name: var.name, rows: var.rows, cols: var.cols, data, weights })
    }

    /// Execute one work unit; returns a checksum of the scores (so the
    /// work cannot be optimized away and results can be sanity-checked).
    pub fn run_unit(&mut self) -> Result<f64> {
        let inputs = [
            lit_f32(&self.data, &[self.rows as i64, self.cols as i64])?,
            lit_f32(&self.weights, &[self.cols as i64, self.cols as i64])?,
        ];
        let outs = self.rt.execute(&self.name, &inputs)?;
        let scores = outs[0]
            .to_vec::<f32>()
            .map_err(|e| Error::Runtime(format!("workload output: {e}")))?;
        Ok(scores.iter().map(|&s| s as f64).sum())
    }

    /// Execute `n` work units, returning the accumulated checksum.
    pub fn run_units(&mut self, n: usize) -> Result<f64> {
        let mut acc = 0.0;
        for _ in 0..n {
            acc += self.run_unit()?;
        }
        Ok(acc)
    }

    /// Measure seconds per work unit (for calibrating `A_j` in the
    /// cluster e2e example).
    pub fn calibrate(&mut self, units: usize) -> Result<f64> {
        // One untimed warm-up unit.
        self.run_unit()?;
        let t0 = std::time::Instant::now();
        self.run_units(units.max(1))?;
        Ok(t0.elapsed().as_secs_f64() / units.max(1) as f64)
    }
}
