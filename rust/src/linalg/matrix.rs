//! Row-major dense `f64` matrix with LU factorization.

use crate::error::{Error, Result};

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix of the given shape.
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major vec. Panics if sizes disagree.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Matrix { rows, cols, data }
    }

    /// Build from nested rows. Panics on ragged input.
    pub fn from_rows(rows: &[Vec<f64>]) -> Matrix {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Borrow a row as a slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row access.
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Flat data access (row-major).
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Matrix–vector product `A x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            y[i] = super::dot(self.row(i), x);
        }
        y
    }

    /// Transposed matrix–vector product `Aᵀ y`.
    pub fn matvec_t(&self, y: &[f64]) -> Vec<f64> {
        assert_eq!(y.len(), self.rows);
        let mut x = vec![0.0; self.cols];
        for i in 0..self.rows {
            let yi = y[i];
            if yi == 0.0 {
                continue;
            }
            let row = self.row(i);
            for j in 0..self.cols {
                x[j] += row[j] * yi;
            }
        }
        x
    }

    /// Matrix product `A B`.
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.rows);
        let mut out = Matrix::zeros(self.rows, b.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self[(i, k)];
                if aik == 0.0 {
                    continue;
                }
                for j in 0..b.cols {
                    out[(i, j)] += aik * b[(k, j)];
                }
            }
        }
        out
    }

    /// Largest singular value estimate via power iteration on `AᵀA`.
    /// Used to pick PDHG step sizes. `iters` ~ 50 is plenty here.
    pub fn spectral_norm_est(&self, iters: usize, seed: u64) -> f64 {
        use crate::util::rng::{Pcg32, Rng};
        if self.rows == 0 || self.cols == 0 {
            return 0.0;
        }
        let mut rng = Pcg32::new(seed);
        let mut v: Vec<f64> = (0..self.cols).map(|_| rng.f64() - 0.5).collect();
        let mut norm = super::norm2(&v);
        if norm == 0.0 {
            v[0] = 1.0;
            norm = 1.0;
        }
        for x in v.iter_mut() {
            *x /= norm;
        }
        let mut sigma = 0.0;
        for _ in 0..iters {
            let av = self.matvec(&v);
            let atav = self.matvec_t(&av);
            let n = super::norm2(&atav);
            if n == 0.0 {
                return 0.0;
            }
            sigma = n.sqrt();
            for (vi, &ai) in v.iter_mut().zip(atav.iter()) {
                *vi = ai / n;
            }
        }
        sigma
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

/// Solve `A x = b` by LU with partial pivoting. `A` must be square.
pub fn lu_solve(a: &Matrix, b: &[f64]) -> Result<Vec<f64>> {
    let n = a.rows();
    if a.cols() != n {
        return Err(Error::Numerical(format!("lu_solve: non-square {}x{}", a.rows(), a.cols())));
    }
    if b.len() != n {
        return Err(Error::Numerical("lu_solve: rhs length mismatch".into()));
    }
    let mut lu = a.clone();
    let mut x = b.to_vec();
    let mut perm: Vec<usize> = (0..n).collect();

    for k in 0..n {
        // Partial pivot.
        let mut p = k;
        let mut max = lu[(k, k)].abs();
        for i in (k + 1)..n {
            let v = lu[(i, k)].abs();
            if v > max {
                max = v;
                p = i;
            }
        }
        if max < 1e-13 {
            return Err(Error::Numerical(format!("lu_solve: singular at pivot {k}")));
        }
        if p != k {
            perm.swap(p, k);
            // Swap rows p and k.
            for j in 0..n {
                let tmp = lu[(k, j)];
                lu[(k, j)] = lu[(p, j)];
                lu[(p, j)] = tmp;
            }
            x.swap(p, k);
        }
        let pivot = lu[(k, k)];
        for i in (k + 1)..n {
            let factor = lu[(i, k)] / pivot;
            lu[(i, k)] = factor;
            if factor != 0.0 {
                for j in (k + 1)..n {
                    let v = lu[(k, j)];
                    lu[(i, j)] -= factor * v;
                }
                x[i] -= factor * x[k];
            }
        }
    }
    // Back substitution.
    for i in (0..n).rev() {
        let mut acc = x[i];
        for j in (i + 1)..n {
            acc -= lu[(i, j)] * x[j];
        }
        x[i] = acc / lu[(i, i)];
    }
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::float::approx_eq_eps;

    #[test]
    fn index_and_eye() {
        let m = Matrix::eye(3);
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(0, 1)], 0.0);
    }

    #[test]
    fn matvec_basic() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(a.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
        assert_eq!(a.matvec_t(&[1.0, 1.0]), vec![4.0, 6.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let i = Matrix::eye(2);
        assert_eq!(a.matmul(&i), a);
    }

    #[test]
    fn lu_solves_small_system() {
        let a = Matrix::from_rows(&[
            vec![2.0, 1.0, -1.0],
            vec![-3.0, -1.0, 2.0],
            vec![-2.0, 1.0, 2.0],
        ]);
        let x = lu_solve(&a, &[8.0, -11.0, -3.0]).unwrap();
        let expect = [2.0, 3.0, -1.0];
        for (xi, ei) in x.iter().zip(expect.iter()) {
            assert!(approx_eq_eps(*xi, *ei, 1e-10, 1e-10), "{x:?}");
        }
    }

    #[test]
    fn lu_requires_pivoting() {
        // Zero on the diagonal forces a row swap.
        let a = Matrix::from_rows(&[vec![0.0, 1.0], vec![1.0, 0.0]]);
        let x = lu_solve(&a, &[2.0, 3.0]).unwrap();
        assert!(approx_eq_eps(x[0], 3.0, 1e-12, 1e-12));
        assert!(approx_eq_eps(x[1], 2.0, 1e-12, 1e-12));
    }

    #[test]
    fn lu_detects_singular() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 4.0]]);
        assert!(lu_solve(&a, &[1.0, 2.0]).is_err());
    }

    #[test]
    fn spectral_norm_of_diagonal() {
        let mut a = Matrix::zeros(3, 3);
        a[(0, 0)] = 3.0;
        a[(1, 1)] = 1.0;
        a[(2, 2)] = 0.5;
        let s = a.spectral_norm_est(100, 42);
        assert!((s - 3.0).abs() < 1e-6, "{s}");
    }

    #[test]
    fn lu_random_roundtrip() {
        use crate::util::rng::{Pcg32, Rng};
        let mut rng = Pcg32::new(9);
        for n in [1usize, 2, 5, 20] {
            let mut a = Matrix::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    a[(i, j)] = rng.f64() - 0.5;
                }
                a[(i, i)] += 2.0; // diagonally dominant => nonsingular
            }
            let x_true: Vec<f64> = (0..n).map(|_| rng.f64() * 10.0).collect();
            let b = a.matvec(&x_true);
            let x = lu_solve(&a, &b).unwrap();
            for (xi, ti) in x.iter().zip(x_true.iter()) {
                assert!(approx_eq_eps(*xi, *ti, 1e-8, 1e-8));
            }
        }
    }
}
