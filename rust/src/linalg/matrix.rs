//! Row-major dense `f64` matrix with LU factorization.

use crate::error::{Error, Result};
use crate::linalg::{SparseMatrix, SparseVector};

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix of the given shape.
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major vec. Panics if sizes disagree.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Matrix { rows, cols, data }
    }

    /// Build from nested rows. Panics on ragged input.
    pub fn from_rows(rows: &[Vec<f64>]) -> Matrix {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Borrow a row as a slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row access.
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Flat data access (row-major).
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Matrix–vector product `A x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// Matrix–vector product `A x` into a caller-owned buffer
    /// (allocation-free variant for hot loops).
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for i in 0..self.rows {
            y[i] = super::dot(self.row(i), x);
        }
    }

    /// Transposed matrix–vector product `Aᵀ y`.
    pub fn matvec_t(&self, y: &[f64]) -> Vec<f64> {
        let mut x = vec![0.0; self.cols];
        self.matvec_t_into(y, &mut x);
        x
    }

    /// Transposed matrix–vector product `Aᵀ y` into a caller-owned
    /// buffer (allocation-free variant for hot loops).
    pub fn matvec_t_into(&self, y: &[f64], x: &mut [f64]) {
        assert_eq!(y.len(), self.rows);
        assert_eq!(x.len(), self.cols);
        x.iter_mut().for_each(|v| *v = 0.0);
        for i in 0..self.rows {
            let yi = y[i];
            if yi == 0.0 {
                continue;
            }
            let row = self.row(i);
            for j in 0..self.cols {
                x[j] += row[j] * yi;
            }
        }
    }

    /// Matrix product `A B`.
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.rows);
        let mut out = Matrix::zeros(self.rows, b.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self[(i, k)];
                if aik == 0.0 {
                    continue;
                }
                for j in 0..b.cols {
                    out[(i, j)] += aik * b[(k, j)];
                }
            }
        }
        out
    }

    /// Largest singular value estimate via power iteration on `AᵀA`.
    /// Used to pick PDHG step sizes. `iters` ~ 50 is plenty here.
    pub fn spectral_norm_est(&self, iters: usize, seed: u64) -> f64 {
        use crate::util::rng::{Pcg32, Rng};
        if self.rows == 0 || self.cols == 0 {
            return 0.0;
        }
        let mut rng = Pcg32::new(seed);
        let mut v: Vec<f64> = (0..self.cols).map(|_| rng.f64() - 0.5).collect();
        let mut norm = super::norm2(&v);
        if norm == 0.0 {
            v[0] = 1.0;
            norm = 1.0;
        }
        for x in v.iter_mut() {
            *x /= norm;
        }
        let mut sigma = 0.0;
        let mut av = vec![0.0; self.rows];
        let mut atav = vec![0.0; self.cols];
        for _ in 0..iters {
            self.matvec_into(&v, &mut av);
            self.matvec_t_into(&av, &mut atav);
            let n = super::norm2(&atav);
            if n == 0.0 {
                return 0.0;
            }
            sigma = n.sqrt();
            for (vi, &ai) in v.iter_mut().zip(atav.iter()) {
                *vi = ai / n;
            }
        }
        sigma
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

/// Solve `A x = b` by LU with partial pivoting. `A` must be square.
///
/// One-shot convenience over [`LuFactors`]; callers that solve against
/// the same matrix repeatedly should factor once and reuse.
pub fn lu_solve(a: &Matrix, b: &[f64]) -> Result<Vec<f64>> {
    if b.len() != a.rows() {
        return Err(Error::Numerical("lu_solve: rhs length mismatch".into()));
    }
    let f = LuFactors::factor(a)?;
    let mut x = vec![0.0; b.len()];
    f.solve_into(b, &mut x);
    Ok(x)
}

/// How the hypersparse triangular solves pick their processing order.
///
/// `Auto` is the production setting: a per-solve crossover on the
/// right-hand-side density chooses between the Gilbert–Peierls
/// symbolic DFS (work proportional to the *result* nonzeros) and the
/// plain column sweep (work proportional to `n`). The forced modes
/// exist for benches and tests that need to compare both kernels on
/// identical inputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SolveMode {
    /// RHS-density crossover heuristic (the default).
    #[default]
    Auto,
    /// Always the Gilbert–Peierls symbolic DFS reach.
    Dfs,
    /// Always the full column sweep.
    Scan,
}

/// In `Auto` mode a solve takes the DFS path when
/// `rhs_nnz * DFS_CROSSOVER < n`: the symbolic reach only pays for
/// itself when the right-hand side (and hence, typically, the result)
/// is much sparser than the dimension.
const DFS_CROSSOVER: usize = 8;

/// Markowitz threshold-pivot tolerance: a pivot candidate qualifies
/// when its magnitude is at least `MARKOWITZ_TAU` times the column
/// maximum. 0.1 is the textbook sparse-LU compromise between numerical
/// safety (1.0 = plain partial pivoting) and fill-in freedom.
pub const MARKOWITZ_TAU: f64 = 0.1;

/// Reusable LU factorization with partial pivoting (`P A = L U`).
///
/// The factors are stored *row/column sparse*: basis matrices of DLT
/// LPs are ~95 % zeros and mostly stay sparse after elimination, so a
/// triangular solve costs O(nnz(L) + nnz(U)) instead of O(n²). Both
/// `A x = b` and `Aᵀ x = b` solves are supported (the revised simplex
/// needs FTRAN and BTRAN against the same basis factorization).
///
/// The sparse solves are *hypersparse*: for a sufficiently sparse
/// right-hand side they run a Gilbert–Peierls symbolic DFS over the
/// factor graph first, so only the topological closure of the RHS
/// nonzeros is ever visited — no O(n) column scan (see [`SolveMode`]).
#[derive(Debug, Clone)]
pub struct LuFactors {
    n: usize,
    /// `perm[i]` = original row that ended up in pivot position `i`.
    perm: Vec<usize>,
    /// `iperm[orig_row]` = pivot position of that row (inverse perm).
    iperm: Vec<usize>,
    /// Row `i` of `L` strictly below the diagonal: `(col j < i, l_ij)`.
    l_rows: Vec<Vec<(usize, f64)>>,
    /// Row `i` of `U` strictly above the diagonal: `(col j > i, u_ij)`.
    u_rows: Vec<Vec<(usize, f64)>>,
    /// Diagonal of `U`.
    u_diag: Vec<f64>,
    /// Column `j` of `L` strictly below the diagonal: `(row i > j, l_ij)`.
    l_cols: Vec<Vec<(usize, f64)>>,
    /// Column `j` of `U` strictly above the diagonal: `(row i < j, u_ij)`.
    u_cols: Vec<Vec<(usize, f64)>>,
    /// Column accumulator for [`LuFactors::refactor_csc`] (kept so
    /// steady-state refactorizations allocate nothing).
    acc: SparseVector,
    /// Solve-order policy for the sparse kernels.
    mode: SolveMode,
    /// Visited marks for the symbolic DFS, generation-stamped so a new
    /// reach is a counter bump, not an O(n) reset.
    stamp: Vec<u32>,
    /// Current stamp generation (0 = everything unvisited).
    stamp_gen: u32,
    /// Explicit DFS stack of `(node, next adjacency position)`.
    dfs_stack: Vec<(usize, usize)>,
    /// Postorder of the last reach; solves process it in reverse
    /// (reverse postorder = topological order of the column DAG).
    dfs_order: Vec<usize>,
    /// Sparse solves answered by the symbolic DFS since construction.
    dfs_solves: usize,
    /// Sparse solves answered by the full column sweep.
    scan_solves: usize,
    /// Nodes visited by the most recent sparse solve (DFS: reach sizes;
    /// scan: `n` per sweep) — the work-∝-result-nnz diagnostic.
    last_work: usize,
    /// Static per-row nonzero counts of the input, used by the
    /// Markowitz pivot rule (reused across refactorizations).
    row_counts: Vec<usize>,
}

/// Iterative DFS over the column adjacency `adj` from `seeds`,
/// appending the postorder of every newly reached node to `order`.
/// Nodes whose stamp equals `gen` are treated as already visited, so
/// callers mark-and-reuse across passes by bumping `gen`.
fn reach(
    adj: &[Vec<(usize, f64)>],
    seeds: &[usize],
    stamp: &mut [u32],
    gen: u32,
    stack: &mut Vec<(usize, usize)>,
    order: &mut Vec<usize>,
) {
    order.clear();
    for &s in seeds {
        if stamp[s] == gen {
            continue;
        }
        stamp[s] = gen;
        stack.push((s, 0));
        while let Some(top) = stack.last_mut() {
            let (node, pos) = *top;
            if let Some(&(child, _)) = adj[node].get(pos) {
                top.1 = pos + 1;
                if stamp[child] != gen {
                    stamp[child] = gen;
                    stack.push((child, 0));
                }
            } else {
                order.push(node);
                stack.pop();
            }
        }
    }
}

/// Clear every inner vector and (re)size the outer one to `n`,
/// keeping inner capacities — the refactorization storage discipline.
fn clear_nested(v: &mut Vec<Vec<(usize, f64)>>, n: usize) {
    for inner in v.iter_mut() {
        inner.clear();
    }
    if v.len() > n {
        v.truncate(n);
    } else {
        v.resize_with(n, Vec::new);
    }
}

impl LuFactors {
    /// Factorization of the identity (the all-slack/artificial simplex
    /// start basis).
    pub fn identity(n: usize) -> LuFactors {
        LuFactors {
            n,
            perm: (0..n).collect(),
            iperm: (0..n).collect(),
            l_rows: vec![Vec::new(); n],
            u_rows: vec![Vec::new(); n],
            u_diag: vec![1.0; n],
            l_cols: vec![Vec::new(); n],
            u_cols: vec![Vec::new(); n],
            acc: SparseVector::default(),
            mode: SolveMode::Auto,
            stamp: Vec::new(),
            stamp_gen: 0,
            dfs_stack: Vec::new(),
            dfs_order: Vec::new(),
            dfs_solves: 0,
            scan_solves: 0,
            last_work: 0,
            row_counts: Vec::new(),
        }
    }

    /// Reset to the identity factorization in place, reusing storage.
    pub fn reset_identity(&mut self, n: usize) {
        self.n = n;
        self.perm.clear();
        self.perm.extend(0..n);
        self.iperm.clear();
        self.iperm.extend(0..n);
        clear_nested(&mut self.l_rows, n);
        clear_nested(&mut self.u_rows, n);
        clear_nested(&mut self.l_cols, n);
        clear_nested(&mut self.u_cols, n);
        self.u_diag.clear();
        self.u_diag.resize(n, 1.0);
    }

    /// Factor a square matrix. Errors when (numerically) singular.
    pub fn factor(a: &Matrix) -> Result<LuFactors> {
        let n = a.rows();
        if a.cols() != n {
            return Err(Error::Numerical(format!(
                "lu factor: non-square {}x{}",
                a.rows(),
                a.cols()
            )));
        }
        let mut lu = a.data().to_vec();
        let mut perm: Vec<usize> = (0..n).collect();

        for k in 0..n {
            // Partial pivot.
            let mut p = k;
            let mut max = lu[k * n + k].abs();
            for i in (k + 1)..n {
                let v = lu[i * n + k].abs();
                if v > max {
                    max = v;
                    p = i;
                }
            }
            if max < 1e-13 {
                return Err(Error::Numerical(format!("lu factor: singular at pivot {k}")));
            }
            if p != k {
                perm.swap(p, k);
                for j in 0..n {
                    lu.swap(k * n + j, p * n + j);
                }
            }
            let pivot = lu[k * n + k];
            for i in (k + 1)..n {
                let factor = lu[i * n + k] / pivot;
                lu[i * n + k] = factor;
                if factor != 0.0 {
                    for j in (k + 1)..n {
                        let v = lu[k * n + j];
                        if v != 0.0 {
                            lu[i * n + j] -= factor * v;
                        }
                    }
                }
            }
        }

        // Extract sparse row/column views of the factors.
        let mut l_rows: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n];
        let mut u_rows: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n];
        let mut l_cols: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n];
        let mut u_cols: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n];
        let mut u_diag = vec![0.0; n];
        for i in 0..n {
            for j in 0..n {
                let v = lu[i * n + j];
                if i == j {
                    u_diag[i] = v;
                } else if v != 0.0 {
                    if j < i {
                        l_rows[i].push((j, v));
                        l_cols[j].push((i, v));
                    } else {
                        u_rows[i].push((j, v));
                        u_cols[j].push((i, v));
                    }
                }
            }
        }
        let mut iperm = vec![0usize; n];
        for (i, &p) in perm.iter().enumerate() {
            iperm[p] = i;
        }
        Ok(LuFactors {
            n,
            perm,
            iperm,
            l_rows,
            u_rows,
            u_diag,
            l_cols,
            u_cols,
            acc: SparseVector::default(),
            mode: SolveMode::Auto,
            stamp: Vec::new(),
            stamp_gen: 0,
            dfs_stack: Vec::new(),
            dfs_order: Vec::new(),
            dfs_solves: 0,
            scan_solves: 0,
            last_work: 0,
            row_counts: Vec::new(),
        })
    }

    /// Factor a square CSC matrix without ever densifying it:
    /// left-looking column LU with partial pivoting. Peak memory is
    /// O(nnz(L) + nnz(U) + n) — the sparse replacement for
    /// [`LuFactors::factor`]'s dense O(n²) working copy.
    pub fn factor_csc(a: &SparseMatrix) -> Result<LuFactors> {
        let mut f = LuFactors::identity(a.rows());
        f.refactor_csc(a)?;
        Ok(f)
    }

    /// [`LuFactors::factor_csc`] with the Markowitz threshold pivot
    /// rule (see [`LuFactors::refactor_csc_markowitz`]).
    pub fn factor_csc_markowitz(a: &SparseMatrix) -> Result<LuFactors> {
        let mut f = LuFactors::identity(a.rows());
        f.refactor_csc_markowitz(a)?;
        Ok(f)
    }

    /// Re-factor a square CSC matrix into this object, reusing all
    /// existing storage (steady-state refactorizations in a warm sweep
    /// allocate nothing once the inner vectors have grown).
    ///
    /// Left-looking column algorithm: column `j` is scattered into a
    /// sparse accumulator, the already-computed `L` columns are applied
    /// in pivot order (skipping those whose pivot entry is zero — the
    /// hypersparse shortcut), the largest unpivoted entry is chosen as
    /// the pivot, and the accumulator splits into a `U` column
    /// (pivoted rows) and a scaled `L` column (unpivoted rows).
    pub fn refactor_csc(&mut self, a: &SparseMatrix) -> Result<()> {
        self.refactor_impl(a, false)
    }

    /// [`LuFactors::refactor_csc`] with a fill-in-aware pivot choice:
    /// among the threshold-eligible candidates of each column (entries
    /// within [`MARKOWITZ_TAU`] of the column maximum), pick the one in
    /// the *sparsest row* of the input. With the column order fixed by
    /// the left-looking sweep, the Markowitz cost `(r_i − 1)(c_j − 1)`
    /// of a candidate varies only through its row count `r_i`, so
    /// minimizing `r_i` among eligible entries *is* the column-wise
    /// Markowitz-minimal choice; static row counts of `A` are the
    /// standard approximation to the exact (dynamically updated)
    /// counts.
    pub fn refactor_csc_markowitz(&mut self, a: &SparseMatrix) -> Result<()> {
        self.refactor_impl(a, true)
    }

    fn refactor_impl(&mut self, a: &SparseMatrix, markowitz: bool) -> Result<()> {
        let n = a.rows();
        if a.cols() != n {
            return Err(Error::Numerical(format!(
                "lu factor (csc): non-square {}x{}",
                a.rows(),
                a.cols()
            )));
        }
        self.n = n;
        self.perm.clear();
        self.perm.resize(n, usize::MAX);
        // `iperm` doubles as the "pivoted yet?" map during the sweep.
        self.iperm.clear();
        self.iperm.resize(n, usize::MAX);
        clear_nested(&mut self.l_rows, n);
        clear_nested(&mut self.u_rows, n);
        clear_nested(&mut self.l_cols, n);
        clear_nested(&mut self.u_cols, n);
        self.u_diag.clear();
        self.u_diag.resize(n, 0.0);
        self.acc.resize_clear(n);
        if markowitz {
            self.row_counts.clear();
            self.row_counts.resize(n, 0);
            for j in 0..n {
                for (i, _) in a.col(j) {
                    self.row_counts[i] += 1;
                }
            }
        }

        for j in 0..n {
            for (i, v) in a.col(j) {
                self.acc.set(i, v);
            }
            // Left-looking elimination, ascending pivot order.
            for step in 0..j {
                let pr = self.perm[step];
                let xv = self.acc.get(pr);
                if xv == 0.0 {
                    continue;
                }
                for &(i, l) in &self.l_cols[step] {
                    self.acc.add(i, -l * xv);
                }
            }
            // Partial pivot among unpivoted rows.
            let mut p = usize::MAX;
            let mut pmax = 0.0f64;
            for &i in self.acc.indices() {
                if self.iperm[i] != usize::MAX {
                    continue;
                }
                let v = self.acc.get(i).abs();
                if v > pmax {
                    pmax = v;
                    p = i;
                }
            }
            if p == usize::MAX || pmax < 1e-13 {
                self.acc.clear();
                return Err(Error::Numerical(format!(
                    "lu factor (csc): singular at pivot {j}"
                )));
            }
            if markowitz {
                // Threshold pivoting: any candidate within MARKOWITZ_TAU
                // of the column max is numerically acceptable; among
                // those, prefer the sparsest input row (least expected
                // fill-in), breaking ties toward the larger magnitude.
                let mut best = p;
                let mut best_count = self.row_counts[p];
                let mut best_mag = pmax;
                for &i in self.acc.indices() {
                    if self.iperm[i] != usize::MAX {
                        continue;
                    }
                    let mag = self.acc.get(i).abs();
                    if mag < MARKOWITZ_TAU * pmax {
                        continue;
                    }
                    let count = self.row_counts[i];
                    if count < best_count || (count == best_count && mag > best_mag) {
                        best = i;
                        best_count = count;
                        best_mag = mag;
                    }
                }
                p = best;
            }
            let pivot = self.acc.get(p);
            self.perm[j] = p;
            self.iperm[p] = j;
            self.u_diag[j] = pivot;
            // Split the accumulator: pivoted rows -> U column `j`
            // (indexed by pivot step), unpivoted -> L column `j`
            // (original-row indices, remapped after the sweep).
            for k in 0..self.acc.nnz() {
                let i = self.acc.index_at(k);
                if i == p {
                    continue;
                }
                let v = self.acc.get(i);
                if v == 0.0 {
                    continue;
                }
                let step = self.iperm[i];
                if step != usize::MAX {
                    self.u_cols[j].push((step, v));
                } else {
                    self.l_cols[j].push((i, v / pivot));
                }
            }
            self.acc.clear();
        }

        // Remap L entries from original-row to pivot-position indices
        // and build the row views both solves need.
        for col in self.l_cols.iter_mut() {
            for e in col.iter_mut() {
                e.0 = self.iperm[e.0];
            }
        }
        for j in 0..n {
            for &(i, l) in &self.l_cols[j] {
                self.l_rows[i].push((j, l));
            }
            for &(i, u) in &self.u_cols[j] {
                self.u_rows[i].push((j, u));
            }
        }
        Ok(())
    }

    /// Stored factor entries (both triangles plus the diagonal) — the
    /// sparse-memory diagnostic a dense `n × n` pair would put at
    /// `2n²`.
    pub fn nnz(&self) -> usize {
        let l: usize = self.l_cols.iter().map(|c| c.len()).sum();
        let u: usize = self.u_cols.iter().map(|c| c.len()).sum();
        l + u + self.n
    }

    /// Upper-factor views `(u_rows, u_cols, u_diag)` for consumers
    /// that maintain their own updated copy of `U` (Forrest–Tomlin).
    pub(crate) fn upper_parts(&self) -> (&[Vec<(usize, f64)>], &[Vec<(usize, f64)>], &[f64]) {
        (&self.u_rows, &self.u_cols, &self.u_diag)
    }

    /// Drop the upper-triangular off-diagonal entries. A consumer that
    /// maintains its own updated `U` (Forrest–Tomlin) calls this after
    /// copying them out, so the factor is not stored twice. Only the
    /// row permutation and the lower factor remain usable — the full
    /// `solve_*` entry points must not be called again until the next
    /// refactorization rebuilds `U` in place (capacities are kept).
    pub(crate) fn clear_upper(&mut self) {
        for c in self.u_cols.iter_mut() {
            c.clear();
        }
        for r in self.u_rows.iter_mut() {
            r.clear();
        }
    }

    /// Dimension.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Force or un-force the sparse-solve processing order (benches and
    /// tests that compare the DFS and scan kernels on identical
    /// inputs; production code leaves this at [`SolveMode::Auto`]).
    pub fn set_solve_mode(&mut self, mode: SolveMode) {
        self.mode = mode;
    }

    /// `(dfs_solves, scan_solves)`: how many sparse triangular solves
    /// took each path since construction (diagnostics; never reset).
    pub fn solve_mode_counts(&self) -> (usize, usize) {
        (self.dfs_solves, self.scan_solves)
    }

    /// Nodes visited by the most recent sparse solve: the sum of the
    /// symbolic reach sizes on the DFS path, or `n` per substitution
    /// sweep on the scan path. The regression tests and
    /// `DLT_BENCH_ASSERT` gates use this to check that DFS work scales
    /// with the result nonzeros, not the dimension.
    pub fn last_solve_work(&self) -> usize {
        self.last_work
    }

    /// Whether a solve with `rhs_nnz` right-hand-side nonzeros takes
    /// the symbolic DFS path under the current [`SolveMode`].
    fn dfs_wanted(&self, rhs_nnz: usize) -> bool {
        match self.mode {
            SolveMode::Auto => rhs_nnz * DFS_CROSSOVER < self.n,
            SolveMode::Dfs => true,
            SolveMode::Scan => false,
        }
    }

    /// Bump the stamp generation (O(1) un-visit of every node),
    /// resizing / rewinding the stamp array on dimension change and
    /// counter wrap-around.
    fn next_stamp(&mut self) -> u32 {
        if self.stamp.len() != self.n {
            self.stamp.clear();
            self.stamp.resize(self.n, 0);
            self.stamp_gen = 0;
        }
        self.stamp_gen = self.stamp_gen.wrapping_add(1);
        if self.stamp_gen == 0 {
            self.stamp.fill(0);
            self.stamp_gen = 1;
        }
        self.stamp_gen
    }

    /// Solve `A x = b` into `out` (allocation-free).
    pub fn solve_into(&self, b: &[f64], out: &mut [f64]) {
        let n = self.n;
        debug_assert_eq!(b.len(), n);
        debug_assert_eq!(out.len(), n);
        // out = P b
        for i in 0..n {
            out[i] = b[self.perm[i]];
        }
        // Forward: L y = P b (unit diagonal).
        for i in 0..n {
            let mut acc = out[i];
            for &(j, l) in &self.l_rows[i] {
                acc -= l * out[j];
            }
            out[i] = acc;
        }
        // Backward: U x = y.
        for i in (0..n).rev() {
            let mut acc = out[i];
            for &(j, u) in &self.u_rows[i] {
                acc -= u * out[j];
            }
            out[i] = acc / self.u_diag[i];
        }
    }

    /// Solve `Aᵀ x = b` into `out`, using `scratch` (both length `n`,
    /// allocation-free). With `P A = L U`: `Aᵀ = Uᵀ Lᵀ P`, so solve
    /// `Uᵀ z = b`, then `Lᵀ w = z`, then `x = Pᵀ w`.
    pub fn solve_transpose_into(&self, b: &[f64], scratch: &mut [f64], out: &mut [f64]) {
        let n = self.n;
        debug_assert_eq!(b.len(), n);
        debug_assert_eq!(scratch.len(), n);
        debug_assert_eq!(out.len(), n);
        // Forward: Uᵀ z = b (row i of Uᵀ is column i of U).
        for i in 0..n {
            let mut acc = b[i];
            for &(j, u) in &self.u_cols[i] {
                acc -= u * scratch[j];
            }
            scratch[i] = acc / self.u_diag[i];
        }
        // Backward: Lᵀ w = z (unit diagonal; row i of Lᵀ is column i of L).
        for i in (0..n).rev() {
            let mut acc = scratch[i];
            for &(j, l) in &self.l_cols[i] {
                acc -= l * scratch[j];
            }
            scratch[i] = acc;
        }
        // x = Pᵀ w.
        for i in 0..n {
            out[self.perm[i]] = scratch[i];
        }
    }

    /// Hypersparse `A x = b` solve, in place: `v` holds `b` on entry
    /// and `x` on return.
    ///
    /// Sparse right-hand sides (see [`SolveMode`]) take the
    /// Gilbert–Peierls path: a symbolic DFS over each factor's column
    /// graph computes the topological closure of the RHS nonzeros, and
    /// the numeric substitution processes exactly that set in reverse
    /// postorder — the work is proportional to the nonzeros actually
    /// created, independent of `n`. Denser inputs keep the column sweep
    /// with zero-skip, whose work is O(n + nnz touched).
    pub fn solve_sparse(&mut self, v: &mut SparseVector, tmp: &mut SparseVector) {
        let n = self.n;
        debug_assert_eq!(v.dim(), n);
        let dfs = self.dfs_wanted(v.nnz());
        tmp.resize_clear(n);
        // z = P b.
        for &j in v.indices() {
            let val = v.get(j);
            if val != 0.0 {
                tmp.set(self.iperm[j], val);
            }
        }
        v.clear();
        if dfs {
            self.dfs_solves += 1;
            self.last_work = 0;
            // Forward: L z' = z over the reach of z in the L column DAG.
            let gen = self.next_stamp();
            reach(
                &self.l_cols,
                tmp.indices(),
                &mut self.stamp,
                gen,
                &mut self.dfs_stack,
                &mut self.dfs_order,
            );
            self.last_work += self.dfs_order.len();
            for &j in self.dfs_order.iter().rev() {
                let zj = tmp.get(j);
                if zj == 0.0 {
                    continue;
                }
                for &(i, l) in &self.l_cols[j] {
                    tmp.add(i, -l * zj);
                }
            }
            // Backward: U x = z' over the reach of z' in the U column DAG.
            let gen = self.next_stamp();
            reach(
                &self.u_cols,
                tmp.indices(),
                &mut self.stamp,
                gen,
                &mut self.dfs_stack,
                &mut self.dfs_order,
            );
            self.last_work += self.dfs_order.len();
            for &j in self.dfs_order.iter().rev() {
                let zj = tmp.get(j);
                if zj == 0.0 {
                    continue;
                }
                let xj = zj / self.u_diag[j];
                v.set(j, xj);
                for &(i, u) in &self.u_cols[j] {
                    tmp.add(i, -u * xj);
                }
            }
        } else {
            self.scan_solves += 1;
            self.last_work = 2 * n;
            // Forward: L z' = z, column sweep with zero-skip.
            for j in 0..n {
                let zj = tmp.get(j);
                if zj == 0.0 {
                    continue;
                }
                for &(i, l) in &self.l_cols[j] {
                    tmp.add(i, -l * zj);
                }
            }
            // Backward: U x = z', column sweep descending.
            for j in (0..n).rev() {
                let zj = tmp.get(j);
                if zj == 0.0 {
                    continue;
                }
                let xj = zj / self.u_diag[j];
                v.set(j, xj);
                for &(i, u) in &self.u_cols[j] {
                    tmp.add(i, -u * xj);
                }
            }
        }
        tmp.clear();
    }

    /// Hypersparse `Aᵀ x = b` solve, in place (see
    /// [`LuFactors::solve_sparse`] for the DFS/scan crossover):
    /// `Uᵀ z = b`, then `Lᵀ w = z`, then `x = Pᵀ w`.
    pub fn solve_transpose_sparse(&mut self, v: &mut SparseVector, tmp: &mut SparseVector) {
        let n = self.n;
        debug_assert_eq!(v.dim(), n);
        if self.dfs_wanted(v.nnz()) {
            self.dfs_solves += 1;
            self.last_work = 0;
            // Forward: Uᵀ z = b over the reach of b in the Uᵀ row DAG.
            let gen = self.next_stamp();
            reach(
                &self.u_rows,
                v.indices(),
                &mut self.stamp,
                gen,
                &mut self.dfs_stack,
                &mut self.dfs_order,
            );
            self.last_work += self.dfs_order.len();
            for &j in self.dfs_order.iter().rev() {
                let bj = v.get(j);
                if bj == 0.0 {
                    continue;
                }
                let zj = bj / self.u_diag[j];
                v.set(j, zj);
                for &(c, u) in &self.u_rows[j] {
                    v.add(c, -u * zj);
                }
            }
            // Backward: Lᵀ w = z over the reach of z in the Lᵀ row DAG.
            let gen = self.next_stamp();
            reach(
                &self.l_rows,
                v.indices(),
                &mut self.stamp,
                gen,
                &mut self.dfs_stack,
                &mut self.dfs_order,
            );
            self.last_work += self.dfs_order.len();
            for &j in self.dfs_order.iter().rev() {
                let wj = v.get(j);
                if wj == 0.0 {
                    continue;
                }
                for &(c, l) in &self.l_rows[j] {
                    v.add(c, -l * wj);
                }
            }
        } else {
            self.scan_solves += 1;
            self.last_work = 2 * n;
            // Forward: Uᵀ z = b (lower triangular), in place ascending.
            for j in 0..n {
                let bj = v.get(j);
                if bj == 0.0 {
                    continue;
                }
                let zj = bj / self.u_diag[j];
                v.set(j, zj);
                for &(c, u) in &self.u_rows[j] {
                    v.add(c, -u * zj);
                }
            }
            // Backward: Lᵀ w = z (upper triangular, unit diagonal).
            for j in (0..n).rev() {
                let wj = v.get(j);
                if wj == 0.0 {
                    continue;
                }
                for &(c, l) in &self.l_rows[j] {
                    v.add(c, -l * wj);
                }
            }
        }
        // x = Pᵀ w.
        tmp.resize_clear(n);
        for &i in v.indices() {
            let val = v.get(i);
            if val != 0.0 {
                tmp.set(self.perm[i], val);
            }
        }
        std::mem::swap(v, tmp);
        tmp.clear();
    }

    /// Forward half of a hypersparse FTRAN: `v ← L⁻¹ P v`, leaving the
    /// result in the pivot-row space. Forrest–Tomlin and Bartels–Golub
    /// keep their own updated `U` and only need this half from the
    /// factorization. Takes the same Gilbert–Peierls DFS path as
    /// [`LuFactors::solve_sparse`] on sparse inputs.
    pub fn lower_solve_sparse(&mut self, v: &mut SparseVector, tmp: &mut SparseVector) {
        let n = self.n;
        debug_assert_eq!(v.dim(), n);
        let dfs = self.dfs_wanted(v.nnz());
        tmp.resize_clear(n);
        for &j in v.indices() {
            let val = v.get(j);
            if val != 0.0 {
                tmp.set(self.iperm[j], val);
            }
        }
        if dfs {
            self.dfs_solves += 1;
            let gen = self.next_stamp();
            reach(
                &self.l_cols,
                tmp.indices(),
                &mut self.stamp,
                gen,
                &mut self.dfs_stack,
                &mut self.dfs_order,
            );
            self.last_work = self.dfs_order.len();
            for &j in self.dfs_order.iter().rev() {
                let zj = tmp.get(j);
                if zj == 0.0 {
                    continue;
                }
                for &(i, l) in &self.l_cols[j] {
                    tmp.add(i, -l * zj);
                }
            }
        } else {
            self.scan_solves += 1;
            self.last_work = n;
            for j in 0..n {
                let zj = tmp.get(j);
                if zj == 0.0 {
                    continue;
                }
                for &(i, l) in &self.l_cols[j] {
                    tmp.add(i, -l * zj);
                }
            }
        }
        std::mem::swap(v, tmp);
        tmp.clear();
    }

    /// Closing half of a hypersparse BTRAN: `v ← Pᵀ L⁻ᵀ v` for a
    /// caller that already did its own upper-transpose solve. DFS/scan
    /// crossover as in [`LuFactors::solve_sparse`].
    pub fn lower_transpose_solve_sparse(&mut self, v: &mut SparseVector, tmp: &mut SparseVector) {
        let n = self.n;
        debug_assert_eq!(v.dim(), n);
        if self.dfs_wanted(v.nnz()) {
            self.dfs_solves += 1;
            let gen = self.next_stamp();
            reach(
                &self.l_rows,
                v.indices(),
                &mut self.stamp,
                gen,
                &mut self.dfs_stack,
                &mut self.dfs_order,
            );
            self.last_work = self.dfs_order.len();
            for &j in self.dfs_order.iter().rev() {
                let wj = v.get(j);
                if wj == 0.0 {
                    continue;
                }
                for &(c, l) in &self.l_rows[j] {
                    v.add(c, -l * wj);
                }
            }
        } else {
            self.scan_solves += 1;
            self.last_work = n;
            for j in (0..n).rev() {
                let wj = v.get(j);
                if wj == 0.0 {
                    continue;
                }
                for &(c, l) in &self.l_rows[j] {
                    v.add(c, -l * wj);
                }
            }
        }
        tmp.resize_clear(n);
        for &i in v.indices() {
            let val = v.get(i);
            if val != 0.0 {
                tmp.set(self.perm[i], val);
            }
        }
        std::mem::swap(v, tmp);
        tmp.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::float::approx_eq_eps;

    #[test]
    fn index_and_eye() {
        let m = Matrix::eye(3);
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(0, 1)], 0.0);
    }

    #[test]
    fn matvec_basic() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(a.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
        assert_eq!(a.matvec_t(&[1.0, 1.0]), vec![4.0, 6.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let i = Matrix::eye(2);
        assert_eq!(a.matmul(&i), a);
    }

    #[test]
    fn lu_solves_small_system() {
        let a = Matrix::from_rows(&[
            vec![2.0, 1.0, -1.0],
            vec![-3.0, -1.0, 2.0],
            vec![-2.0, 1.0, 2.0],
        ]);
        let x = lu_solve(&a, &[8.0, -11.0, -3.0]).unwrap();
        let expect = [2.0, 3.0, -1.0];
        for (xi, ei) in x.iter().zip(expect.iter()) {
            assert!(approx_eq_eps(*xi, *ei, 1e-10, 1e-10), "{x:?}");
        }
    }

    #[test]
    fn lu_requires_pivoting() {
        // Zero on the diagonal forces a row swap.
        let a = Matrix::from_rows(&[vec![0.0, 1.0], vec![1.0, 0.0]]);
        let x = lu_solve(&a, &[2.0, 3.0]).unwrap();
        assert!(approx_eq_eps(x[0], 3.0, 1e-12, 1e-12));
        assert!(approx_eq_eps(x[1], 2.0, 1e-12, 1e-12));
    }

    #[test]
    fn lu_detects_singular() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 4.0]]);
        assert!(lu_solve(&a, &[1.0, 2.0]).is_err());
    }

    #[test]
    fn spectral_norm_of_diagonal() {
        let mut a = Matrix::zeros(3, 3);
        a[(0, 0)] = 3.0;
        a[(1, 1)] = 1.0;
        a[(2, 2)] = 0.5;
        let s = a.spectral_norm_est(100, 42);
        assert!((s - 3.0).abs() < 1e-6, "{s}");
    }

    #[test]
    fn matvec_into_matches_alloc() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        let x = [1.0, -1.0];
        let y = [2.0, 0.0, 1.0];
        let mut buf_r = vec![9.0; 3];
        a.matvec_into(&x, &mut buf_r);
        assert_eq!(buf_r, a.matvec(&x));
        let mut buf_c = vec![9.0; 2];
        a.matvec_t_into(&y, &mut buf_c);
        assert_eq!(buf_c, a.matvec_t(&y));
    }

    #[test]
    fn lu_factors_reuse_and_transpose() {
        use crate::util::rng::{Pcg32, Rng};
        let mut rng = Pcg32::new(77);
        for n in [1usize, 3, 8, 25] {
            let mut a = Matrix::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    // Sparse-ish test matrix (LP bases are mostly zeros).
                    if i == j || rng.f64() < 0.3 {
                        a[(i, j)] = rng.f64() - 0.5;
                    }
                }
                a[(i, i)] += 2.0;
            }
            let f = LuFactors::factor(&a).unwrap();
            let x_true: Vec<f64> = (0..n).map(|_| rng.f64() * 4.0 - 2.0).collect();
            // A x = b
            let b = a.matvec(&x_true);
            let mut x = vec![0.0; n];
            f.solve_into(&b, &mut x);
            for (xi, ti) in x.iter().zip(x_true.iter()) {
                assert!(approx_eq_eps(*xi, *ti, 1e-8, 1e-8), "n={n}");
            }
            // Aᵀ x = b
            let bt = a.matvec_t(&x_true);
            let mut scratch = vec![0.0; n];
            f.solve_transpose_into(&bt, &mut scratch, &mut x);
            for (xi, ti) in x.iter().zip(x_true.iter()) {
                assert!(approx_eq_eps(*xi, *ti, 1e-8, 1e-8), "transpose n={n}");
            }
        }
    }

    #[test]
    fn lu_factors_identity() {
        let f = LuFactors::identity(4);
        let b = [1.0, -2.0, 3.0, 0.5];
        let mut x = vec![0.0; 4];
        f.solve_into(&b, &mut x);
        assert_eq!(x, b.to_vec());
        let mut scratch = vec![0.0; 4];
        f.solve_transpose_into(&b, &mut scratch, &mut x);
        assert_eq!(x, b.to_vec());
    }

    #[test]
    fn csc_factor_and_sparse_solves_match_dense() {
        use crate::util::rng::{Pcg32, Rng};
        let mut rng = Pcg32::new(314);
        for n in [1usize, 2, 5, 12, 30] {
            let mut a = Matrix::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    if i == j || rng.f64() < 0.25 {
                        a[(i, j)] = rng.f64() - 0.5;
                    }
                }
                a[(i, i)] += 2.0;
            }
            let dense = LuFactors::factor(&a).unwrap();
            let mut csc = LuFactors::factor_csc(&SparseMatrix::from_dense(&a, 0.0)).unwrap();
            assert!(
                csc.nnz() <= n * n + n,
                "n={n}: sparse factor stores {} entries",
                csc.nnz()
            );

            // A sparse rhs with a couple of entries — the hypersparse case.
            let mut b = vec![0.0; n];
            b[0] = 1.0;
            if n > 2 {
                b[n / 2] = -2.5;
            }
            let mut want = vec![0.0; n];
            dense.solve_into(&b, &mut want);
            let mut sv = SparseVector::default();
            let mut tmp = SparseVector::default();
            sv.set_from_dense(&b);
            csc.solve_sparse(&mut sv, &mut tmp);
            for i in 0..n {
                assert!(
                    (sv.get(i) - want[i]).abs() < 1e-8,
                    "n={n} solve_sparse[{i}]: {} vs {}",
                    sv.get(i),
                    want[i]
                );
            }

            let mut scratch = vec![0.0; n];
            dense.solve_transpose_into(&b, &mut scratch, &mut want);
            sv.set_from_dense(&b);
            csc.solve_transpose_sparse(&mut sv, &mut tmp);
            for i in 0..n {
                assert!(
                    (sv.get(i) - want[i]).abs() < 1e-8,
                    "n={n} solve_transpose_sparse[{i}]: {} vs {}",
                    sv.get(i),
                    want[i]
                );
            }
        }
    }

    #[test]
    fn csc_factor_detects_singular_and_resets() {
        let a = SparseMatrix::from_triplets(2, 2, &[(0, 0, 1.0), (1, 0, 2.0)]);
        assert!(LuFactors::factor_csc(&a).is_err());
        let mut f = LuFactors::identity(3);
        f.reset_identity(2);
        assert_eq!(f.n(), 2);
        let mut sv = SparseVector::with_dim(2);
        let mut tmp = SparseVector::default();
        sv.set(1, 4.0);
        f.solve_sparse(&mut sv, &mut tmp);
        assert_eq!(sv.get(1), 4.0);
        assert_eq!(sv.get(0), 0.0);
    }

    /// Random sparse nonsingular matrix for the Gilbert–Peierls tests
    /// (diagonally dominant, ~15 % off-diagonal fill).
    fn random_sparse(n: usize, seed: u64) -> Matrix {
        use crate::util::rng::{Pcg32, Rng};
        let mut rng = Pcg32::new(seed);
        let mut a = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                if i == j || rng.f64() < 0.15 {
                    a[(i, j)] = rng.f64() - 0.5;
                }
            }
            a[(i, i)] += 3.0;
        }
        a
    }

    #[test]
    fn dfs_and_scan_solves_agree_to_1e12() {
        // Forced-DFS vs forced-scan on identical hypersparse RHS: the
        // two kernels must produce the same result to 1e-12, for both
        // FTRAN- and BTRAN-shaped solves, over randomized instances.
        use crate::util::rng::{Pcg32, Rng};
        let mut rng = Pcg32::new(2024);
        for rep in 0..40 {
            let n = 8 + (rep % 7) * 13;
            let a = random_sparse(n, 1000 + rep as u64);
            let mut lu = LuFactors::factor_csc(&SparseMatrix::from_dense(&a, 0.0)).unwrap();
            let mut v = SparseVector::with_dim(n);
            let mut tmp = SparseVector::default();
            // 1–3 random nonzeros: the hypersparse regime.
            for _ in 0..(1 + rep % 3) {
                v.set(rng.below(n), rng.f64() * 4.0 - 2.0);
            }
            let mut w = SparseVector::default();
            w.copy_from(&v);

            lu.set_solve_mode(SolveMode::Dfs);
            lu.solve_sparse(&mut v, &mut tmp);
            lu.set_solve_mode(SolveMode::Scan);
            lu.solve_sparse(&mut w, &mut tmp);
            for i in 0..n {
                assert!(
                    (v.get(i) - w.get(i)).abs() < 1e-12,
                    "rep={rep} ftran[{i}]: dfs {} vs scan {}",
                    v.get(i),
                    w.get(i)
                );
            }

            v.clear();
            v.set(rng.below(n), 1.0);
            w.copy_from(&v);
            lu.set_solve_mode(SolveMode::Dfs);
            lu.solve_transpose_sparse(&mut v, &mut tmp);
            lu.set_solve_mode(SolveMode::Scan);
            lu.solve_transpose_sparse(&mut w, &mut tmp);
            for i in 0..n {
                assert!(
                    (v.get(i) - w.get(i)).abs() < 1e-12,
                    "rep={rep} btran[{i}]: dfs {} vs scan {}",
                    v.get(i),
                    w.get(i)
                );
            }
            let (dfs, scan) = lu.solve_mode_counts();
            assert_eq!((dfs, scan), (2, 2), "each mode ran once per solve shape");
        }
    }

    #[test]
    fn auto_mode_picks_dfs_for_sparse_rhs_only() {
        let n = 40;
        let a = random_sparse(n, 7);
        let mut lu = LuFactors::factor_csc(&SparseMatrix::from_dense(&a, 0.0)).unwrap();
        let mut v = SparseVector::with_dim(n);
        let mut tmp = SparseVector::default();
        // 1 nonzero in 40: well under the crossover -> DFS.
        v.set(3, 1.0);
        lu.solve_sparse(&mut v, &mut tmp);
        assert_eq!(lu.solve_mode_counts(), (1, 0));
        // Dense RHS: scan.
        let ones = vec![1.0; n];
        v.set_from_dense(&ones);
        lu.solve_sparse(&mut v, &mut tmp);
        assert_eq!(lu.solve_mode_counts(), (1, 1));
    }

    #[test]
    fn dfs_work_scales_with_result_nnz_not_n() {
        // A lower-bidiagonal chain: the reach of e_{n-1} is {n-1} no
        // matter how long the chain, while e_0 reaches everything.
        // DFS work must stay O(1) in the first case as n grows; the
        // scan always pays 2n.
        for n in [64usize, 256, 1024] {
            let mut trips = Vec::new();
            for i in 0..n {
                trips.push((i, i, 2.0));
                if i + 1 < n {
                    trips.push((i + 1, i, -1.0));
                }
            }
            let a = SparseMatrix::from_triplets(n, n, &trips);
            let mut lu = LuFactors::factor_csc(&a).unwrap();
            let mut v = SparseVector::with_dim(n);
            let mut tmp = SparseVector::default();
            v.set(n - 1, 1.0);
            lu.solve_sparse(&mut v, &mut tmp);
            let (dfs, _) = lu.solve_mode_counts();
            assert_eq!(dfs, 1, "n={n}: sparse unit RHS must take the DFS path");
            assert!(
                lu.last_solve_work() <= 4,
                "n={n}: visited {} nodes for a 1-nnz result",
                lu.last_solve_work()
            );
            // Same factor, scan mode: work is proportional to n.
            v.clear();
            v.set(n - 1, 1.0);
            lu.set_solve_mode(SolveMode::Scan);
            lu.solve_sparse(&mut v, &mut tmp);
            assert_eq!(lu.last_solve_work(), 2 * n);
        }
    }

    #[test]
    fn markowitz_factor_matches_dense_solves() {
        use crate::util::rng::{Pcg32, Rng};
        let mut rng = Pcg32::new(55);
        for n in [1usize, 2, 5, 12, 30] {
            let a = random_sparse(n, 900 + n as u64);
            let dense = LuFactors::factor(&a).unwrap();
            let mut mk = LuFactors::factor_csc_markowitz(&SparseMatrix::from_dense(&a, 0.0))
                .expect("markowitz factor");
            let b: Vec<f64> =
                (0..n).map(|_| if rng.f64() < 0.3 { rng.f64() } else { 0.0 }).collect();
            let mut want = vec![0.0; n];
            dense.solve_into(&b, &mut want);
            let mut sv = SparseVector::default();
            let mut tmp = SparseVector::default();
            sv.set_from_dense(&b);
            mk.solve_sparse(&mut sv, &mut tmp);
            for i in 0..n {
                assert!(
                    (sv.get(i) - want[i]).abs() < 1e-8,
                    "n={n} markowitz[{i}]: {} vs {}",
                    sv.get(i),
                    want[i]
                );
            }
        }
    }

    #[test]
    fn markowitz_detects_singular() {
        let a = SparseMatrix::from_triplets(2, 2, &[(0, 0, 1.0), (1, 0, 2.0)]);
        assert!(LuFactors::factor_csc_markowitz(&a).is_err());
    }

    #[test]
    fn lu_random_roundtrip() {
        use crate::util::rng::{Pcg32, Rng};
        let mut rng = Pcg32::new(9);
        for n in [1usize, 2, 5, 20] {
            let mut a = Matrix::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    a[(i, j)] = rng.f64() - 0.5;
                }
                a[(i, i)] += 2.0; // diagonally dominant => nonsingular
            }
            let x_true: Vec<f64> = (0..n).map(|_| rng.f64() * 10.0).collect();
            let b = a.matvec(&x_true);
            let x = lu_solve(&a, &b).unwrap();
            for (xi, ti) in x.iter().zip(x_true.iter()) {
                assert!(approx_eq_eps(*xi, *ti, 1e-8, 1e-8));
            }
        }
    }
}
