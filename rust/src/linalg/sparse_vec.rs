//! Sparse work vector for the hypersparse simplex kernels.
//!
//! The revised simplex moves *very* sparse vectors through FTRAN and
//! BTRAN: an entering DLT column has a handful of nonzeros, a dual
//! pricing row is a single unit vector, and the basis factors mostly
//! preserve that sparsity. [`SparseVector`] is the classic work-array
//! representation for exploiting it — a dense scatter buffer (`vals`)
//! plus an explicit nonzero index list (`idx`) and a membership mark —
//! so kernels can
//!
//! - read any entry in O(1) (the dense buffer),
//! - iterate only the (potential) nonzeros (the index list),
//! - and reset in O(nnz) instead of O(n) ([`SparseVector::clear`]).
//!
//! Invariants: `vals[i] == 0.0` for every `i` not in `idx`; `idx` holds
//! no duplicates. The list is a *superset* of the true nonzeros —
//! exact cancellation leaves a marked zero entry behind, which costs a
//! slot but never correctness. Index order is unspecified (kernels
//! that need an order iterate positions, not the list).

/// Dense-buffer + index-list sparse vector (see module docs).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SparseVector {
    /// Dense scatter buffer, length = dimension.
    vals: Vec<f64>,
    /// Positions that may hold a nonzero (superset, duplicate-free).
    idx: Vec<usize>,
    /// `mark[i]` ⇔ `idx` contains `i`.
    mark: Vec<bool>,
}

impl SparseVector {
    /// All-zero vector of dimension `n`.
    pub fn with_dim(n: usize) -> SparseVector {
        SparseVector { vals: vec![0.0; n], idx: Vec::new(), mark: vec![false; n] }
    }

    /// Dimension of the dense buffer.
    pub fn dim(&self) -> usize {
        self.vals.len()
    }

    /// Number of tracked (potentially nonzero) entries.
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Reset to all-zero in O(nnz), keeping all capacity.
    pub fn clear(&mut self) {
        for &i in &self.idx {
            self.vals[i] = 0.0;
            self.mark[i] = false;
        }
        self.idx.clear();
    }

    /// Clear and (re)size the dense buffer to dimension `n` — the
    /// scratch-pool entry point: buffers grow on demand and are reused
    /// allocation-free once warm.
    pub fn resize_clear(&mut self, n: usize) {
        self.clear();
        if self.vals.len() != n {
            self.vals.resize(n, 0.0);
            self.mark.resize(n, false);
        }
    }

    /// Entry accessor (O(1) via the dense buffer).
    #[inline]
    pub fn get(&self, i: usize) -> f64 {
        self.vals[i]
    }

    /// Set entry `i`, tracking it in the index list.
    #[inline]
    pub fn set(&mut self, i: usize, v: f64) {
        if !self.mark[i] {
            self.mark[i] = true;
            self.idx.push(i);
        }
        self.vals[i] = v;
    }

    /// Accumulate into entry `i`, tracking it in the index list.
    #[inline]
    pub fn add(&mut self, i: usize, v: f64) {
        if !self.mark[i] {
            self.mark[i] = true;
            self.idx.push(i);
        }
        self.vals[i] += v;
    }

    /// The tracked index list (unordered superset of the nonzeros).
    pub fn indices(&self) -> &[usize] {
        &self.idx
    }

    /// Tracked index at list position `k` (for loops that must mutate
    /// other entries while iterating).
    #[inline]
    pub fn index_at(&self, k: usize) -> usize {
        self.idx[k]
    }

    /// The dense buffer (length [`SparseVector::dim`]).
    pub fn values(&self) -> &[f64] {
        &self.vals
    }

    /// Iterate `(index, value)` over tracked entries, skipping exact
    /// zeros left behind by cancellation.
    pub fn iter(&self) -> impl Iterator<Item = (usize, f64)> + '_ {
        self.idx.iter().map(|&i| (i, self.vals[i])).filter(|&(_, v)| v != 0.0)
    }

    /// Load from a dense slice (the dense-adapter entry point). The
    /// vector is cleared and resized to `v.len()` first.
    pub fn set_from_dense(&mut self, v: &[f64]) {
        self.resize_clear(v.len());
        for (i, &x) in v.iter().enumerate() {
            if x != 0.0 {
                self.set(i, x);
            }
        }
    }

    /// Become a copy of `other` (same tracked entries), reusing
    /// capacity.
    pub fn copy_from(&mut self, other: &SparseVector) {
        self.resize_clear(other.dim());
        for &i in &other.idx {
            let v = other.vals[i];
            if v != 0.0 {
                self.set(i, v);
            }
        }
    }

    /// Scatter into a dense output buffer (zeroed first).
    pub fn copy_into_dense(&self, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.dim());
        out.iter_mut().for_each(|x| *x = 0.0);
        for &i in &self.idx {
            out[i] = self.vals[i];
        }
    }

    /// Squared Euclidean norm over the tracked entries.
    pub fn norm2_sq(&self) -> f64 {
        let mut acc = 0.0;
        for &i in &self.idx {
            let v = self.vals[i];
            acc += v * v;
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_add_get_roundtrip() {
        let mut v = SparseVector::with_dim(6);
        assert_eq!((v.dim(), v.nnz()), (6, 0));
        v.set(2, 3.0);
        v.add(2, -1.0);
        v.add(5, 4.0);
        assert_eq!(v.get(2), 2.0);
        assert_eq!(v.get(5), 4.0);
        assert_eq!(v.get(0), 0.0);
        assert_eq!(v.nnz(), 2, "duplicate touches must not duplicate indices");
    }

    #[test]
    fn clear_is_complete() {
        let mut v = SparseVector::with_dim(4);
        v.set(1, 7.0);
        v.set(3, -2.0);
        v.clear();
        assert_eq!(v.nnz(), 0);
        assert_eq!(v.values(), &[0.0; 4]);
        // Reusable after clear.
        v.set(1, 1.0);
        assert_eq!(v.get(1), 1.0);
        assert_eq!(v.nnz(), 1);
    }

    #[test]
    fn cancellation_keeps_invariant() {
        let mut v = SparseVector::with_dim(3);
        v.add(0, 2.0);
        v.add(0, -2.0);
        // Exact cancellation: still tracked (superset semantics)...
        assert_eq!(v.nnz(), 1);
        assert_eq!(v.get(0), 0.0);
        // ...but iter() skips it.
        assert_eq!(v.iter().count(), 0);
        v.clear();
        assert_eq!(v.values(), &[0.0; 3]);
    }

    #[test]
    fn dense_roundtrip_and_copy() {
        let d = [0.0, 1.5, 0.0, -2.0];
        let mut v = SparseVector::default();
        v.set_from_dense(&d);
        assert_eq!(v.dim(), 4);
        assert_eq!(v.nnz(), 2);
        let mut out = [9.0; 4];
        v.copy_into_dense(&mut out);
        assert_eq!(out, d);
        let mut w = SparseVector::with_dim(1);
        w.copy_from(&v);
        assert_eq!(w.values(), &d);
        assert!((v.norm2_sq() - (1.5 * 1.5 + 4.0)).abs() < 1e-12);
    }

    #[test]
    fn resize_clear_grows_and_shrinks() {
        let mut v = SparseVector::with_dim(2);
        v.set(1, 5.0);
        v.resize_clear(8);
        assert_eq!((v.dim(), v.nnz()), (8, 0));
        v.set(7, 1.0);
        v.resize_clear(3);
        assert_eq!((v.dim(), v.nnz()), (3, 0));
        assert_eq!(v.values(), &[0.0; 3]);
    }
}
