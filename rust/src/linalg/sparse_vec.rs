//! Sparse work vector for the hypersparse simplex kernels.
//!
//! The revised simplex moves *very* sparse vectors through FTRAN and
//! BTRAN: an entering DLT column has a handful of nonzeros, a dual
//! pricing row is a single unit vector, and the basis factors mostly
//! preserve that sparsity. [`SparseVector`] is the classic work-array
//! representation for exploiting it — a dense scatter buffer (`vals`)
//! plus an explicit nonzero index list (`idx`) and a membership mark —
//! so kernels can
//!
//! - read any entry in O(1) (the dense buffer),
//! - iterate only the (potential) nonzeros (the index list),
//! - and reset in O(nnz) instead of O(n) ([`SparseVector::clear`]).
//!
//! Invariants: `vals[i] == 0.0` for every `i` not in `idx`; `idx` holds
//! no duplicates. The list is a *superset* of the true nonzeros —
//! exact cancellation leaves a marked zero entry behind, which costs a
//! slot but never correctness. Index order is unspecified (kernels
//! that need an order iterate positions, not the list).
//!
//! The bulk operations (clear, dense scatter/gather,
//! [`SparseVector::gather_into`]) are written as single-array,
//! branch-free passes — one loop touches one buffer — so the
//! autovectorizer can lift them to SIMD without unsafe code. Hot
//! consumers (the revised-simplex ratio test and x_B update) gather the
//! tracked entries into parallel `(index, value)` arrays once and then
//! stream those contiguously instead of chasing `idx -> vals` twice per
//! iteration.

/// Above `1/DENSE_CLEAR_DIV` occupancy a clear resets the whole dense
/// buffer with `fill` (two memsets) instead of per-index stores: the
/// sparse path wins only when the tracked set is genuinely sparse.
const DENSE_CLEAR_DIV: usize = 4;

/// Dense-buffer + index-list sparse vector (see module docs).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SparseVector {
    /// Dense scatter buffer, length = dimension.
    vals: Vec<f64>,
    /// Positions that may hold a nonzero (superset, duplicate-free).
    idx: Vec<usize>,
    /// `mark[i]` ⇔ `idx` contains `i`.
    mark: Vec<bool>,
}

impl SparseVector {
    /// All-zero vector of dimension `n`.
    pub fn with_dim(n: usize) -> SparseVector {
        SparseVector { vals: vec![0.0; n], idx: Vec::new(), mark: vec![false; n] }
    }

    /// Dimension of the dense buffer.
    pub fn dim(&self) -> usize {
        self.vals.len()
    }

    /// Number of tracked (potentially nonzero) entries.
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Reset to all-zero in O(min(nnz, n)), keeping all capacity.
    ///
    /// Dense-ish vectors (occupancy above `1/4`) are reset with two
    /// contiguous `fill`s — straight memsets — instead of scattered
    /// per-index stores; truly sparse ones keep the O(nnz) path, split
    /// into two single-array loops so each vectorizes independently.
    pub fn clear(&mut self) {
        if self.idx.len() * DENSE_CLEAR_DIV >= self.vals.len() {
            self.vals.fill(0.0);
            self.mark.fill(false);
        } else {
            for &i in &self.idx {
                self.vals[i] = 0.0;
            }
            for &i in &self.idx {
                self.mark[i] = false;
            }
        }
        self.idx.clear();
    }

    /// Clear and (re)size the dense buffer to dimension `n` — the
    /// scratch-pool entry point: buffers grow on demand and are reused
    /// allocation-free once warm.
    pub fn resize_clear(&mut self, n: usize) {
        self.clear();
        if self.vals.len() != n {
            self.vals.resize(n, 0.0);
            self.mark.resize(n, false);
        }
    }

    /// Entry accessor (O(1) via the dense buffer).
    #[inline]
    pub fn get(&self, i: usize) -> f64 {
        self.vals[i]
    }

    /// Set entry `i`, tracking it in the index list.
    #[inline]
    pub fn set(&mut self, i: usize, v: f64) {
        if !self.mark[i] {
            self.mark[i] = true;
            self.idx.push(i);
        }
        self.vals[i] = v;
    }

    /// Accumulate into entry `i`, tracking it in the index list.
    #[inline]
    pub fn add(&mut self, i: usize, v: f64) {
        if !self.mark[i] {
            self.mark[i] = true;
            self.idx.push(i);
        }
        self.vals[i] += v;
    }

    /// The tracked index list (unordered superset of the nonzeros).
    pub fn indices(&self) -> &[usize] {
        &self.idx
    }

    /// Tracked index at list position `k` (for loops that must mutate
    /// other entries while iterating).
    #[inline]
    pub fn index_at(&self, k: usize) -> usize {
        self.idx[k]
    }

    /// The dense buffer (length [`SparseVector::dim`]).
    pub fn values(&self) -> &[f64] {
        &self.vals
    }

    /// Iterate `(index, value)` over tracked entries, skipping exact
    /// zeros left behind by cancellation.
    pub fn iter(&self) -> impl Iterator<Item = (usize, f64)> + '_ {
        self.idx.iter().map(|&i| (i, self.vals[i])).filter(|&(_, v)| v != 0.0)
    }

    /// Load from a dense slice (the dense-adapter entry point). The
    /// vector is cleared and resized to `v.len()` first. Writes go
    /// straight to the buffers — the vector is known clear, so the
    /// per-entry membership test in [`SparseVector::set`] is skipped.
    pub fn set_from_dense(&mut self, v: &[f64]) {
        self.resize_clear(v.len());
        for (i, &x) in v.iter().enumerate() {
            if x != 0.0 {
                self.vals[i] = x;
                self.mark[i] = true;
                self.idx.push(i);
            }
        }
    }

    /// Become a copy of `other` (same tracked entries), reusing
    /// capacity. The index list is copied wholesale and the values
    /// gathered in a separate branch-free pass.
    pub fn copy_from(&mut self, other: &SparseVector) {
        self.resize_clear(other.dim());
        self.idx.extend_from_slice(&other.idx);
        for &i in &self.idx {
            self.vals[i] = other.vals[i];
        }
        for &i in &self.idx {
            self.mark[i] = true;
        }
    }

    /// Scatter into a dense output buffer (zeroed first). The zeroing
    /// is a single `fill` and the scatter a single indexed-store loop.
    pub fn copy_into_dense(&self, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.dim());
        out.fill(0.0);
        for &i in &self.idx {
            out[i] = self.vals[i];
        }
    }

    /// Compact the tracked entries into parallel `(index, value)`
    /// arrays, reusing the callers' buffers. Tracked zeros are kept
    /// (superset semantics, like [`SparseVector::indices`]); the value
    /// gather is one indexed load + contiguous store per entry, so hot
    /// loops downstream stream two flat arrays instead of dereferencing
    /// `idx -> vals` per element.
    pub fn gather_into(&self, out_idx: &mut Vec<usize>, out_vals: &mut Vec<f64>) {
        out_idx.clear();
        out_idx.extend_from_slice(&self.idx);
        out_vals.clear();
        out_vals.resize(self.idx.len(), 0.0);
        for (o, &i) in out_vals.iter_mut().zip(self.idx.iter()) {
            *o = self.vals[i];
        }
    }

    /// Squared Euclidean norm over the tracked entries.
    pub fn norm2_sq(&self) -> f64 {
        let mut acc = 0.0;
        for &i in &self.idx {
            let v = self.vals[i];
            acc += v * v;
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_add_get_roundtrip() {
        let mut v = SparseVector::with_dim(6);
        assert_eq!((v.dim(), v.nnz()), (6, 0));
        v.set(2, 3.0);
        v.add(2, -1.0);
        v.add(5, 4.0);
        assert_eq!(v.get(2), 2.0);
        assert_eq!(v.get(5), 4.0);
        assert_eq!(v.get(0), 0.0);
        assert_eq!(v.nnz(), 2, "duplicate touches must not duplicate indices");
    }

    #[test]
    fn clear_is_complete() {
        let mut v = SparseVector::with_dim(4);
        v.set(1, 7.0);
        v.set(3, -2.0);
        v.clear();
        assert_eq!(v.nnz(), 0);
        assert_eq!(v.values(), &[0.0; 4]);
        // Reusable after clear.
        v.set(1, 1.0);
        assert_eq!(v.get(1), 1.0);
        assert_eq!(v.nnz(), 1);
    }

    #[test]
    fn cancellation_keeps_invariant() {
        let mut v = SparseVector::with_dim(3);
        v.add(0, 2.0);
        v.add(0, -2.0);
        // Exact cancellation: still tracked (superset semantics)...
        assert_eq!(v.nnz(), 1);
        assert_eq!(v.get(0), 0.0);
        // ...but iter() skips it.
        assert_eq!(v.iter().count(), 0);
        v.clear();
        assert_eq!(v.values(), &[0.0; 3]);
    }

    #[test]
    fn dense_roundtrip_and_copy() {
        let d = [0.0, 1.5, 0.0, -2.0];
        let mut v = SparseVector::default();
        v.set_from_dense(&d);
        assert_eq!(v.dim(), 4);
        assert_eq!(v.nnz(), 2);
        let mut out = [9.0; 4];
        v.copy_into_dense(&mut out);
        assert_eq!(out, d);
        let mut w = SparseVector::with_dim(1);
        w.copy_from(&v);
        assert_eq!(w.values(), &d);
        assert!((v.norm2_sq() - (1.5 * 1.5 + 4.0)).abs() < 1e-12);
    }

    #[test]
    fn dense_clear_crossover_resets_everything() {
        // Occupancy 100%: the fill path must leave the same state as
        // the sparse path.
        let mut v = SparseVector::with_dim(5);
        for i in 0..5 {
            v.set(i, (i + 1) as f64);
        }
        v.clear();
        assert_eq!(v.nnz(), 0);
        assert_eq!(v.values(), &[0.0; 5]);
        // And the vector is fully reusable afterwards.
        v.set(3, 2.0);
        assert_eq!((v.nnz(), v.get(3)), (1, 2.0));
    }

    #[test]
    fn gather_into_compacts_tracked_entries() {
        let mut v = SparseVector::with_dim(6);
        v.set(4, 2.0);
        v.set(1, -3.0);
        v.add(5, 1.0);
        v.add(5, -1.0); // cancelled: stays tracked
        let mut idx = vec![99; 1];
        let mut vals = vec![7.0; 9];
        v.gather_into(&mut idx, &mut vals);
        assert_eq!(idx.len(), v.nnz());
        assert_eq!(vals.len(), v.nnz());
        for (&i, &x) in idx.iter().zip(vals.iter()) {
            assert_eq!(v.get(i), x);
        }
        // The cancelled slot is present with value zero.
        let k = idx.iter().position(|&i| i == 5).unwrap();
        assert_eq!(vals[k], 0.0);
    }

    #[test]
    fn resize_clear_grows_and_shrinks() {
        let mut v = SparseVector::with_dim(2);
        v.set(1, 5.0);
        v.resize_clear(8);
        assert_eq!((v.dim(), v.nnz()), (8, 0));
        v.set(7, 1.0);
        v.resize_clear(3);
        assert_eq!((v.dim(), v.nnz()), (3, 0));
        assert_eq!(v.values(), &[0.0; 3]);
    }
}
