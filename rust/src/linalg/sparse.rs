//! Compressed-sparse-column (CSC) matrix.
//!
//! The DLT constraint matrices are ~95 % zeros (each row touches a
//! handful of `β`/`TS`/`TF` variables), and the revised simplex is
//! column-oriented: pricing and FTRAN both walk one column at a time.
//! CSC makes both O(nnz) instead of O(rows × cols).

use crate::linalg::Matrix;

/// Referenced by the `Index` impl for absent entries.
static ZERO: f64 = 0.0;

/// Immutable CSC matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct SparseMatrix {
    rows: usize,
    cols: usize,
    /// `col_ptr[j]..col_ptr[j+1]` indexes column `j` in `row_idx`/`vals`.
    col_ptr: Vec<usize>,
    /// Row index per stored entry, ascending within each column.
    row_idx: Vec<usize>,
    /// Value per stored entry.
    vals: Vec<f64>,
}

impl SparseMatrix {
    /// Empty matrix of the given shape.
    pub fn zeros(rows: usize, cols: usize) -> SparseMatrix {
        SparseMatrix {
            rows,
            cols,
            col_ptr: vec![0; cols + 1],
            row_idx: Vec::new(),
            vals: Vec::new(),
        }
    }

    /// Build from `(row, col, value)` triplets. Duplicates are summed;
    /// entries that sum to exactly zero are dropped. Panics on
    /// out-of-range indices.
    pub fn from_triplets(rows: usize, cols: usize, triplets: &[(usize, usize, f64)]) -> SparseMatrix {
        let mut entries: Vec<(usize, usize, f64)> = triplets.to_vec();
        let mut m = SparseMatrix::zeros(rows, cols);
        m.refill_from_triplets(rows, cols, &mut entries);
        m
    }

    /// Rebuild this matrix in place from `(row, col, value)` triplets,
    /// reusing all storage — the allocation-free variant of
    /// [`SparseMatrix::from_triplets`] for hot paths (the revised
    /// simplex reassembles the basis through a pooled matrix on every
    /// warm solve). The triplet buffer is sorted in place
    /// (`sort_unstable`, no scratch allocation); duplicates are
    /// summed, exact-zero sums dropped. Panics on out-of-range
    /// indices.
    pub fn refill_from_triplets(
        &mut self,
        rows: usize,
        cols: usize,
        triplets: &mut [(usize, usize, f64)],
    ) {
        for &(r, c, _) in triplets.iter() {
            assert!(r < rows && c < cols, "triplet ({r}, {c}) outside {rows}x{cols}");
        }
        triplets.sort_unstable_by(|a, b| (a.1, a.0).cmp(&(b.1, b.0)));

        self.rows = rows;
        self.cols = cols;
        self.col_ptr.clear();
        self.col_ptr.resize(cols + 1, 0);
        self.row_idx.clear();
        self.vals.clear();
        let mut k = 0;
        for c in 0..cols {
            while k < triplets.len() && triplets[k].1 == c {
                let r = triplets[k].0;
                let mut v = triplets[k].2;
                k += 1;
                while k < triplets.len() && triplets[k].1 == c && triplets[k].0 == r {
                    v += triplets[k].2;
                    k += 1;
                }
                if v != 0.0 {
                    self.row_idx.push(r);
                    self.vals.push(v);
                }
            }
            self.col_ptr[c + 1] = self.row_idx.len();
        }
    }

    /// Build from a dense matrix, keeping entries with `|v| > drop_tol`.
    pub fn from_dense(m: &Matrix, drop_tol: f64) -> SparseMatrix {
        let mut trips = Vec::new();
        for i in 0..m.rows() {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v.abs() > drop_tol {
                    trips.push((i, j, v));
                }
            }
        }
        SparseMatrix::from_triplets(m.rows(), m.cols(), &trips)
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Fraction of entries stored (1.0 = fully dense).
    pub fn density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Iterate the `(row, value)` entries of column `j`.
    pub fn col(&self, j: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let lo = self.col_ptr[j];
        let hi = self.col_ptr[j + 1];
        self.row_idx[lo..hi].iter().copied().zip(self.vals[lo..hi].iter().copied())
    }

    /// Stored entries in column `j`.
    pub fn col_nnz(&self, j: usize) -> usize {
        self.col_ptr[j + 1] - self.col_ptr[j]
    }

    /// Entry accessor (binary search within the column).
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        let lo = self.col_ptr[j];
        let hi = self.col_ptr[j + 1];
        match self.row_idx[lo..hi].binary_search(&i) {
            Ok(k) => self.vals[lo + k],
            Err(_) => 0.0,
        }
    }

    /// Dot product of column `j` with a dense vector indexed by row:
    /// `Σ_i A_ij y_i`. This is the revised-simplex pricing kernel.
    pub fn col_dot(&self, j: usize, y: &[f64]) -> f64 {
        debug_assert_eq!(y.len(), self.rows);
        self.col(j).map(|(i, v)| v * y[i]).sum()
    }

    /// Scatter column `j` into a dense buffer (`out` is zeroed first).
    pub fn col_into(&self, j: usize, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.rows);
        out.iter_mut().for_each(|x| *x = 0.0);
        for (i, v) in self.col(j) {
            out[i] = v;
        }
    }

    /// Dense `A x` (column-major accumulation).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// In-place `y = A x` (column-major scatter, O(nnz)). This is the
    /// PDHG forward kernel: `y` is zeroed first, so it can be a pooled
    /// buffer reused across iterations.
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        y.iter_mut().for_each(|v| *v = 0.0);
        for j in 0..self.cols {
            let xj = x[j];
            if xj == 0.0 {
                continue;
            }
            for (i, v) in self.col(j) {
                y[i] += v * xj;
            }
        }
    }

    /// In-place `out = Aᵀ y` (per-column gather, O(nnz)). This is the
    /// PDHG adjoint kernel: each output entry is one [`col_dot`], so
    /// the transpose never has to be materialized.
    ///
    /// [`col_dot`]: SparseMatrix::col_dot
    pub fn matvec_t_into(&self, y: &[f64], out: &mut [f64]) {
        assert_eq!(y.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        for (j, o) in out.iter_mut().enumerate() {
            *o = self.col_dot(j, y);
        }
    }

    /// Materialize as a dense [`Matrix`].
    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for j in 0..self.cols {
            for (i, v) in self.col(j) {
                m[(i, j)] = v;
            }
        }
        m
    }
}

/// Degenerate 0×0 placeholder (empty `col_ptr`, so it allocates
/// nothing — the scratch-pool resting state; every method is safe on
/// it because there is no valid column index).
impl Default for SparseMatrix {
    fn default() -> SparseMatrix {
        SparseMatrix {
            rows: 0,
            cols: 0,
            col_ptr: Vec::new(),
            row_idx: Vec::new(),
            vals: Vec::new(),
        }
    }
}

impl std::ops::Index<(usize, usize)> for SparseMatrix {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        let lo = self.col_ptr[j];
        let hi = self.col_ptr[j + 1];
        match self.row_idx[lo..hi].binary_search(&i) {
            Ok(k) => &self.vals[lo + k],
            Err(_) => &ZERO,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> SparseMatrix {
        // [[1, 0, 2],
        //  [0, 3, 0]]
        SparseMatrix::from_triplets(2, 3, &[(0, 0, 1.0), (1, 1, 3.0), (0, 2, 2.0)])
    }

    #[test]
    fn shape_and_nnz() {
        let a = sample();
        assert_eq!((a.rows(), a.cols(), a.nnz()), (2, 3, 3));
        assert!((a.density() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn indexing_and_get() {
        let a = sample();
        assert_eq!(a[(0, 0)], 1.0);
        assert_eq!(a[(1, 0)], 0.0);
        assert_eq!(a.get(0, 2), 2.0);
        assert_eq!(a.get(1, 2), 0.0);
    }

    #[test]
    fn duplicates_sum_and_zeros_drop() {
        let a = SparseMatrix::from_triplets(
            2,
            2,
            &[(0, 0, 1.0), (0, 0, 2.0), (1, 1, 5.0), (1, 1, -5.0)],
        );
        assert_eq!(a[(0, 0)], 3.0);
        assert_eq!(a.nnz(), 1, "exact cancellation is dropped");
    }

    #[test]
    fn col_iteration_sorted() {
        let a = SparseMatrix::from_triplets(3, 1, &[(2, 0, 9.0), (0, 0, 7.0)]);
        let entries: Vec<(usize, f64)> = a.col(0).collect();
        assert_eq!(entries, vec![(0, 7.0), (2, 9.0)]);
        assert_eq!(a.col_nnz(0), 2);
    }

    #[test]
    fn col_dot_and_scatter() {
        let a = sample();
        assert_eq!(a.col_dot(0, &[2.0, 5.0]), 2.0);
        assert_eq!(a.col_dot(1, &[2.0, 5.0]), 15.0);
        let mut buf = [9.0; 2];
        a.col_into(2, &mut buf);
        assert_eq!(buf, [2.0, 0.0]);
    }

    #[test]
    fn matvec_matches_dense() {
        let a = sample();
        let d = a.to_dense();
        let x = [1.0, 2.0, 3.0];
        assert_eq!(a.matvec(&x), d.matvec(&x));
    }

    #[test]
    fn matvec_into_zeroes_stale_output() {
        let a = sample();
        let mut y = [7.0, 7.0];
        a.matvec_into(&[1.0, 2.0, 3.0], &mut y);
        assert_eq!(y.to_vec(), a.matvec(&[1.0, 2.0, 3.0]));
    }

    #[test]
    fn matvec_t_into_matches_col_dot() {
        let a = sample();
        let y = [2.0, 5.0];
        let mut out = [9.0; 3];
        a.matvec_t_into(&y, &mut out);
        assert_eq!(out, [a.col_dot(0, &y), a.col_dot(1, &y), a.col_dot(2, &y)]);
    }

    #[test]
    fn dense_roundtrip() {
        let a = sample();
        let back = SparseMatrix::from_dense(&a.to_dense(), 0.0);
        assert_eq!(a, back);
    }

    #[test]
    fn empty_matrix() {
        let a = SparseMatrix::zeros(0, 0);
        assert_eq!(a.nnz(), 0);
        assert_eq!(a.density(), 0.0);
        let d = SparseMatrix::default();
        assert_eq!((d.rows(), d.cols(), d.nnz()), (0, 0, 0));
    }

    #[test]
    fn refill_reuses_storage_and_matches_from_triplets() {
        let mut m = SparseMatrix::default();
        let mut trips = vec![(0usize, 0usize, 1.0), (1, 1, 3.0), (0, 2, 2.0)];
        m.refill_from_triplets(2, 3, &mut trips);
        assert_eq!(m, sample());
        // Refill with a different shape: storage reused, result exact.
        let mut trips = vec![(1usize, 0usize, 4.0), (0, 0, 1.0), (0, 0, -1.0)];
        m.refill_from_triplets(2, 2, &mut trips);
        let want = SparseMatrix::from_triplets(2, 2, &[(1, 0, 4.0), (0, 0, 1.0), (0, 0, -1.0)]);
        assert_eq!(m, want);
        assert_eq!(m.nnz(), 1, "exact cancellation dropped in refill too");
    }
}
