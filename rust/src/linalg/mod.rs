//! Small linear-algebra substrate.
//!
//! Used by the §2 closed-form oracle (solving the M+1 linear equations
//! directly), both simplex backends, and PDHG standardization.
//! Everything is `f64` and allocation-explicit — instances in this
//! paper are at most a few thousand variables. [`matrix::Matrix`] is
//! dense row-major; [`sparse::SparseMatrix`] is CSC and carries the LP
//! constraint matrices (which are ~95 % zeros for DLT instances);
//! [`matrix::LuFactors`] is the reusable basis factorization behind
//! the revised simplex, and [`sparse_vec::SparseVector`] is the
//! hypersparse work vector its FTRAN/BTRAN kernels move around.

pub mod matrix;
pub mod sparse;
pub mod sparse_vec;

pub use matrix::{lu_solve, LuFactors, Matrix, SolveMode};
pub use sparse::SparseMatrix;
pub use sparse_vec::SparseVector;

/// Dot product of two equal-length slices.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

/// Euclidean norm.
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Infinity norm.
pub fn norm_inf(a: &[f64]) -> f64 {
    a.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
}

/// `y += alpha * x`.
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_and_norms() {
        let a = [1.0, 2.0, 2.0];
        assert_eq!(dot(&a, &a), 9.0);
        assert_eq!(norm2(&a), 3.0);
        assert_eq!(norm_inf(&[-5.0, 3.0]), 5.0);
    }

    #[test]
    fn axpy_works() {
        let mut y = [1.0, 1.0];
        axpy(2.0, &[3.0, 4.0], &mut y);
        assert_eq!(y, [7.0, 9.0]);
    }
}
