//! Mini property-testing harness (the offline crate set has no
//! `proptest`/`quickcheck`).
//!
//! ```
//! use dlt::testkit::props;
//! props("addition commutes", 100, |g| {
//!     let a = g.f64_in(-1e6, 1e6);
//!     let b = g.f64_in(-1e6, 1e6);
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//! });
//! ```
//!
//! Each case runs with a deterministic seed derived from the property
//! name and the case index; failures report the seed so a case can be
//! replayed exactly with [`replay`].

use crate::util::rng::{Pcg32, Rng};

/// Case-local generator handed to each property execution.
pub struct Gen {
    rng: Pcg32,
    /// Seed this case was created from (for failure reports).
    pub seed: u64,
}

impl Gen {
    /// Create from an explicit seed.
    pub fn from_seed(seed: u64) -> Gen {
        Gen { rng: Pcg32::new(seed), seed }
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_usize(lo, hi)
    }

    /// Random bool.
    pub fn bool(&mut self) -> bool {
        self.rng.bool_with(0.5)
    }

    /// Vector of uniform f64s.
    pub fn f64_vec(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    /// Sorted (ascending) vector of uniform f64s.
    pub fn sorted_f64_vec(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        let mut v = self.f64_vec(len, lo, hi);
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v
    }

    /// Access the raw RNG.
    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }
}

fn name_seed(name: &str) -> u64 {
    // FNV-1a over the property name keeps seeds stable across runs.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Run `cases` executions of a property. Panics on the first failure,
/// reporting the case index and seed.
pub fn props<F>(name: &str, cases: usize, mut property: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let base = name_seed(name);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen::from_seed(seed);
        if let Err(msg) = property(&mut g) {
            panic!(
                "property `{name}` failed at case {case} (seed {seed:#x}): {msg}\n\
                 replay with: dlt::testkit::replay({seed:#x}, ...)"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<F>(seed: u64, mut property: F) -> Result<(), String>
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let mut g = Gen::from_seed(seed);
    property(&mut g)
}

/// Generate a random *valid, sorted* [`crate::model::SystemSpec`] —
/// the workhorse generator for scheduling property tests.
pub fn arb_spec(g: &mut Gen, max_n: usize, max_m: usize) -> crate::model::SystemSpec {
    let n = g.usize_in(1, max_n + 1);
    let m = g.usize_in(1, max_m + 1);
    let gs = g.sorted_f64_vec(n, 0.05, 1.0);
    let rs = g.sorted_f64_vec(n, 0.0, 3.0);
    let a = g.sorted_f64_vec(m, 0.5, 5.0);
    let mut b = crate::model::SystemSpec::builder();
    for i in 0..n {
        b = b.source(gs[i], rs[i]);
    }
    for j in 0..m {
        // Paper §6: faster processors cost more; generate descending
        // cost rates consistent with ascending A.
        b = b.processor_with_cost(a[j], 30.0 - j as f64);
    }
    b.job(g.f64_in(10.0, 200.0)).build().expect("arb_spec generates valid specs")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        props("sort is idempotent", 50, |g| {
            let len = g.usize_in(0, 20);
            let mut v = g.f64_vec(len, -100.0, 100.0);
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let w = {
                let mut w = v.clone();
                w.sort_by(|a, b| a.partial_cmp(b).unwrap());
                w
            };
            if v == w {
                Ok(())
            } else {
                Err("not idempotent".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn failing_property_panics_with_seed() {
        props("always fails", 5, |_| Err("nope".into()));
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        props("capture", 5, |g| {
            first.push(g.f64_in(0.0, 1.0));
            Ok(())
        });
        let mut second = Vec::new();
        props("capture", 5, |g| {
            second.push(g.f64_in(0.0, 1.0));
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn replay_reproduces() {
        let mut g1 = Gen::from_seed(0xabc);
        let x1 = g1.f64_in(0.0, 1.0);
        let ok = replay(0xabc, |g| {
            let x = g.f64_in(0.0, 1.0);
            if x == x1 {
                Ok(())
            } else {
                Err("mismatch".into())
            }
        });
        assert!(ok.is_ok());
    }

    #[test]
    fn sorted_vec_is_sorted() {
        let mut g = Gen::from_seed(1);
        let v = g.sorted_f64_vec(50, 0.0, 10.0);
        assert!(v.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn arb_spec_always_valid() {
        props("arb_spec validates", 100, |g| {
            let spec = arb_spec(g, 5, 8);
            spec.validate().map_err(|e| format!("{e}"))
        });
    }
}
