//! §3.1 — multi-source scheduling for processors **with** front-ends.
//!
//! LP variables: `β_{i,j} ≥ 0` (N·M of them) and the makespan `T_f`.
//! Constraints (paper eqs. 3–6):
//!
//! 1. release:    `R_{i+1} − R_i ≤ β_{i,1} A_1`
//! 2. continuity: `β_{i,j} A_j + β_{i+1,j} G_{i+1} ≤ β_{i,j} G_i + β_{i,j+1} A_{j+1}`
//! 3. finish:     `T_f ≥ R_1 + Σ_{k≤j−1} β_{1,k} G_1 + Σ_i β_{i,j} A_j`
//! 4. normalize:  `Σ_{i,j} β_{i,j} = J`
//!
//! The paper's eq. 5 sums `k = 1..j−1` in the text but `k = 1..j` in its
//! summary block; [`FeOptions::finish_sum_includes_j`] selects the
//! variant (default: `j−1`, which matches the timing diagram).
//!
//! After the LP solve, explicit communication windows are reconstructed
//! with the sequential-distribution recurrence so the schedule can be
//! validated, simulated and executed.

use crate::dlt::schedule::{Schedule, TimingModel};
use crate::error::Result;
use crate::lp::{Cmp, LpProblem, LpSolution};
use crate::model::SystemSpec;
use crate::pipeline::ScenarioModel;

/// Options for the §3.1 builder. Solver/backend tuning lives in
/// [`crate::pipeline::PipelineOptions`] (or, one level up, in the
/// [`crate::api`] request) — the family carries only formulation
/// choices.
#[derive(Debug, Clone, Default)]
pub struct FeOptions {
    /// Use the paper's summary-block variant of eq. 5 (`k = 1..j`)
    /// instead of the text variant (`k = 1..j−1`).
    pub finish_sum_includes_j: bool,
    /// Per-processor compute-ready times (extension for multi-job
    /// pipelining, [`crate::dlt::multi_job`]): processor `j` cannot
    /// start computing before `proc_ready[j]` (it is still finishing
    /// the previous job), adding finish constraints
    /// `T_f ≥ ready_j + Σ_i β_{i,j} A_j`. `None` means all zeros.
    pub proc_ready: Option<Vec<f64>>,
}

/// Index of `β_{i,j}` in the LP variable vector.
#[inline]
fn bidx(i: usize, j: usize, m: usize) -> usize {
    i * m + j
}

/// Build the §3.1 LP for a (validated, sorted) spec.
pub fn build_lp(spec: &SystemSpec, opts: &FeOptions) -> LpProblem {
    let n = spec.n();
    let m = spec.m();
    let g = spec.g();
    let r = spec.releases();
    let a = spec.a();
    let tf = n * m; // T_f variable index
    let mut p = LpProblem::new(n * m + 1);

    for i in 0..n {
        for j in 0..m {
            p.name_var(bidx(i, j, m), format!("beta[{i}][{j}]"));
        }
    }
    p.name_var(tf, "T_f");
    p.set_objective_coeff(tf, 1.0);

    // (3) release: beta[i][0] * A_1 >= R_{i+1} - R_i
    for i in 0..n.saturating_sub(1) {
        p.add_labeled(
            &[(bidx(i, 0, m), a[0])],
            Cmp::Ge,
            r[i + 1] - r[i],
            format!("release[{i}]"),
        );
    }

    // (4) continuity:
    // beta[i][j](A_j - G_i) + beta[i+1][j] G_{i+1} - beta[i][j+1] A_{j+1} <= 0
    for i in 0..n.saturating_sub(1) {
        for j in 0..m.saturating_sub(1) {
            p.add_labeled(
                &[
                    (bidx(i, j, m), a[j] - g[i]),
                    (bidx(i + 1, j, m), g[i + 1]),
                    (bidx(i, j + 1, m), -a[j + 1]),
                ],
                Cmp::Le,
                0.0,
                format!("continuity[{i}][{j}]"),
            );
        }
    }

    // (5) finish: T_f - Σ_{k<=j-1} beta[0][k] G_1 - Σ_i beta[i][j] A_j >= R_1
    for j in 0..m {
        let mut coeffs: Vec<(usize, f64)> = vec![(tf, 1.0)];
        let upper = if opts.finish_sum_includes_j { j + 1 } else { j };
        for k in 0..upper.min(m) {
            coeffs.push((bidx(0, k, m), -g[0]));
        }
        for i in 0..n {
            coeffs.push((bidx(i, j, m), -a[j]));
        }
        p.add_labeled(&coeffs, Cmp::Ge, r[0], format!("finish[{j}]"));
    }

    // (6) normalization.
    let all: Vec<(usize, f64)> =
        (0..n).flat_map(|i| (0..m).map(move |j| (bidx(i, j, m), 1.0))).collect();
    p.add_labeled(&all, Cmp::Eq, spec.job, "normalize");

    // (ext) multi-job pipelining: the processor is still busy with the
    // previous job until ready_j.
    if let Some(ready) = &opts.proc_ready {
        assert_eq!(ready.len(), m, "proc_ready length mismatch");
        for j in 0..m {
            if ready[j] > 0.0 {
                let mut coeffs: Vec<(usize, f64)> = vec![(tf, 1.0)];
                for i in 0..n {
                    coeffs.push((bidx(i, j, m), -a[j]));
                }
                p.add_labeled(&coeffs, Cmp::Ge, ready[j], format!("proc_ready[{j}]"));
            }
        }
    }

    p
}

/// The §3.1 scenario family: [`FeOptions`] *is* the model — the
/// pipeline handles presolve, backend dispatch and warm caching.
impl ScenarioModel for FeOptions {
    fn name(&self) -> &'static str {
        "frontend"
    }

    fn build_lp(&self, spec: &SystemSpec) -> LpProblem {
        build_lp(spec, self)
    }

    fn schedule(&self, spec: &SystemSpec, sol: &LpSolution) -> Result<Schedule> {
        schedule_from_solution(spec, sol)
    }
}

/// Reconstruct the full schedule from an LP solution of the §3.1 LP.
pub(crate) fn schedule_from_solution(spec: &SystemSpec, sol: &LpSolution) -> Result<Schedule> {
    let n = spec.n();
    let m = spec.m();

    let mut beta = vec![0.0; n * m];
    beta.copy_from_slice(&sol.x[..n * m]);
    for b in beta.iter_mut() {
        *b = crate::util::float::snap_nonneg(*b, 1e-9);
    }
    let makespan = sol.x[n * m];

    let (comm_start, comm_end) = reconstruct_comm_windows(spec, &beta);

    // Front-end semantics: processor j computes continuously starting
    // when its first (nonzero) fraction begins arriving.
    let g = spec.g();
    let a = spec.a();
    let r = spec.releases();
    let _ = (&g, &r);
    let mut compute_start = vec![0.0; m];
    let mut compute_end = vec![0.0; m];
    for j in 0..m {
        let first = (0..n).find(|&i| beta[bidx(i, j, m)] > 1e-12);
        let start = match first {
            Some(i) => comm_start[bidx(i, j, m)],
            None => 0.0,
        };
        let total_compute: f64 = (0..n).map(|i| beta[bidx(i, j, m)]).sum::<f64>() * a[j];
        compute_start[j] = start;
        // Compute cannot outrun communication at fraction granularity:
        // the end is at least each fraction's arrival plus the compute
        // time of everything after it.
        let mut end = start + total_compute;
        for i in 0..n {
            let arrived = comm_end[bidx(i, j, m)];
            let remaining: f64 =
                ((i + 1)..n).map(|k| beta[bidx(k, j, m)]).sum::<f64>() * a[j];
            end = end.max(arrived + remaining);
        }
        compute_end[j] = if total_compute > 0.0 { end } else { start };
    }

    Ok(Schedule {
        n,
        m,
        model: TimingModel::FrontEnd,
        beta,
        comm_start,
        comm_end,
        compute_start,
        compute_end,
        makespan,
        lp_iterations: sol.iterations,
    })
}

/// Sequential-distribution recurrence shared by the FE reconstruction:
/// source `i` sends to `P_1..P_M` in order; it may start fraction
/// `(i, j)` only after it finished `(i, j−1)`, after the previous
/// source finished sending to `P_j` (one receive at a time), and — for
/// `j = 1` — not before its release time.
pub fn reconstruct_comm_windows(spec: &SystemSpec, beta: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let n = spec.n();
    let m = spec.m();
    let g = spec.g();
    let r = spec.releases();
    let mut ts = vec![0.0; n * m];
    let mut tf = vec![0.0; n * m];
    for i in 0..n {
        for j in 0..m {
            let mut start = if j == 0 { r[i] } else { tf[bidx(i, j - 1, m)] };
            if i > 0 {
                start = start.max(tf[bidx(i - 1, j, m)]);
            }
            if j == 0 {
                start = start.max(r[i]);
            }
            ts[bidx(i, j, m)] = start;
            tf[bidx(i, j, m)] = start + beta[bidx(i, j, m)] * g[i];
        }
    }
    (ts, tf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::float::approx_eq_eps;

    // The per-family `solve`/`solve_opts` forwards are gone (PR 4):
    // every solve goes through the pipeline (or, one level up, the
    // `dlt::api` facade).
    fn solve(spec: &SystemSpec) -> Result<Schedule> {
        crate::pipeline::solve(&FeOptions::default(), spec)
    }

    fn solve_opts(spec: &SystemSpec, opts: &FeOptions) -> Result<Schedule> {
        crate::pipeline::solve(opts, spec)
    }

    fn table1_spec() -> SystemSpec {
        SystemSpec::builder()
            .source(0.2, 10.0)
            .source(0.4, 50.0)
            .processors(&[2.0, 3.0, 4.0, 5.0, 6.0])
            .job(100.0)
            .build()
            .unwrap()
    }

    #[test]
    fn table1_solves_and_normalizes() {
        let s = solve(&table1_spec()).unwrap();
        assert!(approx_eq_eps(s.total_load(), 100.0, 1e-7, 1e-7));
        assert!(s.makespan > 0.0);
        assert!(s.beta.iter().all(|&b| b >= 0.0));
    }

    #[test]
    fn faster_processors_do_more_work() {
        let s = solve(&table1_spec()).unwrap();
        // Paper Fig. 10/11: processors with faster computing speeds do
        // more processing work.
        for j in 0..s.m - 1 {
            assert!(
                s.load_on_processor(j) >= s.load_on_processor(j + 1) - 1e-6,
                "P{} load {} < P{} load {}",
                j + 1,
                s.load_on_processor(j),
                j + 2,
                s.load_on_processor(j + 1)
            );
        }
    }

    #[test]
    fn release_constraint_respected() {
        let spec = table1_spec();
        let s = solve(&spec).unwrap();
        // beta[0][0] * A_1 >= R_2 - R_1 = 40 -> beta[0][0] >= 20
        assert!(s.beta(0, 0) * 2.0 >= 40.0 - 1e-6, "beta11={}", s.beta(0, 0));
    }

    #[test]
    fn single_source_reduces_to_section2_when_r0() {
        // With N=1, R=0 the FE LP's finish constraints are exactly
        // T_f >= sum_{k<j} beta_k G + total compute on j; the optimum
        // is bounded by the §2 closed form (FE can only be faster or
        // equal because compute overlaps comm).
        let spec = SystemSpec::builder()
            .source(0.2, 0.0)
            .processors(&[2.0, 3.0, 4.0])
            .job(100.0)
            .build()
            .unwrap();
        let fe = solve(&spec).unwrap();
        let ss = crate::dlt::single_source::solve(0.2, &[2.0, 3.0, 4.0], 100.0, 0.0).unwrap();
        assert!(fe.makespan <= ss.makespan + 1e-6, "fe {} > ss {}", fe.makespan, ss.makespan);
    }

    #[test]
    fn makespan_decreases_with_more_processors() {
        let spec = SystemSpec::builder()
            .source(0.5, 2.0)
            .source(0.6, 3.0)
            .processors(&(0..10).map(|k| 1.1 + 0.1 * k as f64).collect::<Vec<_>>())
            .job(100.0)
            .build()
            .unwrap();
        let mut prev = f64::INFINITY;
        for m in 1..=10 {
            let s = solve(&spec.with_m_processors(m)).unwrap();
            assert!(s.makespan <= prev + 1e-9, "m={m}");
            prev = s.makespan;
        }
    }

    #[test]
    fn comm_windows_are_consistent() {
        let s = solve(&table1_spec()).unwrap();
        let spec = table1_spec();
        let g = spec.g();
        for i in 0..s.n {
            for j in 0..s.m {
                let k = i * s.m + j;
                assert!(
                    approx_eq_eps(s.comm_end[k] - s.comm_start[k], s.beta[k] * g[i], 1e-9, 1e-9)
                );
                if j > 0 {
                    assert!(s.comm_start[k] >= s.comm_end[k - 1] - 1e-9, "source busy overlap");
                }
                if i > 0 {
                    assert!(
                        s.comm_start[k] >= s.comm_end[(i - 1) * s.m + j] - 1e-9,
                        "processor receive overlap"
                    );
                }
            }
        }
    }

    #[test]
    fn finish_sum_variant_is_no_faster() {
        // Including beta[0][j] G_1 in the waiting sum only tightens the
        // constraint, so T_f can only grow.
        let spec = table1_spec();
        let default = solve_opts(&spec, &FeOptions::default()).unwrap();
        let variant = solve_opts(
            &spec,
            &FeOptions { finish_sum_includes_j: true, ..FeOptions::default() },
        )
        .unwrap();
        assert!(variant.makespan >= default.makespan - 1e-9);
    }

    #[test]
    fn one_processor_edge_case() {
        let spec = SystemSpec::builder()
            .source(0.2, 0.0)
            .source(0.4, 1.0)
            .processors(&[2.0])
            .job(10.0)
            .build()
            .unwrap();
        let s = solve(&spec).unwrap();
        assert!(approx_eq_eps(s.total_load(), 10.0, 1e-8, 1e-8));
    }
}
