//! Post-hoc schedule validation, independent of the LP.
//!
//! Checks the paper's operational semantics directly on the timed
//! windows: window lengths, sequential-communication exclusivity,
//! release times, normalization, and the compute-timing rules for the
//! front-end / no-front-end models. This is the referee between the LP
//! solutions and the discrete-event simulator.

use crate::dlt::schedule::{Schedule, TimingModel};
use crate::model::SystemSpec;

/// Outcome of validating one schedule.
#[derive(Debug, Clone)]
pub struct ValidationReport {
    /// Hard violations (schedule is not executable as timed).
    pub violations: Vec<String>,
    /// Soft findings (executable but noteworthy: gaps, slack, ...).
    pub warnings: Vec<String>,
    /// Max absolute normalization error.
    pub normalization_error: f64,
    /// `realized_makespan − makespan` (positive means the LP value is
    /// optimistic relative to the reconstructed timing).
    pub makespan_slack: f64,
}

impl ValidationReport {
    /// True when no hard violations were found.
    pub fn is_valid(&self) -> bool {
        self.violations.is_empty()
    }
}

const EPS: f64 = 1e-6;

/// Validate `sched` against `spec`.
pub fn validate(spec: &SystemSpec, sched: &Schedule) -> ValidationReport {
    let mut v = Vec::new();
    let mut w = Vec::new();
    let n = sched.n;
    let m = sched.m;
    let g = spec.g();
    let a = spec.a();
    let r = spec.releases();

    if spec.n() != n || spec.m() != m {
        v.push(format!("shape mismatch: spec {}x{}, schedule {n}x{m}", spec.n(), spec.m()));
    }

    // Non-negative fractions, normalization.
    for (k, &b) in sched.beta.iter().enumerate() {
        if b < -EPS {
            v.push(format!("beta[{}][{}] = {b} < 0", k / m, k % m));
        }
    }
    let norm_err = (sched.total_load() - spec.job).abs();
    if norm_err > EPS * spec.job.max(1.0) {
        v.push(format!("normalization error {norm_err}: total {} != J {}", sched.total_load(), spec.job));
    }

    // Window lengths.
    for i in 0..n {
        for j in 0..m {
            let k = i * m + j;
            let len = sched.comm_end[k] - sched.comm_start[k];
            let want = sched.beta[k] * g[i];
            if (len - want).abs() > EPS * want.max(1.0) {
                v.push(format!("window[{i}][{j}] length {len} != beta*G {want}"));
            }
        }
    }

    // Source sequential exclusivity (one send at a time, in P order).
    for i in 0..n {
        for j in 0..m.saturating_sub(1) {
            let k = i * m + j;
            if sched.comm_end[k] > sched.comm_start[k + 1] + EPS {
                v.push(format!(
                    "source {i} overlaps sends to P{} and P{}",
                    j + 1,
                    j + 2
                ));
            }
        }
    }

    // Processor receive exclusivity (receives in S order).
    for j in 0..m {
        for i in 0..n.saturating_sub(1) {
            let k = i * m + j;
            if sched.comm_end[k] > sched.comm_start[k + m] + EPS {
                v.push(format!(
                    "processor {j} receives from S{} and S{} concurrently",
                    i + 1,
                    i + 2
                ));
            }
        }
    }

    // Release times.
    for i in 0..n {
        if sched.comm_start[i * m] < r[i] - EPS {
            v.push(format!(
                "source {i} starts at {} before release {}",
                sched.comm_start[i * m],
                r[i]
            ));
        }
    }

    // Compute-timing rules.
    match sched.model {
        TimingModel::NoFrontEnd => {
            for j in 0..m {
                let total: f64 = (0..n).map(|i| sched.beta[i * m + j]).sum();
                if total <= EPS {
                    continue;
                }
                let last_arrival =
                    (0..n).fold(0.0f64, |acc, i| acc.max(sched.comm_end[i * m + j]));
                if sched.compute_start[j] < last_arrival - EPS {
                    v.push(format!(
                        "P{j} starts computing at {} before last arrival {last_arrival}",
                        sched.compute_start[j]
                    ));
                }
                let want_end = sched.compute_start[j] + total * a[j];
                if (sched.compute_end[j] - want_end).abs() > EPS * want_end.max(1.0) {
                    v.push(format!(
                        "P{j} compute window {} != start + busy {want_end}",
                        sched.compute_end[j]
                    ));
                }
            }
        }
        TimingModel::FrontEnd => {
            for j in 0..m {
                let total: f64 = (0..n).map(|i| sched.beta[i * m + j]).sum();
                if total <= EPS {
                    continue;
                }
                // Compute cannot start before the first byte arrives.
                let first = (0..n).find(|&i| sched.beta[i * m + j] > EPS).unwrap();
                if sched.compute_start[j] < sched.comm_start[first * m + j] - EPS {
                    v.push(format!("P{j} computes before any data arrives"));
                }
                // Compute cannot end before the last byte arrives.
                let last_arrival =
                    (0..n).fold(0.0f64, |acc, i| acc.max(sched.comm_end[i * m + j]));
                if sched.compute_end[j] < last_arrival - EPS {
                    v.push(format!(
                        "P{j} finishes computing at {} before last arrival {last_arrival}",
                        sched.compute_end[j]
                    ));
                }
                // Busy time fits inside the window.
                let window = sched.compute_end[j] - sched.compute_start[j];
                let busy = total * a[j];
                if window < busy - EPS * busy.max(1.0) {
                    v.push(format!("P{j} window {window} shorter than busy time {busy}"));
                }
                if window > busy + EPS * busy.max(1.0) {
                    w.push(format!(
                        "P{j} idles {:.6} inside its compute window (starvation gap)",
                        window - busy
                    ));
                }
            }
        }
    }

    // Makespan consistency.
    let realized = sched.realized_makespan();
    let slack = realized - sched.makespan;
    if slack > EPS * sched.makespan.max(1.0) {
        w.push(format!(
            "realized makespan {realized} exceeds LP T_f {} by {slack}",
            sched.makespan
        ));
    }

    // Idle-link diagnostics.
    let idle = sched.total_source_idle();
    if idle > EPS {
        w.push(format!("total source idle time {idle:.6}"));
    }

    ValidationReport {
        violations: v,
        warnings: w,
        normalization_error: norm_err,
        makespan_slack: slack,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dlt::frontend::FeOptions;
    use crate::dlt::no_frontend::NfeOptions;
    use crate::dlt::{single_source, Schedule};
    use crate::model::SystemSpec;

    fn fe_solve(spec: &SystemSpec) -> Schedule {
        crate::pipeline::solve(&FeOptions::default(), spec).unwrap()
    }

    fn nfe_solve(spec: &SystemSpec) -> Schedule {
        crate::pipeline::solve(&NfeOptions::default(), spec).unwrap()
    }

    fn table1() -> SystemSpec {
        SystemSpec::builder()
            .source(0.2, 10.0)
            .source(0.4, 50.0)
            .processors(&[2.0, 3.0, 4.0, 5.0, 6.0])
            .job(100.0)
            .build()
            .unwrap()
    }

    fn table2() -> SystemSpec {
        SystemSpec::builder()
            .source(0.2, 0.0)
            .source(0.2, 5.0)
            .processors(&[2.0, 3.0, 4.0])
            .job(100.0)
            .build()
            .unwrap()
    }

    #[test]
    fn frontend_schedule_validates() {
        let spec = table1();
        let s = fe_solve(&spec);
        let rep = validate(&spec, &s);
        assert!(rep.is_valid(), "violations: {:?}", rep.violations);
    }

    #[test]
    fn no_frontend_schedule_validates() {
        let spec = table2();
        let s = nfe_solve(&spec);
        let rep = validate(&spec, &s);
        assert!(rep.is_valid(), "violations: {:?}", rep.violations);
        assert!(rep.makespan_slack.abs() < 1e-5, "slack {}", rep.makespan_slack);
    }

    #[test]
    fn closed_form_schedule_validates() {
        let s = single_source::solve(0.2, &[2.0, 3.0, 4.0], 100.0, 0.0).unwrap();
        let spec = SystemSpec::builder()
            .source(0.2, 0.0)
            .processors(&[2.0, 3.0, 4.0])
            .job(100.0)
            .build()
            .unwrap();
        let rep = validate(&spec, &s);
        assert!(rep.is_valid(), "{:?}", rep.violations);
    }

    #[test]
    fn corrupted_schedule_is_caught() {
        let spec = table2();
        let mut s = nfe_solve(&spec);
        s.beta[0] += 5.0; // break normalization & window length
        let rep = validate(&spec, &s);
        assert!(!rep.is_valid());
        assert!(rep.violations.iter().any(|v| v.contains("normalization")));
    }

    #[test]
    fn overlapping_windows_are_caught() {
        let spec = table2();
        let mut s = nfe_solve(&spec);
        // Force source 0's second window to start before the first ends.
        s.comm_start[1] = s.comm_start[0];
        s.comm_end[1] = s.comm_start[1] + s.beta[1] * 0.2;
        let rep = validate(&spec, &s);
        assert!(!rep.is_valid());
    }
}
